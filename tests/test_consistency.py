"""Property-based offline↔online consistency — the paper's §2(3) guarantee.

Hypothesis generates random workloads (keys, timestamps, values, window
specs); the invariant is that the offline batch engine and the online
request-mode store compute the same features (within f32 tolerance), on
both the naive and pre-aggregated query paths.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (
    Col,
    FeatureView,
    TableSchema,
    range_window,
    rows_window,
    w_count,
    w_distinct_approx,
    w_max,
    w_mean,
    w_min,
    w_std,
    w_sum,
    w_topn_freq,
)
from repro.core.consistency import verify_view

SCHEMA = TableSchema(name="tx", key="uid", ts="ts", numeric=("amount",),
                     categorical=("mcc",))


def _workload(seed, n, k, tmax):
    rng = np.random.default_rng(seed)
    key = rng.integers(0, k, n).astype(np.int32)
    ts = np.sort(rng.integers(0, tmax, n)).astype(np.int32)
    return dict(
        uid=key, ts=ts,
        amount=rng.gamma(2.0, 40.0, n).astype(np.float32),
        mcc=rng.integers(0, 20, n).astype(np.int32),
    )


BIG_VIEW = FeatureView("all_aggs", SCHEMA, {
    "sum_r": w_sum(Col("amount"), range_window(500, bucket=64)),
    "mean_r": w_mean(Col("amount"), range_window(500, bucket=64)),
    "min_r": w_min(Col("amount"), range_window(500, bucket=64)),
    "max_r": w_max(Col("amount"), range_window(500, bucket=64)),
    "std_r": w_std(Col("amount"), range_window(500, bucket=64)),
    "cnt_rows": w_count(Col("amount"), rows_window(9)),
    "sum_rows": w_sum(Col("amount"), rows_window(9)),
    "distinct": w_distinct_approx(Col("mcc"), range_window(500, bucket=64)),
    "top1": w_topn_freq(Col("mcc"), rows_window(16), n=0),
    "derived": w_sum(Col("amount") * (Col("amount") > 50.0),
                     range_window(500, bucket=64)),
})


@pytest.mark.parametrize("mode", ["naive", "preagg"])
@pytest.mark.parametrize("seed", [0, 1])
def test_consistency_all_aggs(mode, seed):
    cols = _workload(seed, n=500, k=6, tmax=3000)
    rep = verify_view(
        BIG_VIEW, cols, num_keys=6, capacity=256, num_buckets=64,
        bucket_size=64, mode=mode,
    )
    assert rep.passed, rep.summary() + f" per-feature: {rep.per_feature}"


@settings(
    max_examples=12, deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
@given(
    seed=st.integers(0, 2**20),
    n=st.integers(50, 300),
    k=st.integers(1, 8),
    tmax=st.integers(200, 4000),
    wsize=st.integers(2, 900),
    mode=st.sampled_from(["naive", "preagg"]),
)
def test_consistency_property_range_windows(seed, n, k, tmax, wsize, mode):
    cols = _workload(seed, n, k, tmax)
    view = FeatureView("prop", SCHEMA, {
        "s": w_sum(Col("amount"), range_window(wsize, bucket=64)),
        "c": w_count(Col("amount"), range_window(wsize, bucket=64)),
        "mx": w_max(Col("amount"), range_window(wsize, bucket=64)),
    })
    rep = verify_view(
        view, cols, num_keys=k, capacity=512, num_buckets=64,
        bucket_size=64, mode=mode,
    )
    assert rep.passed, rep.summary() + f" per-feature: {rep.per_feature}"


@settings(
    max_examples=8, deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
@given(
    seed=st.integers(0, 2**20),
    wrows=st.integers(1, 30),
)
def test_consistency_property_rows_windows(seed, wrows):
    cols = _workload(seed, 200, 4, 2000)
    view = FeatureView("prop_rows", SCHEMA, {
        "s": w_sum(Col("amount"), rows_window(wrows)),
        "mn": w_min(Col("amount"), rows_window(wrows)),
    })
    rep = verify_view(
        view, cols, num_keys=4, capacity=256, num_buckets=64,
        bucket_size=64, mode="naive",
    )
    assert rep.passed, rep.summary() + f" per-feature: {rep.per_feature}"
