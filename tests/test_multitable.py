"""Multi-table feature plane: LAST JOIN + WINDOW UNION.

Offline engines are checked against brute-force numpy oracles; the
offline↔online guarantee is checked via consistency.verify_view on a
4-table view (both query paths) with interleaved multi-table replay.
"""

import numpy as np
import pytest

from repro.core import (
    Col,
    Database,
    FeatureView,
    OfflineEngine,
    OnlineFeatureStore,
    TableCol,
    TableSchema,
    last_join,
    range_window,
    rows_window,
    w_count,
    w_mean,
    w_std,
    w_sum,
    w_topn_freq,
)
from repro.core.consistency import verify_view
from repro.core.expr import LastJoin, WindowAgg, Agg

K = 8
NM = 4

DB = Database(
    name="mt",
    primary=TableSchema(
        "tx", key="acct", ts="ts", numeric=("amount", "merchant")
    ),
    secondary=(
        TableSchema("wires", key="acct", ts="ts", numeric=("amount",)),
        TableSchema("accounts", key="acct", ts="ts", numeric=("limit",)),
        TableSchema("merchants", key="merchant", ts="ts", numeric=("risk",)),
    ),
)


def make_tables(rng, n=300, t_max=2_000):
    # unique primary timestamps: window/join tie-semantics are positional at
    # equal (key, ts); unique ts keeps the numpy oracles unambiguous
    ts = np.sort(rng.choice(t_max, size=n, replace=False)).astype(np.int32)
    tx = dict(
        acct=rng.integers(0, K, n).astype(np.int32),
        ts=ts,
        amount=rng.gamma(2.0, 10.0, n).astype(np.float32),
        merchant=rng.integers(0, NM, n).astype(np.int32),
    )
    m = n // 2
    wires = dict(
        acct=rng.integers(0, K, m).astype(np.int32),
        ts=np.sort(rng.integers(0, t_max, m)).astype(np.int32),
        amount=rng.gamma(2.0, 10.0, m).astype(np.float32),
    )
    accounts = dict(
        acct=np.concatenate([np.arange(K), rng.integers(0, K, K)]).astype(
            np.int32
        ),
        ts=np.concatenate([np.zeros(K), rng.integers(1, t_max, K)]).astype(
            np.int32
        ),
        limit=rng.uniform(100.0, 1000.0, 2 * K).astype(np.float32),
    )
    merchants = dict(
        merchant=np.concatenate(
            [np.arange(NM), rng.integers(0, NM, NM)]
        ).astype(np.int32),
        ts=np.concatenate([np.zeros(NM), rng.integers(1, t_max, NM)]).astype(
            np.int32
        ),
        risk=rng.random(2 * NM).astype(np.float32),
    )
    sec = {"wires": wires, "accounts": accounts, "merchants": merchants}
    return tx, sec


def test_last_join_offline_matches_numpy():
    rng = np.random.default_rng(0)
    tx, sec = make_tables(rng)
    view = FeatureView(
        "lj",
        features={
            "risk": last_join(
                Col("risk"), "merchants", on="merchant", default=-1.0
            ),
            "limit": last_join(Col("limit"), "accounts", on="acct"),
        },
        database=DB,
    )
    res = OfflineEngine().compute(view, tx, sec)

    for fname, table, on, vcol, default in (
        ("risk", "merchants", "merchant", "risk", -1.0),
        ("limit", "accounts", "acct", "limit", 0.0),
    ):
        t = sec[table]
        kcol = DB.table(table).key
        ref = np.full(len(tx["ts"]), default, np.float32)
        for i in range(len(tx["ts"])):
            m = (t[kcol] == tx[on][i]) & (t["ts"] <= tx["ts"][i])
            if m.any():
                js = np.nonzero(m)[0]
                # newest ts; ties -> last in original order (stable sort)
                j = js[np.lexsort((js, t["ts"][js]))][-1]
                ref[i] = t[vcol][j]
        np.testing.assert_allclose(np.asarray(res[fname]), ref, rtol=1e-6)


def test_window_union_offline_matches_numpy():
    rng = np.random.default_rng(1)
    tx, sec = make_tables(rng)
    W = 300
    view = FeatureView(
        "wu",
        features={
            "s": w_sum(Col("amount"), range_window(W), union=("wires",)),
            "c": w_count(Col("amount"), range_window(W), union=("wires",)),
            "m": w_mean(Col("amount"), range_window(W), union=("wires",)),
        },
        database=DB,
    )
    res = OfflineEngine().compute(view, tx, sec)
    w = sec["wires"]
    n = len(tx["ts"])
    s_ref = np.zeros(n)
    c_ref = np.zeros(n)
    for i in range(n):
        lo = tx["ts"][i] - W + 1
        mp = (
            (tx["acct"] == tx["acct"][i])
            & (tx["ts"] >= lo)
            & (tx["ts"] <= tx["ts"][i])
        )
        mw = (
            (w["acct"] == tx["acct"][i])
            & (w["ts"] >= lo)
            & (w["ts"] <= tx["ts"][i])
        )
        s_ref[i] = tx["amount"][mp].sum() + w["amount"][mw].sum()
        c_ref[i] = mp.sum() + mw.sum()
    np.testing.assert_allclose(np.asarray(res["s"]), s_ref, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(res["c"]), c_ref, rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(res["m"]), s_ref / np.maximum(c_ref, 1.0), rtol=1e-4
    )


@pytest.mark.parametrize("mode", ["naive", "preagg"])
def test_multitable_consistency(mode):
    """Acceptance: verify_view passes on a >=3-table view using both a
    LAST JOIN feature and WINDOW UNION aggregations."""
    rng = np.random.default_rng(2)
    tx, sec = make_tables(rng, n=400)
    w1 = range_window(300, bucket=64)
    amt = Col("amount")
    credit = last_join(Col("limit"), "accounts", on="acct", default=500.0)
    view = FeatureView(
        "mtv",
        features={
            "limit": credit,
            "mrisk": last_join(
                Col("risk"), "merchants", on="merchant", default=0.5
            ),
            "out_sum": w_sum(amt, w1, union=("wires",)),
            "out_cnt": w_count(amt, w1, union=("wires",)),
            "out_std": w_std(amt, w1, union=("wires",)),
            "util": w_sum(amt, w1, union=("wires",)) / credit,
            "plain": w_mean(amt, w1),
        },
        database=DB,
    )
    rep = verify_view(
        view,
        tx,
        num_keys=K,
        secondary=sec,
        secondary_num_keys={"merchants": NM},
        mode=mode,
    )
    assert rep.passed, rep.summary()


def test_union_window_uses_bucket_preagg():
    """Union windows with a materialized primary lane must route their
    primary-stream part through the bucket pre-agg path (ROADMAP known
    limit closed) and still verify against the offline engine; oversized
    windows fall back to raw rings instead of raising."""
    rng = np.random.default_rng(5)
    tx, sec = make_tables(rng, n=400)
    amt = Col("amount")
    w1 = range_window(300, bucket=64)
    view = FeatureView(
        "upa",
        features={
            "s": w_sum(amt, w1, union=("wires",)),
            "m": w_mean(amt, w1, union=("wires",)),
            "sd": w_std(amt, w1, union=("wires",)),
        },
        database=DB,
    )
    store = OnlineFeatureStore(
        view, num_keys=K, num_buckets=64, bucket_size=64
    )
    # every union wagg of this view composes its primary part from buckets
    assert store._union_preagg and all(store._union_preagg.values())

    rep = verify_view(
        view, tx, num_keys=K, secondary=sec, mode="preagg",
        num_buckets=64, bucket_size=64,
    )
    assert rep.passed, rep.summary()

    # a window too long for the bucket ring falls back (no capacity error)
    wide = FeatureView(
        "upa_wide",
        features={
            "s": w_sum(amt, range_window(64 * 64 * 2, bucket=64),
                       union=("wires",)),
        },
        database=DB,
    )
    wide_store = OnlineFeatureStore(
        wide, num_keys=K, num_buckets=64, bucket_size=64
    )
    assert not any(wide_store._union_preagg.values())
    rep = verify_view(
        wide, tx, num_keys=K, secondary=sec, mode="preagg",
        num_buckets=64, bucket_size=64,
    )
    assert rep.passed, rep.summary()


def test_online_last_join_default_when_no_match():
    view = FeatureView(
        "d",
        features={
            "risk": last_join(
                Col("risk"), "merchants", on="merchant", default=-7.0
            )
        },
        database=DB,
    )
    store = OnlineFeatureStore(
        view, num_keys=K, secondary_num_keys={"merchants": NM}
    )
    req = dict(
        acct=np.zeros(3, np.int32),
        ts=np.full(3, 100, np.int32),
        amount=np.ones(3, np.float32),
        merchant=np.arange(3, dtype=np.int32),
    )
    out = store.query(req)
    np.testing.assert_allclose(np.asarray(out["risk"]), -7.0)
    # after ingesting one matching merchant row (ts below request ts),
    # that merchant resolves and the others keep the default
    store.ingest_table(
        "merchants",
        dict(
            merchant=np.array([1], np.int32),
            ts=np.array([50], np.int32),
            risk=np.array([0.25], np.float32),
        ),
    )
    out = store.query(req)
    np.testing.assert_allclose(
        np.asarray(out["risk"]), [-7.0, 0.25, -7.0]
    )
    # rows newer than the request ts stay invisible (point-in-time)
    store.ingest_table(
        "merchants",
        dict(
            merchant=np.array([2], np.int32),
            ts=np.array([500], np.int32),
            risk=np.array([0.9], np.float32),
        ),
    )
    out = store.query(req)
    np.testing.assert_allclose(
        np.asarray(out["risk"]), [-7.0, 0.25, -7.0]
    )


def test_lineage_sql_and_tables():
    credit = last_join(Col("limit"), "accounts", on="acct")
    view = FeatureView(
        "lin",
        features={
            "util": w_sum(Col("amount"), range_window(100), union=("wires",))
            / credit,
            "tc": last_join(TableCol("accounts", "limit"), "accounts", on="acct"),
        },
        database=DB,
    )
    assert view.tables == ["tx", "wires", "accounts"]
    lin = view.lineage()["util"]
    assert lin["tables"] == ["tx", "wires", "accounts"]
    assert "accounts.limit" in lin["columns"]
    assert lin["joins"] == [
        {"table": "accounts", "on": "acct", "default": 0.0}
    ]
    assert lin["windows"][0]["union"] == ["wires"]
    sql = lin["sql"]
    assert "UNION wires" in sql
    assert "LAST JOIN accounts" in sql
    assert "accounts.limit" in sql


def test_validation_errors():
    # union windows must be RANGE
    with pytest.raises(ValueError, match="RANGE"):
        w_sum(Col("a"), rows_window(10), union=("wires",))
    # every registered agg is union-composable since the unified algebra
    # (FIRST/TOPN_FREQ compose via extreme/tail states)
    for agg in Agg:
        WindowAgg(agg, Col("a"), range_window(10), union=("wires",))
    # no windows inside join args, no joins inside window args
    with pytest.raises(ValueError, match="row-level"):
        last_join(w_sum(Col("a"), range_window(10)), "wires", on="acct")
    with pytest.raises(ValueError, match="LAST JOIN"):
        w_sum(last_join(Col("a"), "wires", on="acct"), range_window(10))
    # views must only reference tables present in their database
    with pytest.raises(KeyError):
        FeatureView(
            "bad",
            features={"f": last_join(Col("x"), "nope", on="acct")},
            database=DB,
        )
    # a TableCol naming a different table inside a LAST JOIN arg
    with pytest.raises(ValueError, match="joined table only"):
        last_join(TableCol("accounts", "limit"), "merchants", on="merchant")
    # a TableCol outside any LAST JOIN has no table context
    with pytest.raises(ValueError, match="outside a LAST JOIN"):
        FeatureView(
            "stray",
            features={"f": TableCol("wires", "amount") + 1.0},
            database=DB,
        )
    # joining/unioning the primary table itself is unanswerable online
    with pytest.raises(ValueError, match="primary table"):
        FeatureView(
            "selfjoin",
            features={"f": last_join(Col("amount"), "tx", on="acct")},
            database=DB,
        )
    with pytest.raises(ValueError, match="primary table"):
        FeatureView(
            "selfunion",
            features={"f": w_sum(Col("amount"), range_window(10), union=("tx",))},
            database=DB,
        )
    # schema-only views still work and synthesize a database
    v = FeatureView("ok", DB.primary, {"f": Col("amount")})
    assert v.database.primary is DB.primary
    assert v.tables == ["tx"]
    # an equal-but-distinct schema object is accepted alongside a database
    schema_copy = TableSchema(
        "tx", key="acct", ts="ts", numeric=("amount", "merchant")
    )
    v2 = FeatureView("ok2", schema_copy, {"f": Col("amount")}, database=DB)
    assert v2.database is DB
    # a genuinely different schema is rejected
    with pytest.raises(ValueError, match="must equal"):
        FeatureView(
            "bad2", TableSchema("other", key="k", ts="ts"), {}, database=DB
        )
