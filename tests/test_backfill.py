"""Offline backfill bridge + point-in-time training-set export (ISSUE 7).

Two contracts under test:

* **Export**: ``export_training_set`` over a multi-table view (LAST JOINs
  + a WINDOW UNION stream) equals an online replay row-for-row — at label
  times *beyond* the online rings' retention horizon, across shard
  counts — because both sides answer point-in-time per row.
* **Backfill**: migrations that used to refuse or report ``exact=False``
  because history aged out of the rings (capacity grow after wrap; a new
  hash lane underivable from stored f32 columns) complete **bit-exactly**
  when given a :class:`~repro.offline.BackfillSource`, verified against a
  cold rebuild + full replay.  Unsynthesizable backfills still refuse
  loudly, naming the view and features.

Runs multi-device via conftest's host-platform device count.
"""

import jax
import numpy as np
import pytest

from repro.core import (
    Col,
    FeatureView,
    ScenarioPlane,
    Signature,
    range_window,
    w_count,
    w_first,
    w_sum,
    w_topn_freq,
)
from repro.core.layout import plan_layout
from repro.data.synthetic import MULTITABLE_DB, multitable_stream
from repro.offline import BackfillSource, export_training_set, verify_export
from repro.scenarios import multi_scenario_views, multi_table_view

K = 16            # accounts: few keys so rings wrap fast
NM = 8            # merchants
ROWS = 600
T_MAX = 60_000    # t_max/bucket_size=937 < num_buckets: no bucket wrap
SMALL_CAP = 16    # << rows/key (~37): primary rings age out most rows
GROWN_CAP = 64
SEC_NK = {"merchants": NM}
KW = dict(
    num_keys=K, capacity=SMALL_CAP, num_buckets=1024, bucket_size=64,
    secondary_num_keys=SEC_NK,
)


@pytest.fixture(scope="module")
def tabs():
    rng = np.random.default_rng(17)
    return multitable_stream(
        rng, ROWS, num_accounts=K, num_merchants=NM, t_max=T_MAX
    )


def bykey(d, kc):
    o = np.lexsort((d["ts"], d[kc]))
    return {c: v[o] for c, v in d.items()}


def warm(plane, tabs):
    sec = {t: c for t, c in tabs.items() if t != "transactions"}
    for t in plane.store._sec_names:
        kc = MULTITABLE_DB.table(t).key
        plane.ingest_table(t, bykey(sec[t], kc))
    plane.ingest(bykey(tabs["transactions"], "account"))


def states_equal(a, b) -> bool:
    la = jax.tree_util.tree_leaves(a.store.state)
    lb = jax.tree_util.tree_leaves(b.store.state)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb)
    )


def sig_view() -> FeatureView:
    """A view whose window argument is a hash (Signature) lane — never
    synthesizable from stored f32 columns, so deploying it onto a warm
    plane used to be refused outright."""
    w1h = range_window(3600, bucket=64)
    return FeatureView(
        name="merchant_mix",
        features={
            "sig_cnt_1h": w_count(Signature((Col("merchant"),), bits=8), w1h),
            "sig_sum_1h": w_sum(Signature((Col("merchant"),), bits=8), w1h),
        },
        database=MULTITABLE_DB,
    )


# ---------------------------------------------------------------------------
# training-set export == online replay
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def training(tabs):
    view = multi_table_view()
    secondary = {t: c for t, c in tabs.items() if t != "transactions"}
    return export_training_set(
        view, tabs["transactions"], n=64, seed=3, secondary=secondary,
    )


def test_labels_straddle_retention_horizon(tabs, training):
    """The sampled label rows must include rows the online rings have
    aged out by end of replay — otherwise the export test would only
    cover the easy, still-retained regime."""
    tx = tabs["transactions"]
    key, ts = tx["account"], tx["ts"]
    newer = np.array([
        int(((key == key[i]) & (ts > ts[i])).sum()) for i in training.rows
    ])
    assert (newer >= SMALL_CAP).any(), (
        "no label row beyond the retention horizon; shrink capacity or "
        "grow the stream"
    )
    assert (newer < SMALL_CAP).any(), "no label row inside the horizon"


@pytest.mark.parametrize("shards", [1, 4, 8])
def test_export_matches_online_replay(tabs, training, shards):
    view = multi_table_view()
    secondary = {t: c for t, c in tabs.items() if t != "transactions"}
    check = verify_export(
        view, tabs["transactions"], training,
        num_keys=K,
        capacity=SMALL_CAP,
        secondary=secondary,
        secondary_num_keys=SEC_NK,
        num_shards=None if shards == 1 else shards,
    )
    assert check.passed, check.summary()
    assert check.label_rows == len(training)


# ---------------------------------------------------------------------------
# backfilled migrations: previously inexact / refused -> bit-exact
# ---------------------------------------------------------------------------


def test_capacity_grow_inexact_without_backfill(tabs):
    views = multi_scenario_views()
    plane = ScenarioPlane(views[:2], num_shards=4, **KW)
    warm(plane, tabs)
    report = plane.evolve(views[:3], capacity=GROWN_CAP)
    assert not report.exact
    assert report.deficits, "expected an aged-out-history deficit"


@pytest.mark.parametrize("shards", [None, 4])
def test_capacity_grow_backfill_bit_exact(tabs, shards):
    views = multi_scenario_views()
    plane = ScenarioPlane(views[:2], num_shards=shards, **KW)
    warm(plane, tabs)
    src = BackfillSource(MULTITABLE_DB, tabs)
    report = plane.evolve(views[:3], backfill=src, capacity=GROWN_CAP)
    assert report.exact, report.notes
    assert report.backfilled, "expected spliced deficits in the report"

    cold = ScenarioPlane(
        views[:3], num_shards=shards, **dict(KW, capacity=GROWN_CAP)
    )
    warm(cold, tabs)
    assert states_equal(plane, cold), "backfilled state != rebuild+replay"

    probe = {c: v[:16] for c, v in tabs["transactions"].items()}
    hot_q = plane.query(views[2].name, probe)
    cold_q = cold.query(views[2].name, probe)
    for f, v in hot_q.items():
        np.testing.assert_array_equal(np.asarray(v), np.asarray(cold_q[f]))


def test_refused_hash_lane_backfill_bit_exact(tabs):
    views = multi_scenario_views()
    target = views[:2] + [sig_view()]
    plane = ScenarioPlane(views[:2], num_shards=4, **KW)
    warm(plane, tabs)

    # without a source: refused outright (hash lanes are unsynthesizable)
    with pytest.raises(ValueError, match="rebuild"):
        plane.evolve(target, capacity=GROWN_CAP)

    src = BackfillSource(MULTITABLE_DB, tabs)
    report = plane.evolve(target, backfill=src, capacity=GROWN_CAP)
    assert report.exact, report.notes
    assert report.backfilled

    cold = ScenarioPlane(
        target, num_shards=4, **dict(KW, capacity=GROWN_CAP)
    )
    warm(cold, tabs)
    assert states_equal(plane, cold), "backfilled state != rebuild+replay"

    probe = {c: v[:16] for c, v in tabs["transactions"].items()}
    hot_q = plane.query("merchant_mix", probe)
    cold_q = cold.query("merchant_mix", probe)
    for f, v in hot_q.items():
        np.testing.assert_array_equal(np.asarray(v), np.asarray(cold_q[f]))


def order_view() -> FeatureView:
    """A view whose bucket state is the merge-order families (FIRST/TOPN
    over range windows) — deployed onto a warm plane whose rings have
    already aged out history, the families can only be rebuilt exactly
    from offline history."""
    w1h = range_window(3600, bucket=64)
    return FeatureView(
        name="order_mix",
        features={
            "amt_first_1h": w_first(Col("amount"), w1h),
            "amt_top_1h": w_topn_freq(Col("amount"), w1h, n=0),
        },
        database=MULTITABLE_DB,
    )


@pytest.mark.parametrize("shards", [None, 4])
def test_merge_order_family_backfill_bit_exact(tabs, shards):
    views = multi_scenario_views()
    target = views[:2] + [order_view()]

    # without a source: the families are rebuilt from ring-retained rows
    # only — a bucket deficit, not silence
    plane = ScenarioPlane(views[:2], num_shards=shards, **KW)
    warm(plane, tabs)
    report = plane.evolve(target, capacity=GROWN_CAP)
    assert not report.exact
    assert any(d.target == "bucket" for d in report.deficits), (
        report.deficits
    )

    # with the bridge: full-history re-derivation, bit-exact vs cold
    plane2 = ScenarioPlane(views[:2], num_shards=shards, **KW)
    warm(plane2, tabs)
    src = BackfillSource(MULTITABLE_DB, tabs)
    report2 = plane2.evolve(target, backfill=src, capacity=GROWN_CAP)
    assert report2.exact, report2.notes
    assert report2.backfilled

    cold = ScenarioPlane(
        target, num_shards=shards, **dict(KW, capacity=GROWN_CAP)
    )
    warm(cold, tabs)
    assert states_equal(plane2, cold), "backfilled state != rebuild+replay"

    probe = {c: v[:16] for c, v in tabs["transactions"].items()}
    for mode in ("preagg", "naive"):
        hot_q = plane2.query("order_mix", probe, mode=mode)
        cold_q = cold.query("order_mix", probe, mode=mode)
        for f, v in hot_q.items():
            np.testing.assert_array_equal(
                np.asarray(v), np.asarray(cold_q[f]), err_msg=f"{mode} {f}"
            )


# ---------------------------------------------------------------------------
# unsynthesizable backfills refuse loudly, naming the offender
# ---------------------------------------------------------------------------


def test_splice_refuses_missing_column_naming_view(tabs):
    views = multi_scenario_views()
    plane = ScenarioPlane(views[:2], num_shards=4, **KW)
    warm(plane, tabs)
    # history lacks 'amount' — the primary ring rebuild cannot re-derive
    # its lanes, so the splice must refuse (atomically, before anything
    # goes live) and say which view is blocked and what is missing
    crippled = {
        t: {c: v for c, v in cols.items() if c != "amount"}
        for t, cols in tabs.items()
    }
    src = BackfillSource(MULTITABLE_DB, crippled)
    with pytest.raises(ValueError) as ei:
        plane.evolve(views[:3], backfill=src, capacity=GROWN_CAP)
    msg = str(ei.value)
    assert "cannot backfill" in msg
    assert "amount" in msg
    assert "extend the backfill source" in msg


def test_splice_refuses_missing_table(tabs):
    views = multi_scenario_views()
    plane = ScenarioPlane(views[:2], num_shards=4, **KW)
    warm(plane, tabs)
    src = BackfillSource(
        MULTITABLE_DB,
        {t: c for t, c in tabs.items() if t != "transactions"},
    )
    with pytest.raises(ValueError, match="no history for table"):
        plane.evolve(views[:3], backfill=src, capacity=GROWN_CAP)


def test_source_validates_tables_and_columns(tabs):
    with pytest.raises(KeyError):
        BackfillSource(MULTITABLE_DB, {"nope": tabs["transactions"]})
    with pytest.raises(ValueError, match="required"):
        BackfillSource(
            MULTITABLE_DB,
            {"transactions": {
                c: v for c, v in tabs["transactions"].items() if c != "ts"
            }},
        )
    with pytest.raises(ValueError, match="ragged"):
        BackfillSource(
            MULTITABLE_DB,
            {"transactions": dict(
                tabs["transactions"], amount=tabs["transactions"]["amount"][:5]
            )},
        )


# ---------------------------------------------------------------------------
# per-table retention knobs (satellite: planner capacity/TTL overrides)
# ---------------------------------------------------------------------------


def test_per_table_capacity_selective_backfill(tabs):
    """A short-retention table triggers backfill where a long one carries
    verbatim: only the wires ring (capacity 4, wrapped) is deficient on a
    grow; the roomy primary ring migrates exactly with no backfill."""
    views = multi_scenario_views()
    kw = dict(KW, capacity=128, table_capacity={"wires": 4})
    plane = ScenarioPlane(views[:2], num_shards=4, **kw)
    warm(plane, tabs)

    probe_kw = dict(capacity=128, table_capacity={"wires": 32})
    report = plane.evolve(views[:2], **probe_kw)
    assert not report.exact
    assert all("wires" in d.describe() for d in report.deficits), (
        report.describe()
    )

    plane2 = ScenarioPlane(views[:2], num_shards=4, **kw)
    warm(plane2, tabs)
    src = BackfillSource(MULTITABLE_DB, tabs)
    report2 = plane2.evolve(views[:2], backfill=src, **probe_kw)
    assert report2.exact, report2.notes
    assert all("wires" in b for b in report2.backfilled)

    cold = ScenarioPlane(
        views[:2], num_shards=4,
        **dict(KW, capacity=128, table_capacity={"wires": 32}),
    )
    warm(cold, tabs)
    assert states_equal(plane2, cold)


def test_planner_knobs_land_on_rings_and_validate():
    views = multi_scenario_views()
    lay = plan_layout(
        views, num_keys=K, capacity=32, num_buckets=1024,
        secondary_num_keys=SEC_NK,
        table_capacity={"wires": 8, "transactions": 64},
        table_ttl={"wires": 4000},
    )
    assert lay.primary.capacity == 64 and lay.primary.ttl is None
    by_table = {rp.table: rp for rp in lay.tables}
    assert by_table["wires"].capacity == 8
    assert by_table["wires"].ttl == 4000
    assert all(
        rp.capacity == 32 for t, rp in by_table.items() if t != "wires"
    )
    with pytest.raises(ValueError, match="unknown table"):
        plan_layout(
            views, num_keys=K, num_buckets=1024, secondary_num_keys=SEC_NK,
            table_capacity={"nope": 8},
        )
    with pytest.raises(ValueError, match="unknown table"):
        plan_layout(
            views, num_keys=K, num_buckets=1024, secondary_num_keys=SEC_NK,
            table_ttl={"nope": 60},
        )
