"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.signature.ops import signature_embed
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref
from repro.kernels.window_agg.ops import window_stats


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_SHAPES = [
    # B, H, Hkv, S, D, causal, window
    (2, 4, 2, 256, 64, True, None),
    (1, 8, 8, 128, 128, True, 64),
    (2, 4, 1, 192, 80, False, None),   # partial blocks + MQA + pad D
    (1, 2, 2, 100, 32, True, 32),      # odd seq
    (2, 16, 4, 128, 128, True, None),  # GQA 4:1
    (1, 4, 4, 384, 64, True, 128),     # window == block
]


@pytest.mark.parametrize("B,H,Hkv,S,D,causal,window", FA_SHAPES)
def test_flash_attention_matches_ref(B, H, Hkv, S, D, causal, window):
    rng = np.random.default_rng(hash((B, H, S, D)) % 2**31)
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    out = attention(q, k, v, causal=causal, window=window,
                    impl="pallas", interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 4, 128, 64)), dtype)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), dtype)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), dtype)
    out = attention(q, k, v, impl="pallas", interpret=True)
    ref = attention_ref(q, k, v)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32),
        atol=3e-2 if dtype == jnp.bfloat16 else 2e-5, rtol=3e-2,
    )


def test_flash_attention_blocks_sweep():
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    ref = attention_ref(q, k, v)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        out = attention(q, k, v, impl="pallas", interpret=True,
                        block_q=bq, block_k=bk)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

WKV_SHAPES = [(2, 3, 64, 32), (1, 2, 100, 64), (2, 4, 128, 64), (1, 1, 16, 16)]


@pytest.mark.parametrize("B,H,T,D", WKV_SHAPES)
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_wkv6_matches_recurrence(B, H, T, D, impl):
    rng = np.random.default_rng(hash((B, H, T, D)) % 2**31)
    r = jnp.asarray(rng.normal(size=(B, H, T, D)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, T, D)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    lw = jnp.asarray(-np.exp(rng.normal(size=(B, H, T, D)) - 1.0), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, D)) * 0.3, jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(B, H, D, D)) * 0.1, jnp.float32)
    y_ref, s_ref = wkv6_ref(r, k, v, lw, u, s0)
    y, s = wkv6(r, k, v, lw, u, s0, impl=impl, interpret=True)
    np.testing.assert_allclose(y, y_ref, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(s, s_ref, atol=5e-4, rtol=5e-4)


def test_wkv6_state_chaining():
    """Running two halves with carried state == running the whole sequence."""
    rng = np.random.default_rng(11)
    B, H, T, D = 1, 2, 64, 32
    r = jnp.asarray(rng.normal(size=(B, H, T, D)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, T, D)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    lw = jnp.asarray(-np.exp(rng.normal(size=(B, H, T, D)) - 1.0), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, D)) * 0.3, jnp.float32)
    y_full, s_full = wkv6(r, k, v, lw, u, impl="xla")
    h = T // 2
    y1, s1 = wkv6(r[:, :, :h], k[:, :, :h], v[:, :, :h], lw[:, :, :h], u,
                  impl="xla")
    y2, s2 = wkv6(r[:, :, h:], k[:, :, h:], v[:, :, h:], lw[:, :, h:], u,
                  s0=s1, impl="xla")
    np.testing.assert_allclose(
        jnp.concatenate([y1, y2], axis=2), y_full, atol=1e-4, rtol=1e-4
    )
    np.testing.assert_allclose(s2, s_full, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# signature embedding
# ---------------------------------------------------------------------------

SIG_SHAPES = [(512, 128, 64, 2), (1024, 256, 33, 4), (256, 64, 7, 1)]


@pytest.mark.parametrize("V,D,N,k", SIG_SHAPES)
def test_signature_embed_matches_ref(V, D, N, k):
    rng = np.random.default_rng(hash((V, D, N, k)) % 2**31)
    table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
    sig = jnp.asarray(rng.integers(0, 2**20, N), jnp.int32)
    w = jnp.asarray(rng.normal(size=(k,)), jnp.float32)
    a = signature_embed(table, sig, w, num_hashes=k, impl="xla")
    b = signature_embed(table, sig, w, num_hashes=k, impl="pallas",
                        interpret=True)
    np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_signature_embed_deterministic():
    table = jnp.ones((64, 32), jnp.float32)
    sig = jnp.asarray([5, 5, 5], jnp.int32)
    w = jnp.asarray([0.5, 0.5], jnp.float32)
    out = signature_embed(table, sig, w, num_hashes=2, impl="pallas",
                          interpret=True)
    assert np.allclose(out[0], out[1]) and np.allclose(out[1], out[2])


# ---------------------------------------------------------------------------
# window_agg
# ---------------------------------------------------------------------------

def _make_store_state(rng, K, N, capacity=128, num_buckets=64, bucket=64):
    from repro.core import (
        Col, FeatureView, TableSchema, range_window, w_mean, w_sum,
    )
    from repro.core.online import OnlineFeatureStore

    schema = TableSchema(name="tx", key="uid", ts="ts", numeric=("amount",))
    view = FeatureView("v", schema, {
        "s": w_sum(Col("amount"), range_window(600, bucket=bucket)),
        "m": w_mean(Col("amount"), range_window(600, bucket=bucket)),
    })
    store = OnlineFeatureStore(view, num_keys=K, capacity=capacity,
                               num_buckets=num_buckets, bucket_size=bucket)
    key = np.sort(rng.integers(0, K, N)).astype(np.int32)
    ts = rng.integers(0, 4000, N).astype(np.int32)
    order = np.lexsort((ts, key))
    cols = dict(uid=key[order], ts=ts[order],
                amount=rng.gamma(2.0, 50.0, N).astype(np.float32))
    store.ingest(cols)
    return store


@pytest.mark.parametrize("Q,windows", [(16, (600,)), (37, (600, 100)),
                                       (5, (64, 600, 1200))])
def test_window_stats_kernel_matches_ref(Q, windows):
    rng = np.random.default_rng(hash((Q, windows)) % 2**31)
    store = _make_store_state(rng, K=9, N=800)
    qk = jnp.asarray(rng.integers(0, 9, Q), jnp.int32)
    qt = jnp.asarray(rng.integers(3000, 4200, Q), jnp.int32)
    qv = rng.gamma(2.0, 50.0, Q).astype(np.float32)
    qlanes = store._lanes(dict(uid=qk, ts=qt, amount=qv))
    args = (store.state.ring.ts, store.state.ring.vals,
            store.state.bagg.stats, store.state.bagg.bucket, qk, qt, qlanes)
    ref = window_stats(*args, windows=windows, bucket_size=64, impl="xla")
    pal = window_stats(*args, windows=windows, bucket_size=64,
                       impl="pallas", interpret=True)
    np.testing.assert_allclose(ref, pal, atol=1e-3, rtol=1e-5)


def test_window_stats_kernel_matches_online_store():
    rng = np.random.default_rng(99)
    store = _make_store_state(rng, K=9, N=800)
    Q = 25
    qk = jnp.asarray(rng.integers(0, 9, Q), jnp.int32)
    qt = jnp.asarray(rng.integers(3000, 4200, Q), jnp.int32)
    qv = rng.gamma(2.0, 50.0, Q).astype(np.float32)
    qcols = dict(uid=qk, ts=qt, amount=qv)
    qlanes = store._lanes(qcols)
    stats = window_stats(
        store.state.ring.ts, store.state.ring.vals, store.state.bagg.stats,
        store.state.bagg.bucket, qk, qt, qlanes,
        windows=(600,), bucket_size=64, impl="pallas", interpret=True,
    )
    res = store.query(qcols, mode="preagg")
    np.testing.assert_allclose(
        stats[:, 0, 0, 0], res["s"], rtol=1e-4, atol=1e-2
    )
    np.testing.assert_allclose(
        stats[:, 0, 0, 0] / stats[:, 0, 0, 1], res["m"], rtol=1e-4, atol=1e-2
    )


# ---------------------------------------------------------------------------
# segmented-combine fold levels (offline window scan hot loop)
# ---------------------------------------------------------------------------

from repro.kernels.window_agg.ops import fold_levels
from repro.kernels.window_agg.ref import fold_levels_ref, fold_num_levels


def _seg_starts(key):
    from repro.core.windows import segment_starts

    return segment_starts(jnp.asarray(key, jnp.int32))


@pytest.mark.parametrize("N", [5, 100, 1024, 4097])
@pytest.mark.parametrize("op", ["min", "max", "or"])
def test_fold_levels_kernel_matches_ref(N, op):
    import zlib

    # zlib.crc32, not hash(): str hashing is randomized per process and
    # would make any parity failure unreproducible
    rng = np.random.default_rng(zlib.crc32(f"{N}-{op}".encode()) % 2**31)
    key = np.sort(rng.integers(0, 7, N)).astype(np.int32)
    seg = _seg_starts(key)
    if op == "or":
        x = jnp.asarray(rng.integers(-2**31, 2**31 - 1, N), jnp.int32)
    else:
        x = jnp.asarray(rng.normal(size=N).astype(np.float32))
    ref = fold_levels_ref(x, seg, op)
    pal = fold_levels(x, seg, op=op, impl="pallas", interpret=True)
    assert ref.shape == (fold_num_levels(N), N)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


@pytest.mark.parametrize(
    "N,tile_rows",
    [
        ((1 << 17) - 100, 512),  # just under the old VMEM cap, 2 tiles
        ((1 << 17) + 1, 512),    # first size the old dispatcher refused
        ((1 << 17) + 300, None), # non-pow2 straddle, default (single) tile
        (1 << 17, 1024),         # exact old cap, tile == row count
    ],
)
def test_fold_levels_tiled_straddles_old_cap(N, tile_rows):
    """The grid-tiled kernel is exact right across the old 2^17 cutoff.

    Forced-small ``tile_rows`` drives the multi-tile boundary carries
    (lane-carry, row-straddle, whole-tile DMA) in interpret mode without
    needing 10^7-row inputs; the ``None`` case covers the single-tile
    shrink path on a non-pow2 size.
    """
    import zlib

    rng = np.random.default_rng(zlib.crc32(f"straddle-{N}".encode()) % 2**31)
    key = np.sort(rng.integers(0, 13, N)).astype(np.int32)
    seg = _seg_starts(key)
    x = jnp.asarray(rng.normal(size=N).astype(np.float32))
    ref = fold_levels_ref(x, seg, "min")
    pal = fold_levels(x, seg, op="min", impl="pallas", interpret=True,
                      tile_rows=tile_rows)
    assert pal.shape == (fold_num_levels(N), N)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


def test_fold_levels_single_and_multi_tile_edges():
    """Tile-edge shapes: exactly one tile, one row over, tiny tiles."""
    import zlib

    for N, tr in [(8 * 128, 8),        # rows == tile_rows exactly
                  (8 * 128 + 1, 8),    # one element spills a new tile
                  (128, 8),            # single row, single tile
                  (3 * 128, 16)]:      # rows < tile_rows -> tile shrinks
        rng = np.random.default_rng(zlib.crc32(f"edge-{N}-{tr}".encode()))
        key = np.sort(rng.integers(0, 3, N)).astype(np.int32)
        seg = _seg_starts(key)
        x = jnp.asarray(rng.integers(-2**31, 2**31 - 1, N), jnp.int32)
        ref = fold_levels_ref(x, seg, "or")
        pal = fold_levels(x, seg, op="or", impl="pallas", interpret=True,
                          tile_rows=tr)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


def test_fold_levels_auto_has_no_size_cap():
    """impl="auto" resolves to Pallas on TPU at ANY size — the old
    ``_FOLD_PALLAS_MAX_ROWS`` fallback band (2^17..10^7) is gone."""
    from repro.kernels.window_agg.ops import _resolve_fold_impl

    for n in [1, 1 << 17, (1 << 17) + 1, 10**6, 10**7]:
        assert _resolve_fold_impl(n, "tpu") == "pallas"
        assert _resolve_fold_impl(n, "cpu") == "xla"
    # explicit impl always wins
    assert _resolve_fold_impl(10**7, "cpu", "pallas") == "pallas"
    assert _resolve_fold_impl(100, "tpu", "xla") == "xla"


def test_fold_levels_windowed_query_vs_bruteforce():
    """Levels + the two-gather idempotent query == brute-force window min."""
    from repro.core.windows import (
        segment_starts, segmented_windowed_fold, window_start_rows,
    )

    rng = np.random.default_rng(11)
    N = 777
    key = np.sort(rng.integers(0, 5, N)).astype(np.int32)
    x = jnp.asarray(rng.normal(size=N).astype(np.float32))
    seg = segment_starts(jnp.asarray(key))
    j = window_start_rows(seg, 37)
    out = np.asarray(segmented_windowed_fold(x, seg, j, "min"))
    xs, jn = np.asarray(x), np.asarray(j)
    ref = np.array([xs[jn[i]:i + 1].min() for i in range(N)])
    np.testing.assert_array_equal(out, ref)


# -- route-rank (device-resident request routing) ---------------------------

from repro.kernels.route.ops import route_rank
from repro.kernels.route.ref import route_rank_ref


@pytest.mark.parametrize("n,S", [(1, 1), (16, 4), (33, 8), (257, 3), (512, 8)])
def test_route_rank_pallas_matches_ref(n, S):
    """Pallas rank-within-shard == one-hot cumsum oracle, exactly —
    rank is batch-order position within the row's shard, counts are
    rows per shard."""
    rng = np.random.default_rng(n + S)
    shard = rng.integers(0, S, n).astype(np.int32)
    r_ref, c_ref = route_rank_ref(jnp.asarray(shard), S)
    r_pal, c_pal = route_rank(
        jnp.asarray(shard), num_shards=S, impl="pallas", interpret=True
    )
    np.testing.assert_array_equal(np.asarray(r_ref), np.asarray(r_pal))
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_pal))
    # rank is a bijection into [0, count) per shard
    for s in range(S):
        got = np.sort(np.asarray(r_ref)[shard == s])
        np.testing.assert_array_equal(got, np.arange(len(got)))


def test_route_rank_skewed_and_empty_shards():
    """All rows on one shard (worst skew) and shards owning nothing."""
    n, S = 96, 8
    shard = np.full(n, 5, np.int32)
    rank, counts = route_rank(
        jnp.asarray(shard), num_shards=S, impl="pallas", interpret=True
    )
    np.testing.assert_array_equal(np.asarray(rank), np.arange(n))
    want = np.zeros(S, np.int32)
    want[5] = n
    np.testing.assert_array_equal(np.asarray(counts), want)
