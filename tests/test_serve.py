"""Serving layer: micro-batch deadlines, tail-latency stats, shard router.

Covers the BatchScheduler ``max_wait_us`` deadline (partial batches flush
on timeout), ServiceStats percentiles, FeatureService.build(sharded=True)
opt-in, and the ShardRouter submit -> pump -> scatter-back loop end to
end against both store flavours (answers must agree exactly).
"""

import numpy as np
import pytest

from repro.core import Col, FeatureView, range_window, rows_window, w_count, w_mean, w_sum
from repro.core.shard import ShardedOnlineStore
from repro.data.synthetic import FRAUD_SCHEMA, fraud_stream
from repro.serve.router import ShardRouter
from repro.serve.service import BatchScheduler, FeatureService, ServiceStats


def fraud_view() -> FeatureView:
    amt = Col("amount")
    w1 = range_window(600, bucket=64)
    return FeatureView(
        "serve_t",
        FRAUD_SCHEMA,
        {
            "s": w_sum(amt, w1),
            "m": w_mean(amt, w1),
            "c5": w_count(amt, rows_window(5)),
        },
    )


def _rows(rng, n, t0=100_000):
    return [
        dict(
            card=int(rng.integers(0, 32)),
            ts=int(t0 + i),
            amount=float(rng.gamma(1.5, 60.0)),
            mcc=int(rng.integers(0, 32)),
            device=int(rng.integers(0, 8)),
            geo=int(rng.integers(0, 16)),
        )
        for i in range(n)
    ]


# -- BatchScheduler deadline ---------------------------------------------------

def test_scheduler_waits_until_deadline():
    s = BatchScheduler(max_batch=8, max_wait_us=500)
    s.submit({"k": 1}, now_us=0)
    s.submit({"k": 2}, now_us=100)
    # neither full nor expired: keep coalescing
    assert s.next_batch(now_us=300) is None
    assert len(s.queue) == 2
    # oldest request hits the 500us deadline -> partial batch flushes
    b = s.next_batch(now_us=500)
    assert b is not None
    assert int(b["__valid__"].sum()) == 2
    assert s.next_batch(now_us=501) is None  # queue drained


def test_scheduler_full_batch_preempts_deadline():
    s = BatchScheduler(max_batch=2, max_wait_us=10_000)
    s.submit({"k": 1}, now_us=0)
    assert s.next_batch(now_us=1) is None
    s.submit({"k": 2}, now_us=2)
    b = s.next_batch(now_us=3)  # full batch flushes immediately
    assert b is not None and int(b["__valid__"].sum()) == 2


def test_scheduler_flush_overrides_deadline():
    s = BatchScheduler(max_batch=8, max_wait_us=10_000)
    s.submit({"k": 1}, now_us=0)
    assert s.next_batch(now_us=1) is None
    b = s.next_batch(now_us=1, flush=True)
    assert b is not None and int(b["__valid__"].sum()) == 1


def test_scheduler_no_deadline_is_immediate():
    s = BatchScheduler()
    s.submit({"k": 1})
    b = s.next_batch()
    assert b is not None and int(b["__valid__"].sum()) == 1


def test_scheduler_deadline_fifo_across_batches():
    s = BatchScheduler(buckets=(1, 4), max_batch=4, max_wait_us=100)
    for i in range(6):
        s.submit({"k": i}, now_us=i)
    b1 = s.next_batch(now_us=105)
    assert list(b1["k"][b1["__valid__"]]) == [0, 1, 2, 3]
    # remaining two flush when *their* oldest (submitted at t=4) expires
    assert s.next_batch(now_us=103) is None
    b2 = s.next_batch(now_us=104 + 100)
    assert list(b2["k"][b2["__valid__"]]) == [4, 5]


# -- ServiceStats percentiles --------------------------------------------------

def test_service_stats_percentiles():
    st = ServiceStats(window=100)
    for ms in range(1, 101):  # 1..100 ms
        st.observe(ms / 1e3, n_requests=1)
    assert st.requests == 100 and st.batches == 100
    assert abs(st.p50_ms - 50.5) < 1.0
    assert st.p95_ms > 90.0 and st.p99_ms > 98.0
    assert st.p99_ms <= 100.0
    # ring keeps only the newest `window` samples
    for _ in range(100):
        st.observe(0.001, n_requests=1)
    assert st.p99_ms <= 1.5


def test_service_stats_empty():
    st = ServiceStats()
    assert st.p50_ms == 0.0 and st.p99_ms == 0.0


# -- sharded service + router --------------------------------------------------

@pytest.mark.parametrize("sharded", [False, True])
def test_feature_service_build(sharded):
    svc = FeatureService.build(
        "svc", fraud_view(), num_keys=32, sharded=sharded,
        num_shards=4 if sharded else None, capacity=64,
    )
    assert isinstance(svc.store, ShardedOnlineStore) == sharded
    rng = np.random.default_rng(0)
    rows = _rows(rng, 8)
    batch = {k: np.asarray([r[k] for r in rows]) for k in rows[0]}
    out = svc.request(batch)
    assert set(out) == {"s", "m", "c5"}
    assert svc.stats.requests == 8 and svc.stats.p50_ms > 0.0


def test_feature_service_build_rejects_shards_without_flag():
    with pytest.raises(ValueError, match="sharded=True"):
        FeatureService.build("svc", fraud_view(), num_keys=32, num_shards=4)


def test_shard_router_end_to_end_matches_single():
    """Same request stream through a sharded router and a single-device
    service: identical per-request answers, occupancy accounted."""
    rng = np.random.default_rng(1)
    view = fraud_view()
    single = FeatureService.build("one", view, num_keys=32, capacity=64)
    sharded = FeatureService.build(
        "many", view, num_keys=32, sharded=True, num_shards=4, capacity=64
    )
    router = ShardRouter(
        sharded,
        BatchScheduler(max_batch=16, max_wait_us=2_000),
    )
    rows = _rows(rng, 50)
    got = []
    ref = []
    now = 0
    for i, r in enumerate(rows):
        router.submit(r, now_us=now)
        now += 200
        out = router.pump(now_us=now)
        if out is not None:
            got.append(out)
    tail = router.drain(now_us=now)
    if tail is not None:
        got.append(tail)

    # reference: same rows in the same batch boundaries through the
    # single-device service (ingest-on-request makes state order-sensitive,
    # so batches must match — the router preserves FIFO order)
    n_done = 0
    for g in got:
        n = len(g["s"])
        batch = {
            k: np.asarray([r[k] for r in rows[n_done:n_done + n]])
            for k in rows[0]
        }
        ref.append(single.request(batch))
        n_done += n
    assert n_done == len(rows)
    for g, a in zip(got, ref):
        for f in view.features:
            np.testing.assert_array_equal(g[f], np.asarray(a[f]))
    assert router.shard_histogram().sum() == len(rows)
    assert sharded.stats.requests == len(rows)
    assert sharded.stats.p99_ms >= sharded.stats.p50_ms > 0.0
