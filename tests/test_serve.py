"""Serving layer: micro-batch deadlines, tail-latency stats, shard router.

Covers the BatchScheduler ``max_wait_us`` deadline (partial batches flush
on timeout), ServiceStats percentiles, FeatureService.build(sharded=True)
opt-in, and the ShardRouter submit -> pump -> scatter-back loop end to
end against both store flavours (answers must agree exactly).
"""

import numpy as np
import pytest

from repro.core import Col, FeatureView, range_window, rows_window, w_count, w_mean, w_sum
from repro.core.shard import ShardedOnlineStore
from repro.data.synthetic import FRAUD_SCHEMA, fraud_stream
from repro.serve.router import ShardRouter
from repro.serve.service import BatchScheduler, FeatureService, ServiceStats


def fraud_view() -> FeatureView:
    amt = Col("amount")
    w1 = range_window(600, bucket=64)
    return FeatureView(
        "serve_t",
        FRAUD_SCHEMA,
        {
            "s": w_sum(amt, w1),
            "m": w_mean(amt, w1),
            "c5": w_count(amt, rows_window(5)),
        },
    )


def _rows(rng, n, t0=100_000):
    return [
        dict(
            card=int(rng.integers(0, 32)),
            ts=int(t0 + i),
            amount=float(rng.gamma(1.5, 60.0)),
            mcc=int(rng.integers(0, 32)),
            device=int(rng.integers(0, 8)),
            geo=int(rng.integers(0, 16)),
        )
        for i in range(n)
    ]


# -- BatchScheduler deadline ---------------------------------------------------

def test_scheduler_waits_until_deadline():
    s = BatchScheduler(max_batch=8, max_wait_us=500)
    s.submit({"k": 1}, now_us=0)
    s.submit({"k": 2}, now_us=100)
    # neither full nor expired: keep coalescing
    assert s.next_batch(now_us=300) is None
    assert len(s.queue) == 2
    # oldest request hits the 500us deadline -> partial batch flushes
    b = s.next_batch(now_us=500)
    assert b is not None
    assert int(b["__valid__"].sum()) == 2
    assert s.next_batch(now_us=501) is None  # queue drained


def test_scheduler_full_batch_preempts_deadline():
    s = BatchScheduler(max_batch=2, max_wait_us=10_000)
    s.submit({"k": 1}, now_us=0)
    assert s.next_batch(now_us=1) is None
    s.submit({"k": 2}, now_us=2)
    b = s.next_batch(now_us=3)  # full batch flushes immediately
    assert b is not None and int(b["__valid__"].sum()) == 2


def test_scheduler_flush_overrides_deadline():
    s = BatchScheduler(max_batch=8, max_wait_us=10_000)
    s.submit({"k": 1}, now_us=0)
    assert s.next_batch(now_us=1) is None
    b = s.next_batch(now_us=1, flush=True)
    assert b is not None and int(b["__valid__"].sum()) == 1


def test_scheduler_no_deadline_is_immediate():
    s = BatchScheduler()
    s.submit({"k": 1})
    b = s.next_batch()
    assert b is not None and int(b["__valid__"].sum()) == 1


def test_scheduler_deadline_fifo_across_batches():
    s = BatchScheduler(buckets=(1, 4), max_batch=4, max_wait_us=100)
    for i in range(6):
        s.submit({"k": i}, now_us=i)
    b1 = s.next_batch(now_us=105)
    assert list(b1["k"][b1["__valid__"]]) == [0, 1, 2, 3]
    # remaining two flush when *their* oldest (submitted at t=4) expires
    assert s.next_batch(now_us=103) is None
    b2 = s.next_batch(now_us=104 + 100)
    assert list(b2["k"][b2["__valid__"]]) == [4, 5]


# -- ServiceStats percentiles --------------------------------------------------

def test_service_stats_percentiles():
    st = ServiceStats(window=100)
    for ms in range(1, 101):  # 1..100 ms
        st.observe(ms / 1e3, n_requests=1)
    assert st.requests == 100 and st.batches == 100
    assert abs(st.p50_ms - 50.5) < 1.0
    assert st.p95_ms > 90.0 and st.p99_ms > 98.0
    assert st.p99_ms <= 100.0
    # ring keeps only the newest `window` samples
    for _ in range(100):
        st.observe(0.001, n_requests=1)
    assert st.p99_ms <= 1.5


def test_service_stats_empty():
    st = ServiceStats()
    assert st.p50_ms == 0.0 and st.p99_ms == 0.0


# -- sharded service + router --------------------------------------------------

@pytest.mark.parametrize("sharded", [False, True])
def test_feature_service_build(sharded):
    svc = FeatureService.build(
        "svc", fraud_view(), num_keys=32, sharded=sharded,
        num_shards=4 if sharded else None, capacity=64,
    )
    assert isinstance(svc.store, ShardedOnlineStore) == sharded
    rng = np.random.default_rng(0)
    rows = _rows(rng, 8)
    batch = {k: np.asarray([r[k] for r in rows]) for k in rows[0]}
    out = svc.request(batch)
    assert set(out) == {"s", "m", "c5"}
    assert svc.stats.requests == 8 and svc.stats.p50_ms > 0.0


def test_feature_service_build_rejects_shards_without_flag():
    with pytest.raises(ValueError, match="sharded=True"):
        FeatureService.build("svc", fraud_view(), num_keys=32, num_shards=4)


def test_shard_router_end_to_end_matches_single():
    """Same request stream through a sharded router and a single-device
    service: identical per-request answers, occupancy accounted."""
    rng = np.random.default_rng(1)
    view = fraud_view()
    single = FeatureService.build("one", view, num_keys=32, capacity=64)
    sharded = FeatureService.build(
        "many", view, num_keys=32, sharded=True, num_shards=4, capacity=64
    )
    router = ShardRouter(
        sharded,
        BatchScheduler(max_batch=16, max_wait_us=2_000),
    )
    rows = _rows(rng, 50)
    got = []
    ref = []
    now = 0
    for i, r in enumerate(rows):
        router.submit(r, now_us=now)
        now += 200
        out = router.pump(now_us=now)
        if out is not None:
            got.append(out)
    tail = router.drain(now_us=now)
    if tail is not None:
        got.append(tail)

    # reference: same rows in the same batch boundaries through the
    # single-device service (ingest-on-request makes state order-sensitive,
    # so batches must match — the router preserves FIFO order)
    n_done = 0
    for g in got:
        n = len(g["s"])
        batch = {
            k: np.asarray([r[k] for r in rows[n_done:n_done + n]])
            for k in rows[0]
        }
        ref.append(single.request(batch))
        n_done += n
    assert n_done == len(rows)
    for g, a in zip(got, ref):
        for f in view.features:
            np.testing.assert_array_equal(g[f], np.asarray(a[f]))
    assert router.shard_histogram().sum() == len(rows)
    assert sharded.stats.requests == len(rows)
    assert sharded.stats.p99_ms >= sharded.stats.p50_ms > 0.0


# -- device-resident mixed-batch routing --------------------------------------


def _multi_views():
    amt = Col("amount")
    w1 = range_window(600, bucket=64)
    return [
        FeatureView(
            "mx_fraud", FRAUD_SCHEMA,
            {"s": w_sum(amt, w1), "c5": w_count(amt, rows_window(5))},
        ),
        FeatureView("mx_risk", FRAUD_SCHEMA, {"m": w_mean(amt, w1)}),
        FeatureView(
            "mx_velocity", FRAUD_SCHEMA, {"c8": w_count(amt, rows_window(8))},
        ),
    ]


def _span_counts(tel):
    counts = {}

    def walk(s):
        counts[s.name] = counts.get(s.name, 0) + 1
        for c in s.children:
            walk(c)

    for s in tel.tracer.roots():
        walk(s)
    return counts


def _drive_mixed(device_routing, n_req=26, pumps_of=9):
    """Build a 3-scenario sharded service, push an interleaved stream
    through the router in several pumps, return (per-pump outputs,
    drained output, router, span counts)."""
    from repro.obs import Telemetry, use_telemetry

    views = _multi_views()
    names = [v.name for v in views]
    rng = np.random.default_rng(91)
    rows = _rows(rng, n_req)
    tel = Telemetry()
    with use_telemetry(tel):
        svc = FeatureService.build_multi(
            "mx", views, num_keys=32, sharded=True, num_shards=4,
            capacity=64, device_routing=device_routing,
        )
        router = ShardRouter(
            svc, BatchScheduler(buckets=(1, 4, 16), max_batch=pumps_of)
        )
        pump_outs = []
        for i, row in enumerate(rows):
            router.submit(row, scenario=names[i % 3])
            if (i + 1) % pumps_of == 0:
                got = router.pump()
                assert got is not None
                pump_outs.append(got)
        drained = router.drain()
    return pump_outs, drained, router, _span_counts(tel), svc


def test_mixed_pump_is_one_fused_dispatch():
    """Tentpole acceptance: a mixed 3-scenario batch is served by ONE
    fused device dispatch — one ``route.device`` span and one request
    span per pump — where the host oracle runs one request per scenario
    group and never touches ``route.device``."""
    _, _, _, spans_d, _ = _drive_mixed(True)
    _, _, _, spans_h, _ = _drive_mixed(False)
    n_batches = 3  # 26 requests, pumps of 9 -> 9 + 9 + 8 (drain)
    assert spans_d.get("route.device") == n_batches
    assert spans_d.get("request") == n_batches
    assert "query.compute" not in spans_d  # host-path span, device run
    assert "route.device" not in spans_h
    assert spans_h.get("request") == 3 * n_batches  # one per group


def test_mixed_router_device_equals_host():
    """Mixed batches through the device-routed plane equal the host
    oracle bit-for-bit, pump by pump, with identical (scenario, shard)
    occupancy histograms — with ingest on, across multiple pumps."""
    pumps_d, drain_d, router_d, _, _ = _drive_mixed(True)
    pumps_h, drain_h, router_h, _, _ = _drive_mixed(False)
    assert len(pumps_d) == len(pumps_h)
    for i, (a, b) in enumerate(zip(pumps_d + [drain_d], pumps_h + [drain_h])):
        assert set(a) == set(b)
        for s in a:
            for f in a[s]:
                np.testing.assert_array_equal(
                    a[s][f], b[s][f], err_msg=f"pump={i} {s}/{f}"
                )
    np.testing.assert_array_equal(
        router_d.shard_histogram(), router_h.shard_histogram()
    )
    hd, hh = (
        router_d.scenario_shard_histogram(),
        router_h.scenario_shard_histogram(),
    )
    assert set(hd) == set(hh)
    for s in hd:
        np.testing.assert_array_equal(hd[s], hh[s], err_msg=s)
        assert hd[s].sum() > 0
    # per-scenario QPS accounting survives the fused dispatch
    st_d, st_h = router_d.service.scenario_stats, router_h.service.scenario_stats
    for s in st_d:
        assert st_d[s].requests == st_h[s].requests > 0


@pytest.mark.parametrize("device_routing", [True, False])
def test_drain_submission_order_across_pumps(device_routing):
    """Satellite regression: drain() must return each scenario's rows in
    submission order even when the queue empties over MULTIPLE pumps —
    verified against per-row single-request answers on a frozen store."""
    from repro.obs import Telemetry, use_telemetry

    views = _multi_views()
    names = [v.name for v in views]
    rng = np.random.default_rng(17)
    rows = _rows(rng, 22)
    with use_telemetry(Telemetry()):
        svc = FeatureService.build_multi(
            "ord", views, num_keys=32, sharded=True, num_shards=4,
            capacity=64, device_routing=device_routing,
        )
        # warm state, then freeze (ingest=False below) so expected
        # per-row answers don't depend on serving order
        hist = _rows(rng, 60, t0=90_000)
        cols = {k: np.asarray([r[k] for r in hist]) for k in hist[0]}
        o = np.lexsort((cols["ts"], cols["card"]))
        svc.store.ingest({c: v[o] for c, v in cols.items()})
        router = ShardRouter(
            svc, BatchScheduler(buckets=(1, 4), max_batch=4), ingest=False
        )
        tags = [names[i % 3] for i in range(len(rows))]
        for row, tag in zip(rows, tags):
            router.submit(row, scenario=tag)
        out = router.drain()  # 22 rows, pumps of <= 4 -> >= 6 pumps
        for s in names:
            srows = [r for r, t in zip(rows, tags) if t == s]
            feats = svc.plane.views[s].features
            assert set(out[s]) == set(feats)
            assert len(out[s][list(feats)[0]]) == len(srows)
            for i, r in enumerate(srows):
                one = svc.request(
                    {k: np.asarray([v]) for k, v in r.items()},
                    ingest=False, scenario=s,
                )
                for f in feats:
                    np.testing.assert_array_equal(
                        np.asarray(out[s][f])[i : i + 1],
                        np.asarray(one[f]),
                        err_msg=f"{s} row {i} feature {f}",
                    )
