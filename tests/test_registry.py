"""FeatureRegistry (version history, deploy records, JSON export) and the
serving layer's padding-mask handling."""

import json

import numpy as np
import pytest

from repro.core import (
    Col,
    FeatureRegistry,
    FeatureView,
    OnlineFeatureStore,
    TableSchema,
    range_window,
    w_count,
    w_mean,
    w_sum,
)
from repro.serve.service import BatchScheduler, FeatureService

SCHEMA = TableSchema(
    name="tx", key="uid", ts="ts", numeric=("amount",)
)


def make_view(version_features=None):
    feats = {"s": w_sum(Col("amount"), range_window(600))}
    feats.update(version_features or {})
    return FeatureView("fraud", SCHEMA, feats)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_version_history_evolve_and_versions():
    reg = FeatureRegistry()
    v1 = reg.register(make_view())
    v2 = reg.register(
        v1.evolve({"m": w_mean(Col("amount"), range_window(600))}, "add mean")
    )
    v3 = reg.register(v2.evolve({"c": w_count(Col("amount"), range_window(60))}))
    assert reg.versions("fraud") == [1, 2, 3]
    # every historical version stays retrievable, unmutated
    assert set(reg.get("fraud", 1).features) == {"s"}
    assert set(reg.get("fraud", 2).features) == {"s", "m"}
    assert set(reg.get("fraud").features) == {"s", "m", "c"}  # latest
    assert reg.get("fraud", 2).description == "add mean"
    assert v3.version == 3
    # re-registering an existing (name, version) is an error
    with pytest.raises(ValueError, match="already registered"):
        reg.register(make_view())


def test_deploy_records():
    reg = FeatureRegistry()
    reg.register(make_view())
    reg.register(
        reg.get("fraud").evolve(
            {"m": w_mean(Col("amount"), range_window(600))}
        )
    )
    rec = reg.deploy("svc_a", "fraud", description="canary")
    assert rec["view"] == "fraud"
    assert rec["version"] == 2  # defaults to latest
    assert rec["features"] == ["s", "m"]
    assert rec["tables"] == ["tx"]
    assert rec["description"] == "canary"
    assert rec["deployed_at"] > 0
    # pinned deployment of an older version
    rec1 = reg.deploy("svc_b", "fraud", version=1)
    assert rec1["version"] == 1
    assert reg.service("svc_b")["features"] == ["s"]
    # deploy events are logged
    kinds = [e["kind"] for e in reg._events]
    assert kinds.count("deploy") == 2
    assert kinds.count("register_view") == 2


def test_injectable_clock_makes_deploy_history_deterministic():
    """FeatureRegistry takes an injectable clock (mirroring
    BatchScheduler's from the serving layer) so deploy-history ordering
    and timestamps are deterministic under test/replay."""
    ticks = iter(range(100, 200))
    reg = FeatureRegistry(clock=lambda: float(next(ticks)))
    reg.register(make_view())
    reg.register(
        reg.get("fraud").evolve(
            {"m": w_mean(Col("amount"), range_window(600))}
        )
    )
    a = reg.deploy("svc_a", "fraud", version=1)
    b = reg.deploy("svc_b", "fraud", version=2)
    c = reg.deploy("svc_c", "fraud")
    # stamps come from the injected clock, strictly ordered & reproducible
    assert (a["deployed_at"], b["deployed_at"], c["deployed_at"]) == (
        102.0, 103.0, 104.0,
    )
    assert [e["t"] for e in reg._events] == [100.0, 101.0, 102.0, 103.0, 104.0]
    ordered = [d["service"] for d in reg.deployments("fraud")]
    assert ordered == ["svc_a", "svc_b", "svc_c"]
    # two registries on the same injected clock agree exactly
    ticks2 = iter(range(100, 200))
    reg2 = FeatureRegistry(clock=lambda: float(next(ticks2)))
    reg2.register(make_view())
    reg2.register(
        reg2.get("fraud").evolve(
            {"m": w_mean(Col("amount"), range_window(600))}
        )
    )
    assert reg2.deploy("svc_a", "fraud", version=1) == a
    # default clock still stamps real time
    reg3 = FeatureRegistry()
    reg3.register(make_view())
    assert reg3.deploy("svc", "fraud")["deployed_at"] > 1e9


def test_to_json_roundtrip():
    reg = FeatureRegistry()
    reg.register(make_view())
    reg.register(
        reg.get("fraud").evolve(
            {"m": w_mean(Col("amount"), range_window(600))}
        )
    )
    reg.deploy("svc", "fraud")
    doc = json.loads(reg.to_json())
    assert {v["version"] for v in doc["views"]} == {1, 2}
    v2 = next(v for v in doc["views"] if v["version"] == 2)
    assert v2["name"] == "fraud"
    assert v2["table"] == "tx"
    assert v2["tables"] == ["tx"]
    assert set(v2["features"]) == {"s", "m"}
    assert v2["features"]["s"].startswith("SELECT sum(amount) OVER")
    assert doc["services"]["svc"]["view"] == "fraud"
    assert doc["services"]["svc"]["version"] == 2


# ---------------------------------------------------------------------------
# serving: BatchScheduler padding mask
# ---------------------------------------------------------------------------


def test_scheduler_pads_and_masks():
    sched = BatchScheduler(buckets=(1, 4, 16))
    for i in range(3):
        sched.submit({"uid": i, "ts": 10 + i, "amount": 1.0})
    batch = sched.next_batch()
    assert len(batch["uid"]) == 4  # padded to the bucket
    assert batch["__valid__"].tolist() == [True, True, True, False]
    # padding repeats the last real row
    assert batch["uid"][3] == batch["uid"][2]
    assert sched.next_batch() is None


def test_service_does_not_ingest_padding_rows():
    view = FeatureView(
        "svc_view", SCHEMA,
        {"cnt": w_count(Col("amount"), range_window(600))},
    )
    store = OnlineFeatureStore(view, num_keys=8)
    svc = FeatureService("svc", view, store)

    sched = BatchScheduler(buckets=(4,))
    for i in range(3):
        sched.submit({"uid": i, "ts": 100, "amount": 10.0})
    batch = sched.next_batch()
    out = svc.request(batch)
    assert len(out["cnt"]) == 4  # full padded batch is answered
    assert svc.stats.requests == 3  # but only real rows are counted

    # the padding row duplicated uid=2; with the mask honored, uid=2 must
    # have exactly ONE ingested row => a later query counts 1 (+ request)
    probe = {
        "uid": np.array([2], np.int32),
        "ts": np.array([200], np.int32),
        "amount": np.array([1.0], np.float32),
    }
    res = svc.request(probe, ingest=False)
    assert float(res["cnt"][0]) == 2.0  # 1 stored + the request row

    # stripping __valid__ must also happen when ingest=False
    sched.submit({"uid": 5, "ts": 300, "amount": 2.0})
    b2 = sched.next_batch()
    res2 = svc.request(b2, ingest=False)
    assert len(res2["cnt"]) == 4
