"""Algebraic property tests for the unified aggregator registry.

Every ``Agg``'s spec must be a genuine monoid — ``combine`` associative,
``init`` the identity — because every layer (offline scan, online naive,
online pre-agg, WINDOW UNION, sharded plane) evaluates folds of it in a
different association order.  Checked as hypothesis property tests where
hypothesis is installed, and as a deterministic seeded sweep everywhere
(the container may not ship hypothesis; the property still runs in tier-1).

Plus the end-to-end payoff of the algebra: FIRST and TOPN_FREQ — the two
aggregates that used to be rejected over WINDOW UNION — now agree *exactly*
between the offline engine, the online store (both query paths), and the
sharded plane.
"""

import numpy as np
import pytest

from repro.core import (
    Col,
    Database,
    FeatureView,
    TableSchema,
    range_window,
    w_first,
    w_last,
    w_sum,
    w_topn_freq,
)
from repro.core.aggregates import (
    AGG_SPECS,
    TOPN_TAIL,
    _sort_tail_desc,
    agg_spec,
)
from repro.core.consistency import verify_view
from repro.core.expr import UNION_AGGS, Agg

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as hst

    HAVE_HYPOTHESIS = True
except ImportError:  # container may not ship hypothesis
    HAVE_HYPOTHESIS = False

B = 5  # batch shape of generated states — combines are elementwise-batched


# ---------------------------------------------------------------------------
# state generators / observational equality
# ---------------------------------------------------------------------------


def _random_states(spec, rng, count):
    """``count`` random valid states of ``spec``, batch shape (B,).

    Lane values are integer-valued floats so f32 addition is exact (the
    associativity contract is algebraic; fp rounding is tested by the
    consistency suite's tolerances instead).  Merge coordinates (ts, rank,
    pos) are globally distinct — the merge order is a strict total order
    over real rows, so equal coordinates cannot occur.
    """
    if spec.state == "lanes":
        return [
            {
                l: rng.integers(-50, 50, B).astype(np.float32)
                for l in spec.lanes
            }
            for _ in range(count)
        ]
    if spec.state == "bitmap":
        return [
            {"bits": rng.integers(0, 2**31 - 1, B).astype(np.int32)}
            for _ in range(count)
        ]
    if spec.state == "extreme":
        ts = rng.choice(10**6, size=(count, B), replace=False)
        return [
            {
                "ts": ts[i].astype(np.int32),
                "rank": rng.integers(0, 4, B).astype(np.int32),
                "pos": rng.integers(0, 256, B).astype(np.int32),
                "val": rng.integers(-50, 50, B).astype(np.float32),
                "has": rng.random(B) < 0.8,
            }
            for i in range(count)
        ]
    # tail: canonical states (entries newest-first, valid-first)
    widths = rng.integers(0, 13, count)
    total = int(widths.sum())
    ts_pool = rng.choice(10**6, size=(total, B), replace=False)
    out, used = [], 0
    for w in widths:
        w = int(w)
        s = {
            "ts": ts_pool[used:used + w].T.astype(np.int32),
            "rank": rng.integers(0, 4, (B, w)).astype(np.int32),
            "pos": rng.integers(0, 256, (B, w)).astype(np.int32),
            "val": rng.integers(-8, 8, (B, w)).astype(np.float32),
            "valid": np.ones((B, w), bool),
        }
        used += w
        out.append({k: np.asarray(v) for k, v in _sort_tail_desc(
            {k: np.asarray(v) for k, v in s.items()}
        ).items()})
    return out


def _states_equal(spec, a, b):
    """Observational state equality (fields of absent/invalid entries are
    don't-cares)."""
    a = {k: np.asarray(v) for k, v in a.items()}
    b = {k: np.asarray(v) for k, v in b.items()}
    if spec.state in ("lanes", "bitmap"):
        return all(np.array_equal(a[k], b[k]) for k in a)
    if spec.state == "extreme":
        if not np.array_equal(a["has"], b["has"]):
            return False
        h = a["has"]
        return all(
            np.array_equal(a[k][h], b[k][h])
            for k in ("ts", "rank", "pos", "val")
        )
    if a["valid"].shape != b["valid"].shape or not np.array_equal(
        a["valid"], b["valid"]
    ):
        return False
    v = a["valid"]
    return all(
        np.array_equal(a[k][v], b[k][v]) for k in ("ts", "rank", "pos", "val")
    )


def _check_associative(agg, seed):
    spec = agg_spec(agg)
    sa, sb, sc = _random_states(spec, np.random.default_rng(seed), 3)
    left = spec.combine(spec.combine(sa, sb), sc)
    right = spec.combine(sa, spec.combine(sb, sc))
    assert _states_equal(spec, left, right), (
        f"{agg}: combine not associative (seed {seed})"
    )


def _check_identity(agg, seed):
    spec = agg_spec(agg)
    (s,) = _random_states(spec, np.random.default_rng(seed), 1)
    ident = spec.init((B,))
    assert _states_equal(spec, spec.combine(ident, s), s), (
        f"{agg}: init is not a left identity (seed {seed})"
    )
    assert _states_equal(spec, spec.combine(s, ident), s), (
        f"{agg}: init is not a right identity (seed {seed})"
    )


# ---------------------------------------------------------------------------
# the properties — deterministic sweep (always) + hypothesis (where present)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("agg", list(Agg))
@pytest.mark.parametrize("seed", range(6))
def test_combine_associative(agg, seed):
    _check_associative(agg, 1000 * seed + 17)


@pytest.mark.parametrize("agg", list(Agg))
@pytest.mark.parametrize("seed", range(6))
def test_init_identity(agg, seed):
    _check_identity(agg, 1000 * seed + 29)


if HAVE_HYPOTHESIS:

    @settings(
        max_examples=80, deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        agg=hst.sampled_from(list(Agg)), seed=hst.integers(0, 2**20)
    )
    def test_combine_associative_hypothesis(agg, seed):
        _check_associative(agg, seed)

    @settings(
        max_examples=80, deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        agg=hst.sampled_from(list(Agg)), seed=hst.integers(0, 2**20)
    )
    def test_init_identity_hypothesis(agg, seed):
        _check_identity(agg, seed)


def test_registry_covers_every_agg_and_union_flags_match():
    assert set(AGG_SPECS) == set(Agg)
    assert tuple(a for a in Agg if AGG_SPECS[a].union_composable) == tuple(
        sorted(UNION_AGGS, key=list(Agg).index)
    )
    # every state family is bucket-composable: lanes/bitmap persist in the
    # core stat arrays, extreme/tail in the merge-order state arrays the
    # layout plans alongside (BucketPlan.extreme / .tail)
    for agg, spec in AGG_SPECS.items():
        assert spec.bucket_composable, agg
        assert spec.state in ("lanes", "bitmap", "extreme", "tail"), agg


# ---------------------------------------------------------------------------
# end-to-end: FIRST / TOPN_FREQ under WINDOW UNION, exact on every path
# ---------------------------------------------------------------------------

DB = Database(
    name="alg",
    primary=TableSchema("tx", key="acct", ts="ts", numeric=("amount",)),
    secondary=(
        TableSchema("wires", key="acct", ts="ts", numeric=("amount",)),
    ),
)


def _union_workload(seed, n=260, m=130, k=7, t_max=2_000):
    rng = np.random.default_rng(seed)
    # unique timestamps across both tables: the merge order is then fully
    # determined by ts, so brute-force/offline/online agree unambiguously
    all_ts = rng.choice(t_max, size=n + m, replace=False).astype(np.int32)
    tx = dict(
        acct=rng.integers(0, k, n).astype(np.int32),
        ts=np.sort(all_ts[:n]),
        amount=rng.integers(0, 6, n).astype(np.float32),
    )
    wires = dict(
        acct=rng.integers(0, k, m).astype(np.int32),
        ts=np.sort(all_ts[n:]),
        amount=rng.integers(0, 6, m).astype(np.float32),
    )
    return tx, wires, k


UNION_VIEW = FeatureView(
    "union_exact", DB.primary, {
        "first_u": w_first(
            Col("amount"), range_window(500, bucket=64), union=("wires",)
        ),
        "last_u": w_last(
            Col("amount"), range_window(500, bucket=64), union=("wires",)
        ),
        "top1_u": w_topn_freq(
            Col("amount"), range_window(400, bucket=64), n=0, union=("wires",)
        ),
        "top2_u": w_topn_freq(
            Col("amount"), range_window(400, bucket=64), n=1, union=("wires",)
        ),
    },
    database=DB,
)


@pytest.mark.parametrize("mode", ["naive", "preagg"])
@pytest.mark.parametrize("num_shards", [None, 4])
def test_first_topn_union_exact(mode, num_shards):
    tx, wires, k = _union_workload(seed=23)
    rep = verify_view(
        UNION_VIEW, tx, num_keys=k, capacity=256, num_buckets=64,
        bucket_size=64, mode=mode, secondary={"wires": wires},
        num_shards=num_shards,
    )
    assert rep.passed, rep.summary() + f" per-feature: {rep.per_feature}"
    # FIRST/LAST/TOPN return raw row values — no fp accumulation, so the
    # offline/online/sharded agreement must be *exact*, not tolerance-based
    for f, err in rep.per_feature.items():
        assert err == 0.0, f"{f}: max abs err {err} (expected exact)"


PRIMARY_VIEW = FeatureView(
    "primary_exact", DB.primary, {
        "first_r": w_first(Col("amount"), range_window(500, bucket=64)),
        "last_r": w_last(Col("amount"), range_window(500, bucket=64)),
        "top1_r": w_topn_freq(
            Col("amount"), range_window(400, bucket=64), n=0
        ),
        "top2_r": w_topn_freq(
            Col("amount"), range_window(400, bucket=64), n=1
        ),
    },
    database=DB,
)


@pytest.mark.parametrize("mode", ["naive", "preagg"])
@pytest.mark.parametrize("num_shards", [None, 4])
def test_first_topn_primary_bucket_exact(mode, num_shards):
    """FIRST/LAST/TOPN over a plain (non-union) RANGE window compose from
    the persisted merge-order bucket families on the pre-agg path —
    exactly, matching the offline oracle row for row."""
    tx, _, k = _union_workload(seed=31)
    rep = verify_view(
        PRIMARY_VIEW, tx, num_keys=k, capacity=256, num_buckets=64,
        bucket_size=64, mode=mode, num_shards=num_shards,
    )
    assert rep.passed, rep.summary() + f" per-feature: {rep.per_feature}"
    for f, err in rep.per_feature.items():
        assert err == 0.0, f"{f}: max abs err {err} (expected exact)"


def _evo_view(with_families):
    feats = {"s": w_sum(Col("amount"), range_window(500, bucket=64))}
    if with_families:
        feats["first_r"] = w_first(
            Col("amount"), range_window(500, bucket=64)
        )
        feats["top1_r"] = w_topn_freq(
            Col("amount"), range_window(400, bucket=64), n=0
        )
    return FeatureView("evo", DB.primary, feats, database=DB)


@pytest.mark.parametrize("num_shards", [None, 4])
def test_merge_order_states_through_evolution(num_shards):
    """Adding FIRST/TOPN to a live lanes-only plane plans the merge-order
    bucket families mid-flight: the hot deploy rebuilds them from the
    ring-retained history, and a subsequent capacity re-lay carries them —
    both ending bit-identical to a cold rebuild + replay."""
    from repro.core import ScenarioPlane

    tx, _, k = _union_workload(seed=17)
    o = np.lexsort((tx["ts"], tx["acct"]))
    stream = {c: np.asarray(v)[o] for c, v in tx.items()}
    kw = dict(
        num_keys=k, num_shards=num_shards, capacity=256, num_buckets=64,
        bucket_size=64,
    )

    plane = ScenarioPlane([_evo_view(False)], **kw)
    assert plane.store.state.bagg.seq is None  # lanes-only: no families
    plane.ingest(stream)

    rep1 = plane.evolve([_evo_view(True)])  # families appear mid-flight
    assert rep1.exact, rep1.summary()
    bagg = plane.store.state.bagg
    assert bagg.seq is not None and bagg.xts is not None
    assert bagg.tts is not None

    rep2 = plane.evolve([_evo_view(True)], capacity=384)  # carry path
    assert rep2.exact, rep2.summary()

    cold = ScenarioPlane([_evo_view(True)], **{**kw, "capacity": 384})
    cold.ingest(stream)

    q = {c: v[-16:] for c, v in stream.items()}
    for mode in ("preagg", "naive"):
        got = plane.query("evo", dict(q), mode=mode)
        want = cold.query("evo", dict(q), mode=mode)
        for f in ("first_r", "top1_r"):
            np.testing.assert_array_equal(
                np.asarray(got[f]), np.asarray(want[f]),
                err_msg=f"{mode} {f}",
            )
        np.testing.assert_allclose(
            np.asarray(got["s"]), np.asarray(want["s"]), rtol=1e-6
        )

    # the family state itself matches the cold rebuild wherever observable
    # (fields of absent entries are don't-cares)
    hb, cb = plane.store.state.bagg, cold.store.state.bagg
    np.testing.assert_array_equal(np.asarray(hb.seq), np.asarray(cb.seq))
    has = np.asarray(cb.xhas)
    np.testing.assert_array_equal(np.asarray(hb.xhas), has)
    for d in (0, 1):
        m = has[..., d]
        for nm in ("xts", "xpos"):
            np.testing.assert_array_equal(
                np.asarray(getattr(hb, nm))[..., d][m],
                np.asarray(getattr(cb, nm))[..., d][m], err_msg=nm,
            )
        np.testing.assert_array_equal(
            np.asarray(hb.xval)[..., d][m], np.asarray(cb.xval)[..., d][m]
        )
    valid = np.asarray(cb.tvalid)
    np.testing.assert_array_equal(np.asarray(hb.tvalid), valid)
    for nm in ("tts", "tpos"):
        np.testing.assert_array_equal(
            np.asarray(getattr(hb, nm))[valid],
            np.asarray(getattr(cb, nm))[valid], err_msg=nm,
        )
    hv = np.moveaxis(np.asarray(hb.tval), -2, -1)  # (.., T, F) for masking
    cv = np.moveaxis(np.asarray(cb.tval), -2, -1)
    np.testing.assert_array_equal(hv[valid], cv[valid], err_msg="tval")


def test_first_union_brute_force():
    """Offline FIRST over a union window vs a direct numpy oracle."""
    tx, wires, k = _union_workload(seed=5)
    from repro.core import OfflineEngine

    out = np.asarray(
        OfflineEngine().compute(
            UNION_VIEW,
            {c: np.asarray(v) for c, v in tx.items()},
            secondary={"wires": wires},
        )["first_u"]
    )
    W = 500
    for i in rng_idx(len(tx["ts"])):
        t_i, a_i = int(tx["ts"][i]), int(tx["acct"][i])
        rows = [
            (int(t), float(v))
            for t, v, a in zip(tx["ts"], tx["amount"], tx["acct"])
            if a == a_i and t_i - W < int(t) <= t_i and int(t) <= t_i
        ] + [
            (int(t), float(v))
            for t, v, a in zip(wires["ts"], wires["amount"], wires["acct"])
            if a == a_i and t_i - W < int(t) <= t_i
        ]
        want = min(rows)[1]  # oldest ts wins (unique ts by construction)
        assert out[i] == np.float32(want), i


def rng_idx(n, count=40, seed=3):
    return np.random.default_rng(seed).choice(n, size=min(count, n),
                                              replace=False)
