"""The scenario-explosion stress suite's own tier-1 coverage (ISSUE 9).

Fast checks always run: generator determinism (byte-identical across two
processes — the PR 2 flake class, asserted not assumed), IR-surface
coverage, a small end-to-end harness run (deploy + churn + both routing
flavours + sampled verification), and the shrink-to-minimal-repro path
under a forced failure.  The full N=128 sweep is ``@pytest.mark.stress``
— excluded from tier-1 by pytest.ini, run on demand with
``pytest -m stress``.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.expr import (
    Agg,
    Hash,
    LastJoin,
    Signature,
    collect_last_joins,
    collect_window_aggs,
)
from repro.core.layout import plan_layout
from repro.stress.generate import (
    NUM_ENTITIES,
    PROFILES,
    filter_table_knobs,
    gen_store_kwargs,
    gen_views,
    view_fingerprint,
)
from repro.stress.harness import run_repro, run_stress

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


def _walk(e):
    yield e
    for c in e.children():
        yield from _walk(c)


def test_deterministic_across_processes():
    """gen_views(seed, n) must be byte-identical in a fresh interpreter —
    the whole repro story (seeds in failure scripts) rests on this."""
    local = view_fingerprint(gen_views(11, 32))
    env = dict(os.environ, PYTHONPATH=REPO_SRC)
    out = subprocess.run(
        [
            sys.executable,
            "-c",
            "from repro.stress.generate import gen_views, view_fingerprint;"
            "print(view_fingerprint(gen_views(11, 32)))",
        ],
        env=env,
        capture_output=True,
        text=True,
        check=True,
    )
    assert out.stdout.strip() == local
    # and stable within-process across calls
    assert view_fingerprint(gen_views(11, 32)) == local


def test_ir_surface_coverage():
    """n=40 at the default profile must exercise the whole IR surface."""
    views = gen_views(7, 40)
    assert [v.name for v in views] == [f"gen_v{i:03d}" for i in range(40)]
    exprs = [e for v in views for e in v.features.values()]
    waggs = list(collect_window_aggs(exprs).values())
    assert {w.agg for w in waggs} == set(Agg)          # all ten aggregates
    assert {w.window.mode for w in waggs} == {"rows", "range"}
    assert any(w.union for w in waggs)                 # WINDOW UNIONs
    joined = {j.table for j in collect_last_joins(exprs).values()}
    assert joined & {"profiles", "items"}              # dimension joins
    nodes = [n for e in exprs for n in _walk(e)]
    assert any(isinstance(n, Signature) for n in nodes)
    assert any(isinstance(n, Hash) for n in nodes)
    assert any(v.version > 1 for v in views)           # evolve chains
    # cross-view CSE: shared pool lanes appear in >1 view
    per_view = [
        set(collect_window_aggs(list(v.features.values())))  # structural keys
        for v in views
    ]
    shared = {
        k for i, a in enumerate(per_view)
        for b in per_view[i + 1:] for k in (a & b)
    }
    assert shared, "no window-agg lane shared across views"


def test_profiles_valid_and_plannable():
    for profile in PROFILES:
        views = gen_views(3, 12, profile)
        kw = gen_store_kwargs(3, 12, profile)
        layout = plan_layout(
            views,
            num_keys=NUM_ENTITIES,
            num_shards=8,
            raw_lanes=True,
            **filter_table_knobs(kw, views),
        )
        assert layout.num_shards == 8
    with pytest.raises(KeyError):
        gen_views(0, 4, "no_such_profile")


def test_harness_small_end_to_end(tmp_path):
    """Tiny full protocol: deploy, one churn wave, traffic + parity under
    both flavours, spot check, sampled verify — all green."""
    rep = run_stress(
        seed=3, n=5, num_shards=4, waves=1, wave_size=2, rows=400,
        verify_samples=1, verify_rows=256, repro_dir=str(tmp_path),
    )
    assert rep.passed, rep.summary()
    assert rep.deployed == 5
    assert rep.waves_survived == 1
    assert rep.parity_batches == 2
    assert rep.spot_checked
    assert rep.requests > 0
    # the two sampled verifies alternated routing flavours
    assert any(v.endswith("/host") for v in rep.verified)
    assert any(not v.endswith("/host") for v in rep.verified)
    assert not list(tmp_path.iterdir())  # no repro scripts on a pass


def test_forced_failure_shrinks_to_runnable_repro(tmp_path):
    """--force-fail drives the shrink machinery end to end: the report
    fails, and a minimal repro script lands naming seed + view spec."""
    views = gen_views(3, 5)
    target = views[0].name
    rep = run_stress(
        seed=3, n=5, num_shards=4, waves=1, wave_size=2, rows=400,
        verify_samples=1, verify_rows=256, force_fail=(target,),
        repro_dir=str(tmp_path),
    )
    assert not rep.passed
    fails = [f for f in rep.failures if f.view == target]
    assert fails and fails[0].stage == "verify"
    assert fails[0].shrunk_rows is not None
    assert fails[0].shrunk_rows <= 256 // 2  # the shrinker actually shrank
    path = fails[0].repro_path
    assert path and os.path.exists(path)
    script = open(path).read()
    assert "--seed 3" in script and f"--view {target}" in script
    assert "python -m repro.stress --repro" in script
    assert "SELECT" in script  # the view spec rides along as comments
    # the emitted command is runnable in-process (forced failures are
    # harness verdicts, not planted bugs, so the isolated re-run passes)
    cmd = script.strip().splitlines()[-1].split()
    args = dict(zip(cmd[:-1], cmd[1:]))
    rep2 = run_repro(
        seed=int(args["--seed"]), n=int(args["--n"]),
        profile=args["--profile"], view_name=args["--view"],
        data_rows=int(args["--data-rows"]), rows=int(args["--rows"]),
        device_routing="--host-routing" not in cmd, num_shards=4,
    )
    assert rep2.view == target


@pytest.mark.stress
def test_full_sweep_n128(tmp_path):
    """The headline sweep: 128 generated views, 2 hot-deploy waves of 8,
    mixed traffic under both flavours, rotating verification."""
    rep = run_stress(
        seed=0, n=128, num_shards=8, waves=2, wave_size=8, rows=2400,
        verify_samples=3, verify_rows=600, repro_dir=str(tmp_path),
    )
    assert rep.passed, rep.summary()
    assert rep.deployed == 128
    assert rep.waves_survived == 2


@pytest.mark.stress
def test_full_sweep_forced_fail_emits_runnable_repro(tmp_path):
    """At full scale, a forced failure must still shrink and emit a
    script that actually runs (subprocess, fresh interpreter)."""
    target = gen_views(0, 64)[2].name
    rep = run_stress(
        seed=0, n=64, num_shards=8, waves=1, wave_size=4, rows=1200,
        verify_samples=3, verify_rows=480, force_fail=(target,),
        repro_dir=str(tmp_path),
    )
    assert not rep.passed
    fail = next(f for f in rep.failures if f.view == target)
    assert fail.repro_path
    cmd = open(fail.repro_path).read().strip().splitlines()[-1]
    env = dict(os.environ, PYTHONPATH=REPO_SRC)
    out = subprocess.run(
        cmd.replace("PYTHONPATH=src ", "").split(),
        env=env, capture_output=True, text=True,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert target in out.stdout
