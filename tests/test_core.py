"""Feature-engine behaviour tests: offline engine, online store, views,
lineage, signatures, sketches."""

import zlib

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    Agg,
    Col,
    FeatureRegistry,
    FeatureView,
    OfflineEngine,
    OnlineFeatureStore,
    TableSchema,
    range_window,
    render_sql,
    rows_window,
    w_count,
    w_distinct_approx,
    w_first,
    w_last,
    w_max,
    w_mean,
    w_min,
    w_std,
    w_sum,
    w_topn_freq,
)
from repro.core.signature import (
    cms_init,
    cms_query,
    cms_update,
    multi_hash_ids,
    signature_ids,
)


SCHEMA = TableSchema(name="tx", key="uid", ts="ts", numeric=("amount",),
                     categorical=("mcc",))


def _table(rng, n=400, k=5, tmax=3000):
    key = rng.integers(0, k, n).astype(np.int32)
    ts = np.sort(rng.integers(0, tmax, n)).astype(np.int32)
    return dict(
        uid=key, ts=ts,
        amount=rng.gamma(2.0, 40.0, n).astype(np.float32),
        mcc=rng.integers(0, 30, n).astype(np.int32),
    )


def _brute_offline(cols, agg, window_mode, size):
    """O(N^2) brute-force oracle for per-key windows."""
    key, ts, x = cols["uid"], cols["ts"], cols["amount"]
    n = len(key)
    out = np.zeros(n, np.float64)
    order = np.lexsort((ts, key))
    pos_in_seg = {}
    rows_by_key = {}
    res = np.zeros(n, np.float64)
    for idx in order:
        kk = key[idx]
        hist = rows_by_key.setdefault(kk, [])
        hist.append((ts[idx], x[idx], idx))
        if window_mode == "rows":
            win = hist[-size:]
        else:
            win = [h for h in hist if h[0] > ts[idx] - size]
        vals = np.array([h[1] for h in win], np.float64)
        if agg == "sum":
            res[idx] = vals.sum()
        elif agg == "count":
            res[idx] = len(vals)
        elif agg == "mean":
            res[idx] = vals.mean()
        elif agg == "min":
            res[idx] = vals.min()
        elif agg == "max":
            res[idx] = vals.max()
        elif agg == "std":
            res[idx] = vals.std()
        elif agg == "first":
            res[idx] = vals[0]
        elif agg == "last":
            res[idx] = vals[-1]
    return res


@pytest.mark.parametrize("agg,maker", [
    ("sum", w_sum), ("count", w_count), ("mean", w_mean), ("min", w_min),
    ("max", w_max), ("std", w_std), ("first", w_first), ("last", w_last),
])
@pytest.mark.parametrize("mode,size", [("rows", 7), ("range", 500)])
def test_offline_engine_vs_bruteforce(agg, maker, mode, size):
    # zlib.crc32, not hash(): Python string hashing is randomized per
    # process, which made this test a per-run lottery over datasets
    seed = zlib.crc32(f"{agg}-{mode}-{size}".encode()) % 2**31
    rng = np.random.default_rng(seed)
    cols = _table(rng)
    w = rows_window(size) if mode == "rows" else range_window(size)
    view = FeatureView("t", SCHEMA, {"f": maker(Col("amount"), w)})
    out = np.asarray(OfflineEngine().compute(
        view, {k: jnp.asarray(v) for k, v in cols.items()}
    )["f"])
    ref = _brute_offline(cols, agg, mode, size)
    # STD's E[x^2]-E[x]^2 form keeps an f32 noise floor of
    # ~2|x-mu|*ulp(window sum) under sqrt even with compensated prefix
    # sums — near-zero-variance windows (e.g. single-row) may read as
    # ~1e-1 instead of 0 at value scales ~1e2
    atol = 0.15 if agg == "std" else 2e-2
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=atol)


def test_offline_rowlevel_composition():
    rng = np.random.default_rng(1)
    cols = _table(rng)
    ratio = w_sum(Col("amount"), rows_window(5)) / w_count(
        Col("amount"), rows_window(5)
    )
    view = FeatureView("t", SCHEMA, {
        "ratio": ratio,
        "mean": w_mean(Col("amount"), rows_window(5)),
    })
    out = OfflineEngine().compute(view, {k: jnp.asarray(v) for k, v in cols.items()})
    np.testing.assert_allclose(out["ratio"], out["mean"], rtol=1e-5, atol=1e-4)


def test_offline_derived_arg():
    """Window agg over a derived expression (amount > 100)."""
    rng = np.random.default_rng(2)
    cols = _table(rng)
    view = FeatureView("t", SCHEMA, {
        "big_cnt": w_sum(Col("amount") > 100.0, rows_window(10)),
    })
    out = np.asarray(OfflineEngine().compute(
        view, {k: jnp.asarray(v) for k, v in cols.items()}
    )["big_cnt"])
    # centered prefix sums may leave O(eps) negatives on 0/1 data
    assert out.min() >= -1e-4 and out.max() <= 10 + 1e-4


def test_topn_freq_exact_small():
    """TOPN over a tiny controlled history."""
    key = np.zeros(6, np.int32)
    ts = np.arange(6, dtype=np.int32)
    mcc = np.array([3, 3, 5, 3, 5, 7], np.int32)
    cols = dict(uid=key, ts=ts, amount=np.ones(6, np.float32), mcc=mcc)
    view = FeatureView("t", SCHEMA, {
        "top1": w_topn_freq(Col("mcc"), rows_window(6), n=0),
        "top2": w_topn_freq(Col("mcc"), rows_window(6), n=1),
    })
    out = OfflineEngine().compute(view, {k: jnp.asarray(v) for k, v in cols.items()})
    # at the last row: history = [3,3,5,3,5,7] -> top1=3 (x3), top2=5 (x2)
    assert float(out["top1"][-1]) == 3.0
    assert float(out["top2"][-1]) == 5.0


def test_online_store_rows_window_incremental():
    rng = np.random.default_rng(3)
    view = FeatureView("t", SCHEMA, {
        "s5": w_sum(Col("amount"), rows_window(5)),
    })
    store = OnlineFeatureStore(view, num_keys=4, capacity=32,
                               num_buckets=16, bucket_size=32)
    amounts = rng.gamma(2.0, 40.0, 20).astype(np.float32)
    # single key, sequential ingest; query before each ingest
    run = []
    for i, a in enumerate(amounts):
        cols = dict(uid=np.array([0], np.int32),
                    ts=np.array([i * 10], np.int32),
                    amount=np.array([a], np.float32),
                    mcc=np.array([1], np.int32))
        res = store.query(cols, mode="naive")
        expect = amounts[max(0, i - 4): i + 1].sum()
        run.append((float(res["s5"][0]), float(expect)))
        store.ingest(cols)
    got, want = zip(*run)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_ring_eviction_keeps_recent():
    """Ring keeps the newest `capacity` rows; old rows age out (TTL)."""
    view = FeatureView("t", SCHEMA, {"c": w_count(Col("amount"), rows_window(100))})
    store = OnlineFeatureStore(view, num_keys=2, capacity=8,
                               num_buckets=16, bucket_size=32)
    n = 20
    cols = dict(uid=np.zeros(n, np.int32), ts=np.arange(n, dtype=np.int32),
                amount=np.ones(n, np.float32), mcc=np.zeros(n, np.int32))
    store.ingest(cols)
    res = store.query(dict(uid=np.array([0], np.int32),
                           ts=np.array([n], np.int32),
                           amount=np.array([1.0], np.float32),
                           mcc=np.array([0], np.int32)), mode="naive")
    # only 8 retained + request row
    assert float(res["c"][0]) == 9.0


def test_feature_registry_versioning_and_lineage():
    reg = FeatureRegistry()
    v1 = FeatureView("fraud", SCHEMA, {
        "s": w_sum(Col("amount"), range_window(600)),
    })
    reg.register(v1)
    v2 = v1.evolve({"m": w_mean(Col("amount"), range_window(600))})
    reg.register(v2)
    assert reg.versions("fraud") == [1, 2]
    assert set(reg.get("fraud").features) == {"s", "m"}  # latest
    lin = reg.lineage("fraud", "s", version=2)
    assert lin["columns"] == ["amount"]
    assert lin["windows"][0]["size"] == 600
    assert "OVER (PARTITION BY uid" in lin["sql"]
    rec = reg.deploy("fraud_svc", "fraud")
    assert rec["version"] == 2
    assert reg.service("fraud_svc")["features"] == ["s", "m"]


def test_render_sql_roundtrip_tokens():
    e = w_sum(Col("amount") * (Col("amount") > 10.0), range_window(100))
    sql = render_sql("f", e, SCHEMA)
    for tok in ("sum", "amount", "RANGE BETWEEN 100 PRECEDING", "PARTITION BY uid"):
        assert tok in sql, sql


def test_signature_ids_range_and_determinism():
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.integers(0, 1000, 256), jnp.int32)
    b = jnp.asarray(rng.integers(0, 1000, 256), jnp.int32)
    s1 = signature_ids([a, b], bits=20)
    s2 = signature_ids([a, b], bits=20)
    assert np.array_equal(s1, s2)
    assert int(s1.min()) >= 0 and int(s1.max()) < 2**20
    # order sensitivity (product x item != item x product)
    s3 = signature_ids([b, a], bits=20)
    assert not np.array_equal(s1, s3)


def test_multi_hash_ids_distinct_probes():
    sig = jnp.asarray([42], jnp.int32)
    ids = multi_hash_ids(sig, 4, 1 << 16)
    assert len(set(np.asarray(ids).ravel().tolist())) >= 3


def test_count_min_sketch_overestimates_bounded():
    rng = np.random.default_rng(6)
    items = rng.zipf(1.5, 5000).astype(np.int32) % 1000
    sk = cms_init(depth=4, width=2048)
    sk = cms_update(sk, jnp.asarray(items))
    uniq, counts = np.unique(items, return_counts=True)
    est = np.asarray(cms_query(sk, jnp.asarray(uniq)))
    assert (est >= counts - 1e-5).all()          # never underestimates
    assert (est - counts).mean() < 30            # small average overestimate
