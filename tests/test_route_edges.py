"""route_rank / fused-dispatch capacity boundaries (ISSUE 9 satellite).

Three edges the curated suites never hit:

* ``route_rank`` correctness at and just above the 2^20 Pallas row
  cutoff (the auto-dispatch boundary), plus interpret-mode Pallas parity
  at pow2-edge batch sizes;
* ``_route_bucket`` values and invariants at pow2 edges — the optimistic
  grid capacity is a latency guess, never a correctness one, so its
  contract (pow2, floored at 16, capped at pow2ceil(m), monotone) is
  what the overflow machinery relies on;
* the overflow → exact re-dispatch path at a pow2 edge, and the
  ≤2-compiles-per-shape-bucket budget under generated-view diversity
  (one optimistic capacity + one safe cap per batch shape, never more).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import FeatureView, ShardedOnlineStore
from repro.core.expr import Col, range_window, w_count, w_sum
from repro.data.synthetic import STRESS_DB, stress_stream
from repro.kernels.route.ops import _ROUTE_PALLAS_MAX_ROWS, route_rank
from repro.kernels.route.ref import route_rank_ref
from repro.stress.generate import NUM_ENTITIES, T_MAX, gen_views, stress_rng


def _expected_ranks(shard: np.ndarray, S: int):
    """Independent O(n) oracle: rank = #earlier rows on the same shard."""
    counts = np.bincount(shard, minlength=S)
    order = np.argsort(shard, kind="stable")
    starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
    rank = np.empty(len(shard), np.int64)
    rank[order] = np.arange(len(shard)) - np.repeat(starts, counts)
    return rank, counts


@pytest.mark.parametrize(
    "n", [_ROUTE_PALLAS_MAX_ROWS, _ROUTE_PALLAS_MAX_ROWS + 1]
)
def test_route_rank_at_pallas_cutoff(n):
    """Exactly at / just above the cutoff: the XLA path (what auto picks
    above the boundary, and everywhere off-TPU) stays correct at rows
    the curated batches never reach."""
    S = 8
    rng = np.random.default_rng(n)
    shard = rng.integers(0, S, size=n).astype(np.int32)
    rank, counts = route_rank(jnp.asarray(shard), num_shards=S, impl="xla")
    exp_rank, exp_counts = _expected_ranks(shard, S)
    assert np.array_equal(np.asarray(counts), exp_counts)
    assert np.array_equal(np.asarray(rank), exp_rank)
    # auto must agree bit-for-bit with the explicit impl on this backend
    rank_a, counts_a = route_rank(jnp.asarray(shard), num_shards=S)
    assert np.array_equal(np.asarray(rank_a), exp_rank)
    assert np.array_equal(np.asarray(counts_a), exp_counts)


def test_route_rank_auto_cutoff_is_tpu_only():
    """The auto policy: Pallas only on a TPU backend and only at or
    below the row cutoff — on this (CPU) backend auto resolves to the
    XLA reference for every size."""
    assert _ROUTE_PALLAS_MAX_ROWS == 1 << 20
    assert jax.default_backend() != "tpu" or pytest.skip("CPU-only check")


@pytest.mark.parametrize("n", [15, 16, 17, 1023, 1024, 1025])
def test_route_rank_pallas_interpret_pow2_edges(n):
    """Interpret-mode Pallas parity at pow2-edge sizes (the tiling's
    padding boundary: lane remainder vs full tiles)."""
    S = 4
    rng = np.random.default_rng(n)
    shard = rng.integers(0, S, size=n).astype(np.int32)
    r_ref, c_ref = route_rank_ref(jnp.asarray(shard), S)
    r_pal, c_pal = route_rank(
        jnp.asarray(shard), num_shards=S, impl="pallas", interpret=True
    )
    assert np.array_equal(np.asarray(r_pal), np.asarray(r_ref))
    assert np.array_equal(np.asarray(c_pal), np.asarray(c_ref))


def _edge_view() -> FeatureView:
    return FeatureView(
        "route_edge",
        features={
            "s": w_sum(Col("amount"), range_window(256, bucket=64)),
            "c": w_count(Col("amount"), range_window(512, bucket=64)),
        },
        database=STRESS_DB,
    )


def _edge_store(num_keys=256, num_shards=8, device_routing=True):
    return ShardedOnlineStore(
        _edge_view(),
        num_keys=num_keys,
        num_shards=num_shards,
        capacity=64,
        device_routing=device_routing,
    )


def test_route_bucket_pow2_edges():
    store = _edge_store()
    S = store.num_shards
    f = store._route_bucket
    # hand-computed pow2-edge values for S=8: per-shard share doubles,
    # pow2-rounded, floored at 16, capped at pow2ceil(m)
    assert [f(m) for m in (1, 2, 8, 15, 16, 17)] == [1, 2, 8, 16, 16, 16]
    assert f(64) == 16           # even split: 8/shard, 2x=16
    assert f(65) == 32           # crossing the edge doubles the guess
    assert [f(m) for m in (128, 129, 256)] == [32, 64, 64]
    prev = 0
    for m in range(1, 1025):
        b = f(m)
        cap = 1 << max(m - 1, 0).bit_length()
        assert b & (b - 1) == 0          # power of two
        assert b <= max(cap, 1)          # never beyond the safe cap
        assert b >= min(16, cap)         # floored at 16 (unless capped)
        assert b >= prev                 # monotone in m
        prev = b


def test_overflow_redispatch_exact_at_pow2_edge():
    """An adversarial batch one row past the optimistic capacity on a
    single shard: the on-device overflow flag must re-dispatch at the
    safe cap and stay bit-identical to the host-routed oracle — and the
    shape bucket must have compiled exactly two capacities."""
    rng = np.random.default_rng(123)
    dev = _edge_store(device_routing=True)
    host = _edge_store(device_routing=False)
    n = 400
    rows = dict(
        entity=rng.integers(0, 256, n).astype(np.int32),
        ts=np.sort(rng.choice(3000, n, replace=False)).astype(np.int32),
        amount=rng.gamma(2.0, 30.0, n).astype(np.float32),
        quantity=np.ones(n, np.float32),
        score=np.zeros(n, np.float32),
        item=np.zeros(n, np.int32),
    )
    order = np.lexsort((rows["ts"], rows["entity"]))
    for s in (dev, host):
        s.ingest({c: v[order] for c, v in rows.items()})
    # pick 17 keys that all route to one shard: m=17 gets optimistic
    # bucket 16 (pow2 edge), so a one-shard batch overflows by one row
    all_keys = np.arange(256, dtype=np.int64)
    on_shard = all_keys[np.asarray(dev.shard_of(all_keys)) == 0][:17]
    assert len(on_shard) == 17
    assert dev._route_bucket(17) == 16
    m = len(on_shard)
    req = dict(
        entity=on_shard.astype(np.int32),
        ts=np.full(m, 3500, np.int32),
        amount=np.ones(m, np.float32),
        quantity=np.ones(m, np.float32),
        score=np.zeros(m, np.float32),
        item=np.zeros(m, np.int32),
    )
    a = dev.query(req, mode="preagg")
    b = host.query(req, mode="preagg")
    for f in ("s", "c"):
        np.testing.assert_array_equal(np.asarray(a[f]), np.asarray(b[f]))
    # ≤2 compiles for the shape bucket: optimistic 16 + safe cap 32
    caps = {k[2] for k in dev._fused_fns}
    assert caps == {16, 32}, caps


def test_compile_budget_under_generated_view_diversity():
    """Generated-view diversity must not widen the per-shape compile
    budget: for every (program, mode, scenario-count) group, at most two
    grid capacities — the optimistic bucket and the safe cap."""
    from repro.core.scenario import ScenarioPlane

    views = gen_views(5, 8)
    plane = ScenarioPlane(
        views, num_keys=NUM_ENTITIES, num_shards=8, name="budget",
        capacity=256, secondary_num_keys={"items": 24},
    )
    tabs = stress_stream(
        stress_rng(5, 8, "default", "data"), 600,
        num_entities=NUM_ENTITIES, num_items=24, t_max=T_MAX,
    )
    for t in plane.store._sec_names:
        sch = STRESS_DB.table(t)
        cols = tabs[t]
        order = np.lexsort((cols[sch.ts], cols[sch.key]))
        plane.ingest_table(t, {c: v[order] for c, v in cols.items()})
    ev = tabs["events"]
    order = np.lexsort((ev["ts"], ev["entity"]))
    plane.ingest({c: v[order] for c, v in ev.items()})
    rng = np.random.default_rng(17)
    scens = plane.scenarios
    for start in (0, 64, 128, 192):
        idx = np.arange(start, start + 48)
        probe = {c: v[idx] for c, v in ev.items()}
        tags = np.array([scens[i % len(scens)] for i in range(48)])
        plane.query_mixed(probe, tags)
    # adversarial one-shard batch forces the overflow capacity too
    keys = np.arange(NUM_ENTITIES, dtype=np.int64)
    skewed = keys[np.asarray(plane.store.shard_of(keys)) == 1]
    idx = np.where(np.isin(ev["entity"], skewed))[0][:48]
    if len(idx):
        probe = {c: v[idx] for c, v in ev.items()}
        tags = np.array([scens[i % len(scens)] for i in range(len(idx))])
        plane.query_mixed(probe, tags)
    by_group = {}
    for pname, mode, bucket, num_scen in plane.store._fused_fns:
        by_group.setdefault((pname, mode, num_scen), set()).add(bucket)
    assert by_group, "fused path never compiled"
    for group, buckets in by_group.items():
        assert len(buckets) <= 2, (group, buckets)
