"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs; plus a decode-step cache check."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS, get_smoke_config
from repro.models import build_model

ALL_ARCHS = [a for a in ARCHS]


def _batch_for(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.float32
        )
    elif cfg.frontend is not None:
        batch["frontend_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(seed=0)
    batch = _batch_for(cfg)

    def loss_fn(p):
        loss, metrics = model.loss(p, batch)
        return loss, metrics

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    # gradient sanity: finite and at least one nonzero leaf
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves), arch
    assert any(float(jnp.abs(l).max()) > 0 for l in leaves), arch
    # loss should be near ln(vocab) at random init
    expected = np.log(cfg.vocab)
    assert 0.3 * expected < float(metrics["nll"]) < 3.0 * expected, (
        arch, float(metrics["nll"]), expected
    )


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(seed=0)
    B, S = 2, 16
    batch = _batch_for(cfg, B=B, S=S)

    if cfg.family in ("dense", "moe"):
        extra = cfg.frontend_len if cfg.frontend else 0
        logits, cache = model.prefill(params, batch, max_len=S + extra + 4)
    elif cfg.family == "rwkv":
        logits, cache = model.prefill(params, batch)
    elif cfg.family == "griffin":
        cache = model.init_state(B)
        logits = None
    else:  # encdec
        logits, cache = model.prefill(params, batch, max_len=S + 4)

    if logits is not None:
        assert logits.shape[:2] == (B, 1)
        assert np.isfinite(np.asarray(logits)).all(), arch

    tok = jnp.ones((B, 1), jnp.int32)
    if cfg.family == "griffin":
        logits2, cache2 = model.decode_step(params, cache, tok)
    else:
        logits2, cache2 = model.decode_step(params, cache, tok)
    assert logits2.shape == (B, 1, cfg.vocab_padded), (arch, logits2.shape)
    assert np.isfinite(np.asarray(logits2)).all(), arch
    # cache advanced
    def _pos(c):
        if isinstance(c, dict):
            return c["self"].pos if "self" in c else c["pos"]
        return c.pos

    assert int(_pos(cache2)[0]) == int(_pos(cache)[0]) + 1


def test_decode_matches_prefill_dense():
    """Teacher-forcing equivalence: decode logits == prefill logits."""
    cfg = get_smoke_config("qwen3-32b")
    model = build_model(cfg)
    params = model.init(seed=0)
    rng = np.random.default_rng(0)
    B, S = 1, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    # full forward logits at each position
    full, _ = model.loss(params, {"tokens": tokens, "labels": jnp.full((B, S), -1)})
    # prefill on the prefix, then decode token by token
    prefix = 6
    logits_p, cache = model.prefill(
        params, {"tokens": tokens[:, :prefix]}, max_len=S
    )
    outs = [logits_p[:, 0]]
    for i in range(prefix, S):
        lg, cache = model.decode_step(params, cache, tokens[:, i:i + 1])
        outs.append(lg[:, 0])

    # reference: prefill over longer prefixes, compare last-token logits
    for i in range(prefix, S):
        ref, _ = model.prefill(params, {"tokens": tokens[:, :i + 1]}, max_len=S)
        got = outs[i - prefix + 1] if i + 1 <= S - 1 else outs[-1]
        # outs[j] is logits after consuming token j-1+prefix
        np.testing.assert_allclose(
            np.asarray(outs[i - prefix + 1]), np.asarray(ref[:, 0]),
            rtol=2e-4, atol=2e-4,
        )


def test_rwkv_decode_matches_prefill():
    cfg = get_smoke_config("rwkv6-3b")
    model = build_model(cfg)
    params = model.init(seed=0)
    rng = np.random.default_rng(1)
    B, S = 1, 10
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    prefix = 5
    _, state = model.prefill(params, {"tokens": tokens[:, :prefix]})
    outs = []
    for i in range(prefix, S):
        lg, state = model.decode_step(params, state, tokens[:, i:i + 1])
        outs.append(lg[:, 0])
    for i in range(prefix, S):
        ref, _ = model.prefill(params, {"tokens": tokens[:, :i + 1]})
        np.testing.assert_allclose(
            np.asarray(outs[i - prefix]), np.asarray(ref[:, 0]),
            rtol=5e-4, atol=5e-4,
        )


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the exact assigned hyperparams."""
    from repro.configs.registry import get_config

    spec = {
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "rwkv6-3b": (32, 2560, None, None, 8960, 65536),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "phi3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }
    for arch, (L, D, H, Hkv, F, V) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L and cfg.d_model == D, arch
        assert cfg.d_ff == F and cfg.vocab == V, arch
        if H is not None:
            assert cfg.n_heads == H and cfg.n_kv_heads == Hkv, arch
    assert get_config("mixtral-8x7b").num_experts == 8
    assert get_config("mixtral-8x7b").top_k == 2
    assert get_config("moonshot-v1-16b-a3b").num_experts == 64
    assert get_config("moonshot-v1-16b-a3b").top_k == 6
