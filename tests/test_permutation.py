"""KeyPermutation property coverage (ISSUE 9 satellite).

The Feistel permutation is the sharded plane's routing primitive and —
since the algebraic inverse — the migration decoder's too.  Three
contracts, over randomized key domains including non-pow2 sizes:

* bijectivity on [0, upper) and exact round-trips both ways:
  ``inverse(perm(k)) == k`` and ``perm(inverse(k)) == k``;
* host/device bit-exactness: ``device_call`` (the fused request path)
  equals ``__call__`` (ingest routing) for every key;
* ``mix32_np`` == ``mix32`` bit-exactness (the Feistel round function's
  two implementations), including negative int32 inputs.

Deterministic sweeps always run; richer randomized sweeps activate when
``hypothesis`` is installed (requirements.txt), same gating pattern as
tests/test_aggregates.py.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.hashing import KeyPermutation, mix32, mix32_np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False

# deliberately non-pow2-heavy: primes, pow2±1, tiny and mid-size domains
UPPERS = [1, 2, 3, 5, 7, 12, 16, 17, 48, 100, 255, 256, 257, 1000, 4096, 5001]


@pytest.mark.parametrize("upper", UPPERS)
def test_bijection_and_roundtrip(upper):
    perm = KeyPermutation(upper, salt=upper * 7 + 1)
    k = np.arange(upper, dtype=np.int64)
    fwd = perm(k)
    # bijection onto the exact domain (cycle-walking never escapes it)
    assert np.array_equal(np.sort(fwd), k)
    # both round-trip directions are exact
    assert np.array_equal(perm.inverse(fwd), k)
    assert np.array_equal(perm(perm.inverse(k)), k)


@pytest.mark.parametrize("upper", [7, 48, 257, 5001])
def test_host_device_bit_exact(upper):
    perm = KeyPermutation(upper, salt=3)
    k = np.arange(upper, dtype=np.int64)
    host = perm(k)
    dev = np.asarray(perm.device_call(jnp.asarray(k, jnp.int32)))
    assert np.array_equal(host, dev)


def test_mix32_host_device_bit_exact():
    rng = np.random.default_rng(9)
    x = rng.integers(-(2**31), 2**31, size=4096, dtype=np.int64)
    for salt in (0, 1, 0x9E37, 0x7FFFFFFF):
        a = mix32_np(x, salt=salt)
        b = np.asarray(mix32(jnp.asarray(x, jnp.int32), salt=salt))
        assert np.array_equal(a, b), salt


def test_inverse_rejects_out_of_domain():
    perm = KeyPermutation(100)
    with pytest.raises(ValueError):
        perm.inverse(np.array([100]))
    with pytest.raises(ValueError):
        perm.inverse(np.array([-1]))


def test_scalar_shape_preserved():
    perm = KeyPermutation(48, salt=5)
    v = perm(7)
    assert np.shape(v) == ()
    assert perm.inverse(v) == 7


if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=60)
    @given(
        upper=st.integers(min_value=1, max_value=1 << 16),
        salt=st.integers(min_value=0, max_value=2**31 - 1),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_roundtrip_randomized(upper, salt, seed):
        perm = KeyPermutation(upper, salt=salt)
        rng = np.random.default_rng(seed)
        k = rng.integers(0, upper, size=min(upper, 512), dtype=np.int64)
        assert np.array_equal(perm.inverse(perm(k)), k)
        assert np.array_equal(perm(perm.inverse(k)), k)

    @settings(deadline=None, max_examples=30)
    @given(
        upper=st.integers(min_value=1, max_value=1 << 14),
        salt=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_host_device_randomized(upper, salt):
        perm = KeyPermutation(upper, salt=salt)
        k = np.arange(min(upper, 1024), dtype=np.int64)
        host = perm(k)
        dev = np.asarray(perm.device_call(jnp.asarray(k, jnp.int32)))
        assert np.array_equal(host, dev)
