"""Sharded online serving plane: routing invariance + consistency.

The contract under test (ISSUE 2 acceptance): for random multi-table
streams, a ShardedOnlineStore's answers — any shard count, any ingest
interleaving — are **exactly** equal (bit-for-bit, not approximately) to
the single-device OnlineFeatureStore's under the same stream, and the
sharded replay passes the offline↔online verification.  Runs multi-device
via conftest's ``--xla_force_host_platform_device_count=8``.
"""

import numpy as np
import pytest

import jax

from repro.core import (
    Col,
    Database,
    FeatureView,
    OnlineFeatureStore,
    ShardedOnlineStore,
    TableSchema,
    last_join,
    range_window,
    rows_window,
    w_count,
    w_distinct_approx,
    w_max,
    w_mean,
    w_std,
    w_sum,
)
from repro.core.consistency import replay_rounds, verify_view
from repro.core.shard import build_route, make_shard_mesh

K = 16
NM = 4

DB = Database(
    name="mt",
    primary=TableSchema(
        "tx", key="acct", ts="ts", numeric=("amount", "merchant")
    ),
    secondary=(
        TableSchema("wires", key="acct", ts="ts", numeric=("amount",)),
        TableSchema("accounts", key="acct", ts="ts", numeric=("limit",)),
        TableSchema("merchants", key="merchant", ts="ts", numeric=("risk",)),
    ),
)


def multi_table_view() -> FeatureView:
    amt = Col("amount")
    w1 = range_window(300, bucket=64)
    credit = last_join(Col("limit"), "accounts", on="acct", default=500.0)
    return FeatureView(
        "sharded_mtv",
        features={
            "limit": credit,
            "mrisk": last_join(
                Col("risk"), "merchants", on="merchant", default=0.5
            ),
            "out_sum": w_sum(amt, w1, union=("wires",)),
            "out_cnt": w_count(amt, w1, union=("wires",)),
            "out_std": w_std(amt, w1, union=("wires",)),
            "util": w_sum(amt, w1, union=("wires",)) / credit,
            "plain": w_mean(amt, w1),
            "mx": w_max(amt, w1),
            "r5": w_count(amt, rows_window(5)),
            "uniq": w_distinct_approx(Col("merchant"), w1),
        },
        database=DB,
    )


def make_tables(rng, n=240, t_max=2_000):
    ts = np.sort(rng.choice(t_max, size=n, replace=False)).astype(np.int32)
    tx = dict(
        acct=rng.integers(0, K, n).astype(np.int32),
        ts=ts,
        amount=rng.gamma(2.0, 10.0, n).astype(np.float32),
        merchant=rng.integers(0, NM, n).astype(np.int32),
    )
    m = n // 2
    wires = dict(
        acct=rng.integers(0, K, m).astype(np.int32),
        ts=np.sort(rng.integers(0, t_max, m)).astype(np.int32),
        amount=rng.gamma(2.0, 10.0, m).astype(np.float32),
    )
    accounts = dict(
        acct=np.concatenate([np.arange(K), rng.integers(0, K, K)]).astype(
            np.int32
        ),
        ts=np.concatenate([np.zeros(K), rng.integers(1, t_max, K)]).astype(
            np.int32
        ),
        limit=rng.uniform(100.0, 1000.0, 2 * K).astype(np.float32),
    )
    merchants = dict(
        merchant=np.arange(NM).astype(np.int32),
        ts=np.zeros(NM, np.int32),
        risk=rng.random(NM).astype(np.float32),
    )
    return tx, {"wires": wires, "accounts": accounts, "merchants": merchants}


def _bykey(d, kc):
    o = np.lexsort((d["ts"], d[kc]))
    return {c: v[o] for c, v in d.items()}


def _ingest_stream(store, tx, sec, chunks):
    """Interleave primary/secondary ingest in ``chunks`` pieces each."""
    for piece in np.array_split(np.arange(len(sec["wires"]["ts"])), chunks):
        if len(piece):
            store.ingest_table(
                "wires",
                _bykey({c: v[piece] for c, v in sec["wires"].items()}, "acct"),
            )
    store.ingest_table("accounts", _bykey(sec["accounts"], "acct"))
    store.ingest_table("merchants", _bykey(sec["merchants"], "merchant"))
    for piece in np.array_split(np.arange(len(tx["ts"])), chunks):
        if len(piece):
            store.ingest(_bykey({c: v[piece] for c, v in tx.items()}, "acct"))


def test_multiple_devices_available():
    """conftest must have forced the multi-device CPU platform."""
    assert len(jax.devices()) >= 8


def test_build_route_shapes():
    shard = np.array([0, 1, 0, 2, 0, 1])
    plan = build_route(shard, 4, min_bucket=2)
    assert [list(ix) for ix in plan.idx] == [[0, 2, 4], [1, 5], [3], []]
    assert plan.bucket == 4  # longest=3 -> pow2 -> 4
    assert list(plan.counts) == [3, 2, 1, 0]


def test_mesh_divisor_fallback():
    # 8 devices: 8 shards -> 8-way mesh; 3 shards -> 3-way; 5 -> 5-way
    assert make_shard_mesh(8).devices.size == 8
    assert make_shard_mesh(3).devices.size == 3
    assert make_shard_mesh(16).devices.size == 8  # 16 % 8 == 0


@pytest.mark.parametrize(
    "mode,num_shards",
    [("naive", 1), ("preagg", 1), ("naive", 3), ("preagg", 8)],
)
def test_shard_invariance_multitable(mode, num_shards):
    """Property: sharded answers == single-device answers, bit-for-bit,
    for a 4-table view (LAST JOIN + WINDOW UNION), replayed round by
    round with interleaved ingest."""
    rng = np.random.default_rng(100 + num_shards)
    tx, sec = make_tables(rng)
    view = multi_table_view()
    kw = dict(num_keys=K, capacity=128, secondary_num_keys={"merchants": NM})
    single = OnlineFeatureStore(view, **kw)
    shard = ShardedOnlineStore(view, num_shards=num_shards, **kw)

    # preload the secondary tables, then replay the primary stream in
    # query-then-ingest rounds (the live-service pattern)
    for t in ("wires", "accounts", "merchants"):
        kc = DB.table(t).key
        for s in (single, shard):
            s.ingest_table(t, _bykey(sec[t], kc))

    key, ts = tx["acct"], tx["ts"]
    for idx in replay_rounds(key, ts):
        batch = {c: v[idx] for c, v in tx.items()}
        a = single.query(batch, mode=mode)
        b = shard.query(batch, mode=mode)
        for f in view.features:
            np.testing.assert_array_equal(
                np.asarray(a[f]),
                np.asarray(b[f]),
                err_msg=f"shards={num_shards} mode={mode} feature={f}",
            )
        srt = _bykey(batch, "acct")
        single.ingest(srt)
        shard.ingest(srt)


@pytest.mark.parametrize("chunks_a,chunks_b", [(1, 5)])
def test_shard_invariance_ingest_interleaving(chunks_a, chunks_b):
    """Property: for the SAME ingest interleaving, sharded == single
    exactly — under several different chunkings of the same stream."""
    rng = np.random.default_rng(42)
    tx, sec = make_tables(rng, n=200)
    view = multi_table_view()
    kw = dict(num_keys=K, capacity=128, secondary_num_keys={"merchants": NM})
    req = dict(
        acct=rng.integers(0, K, 33).astype(np.int32),
        ts=np.full(33, 3_000, np.int32),
        amount=rng.gamma(2.0, 10.0, 33).astype(np.float32),
        merchant=rng.integers(0, NM, 33).astype(np.int32),
    )
    for chunks in (chunks_a, chunks_b):
        single = OnlineFeatureStore(view, **kw)
        shard = ShardedOnlineStore(view, num_shards=4, **kw)
        _ingest_stream(single, tx, sec, chunks)
        _ingest_stream(shard, tx, sec, chunks)
        for mode in ("naive", "preagg"):
            a = single.query(req, mode=mode)
            b = shard.query(req, mode=mode)
            for f in view.features:
                np.testing.assert_array_equal(
                    np.asarray(a[f]),
                    np.asarray(b[f]),
                    err_msg=f"chunks={chunks} mode={mode} feature={f}",
                )


@pytest.mark.parametrize("mode", ["naive", "preagg"])
def test_verify_view_sharded(mode):
    """Acceptance: the sharded replay passes offline↔online verification
    on a multi-table view (LAST JOIN + WINDOW UNION included)."""
    rng = np.random.default_rng(3)
    tx, sec = make_tables(rng, n=320)
    rep = verify_view(
        multi_table_view(),
        tx,
        num_keys=K,
        secondary=sec,
        secondary_num_keys={"merchants": NM},
        mode=mode,
        num_shards=4,
    )
    assert rep.passed, rep.summary()
    assert "shards=4" in rep.mode


def test_secondary_table_placement():
    """Union-only tables are key-partitioned; join targets replicated."""
    view = multi_table_view()
    store = ShardedOnlineStore(
        view, num_keys=K, num_shards=4,
        secondary_num_keys={"merchants": NM},
    )
    assert store._sec_sharded == {
        "wires": True, "accounts": False, "merchants": False
    }
    # partitioned ring is ceil(K/S) keys per shard, replicated keeps K
    iw = store._sec_index["wires"]
    ia = store._sec_index["accounts"]
    assert store.state.sec[iw].ts.shape[:2] == (4, K // 4)
    assert store.state.sec[ia].ts.shape[:2] == (4, K)


def test_dual_use_table_is_split():
    """A table that is both a union stream and a join target is SPLIT by
    the layout planner: its union-stream rows are key-partitioned (stored
    once, not S×) and only a narrow replicated join slice is copied per
    shard — the dual-use partitioning that recovers the S× memory the old
    replicate-everything policy paid."""
    db = Database(
        name="d",
        primary=TableSchema("tx", key="k", ts="ts", numeric=("a",)),
        secondary=(TableSchema("w", key="k", ts="ts", numeric=("a",)),),
    )
    view = FeatureView(
        "dual",
        features={
            "u": w_sum(Col("a"), range_window(100), union=("w",)),
            "j": last_join(Col("a"), "w", on="k"),
        },
        database=db,
    )
    S = 4
    store = ShardedOnlineStore(view, num_keys=8, num_shards=S, capacity=64)
    rings = store.layout.rings_of("w")
    assert len(rings) == 2
    union_p = store.layout.tables[store.layout.union_ring("w")]
    join_p = store.layout.tables[store.layout.join_ring("w")]
    assert union_p.partitioned and union_p.serves == ("union",)
    assert not join_p.partitioned and join_p.serves == ("join",)
    # partitioned union ring: ceil(K/S) keys per shard; join slice: all K
    iu, ij = store.layout.union_ring("w"), store.layout.join_ring("w")
    assert store.state.sec[iu].ts.shape[:2] == (S, 8 // S)
    assert store.state.sec[ij].ts.shape[:2] == (S, 8)

    # ingest N rows -> union part stores N rows TOTAL (spread over
    # shards), join slice stores N per shard; answers match the single
    # store bit-for-bit
    rng = np.random.default_rng(8)
    n = 48
    rows = dict(
        k=np.repeat(np.arange(8, dtype=np.int32), n // 8),
        ts=np.tile(np.arange(n // 8, dtype=np.int32), 8),
        a=rng.gamma(2.0, 5.0, n).astype(np.float32),
    )
    single = OnlineFeatureStore(view, num_keys=8, capacity=64)
    for s in (single, store):
        s.ingest_table("w", rows)
        s.ingest(
            {
                "k": np.arange(8, dtype=np.int32),
                "ts": np.full(8, 50, np.int32),
                "a": np.ones(8, np.float32),
            }
        )
    counts = store.ring_row_counts()
    assert counts[("w", "partitioned")].sum() == n       # stored once
    assert counts[("w", "partitioned")].max() < n        # and spread
    assert (counts[("w", "replicated")] == n).all()      # join slice S×
    # the table's total accounting: N partitioned + S×N replicated slice
    assert store.ingest_row_counts()["w"] == n + S * n
    req = {
        "k": np.arange(8, dtype=np.int32),
        "ts": np.full(8, 100, np.int32),
        "a": np.ones(8, np.float32),
    }
    for mode in ("naive", "preagg"):
        a = single.query(req, mode=mode)
        b = store.query(req, mode=mode)
        for f in view.features:
            np.testing.assert_array_equal(
                np.asarray(a[f]), np.asarray(b[f]), err_msg=f"{mode}:{f}"
            )


def test_out_of_range_key_rejected():
    """The single store clamps out-of-range keys; the sharded store would
    route them to a different key's shard, so it must reject them."""
    view = FeatureView(
        "oor", DB.primary,
        {"s": w_sum(Col("amount"), range_window(100))},
    )
    store = ShardedOnlineStore(view, num_keys=K, num_shards=4, capacity=64)
    req = dict(
        acct=np.array([K], np.int32),  # one past the key space
        ts=np.array([10], np.int32),
        amount=np.ones(1, np.float32),
        merchant=np.zeros(1, np.int32),
    )
    with pytest.raises(ValueError, match="out of range"):
        store.query(req)
    with pytest.raises(ValueError, match="out of range"):
        store.ingest(req)


def test_shard_row_counts_balance():
    rng = np.random.default_rng(9)
    view = FeatureView(
        "s", DB.primary,
        {"s": w_sum(Col("amount"), range_window(100))},
    )
    store = ShardedOnlineStore(view, num_keys=K, num_shards=4, capacity=64)
    n = 400
    tx = dict(
        acct=rng.integers(0, K, n).astype(np.int32),
        ts=np.arange(n, dtype=np.int32),
        amount=np.ones(n, np.float32),
        merchant=np.zeros(n, np.int32),
    )
    store.ingest(_bykey(tx, "acct"))
    counts = store.shard_row_counts()
    assert counts.sum() == n
    # uniform keys => no shard owns everything
    assert counts.min() > 0


def test_hash_routing_spreads_strided_keys():
    """Adversarial key pattern (all keys ≡ 0 mod S): raw modulo routing
    collapses onto shard 0; the default mix64-Feistel routing spreads the
    load — visible in ShardRouter's skew histogram."""
    from repro.serve.router import ShardRouter
    from repro.serve.service import FeatureService

    S, n_keys = 4, 64
    view = FeatureView(
        "skew", DB.primary,
        {"s": w_sum(Col("amount"), range_window(100))},
    )
    strided = np.arange(0, n_keys * S, S, dtype=np.int32)  # all ≡ 0 mod S

    def histogram(hash_routing):
        store = ShardedOnlineStore(
            view, num_keys=n_keys * S, num_shards=S, capacity=64,
            hash_routing=hash_routing,
        )
        router = ShardRouter(FeatureService("svc", view, store), ingest=False)
        for k in strided:
            router.submit(dict(acct=int(k), ts=10, amount=1.0, merchant=0))
        router.drain()
        return router.shard_histogram()

    mod = histogram(False)
    hashed = histogram(True)
    assert mod[0] == len(strided) and (mod[1:] == 0).all()  # the collapse
    assert (hashed > 0).all()                               # the spread
    assert hashed.max() < len(strided) // 2
    assert hashed.sum() == mod.sum() == len(strided)


@pytest.mark.parametrize("hash_routing", [False, True])
def test_hash_routing_same_answers(hash_routing):
    """Routing choice is invisible in answers: both modes match the
    single-device store bit-for-bit (per-key state is key-local)."""
    rng = np.random.default_rng(17)
    view = FeatureView(
        "hr", DB.primary,
        {"s": w_sum(Col("amount"), range_window(300, bucket=64)),
         "m": w_mean(Col("amount"), rows_window(5))},
    )
    n = 300
    tx = dict(
        acct=(rng.integers(0, K, n) * 8 % K).astype(np.int32),  # strided-ish
        ts=np.arange(n, dtype=np.int32),
        amount=rng.gamma(2.0, 10.0, n).astype(np.float32),
        merchant=np.zeros(n, np.int32),
    )
    single = OnlineFeatureStore(view, num_keys=K, capacity=64)
    sharded = ShardedOnlineStore(
        view, num_keys=K, num_shards=4, capacity=64,
        hash_routing=hash_routing,
    )
    by_key = _bykey(tx, "acct")
    single.ingest(by_key)
    sharded.ingest(by_key)
    req = dict(
        acct=np.arange(K, dtype=np.int32),
        ts=np.full(K, n + 1, np.int32),
        amount=np.ones(K, np.float32),
        merchant=np.zeros(K, np.int32),
    )
    for mode in ("naive", "preagg"):
        a = single.query(req, mode=mode)
        b = sharded.query(req, mode=mode)
        for f in view.features:
            np.testing.assert_array_equal(
                np.asarray(a[f]), np.asarray(b[f]), err_msg=f"{mode}:{f}"
            )


# -- device-resident request path (fused on-mesh routing) -------------------


def test_device_perm_matches_host():
    """The device Feistel mirror returns the SAME permuted id as the host
    numpy permutation for every key — the routing split (shard, local)
    is bit-identical on both sides."""
    import jax.numpy as jnp

    from repro.core.hashing import KeyPermutation

    for upper in (1, 2, 5, 16, 100, 1 << 14):
        perm = KeyPermutation(upper, salt=upper)
        keys = np.arange(upper, dtype=np.int64)[:4096]
        host = perm(keys)
        dev = np.asarray(perm.device_call(jnp.asarray(keys, jnp.int32)))
        np.testing.assert_array_equal(host, dev, err_msg=f"upper={upper}")


@pytest.mark.parametrize("num_shards", [1, 2, 4, 8])
def test_device_host_single_routing_parity(num_shards):
    """Tentpole acceptance: the fused device-routed path == the
    host-routed oracle == the single-device store, bit-for-bit, both
    query modes, replayed with interleaved ingest — and the per-shard
    routing histograms (``route_info``) are identical under both paths,
    so skew monitoring cannot drift between flavours."""
    rng = np.random.default_rng(500 + num_shards)
    tx, sec = make_tables(rng, n=160)
    view = multi_table_view()
    kw = dict(num_keys=K, capacity=128, secondary_num_keys={"merchants": NM})
    single = OnlineFeatureStore(view, **kw)
    host = ShardedOnlineStore(
        view, num_shards=num_shards, device_routing=False, **kw
    )
    dev = ShardedOnlineStore(
        view, num_shards=num_shards, device_routing=True, **kw
    )
    assert not host.device_routing and dev.device_routing
    stores = (single, host, dev)
    for t in ("wires", "accounts", "merchants"):
        kc = DB.table(t).key
        for s in stores:
            s.ingest_table(t, _bykey(sec[t], kc))
    key, ts = tx["acct"], tx["ts"]
    for idx in replay_rounds(key, ts):
        batch = {c: v[idx] for c, v in tx.items()}
        for mode in ("naive", "preagg"):
            ri_h, ri_d = {}, {}
            a = single.query(batch, mode=mode)
            b = host.query(batch, mode=mode, route_info=ri_h)
            c = dev.query(batch, mode=mode, route_info=ri_d)
            for f in view.features:
                np.testing.assert_array_equal(
                    np.asarray(a[f]), np.asarray(b[f]),
                    err_msg=f"host S={num_shards} {mode}:{f}",
                )
                np.testing.assert_array_equal(
                    np.asarray(b[f]), np.asarray(c[f]),
                    err_msg=f"device S={num_shards} {mode}:{f}",
                )
            np.testing.assert_array_equal(
                ri_h["shard_counts"], ri_d["shard_counts"],
                err_msg=f"S={num_shards} {mode} histogram",
            )
            assert ri_d["shard_counts"].sum() == len(batch["ts"])
        srt = _bykey(batch, "acct")
        for s in stores:
            s.ingest(srt)


def test_device_routing_padding_mask_honored():
    """Filler rows (a real row repeated, ``valid=False``) must not leak
    into answers or histograms on either path: real-row answers equal
    the unpadded query's and both paths count only valid rows."""
    rng = np.random.default_rng(31)
    tx, sec = make_tables(rng, n=160)
    view = multi_table_view()
    kw = dict(num_keys=K, capacity=128, secondary_num_keys={"merchants": NM})
    host = ShardedOnlineStore(view, num_shards=4, device_routing=False, **kw)
    dev = ShardedOnlineStore(view, num_shards=4, device_routing=True, **kw)
    for t in ("wires", "accounts", "merchants"):
        kc = DB.table(t).key
        for s in (host, dev):
            s.ingest_table(t, _bykey(sec[t], kc))
            s2 = s  # noqa: F841  (clarity: both stores get the stream)
    for s in (host, dev):
        s.ingest(_bykey(tx, "acct"))
    q, pad = 13, 3
    req = {c: v[:q] for c, v in tx.items()}
    padded = {
        c: np.concatenate([v, np.repeat(v[-1:], pad)]) for c, v in req.items()
    }
    valid = np.arange(q + pad) < q
    for mode in ("naive", "preagg"):
        ri_h, ri_d = {}, {}
        bare = dev.query(req, mode=mode)
        b = host.query(padded, mode=mode, valid=valid, route_info=ri_h)
        c = dev.query(padded, mode=mode, valid=valid, route_info=ri_d)
        for f in view.features:
            np.testing.assert_array_equal(
                np.asarray(b[f])[:q], np.asarray(c[f])[:q],
                err_msg=f"{mode}:{f}",
            )
            np.testing.assert_array_equal(
                np.asarray(bare[f]), np.asarray(c[f])[:q],
                err_msg=f"unpadded {mode}:{f}",
            )
        np.testing.assert_array_equal(
            ri_h["shard_counts"], ri_d["shard_counts"]
        )
        assert ri_d["shard_counts"].sum() == q  # filler rows never counted


def test_device_route_overflow_fallback_exact():
    """Pathological skew — every row the same key, S=8 — overflows the
    optimistic per-shard capacity; the in-span safe re-dispatch keeps
    answers bit-identical to the host oracle and compiles exactly one
    extra capacity (the compile budget: optimistic + safe, never more)."""
    rng = np.random.default_rng(77)
    tx, sec = make_tables(rng, n=160)
    view = multi_table_view()
    kw = dict(num_keys=K, capacity=128, secondary_num_keys={"merchants": NM})
    host = ShardedOnlineStore(view, num_shards=8, device_routing=False, **kw)
    dev = ShardedOnlineStore(view, num_shards=8, device_routing=True, **kw)
    for t in ("wires", "accounts", "merchants"):
        kc = DB.table(t).key
        for s in (host, dev):
            s.ingest_table(t, _bykey(sec[t], kc))
    for s in (host, dev):
        s.ingest(_bykey(tx, "acct"))
    n = 64
    req = dict(
        acct=np.full(n, 3, np.int32),          # all rows -> one shard
        ts=np.full(n, 3_000, np.int32),
        amount=rng.gamma(2.0, 10.0, n).astype(np.float32),
        merchant=rng.integers(0, NM, n).astype(np.int32),
    )
    a = host.query(req, mode="preagg")
    b = dev.query(req, mode="preagg")
    for f in view.features:
        np.testing.assert_array_equal(
            np.asarray(a[f]), np.asarray(b[f]), err_msg=f
        )
    # optimistic capacity for m=64 over S=8 is 16 < 64 rows on one shard,
    # so the overflow re-dispatch must have compiled the safe capacity too
    caps = {k[2] for k in dev._fused_fns}  # (pname, mode, bucket, num_scen)
    assert caps == {16, 64}, caps
