"""Declarative StoreLayout plan + live plane evolution (ISSUE 5).

Acceptance contract under test: ``hot_deploy`` of a new scenario on a
warm sharded plane (shards ∈ {1, 4, 8}) preserves all prior state
**bit-exactly** vs a cold rebuild + full replay oracle, without
re-ingesting shared tables (``ingest_row_counts`` unchanged for
carried-over tables); and dual-use secondary tables no longer pay S×
replication for their union-stream part (asserted via per-shard row
counts).  Plus: planner determinism/append-stability, lane synthesis,
capacity re-lay, fail-loud unsupported diffs, and TTL plan knobs.
"""

import numpy as np
import pytest

import jax

from repro.core import (
    Col,
    FeatureView,
    ScenarioPlane,
    ShardedOnlineStore,
    OnlineFeatureStore,
    diff_layouts,
    last_join,
    plan_layout,
    range_window,
    w_count,
    w_max,
    w_mean,
    w_sum,
)
from repro.core.consistency import replay_rounds
from repro.core.expr import Hash
from repro.data.synthetic import MULTITABLE_DB, multitable_stream
from repro.scenarios import multi_scenario_views

K = 16
NM = 8
STORE_KW = dict(
    num_keys=K, capacity=128, num_buckets=512, bucket_size=64,
    secondary_num_keys={"merchants": NM},
)


def make_tables(rng, n=150, t_max=40_000):
    tabs = multitable_stream(
        rng, n, num_accounts=K, num_merchants=NM, t_max=t_max
    )
    return tabs["transactions"], {
        t: c for t, c in tabs.items() if t != "transactions"
    }


def _bykey(d, kc):
    o = np.lexsort((d["ts"], d[kc]))
    return {c: v[o] for c, v in d.items()}


def _warm(plane, tx, sec, rounds=False):
    """Same deterministic ingest schedule for the live plane and the
    cold-rebuild oracle (bit-exactness is stated against an oracle that
    replays the SAME batch sequence)."""
    for t in plane.store._sec_names:
        kc = MULTITABLE_DB.table(t).key
        plane.ingest_table(t, _bykey(sec[t], kc))
    if rounds:
        key, ts = tx["account"], tx["ts"]
        for idx in replay_rounds(key, ts):
            plane.ingest(_bykey({c: v[idx] for c, v in tx.items()}, "account"))
    else:
        plane.ingest(_bykey(tx, "account"))


def _assert_state_equal(a, b):
    la = jax.tree_util.tree_leaves_with_path(a.store.state)
    lb = jax.tree_util.tree_leaves_with_path(b.store.state)
    assert len(la) == len(lb)
    for (p1, x), (_, y) in zip(la, lb):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=str(p1)
        )


def _assert_answers_equal(a, b, views, req, modes=("naive", "preagg")):
    for v in views:
        for mode in modes:
            ra = a.query(v.name, req, mode=mode)
            rb = b.query(v.name, req, mode=mode)
            for f in v.features:
                np.testing.assert_array_equal(
                    np.asarray(ra[f]),
                    np.asarray(rb[f]),
                    err_msg=f"{v.name}:{f}:{mode}",
                )


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------


def test_planner_roles_and_sizes():
    views = multi_scenario_views()
    lay = plan_layout(views, num_shards=4, raw_lanes=True, **STORE_KW)
    assert lay.primary.partitioned and lay.primary.ring_keys == K // 4
    roles = {
        (p.table, p.partitioned, p.serves) for p in lay.tables
    }
    assert ("wires", True, ("union",)) in roles          # union-only: partitioned
    assert ("accounts", False, ("join",)) in roles       # join-only: replicated
    assert ("merchants", False, ("join",)) in roles
    # evolvable: raw columns are lanes from day one
    assert ("col", "amount") in lay.primary.lane_keys
    assert ("col", "merchant") in lay.primary.lane_keys
    # bucket plan consumed by preagg
    assert lay.bucket.num_buckets == 512 and lay.bucket.bucket_size == 64


def test_planner_append_stable():
    """plan(views + [v]) keeps every slot and ring of plan(views) at the
    same position — the property hot deployment rests on."""
    views = multi_scenario_views()
    a = plan_layout(views[:2], num_shards=4, raw_lanes=True, **STORE_KW)
    b = plan_layout(views, num_shards=4, raw_lanes=True, **STORE_KW)
    assert b.primary.lane_keys[: len(a.primary.lane_keys)] == a.primary.lane_keys
    for i, p in enumerate(a.tables):
        assert b.tables[i].identity() == p.identity()
    # determinism
    c = plan_layout(views, num_shards=4, raw_lanes=True, **STORE_KW)
    assert b == c


def test_planner_names_offending_feature_on_bucket_overflow():
    """The window-fit ValueError names the view/feature and the computed
    bucket need — not just the raw sizes (ISSUE 5 satellite)."""
    big = FeatureView(
        "bigwin",
        MULTITABLE_DB.primary,
        {"huge_sum": w_sum(Col("amount"), range_window(100_000, bucket=64))},
        database=MULTITABLE_DB,
    )
    need = 100_000 // 64 + 2
    with pytest.raises(ValueError) as ei:
        plan_layout([big], num_keys=K, num_buckets=64, bucket_size=64)
    msg = str(ei.value)
    assert "huge_sum" in msg and str(need) in msg and "num_buckets=64" in msg
    # the store constructor path (planner inside) reports the same
    with pytest.raises(ValueError, match="huge_sum"):
        OnlineFeatureStore(big, num_keys=K, num_buckets=64, bucket_size=64)


# ---------------------------------------------------------------------------
# acceptance: hot deploy on a warm sharded plane == cold rebuild + replay
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("num_shards", [1, 4, 8])
def test_hot_deploy_bit_exact_vs_rebuild(num_shards):
    from repro.serve.service import FeatureService

    rng = np.random.default_rng(400 + num_shards)
    tx, sec = make_tables(rng)
    views = multi_scenario_views()

    svc = FeatureService.build_multi(
        "plane", views[:2], sharded=True, num_shards=num_shards, **STORE_KW
    )
    hot = svc.plane
    _warm(hot, tx, sec)
    before = hot.ingest_row_counts()

    report = svc.hot_deploy(views[2])
    assert report.exact
    assert report.new_programs == [views[2].name]
    # no re-ingest: carried tables' row accounting is unchanged
    assert hot.ingest_row_counts() == before

    cold = ScenarioPlane(views, num_shards=num_shards, **STORE_KW)
    _warm(cold, tx, sec)
    _assert_state_equal(hot, cold)

    req = dict(
        account=rng.integers(0, K, 33).astype(np.int32),
        ts=np.full(33, 50_000, np.int32),
        amount=rng.gamma(2.0, 10.0, 33).astype(np.float32),
        merchant=rng.integers(0, NM, 33).astype(np.int32),
    )
    _assert_answers_equal(hot, cold, views, req)
    # the new scenario serves through the service request path too
    out = svc.request(
        {c: v[:8] for c, v in req.items()}, ingest=False,
        scenario=views[2].name,
    )
    assert set(out) == set(views[2].features)


@pytest.mark.parametrize("num_shards", [None, 4])
def test_evolve_synthesizes_derived_lanes_and_recapacity(num_shards):
    """A hot-deployed view may introduce NEW derived window-arg lanes and
    grow ring capacity: lanes are synthesized from the raw-column history
    (ring values AND bucket pre-agg states), rings re-laid — still
    bit-exact vs the rebuild oracle inside the retention horizon."""
    amt = Col("amount")
    w1h = range_window(3600, bucket=64)
    va = FeatureView(
        "va",
        features={
            "out": w_sum(amt, w1h, union=("wires",)),
            "cnt": w_count(amt, w1h),
        },
        database=MULTITABLE_DB,
    )
    vb = FeatureView(
        "vb",
        features={
            "dbl": w_sum(amt * 2.0, w1h),
            "mx": w_max(amt * 2.0, w1h),
            "big": w_mean(amt > 20.0, w1h),
        },
        database=MULTITABLE_DB,
    )
    rng = np.random.default_rng(7 if num_shards is None else 7 + num_shards)
    tx, sec = make_tables(rng, n=140)

    hot = ScenarioPlane([va], num_shards=num_shards, **STORE_KW)
    _warm(hot, tx, sec, rounds=True)
    report = hot.evolve([va, vb], capacity=192)
    assert report.exact, report.notes
    assert any("dbl" in s or "mul" in s for s in report.synthesized_lanes)

    kw = {k: v for k, v in STORE_KW.items() if k != "capacity"}
    cold = ScenarioPlane([va, vb], num_shards=num_shards, capacity=192, **kw)
    _warm(cold, tx, sec, rounds=True)
    _assert_state_equal(hot, cold)
    req = dict(
        account=np.arange(K, dtype=np.int32),
        ts=np.full(K, 50_000, np.int32),
        amount=np.full(K, 25.0, np.float32),
        merchant=np.zeros(K, np.int32),
    )
    _assert_answers_equal(hot, cold, [va, vb], req)


def test_evolve_splits_dual_use_table():
    """Evolving a plane so a union table gains a LAST JOIN splits it
    live: the union-stream part stays partitioned (stored once — the S×
    recovery), a narrow replicated join slice is rebuilt from the
    partitioned rows, and everything stays bit-exact vs rebuild."""
    amt = Col("amount")
    w1h = range_window(3600, bucket=64)
    va = FeatureView(
        "va",
        features={"out": w_sum(amt, w1h, union=("wires",))},
        database=MULTITABLE_DB,
    )
    vb = FeatureView(
        "vb",
        features={
            "wire_amt": last_join(Col("amount"), "wires", on="account")
        },
        database=MULTITABLE_DB,
    )
    S = 4
    rng = np.random.default_rng(11)
    tx, sec = make_tables(rng, n=120)
    n_wires = len(sec["wires"]["ts"])

    hot = ScenarioPlane([va], num_shards=S, **STORE_KW)
    _warm(hot, tx, sec)
    counts0 = hot.store.ring_row_counts()
    assert counts0[("wires", "partitioned")].sum() == n_wires

    report = hot.evolve([va, vb])
    assert report.exact, report.notes

    counts = hot.store.ring_row_counts()
    # union part still stored ONCE (not S×), and spread across shards
    assert counts[("wires", "partitioned")].sum() == n_wires
    assert counts[("wires", "partitioned")].max() < n_wires
    # replicated join slice: one narrow copy per shard
    assert (counts[("wires", "replicated")] == n_wires).all()
    join_plan = hot.layout.tables[hot.layout.join_ring("wires")]
    assert len(join_plan.lanes) == 1  # the join-arg slice, not all lanes

    cold = ScenarioPlane([va, vb], num_shards=S, **STORE_KW)
    _warm(cold, tx, sec)
    _assert_state_equal(hot, cold)
    req = dict(
        account=np.arange(K, dtype=np.int32),
        ts=np.full(K, 50_000, np.int32),
        amount=np.ones(K, np.float32),
        merchant=np.zeros(K, np.int32),
    )
    _assert_answers_equal(hot, cold, [va, vb], req)


def test_evolve_can_drop_a_scenario():
    """Evolution also goes the other way: dropping a view removes its
    lanes (a lane PERMUTE for the survivors, not just truncation) and its
    program, and the shrunken plane still equals a fresh build + replay."""
    amt = Col("amount")
    w1h = range_window(3600, bucket=64)
    va = FeatureView(
        "va",
        features={"big": w_mean(amt > 10.0, w1h), "cnt": w_count(amt, w1h)},
        database=MULTITABLE_DB,
    )
    vb = FeatureView(
        "vb", features={"dbl": w_sum(amt * 2.0, w1h)}, database=MULTITABLE_DB
    )
    rng = np.random.default_rng(23)
    tx, sec = make_tables(rng, n=100)
    # vb registered FIRST, so its derived lane precedes va's in the plan;
    # dropping vb shifts va's lane position — the permute path
    hot = ScenarioPlane([vb, va], num_shards=4, **STORE_KW)
    _warm(hot, tx, sec, rounds=True)
    report = hot.evolve([va])
    assert report.exact, report.notes
    assert hot.scenarios == ["va"]
    with pytest.raises(KeyError, match="unknown scenario"):
        hot.query("vb", {})

    cold = ScenarioPlane([va], num_shards=4, **STORE_KW)
    _warm(cold, tx, sec, rounds=True)
    _assert_state_equal(hot, cold)
    req = dict(
        account=np.arange(K, dtype=np.int32),
        ts=np.full(K, 50_000, np.int32),
        amount=np.full(K, 15.0, np.float32),
        merchant=np.zeros(K, np.int32),
    )
    _assert_answers_equal(hot, cold, [va], req)


def test_unsupported_diffs_fail_loudly():
    views = multi_scenario_views()
    a = plan_layout(views, num_shards=4, raw_lanes=True, **STORE_KW)
    b = plan_layout(views, num_shards=8, raw_lanes=True, **STORE_KW)
    with pytest.raises(ValueError, match="shard count"):
        diff_layouts(a, b)
    kw = {
        k: v
        for k, v in STORE_KW.items()
        if k not in ("bucket_size", "num_buckets")
    }
    c = plan_layout(
        views, num_shards=4, raw_lanes=True, bucket_size=32,
        num_buckets=1024, **kw,
    )
    with pytest.raises(ValueError, match="bucket_size"):
        diff_layouts(a, c)
    plane = ScenarioPlane(views[:1], num_shards=4, **STORE_KW)
    with pytest.raises(ValueError, match="rebuild"):
        plane.evolve(views[:1], bucket_size=32)


def test_unsynthesizable_lane_needs_rebuild():
    """A new lane containing hash nodes cannot be synthesized bit-exactly
    from stored f32 columns — the migration must say so, not corrupt."""
    amt = Col("amount")
    w1h = range_window(3600, bucket=64)
    va = FeatureView(
        "va", features={"cnt": w_count(amt, w1h)}, database=MULTITABLE_DB
    )
    vb = FeatureView(
        "vb",
        features={"hashed": w_count(Hash(Col("merchant"), bits=8), w1h)},
        database=MULTITABLE_DB,
    )
    plane = ScenarioPlane([va], **STORE_KW)
    rng = np.random.default_rng(2)
    tx, sec = make_tables(rng, n=60)
    _warm(plane, tx, sec)
    req = dict(
        account=np.arange(K, dtype=np.int32),
        ts=np.full(K, 50_000, np.int32),
        amount=np.ones(K, np.float32),
        merchant=np.zeros(K, np.int32),
    )
    before = {
        f: np.asarray(v) for f, v in plane.query("va", req).items()
    }
    with pytest.raises(ValueError, match="rebuild"):
        plane.evolve([va, vb])
    # a refused migration is ATOMIC: the live plane keeps serving the old
    # layout — same answers, ingest still works, scenario list unchanged
    assert plane.scenarios == ["va"]
    after = plane.query("va", req)
    for f, v in before.items():
        np.testing.assert_array_equal(v, np.asarray(after[f]))
    plane.ingest(
        dict(
            account=np.array([1], np.int32),
            ts=np.array([60_000], np.int32),
            amount=np.array([5.0], np.float32),
            merchant=np.array([0], np.int32),
        )
    )


def test_horizon_exceeded_flags_inexact():
    """Shrinking capacity while adding a derived lane loses aged-out rows
    for the bucket-state rebuild: the migration must flag exact=False
    (never silently report an exact migration it cannot guarantee)."""
    amt = Col("amount")
    w1h = range_window(512, bucket=64)
    va = FeatureView(
        "va", features={"cnt": w_count(amt, w1h)}, database=MULTITABLE_DB
    )
    vb = FeatureView(
        "vb", features={"dbl": w_sum(amt * 2.0, w1h)}, database=MULTITABLE_DB
    )
    kw = dict(
        num_keys=4, capacity=32, num_buckets=64, bucket_size=64,
        secondary_num_keys={"merchants": NM},
    )
    plane = ScenarioPlane([va], **kw)
    rng = np.random.default_rng(31)
    n = 200  # 50 rows/key: cursor (50) > min(32, 16) -> rows aged out
    rows = dict(
        account=np.repeat(np.arange(4, dtype=np.int32), n // 4),
        ts=np.tile(np.arange(n // 4, dtype=np.int32) * 10, 4),
        amount=rng.gamma(2.0, 10.0, n).astype(np.float32),
        merchant=np.zeros(n, np.int32),
    )
    plane.ingest(rows)
    report = plane.evolve([va, vb], capacity=16)
    assert not report.exact
    assert any("aged out" in note for note in report.notes)


def test_ttl_retention_policy():
    """The layout's TTL knob caps every RANGE window's lookback — rows
    older than the TTL are expired from answers on both query paths."""
    amt = Col("amount")
    view = FeatureView(
        "ttl_v",
        MULTITABLE_DB.primary,
        {"s6h": w_sum(amt, range_window(21_600, bucket=64))},
        database=MULTITABLE_DB,
    )
    short = FeatureView(
        "short_v",
        MULTITABLE_DB.primary,
        {"s1h": w_sum(amt, range_window(3_600, bucket=64))},
        database=MULTITABLE_DB,
    )
    rng = np.random.default_rng(5)
    tx, _ = make_tables(rng, n=120)
    ttl_store = OnlineFeatureStore(
        view,
        layout=plan_layout([view], ttl=3_600, **STORE_KW),
    )
    ref_store = OnlineFeatureStore(short, **STORE_KW)
    srt = _bykey(tx, "account")
    ttl_store.ingest(srt)
    ref_store.ingest(srt)
    req = dict(
        account=np.arange(K, dtype=np.int32),
        ts=np.full(K, 40_000, np.int32),
        amount=np.ones(K, np.float32),
        merchant=np.zeros(K, np.int32),
    )
    for mode in ("naive", "preagg"):
        a = ttl_store.query(req, mode=mode)
        b = ref_store.query(req, mode=mode)
        np.testing.assert_array_equal(
            np.asarray(a["s6h"]), np.asarray(b["s1h"]), err_msg=mode
        )
    # the TTL-clamped window still fits the bucket plan even when the raw
    # window would not (the planner clamps the need the same way the
    # store does)
    plan_layout(
        [view], num_keys=K, num_buckets=64, bucket_size=64, ttl=3_600
    )
    with pytest.raises(ValueError, match="s6h"):
        plan_layout([view], num_keys=K, num_buckets=64, bucket_size=64)


def test_ttl_applies_to_rows_windows_too():
    """Retention is window-mode-independent: a ROWS window cannot count
    TTL-expired rows either."""
    from repro.core import rows_window

    amt = Col("amount")
    view = FeatureView(
        "rows_ttl",
        MULTITABLE_DB.primary,
        {"c10": w_count(amt, rows_window(10))},
        database=MULTITABLE_DB,
    )
    store = OnlineFeatureStore(
        view, layout=plan_layout([view], ttl=100, **STORE_KW)
    )
    # 5 old rows (expired at query time) + 2 recent rows for key 0
    store.ingest(
        dict(
            account=np.zeros(7, np.int32),
            ts=np.array([10, 11, 12, 13, 14, 950, 960], np.int32),
            amount=np.ones(7, np.float32),
            merchant=np.zeros(7, np.int32),
        )
    )
    req = dict(
        account=np.array([0], np.int32),
        ts=np.array([1_000], np.int32),
        amount=np.ones(1, np.float32),
        merchant=np.zeros(1, np.int32),
    )
    for mode in ("naive", "preagg"):
        out = store.query(req, mode=mode)
        # 2 recent stored rows + the request row; the 5 expired rows
        # must not count even though the ROWS window has room for 10
        assert float(out["c10"][0]) == 3.0, mode


# ---------------------------------------------------------------------------
# scenario-aware router edge cases (ISSUE 5 satellite)
# ---------------------------------------------------------------------------


def test_router_mixed_flush_with_empty_scenario():
    """A mixed flush where one registered scenario got NO rows must answer
    only the populated scenarios (no empty-batch device call, no key in
    the result)."""
    from repro.serve.router import ShardRouter
    from repro.serve.service import BatchScheduler, FeatureService

    views = multi_scenario_views()
    svc = FeatureService.build_multi(
        "p", views, sharded=True, num_shards=4, **STORE_KW
    )
    router = ShardRouter(svc, BatchScheduler(buckets=(1, 4, 16)), ingest=False)
    for i in range(6):
        router.submit(
            dict(account=i % K, ts=100 + i, amount=1.0, merchant=0),
            scenario=views[i % 2].name,  # only the first two scenarios
        )
    out = router.drain()
    assert set(out) == {views[0].name, views[1].name}
    assert views[2].name not in out
    hists = router.scenario_shard_histogram()
    assert int(hists[views[2].name].sum()) == 0
    assert sum(int(h.sum()) for h in hists.values()) == 6


def test_router_single_scenario_plane_via_build_multi():
    """build_multi([one view]) is a legal multi-scenario deployment of
    size 1: tags required, answers equal a dedicated store's."""
    from repro.serve.router import ShardRouter
    from repro.serve.service import FeatureService

    views = multi_scenario_views()
    rng = np.random.default_rng(19)
    tx, sec = make_tables(rng, n=80)
    svc = FeatureService.build_multi("solo", [views[0]], **STORE_KW)
    single = OnlineFeatureStore(views[0], **STORE_KW)
    for store in (svc.plane.store, single):
        for t in store._sec_names:
            kc = MULTITABLE_DB.table(t).key
            store.ingest_table(t, _bykey(sec[t], kc))
        store.ingest(_bykey(tx, "account"))
    router = ShardRouter(svc, ingest=False)
    with pytest.raises(ValueError, match="scenario"):
        router.submit(dict(account=1, ts=50_000, amount=1.0, merchant=0))
    reqs = [
        dict(account=int(rng.integers(0, K)), ts=50_000 + i,
             amount=float(rng.gamma(2.0, 10.0)), merchant=0)
        for i in range(5)
    ]
    for r in reqs:
        router.submit(r, scenario=views[0].name)
    out = router.drain()[views[0].name]
    batch = {c: np.asarray([r[c] for r in reqs]) for c in reqs[0]}
    ref = single.query(batch, mode="preagg")
    for f in views[0].features:
        np.testing.assert_array_equal(np.asarray(ref[f]), out[f], err_msg=f)


def test_router_histogram_after_hot_deploy():
    """scenario_shard_histogram() grows a row for a scenario hot-deployed
    AFTER the router was built, and counts its traffic."""
    from repro.serve.router import ShardRouter
    from repro.serve.service import FeatureService

    views = multi_scenario_views()
    svc = FeatureService.build_multi(
        "p", views[:2], sharded=True, num_shards=4, **STORE_KW
    )
    router = ShardRouter(svc, ingest=False)
    router.submit(
        dict(account=3, ts=100, amount=1.0, merchant=0),
        scenario=views[0].name,
    )
    router.drain()
    with pytest.raises(KeyError, match="unknown scenario"):
        router.submit(
            dict(account=3, ts=101, amount=1.0, merchant=0),
            scenario=views[2].name,
        )
    svc.hot_deploy(views[2])
    for i in range(4):
        router.submit(
            dict(account=i, ts=200 + i, amount=1.0, merchant=i % NM),
            scenario=views[2].name,
        )
    router.drain()
    hists = router.scenario_shard_histogram()
    assert views[2].name in hists
    assert int(hists[views[2].name].sum()) == 4
    assert int(sum(h.sum() for h in hists.values())) == 5
    np.testing.assert_array_equal(
        sum(hists.values()), router.shard_histogram()
    )
