"""Multi-scenario serving plane: exact equality + shared-ingest accounting.

The contract under test (ISSUE 4 acceptance): a ScenarioPlane serving N
views from ONE store (one mesh when sharded) answers every scenario
**bit-identically** to N independent single-view stores fed the same
stream — for ≥3 views sharing at least one WINDOW UNION table and one
LAST JOIN table, any shard count, any ingest interleaving — while storing
each shared secondary table once per shard, not once per view.  Runs
multi-device via conftest's ``--xla_force_host_platform_device_count=8``.
"""

import numpy as np
import pytest

from repro.core import (
    Col,
    FeatureView,
    OnlineFeatureStore,
    ScenarioPlane,
    merge_views,
    range_window,
    w_count,
    w_sum,
)
from repro.core.consistency import replay_rounds
from repro.data.synthetic import MULTITABLE_DB, RECO_SCHEMA, multitable_stream
from repro.scenarios import multi_scenario_views

K = 16  # accounts
NM = 8  # merchants
SEC_NK = {"merchants": NM}
STORE_KW = dict(
    num_keys=K, capacity=128, num_buckets=512, bucket_size=64,
    secondary_num_keys=SEC_NK,
)


def make_tables(rng, n=180, t_max=40_000):
    tabs = multitable_stream(
        rng, n, num_accounts=K, num_merchants=NM, t_max=t_max
    )
    return tabs["transactions"], {
        t: c for t, c in tabs.items() if t != "transactions"
    }


def _bykey(d, kc):
    o = np.lexsort((d["ts"], d[kc]))
    return {c: v[o] for c, v in d.items()}


def _preload_secondary(store, sec):
    """Push each referenced secondary table once (only tables the store's
    view references — a dedicated store rejects the rest)."""
    for t in store._sec_names:
        kc = MULTITABLE_DB.table(t).key
        store.ingest_table(t, _bykey(sec[t], kc))


def _independent_stores(views):
    return {v.name: OnlineFeatureStore(v, **STORE_KW) for v in views}


def test_trio_shares_tables():
    """The canonical trio really exercises the sharing the plane claims:
    a union table and join tables each referenced by ≥2 views."""
    views = multi_scenario_views()
    assert len(views) >= 3
    refs = {
        v.name: set(v.tables[1:]) for v in views
    }
    assert sum("wires" in r for r in refs.values()) >= 2      # shared union
    assert sum("accounts" in r for r in refs.values()) >= 2   # shared join
    assert sum("merchants" in r for r in refs.values()) >= 2


@pytest.mark.parametrize("num_shards", [1, 4, 8])
def test_plane_bit_identical_replay(num_shards):
    """Acceptance: sharded multi-scenario plane == N independent
    single-view (single-device) stores, bit-for-bit, replayed round by
    round with interleaved ingest."""
    rng = np.random.default_rng(200 + num_shards)
    tx, sec = make_tables(rng)
    views = multi_scenario_views()
    plane = ScenarioPlane(views, num_shards=num_shards, **STORE_KW)
    singles = _independent_stores(views)

    for store in [plane.store] + list(singles.values()):
        _preload_secondary(store, sec)

    key, ts = tx["account"], tx["ts"]
    for idx in replay_rounds(key, ts):
        batch = {c: v[idx] for c, v in tx.items()}
        for v in views:
            a = singles[v.name].query(batch, mode="preagg")
            b = plane.query(v.name, batch, mode="preagg")
            for f in v.features:
                np.testing.assert_array_equal(
                    np.asarray(a[f]),
                    np.asarray(b[f]),
                    err_msg=f"shards={num_shards} view={v.name} feature={f}",
                )
        srt = _bykey(batch, "account")
        plane.ingest(srt)  # once — serves all three scenarios
        for s in singles.values():
            s.ingest(srt)  # once per dedicated store


@pytest.mark.parametrize("chunks", [1, 4])
def test_plane_bit_identical_ingest_interleaving(chunks):
    """Same contract under different chunkings of the same stream, both
    query modes, after full ingest."""
    rng = np.random.default_rng(77)
    tx, sec = make_tables(rng, n=160)
    views = multi_scenario_views()
    plane = ScenarioPlane(views, num_shards=4, **STORE_KW)
    singles = _independent_stores(views)

    for store in [plane.store] + list(singles.values()):
        for piece in np.array_split(np.arange(len(sec["wires"]["ts"])), chunks):
            if len(piece) and "wires" in store._sec_names:
                store.ingest_table(
                    "wires",
                    _bykey(
                        {c: v[piece] for c, v in sec["wires"].items()},
                        "account",
                    ),
                )
        for t in ("accounts", "merchants"):
            if t in store._sec_names:
                store.ingest_table(
                    t, _bykey(sec[t], MULTITABLE_DB.table(t).key)
                )
        for piece in np.array_split(np.arange(len(tx["ts"])), chunks):
            if len(piece):
                store.ingest(
                    _bykey({c: v[piece] for c, v in tx.items()}, "account")
                )

    req = dict(
        account=rng.integers(0, K, 33).astype(np.int32),
        ts=np.full(33, 50_000, np.int32),
        amount=rng.gamma(2.0, 10.0, 33).astype(np.float32),
        merchant=rng.integers(0, NM, 33).astype(np.int32),
    )
    for mode in ("naive", "preagg"):
        for v in views:
            a = singles[v.name].query(req, mode=mode)
            b = plane.query(v.name, req, mode=mode)
            for f in v.features:
                np.testing.assert_array_equal(
                    np.asarray(a[f]),
                    np.asarray(b[f]),
                    err_msg=f"chunks={chunks} mode={mode} "
                    f"view={v.name} feature={f}",
                )


@pytest.mark.parametrize("num_shards", [None, 4])
def test_shared_tables_stored_once_per_shard_not_per_view(num_shards):
    """The consolidation claim, in row counts: the plane stores each
    shared secondary table once per shard (partitioned union tables:
    once total), while N dedicated stores hold one copy each."""
    rng = np.random.default_rng(5)
    tx, sec = make_tables(rng, n=120)
    views = multi_scenario_views()
    plane = ScenarioPlane(views, num_shards=num_shards, **STORE_KW)
    singles = _independent_stores(views)
    for store in [plane.store] + list(singles.values()):
        _preload_secondary(store, sec)
        store.ingest(_bykey(tx, "account"))

    S = num_shards or 1
    rows = {t: len(c["ts"]) for t, c in sec.items()}
    counts = plane.ingest_row_counts()
    # primary + partitioned union stream: every row lives on exactly one
    # shard — stored once, period
    assert counts["transactions"] == len(tx["ts"])
    assert counts["wires"] == rows["wires"]
    # replicated LAST JOIN targets: once per shard (dimension-table copy),
    # NOT once per referencing view
    assert counts["accounts"] == S * rows["accounts"]
    assert counts["merchants"] == S * rows["merchants"]

    # the plane's whole point: dedicated stores pay per *view* instead
    ded = {t: 0 for t in rows}
    for s in singles.values():
        for t, c in s.ingest_row_counts().items():
            if t in ded:
                ded[t] += c
    assert ded["wires"] == 2 * rows["wires"]        # 2 views reference it
    assert ded["accounts"] == 2 * rows["accounts"]
    assert ded["merchants"] == 2 * rows["merchants"]


def test_merge_views_validation():
    views = multi_scenario_views()
    # duplicate scenario names
    with pytest.raises(ValueError, match="duplicate"):
        merge_views([views[0], views[0]])
    # mismatched primary schema
    other = FeatureView(
        "other", RECO_SCHEMA,
        {"s": w_sum(Col("price"), range_window(100))},
    )
    with pytest.raises(ValueError, match="primary"):
        merge_views([views[0], other])
    # merged view namespaces features and unions tables
    merged = merge_views(views, name="p")
    assert f"{views[0].name}/outflow_1h" in merged.features
    assert set(merged.tables) == {
        "transactions", "wires", "accounts", "merchants"
    }


@pytest.mark.parametrize("num_shards", [None, 4])
def test_scenario_requests_need_only_own_columns(num_shards):
    """A scenario request carries only the columns ITS view references —
    other scenarios' join keys / window args must not leak into the
    requirement (regression: the merged store once validated its full
    join-col set against every scenario's requests)."""
    views = multi_scenario_views()
    plane = ScenarioPlane(views, num_shards=num_shards, **STORE_KW)
    req = dict(
        account=np.arange(8, dtype=np.int32),
        ts=np.full(8, 100, np.int32),
        amount=np.ones(8, np.float32),
    )  # no 'merchant' column: acct_risk never reads it
    out = plane.query("acct_risk", req)
    assert set(out) == set(views[0].features)
    single = OnlineFeatureStore(views[0], **STORE_KW)
    ref = single.query(req)
    for f in ref:
        np.testing.assert_array_equal(
            np.asarray(ref[f]), np.asarray(out[f])
        )
    # a scenario that DOES join on merchant still demands it, and the
    # error names that scenario's view (not the internal merged view)
    with pytest.raises(KeyError, match="merchant_watch"):
        plane.query("merchant_watch", req)


def test_program_requires_subview():
    """A program for a view whose aggregations are not in the shared lane
    plan must fail loudly, not answer garbage."""
    views = multi_scenario_views()
    plane = ScenarioPlane(views[:2], **STORE_KW)
    foreign = FeatureView(
        "foreign",
        MULTITABLE_DB.primary,
        {"c": w_count(Col("amount"), range_window(999))},
        database=MULTITABLE_DB,
    )
    with pytest.raises(ValueError, match="sub-view"):
        plane.store.compile_program(foreign)
    with pytest.raises(KeyError, match="unknown scenario"):
        plane.query("nope", {})


def test_multi_service_router_end_to_end():
    """FeatureService.build_multi + scenario-tagged ShardRouter: drained
    answers equal dedicated stores' (bit-for-bit), per-scenario stats and
    (scenario, shard) occupancy add up."""
    from repro.serve.router import ShardRouter
    from repro.serve.service import BatchScheduler, FeatureService

    rng = np.random.default_rng(13)
    tx, sec = make_tables(rng, n=140)
    views = multi_scenario_views()
    svc = FeatureService.build_multi(
        "plane_svc", views, sharded=True, num_shards=4, **STORE_KW
    )
    singles = _independent_stores(views)
    for store in [svc.plane.store] + list(singles.values()):
        _preload_secondary(store, sec)
        store.ingest(_bykey(tx, "account"))

    router = ShardRouter(
        svc, BatchScheduler(buckets=(1, 4, 16)), ingest=False
    )
    n_req, names = 24, [v.name for v in views]
    reqs = [
        dict(
            account=int(rng.integers(0, K)),
            ts=50_000 + i,
            amount=float(rng.gamma(2.0, 10.0)),
            merchant=int(rng.integers(0, NM)),
        )
        for i in range(n_req)
    ]
    tags = [names[i % len(names)] for i in range(n_req)]
    for row, tag in zip(reqs, tags):
        router.submit(row, scenario=tag)
    out = router.drain()

    for v in views:
        idx = [i for i, t in enumerate(tags) if t == v.name]
        batch = {
            c: np.asarray([reqs[i][c] for i in idx])
            for c in ("account", "ts", "amount", "merchant")
        }
        ref = singles[v.name].query(batch, mode="preagg")
        for f in v.features:
            np.testing.assert_array_equal(
                np.asarray(ref[f]), out[v.name][f],
                err_msg=f"view={v.name} feature={f}",
            )
        assert svc.scenario_stats[v.name].requests == len(idx)
    assert svc.stats.requests == n_req
    hists = router.scenario_shard_histogram()
    assert sum(int(h.sum()) for h in hists.values()) == n_req
    np.testing.assert_array_equal(
        sum(hists.values()), router.shard_histogram()
    )
    # single-scenario router rejects tags; multi rejects missing tags
    with pytest.raises(ValueError, match="scenario"):
        router.submit(reqs[0])
    with pytest.raises(KeyError, match="unknown scenario"):
        router.submit(reqs[0], scenario="nope")


def test_describe_and_catalog_fresh():
    """View.describe() names tables/SQL/deploys deterministically, and the
    committed docs/CATALOG.md matches the live definitions (the same
    regenerate-and-diff gate scripts/ci.sh runs)."""
    import pathlib

    from repro.catalog import CATALOG_PATH, build_catalog
    from repro.core import FeatureRegistry

    views = multi_scenario_views()
    reg = FeatureRegistry()
    reg.register(views[0])
    reg.deploy("svc_a", views[0].name)
    md = views[0].describe(reg)
    assert f"### `{views[0].name}`" in md
    assert "WINDOW UNION stream" in md and "LAST JOIN target" in md
    for f in views[0].features:
        assert f"`{f}`" in md
    assert "SELECT" in md and "svc_a" in md
    assert md == views[0].describe(reg)  # deterministic

    fresh = build_catalog()
    assert fresh == build_catalog()  # no wall-clock leaks
    path = pathlib.Path(CATALOG_PATH)
    assert path.exists(), "docs/CATALOG.md missing — run python -m repro.catalog"
    assert path.read_text() == fresh, (
        "docs/CATALOG.md is stale — run `python -m repro.catalog`"
    )


def test_multi_service_shared_ingest_path():
    """request(ingest=True) on the multi service ingests once into the
    shared store and every scenario sees the row."""
    from repro.serve.service import FeatureService

    views = multi_scenario_views()
    svc = FeatureService.build_multi("p", views, **STORE_KW)
    rng = np.random.default_rng(1)
    tx, sec = make_tables(rng, n=60)
    _preload_secondary(svc.plane.store, sec)
    row = dict(
        account=np.array([3], np.int32),
        ts=np.array([60_000], np.int32),
        amount=np.array([123.0], np.float32),
        merchant=np.array([1], np.int32),
    )
    before = svc.plane.ingest_row_counts()["transactions"]
    svc.request(row, ingest=True, scenario=views[0].name)
    assert svc.plane.ingest_row_counts()["transactions"] == before + 1
    # the ingested row is visible to ANOTHER scenario's window
    later = dict(row)
    later["ts"] = np.array([60_001], np.int32)
    out = svc.request(later, ingest=False, scenario="spend_profile")
    assert float(out["outflow_1h"][0]) >= 123.0
    # single-scenario service still rejects tags
    single = FeatureService.build(
        "one", views[0], registry=None, **STORE_KW
    )
    with pytest.raises(ValueError, match="single-scenario"):
        single.request(row, scenario="acct_risk")
