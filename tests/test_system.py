"""System-level integration tests: data import, serving stack, checkpoint
restart, elastic supervisor, wide-time-span ingest."""

import io

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt.manifest import CheckpointManager
from repro.core import (
    Col, FeatureRegistry, FeatureView, OnlineFeatureStore, range_window,
    w_count, w_sum,
)
from repro.core.storage import TableSchema
from repro.data import insert_rows, load_csv, load_table
from repro.data.synthetic import FRAUD_SCHEMA, fraud_stream, lm_stream
from repro.runtime.coordinator import (
    ElasticPlanner, MeshTemplate, TrainSupervisor,
)
from repro.serve.service import BatchScheduler, FeatureService

SCHEMA = TableSchema(name="t", key="k", ts="ts", numeric=("x",),
                     categorical=("c",))


def test_csv_import_round_trip():
    csv = io.StringIO("k,ts,x,c\n0,1,1.5,3\n1,2,2.5,4\n0,3,3.5,5\n")
    cols = load_csv(csv, SCHEMA)
    assert cols["k"].dtype == np.int32
    assert cols["x"].dtype == np.float32
    np.testing.assert_allclose(cols["x"], [1.5, 2.5, 3.5])
    more = insert_rows([{"k": 2, "ts": 4, "x": 9.0, "c": 1}], SCHEMA, into=cols)
    assert len(more["k"]) == 4


def test_load_table_dispatch_errors():
    with pytest.raises(NotImplementedError):
        load_table("x", SCHEMA, format="hive")
    with pytest.raises(ValueError):
        load_table("x", SCHEMA, format="bogus")


def test_lm_stream_shapes():
    it = lm_stream(np.random.default_rng(0), batch=2, seq_len=16, vocab=64)
    b = next(it)
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    assert int(b["tokens"].max()) < 64


def test_wide_span_ingest_matches_naive():
    """Backfills spanning more buckets than the ring are split internally;
    preagg query must still equal the naive ring-scan."""
    rng = np.random.default_rng(0)
    cols, _ = fraud_stream(rng, 1200, num_cards=16, t_max=300_000)  # wide span
    view = FeatureView(
        name="w", schema=FRAUD_SCHEMA,
        features={"s": w_sum(Col("amount"), range_window(3600, bucket=64)),
                  "c": w_count(Col("amount"), range_window(3600, bucket=64))},
    )
    store = OnlineFeatureStore(view, num_keys=16, capacity=256,
                               num_buckets=64, bucket_size=64)
    order = np.lexsort((cols["ts"], cols["card"]))
    store.ingest({c: v[order] for c, v in cols.items()})
    req = {c: v[-16:].copy() for c, v in cols.items()}
    req["ts"] = np.full(16, 300_001, np.int32)
    req["card"] = np.arange(16, dtype=np.int32)
    a = store.query(req, mode="naive")
    b = store.query(req, mode="preagg")
    for f in view.features:
        np.testing.assert_allclose(np.asarray(a[f]), np.asarray(b[f]),
                                   rtol=1e-4, atol=1e-2)


def test_batch_scheduler_buckets():
    s = BatchScheduler(buckets=(1, 4, 16))
    for i in range(6):
        s.submit({"k": np.int32(i), "ts": np.int32(i), "x": np.float32(i),
                  "c": np.int32(0)})
    b = s.next_batch()
    assert len(b["k"]) == 16 and b["__valid__"].sum() == 6  # padded to bucket
    assert s.next_batch() is None


def test_feature_service_registry_lineage():
    rng = np.random.default_rng(1)
    cols, _ = fraud_stream(rng, 400, num_cards=8, t_max=20_000)
    view = FeatureView(
        name="svc_view", schema=FRAUD_SCHEMA,
        features={"s1h": w_sum(Col("amount"), range_window(3600, bucket=64))},
    )
    reg = FeatureRegistry()
    reg.register(view)
    store = OnlineFeatureStore(view, num_keys=8, num_buckets=64,
                               bucket_size=64)
    order = np.lexsort((cols["ts"], cols["card"]))
    store.ingest({c: v[order] for c, v in cols.items()})
    svc = FeatureService("svc", view, store, reg)
    out = svc.request({c: v[:4] for c, v in cols.items()}, ingest=False)
    assert np.asarray(out["s1h"]).shape == (4,)
    assert reg.service("svc")["version"] == 1
    lin = reg.lineage("svc_view", "s1h")
    assert lin["columns"] == ["amount"] and "OVER" in lin["sql"]


def test_checkpoint_restart_equivalence(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": jnp.zeros((4,))}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x + step, tree), blocking=True)
    assert mgr.latest_step() == 3
    restored = mgr.restore(3, like=tree)
    np.testing.assert_allclose(restored["a"], np.arange(6.0).reshape(2, 3) + 3)
    # keep=2 garbage-collected step 1
    assert not (tmp_path / "step_000000001").exists()


def test_supervisor_failure_restart(tmp_path):
    """Host failure mid-training -> restore from checkpoint -> rescale."""
    mgr = CheckpointManager(str(tmp_path))
    planner = ElasticPlanner(MeshTemplate(data=8, model=4))
    fail_at = {"step": 13, "done": False}

    def step_fn(state, step, plan):
        if step == fail_at["step"] and not fail_at["done"]:
            fail_at["done"] = True
            raise TrainSupervisor.HostFailure("host3")
        return {"w": state["w"] + 1.0}

    sup = TrainSupervisor(planner, mgr, lambda: {"w": jnp.zeros(())},
                          step_fn, ckpt_every=5)
    state, info = sup.run(target_steps=20, total_hosts=8)
    assert info["restarts"] == 1
    assert info["final_step"] == 20
    assert float(state["w"]) == 20.0  # resumed from step-10 ckpt, re-ran 10..20
    kinds = [e["kind"] for e in info["events"]]
    assert "failure" in kinds and "rescale" in kinds
    assert info["plan"].new_data == 4  # shrunk to the power-of-two <= 7 hosts
