"""Telemetry plane: clocks, spans, metric schema, and the instrumented
request path.

Everything deterministic runs under :class:`FakeClock` — one counter
drives the monotonic clock (scheduler, spans) AND the wall clock
(registry deploy stamps), which is the unified-clock contract of
satellite #2.
"""

import json

import numpy as np
import pytest

from repro.core import (
    Col,
    FeatureView,
    range_window,
    rows_window,
    w_count,
    w_first,
    w_last,
    w_mean,
    w_sum,
    w_topn_freq,
)
from repro.data.synthetic import FRAUD_SCHEMA
from repro.obs import (
    FakeClock,
    MetricCardinalityError,
    Telemetry,
    use_telemetry,
)
from repro.serve.router import ShardRouter
from repro.serve.service import BatchScheduler, FeatureService, ServiceStats

AMT = Col("amount")


def _row(rng, ts, num_cards=32):
    return dict(
        card=int(rng.integers(0, num_cards)),
        ts=int(ts),
        amount=float(rng.gamma(1.5, 60.0)),
        mcc=int(rng.integers(0, 32)),
        device=int(rng.integers(0, 8)),
        geo=int(rng.integers(0, 16)),
    )


# -- clock + spans -----------------------------------------------------------


def test_fake_clock_drives_monotonic_and_wall_together():
    clk = FakeClock(start_s=10.0, epoch_s=1_000.0)
    assert clk.now() == 10.0
    assert clk.now_us() == 10_000_000
    assert clk.time() == 1_010.0
    clk.tick(2_500)  # 2.5 ms in µs
    assert clk.now() == pytest.approx(10.0025)
    assert clk.time() == pytest.approx(1_010.0025)
    clk.advance(1.0)
    assert clk.now_us() == 11_002_500
    with pytest.raises(ValueError):
        clk.advance(-1.0)


def test_span_tree_deterministic_under_fake_clock():
    tel = Telemetry(clock=FakeClock())
    tr = tel.tracer
    with tr.span("request", service="svc") as root:
        tel.clock.advance(0.010)
        with tr.span("query.route"):
            tel.clock.advance(0.003)
        with tr.span("query.compute", kind="device") as sp:
            tel.clock.advance(0.005)
            sp.fence(np.float32(1.0))
        tel.clock.advance(0.002)
    assert root.duration_s == pytest.approx(0.020)
    (route,) = root.find("query.route")
    (compute,) = root.find("query.compute")
    assert route.duration_s == pytest.approx(0.003)
    assert compute.duration_s == pytest.approx(0.005)
    assert compute.fenced and compute.kind == "device"
    assert not route.fenced
    # completed spans land in the span_seconds histogram
    h = tel.metrics.histogram(
        "span_seconds", "span durations", "s", labels=("name", "kind")
    )
    assert h.count(name="request", kind="host") == 1
    assert h.sum(name="query.compute", kind="device") == pytest.approx(0.005)
    # and in the snapshot's recent-span list, as a nested dict
    snap = tel.snapshot()
    assert snap["spans"][-1]["name"] == "request"
    names = [c["name"] for c in snap["spans"][-1]["children"]]
    assert names == ["query.route", "query.compute"]


def test_disabled_telemetry_records_nothing_but_still_fences():
    tel = Telemetry(enabled=False, clock=FakeClock())
    with tel.tracer.span("request") as sp:
        out = sp.fence(np.arange(3))
    assert np.array_equal(out, np.arange(3))
    assert tel.snapshot()["metrics"] == {}
    assert tel.snapshot()["spans"] == []


def test_unified_clock_spans_scheduler_and_registry():
    """One FakeClock advances spans, scheduler waits, and deploy stamps."""
    from repro.core.view import FeatureRegistry

    clk = FakeClock(start_s=5.0, epoch_s=2_000.0)
    tel = Telemetry(clock=clk)
    with use_telemetry(tel):
        reg = FeatureRegistry()  # no clock arg: reads the plane clock
        view = FeatureView(
            "clk", FRAUD_SCHEMA, {"s": w_sum(AMT, range_window(600))}
        )
        reg.register(view)
        rec = reg.deploy("svc", "clk")
        assert rec["deployed_at"] == 2_005.0  # epoch + elapsed monotonic
        sched = BatchScheduler(max_batch=4, max_wait_us=10_000)
        sched.submit({"card": 1, "ts": 1})  # arrival at clk.now_us()
        clk.tick(3_000)
        batch = sched.next_batch(flush=True)
        assert list(batch["__wait_us__"]) == [3_000]


# -- metric registry schema --------------------------------------------------


def test_registry_rejects_schema_drift_and_label_mismatch():
    tel = Telemetry()
    m = tel.metrics
    c = m.counter("reqs", "requests", "1", labels=("svc",))
    c.inc(svc="a")
    assert m.counter("reqs", "requests", "1", labels=("svc",)) is c
    with pytest.raises(ValueError):
        m.gauge("reqs", "requests", "1", labels=("svc",))  # type flip
    with pytest.raises(ValueError):
        m.counter("reqs", "requests", "s", labels=("svc",))  # unit flip
    with pytest.raises(ValueError):
        m.counter("reqs", "requests", "1", labels=("svc", "x"))  # labels
    with pytest.raises(ValueError):
        c.inc(other="a")  # undeclared label name


def test_metric_cardinality_cap():
    tel = Telemetry()
    c = tel.metrics.counter(
        "cardinality", "x", "1", labels=("k",), max_series=8
    )
    for i in range(8):
        c.inc(k=str(i))
    with pytest.raises(MetricCardinalityError):
        c.inc(k="overflow")
    assert c.series_count() == 8


def test_snapshot_schema_stable_and_json_round_trips():
    tel = Telemetry(clock=FakeClock())
    tel.metrics.counter("a_total", "a", "1", labels=("l",)).inc(2, l="x")
    tel.metrics.gauge("g", "g", "1", labels=()).set(0.5)
    h = tel.metrics.histogram("h_seconds", "h", "s", labels=())
    h.observe(0.010, n=3)
    snap = json.loads(tel.snapshot_json())
    assert set(snap) == {
        "schema_version", "enabled", "time_s", "metrics", "spans"
    }
    assert snap["schema_version"] == Telemetry.SCHEMA_VERSION
    for name, m in snap["metrics"].items():
        assert set(m) == {"type", "unit", "help", "labels", "series"}, name
    hs = snap["metrics"]["h_seconds"]["series"][0]
    assert hs["count"] == 3 and hs["sum"] == pytest.approx(0.030)
    for k in ("p50", "p95", "p99", "max", "buckets"):
        assert k in hs
    prom = tel.to_prometheus()
    assert 'a_total{l="x"} 2' in prom
    assert "# TYPE h_seconds histogram" in prom
    assert 'le="+Inf"' in prom


def test_golden_catalog_gate_runs():
    from repro.obs.check import schema_check

    schema_check(verbose=False)


# -- request-path semantics --------------------------------------------------


def test_request_percentiles_weight_by_request_not_batch():
    """Satellite 1: one 99-row batch + one 1-row straggler.  Batch
    percentiles say p50 = midpoint of two batches; request percentiles
    must say p50 = the big batch's latency."""
    st = ServiceStats()
    st.observe(0.010, 99)  # fast big batch
    st.observe(0.500, 1)  # slow straggler
    # deprecated batch semantics: midpoint of {10ms, 500ms}
    assert st.p50_ms == pytest.approx(255.0)
    st.observe_requests([0.010] * 99 + [0.500])
    assert st.request_p50_ms == pytest.approx(10.0)
    assert st.request_p99_ms >= 10.0
    assert st.requests == 100


def test_request_latency_includes_queue_wait():
    clk = FakeClock()
    tel = Telemetry(clock=clk)
    view = FeatureView(
        "lat", FRAUD_SCHEMA, {"s": w_sum(AMT, range_window(600, bucket=64))}
    )
    with use_telemetry(tel):
        svc = FeatureService.build("lat", view, num_keys=32, capacity=64)
        sched = BatchScheduler(max_batch=8, max_wait_us=50_000)
        rng = np.random.default_rng(0)
        sched.submit(_row(rng, 1_000))
        clk.tick(40_000)  # 40 ms in queue
        batch = sched.next_batch(flush=True)
        svc.request(batch)
    # FakeClock doesn't advance during request -> latency == queue wait
    assert svc.stats.request_p50_ms == pytest.approx(40.0)
    h = tel.metrics.histogram(
        "queue_wait_seconds", "", "s", labels=("service",)
    )
    assert h.mean(service="lat") == pytest.approx(0.040)


def test_preagg_hit_and_fallback_counters():
    """A range-window SUM is answered from the bucket pre-agg store; a
    rows-window COUNT must fall back to the raw ring fold."""
    tel = Telemetry()
    view = FeatureView(
        "pa", FRAUD_SCHEMA,
        {
            "s": w_sum(AMT, range_window(600, bucket=64)),  # hit
            "c5": w_count(AMT, rows_window(5)),  # fallback
        },
    )
    with use_telemetry(tel):
        svc = FeatureService.build("pa", view, num_keys=32, capacity=64)
        svc.request(
            {
                "card": np.arange(4, dtype=np.int32),
                "ts": np.full(4, 10_000),
                "amount": np.ones(4, np.float32),
                "mcc": np.zeros(4, np.int64),
                "device": np.zeros(4, np.int64),
                "geo": np.zeros(4, np.int64),
            }
        )
    hits = tel.metrics.counter("preagg_hits_total", "", "1", labels=("agg",))
    falls = tel.metrics.counter(
        "preagg_fallback_total", "", "1", labels=("agg",)
    )
    assert hits.value(agg="sum") == 1
    assert falls.value(agg="count") == 1
    assert hits.value(agg="count") == 0


def test_first_topn_preagg_hit_not_fallback():
    """FIRST/LAST/TOPN over range windows compose from the merge-order
    bucket families — the pre-agg path answers them with ZERO fallbacks
    (the counter this used to light up)."""
    tel = Telemetry()
    view = FeatureView(
        "mo", FRAUD_SCHEMA,
        {
            "f": w_first(AMT, range_window(600, bucket=64)),
            "l": w_last(AMT, range_window(600, bucket=64)),
            "t0": w_topn_freq(Col("mcc"), range_window(600, bucket=64), n=0),
        },
    )
    with use_telemetry(tel):
        svc = FeatureService.build("mo", view, num_keys=32, capacity=64)
        svc.request(
            {
                "card": np.arange(4, dtype=np.int32),
                "ts": np.full(4, 10_000),
                "amount": np.ones(4, np.float32),
                "mcc": np.zeros(4, np.int64),
                "device": np.zeros(4, np.int64),
                "geo": np.zeros(4, np.int64),
            }
        )
    hits = tel.metrics.counter("preagg_hits_total", "", "1", labels=("agg",))
    falls = tel.metrics.counter(
        "preagg_fallback_total", "", "1", labels=("agg",)
    )
    for agg in ("first", "last", "topn_freq"):
        assert hits.value(agg=agg) == 1, agg
        assert falls.value(agg=agg) == 0, agg
    # every ingest dispatch is counted by resolved implementation; the
    # merge-order families route ingest down the split XLA path on any
    # backend (the fused kernel covers only the six core arrays)
    kd = tel.metrics.counter(
        "kernel_dispatch_total", "", "1", labels=("kernel", "impl")
    )
    assert kd.value(kernel="fused_ingest", impl="xla") >= 1


def test_compile_time_captured_once_per_trace():
    tel = Telemetry()
    view = FeatureView(
        "ct", FRAUD_SCHEMA, {"m": w_mean(AMT, range_window(600, bucket=64))}
    )
    with use_telemetry(tel):
        svc = FeatureService.build("ct", view, num_keys=32, capacity=64)
        b = {
            "card": np.arange(4, dtype=np.int32),
            "ts": np.full(4, 10_000),
            "amount": np.ones(4, np.float32),
            "mcc": np.zeros(4, np.int64),
            "device": np.zeros(4, np.int64),
            "geo": np.zeros(4, np.int64),
        }
        svc.request(b, ingest=False)
        svc.request(b, ingest=False)  # warm: same shape, no new trace
    h = tel.metrics.histogram(
        "query_compile_seconds", "", "s", labels=("program", "mode")
    )
    assert h.count(program="ct", mode="preagg") == 1
    assert h.sum(program="ct", mode="preagg") > 0


def test_overhead_within_bound():
    from repro.obs.check import overhead_check

    # generous bound at test size: the gate's real tuning lives in CI
    overhead_check(bound_ratio=4.0, floor_s=10e-3, iters=15, verbose=False)


# -- router padding / skew ---------------------------------------------------


def test_skew_histograms_exclude_padding():
    """Satellite 6: non-bucket-aligned submit counts pad every popped
    batch; the skew histograms must still sum to exactly the real
    request count, with padding reported by the telemetry instead."""
    tel = Telemetry()
    view = FeatureView(
        "skew", FRAUD_SCHEMA, {"s": w_sum(AMT, range_window(600, bucket=64))}
    )
    n_req = 13  # 13 -> buckets pad to 16 (and shard buckets pad more)
    with use_telemetry(tel):
        svc = FeatureService.build(
            "skew", view, num_keys=32, sharded=True, num_shards=4,
            capacity=64,
        )
        router = ShardRouter(
            svc, BatchScheduler(buckets=(1, 4, 16), max_batch=16)
        )
        rng = np.random.default_rng(1)
        now = 0
        for i in range(n_req):
            router.submit(_row(rng, 1_000 + i), now_us=now)
            now += 100
        router.drain(now_us=now)
    hist = router.shard_histogram()
    assert hist.sum() == n_req
    pad = tel.metrics.counter("padding_rows_total", "", "1", labels=("layer",))
    assert pad.value(layer="scheduler") == 3  # 13 padded to 16
    assert pad.value(layer="shard") > 0
    disp = tel.metrics.counter(
        "shard_dispatch_rows_total", "", "1", labels=("scenario", "shard")
    )
    assert disp.total() == n_req


def test_multi_scenario_skew_histograms_exclude_padding():
    tel = Telemetry()
    v1 = FeatureView(
        "fraud", FRAUD_SCHEMA, {"s": w_sum(AMT, range_window(600, bucket=64))}
    )
    v2 = FeatureView("risk", FRAUD_SCHEMA, {"c": w_count(AMT, rows_window(5))})
    n_req = 11
    with use_telemetry(tel):
        svc = FeatureService.build_multi(
            "ms", [v1, v2], num_keys=32, sharded=True, num_shards=4,
            capacity=64,
        )
        router = ShardRouter(
            svc, BatchScheduler(buckets=(1, 4, 16), max_batch=16)
        )
        rng = np.random.default_rng(2)
        for i in range(n_req):
            router.submit(
                _row(rng, 1_000 + i), now_us=i * 100,
                scenario="fraud" if i % 2 else "risk",
            )
        router.drain(now_us=n_req * 100)
    per = router.scenario_shard_histogram()
    assert sum(h.sum() for h in per.values()) == n_req
    assert router.shard_histogram().sum() == n_req
    assert per["fraud"].sum() == n_req // 2
    assert per["risk"].sum() == n_req - n_req // 2
