"""Fused ingest kernel: bit-exactness vs the split XLA sequence.

The contract (ISSUE 10): the one-pass Pallas kernel (ring scatter +
bucket pre-agg merge) must match the two-dispatch ``ring_ingest`` +
``bucket_ingest`` oracle bit-for-bit — at the raw kernel layer across
sequential batches, and end-to-end through ``OnlineFeatureStore`` /
``ShardedOnlineStore`` at shard counts {1, 4, 8}.  Runs in interpret
mode on CPU (the same kernel lowers via Mosaic on TPU).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    Col,
    FeatureView,
    OnlineFeatureStore,
    ShardedOnlineStore,
    TableSchema,
    range_window,
    rows_window,
    w_count,
    w_distinct_approx,
    w_std,
    w_sum,
)
from repro.core import preagg as pg
from repro.core import storage as st
from repro.core.aggregates import row_bitmap
from repro.kernels.ingest.ingest import _row_bitmap
from repro.kernels.ingest.ops import fused_ingest

K, C, F, NB, BS = 7, 16, 3, 8, 50

STATE_NAMES = ("ring_ts", "ring_vals", "cursor", "bstats", "bbitmap", "bbucket")


def _init_state():
    ring = st.ring_init(K, C, F)
    bagg = pg.bucket_init(K, NB, F, BS)
    return (ring.ts, ring.vals, ring.cursor,
            bagg.stats, bagg.bitmap, bagg.bucket)


def _batch(rng, n, t_lo, t_hi, pad_to=None):
    key = np.sort(rng.integers(0, K, n)).astype(np.int32)
    ts = rng.integers(t_lo, t_hi, n).astype(np.int32)
    order = np.lexsort((ts, key))
    key, ts = key[order], ts[order]
    vals = rng.normal(size=(n, F)).astype(np.float32)
    if pad_to and pad_to > n:
        p = pad_to - n
        key = np.concatenate([key, np.full(p, K, np.int32)])
        ts = np.concatenate([ts, np.broadcast_to(ts[-1], (p,))])
        vals = np.concatenate([vals, np.zeros((p, F), np.float32)])
    return jnp.asarray(key), jnp.asarray(ts), jnp.asarray(vals)


def test_fused_ingest_kernel_bit_exact_sequential_batches():
    """Raw kernel layer: five sequential padded batches, every state
    array equal bit-for-bit after each one (incl. sumsq — the lane where
    fma contraction would show as a 1-ulp drift)."""
    rng = np.random.default_rng(0)
    state_x, state_p = _init_state(), _init_state()
    plan = [(20, 0, 300, 32), (15, 250, 380, 16), (9, 350, 400, 16),
            (30, 380, 390, 32), (25, 390, 700, 32)]
    for step, (n, lo, hi, pad) in enumerate(plan):
        k, t, v = _batch(rng, n, lo, hi, pad)
        state_x = fused_ingest(*state_x, k, t, v, bucket_size=BS, impl="xla")
        state_p = fused_ingest(*state_p, k, t, v, bucket_size=BS,
                               impl="pallas", interpret=True)
        for nm, a, b in zip(STATE_NAMES, state_x, state_p):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"step {step} {nm}"
            )


def test_fused_ingest_all_pad_batch_is_noop():
    """A batch of only sentinel pads must leave every array untouched."""
    rng = np.random.default_rng(1)
    state = _init_state()
    k, t, v = _batch(rng, 12, 0, 200, pad_to=16)
    state = fused_ingest(*state, k, t, v, bucket_size=BS,
                         impl="pallas", interpret=True)
    pk = jnp.full((16,), K, jnp.int32)
    pt = jnp.full((16,), 500, jnp.int32)
    pv = jnp.zeros((16, F), jnp.float32)
    after = fused_ingest(*state, pk, pt, pv, bucket_size=BS,
                         impl="pallas", interpret=True)
    for nm, a, b in zip(STATE_NAMES, state, after):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=nm
        )


def test_kernel_row_bitmap_matches_library():
    """The kernel restates aggregates.row_bitmap with python-literal
    constants (Pallas kernels cannot capture device constants) — pin the
    bit-exact equality so the hash chains can never drift apart."""
    rng = np.random.default_rng(2)
    v = jnp.asarray(
        np.concatenate([
            rng.normal(size=500).astype(np.float32),
            np.array([0.0, -0.0, 1.0, -1.0, 3.0e38, -3.0e38], np.float32),
        ])
    )
    np.testing.assert_array_equal(
        np.asarray(_row_bitmap(v)), np.asarray(row_bitmap(v))
    )


SCHEMA = TableSchema(name="tx", key="uid", ts="ts", numeric=("amount",),
                     categorical=("mcc",))


def _view():
    return FeatureView("t", SCHEMA, {
        "s": w_sum(Col("amount"), range_window(300, bucket=32)),
        "sd": w_std(Col("amount"), range_window(300, bucket=32)),
        "c": w_count(Col("amount"), rows_window(10)),
        "d": w_distinct_approx(Col("amount"), range_window(300, bucket=32)),
    })


def _stream(rng, n, lo, hi, k=6):
    key = rng.integers(0, k, n).astype(np.int32)
    ts = rng.integers(lo, hi, n).astype(np.int32)
    o = np.lexsort((ts, key))
    return dict(
        uid=key[o], ts=ts[o],
        amount=rng.gamma(2.0, 40.0, n).astype(np.float32),
        mcc=rng.integers(0, 30, n).astype(np.int32),
    )


STORE_KW = dict(num_keys=6, capacity=64, num_buckets=16, bucket_size=32)


@pytest.mark.parametrize("num_shards", [1, 4, 8])
def test_store_fused_vs_split_bit_exact(num_shards):
    """End-to-end: a store on the fused Pallas path equals the split XLA
    path bit-for-bit — state arrays and query answers — at every shard
    count, through routing, padding and epoch splitting."""
    rng = np.random.default_rng(40 + num_shards)
    if num_shards == 1:
        sx = OnlineFeatureStore(_view(), **STORE_KW)
        sp = OnlineFeatureStore(_view(), **STORE_KW)
    else:
        sx = ShardedOnlineStore(_view(), num_shards=num_shards, **STORE_KW)
        sp = ShardedOnlineStore(_view(), num_shards=num_shards, **STORE_KW)
    sp.ingest_impl = "pallas"
    sp.ingest_interpret = True
    sp._build_fns()
    for lo, hi, n in [(0, 300, 40), (250, 500, 25), (480, 900, 50)]:
        b = _stream(rng, n, lo, hi)
        sx.ingest(dict(b))
        sp.ingest(dict(b))
    np.testing.assert_array_equal(
        np.asarray(sx.state.ring.ts), np.asarray(sp.state.ring.ts))
    np.testing.assert_array_equal(
        np.asarray(sx.state.ring.vals), np.asarray(sp.state.ring.vals))
    np.testing.assert_array_equal(
        np.asarray(sx.state.ring.cursor), np.asarray(sp.state.ring.cursor))
    np.testing.assert_array_equal(
        np.asarray(sx.state.bagg.stats), np.asarray(sp.state.bagg.stats))
    np.testing.assert_array_equal(
        np.asarray(sx.state.bagg.bitmap), np.asarray(sp.state.bagg.bitmap))
    np.testing.assert_array_equal(
        np.asarray(sx.state.bagg.bucket), np.asarray(sp.state.bagg.bucket))
    q = _stream(rng, 8, 900, 950)
    for mode in ("naive", "preagg"):
        rx = sx.query(dict(q), mode=mode)
        rp = sp.query(dict(q), mode=mode)
        for f in rx:
            np.testing.assert_array_equal(
                np.asarray(rx[f]), np.asarray(rp[f]),
                err_msg=f"S={num_shards} {mode}:{f}",
            )
