"""Force a multi-device CPU platform for the whole suite.

The sharded serving plane (repro.core.shard) partitions online state over
a ('shard',) device mesh; its tests must see several devices to exercise
real NamedSharding layouts.  conftest imports before any test module, so
this is the one place early enough to set the flag (a user-supplied
XLA_FLAGS with an explicit device count is respected).
"""

from repro.hostdevices import force_host_devices

force_host_devices(8)
