"""Paper §1/§3.3: feature signatures for trillion-dimensional spaces.

The trillion-dim space never materializes: k independent 64-bit mix
hashes index a 2^bits-row embedding table.  We measure

* signature computation throughput (ids/s) for 2- and 3-column crosses,
* hash-embedding lookup throughput (the gather the Pallas kernel fuses),
* empirical collision rate vs table bits (the accuracy/memory dial).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import emit, timeit
from repro.core.signature import multi_hash_ids, signature_ids
from repro.kernels.signature.ops import signature_embed

N = 1 << 14  # full size; smoke shrinks in run()


def run() -> None:
    global N
    N = common.scaled(1 << 14, 1 << 11)
    rng = np.random.default_rng(6)
    cols2 = [rng.integers(0, 1 << 20, N).astype(np.int32) for _ in range(2)]
    cols3 = [rng.integers(0, 1 << 20, N).astype(np.int32) for _ in range(3)]

    for name, cs in [("cross2", cols2), ("cross3", cols3)]:
        fn = lambda cs=cs: signature_ids([jnp.asarray(c) for c in cs], bits=24)
        t = timeit(fn, iters=5)
        emit("signature", f"{name}_ids_per_s", N / t["median_s"], "ids/s")

    # collision rate vs bits: distinct inputs mapping to same signature
    uniq_in = len(np.unique(np.stack(cols2, 1), axis=0))
    for bits in (16, 20, 24):
        sig = np.asarray(signature_ids([jnp.asarray(c) for c in cols2], bits=bits))
        coll = 1.0 - len(np.unique(sig)) / uniq_in
        emit("signature", f"collision_rate_bits{bits}", coll, "frac",
             f"{uniq_in} distinct crosses")

    # hash-embedding lookup (XLA ref path timing; Pallas correctness)
    V, D, K = 1 << 16, 128, 2
    table = jnp.asarray(rng.normal(0, 0.02, (V, D)), jnp.float32)
    sig = jnp.asarray(rng.integers(0, 1 << 31, 4096), jnp.int32)
    w = jnp.asarray([1.0, 0.5], jnp.float32)
    t = timeit(lambda: signature_embed(table, sig, w, num_hashes=K, impl="xla"),
               iters=5)
    emit("signature", "embed_lookups_per_s", 4096 / t["median_s"], "rows/s",
         f"V={V} D={D} k={K}")
    ref = signature_embed(table, sig, w, num_hashes=K, impl="xla")
    pal = signature_embed(table, sig[:256], w, num_hashes=K, impl="pallas",
                          interpret=True)
    err = float(jnp.max(jnp.abs(pal - ref[:256])))
    emit("signature", "pallas_vs_ref_max_abs_err", err, "abs")
    assert err < 1e-4, err


if __name__ == "__main__":
    run()
