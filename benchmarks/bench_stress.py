"""Generated-plane scale: deploy time, aggregate QPS, lane-pack stats.

The paper's "100+ scenarios on one platform" claim, measured: deploy
N∈{16, 64, 128} generated views (repro.stress.generate) onto one 8-shard
``ScenarioPlane``, then drive mixed-scenario traffic through the fused
device-routing path.  Emitted per N:

* ``deploy_s`` — build_multi wall time (layout planning + per-view
  program setup; program *compilation* is lazy, so this is the planner's
  scaling story);
* ``lanes_primary`` / ``lanes_shared`` — lane-pack stats: how many
  physical lanes the plan packs, and how many window-agg lanes CSE
  deduplicated across views (the shared-ingest accounting the generator
  deliberately stresses);
* ``mixed_qps`` — aggregate requests/s through ``query_mixed`` batches
  tagged round-robin across all N scenarios;
* telemetry snapshot counts (requests served, route rows) so the
  instrumentation layer is exercised at high scenario counts.

Smoke mode runs N=16 only (CI keeps the script from rotting); the full
ladder is the on-demand scaling curve.
"""

from __future__ import annotations

import sys

if __name__ == "__main__":
    from repro.hostdevices import force_host_devices

    force_host_devices(8)

import time

import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro.core import ScenarioPlane
from repro.core.expr import collect_window_aggs
from repro.data.synthetic import STRESS_DB, stress_stream
from repro.obs import get_telemetry
from repro.stress.generate import (
    NUM_ENTITIES,
    NUM_ITEMS,
    T_MAX,
    filter_table_knobs,
    gen_store_kwargs,
    gen_views,
    stress_rng,
)

SHARDS = 8
ROWS = 900
BATCH = 64


def _one_scale(n: int) -> None:
    views = gen_views(0, n)
    kwargs = filter_table_knobs(gen_store_kwargs(0, n), views)
    t0 = time.perf_counter()
    plane = ScenarioPlane(
        views, num_keys=NUM_ENTITIES, num_shards=SHARDS,
        name=f"stress{n}", **kwargs,
    )
    deploy_s = time.perf_counter() - t0
    emit("stress", f"deploy_n{n}_s", deploy_s, "s",
         note=f"{SHARDS} shards")

    # lane-pack stats: physical lanes vs CSE-deduplicated window aggs
    lay = plane.store.layout
    exprs = [e for v in views for e in v.features.values()]
    distinct = len(collect_window_aggs(exprs))
    per_view = sum(
        len(collect_window_aggs(list(v.features.values()))) for v in views
    )
    emit("stress", f"lanes_primary_n{n}", len(lay.primary.lanes), "lanes")
    emit("stress", f"lanes_shared_n{n}", per_view - distinct, "lanes",
         note=f"{per_view} per-view waggs -> {distinct} packed")

    tabs = stress_stream(
        stress_rng(0, n, "default", "data"), ROWS,
        num_entities=NUM_ENTITIES, num_items=NUM_ITEMS, t_max=T_MAX,
    )
    for t in plane.store._sec_names:
        sch = STRESS_DB.table(t)
        cols = tabs[t]
        order = np.lexsort((cols[sch.ts], cols[sch.key]))
        plane.ingest_table(t, {c: v[order] for c, v in cols.items()})
    ev = tabs["events"]
    order = np.lexsort((ev["ts"], ev["entity"]))
    plane.ingest({c: v[order] for c, v in ev.items()})

    scens = plane.scenarios
    batches = common.scaled(8, 2)
    rng = stress_rng(0, n, "default", "bench-traffic")

    def probe(i: int):
        idx = np.arange((i * BATCH) % (ROWS - BATCH),
                        (i * BATCH) % (ROWS - BATCH) + BATCH)
        cols = {c: v[idx] for c, v in ev.items()}
        tags = np.array(
            [scens[int(t)] for t in rng.integers(len(scens), size=BATCH)]
        )
        return cols, tags

    # compile the fused shape, then time the steady state
    plane.query_mixed(*probe(0))
    t0 = time.perf_counter()
    for i in range(batches):
        plane.query_mixed(*probe(i + 1))
    dt = time.perf_counter() - t0
    emit("stress", f"mixed_qps_n{n}", batches * BATCH / dt, "req/s",
         note=f"{batches}x{BATCH} rows, {len(scens)} scenarios")

    snap = get_telemetry().metrics.snapshot()
    emit("stress", f"metrics_n{n}", len(snap), "series",
         note="telemetry registry size at this scenario count")


def run() -> None:
    for n in ([16] if common.SMOKE else [16, 64, 128]):
        _one_scale(n)


if __name__ == "__main__":
    common.header()
    run()
    print("bench_stress done", file=sys.stderr)
