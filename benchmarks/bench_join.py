"""Multi-table feature plane: LAST JOIN + WINDOW UNION cost (§1 / §2).

The paper's first challenge is feature computation over "large-scale,
complex raw data (e.g., the 2018 PHM dataset contains 17 tables)"; OpenMLDB
answers it with point-in-time LAST JOIN and WINDOW UNION.  This bench
measures what the multi-table plane costs on both engines:

* offline — batch throughput (rows/s) of a 4-table view (2 LAST JOINs +
  2 WINDOW UNION aggs + plain windows) vs the same view with the
  multi-table features removed, isolating the join/union overhead;
* online  — request latency of the same view answered from device state
  (per-table rings: joins resolve by masked gather, unions by combining
  masked ring windows) on the naive and preagg paths.

Offline↔online equality is asserted on a replay prefix before timing.

Aggregations are restricted to the prefix-sum family (sum/count/mean/std)
so the bench isolates the join/union machinery from the windowed-fold
primitives (MIN/MAX now compile fine — see bench_window_agg for their
compile/run split — but add nothing to the join signal).
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import emit, timeit
from repro.core import (
    Col, FeatureView, OfflineEngine, OnlineFeatureStore,
    last_join, range_window, w_count, w_mean, w_std, w_sum,
)
from repro.data.synthetic import MULTITABLE_DB, multitable_stream

HIST_ROWS = 8_000
NUM_ACCOUNTS = 256
NUM_MERCHANTS = 32
T_MAX = 100_000
Q = 64  # request batch


def join_view() -> FeatureView:
    amt = Col("amount")
    w1h = range_window(3600, bucket=64)
    return FeatureView(
        name="join_bench",
        features={
            "credit_limit": last_join(
                Col("credit_limit"), "accounts", on="account", default=1000.0
            ),
            "merchant_reports": last_join(
                Col("fraud_reports"), "merchants", on="merchant"
            ),
            "outflow_sum_1h": w_sum(amt, w1h, union=("wires",)),
            "outflow_cnt_1h": w_count(amt, w1h, union=("wires",)),
            "amt_mean_1h": w_mean(amt, w1h),
            "amt_std_1h": w_std(amt, w1h),
        },
        database=MULTITABLE_DB,
    )


def single_table_view() -> FeatureView:
    amt = Col("amount")
    w1h = range_window(3600, bucket=64)
    return FeatureView(
        name="join_bench_baseline",
        features={
            "amt_sum_1h": w_sum(amt, w1h),
            "amt_cnt_1h": w_count(amt, w1h),
            "amt_mean_1h": w_mean(amt, w1h),
            "amt_std_1h": w_std(amt, w1h),
        },
        database=MULTITABLE_DB,
    )


def run() -> None:
    hist_rows = common.scaled(HIST_ROWS, 800)
    rng = np.random.default_rng(7)
    tables = multitable_stream(
        rng, hist_rows, num_accounts=NUM_ACCOUNTS,
        num_merchants=NUM_MERCHANTS, t_max=T_MAX,
    )
    tx = tables["transactions"]
    secondary = {t: c for t, c in tables.items() if t != "transactions"}
    view = join_view()
    base = single_table_view()
    engine = OfflineEngine()

    # -- offline throughput ---------------------------------------------------
    engine.compute(view, tx, secondary)  # warm/compile
    r = timeit(lambda: engine.compute(view, tx, secondary))
    emit("join", "offline_rows_per_s", hist_rows / r["median_s"], "rows/s",
         "4-table view: 2 LAST JOIN + 2 WINDOW UNION")
    engine.compute(base, tx, secondary)
    rb = timeit(lambda: engine.compute(base, tx, secondary))
    emit("join", "offline_rows_per_s_single_table", hist_rows / rb["median_s"],
         "rows/s", "same windows; no joins/unions")
    emit("join", "offline_multitable_overhead",
         r["median_s"] / rb["median_s"], "x")

    # -- online: preload device state, equality check, latency ----------------
    sec_nk = {"merchants": NUM_MERCHANTS}
    stores = {}
    for mode in ("naive", "preagg"):
        s = OnlineFeatureStore(
            view, num_keys=NUM_ACCOUNTS, capacity=256,
            secondary_num_keys=sec_nk,
        )
        for t, cols in secondary.items():
            sch = MULTITABLE_DB.table(t)
            order = np.lexsort((cols[sch.ts], cols[sch.key]))
            s.ingest_table(t, {c: v[order] for c, v in cols.items()})
        order = np.lexsort((tx["ts"], tx["account"]))
        s.ingest({c: v[order] for c, v in tx.items()})
        stores[mode] = s

    # equality vs offline on fresh request rows (later ts than the history;
    # unique accounts: a batched query answers every request against state
    # excluding the whole batch — verify_view's unique-key-round semantics)
    req = {
        "account": rng.choice(NUM_ACCOUNTS, Q, replace=False).astype(np.int32),
        "ts": np.sort(rng.integers(T_MAX, T_MAX + 3600, Q)).astype(np.int32),
        "amount": rng.gamma(1.5, 60.0, Q).astype(np.float32),
        "merchant": rng.integers(0, NUM_MERCHANTS, Q).astype(np.int32),
    }
    off = engine.compute(
        view,
        {c: np.concatenate([tx[c], req[c]]) for c in tx},
        secondary,
    )
    for mode, s in stores.items():
        on = s.query(req, mode=mode)
        for f in view.features:
            a = np.asarray(off[f])[-Q:]
            b = np.asarray(on[f])
            # scale-aware tolerance (same contract as consistency.verify_view:
            # offline prefix-sum differences vs online direct masked sums;
            # STD sqrt-amplifies near zero — see windows._segment_prefix_sum)
            atol = 2e-3 * max(1.0, float(np.percentile(np.abs(a), 99)))
            assert np.allclose(a, b, rtol=2e-4, atol=atol), (
                mode, f, np.abs(a - b).max()
            )

    for mode, s in stores.items():
        s.query(req, mode=mode)  # warm
        t = timeit(lambda: s.query(req, mode=mode))
        emit("join", f"online_{mode}_batch_ms", 1e3 * t["median_s"], "ms",
             f"Q={Q} multi-table requests")
        emit("join", f"online_{mode}_qps", Q / t["median_s"], "req/s")


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
