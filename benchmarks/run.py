"""Benchmark harness — one module per paper table/claim (DESIGN.md §6).

  python -m benchmarks.run            # all feature/system benches + roofline
  python -m benchmarks.run --only feature_latency
  python -m benchmarks.run --smoke    # CI: tiny N, one rep, no roofline

Multi-device CPU (the shard bench wants 8 shards = 8 devices):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 python -m benchmarks.run
"""

from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import common
from benchmarks.common import emit, header

BENCHES = [
    "feature_latency",   # §3.3 fraud: naive vs tuned vs featinsight
    "window_agg",        # §2 pre-aggregation vs window size + kernel check
    "fold",              # kernel roofline: XLA vs Pallas fold + fused ingest
    "ingest",            # §3.2 millisecond updates / 720M orders/day
    "wide_view",         # Fig. 4: 784-feature banking view
    "deploy",            # §3.2 one-click deployment pipeline
    "consistency",       # §2 offline/online verification
    "signature",         # §1 trillion-dim signatures
    "join",              # §1 multi-table plane: LAST JOIN + WINDOW UNION
    "shard",             # sharded serving plane: throughput vs shard count
    "stress",            # generated-plane scale: N views deploy/QPS/lanes
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI mode: tiny sizes, one rep per timing, skip roofline",
    )
    args = ap.parse_args()
    if args.smoke:
        common.set_smoke(True)

    header()
    failures = []
    for name in BENCHES:
        if args.only and name != args.only:
            continue
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            mod.run()
            emit(name, "bench_wall_s", time.perf_counter() - t0, "s")
        except Exception as e:  # keep the harness running
            failures.append(name)
            emit(name, "FAILED", 0, "", str(e)[:120].replace(",", ";"))
            traceback.print_exc()

    if not args.skip_roofline and not args.only and not args.smoke:
        from benchmarks import roofline
        roofline.run()

    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
