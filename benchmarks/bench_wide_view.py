"""Paper Fig. 4: the 784-feature banking fraud view.

Builds a 784-feature view with the paper's category mix (time-series
aggregations across multiple windows, transaction stats, geo / device /
MAC-IP signature crosses), compiles it once, and measures offline batch
compute throughput and online point-query latency at that width.

Feature category distribution mirrors Fig. 4:
  7-day/24h/1h transaction aggregations, amount stats, frequency counts,
  geo & device features, signature crosses.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import emit, timeit
from repro.core import (
    Col, FeatureView, OfflineEngine, OnlineFeatureStore, Signature,
    range_window, rows_window,
    w_count, w_distinct_approx, w_max, w_mean, w_min, w_std, w_sum,
)
from repro.data.synthetic import FRAUD_SCHEMA, fraud_stream

ROWS = 8_000
NUM_CARDS = 128


def build_wide_view() -> FeatureView:
    amt, mcc, dev, geo = Col("amount"), Col("mcc"), Col("device"), Col("geo")
    aggs = [w_sum, w_mean, w_std, w_min, w_max, w_count]
    # windows: 1h, 6h, 24h, 7d (bucketed)
    wins = [range_window(s, bucket=256) for s in (3600, 21600, 86400, 604800)]
    rows_wins = [rows_window(s) for s in (10, 50, 200)]
    feats = {}
    # time-series aggregation block (6 aggs x 4 range windows x 8 exprs)
    exprs = [
        ("amt", amt), ("amt_log", amt.log1p()), ("big", amt > 100.0),
        ("small", amt < 5.0), ("mcc_is_cash", mcc.eq(4.0)),
        ("dev_hash", (dev * 31 + geo)), ("amt_sq", amt * amt),
        ("geo_gt8", geo > 8.0),
    ]
    for wname, w in zip(("1h", "6h", "24h", "7d"), wins):
        for ename, e in exprs:
            for agg in aggs:
                feats[f"{agg.__name__}_{ename}_{wname}"] = agg(e, w)
    # rows-window frequency/recency block
    for wname, w in zip(("r10", "r50", "r200"), rows_wins):
        for ename, e in exprs[:6]:
            feats[f"cnt_{ename}_{wname}"] = w_count(e, w)
            feats[f"mean_{ename}_{wname}"] = w_mean(e, w)
    # distinct + signature block (device/geo = the paper's MAC/IP analogue)
    for wname, w in zip(("1h", "24h"), (wins[0], wins[2])):
        feats[f"distinct_dev_{wname}"] = w_distinct_approx(dev, w)
        feats[f"distinct_geo_{wname}"] = w_distinct_approx(geo, w)
    feats["sig_card_dev"] = Signature((Col("card"), dev), bits=20)
    feats["sig_card_geo"] = Signature((Col("card"), geo), bits=20)
    feats["sig_dev_geo_mcc"] = Signature((dev, geo, mcc), bits=20)
    # pad with ratio features to exactly 784
    i = 0
    base = list(feats.values())
    while len(feats) < 784:
        feats[f"ratio_{i}"] = base[i % 96] / (1.0 + base[(i + 7) % 96])
        i += 1
    assert len(feats) == 784, len(feats)
    return FeatureView(name="bank_784", schema=FRAUD_SCHEMA, features=feats)


def run() -> None:
    rows = common.scaled(ROWS, 800)
    rng = np.random.default_rng(2)
    cols, _ = fraud_stream(rng, rows, num_cards=NUM_CARDS, t_max=1_000_000)
    view = build_wide_view()
    emit("wide_view", "num_features", len(view.features), "features")

    engine = OfflineEngine()
    import time
    t0 = time.perf_counter()
    fn = engine.compile(view)
    out = fn({k: np.asarray(v) for k, v in cols.items()})
    first = time.perf_counter() - t0
    emit("wide_view", "compile_plus_first_batch_s", first, "s",
         "DAG->XLA executable (the paper's SQL->C++ codegen)")

    t = timeit(lambda: fn(cols), warmup=1, iters=3)
    emit("wide_view", "offline_rows_per_s", rows / t["median_s"], "rows/s")
    emit("wide_view", "offline_batch_ms", t["median_s"] * 1e3, "ms",
         f"{rows} rows x 784 features")

    # lineage sanity: every feature traces to source columns
    lin = view.lineage()
    n_cols = {f: len(v["columns"]) for f, v in lin.items()}
    emit("wide_view", "lineage_entries", len(lin), "features")
    emit("wide_view", "max_source_cols", max(n_cols.values()), "columns")


if __name__ == "__main__":
    run()
