"""Paper §3.3 (fraud detection): feature-computation latency & QPS.

The paper's table: naive Spark ≈ 200 ms, tuned in-house Spark ≈ 50 ms,
FeatInsight < 20 ms at QPS > 1000.  The reproducible claim is the
*relative ordering and magnitude gap* between

  1. ``naive``    — per-request recompute over the full history table
                    (what a batch engine does when asked point queries),
  2. ``tuned``    — vectorized masked scan over the per-key ring buffer
                    (online store, ``mode='naive'``: right data layout,
                    no pre-aggregation),
  3. ``featinsight`` — pre-aggregated bucket merge (``mode='preagg'``,
                    the paper's long-window pre-aggregation).

All three compute the identical 8-feature fraud view; equality is
asserted before timing.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import emit, timeit
from repro.core import OfflineEngine, OnlineFeatureStore
from repro.data.synthetic import fraud_stream
from repro.scenarios import fraud_view  # noqa: F401  (also re-exported)

HIST_ROWS = 20_000
NUM_CARDS = 256
Q = 64  # request batch


def run() -> None:
    hist_rows = common.scaled(HIST_ROWS, 1_500)
    rng = np.random.default_rng(0)
    hist, _ = fraud_stream(rng, hist_rows, num_cards=NUM_CARDS, t_max=200_000)
    view = fraud_view()

    # online stores, pre-loaded with history (sorted by key,ts as required)
    order = np.lexsort((hist["ts"], hist["card"]))
    hist_sorted = {c: v[order] for c, v in hist.items()}
    store = OnlineFeatureStore(
        view, num_keys=NUM_CARDS, capacity=256, num_buckets=512, bucket_size=64
    )
    store.ingest(hist_sorted)

    # request batch: late timestamps, distinct cards (rows of the same key
    # at the same instant would see each other offline but not online —
    # verify_view's unique-key-round semantics, kept here for the equality
    # gate)
    req = {
        "card": rng.permutation(NUM_CARDS)[:Q].astype(np.int32),
        "ts": np.full(Q, 200_001, np.int32),
        "amount": rng.gamma(1.5, 60.0, Q).astype(np.float32),
        "mcc": rng.integers(0, 32, Q).astype(np.int32),
        "device": rng.integers(0, 8, Q).astype(np.int32),
        "geo": rng.integers(0, 16, Q).astype(np.int32),
    }

    # naive engine baseline: append request rows to history, recompute all
    engine = OfflineEngine()

    def naive():
        cols = {
            c: np.concatenate([hist[c], req[c]]) for c in hist
        }
        out = engine.compute(view, cols)
        return {k: v[-Q:] for k, v in out.items()}

    tuned = lambda: store.query(req, mode="naive")
    fast = lambda: store.query(req, mode="preagg")

    # correctness gate: all three agree on the request rows.  std uses the
    # composable sum-of-squares form whose f32 cancellation noise floor is
    # ~sqrt(E[x^2]*eps) ~ 0.05 here, hence the wider atol for that feature.
    a, b, c = naive(), tuned(), fast()
    for f in view.features:
        atol = 0.5 if "std" in f else 1e-2
        np.testing.assert_allclose(
            np.asarray(a[f]), np.asarray(b[f]), rtol=2e-4, atol=atol
        )
        np.testing.assert_allclose(
            np.asarray(a[f]), np.asarray(c[f]), rtol=2e-4, atol=atol
        )

    for name, fn in [("naive", naive), ("tuned", tuned), ("featinsight", fast)]:
        t = timeit(fn, warmup=2, iters=7)
        ms = t["median_s"] * 1e3
        qps = Q / t["median_s"]
        emit("feature_latency", f"{name}_ms_per_batch{Q}", ms, "ms")
        emit("feature_latency", f"{name}_qps", qps, "req/s")
    emit(
        "feature_latency", "history_rows", hist_rows, "rows",
        "paper: naive 200ms / tuned 50ms / featinsight <20ms",
    )

    # tail latency through the deployed service path — the paper's claims
    # are tail claims, so report the percentile spread, not just the mean
    from repro.serve.service import FeatureService

    svc = FeatureService("fraud_latency", view, store)
    svc.request(req, ingest=False)  # absorb any residual compile
    svc.stats = type(svc.stats)()
    for _ in range(common.scaled(64, 3)):
        svc.request(req, ingest=False)
    st = svc.stats
    emit("feature_latency", "service_p50_ms", st.p50_ms, "ms")
    emit("feature_latency", "service_p95_ms", st.p95_ms, "ms")
    emit("feature_latency", "service_p99_ms", st.p99_ms, "ms")


if __name__ == "__main__":
    run()
