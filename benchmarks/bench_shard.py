"""Sharded serving plane: throughput + tail latency vs shard count.

The paper's serving claims (<20 ms at QPS > 1000) scale in production by
partitioning online state across nodes (OpenMLDB's partitioned tables).
This bench measures the reproduction's :class:`ShardedOnlineStore` on the
8-feature fraud view at shard counts {1, 2, 4, 8}: request throughput and
p50/p95/p99 batch latency from the service's tail-latency stats, plus an
exactness gate (every shard count must answer bit-identically to S=1).

True multi-device CPU numbers need forced host devices *before* jax
initializes:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m benchmarks.bench_shard

With fewer devices the mesh falls back (several shards per device) and
the bench still runs — throughput then measures routing overhead, not
parallel speedup; the emitted ``devices`` note says which one you got.
"""

from __future__ import annotations

import sys

if __name__ == "__main__":
    from repro.hostdevices import force_host_devices

    force_host_devices(8)

import time
from typing import Dict

import jax
import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro.core import ScenarioPlane, ShardedOnlineStore
from repro.data.synthetic import MULTITABLE_DB, fraud_stream, multitable_stream
from repro.scenarios import fraud_view, multi_scenario_views
from repro.serve.service import FeatureService, ServiceStats

SHARD_COUNTS = (1, 2, 4, 8)
NUM_CARDS = 256
T_MAX = 200_000


def run() -> None:
    hist_rows = common.scaled(20_000, 1_500)
    q = common.scaled(256, 32)
    n_batches = common.scaled(48, 3)

    rng = np.random.default_rng(0)
    hist, _ = fraud_stream(rng, hist_rows, num_cards=NUM_CARDS, t_max=T_MAX)
    order = np.lexsort((hist["ts"], hist["card"]))
    hist_sorted = {c: v[order] for c, v in hist.items()}
    view = fraud_view()

    def req_batch(r):
        return {
            "card": r.integers(0, NUM_CARDS, q).astype(np.int32),
            "ts": np.full(q, T_MAX + 1, np.int32),
            "amount": r.gamma(1.5, 60.0, q).astype(np.float32),
            "mcc": r.integers(0, 32, q).astype(np.int32),
            "device": r.integers(0, 8, q).astype(np.int32),
            "geo": r.integers(0, 16, q).astype(np.int32),
        }

    probe = req_batch(np.random.default_rng(1))
    ref = None
    emit("shard", "devices", len(jax.devices()), "devices")
    for s_count in SHARD_COUNTS:
        store = ShardedOnlineStore(
            view,
            num_keys=NUM_CARDS,
            num_shards=s_count,
            capacity=256,
            num_buckets=512,
            bucket_size=64,
        )
        store.ingest(hist_sorted)
        svc = FeatureService(f"fraud_s{s_count}", view, store)

        # exactness gate: all shard counts agree bit-for-bit
        out = svc.request(probe, ingest=False)
        if ref is None:
            ref = out
        else:
            for f in view.features:
                np.testing.assert_array_equal(out[f], ref[f])

        svc.stats = ServiceStats()  # drop the compile-latency sample
        r = np.random.default_rng(2)
        for _ in range(n_batches):
            svc.request(req_batch(r), ingest=False)
        st = svc.stats
        qps = st.requests / max(st.total_latency_s, 1e-9)
        mesh = store.mesh.devices.size
        emit("shard", f"s{s_count}_qps", qps, "req/s", f"mesh={mesh}dev")
        emit("shard", f"s{s_count}_p50_ms", st.p50_ms, "ms")
        emit("shard", f"s{s_count}_p95_ms", st.p95_ms, "ms")
        emit("shard", f"s{s_count}_p99_ms", st.p99_ms, "ms")
    emit(
        "shard", "batch_size", q, "rows",
        "exactness gate: all shard counts bit-identical",
    )

    # routing skew under an adversarial strided key pattern (all keys
    # ≡ 0 mod S): raw modulo routing collapses onto one shard, the default
    # mix64-Feistel hash routing spreads it (ROADMAP open item)
    S = 8
    strided = np.arange(0, NUM_CARDS, S, dtype=np.int32)
    # routing is pure host-side state: a minimal one-feature store exercises
    # the real shard_of without allocating the fraud view's device state
    from repro.core import Col, FeatureView, w_sum, range_window

    tiny = FeatureView(
        "route_probe", view.schema,
        {"s": w_sum(Col("amount"), range_window(64, bucket=32))},
    )
    for flag, name in ((False, "modulo"), (True, "hash")):
        store = ShardedOnlineStore(
            tiny, num_keys=NUM_CARDS, num_shards=S, capacity=16,
            num_buckets=4, bucket_size=32, hash_routing=flag,
        )
        counts = np.bincount(store.shard_of(strided), minlength=S)
        emit(
            "shard", f"strided_{name}_max_share",
            counts.max() / counts.sum(), "frac",
            f"occupied {int((counts > 0).sum())}/{S} shards",
        )

    multi_scenario_section()
    wire_to_wire_section()
    device_ab_section()


def wire_to_wire_section() -> None:
    """Wire-to-wire latency breakdown per stage, host vs device.

    Drives the full serving loop — submit -> scheduler -> router ->
    sharded query -> ingest — under a fresh telemetry per shard count
    {1, 4, 8}, single- and multi-scenario, and reports each stage from
    the span histograms: queue wait, shard routing (host), device
    compute (fenced), scatter-back (host), ingest-to-queryable, plus
    padding waste.  ROADMAP item 1 wants the host/device split "measured,
    not assumed" — this is the measurement.  The final snapshot is saved
    to ``benchmarks/telemetry_snapshot.json`` and rendered by
    ``python -m repro.obs.report``.
    """
    import json
    import os

    from repro.core import Col, FeatureView, rows_window, w_count, w_mean
    from repro.obs import Telemetry, use_telemetry
    from repro.obs.report import render_markdown
    from repro.serve.router import ShardRouter
    from repro.serve.service import BatchScheduler, FeatureService

    n_req = common.scaled(768, 96)
    view = fraud_view()
    amt = Col("amount")
    from repro.core import range_window, w_sum

    w1 = range_window(600, bucket=64)
    multi_views = [
        FeatureView(
            "w2w_fraud", view.schema,
            {"s": w_sum(amt, w1), "c5": w_count(amt, rows_window(5))},
        ),
        FeatureView("w2w_risk", view.schema, {"m": w_mean(amt, w1)}),
        FeatureView(
            "w2w_velocity", view.schema, {"c8": w_count(amt, rows_window(8))},
        ),
    ]

    def drive(svc, scenarios=None):
        router = ShardRouter(
            svc,
            BatchScheduler(
                buckets=(1, 4, 16, 64), max_batch=64, max_wait_us=2_000
            ),
        )
        r = np.random.default_rng(3)
        now = 0
        for i in range(n_req):
            row = dict(
                card=int(r.integers(0, NUM_CARDS)),
                ts=int(T_MAX + 1 + i),
                amount=float(r.gamma(1.5, 60.0)),
                mcc=int(r.integers(0, 32)),
                device=int(r.integers(0, 8)),
                geo=int(r.integers(0, 16)),
            )
            router.submit(
                row, now_us=now,
                scenario=(
                    scenarios[i % len(scenarios)] if scenarios else None
                ),
            )
            now += 150
            router.pump(now_us=now)
        router.drain(now_us=now)
        svc.store.record_gauges()

    def mean_ms(snap, metric, **match):
        for s in snap["metrics"].get(metric, {"series": ()})["series"]:
            if all(s["labels"].get(k) == v for k, v in match.items()):
                c = s["count"]
                return s["sum"] / c * 1e3 if c else 0.0
        return 0.0

    def pct_ms(snap, metric, p, **match):
        for s in snap["metrics"].get(metric, {"series": ()})["series"]:
            if all(s["labels"].get(k) == v for k, v in match.items()):
                return s[p] * 1e3
        return 0.0

    final_snap = None
    for flavour, shard_counts in (("single", (1, 4, 8)), ("multi", (1, 4, 8))):
        for S in shard_counts:
            tel = Telemetry(max_series=512)
            with use_telemetry(tel):
                if flavour == "single":
                    svc = FeatureService.build(
                        f"w2w_s{S}", view, num_keys=NUM_CARDS, sharded=True,
                        num_shards=S, capacity=256, num_buckets=512,
                        bucket_size=64,
                    )
                    drive(svc)
                else:
                    svc = FeatureService.build_multi(
                        f"w2w_multi_s{S}", multi_views, num_keys=NUM_CARDS,
                        sharded=True, num_shards=S, capacity=256,
                        num_buckets=512, bucket_size=64,
                    )
                    drive(svc, scenarios=[v.name for v in multi_views])
                snap = tel.snapshot()
            tag = f"w2w_{flavour}_s{S}"
            emit(
                "shard", f"{tag}_req_p50_ms",
                svc.stats.request_p50_ms, "ms",
                "per-request: queue wait + batch wall",
            )
            emit(
                "shard", f"{tag}_req_p95_ms",
                svc.stats.request_p95_ms, "ms",
            )
            for stage, side in (
                ("query.route", "host"),
                ("query.compute", "device"),
                ("query.scatter", "host"),
                ("ingest", "device"),
            ):
                emit(
                    "shard", f"{tag}_{stage.replace('query.', '')}_ms",
                    pct_ms(snap, "span_seconds", "p50", name=stage), "ms",
                    f"{side} (p50 per batch; first-trace compile lands "
                    "in query_compile_seconds)",
                )
            emit(
                "shard", f"{tag}_queue_wait_ms",
                mean_ms(snap, "queue_wait_seconds"), "ms",
                "host (mean per request)",
            )
            emit(
                "shard", f"{tag}_fresh_p95_ms",
                pct_ms(snap, "ingest_freshness_seconds", "p95",
                       table="transactions"), "ms",
                "ingest-to-queryable",
            )
            pad_shard = sum(
                s["value"]
                for s in snap["metrics"]["padding_rows_total"]["series"]
            )
            emit(
                "shard", f"{tag}_padding_rows", pad_shard, "rows",
                "scheduler + shard buckets",
            )
            final_snap = snap

    out_path = os.path.join(
        os.path.dirname(__file__), "telemetry_snapshot.json"
    )
    with open(out_path, "w") as f:
        json.dump(final_snap, f, indent=2)
    emit("shard", "telemetry_snapshot", 1, "file", out_path)
    print(render_markdown(
        final_snap, title="wire-to-wire (multi-scenario, 8 shards)"
    ))


def multi_scenario_section() -> None:
    """Aggregate QPS of 3 scenarios on ONE plane/mesh vs 3 isolated stores.

    The live-serving loop (query, then ingest the served rows — the
    online-learning pattern) is where consolidation pays: the plane
    ingests each primary batch and each shared wires batch ONCE for all
    scenarios, while isolated stores re-ingest per referencing view.  The
    answers are bit-identical either way (gated below), so the entire
    delta is the multi-scenario plane's shared state.
    """
    S = 8
    n_acct, n_merch = 256, 16
    hist_rows = common.scaled(6_000, 600)
    q = common.scaled(128, 16)
    rounds = common.scaled(16, 2)
    t_max = 100_000

    views = multi_scenario_views()
    kw = dict(
        num_keys=n_acct, capacity=256, num_buckets=512, bucket_size=64,
        secondary_num_keys={"merchants": n_merch},
    )
    rng = np.random.default_rng(7)
    tables = multitable_stream(
        rng, hist_rows, num_accounts=n_acct, num_merchants=n_merch,
        t_max=t_max,
    )

    def bykey(d, kc):
        o = np.lexsort((d["ts"], d[kc]))
        return {c: v[o] for c, v in d.items()}

    def preload(store):
        for t in store._sec_names:
            store.ingest_table(
                t, bykey(tables[t], MULTITABLE_DB.table(t).key)
            )
        store.ingest(bykey(tables["transactions"], "account"))

    plane = ScenarioPlane(views, num_shards=S, **kw)
    isolated = {
        v.name: ShardedOnlineStore(v, num_shards=S, **kw) for v in views
    }
    preload(plane.store)
    for st in isolated.values():
        preload(st)

    def batches(seed, t0):
        r = np.random.default_rng(seed)
        for i in range(rounds):
            yield {
                "account": r.permutation(n_acct)[:q].astype(np.int32),
                "ts": np.full(q, t0 + i + 1, np.int32),
                "amount": r.gamma(1.5, 60.0, q).astype(np.float32),
                "merchant": r.integers(0, n_merch, q).astype(np.int32),
            }, {
                "account": r.integers(0, n_acct, q // 4).astype(np.int32),
                "ts": np.full(q // 4, t0 + i + 1, np.int32),
                "amount": r.gamma(2.0, 120.0, q // 4).astype(np.float32),
            }

    # exactness gate + compile warm-up in one pass (both sides answer the
    # same probe identically; timing below excludes compiles)
    probe, probe_w = next(batches(1, t_max))
    for v in views:
        a = isolated[v.name].query(probe)
        b = plane.query(v.name, probe)
        for f in v.features:
            np.testing.assert_array_equal(
                np.asarray(a[f]), np.asarray(b[f])
            )
    warm = bykey(probe, "account")
    warm_w = bykey(probe_w, "account")
    plane.ingest(warm)
    plane.ingest_table("wires", warm_w)
    for v in views:
        isolated[v.name].ingest(warm)
        if "wires" in isolated[v.name]._sec_names:
            isolated[v.name].ingest_table("wires", warm_w)

    def serve_plane():
        for req, wire in batches(2, t_max + rounds + 8):
            for v in views:
                plane.query(v.name, req)
            plane.ingest(bykey(req, "account"))          # once
            plane.ingest_table("wires", bykey(wire, "account"))  # once

    def serve_isolated():
        for req, wire in batches(2, t_max + 2 * rounds + 16):
            for v in views:
                isolated[v.name].query(req)
            srt, srt_w = bykey(req, "account"), bykey(wire, "account")
            for v in views:
                isolated[v.name].ingest(srt)             # once per view
                if "wires" in isolated[v.name]._sec_names:
                    isolated[v.name].ingest_table("wires", srt_w)

    n_served = 3 * q * rounds
    t0 = time.perf_counter()
    serve_plane()
    t_plane = time.perf_counter() - t0
    t0 = time.perf_counter()
    serve_isolated()
    t_iso = time.perf_counter() - t0

    emit(
        "shard", "multi3_plane_qps", n_served / max(t_plane, 1e-9), "req/s",
        f"3 scenarios; one mesh; shared ingest; S={S}",
    )
    emit(
        "shard", "multi3_isolated_qps", n_served / max(t_iso, 1e-9), "req/s",
        "3 dedicated sharded stores; per-view ingest",
    )
    emit(
        "shard", "multi3_plane_speedup", t_iso / max(t_plane, 1e-9), "x",
        "exactness gate: plane == isolated bit-identical",
    )


def route_compile_budget_check(store, max_caps_per_bucket: int = 2) -> int:
    """Fused-program compile budget: per (program, mode, batch-shape
    bucket) the device path may trace at most ``max_caps_per_bucket``
    executables — the optimistic per-shard capacity plus the always-safe
    overflow rerun.  More means the capacity guess is churning and every
    skewed batch pays a fresh XLA compile.  Returns the fused trace count.
    """
    fused = [k for k in store._seen_traces if isinstance(k[2], tuple)]
    per_bucket: Dict[tuple, set] = {}
    for name, mode, (m, cap) in fused:
        per_bucket.setdefault((name, mode, m), set()).add(cap)
    for key, caps in sorted(per_bucket.items()):
        if len(caps) > max_caps_per_bucket:
            raise AssertionError(
                f"fused route program compiled {len(caps)} capacities "
                f"{sorted(caps)} for bucket {key} — budget is "
                f"{max_caps_per_bucket} (optimistic + overflow)"
            )
    return len(fused)


def device_ab_section() -> Dict:
    """Host-routed vs device-routed request path A/B — the PR's claim.

    The SAME request stream (same scheduler, same injected clock) runs
    through two identical deployments, one ``device_routing=False`` (host
    oracle), one ``device_routing=True`` (fused on-mesh program), at
    shards {1, 4, 8}, single- and multi-scenario.  Hard gates:

    * exactness — device answers == host answers bit-for-bit, pump by
      pump, scenario by scenario (the non-negotiable);
    * one fused dispatch per pump — ``route.device`` span count equals
      the batch count (a mixed 3-scenario batch is still ONE dispatch);
    * compile budget — :func:`route_compile_budget_check`.

    Per-stage span timings (p50/p95 of ``query.route`` /
    ``query.compute`` / ``route.device`` / ``query.scatter``) for both
    flavours are persisted machine-readably to
    ``benchmarks/BENCH_route.json``; the host-side routing share
    (route + scatter spans) is the number the device path exists to
    shrink, and ``device_wins`` records whether it did at each point.
    """
    import json
    import os

    from repro.core import Col, FeatureView, range_window, rows_window
    from repro.core import w_count, w_mean, w_sum
    from repro.obs import Telemetry, use_telemetry
    from repro.serve.router import ShardRouter
    from repro.serve.service import BatchScheduler, FeatureService

    n_req = common.scaled(768, 120)
    view = fraud_view()
    amt = Col("amount")
    w1 = range_window(600, bucket=64)
    multi_views = [
        FeatureView(
            "ab_fraud", view.schema,
            {"s": w_sum(amt, w1), "c5": w_count(amt, rows_window(5))},
        ),
        FeatureView("ab_risk", view.schema, {"m": w_mean(amt, w1)}),
        FeatureView(
            "ab_velocity", view.schema, {"c8": w_count(amt, rows_window(8))},
        ),
    ]

    def drive(svc, scenarios):
        router = ShardRouter(
            svc,
            BatchScheduler(
                buckets=(1, 4, 16, 64), max_batch=64, max_wait_us=2_000
            ),
        )
        r = np.random.default_rng(11)
        outs = []
        now = 0
        for i in range(n_req):
            row = dict(
                card=int(r.integers(0, NUM_CARDS)),
                ts=int(T_MAX + 1 + i),
                amount=float(r.gamma(1.5, 60.0)),
                mcc=int(r.integers(0, 32)),
                device=int(r.integers(0, 8)),
                geo=int(r.integers(0, 16)),
            )
            router.submit(
                row, now_us=now,
                scenario=(
                    scenarios[i % len(scenarios)] if scenarios else None
                ),
            )
            now += 150
            got = router.pump(now_us=now)
            if got is not None:
                outs.append(got)
        got = router.drain(now_us=now)
        if got is not None:
            outs.append(got)
        return outs, router

    def span_stat(snap, name, stat):
        for s in snap["metrics"].get("span_seconds", {"series": ()})[
            "series"
        ]:
            if s["labels"].get("name") == name:
                return (
                    int(s["count"])
                    if stat == "count"
                    else float(s[stat]) * 1e3
                )
        return 0
    results: Dict = {
        "devices": len(jax.devices()),
        "smoke": bool(common.SMOKE),
        "requests": n_req,
        "points": {},
    }
    for flavour in ("single", "multi"):
        scenarios = [v.name for v in multi_views] if flavour == "multi" else None
        for S in (1, 4, 8):
            point: Dict = {}
            outs_by_path: Dict[str, list] = {}
            for path in ("host", "device"):
                tel = Telemetry(max_series=512)
                with use_telemetry(tel):
                    if flavour == "single":
                        svc = FeatureService.build(
                            f"ab_{path}_s{S}", view, num_keys=NUM_CARDS,
                            sharded=True, num_shards=S, capacity=256,
                            num_buckets=512, bucket_size=64,
                            device_routing=(path == "device"),
                        )
                    else:
                        svc = FeatureService.build_multi(
                            f"ab_{path}_multi_s{S}", multi_views,
                            num_keys=NUM_CARDS, sharded=True, num_shards=S,
                            capacity=256, num_buckets=512, bucket_size=64,
                            device_routing=(path == "device"),
                        )
                    outs, _router = drive(svc, scenarios)
                    snap = tel.snapshot()
                outs_by_path[path] = outs
                host_ms = (
                    span_stat(snap, "query.route", "p50")
                    + span_stat(snap, "query.scatter", "p50")
                )
                point[path] = {
                    "batches": int(svc.stats.batches),
                    "route_p50_ms": span_stat(snap, "query.route", "p50"),
                    "route_p95_ms": span_stat(snap, "query.route", "p95"),
                    "compute_p50_ms": span_stat(
                        snap, "query.compute", "p50"
                    ),
                    "compute_p95_ms": span_stat(
                        snap, "query.compute", "p95"
                    ),
                    "route_device_p50_ms": span_stat(
                        snap, "route.device", "p50"
                    ),
                    "route_device_p95_ms": span_stat(
                        snap, "route.device", "p95"
                    ),
                    "scatter_p50_ms": span_stat(
                        snap, "query.scatter", "p50"
                    ),
                    "scatter_p95_ms": span_stat(
                        snap, "query.scatter", "p95"
                    ),
                    "host_route_scatter_p50_ms": host_ms,
                    "fused_dispatches": span_stat(
                        snap, "route.device", "count"
                    ),
                    "request_p50_ms": svc.stats.request_p50_ms,
                    "request_p95_ms": svc.stats.request_p95_ms,
                }
                if path == "device":
                    # one fused dispatch per pumped batch, even mixed
                    assert point[path]["fused_dispatches"] == int(
                        svc.stats.batches
                    ), (
                        f"{flavour} S={S}: {point[path]['fused_dispatches']}"
                        f" fused dispatches != {svc.stats.batches} batches"
                    )
                    point["fused_traces"] = route_compile_budget_check(
                        svc.store
                    )
                else:
                    assert point[path]["fused_dispatches"] == 0
            # exactness gate: identical streams, bit-identical answers
            a, b = outs_by_path["host"], outs_by_path["device"]
            assert len(a) == len(b), (len(a), len(b))
            for i, (oa, ob) in enumerate(zip(a, b)):
                if scenarios is None:
                    oa, ob = {"": oa}, {"": ob}
                assert set(oa) == set(ob)
                for s in oa:
                    for f in oa[s]:
                        np.testing.assert_array_equal(
                            oa[s][f], ob[s][f],
                            err_msg=f"{flavour} S={S} pump={i} {s}/{f}",
                        )
            point["device_wins"] = bool(
                point["device"]["host_route_scatter_p50_ms"]
                < point["host"]["host_route_scatter_p50_ms"]
            )
            tag = f"{flavour}_s{S}"
            results["points"][tag] = point
            emit(
                "shard", f"ab_{tag}_host_route_scatter_p50_ms",
                point["host"]["host_route_scatter_p50_ms"], "ms",
                "host-routed flavour: host route+scatter share",
            )
            emit(
                "shard", f"ab_{tag}_device_route_scatter_p50_ms",
                point["device"]["host_route_scatter_p50_ms"], "ms",
                f"device flavour; wins={point['device_wins']}; "
                "exactness gate passed",
            )
    out_path = os.path.join(os.path.dirname(__file__), "BENCH_route.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    emit("shard", "bench_route_json", 1, "file", out_path)
    # the device path must shrink the host routing share once routing is
    # real work (S >= 4); at S=1 both flavours route trivially.  The
    # margin is 4-10x in practice, so this holds even at smoke sizes.
    for flavour in ("single", "multi"):
        for S in (4, 8):
            p = results["points"][f"{flavour}_s{S}"]
            assert p["device_wins"], (
                f"device path did not win host route+scatter at "
                f"{flavour} S={S} "
                f"(host {p['host']['host_route_scatter_p50_ms']:.3f} ms vs "
                f"device {p['device']['host_route_scatter_p50_ms']:.3f} ms)"
            )
    return results


if __name__ == "__main__":
    run()
    print("bench_shard done", file=sys.stderr)
