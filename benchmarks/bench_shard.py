"""Sharded serving plane: throughput + tail latency vs shard count.

The paper's serving claims (<20 ms at QPS > 1000) scale in production by
partitioning online state across nodes (OpenMLDB's partitioned tables).
This bench measures the reproduction's :class:`ShardedOnlineStore` on the
8-feature fraud view at shard counts {1, 2, 4, 8}: request throughput and
p50/p95/p99 batch latency from the service's tail-latency stats, plus an
exactness gate (every shard count must answer bit-identically to S=1).

True multi-device CPU numbers need forced host devices *before* jax
initializes:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m benchmarks.bench_shard

With fewer devices the mesh falls back (several shards per device) and
the bench still runs — throughput then measures routing overhead, not
parallel speedup; the emitted ``devices`` note says which one you got.
"""

from __future__ import annotations

import sys

if __name__ == "__main__":
    from repro.hostdevices import force_host_devices

    force_host_devices(8)

import jax
import numpy as np

from benchmarks import common
from benchmarks.common import emit
from benchmarks.bench_feature_latency import fraud_view
from repro.core import ShardedOnlineStore
from repro.data.synthetic import fraud_stream
from repro.serve.service import FeatureService, ServiceStats

SHARD_COUNTS = (1, 2, 4, 8)
NUM_CARDS = 256
T_MAX = 200_000


def run() -> None:
    hist_rows = common.scaled(20_000, 1_500)
    q = common.scaled(256, 32)
    n_batches = common.scaled(48, 3)

    rng = np.random.default_rng(0)
    hist, _ = fraud_stream(rng, hist_rows, num_cards=NUM_CARDS, t_max=T_MAX)
    order = np.lexsort((hist["ts"], hist["card"]))
    hist_sorted = {c: v[order] for c, v in hist.items()}
    view = fraud_view()

    def req_batch(r):
        return {
            "card": r.integers(0, NUM_CARDS, q).astype(np.int32),
            "ts": np.full(q, T_MAX + 1, np.int32),
            "amount": r.gamma(1.5, 60.0, q).astype(np.float32),
            "mcc": r.integers(0, 32, q).astype(np.int32),
            "device": r.integers(0, 8, q).astype(np.int32),
            "geo": r.integers(0, 16, q).astype(np.int32),
        }

    probe = req_batch(np.random.default_rng(1))
    ref = None
    emit("shard", "devices", len(jax.devices()), "devices")
    for s_count in SHARD_COUNTS:
        store = ShardedOnlineStore(
            view,
            num_keys=NUM_CARDS,
            num_shards=s_count,
            capacity=256,
            num_buckets=512,
            bucket_size=64,
        )
        store.ingest(hist_sorted)
        svc = FeatureService(f"fraud_s{s_count}", view, store)

        # exactness gate: all shard counts agree bit-for-bit
        out = svc.request(probe, ingest=False)
        if ref is None:
            ref = out
        else:
            for f in view.features:
                np.testing.assert_array_equal(out[f], ref[f])

        svc.stats = ServiceStats()  # drop the compile-latency sample
        r = np.random.default_rng(2)
        for _ in range(n_batches):
            svc.request(req_batch(r), ingest=False)
        st = svc.stats
        qps = st.requests / max(st.total_latency_s, 1e-9)
        mesh = store.mesh.devices.size
        emit("shard", f"s{s_count}_qps", qps, "req/s", f"mesh={mesh}dev")
        emit("shard", f"s{s_count}_p50_ms", st.p50_ms, "ms")
        emit("shard", f"s{s_count}_p95_ms", st.p95_ms, "ms")
        emit("shard", f"s{s_count}_p99_ms", st.p99_ms, "ms")
    emit(
        "shard", "batch_size", q, "rows",
        "exactness gate: all shard counts bit-identical",
    )

    # routing skew under an adversarial strided key pattern (all keys
    # ≡ 0 mod S): raw modulo routing collapses onto one shard, the default
    # mix64-Feistel hash routing spreads it (ROADMAP open item)
    S = 8
    strided = np.arange(0, NUM_CARDS, S, dtype=np.int32)
    # routing is pure host-side state: a minimal one-feature store exercises
    # the real shard_of without allocating the fraud view's device state
    from repro.core import Col, FeatureView, w_sum, range_window

    tiny = FeatureView(
        "route_probe", view.schema,
        {"s": w_sum(Col("amount"), range_window(64, bucket=32))},
    )
    for flag, name in ((False, "modulo"), (True, "hash")):
        store = ShardedOnlineStore(
            tiny, num_keys=NUM_CARDS, num_shards=S, capacity=16,
            num_buckets=4, bucket_size=32, hash_routing=flag,
        )
        counts = np.bincount(store.shard_of(strided), minlength=S)
        emit(
            "shard", f"strided_{name}_max_share",
            counts.max() / counts.sum(), "frac",
            f"occupied {int((counts > 0).sum())}/{S} shards",
        )


if __name__ == "__main__":
    run()
    print("bench_shard done", file=sys.stderr)
