"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by ``repro.launch.dryrun``) and
prints the per-cell three-term roofline: compute / memory / collective
seconds per step, the dominant term, and the useful-FLOPs ratio.

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI — constants live in repro.launch.roofline.
"""

from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(tag: str = "") -> List[Dict]:
    out = []
    suffix = f"-{tag}" if tag else ""
    for p in sorted(DRYRUN.glob(f"*__*{suffix}.json")):
        stem = p.stem
        if tag and not stem.endswith(suffix):
            continue
        if not tag and "-" in stem.split("__")[-1]:
            # skip tagged perf-iteration variants in the baseline table
            if stem.split("__")[-1] not in ("single", "multi"):
                continue
        try:
            out.append(json.loads(p.read_text()))
        except json.JSONDecodeError:
            continue
    return out


def fmt_row(r: Dict) -> Optional[str]:
    cell = f"{r['arch']} x {r['shape']} [{r.get('mesh','?')}]"
    if r.get("skipped"):
        return f"{cell:58s} SKIP ({r['reason'].split(':')[0]})"
    if "roofline" not in r:
        return None
    t = r["roofline"]
    return (
        f"{cell:58s} c={t['compute_s']:.4f}s m={t['memory_s']:.4f}s "
        f"coll={t['collective_s']:.4f}s dom={t['dominant']:<10s} "
        f"useful={r.get('useful_flops_ratio', 0):.2f}"
    )


def run() -> None:
    cells = load_cells()
    n_ok = n_skip = 0
    print("== roofline table (from dry-run compile artifacts) ==")
    for r in cells:
        line = fmt_row(r)
        if line is None:
            continue
        print(line)
        n_skip += int(bool(r.get("skipped")))
        n_ok += int(not r.get("skipped"))
    print(f"cells: {n_ok} compiled, {n_skip} skipped "
          f"(see EXPERIMENTS.md for analysis)")


if __name__ == "__main__":
    run()
