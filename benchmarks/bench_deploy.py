"""Paper §3.2: one-click deployment — define -> compile -> verify -> serve.

The paper's claim: the packaged pipeline deploys features "within an
hour" vs months of manual consistency checking.  Here the whole pipeline
is mechanized; we measure its wall time end-to-end:

  1. define view (DAG -> lineage + SQL rendering),
  2. compile offline executable (XLA codegen),
  3. offline/online consistency verification on test data,
  4. deploy to the registry + warm the online service.

Also exercises version evolution (the paper's cached prior versions):
v2 = v1 + new features, measuring the incremental redeploy cost.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro.core import (
    Col, FeatureRegistry, FeatureView, OfflineEngine, OnlineFeatureStore,
    range_window, w_count, w_mean, w_sum,
)
from repro.core.consistency import verify_view
from repro.data.synthetic import FRAUD_SCHEMA, fraud_stream

ROWS = 2_000
NUM_CARDS = 64


def run() -> None:
    rng = np.random.default_rng(3)
    cols, _ = fraud_stream(rng, common.scaled(ROWS, 400), num_cards=NUM_CARDS,
                           t_max=100_000)
    registry = FeatureRegistry()
    engine = OfflineEngine()

    amt = Col("amount")
    w1h = range_window(3600, bucket=64)

    t0 = time.perf_counter()
    view = FeatureView(
        name="fraud_v1", schema=FRAUD_SCHEMA,
        features={
            "amt_sum_1h": w_sum(amt, w1h),
            "amt_mean_1h": w_mean(amt, w1h),
            "tx_count_1h": w_count(amt, w1h),
        },
        description="v1 fraud features",
    )
    registry.register(view)
    t_define = time.perf_counter() - t0

    t0 = time.perf_counter()
    engine.compile(view)
    engine.compute(view, cols)  # warm
    t_compile = time.perf_counter() - t0

    t0 = time.perf_counter()
    report = verify_view(
        view, cols, num_keys=NUM_CARDS, num_buckets=64, bucket_size=64,
        engine=engine,
    )
    t_verify = time.perf_counter() - t0
    assert report.passed, report.summary()

    t0 = time.perf_counter()
    store = OnlineFeatureStore(view, num_keys=NUM_CARDS, num_buckets=64,
                               bucket_size=64)
    order = np.lexsort((cols["ts"], cols["card"]))
    store.ingest({c: v[order] for c, v in cols.items()})
    registry.deploy("fraud_service", view.name, view.version)
    q = {c: v[:8] for c, v in cols.items()}
    store.query(q)  # warm the serving executable
    t_deploy = time.perf_counter() - t0

    total = t_define + t_compile + t_verify + t_deploy
    emit("deploy", "define_s", t_define, "s")
    emit("deploy", "compile_s", t_compile, "s")
    emit("deploy", "consistency_verify_s", t_verify, "s",
         report.summary().replace(",", ";"))
    emit("deploy", "deploy_serve_s", t_deploy, "s")
    emit("deploy", "total_s", total, "s",
         "paper: <1h end-to-end; manual baseline: months")

    # incremental evolution (v2 reuses v1's lineage + store layout)
    t0 = time.perf_counter()
    v2 = view.evolve({"big_count_1h": w_count(amt > 100.0, w1h)})
    registry.register(v2)
    engine.compile(v2)
    engine.compute(v2, cols)
    registry.deploy("fraud_service", v2.name, v2.version)
    t_evolve = time.perf_counter() - t0
    emit("deploy", "evolve_v2_s", t_evolve, "s",
         "incremental redefinition via cached v1")
    assert registry.versions("fraud_v1") == [1, 2]


if __name__ == "__main__":
    run()
