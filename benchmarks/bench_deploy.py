"""Paper §3.2: one-click deployment — define -> compile -> verify -> serve.

The paper's claim: the packaged pipeline deploys features "within an
hour" vs months of manual consistency checking.  Here the whole pipeline
is mechanized; we measure its wall time end-to-end:

  1. define view (DAG -> lineage + SQL rendering),
  2. compile offline executable (XLA codegen),
  3. offline/online consistency verification on test data,
  4. deploy to the registry + warm the online service.

Also exercises version evolution (the paper's cached prior versions):
v2 = v1 + new features, measuring the incremental redeploy cost.

The ``hot_deploy`` section measures the live-plane evolution path
(ISSUE 5): adding scenario #3 to a WARM 8-shard multi-scenario plane via
``MultiScenarioService.hot_deploy`` (a StoreLayout diff + state
migration) vs the cold baseline (rebuild the merged plane and replay the
whole warm stream).  :func:`migration_exactness_check` is the CI gate:
hot-deployed state must equal rebuild+replay bit-for-bit.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro.core import (
    Col, FeatureRegistry, FeatureView, OfflineEngine, OnlineFeatureStore,
    ScenarioPlane, Signature, range_window, w_count, w_mean, w_sum,
)
from repro.core.consistency import verify_view
from repro.data.synthetic import FRAUD_SCHEMA, fraud_stream

ROWS = 2_000
NUM_CARDS = 64

HOT_ROWS = 4_000
HOT_ACCTS = 128
HOT_SHARDS = 8


def run() -> None:
    rng = np.random.default_rng(3)
    cols, _ = fraud_stream(rng, common.scaled(ROWS, 400), num_cards=NUM_CARDS,
                           t_max=100_000)
    registry = FeatureRegistry()
    engine = OfflineEngine()

    amt = Col("amount")
    w1h = range_window(3600, bucket=64)

    t0 = time.perf_counter()
    view = FeatureView(
        name="fraud_v1", schema=FRAUD_SCHEMA,
        features={
            "amt_sum_1h": w_sum(amt, w1h),
            "amt_mean_1h": w_mean(amt, w1h),
            "tx_count_1h": w_count(amt, w1h),
        },
        description="v1 fraud features",
    )
    registry.register(view)
    t_define = time.perf_counter() - t0

    t0 = time.perf_counter()
    engine.compile(view)
    engine.compute(view, cols)  # warm
    t_compile = time.perf_counter() - t0

    t0 = time.perf_counter()
    report = verify_view(
        view, cols, num_keys=NUM_CARDS, num_buckets=64, bucket_size=64,
        engine=engine,
    )
    t_verify = time.perf_counter() - t0
    assert report.passed, report.summary()

    t0 = time.perf_counter()
    store = OnlineFeatureStore(view, num_keys=NUM_CARDS, num_buckets=64,
                               bucket_size=64)
    order = np.lexsort((cols["ts"], cols["card"]))
    store.ingest({c: v[order] for c, v in cols.items()})
    registry.deploy("fraud_service", view.name, view.version)
    q = {c: v[:8] for c, v in cols.items()}
    store.query(q)  # warm the serving executable
    t_deploy = time.perf_counter() - t0

    total = t_define + t_compile + t_verify + t_deploy
    emit("deploy", "define_s", t_define, "s")
    emit("deploy", "compile_s", t_compile, "s")
    emit("deploy", "consistency_verify_s", t_verify, "s",
         report.summary().replace(",", ";"))
    emit("deploy", "deploy_serve_s", t_deploy, "s")
    emit("deploy", "total_s", total, "s",
         "paper: <1h end-to-end; manual baseline: months")

    # incremental evolution (v2 reuses v1's lineage + store layout)
    t0 = time.perf_counter()
    v2 = view.evolve({"big_count_1h": w_count(amt > 100.0, w1h)})
    registry.register(v2)
    engine.compile(v2)
    engine.compute(v2, cols)
    registry.deploy("fraud_service", v2.name, v2.version)
    t_evolve = time.perf_counter() - t0
    emit("deploy", "evolve_v2_s", t_evolve, "s",
         "incremental redefinition via cached v1")
    assert registry.versions("fraud_v1") == [1, 2]

    hot_deploy_section()
    backfill_section()


# ---------------------------------------------------------------------------
# live plane evolution: hot-add scenario #3 on a warm sharded plane
# ---------------------------------------------------------------------------


def _hot_setup(rows: int, accts: int, capacity: int = 256):
    from repro.data.synthetic import MULTITABLE_DB, multitable_stream
    from repro.scenarios import multi_scenario_views

    rng = np.random.default_rng(17)
    # t_max/bucket_size < num_buckets: no bucket-ring wraparound.  With
    # the default capacity > rows/key there is no ring aging either — the
    # horizon inside which the migration's bit-exactness contract is
    # unconditional; the backfill sections shrink ``capacity`` below
    # rows/key on purpose to force aged-out history.
    tabs = multitable_stream(
        rng, rows, num_accounts=accts, num_merchants=16, t_max=60_000
    )
    tx = tabs["transactions"]
    sec = {t: c for t, c in tabs.items() if t != "transactions"}
    views = multi_scenario_views()
    kw = dict(
        num_keys=accts, capacity=capacity, num_buckets=1024, bucket_size=64,
        secondary_num_keys={"merchants": 16},
    )

    def bykey(d, kc):
        o = np.lexsort((d["ts"], d[kc]))
        return {c: v[o] for c, v in d.items()}

    def warm(plane):
        for t in plane.store._sec_names:
            kc = MULTITABLE_DB.table(t).key
            plane.ingest_table(t, bykey(sec[t], kc))
        plane.ingest(bykey(tx, "account"))

    return views, kw, warm, tx, tabs


def _state_equal(a, b) -> bool:
    import jax

    la = jax.tree_util.tree_leaves(a.store.state)
    lb = jax.tree_util.tree_leaves(b.store.state)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb)
    )


def hot_deploy_section() -> None:
    """Hot-add scenario #3 on a warm plane vs cold rebuild + full replay."""
    from repro.serve.service import FeatureService

    rows = common.scaled(HOT_ROWS, 300)
    accts = common.scaled(HOT_ACCTS, 32)
    shards = common.scaled(HOT_SHARDS, 4)
    views, kw, warm, tx, _ = _hot_setup(rows, accts)

    svc = FeatureService.build_multi(
        "hot_plane", views[:2], sharded=True, num_shards=shards, **kw
    )
    warm(svc.plane)
    probe = {c: v[:16] for c, v in tx.items()}
    for v in views[:2]:  # warm the serving executables
        svc.plane.query(v.name, probe)

    t0 = time.perf_counter()
    report = svc.hot_deploy(views[2])
    svc.plane.query(views[2].name, probe)  # first answer incl. compile
    t_hot = time.perf_counter() - t0
    assert report.exact, report.notes

    t0 = time.perf_counter()
    cold = ScenarioPlane(views, num_shards=shards, **kw)
    warm(cold)  # the replay a rebuild forces
    cold.query(views[2].name, probe)
    t_cold = time.perf_counter() - t0

    assert _state_equal(svc.plane, cold), "hot deploy diverged from rebuild"

    emit("deploy", "hot_deploy_ms", 1e3 * t_hot, "ms",
         f"add scenario #3 on warm {shards}-shard plane ({rows} rows kept)")
    emit("deploy", "cold_rebuild_replay_ms", 1e3 * t_cold, "ms",
         "rebuild merged plane + re-ingest full stream")
    emit("deploy", "hot_deploy_speedup", t_cold / max(t_hot, 1e-9), "x",
         "state migration vs rebuild+replay; bit-exactness asserted")


def backfill_section() -> None:
    """Hot deploy needing aged-out history: offline backfill vs rebuild.

    The plane runs with ``capacity`` far below rows/key, so primary rings
    have aged out most of the stream by deploy time.  Growing capacity on
    hot deploy then *requires* history the rings no longer hold — the
    diff that used to report ``exact=False``.  With a
    :class:`~repro.offline.BackfillSource` the migration re-derives the
    aged-out rows offline and stays bit-exact; we report the splice cost
    against the cold rebuild + full replay it replaces.
    """
    from repro.data.synthetic import MULTITABLE_DB
    from repro.obs import get_telemetry
    from repro.offline import BackfillSource
    from repro.serve.service import FeatureService

    rows = common.scaled(HOT_ROWS, 600)
    shards = common.scaled(HOT_SHARDS, 4)
    accts = 16  # few keys: every key's ring wraps at capacity 16
    views, kw, warm, tx, tabs = _hot_setup(rows, accts, capacity=16)

    svc = FeatureService.build_multi(
        "bf_plane", views[:2], sharded=True, num_shards=shards, **kw
    )
    warm(svc.plane)
    probe = {c: v[:16] for c, v in tx.items()}
    for v in views[:2]:
        svc.plane.query(v.name, probe)

    src = BackfillSource(MULTITABLE_DB, tabs)
    tel = get_telemetry()
    t0 = time.perf_counter()
    report = svc.hot_deploy(views[2], backfill=src, capacity=64)
    svc.plane.query(views[2].name, probe)
    t_hot = time.perf_counter() - t0
    assert report.exact, report.notes
    assert report.backfilled, "expected an aged-out-history backfill"
    root = tel.tracer.last_root("hot_deploy")
    spans = root.find("backfill") if root else []
    bf_ms = 1e3 * spans[0].duration_s if spans else float("nan")

    t0 = time.perf_counter()
    cold = ScenarioPlane(views, num_shards=shards, **dict(kw, capacity=64))
    warm(cold)
    cold.query(views[2].name, probe)
    t_cold = time.perf_counter() - t0
    assert _state_equal(svc.plane, cold), "backfilled state != rebuild+replay"

    emit("deploy", "backfill_splice_ms", bf_ms, "ms",
         f"re-derive aged-out rows for capacity 16->64 grow "
         f"({rows} rows, {shards} shards)")
    emit("deploy", "backfill_hot_deploy_ms", 1e3 * t_hot, "ms",
         "hot deploy incl. backfill splice + first query compile")
    emit("deploy", "backfill_cold_rebuild_ms", 1e3 * t_cold, "ms",
         "rebuild at new capacity + re-ingest full stream")
    emit("deploy", "backfill_speedup", t_cold / max(t_hot, 1e-9), "x",
         "backfilled hot deploy vs rebuild+replay; bit-exactness asserted")


def migration_exactness_check(rows: int = 600, shards: int = 4) -> None:
    """CI gate (scripts/ci.sh): hot-deploy == cold rebuild + full replay,
    bit-for-bit, on a warm sharded plane.  Raises on any divergence.

    Two phases: (1) the within-retention migration (no backfill needed);
    (2) a previously-refused diff — a new Signature lane plus a capacity
    grow on a plane whose rings have aged out most of the stream — made
    bit-exact by an offline :class:`~repro.offline.BackfillSource`.
    """
    from repro.data.synthetic import MULTITABLE_DB
    from repro.offline import BackfillSource
    from repro.serve.service import FeatureService

    views, kw, warm, _, _ = _hot_setup(rows, 64)
    svc = FeatureService.build_multi(
        "gate_plane", views[:2], sharded=True, num_shards=shards, **kw
    )
    warm(svc.plane)
    before = svc.plane.ingest_row_counts()
    report = svc.hot_deploy(views[2])
    assert report.exact, f"migration not exact: {report.notes}"
    assert svc.plane.ingest_row_counts() == before, "hot deploy re-ingested"
    cold = ScenarioPlane(views, num_shards=shards, **kw)
    warm(cold)
    assert _state_equal(svc.plane, cold), (
        "hot-deployed state != rebuild+replay"
    )
    print(
        f"migration exactness gate OK: {report.describe().splitlines()[0]}"
    )

    # phase 2: beyond the retention horizon.  16-row rings age out ~60%
    # of the stream; the Signature lane is new (underivable from stored
    # lanes) and the capacity grow needs aged-out rows — refused without
    # a backfill source, bit-exact with one.
    views, kw, warm, _, tabs = _hot_setup(rows, 16, capacity=16)
    w1h = range_window(3600, bucket=64)
    sig_view = FeatureView(
        name="merchant_mix",
        features={
            "sig_cnt_1h": w_count(
                Signature((Col("merchant"),), bits=8), w1h
            ),
            "sig_sum_1h": w_sum(
                Signature((Col("merchant"),), bits=8), w1h
            ),
        },
        database=MULTITABLE_DB,
    )
    svc = FeatureService.build_multi(
        "gate_backfill", views[:2], sharded=True, num_shards=shards, **kw
    )
    warm(svc.plane)
    try:
        svc.hot_deploy(sig_view, capacity=64)
        raise AssertionError("expected refusal without a backfill source")
    except ValueError as e:
        assert "backfill" in str(e), e
    report = svc.hot_deploy(
        sig_view, backfill=BackfillSource(MULTITABLE_DB, tabs), capacity=64
    )
    assert report.exact, f"backfilled migration not exact: {report.notes}"
    assert report.backfilled, "expected backfilled deficits in the report"
    cold = ScenarioPlane(
        views[:2] + [sig_view], num_shards=shards, **dict(kw, capacity=64)
    )
    warm(cold)
    assert _state_equal(svc.plane, cold), (
        "backfilled state != rebuild+replay"
    )
    print(
        "backfill exactness gate OK: previously-refused diff "
        f"({len(report.backfilled)} deficits spliced) now bit-exact"
    )


if __name__ == "__main__":
    run()
