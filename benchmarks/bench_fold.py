"""Kernel-layer roofline: XLA vs Pallas for fold_levels + fused ingest.

The two hot loops ISSUE 10 rewrote:

* ``fold_levels`` — the doubling segmented combine behind offline
  MIN/MAX and the preagg tail fold.  The grid-tiled kernel streams row
  tiles through VMEM (the old 2^17-row cap is gone), so ``impl="auto"``
  stays Pallas at every size on TPU.
* ``fused_ingest`` — ring scatter + bucket pre-agg merge in ONE pass
  over the batch, vs the split two-dispatch XLA sequence
  (``ring_ingest`` + ``bucket_ingest``, preserved as the ``impl="xla"``
  oracle).

Sweeps N ∈ {10^5, 10^6, 10^7} (smoke: one tiny N) and persists the
numbers machine-readably to ``benchmarks/BENCH_fold.json``, re-checked
by ``scripts/ci.sh``: bit-exact parity is gated on EVERY backend (on CPU
the Pallas kernels run via ``interpret=True`` at a small parity size —
interpret timings are meaningless and never recorded); the
"Pallas >= XLA at N >= 10^6" speed gate applies only where the kernels
lower natively (TPU).

Roofline context (why Pallas should win): per row, fold_levels moves
~4·(2 + KL) bytes of HBM traffic (read x + seg once, write KL level
planes); the XLA reference materializes every intermediate level
round-trip.  A fused-ingest row moves the batch payload plus one ring
slot write and amortized bucket-state RMW — the split sequence reads the
batch twice and round-trips the bucket arrays.  Achieved GB/s = modeled
bytes / median time, reported against the hardware model's HBM peak
(``repro.launch.roofline.HBM_BW``) so the gap to roof is a number, not a
vibe.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, timeit
from repro.core import preagg as pg
from repro.core import storage as st
from repro.kernels.ingest.ops import fused_ingest
from repro.kernels.window_agg.ops import fold_levels
from repro.kernels.window_agg.ref import fold_num_levels
from repro.launch.roofline import HBM_BW

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_fold.json")

FOLD_OP = "max"  # exact value-pick: XLA/Pallas parity must be bit-exact

# ingest state geometry (full mode): sized so the state arrays are
# HBM-resident but dwarfed by the 10^7-row batch payload
ING_K, ING_C, ING_F, ING_NB, ING_BS = 1024, 1024, 4, 256, 64


def _fold_inputs(rng, n):
    """(x, seg) for a segmented fold over ~n/4096-row key runs."""
    key = np.sort(rng.integers(0, max(n // 4096, 4), n).astype(np.int32))
    idx = np.arange(n, dtype=np.int32)
    first = np.concatenate([[True], key[1:] != key[:-1]])
    seg = np.maximum.accumulate(np.where(first, idx, 0)).astype(np.int32)
    x = rng.standard_normal(n).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(seg)


def _ingest_batch(rng, n, num_keys, t_max, f):
    """(key, ts, vals) sorted by (key, ts) — one padded-free batch."""
    key = np.sort(rng.integers(0, num_keys, n).astype(np.int32))
    ts = rng.integers(0, t_max, n).astype(np.int32)
    order = np.lexsort((ts, key))
    vals = rng.standard_normal((n, f)).astype(np.float32)
    return (jnp.asarray(key[order]), jnp.asarray(ts[order]),
            jnp.asarray(vals))


def _ingest_state(num_keys, cap, f, nb, bs):
    ring = st.ring_init(num_keys, cap, f)
    bagg = pg.bucket_init(num_keys, nb, f, bs)
    return (ring.ts, ring.vals, ring.cursor,
            bagg.stats, bagg.bitmap, bagg.bucket)


def _fold_bytes(n: int) -> int:
    """Modeled HBM traffic: read x + seg (4 B each), write KL levels."""
    return n * 4 * (2 + fold_num_levels(n))


def _ingest_bytes(n: int, f: int) -> int:
    """Modeled HBM floor: read key/ts/vals, write ring ts/vals slots
    (bucket-state RMW amortizes over rows and is excluded — the model is
    a lower bound shared by both impls)."""
    return n * (8 + 4 * f) + n * (4 + 4 * f)


def _gbps(nbytes: int, seconds: float) -> float:
    return nbytes / seconds / 1e9 if seconds > 0 else 0.0


def _fold_point(rng, n: int, native_pallas: bool) -> dict:
    x, seg = _fold_inputs(rng, n)
    nbytes = _fold_bytes(n)
    tx = timeit(lambda: fold_levels(x, seg, op=FOLD_OP, impl="xla"),
                iters=3)
    point = {
        "rows": n,
        "levels": fold_num_levels(n),
        "bytes_moved": nbytes,
        "xla": tx,
        "xla_gbps": _gbps(nbytes, tx["median_s"]),
        "pallas": None,
        "pallas_gbps": None,
    }
    emit("fold", f"fold_xla_N{n}_ms", tx["median_s"] * 1e3, "ms",
         f"{point['xla_gbps']:.1f} GB/s of {HBM_BW / 1e9:.0f} peak")
    if native_pallas:
        tp = timeit(lambda: fold_levels(x, seg, op=FOLD_OP, impl="pallas"),
                    iters=3)
        point["pallas"] = tp
        point["pallas_gbps"] = _gbps(nbytes, tp["median_s"])
        emit("fold", f"fold_pallas_N{n}_ms", tp["median_s"] * 1e3, "ms",
             f"{point['pallas_gbps']:.1f} GB/s of {HBM_BW / 1e9:.0f} peak")
    return point


def _ingest_point(rng, n: int, native_pallas: bool) -> dict:
    nk = min(ING_K, max(n // 64, 8))
    batch = _ingest_batch(rng, n, nk, ING_NB * ING_BS, ING_F)
    state = _ingest_state(nk, ING_C, ING_F, ING_NB, ING_BS)
    nbytes = _ingest_bytes(n, ING_F)
    tx = timeit(
        lambda: fused_ingest(*state, *batch, bucket_size=ING_BS,
                             impl="xla"),
        iters=3,
    )
    point = {
        "rows": n,
        "bytes_moved": nbytes,
        "split_xla": tx,
        "split_xla_gbps": _gbps(nbytes, tx["median_s"]),
        "fused_pallas": None,
        "fused_pallas_gbps": None,
    }
    emit("fold", f"ingest_split_N{n}_ms", tx["median_s"] * 1e3, "ms",
         f"{point['split_xla_gbps']:.1f} GB/s of {HBM_BW / 1e9:.0f} peak")
    if native_pallas:
        tp = timeit(
            lambda: fused_ingest(*state, *batch, bucket_size=ING_BS,
                                 impl="pallas"),
            iters=3,
        )
        point["fused_pallas"] = tp
        point["fused_pallas_gbps"] = _gbps(nbytes, tp["median_s"])
        emit("fold", f"ingest_fused_N{n}_ms", tp["median_s"] * 1e3, "ms",
             f"{point['fused_pallas_gbps']:.1f} GB/s of "
             f"{HBM_BW / 1e9:.0f} peak")
    return point


def _parity(rng, native_pallas: bool) -> dict:
    """Bit-exact XLA-vs-Pallas parity, gated on every backend — on CPU
    via interpret mode at a small size (tier-1 covers the 2^17 straddle;
    this keeps the bench itself honest end to end)."""
    interp = not native_pallas
    n_fold = common.scaled(8_192, 1_024)
    x, seg = _fold_inputs(rng, n_fold)
    ref = fold_levels(x, seg, op=FOLD_OP, impl="xla")
    ker = fold_levels(x, seg, op=FOLD_OP, impl="pallas", interpret=interp)
    fold_err = float(np.max(np.abs(np.asarray(ref) - np.asarray(ker))))

    n_ing = common.scaled(2_048, 512)
    nk = max(n_ing // 64, 8)
    batch = _ingest_batch(rng, n_ing, nk, ING_NB * ING_BS, ING_F)
    state = _ingest_state(nk, 64, ING_F, ING_NB, ING_BS)
    out_x = fused_ingest(*state, *batch, bucket_size=ING_BS, impl="xla")
    out_p = fused_ingest(*state, *batch, bucket_size=ING_BS,
                         impl="pallas", interpret=interp)
    ing_err = max(
        float(np.max(np.abs(
            np.asarray(a, np.float64) - np.asarray(b, np.float64)
        )))
        for a, b in zip(out_x, out_p)
    )
    emit("fold", "fold_parity_max_abs_err", fold_err, "abs",
         f"N={n_fold}, interpret={interp}")
    emit("fold", "ingest_parity_max_abs_err", ing_err, "abs",
         f"N={n_ing}, interpret={interp}")
    return {
        "fold_rows": n_fold, "fold_max_abs_err": fold_err,
        "ingest_rows": n_ing, "ingest_max_abs_err": ing_err,
        "interpret": interp,
    }


def run() -> None:
    rng = np.random.default_rng(11)
    backend = jax.default_backend()
    native = backend == "tpu"
    sweep = [20_000] if common.SMOKE else [10**5, 10**6, 10**7]

    results = {
        "backend": backend,
        "smoke": common.SMOKE,
        "pallas_native": native,
        "hbm_peak_gbps": HBM_BW / 1e9,
        "fold_op": FOLD_OP,
        "fold": {},
        "ingest": {},
    }
    for n in sweep:
        results["fold"][f"N{n}"] = _fold_point(rng, n, native)
    for n in sweep:
        results["ingest"][f"N{n}"] = _ingest_point(rng, n, native)
    results["parity"] = _parity(rng, native)

    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("fold", "artifact_points",
         len(results["fold"]) + len(results["ingest"]), "points", OUT_PATH)


if __name__ == "__main__":
    run()
