"""Render EXPERIMENTS.md §Roofline markdown tables from dry-run JSONs.

  PYTHONPATH=src:. python -m benchmarks.roofline_md [--mesh 16x16|2x16x16]
"""

from __future__ import annotations

import argparse
import json
import pathlib
from collections import defaultdict

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

ARCH_ORDER = [
    "nemotron-4-15b", "qwen3-32b", "yi-34b", "phi3-mini-3.8b",
    "mixtral-8x7b", "moonshot-v1-16b-a3b", "rwkv6-3b",
    "seamless-m4t-medium", "phi3-vision-4.2b", "recurrentgemma-9b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load():
    cells = defaultdict(dict)
    for p in sorted(DRYRUN.glob("*.json")):
        parts = p.stem.split("__")
        if len(parts) != 3:
            continue
        arch, shape, tag = parts
        try:
            d = json.loads(p.read_text())
        except json.JSONDecodeError:
            continue
        cells[(arch, shape)][tag] = d
    return cells


def fmt(v, digits=3):
    if v == 0:
        return "0"
    if v < 0.01:
        return f"{v:.1e}"
    return f"{v:.{digits}g}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16", choices=["16x16", "2x16x16"])
    args = ap.parse_args()
    tag = "single" if args.mesh == "16x16" else "multi"

    cells = load()
    print(f"| arch | shape | compute s | memory s | collective s | dominant "
          f"| MODEL_FLOPs/HLO_FLOPs | bytes/device | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = cells.get((arch, shape), {}).get(tag)
            if rec is None:
                continue
            if rec.get("skipped"):
                print(f"| {arch} | {shape} | — | — | — | — | — | — | "
                      f"SKIP: {rec['reason'].split(':')[0]} |")
                continue
            t = rec["roofline"]
            mem = rec.get("memory", {})
            peak = mem.get("peak_estimate_bytes", 0) / 1e9
            note = ""
            print(
                f"| {arch} | {shape} | {fmt(t['compute_s'])} "
                f"| {fmt(t['memory_s'])} | {fmt(t['collective_s'])} "
                f"| {t['dominant']} "
                f"| {rec.get('useful_flops_ratio', 0):.2f} "
                f"| {peak:.1f} GB | {note} |"
            )


if __name__ == "__main__":
    main()
