"""Paper §3.2: millisecond-level feature updates / 720M daily orders.

Measures online-store ingest throughput (rows/s) two ways:

* ``fused``      — one jit'd scatter applying a whole micro-batch
                   (the TPU-native replacement for lock-free CAS),
* ``row_at_a_time`` — one jit call per row (what naive row-locking
                   emulation would cost).

720M orders/day = 8333 rows/s sustained; the fused path exceeds that by
orders of magnitude even on 1 CPU core.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import emit, timeit
from repro.core import Col, FeatureView, OnlineFeatureStore, range_window, w_sum
from repro.data.synthetic import RECO_SCHEMA, reco_stream

NUM_USERS = 256


def run() -> None:
    N = common.scaled(4096, 512)
    rows_single = common.scaled(64, 8)
    rng = np.random.default_rng(1)
    view = FeatureView(
        name="reco_min",
        schema=RECO_SCHEMA,
        features={"spend_1h": w_sum(Col("price") * Col("qty"), range_window(3600, bucket=64))},
    )
    rows = reco_stream(rng, N, num_users=NUM_USERS)
    order = np.lexsort((rows["ts"], rows["user"]))
    rows = {c: v[order] for c, v in rows.items()}

    def fresh_store():
        return OnlineFeatureStore(
            view, num_keys=NUM_USERS, capacity=256, num_buckets=64, bucket_size=64
        )

    store = fresh_store()

    def fused():
        store.ingest(rows)
        return store.state.ring.cursor

    t = timeit(fused, warmup=1, iters=5)
    emit("ingest", "fused_rows_per_s", N / t["median_s"], "rows/s")
    emit("ingest", "fused_batch_ms", t["median_s"] * 1e3, "ms", f"batch={N}")

    store2 = fresh_store()
    one = {c: v[:1] for c, v in rows.items()}

    def row_at_a_time():
        for i in range(rows_single):
            store2.ingest({c: v[i:i + 1] for c, v in rows.items()})
        return store2.state.ring.cursor

    t2 = timeit(row_at_a_time, warmup=1, iters=3)
    emit("ingest", "row_at_a_time_rows_per_s", rows_single / t2["median_s"],
         "rows/s")
    emit(
        "ingest", "vipshop_required_rows_per_s", 720e6 / 86400, "rows/s",
        "720M orders/day sustained",
    )


if __name__ == "__main__":
    run()
