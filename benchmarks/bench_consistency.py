"""Paper §2: offline/online consistency verification throughput.

Runs the mechanized verifier over randomized workloads (all agg kinds,
rows+range windows) and reports rows/s verified and the pass rate.
The paper's point: this step replaces months of manual checking.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro.core import (
    Col, FeatureView, range_window, rows_window,
    w_count, w_max, w_mean, w_min, w_std, w_sum,
)
from repro.core.consistency import verify_view
from repro.data.synthetic import FRAUD_SCHEMA, fraud_stream

ROWS = 1_500
NUM_CARDS = 48


def run() -> None:
    rows = common.scaled(ROWS, 300)
    rng = np.random.default_rng(4)
    cols, _ = fraud_stream(rng, rows, num_cards=NUM_CARDS, t_max=60_000)
    amt = Col("amount")
    view = FeatureView(
        name="verify_bench", schema=FRAUD_SCHEMA,
        features={
            "s1": w_sum(amt, range_window(3600, bucket=64)),
            "m1": w_mean(amt, range_window(3600, bucket=64)),
            "sd": w_std(amt, range_window(7200, bucket=64)),
            "mn": w_min(amt, rows_window(20)),
            "mx": w_max(amt, rows_window(20)),
            "c6": w_count(amt, range_window(21600, bucket=64)),
        },
    )
    n_pass = 0
    t0 = time.perf_counter()
    for mode in ("naive", "preagg"):
        rep = verify_view(
            view, cols, num_keys=NUM_CARDS, num_buckets=512, bucket_size=64,
            mode=mode,
        )
        n_pass += int(rep.passed)
        emit("consistency", f"{mode}_max_rel_err", rep.max_rel_err, "rel",
             rep.summary().replace(",", ";"))
    dt = time.perf_counter() - t0
    emit("consistency", "verified_rows_per_s", 2 * rows / dt, "rows/s")
    emit("consistency", "passed", n_pass, "/2",
         "offline batch == online incremental on identical definitions")


if __name__ == "__main__":
    run()
