"""Paper §2 (pre-aggregation): window computation cost vs window length.

Sweeps the range-window size W and compares per-query work of

* ``naive``  — masked reduction over the raw ring (O(capacity) per query
               regardless of W, but capacity must cover W), vs
* ``preagg`` — bucket-merge (O(W/bucket) partials + O(bucket) tail).

Also validates the Pallas kernels (interpret mode) against the jnp oracles
at each size — the query kernel IS the preagg path on TPU, and the
segmented-combine kernel the offline MIN/MAX scan — and measures the
offline MIN/MAX path at N ∈ {5k, 50k} with compile time reported
*separately* from run time: the old sparse-table formulation compiled
minutes-slow at N >~ 5k (its chained dynamic gathers blew up XLA), which
is why this bench previously avoided MIN/MAX entirely.  The doubling-fold
formulation holds compile to seconds; :func:`compile_budget_check` is the
CI gate that keeps it there.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.common import emit, timeit
from repro.core import Col, FeatureView, OnlineFeatureStore, range_window, w_sum
from repro.data.synthetic import FRAUD_SCHEMA, fraud_stream
from repro.kernels.window_agg.ops import window_stats

NUM_CARDS = 64
Q = 64


def run() -> None:
    rng = np.random.default_rng(5)
    sweep = [(1_000, 4_000), (10_000, 8_000), (100_000, 16_000)]
    if common.SMOKE:
        sweep = [(1_000, 600)]
    for w_size, n_hist in sweep:
        # pre-agg granularity scales with the window (the paper's long-
        # window insight): ~128 partials per window keeps the merge O(1)-ish
        bucket = max(64, w_size // 128)
        view = FeatureView(
            name=f"wagg_{w_size}", schema=FRAUD_SCHEMA,
            features={"s": w_sum(Col("amount"), range_window(w_size, bucket=bucket))},
        )
        cols, _ = fraud_stream(rng, n_hist, num_cards=NUM_CARDS,
                               t_max=4 * w_size)
        order = np.lexsort((cols["ts"], cols["card"]))
        store = OnlineFeatureStore(
            view, num_keys=NUM_CARDS, capacity=1024,
            num_buckets=w_size // bucket + 66, bucket_size=bucket,
        )
        store.ingest({c: v[order] for c, v in cols.items()})
        req = {c: v[-Q:] for c, v in cols.items()}
        req["ts"] = np.full(Q, int(cols["ts"].max()) + 1, np.int32)

        t_naive = timeit(lambda: store.query(req, mode="naive"), iters=5)
        t_pre = timeit(lambda: store.query(req, mode="preagg"), iters=5)
        emit("window_agg", f"naive_W{w_size}_us_per_q",
             t_naive["median_s"] / Q * 1e6, "us")
        emit("window_agg", f"preagg_W{w_size}_us_per_q",
             t_pre["median_s"] / Q * 1e6, "us")

    # offline path: O(N*W) naive masked-gather vs the engine's O(N)
    # segmented-prefix-sum evaluation (this is where the paper's
    # long-window claim bites — cost vs window length)
    import jax
    import jax.numpy as jnp
    from repro.core.windows import (
        segment_starts, sort_by_key_ts, window_start_rows, windowed_aggregate,
    )
    from repro.core.expr import Agg, rows_window as _rw

    N = common.scaled(8192, 1024)
    cols, _ = fraud_stream(rng, N, num_cards=NUM_CARDS, t_max=1 << 20)
    skey, sts, samt, _ = sort_by_key_ts(
        jnp.asarray(cols["card"], jnp.int32), jnp.asarray(cols["ts"], jnp.int32),
        jnp.asarray(cols["amount"]),
    )

    for W in (16,) if common.SMOKE else (16, 128, 1024):
        @jax.jit
        def naive_w(k, x):
            # per row, gather the previous W rows and mask same-key window
            idx = jnp.arange(N)[:, None] - jnp.arange(W)[None, ::-1]  # (N, W)
            ok = idx >= 0
            idxc = jnp.clip(idx, 0, N - 1)
            same = (k[idxc] == k[:, None]) & ok
            return jnp.sum(jnp.where(same, x[idxc], 0.0), axis=1)

        @jax.jit
        def engine_w(k, t, x):
            req = {"s": (Agg.SUM, x, _rw(W), 0)}
            return windowed_aggregate(k, t, req)["s"]

        ref_n = naive_w(skey, samt)
        ref_e = engine_w(skey, sts, samt)
        np.testing.assert_allclose(np.asarray(ref_n), np.asarray(ref_e),
                                   rtol=1e-4, atol=1e-2)
        tn = timeit(lambda: naive_w(skey, samt), iters=5)
        te = timeit(lambda: engine_w(skey, sts, samt), iters=5)
        emit("window_agg", f"offline_naive_W{W}_ms", tn["median_s"] * 1e3, "ms",
             "O(N*W) masked gather")
        emit("window_agg", f"offline_engine_W{W}_ms", te["median_s"] * 1e3, "ms",
             "O(N) segmented prefix sum")

    # offline MIN/MAX at N ∈ {5k, 50k}: compile time vs run time.  These
    # sizes were unusable before the scan-based fold (sparse-table compile
    # took ~150 s at N=5k on CPU XLA; now ~2 s).
    for N_mm in (1_000,) if common.SMOKE else (5_000, 50_000):
        cols, _ = fraud_stream(rng, N_mm, num_cards=NUM_CARDS, t_max=1 << 20)
        skey, sts, samt, _ = sort_by_key_ts(
            jnp.asarray(cols["card"], jnp.int32),
            jnp.asarray(cols["ts"], jnp.int32),
            jnp.asarray(cols["amount"]),
        )

        @jax.jit
        def minmax_w(k, t, x):
            req = {
                "mn": (Agg.MIN, x, range_window(1_000), 0),
                "mx": (Agg.MAX, x, range_window(1_000), 0),
            }
            return windowed_aggregate(k, t, req)

        t0 = time.perf_counter()
        compiled = minmax_w.lower(skey, sts, samt).compile()
        t_compile = time.perf_counter() - t0
        t_run = timeit(
            lambda: jax.block_until_ready(compiled(skey, sts, samt)), iters=5
        )
        emit("window_agg", f"offline_minmax_N{N_mm}_compile_s", t_compile,
             "s", "doubling fold (was ~150s sparse-table at N=5k)")
        emit("window_agg", f"offline_minmax_N{N_mm}_run_ms",
             t_run["median_s"] * 1e3, "ms")

    # Pallas kernel correctness at one representative size (interpret=True)
    view = FeatureView(
        name="wagg_k", schema=FRAUD_SCHEMA,
        features={"s": w_sum(Col("amount"), range_window(2048, bucket=64))},
    )
    cols, _ = fraud_stream(rng, common.scaled(2_000, 600), num_cards=32,
                           t_max=8_192)
    order = np.lexsort((cols["ts"], cols["card"]))
    store = OnlineFeatureStore(view, num_keys=32, capacity=256,
                               num_buckets=64, bucket_size=64)
    store.ingest({c: v[order] for c, v in cols.items()})
    st = store.state
    qk = np.arange(16, dtype=np.int32) % 32
    qt = np.full(16, 8_200, np.int32)
    ql = np.zeros((16, store.num_lanes), np.float32)
    args = (st.ring.ts, st.ring.vals, st.bagg.stats, st.bagg.bucket,
            qk, qt, ql)
    ref = window_stats(*args, windows=(2048,), bucket_size=64, impl="xla")
    ker = window_stats(*args, windows=(2048,), bucket_size=64,
                       impl="pallas", interpret=True)
    err = float(np.max(np.abs(np.asarray(ref) - np.asarray(ker))))
    emit("window_agg", "pallas_vs_ref_max_abs_err", err, "abs",
         "interpret=True on CPU; TPU target")
    assert err < 1e-3, err


def compile_budget_check(n: int = 5_000, budget_s: float = 30.0) -> float:
    """CI gate: offline MIN/MAX at N=``n`` must compile within ``budget_s``.

    The seed's sparse-table formulation took ~150 s here; the scan-based
    fold takes ~2 s.  Asserting the budget keeps the blowup from silently
    regressing (run by scripts/ci.sh).
    """
    import jax
    import jax.numpy as jnp
    from repro.core.expr import Agg
    from repro.core.windows import sort_by_key_ts, windowed_aggregate

    rng = np.random.default_rng(0)
    cols, _ = fraud_stream(rng, n, num_cards=NUM_CARDS, t_max=1 << 20)
    skey, sts, samt, _ = sort_by_key_ts(
        jnp.asarray(cols["card"], jnp.int32),
        jnp.asarray(cols["ts"], jnp.int32),
        jnp.asarray(cols["amount"]),
    )

    @jax.jit
    def minmax_w(k, t, x):
        req = {
            "mn": (Agg.MIN, x, range_window(1_000), 0),
            "mx": (Agg.MAX, x, range_window(1_000), 0),
        }
        return windowed_aggregate(k, t, req)

    t0 = time.perf_counter()
    minmax_w.lower(skey, sts, samt).compile()
    elapsed = time.perf_counter() - t0
    assert elapsed < budget_s, (
        f"offline MIN/MAX at N={n} compiled in {elapsed:.1f}s "
        f"(budget {budget_s:.0f}s) — the sparse-table compile blowup is back"
    )
    print(f"compile_budget_check: N={n} compiled in {elapsed:.1f}s "
          f"(budget {budget_s:.0f}s)")
    return elapsed


if __name__ == "__main__":
    run()
