"""Shared benchmark plumbing: timing, CSV row emission, result registry."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import numpy as np

RESULTS: List[Dict] = []


def block(x):
    return jax.tree.map(
        lambda a: a.block_until_ready() if hasattr(a, "block_until_ready") else a,
        x,
    )


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 5) -> Dict[str, float]:
    """Median wall time of ``fn()`` (which must block on its own result)."""
    for _ in range(warmup):
        block(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        block(fn())
        ts.append(time.perf_counter() - t0)
    ts = sorted(ts)
    return {
        "median_s": ts[len(ts) // 2],
        "best_s": ts[0],
        "mean_s": float(np.mean(ts)),
    }


def emit(bench: str, name: str, value: float, unit: str, note: str = "") -> None:
    RESULTS.append(
        {"bench": bench, "name": name, "value": value, "unit": unit, "note": note}
    )
    print(f"{bench},{name},{value:.6g},{unit},{note}", flush=True)


def header() -> None:
    print("bench,name,value,unit,note", flush=True)
