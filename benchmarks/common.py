"""Shared benchmark plumbing: timing, CSV row emission, result registry.

``--smoke`` mode (set by benchmarks.run, used by scripts/ci.sh): every
bench runs at tiny N with one timing rep — numbers are meaningless, but
the scripts execute end to end on every CI run so they cannot silently
rot.  Benches opt their sizes in via :func:`scaled`.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import numpy as np

RESULTS: List[Dict] = []

SMOKE = False


def set_smoke(on: bool = True) -> None:
    """Enable smoke mode (tiny sizes, single rep) process-wide."""
    global SMOKE
    SMOKE = bool(on)


def scaled(full: int, smoke: int) -> int:
    """Pick a problem size: ``full`` normally, ``smoke`` under --smoke."""
    return smoke if SMOKE else full


def block(x):
    return jax.tree.map(
        lambda a: a.block_until_ready() if hasattr(a, "block_until_ready") else a,
        x,
    )


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 5) -> Dict[str, float]:
    """Median wall time of ``fn()`` (which must block on its own result)."""
    if SMOKE:
        warmup, iters = min(warmup, 1), 1
    for _ in range(warmup):
        block(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        block(fn())
        ts.append(time.perf_counter() - t0)
    ts = sorted(ts)
    return {
        "median_s": ts[len(ts) // 2],
        "best_s": ts[0],
        "mean_s": float(np.mean(ts)),
    }


def emit(bench: str, name: str, value: float, unit: str, note: str = "") -> None:
    RESULTS.append(
        {"bench": bench, "name": name, "value": value, "unit": unit, "note": note}
    )
    print(f"{bench},{name},{value:.6g},{unit},{note}", flush=True)


def header() -> None:
    print("bench,name,value,unit,note", flush=True)
