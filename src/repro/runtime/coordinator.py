"""Cluster coordination: failure detection, elastic rescale, stragglers.

FeatInsight gets HA from ZooKeeper; a TPU training fleet gets it from a
coordinator of exactly this shape.  The container has no real cluster, so
hosts are simulated — the *protocol* is implemented and unit-tested:

* **HeartbeatTracker** — hosts report heartbeats; a host silent for
  ``timeout`` is declared failed (phi-accrual simplified to a hard
  deadline; the clock is injected for determinism).
* **ElasticPlanner** — given surviving hosts and the mesh template,
  produce the largest runnable mesh (shrink the data axis to the largest
  feasible size; the model axis is sacred — TP shards are not
  reconstructible without a full reshard) + the checkpoint-reshard plan.
* **StragglerMonitor** — per-host step-time EWMA; hosts slower than
  ``k x`` the fleet median are flagged for replacement (the scheduler
  drains them at the next checkpoint boundary rather than killing the
  step — synchronous SPMD cannot drop a participant mid-step).
* **TrainSupervisor** — the restart loop: run -> on failure, plan ->
  restore latest checkpoint (resharded) -> continue.  Drives the e2e
  fault-tolerance test in tests/test_runtime.py.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "HeartbeatTracker", "ElasticPlanner", "StragglerMonitor",
    "TrainSupervisor", "MeshTemplate", "RescalePlan",
]


class HeartbeatTracker:
    def __init__(self, hosts: Sequence[str], timeout: float, now: Callable[[], float]):
        self._now = now
        self.timeout = timeout
        self.last: Dict[str, float] = {h: now() for h in hosts}

    def beat(self, host: str) -> None:
        self.last[host] = self._now()

    def failed(self) -> List[str]:
        t = self._now()
        return [h for h, last in self.last.items() if t - last > self.timeout]

    def alive(self) -> List[str]:
        t = self._now()
        return [h for h, last in self.last.items() if t - last <= self.timeout]

    def remove(self, host: str) -> None:
        self.last.pop(host, None)


@dataclasses.dataclass(frozen=True)
class MeshTemplate:
    data: int
    model: int
    pods: int = 1
    hosts_per_data_slice: int = 1  # hosts needed per data-axis unit


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    new_data: int
    new_model: int
    new_pods: int
    dropped_hosts: Tuple[str, ...]
    batch_scale: float          # global batch multiplier (keep per-replica fixed)
    needs_reshard: bool

    @property
    def mesh_shape(self) -> Tuple[int, ...]:
        if self.new_pods > 1:
            return (self.new_pods, self.new_data, self.new_model)
        return (self.new_data, self.new_model)


class ElasticPlanner:
    """Shrink the data axis to fit surviving hosts (powers-of-two ladder)."""

    def __init__(self, template: MeshTemplate):
        self.template = template

    def plan(self, alive_hosts: int, failed: Sequence[str] = ()) -> Optional[RescalePlan]:
        t = self.template
        hosts_needed_per_data = t.hosts_per_data_slice
        max_data = alive_hosts // (hosts_needed_per_data * t.pods)
        data = t.data
        while data > max_data:
            data //= 2
        if data < 1:
            return None  # not enough hosts for even one slice
        return RescalePlan(
            new_data=data,
            new_model=t.model,            # TP untouched
            new_pods=t.pods,
            dropped_hosts=tuple(failed),
            batch_scale=data / t.data,
            needs_reshard=data != t.data,
        )


class StragglerMonitor:
    def __init__(self, threshold: float = 1.5, alpha: float = 0.3):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: Dict[str, float] = {}

    def record(self, host: str, step_time: float) -> None:
        prev = self.ewma.get(host)
        self.ewma[host] = (
            step_time if prev is None
            else self.alpha * step_time + (1 - self.alpha) * prev
        )

    def stragglers(self) -> List[str]:
        if len(self.ewma) < 2:
            return []
        vals = sorted(self.ewma.values())
        median = vals[len(vals) // 2]
        return [
            h for h, v in self.ewma.items() if v > self.threshold * median
        ]


class TrainSupervisor:
    """Checkpoint/restart loop around a step function (simulated hosts).

    run() executes steps; injected failures raise HostFailure; the
    supervisor detects, plans a rescale, restores from the checkpoint
    manager and continues until target_steps.
    """

    class HostFailure(RuntimeError):
        def __init__(self, host: str):
            super().__init__(f"host {host} failed")
            self.host = host

    def __init__(
        self,
        planner: ElasticPlanner,
        ckpt,                       # CheckpointManager-like
        make_state: Callable[[], object],
        step_fn: Callable[[object, int, RescalePlan], object],
        ckpt_every: int = 10,
    ):
        self.planner = planner
        self.ckpt = ckpt
        self.make_state = make_state
        self.step_fn = step_fn
        self.ckpt_every = ckpt_every
        self.events: List[Dict] = []

    def run(self, target_steps: int, total_hosts: int) -> Tuple[object, Dict]:
        alive = total_hosts
        plan = self.planner.plan(alive)
        assert plan is not None
        state = self.make_state()
        step = 0
        restarts = 0
        while step < target_steps:
            try:
                state = self.step_fn(state, step, plan)
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state, blocking=True)
                    self.events.append({"kind": "ckpt", "step": step})
            except TrainSupervisor.HostFailure as f:
                restarts += 1
                alive -= 1
                self.events.append({"kind": "failure", "host": f.host,
                                    "step": step})
                new_plan = self.planner.plan(alive, failed=(f.host,))
                if new_plan is None:
                    raise RuntimeError("insufficient hosts to continue")
                plan = new_plan
                latest = self.ckpt.latest_step()
                if latest is not None:
                    state = self.ckpt.restore(latest, like=state)
                    step = latest
                else:
                    state = self.make_state()
                    step = 0
                self.events.append({
                    "kind": "rescale", "step": step,
                    "mesh": plan.mesh_shape, "reshard": plan.needs_reshard,
                })
        return state, {"restarts": restarts, "final_step": step,
                       "plan": plan, "events": self.events}
