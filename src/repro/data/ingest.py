"""Data import — FeatInsight §3.1 step 1 ("Import Data").

The paper ingests CSV, Hive, SQL INSERT/LOAD DATA, Parquet and single-row
data.  In this container the implemented adapters are:

* ``load_csv``    — CSV files (stdlib csv; schema-driven typing),
* ``load_npz``    — columnar .npz archives (the offline-export format),
* ``insert_rows`` — single/multi row INSERT-equivalent (list of dicts),
* ``load_table``  — format dispatcher (the "Data Import" button).

Hive/Parquet adapters require external services / libraries not present
offline; the dispatcher raises a clear error naming the missing backend so
a deployment can drop in an adapter without touching call sites.

All adapters return a ``columns`` dict (``{name: np.ndarray}``) validated
against a :class:`repro.core.storage.TableSchema` — key/ts as int32,
numeric lanes f32, categorical lanes int32 — the exact layout the offline
engine and online store consume.
"""

from __future__ import annotations

import csv
import io
import pathlib
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Union

import numpy as np

from repro.core.storage import TableSchema

__all__ = ["load_csv", "load_npz", "insert_rows", "load_table", "validate"]

PathLike = Union[str, pathlib.Path]


def _typed(schema: TableSchema, name: str, vals: Sequence) -> np.ndarray:
    if name == schema.key or name == schema.ts:
        return np.asarray(vals, np.int32)
    if name in schema.categorical:
        return np.asarray(vals, np.int32)
    return np.asarray(vals, np.float32)


def validate(schema: TableSchema, columns: Mapping[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Type-check and coerce a columns dict against the schema."""
    need = (schema.key, schema.ts) + tuple(schema.numeric) + tuple(schema.categorical)
    missing = [c for c in need if c not in columns]
    if missing:
        raise ValueError(f"table {schema.name!r}: missing columns {missing}")
    n = len(columns[schema.key])
    out: Dict[str, np.ndarray] = {}
    for c in need:
        arr = _typed(schema, c, columns[c])
        if len(arr) != n:
            raise ValueError(
                f"column {c!r} has {len(arr)} rows, key has {n}"
            )
        out[c] = arr
    return out


def load_csv(
    path_or_text: Union[PathLike, io.StringIO],
    schema: TableSchema,
) -> Dict[str, np.ndarray]:
    """CSV -> columns dict. Header row must name the schema columns."""
    if isinstance(path_or_text, io.StringIO):
        fh = path_or_text
        rows = list(csv.DictReader(fh))
    else:
        with open(path_or_text, newline="") as fh:
            rows = list(csv.DictReader(fh))
    if not rows:
        raise ValueError("empty CSV")
    cols: Dict[str, List] = {c: [] for c in rows[0].keys()}
    for r in rows:
        for c, v in r.items():
            cols[c].append(v)
    typed = {c: _typed(schema, c, np.asarray(v, np.float64)) for c, v in cols.items()}
    return validate(schema, typed)


def load_npz(path: PathLike, schema: TableSchema) -> Dict[str, np.ndarray]:
    with np.load(path) as z:
        return validate(schema, {k: z[k] for k in z.files})


def insert_rows(
    rows: Iterable[Mapping[str, float]],
    schema: TableSchema,
    into: Optional[Dict[str, np.ndarray]] = None,
) -> Dict[str, np.ndarray]:
    """INSERT-equivalent: append rows (dicts) to an existing columns dict."""
    rows = list(rows)
    cols = {c: [r[c] for r in rows] for c in rows[0].keys()}
    new = validate(schema, {c: np.asarray(v) for c, v in cols.items()})
    if into is None:
        return new
    return {
        c: np.concatenate([np.asarray(into[c]), new[c]]) for c in new
    }


_BACKENDS = ("csv", "npz", "rows")


def load_table(
    source, schema: TableSchema, format: str = "csv"
) -> Dict[str, np.ndarray]:
    """Format dispatcher — the paper's multi-format "Data Import"."""
    if format == "csv":
        return load_csv(source, schema)
    if format == "npz":
        return load_npz(source, schema)
    if format == "rows":
        return insert_rows(source, schema)
    if format in ("hive", "parquet", "sql"):
        raise NotImplementedError(
            f"{format!r} import requires an external backend not available "
            f"offline; implement a {format}->columns adapter and register it "
            f"here (available: {_BACKENDS})"
        )
    raise ValueError(f"unknown format {format!r}; available: {_BACKENDS}")
