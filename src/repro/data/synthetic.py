"""Synthetic workload generators for the paper's two demo scenarios plus a
tokenized LM stream for the end-to-end training example.

* ``fraud_stream``  — §3.3: card transactions (key=card id, heavy-tailed
  amounts, bursty timestamps, categorical MCC / device / geo columns).
  Fraud labels follow a planted rule over true window aggregates so a
  model trained on FeatInsight features is actually learnable.
* ``reco_stream``   — §3.2: minute-level order events (user x product),
  the Vipshop-style recommendation workload.
* ``lm_stream``     — token batches for examples/train_lm.py: a synthetic
  integer-sequence language with local structure (Zipf unigrams + copy
  motifs) so cross-entropy visibly decreases within a few hundred steps.
* ``multitable_stream`` — §1's "complex raw data" challenge (the 2018 PHM
  dataset spans 17 tables): a PHM-flavoured multi-table database of card
  transactions (primary) + wire transfers (union stream) + account
  profiles and merchant registries (point-in-time LAST JOIN targets).
"""

from __future__ import annotations

from typing import Dict, Iterator, Tuple

import numpy as np

from repro.core.storage import Database, TableSchema

__all__ = [
    "FRAUD_SCHEMA", "RECO_SCHEMA", "MULTITABLE_DB", "STRESS_DB",
    "fraud_stream", "reco_stream", "lm_stream", "multitable_stream",
    "stress_stream",
]

FRAUD_SCHEMA = TableSchema(
    name="transactions", key="card", ts="ts",
    numeric=("amount",),
    categorical=("mcc", "device", "geo"),
)

RECO_SCHEMA = TableSchema(
    name="orders", key="user", ts="ts",
    numeric=("price", "qty"),
    categorical=("product", "category"),
)

MULTITABLE_DB = Database(
    name="fraud_multitable",
    primary=TableSchema(
        name="transactions", key="account", ts="ts",
        numeric=("amount", "merchant"),
    ),
    secondary=(
        # union stream: same key space + shared "amount" column
        TableSchema(name="wires", key="account", ts="ts", numeric=("amount",)),
        # LAST JOIN targets: slowly-changing profile tables
        TableSchema(
            name="accounts", key="account", ts="ts",
            numeric=("credit_limit", "risk_score"),
        ),
        TableSchema(
            name="merchants", key="merchant", ts="ts",
            numeric=("avg_ticket", "fraud_reports"),
        ),
    ),
)


STRESS_DB = Database(
    name="stress_plane",
    primary=TableSchema(
        name="events", key="entity", ts="ts",
        numeric=("amount", "quantity", "score", "item"),
    ),
    secondary=(
        # union streams in the primary key space; `refunds` shares two
        # numeric columns with the primary (so two-table union args can
        # reference either), `clicks` only `amount` (so three-way unions
        # exercise the schema-compatibility narrowing)
        TableSchema(
            name="refunds", key="entity", ts="ts",
            numeric=("amount", "quantity"),
        ),
        TableSchema(name="clicks", key="entity", ts="ts", numeric=("amount",)),
        # LAST JOIN targets: a profile table keyed like the primary and a
        # dimension registry keyed by the `item` column
        TableSchema(
            name="profiles", key="entity", ts="ts",
            numeric=("tier", "spend_limit"),
        ),
        TableSchema(
            name="items", key="item", ts="ts",
            numeric=("base_price", "popularity"),
        ),
    ),
)


def stress_stream(
    rng: np.random.Generator,
    n: int,
    num_entities: int = 48,
    num_items: int = 24,
    t_max: int = 40_000,
) -> Dict[str, Dict[str, np.ndarray]]:
    """Matched synthetic data for :data:`STRESS_DB` ({table: {col: array}}).

    Built for the stress generator's verification loop: primary
    timestamps are globally unique (window tie-semantics trivially
    well-defined, so offline==online stays exact for order-sensitive
    aggregates), join targets carry a t=0 baseline row for every key plus
    sporadic revisions, and the union streams are ~n/4 and ~n/6 rows in
    the same entity id space.
    """
    ts = (
        np.sort(rng.choice(t_max, size=n, replace=False))
        if n <= t_max
        else np.sort(rng.integers(0, t_max, n))
    ).astype(np.int32)
    events = dict(
        entity=rng.integers(0, num_entities, n).astype(np.int32),
        ts=ts,
        amount=rng.gamma(1.8, 55.0, n).astype(np.float32),
        quantity=rng.integers(1, 9, n).astype(np.float32),
        score=rng.beta(2.0, 5.0, n).astype(np.float32),
        item=rng.integers(0, num_items, n).astype(np.int32),
    )

    nr = max(n // 4, 1)
    refunds = dict(
        entity=rng.integers(0, num_entities, nr).astype(np.int32),
        ts=np.sort(rng.integers(0, t_max, nr)).astype(np.int32),
        amount=rng.gamma(2.0, 80.0, nr).astype(np.float32),
        quantity=rng.integers(1, 5, nr).astype(np.float32),
    )

    nc = max(n // 6, 1)
    clicks = dict(
        entity=rng.integers(0, num_entities, nc).astype(np.int32),
        ts=np.sort(rng.integers(0, t_max, nc)).astype(np.int32),
        amount=rng.gamma(1.2, 10.0, nc).astype(np.float32),
    )

    updates = max(num_entities // 2, 1)
    profiles = dict(
        entity=np.concatenate(
            [np.arange(num_entities), rng.integers(0, num_entities, updates)]
        ).astype(np.int32),
        ts=np.concatenate(
            [np.zeros(num_entities), rng.integers(1, t_max, updates)]
        ).astype(np.int32),
        tier=rng.integers(0, 5, num_entities + updates).astype(np.float32),
        spend_limit=rng.uniform(
            200.0, 10_000.0, num_entities + updates
        ).astype(np.float32),
    )

    refreshes = max(num_items // 2, 1)
    items = dict(
        item=np.concatenate(
            [np.arange(num_items), rng.integers(0, num_items, refreshes)]
        ).astype(np.int32),
        ts=np.concatenate(
            [np.zeros(num_items), rng.integers(1, t_max, refreshes)]
        ).astype(np.int32),
        base_price=rng.gamma(2.0, 30.0, num_items + refreshes).astype(
            np.float32
        ),
        popularity=rng.beta(1.5, 4.0, num_items + refreshes).astype(
            np.float32
        ),
    )
    return {
        "events": events,
        "refunds": refunds,
        "clicks": clicks,
        "profiles": profiles,
        "items": items,
    }


def fraud_stream(
    rng: np.random.Generator, n: int, num_cards: int = 64, t_max: int = 50_000
) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    """Transactions + planted fraud labels.

    Label rule (unknown to the model): fraud when the 1h rolling sum for
    the card exceeds a threshold AND the current amount is itself large —
    i.e. exactly the kind of decision the paper's 784-feature view feeds.
    The rule is stationary (same fraud rate early and late in the stream)
    so train/serve splits see the same distribution.
    """
    card = rng.integers(0, num_cards, n).astype(np.int32)
    ts = np.sort(rng.integers(0, t_max, n)).astype(np.int32)
    amount = rng.gamma(1.5, 60.0, n).astype(np.float32)
    mcc = rng.integers(0, 32, n).astype(np.int32)
    device = rng.integers(0, 8, n).astype(np.int32)
    geo = rng.integers(0, 16, n).astype(np.int32)

    # planted rule over true trailing-3600s sums
    label = np.zeros(n, np.float32)
    hist: Dict[int, list] = {}
    for i in range(n):
        c = int(card[i])
        h = hist.setdefault(c, [])
        h.append((int(ts[i]), float(amount[i])))
        roll = sum(a for (t, a) in h if t > ts[i] - 3600)
        label[i] = 1.0 if (roll > 500.0 and amount[i] > 100.0) else 0.0
    cols = dict(card=card, ts=ts, amount=amount, mcc=mcc, device=device, geo=geo)
    return cols, label


def reco_stream(
    rng: np.random.Generator, n: int, num_users: int = 128,
    num_products: int = 512, t_max: int = 86_400
) -> Dict[str, np.ndarray]:
    """Minute-level order events (Zipf product popularity)."""
    user = rng.integers(0, num_users, n).astype(np.int32)
    ts = np.sort(rng.integers(0, t_max, n)).astype(np.int32)
    product = (rng.zipf(1.3, n) % num_products).astype(np.int32)
    category = (product % 24).astype(np.int32)
    price = rng.gamma(2.0, 25.0, n).astype(np.float32)
    qty = rng.integers(1, 5, n).astype(np.float32)
    return dict(user=user, ts=ts, product=product, category=category,
                price=price, qty=qty)


def multitable_stream(
    rng: np.random.Generator,
    n: int,
    num_accounts: int = 64,
    num_merchants: int = 16,
    t_max: int = 50_000,
) -> Dict[str, Dict[str, np.ndarray]]:
    """Generate the :data:`MULTITABLE_DB` tables ({table: {col: array}}).

    * ``transactions`` — primary card stream: n rows, heavy-tailed amounts,
      per-account unique timestamps (strictly the paper's request-stream
      shape; uniqueness keeps window tie-semantics trivially well-defined).
    * ``wires``        — ~n/4 wire transfers in the same account id space,
      the WINDOW UNION stream.
    * ``accounts``     — profile updates: a t=0 baseline for every account
      plus sporadic limit/risk revisions (slowly-changing dimension).
    * ``merchants``    — merchant registry with periodic stat refreshes.
    """
    # primary: globally unique timestamps => per-key unique, ties impossible
    ts = (
        np.sort(rng.choice(t_max, size=n, replace=False))
        if n <= t_max
        else np.sort(rng.integers(0, t_max, n))
    ).astype(np.int32)
    transactions = dict(
        account=rng.integers(0, num_accounts, n).astype(np.int32),
        ts=ts,
        amount=rng.gamma(1.5, 60.0, n).astype(np.float32),
        merchant=rng.integers(0, num_merchants, n).astype(np.int32),
    )

    nw = max(n // 4, 1)
    wires = dict(
        account=rng.integers(0, num_accounts, nw).astype(np.int32),
        ts=np.sort(rng.integers(0, t_max, nw)).astype(np.int32),
        amount=rng.gamma(2.0, 120.0, nw).astype(np.float32),
    )

    updates = max(num_accounts // 2, 1)
    accounts = dict(
        account=np.concatenate(
            [np.arange(num_accounts), rng.integers(0, num_accounts, updates)]
        ).astype(np.int32),
        ts=np.concatenate(
            [np.zeros(num_accounts), rng.integers(1, t_max, updates)]
        ).astype(np.int32),
        credit_limit=rng.uniform(500.0, 20_000.0, num_accounts + updates).astype(
            np.float32
        ),
        risk_score=rng.beta(2.0, 8.0, num_accounts + updates).astype(np.float32),
    )

    refreshes = max(num_merchants // 2, 1)
    merchants = dict(
        merchant=np.concatenate(
            [np.arange(num_merchants), rng.integers(0, num_merchants, refreshes)]
        ).astype(np.int32),
        ts=np.concatenate(
            [np.zeros(num_merchants), rng.integers(1, t_max, refreshes)]
        ).astype(np.int32),
        avg_ticket=rng.gamma(2.0, 40.0, num_merchants + refreshes).astype(
            np.float32
        ),
        fraud_reports=rng.poisson(2.0, num_merchants + refreshes).astype(
            np.float32
        ),
    )
    return {
        "transactions": transactions,
        "wires": wires,
        "accounts": accounts,
        "merchants": merchants,
    }


def lm_stream(
    rng: np.random.Generator, batch: int, seq_len: int, vocab: int,
) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite stream of {tokens, labels} with learnable local structure."""
    base = np.minimum(
        rng.zipf(1.5, size=(1 << 16,)) % vocab, vocab - 1
    ).astype(np.int32)
    while True:
        toks = np.empty((batch, seq_len + 1), np.int32)
        for b in range(batch):
            start = int(rng.integers(0, len(base) - 2 * seq_len - 2))
            row = base[start:start + seq_len + 1].copy()
            # copy motif: second half repeats a window from the first half
            w = seq_len // 4
            src = int(rng.integers(0, seq_len // 2 - w))
            dst = int(rng.integers(seq_len // 2, seq_len - w))
            row[dst:dst + w] = row[src:src + w]
            toks[b] = row
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
