"""Synthetic workload generators for the paper's two demo scenarios plus a
tokenized LM stream for the end-to-end training example.

* ``fraud_stream``  — §3.3: card transactions (key=card id, heavy-tailed
  amounts, bursty timestamps, categorical MCC / device / geo columns).
  Fraud labels follow a planted rule over true window aggregates so a
  model trained on FeatInsight features is actually learnable.
* ``reco_stream``   — §3.2: minute-level order events (user x product),
  the Vipshop-style recommendation workload.
* ``lm_stream``     — token batches for examples/train_lm.py: a synthetic
  integer-sequence language with local structure (Zipf unigrams + copy
  motifs) so cross-entropy visibly decreases within a few hundred steps.
"""

from __future__ import annotations

from typing import Dict, Iterator, Tuple

import numpy as np

from repro.core.storage import TableSchema

__all__ = [
    "FRAUD_SCHEMA", "RECO_SCHEMA", "fraud_stream", "reco_stream", "lm_stream",
]

FRAUD_SCHEMA = TableSchema(
    name="transactions", key="card", ts="ts",
    numeric=("amount",),
    categorical=("mcc", "device", "geo"),
)

RECO_SCHEMA = TableSchema(
    name="orders", key="user", ts="ts",
    numeric=("price", "qty"),
    categorical=("product", "category"),
)


def fraud_stream(
    rng: np.random.Generator, n: int, num_cards: int = 64, t_max: int = 50_000
) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    """Transactions + planted fraud labels.

    Label rule (unknown to the model): fraud when the 1h rolling sum for
    the card exceeds a threshold AND the current amount is itself large —
    i.e. exactly the kind of decision the paper's 784-feature view feeds.
    The rule is stationary (same fraud rate early and late in the stream)
    so train/serve splits see the same distribution.
    """
    card = rng.integers(0, num_cards, n).astype(np.int32)
    ts = np.sort(rng.integers(0, t_max, n)).astype(np.int32)
    amount = rng.gamma(1.5, 60.0, n).astype(np.float32)
    mcc = rng.integers(0, 32, n).astype(np.int32)
    device = rng.integers(0, 8, n).astype(np.int32)
    geo = rng.integers(0, 16, n).astype(np.int32)

    # planted rule over true trailing-3600s sums
    label = np.zeros(n, np.float32)
    hist: Dict[int, list] = {}
    for i in range(n):
        c = int(card[i])
        h = hist.setdefault(c, [])
        h.append((int(ts[i]), float(amount[i])))
        roll = sum(a for (t, a) in h if t > ts[i] - 3600)
        label[i] = 1.0 if (roll > 500.0 and amount[i] > 100.0) else 0.0
    cols = dict(card=card, ts=ts, amount=amount, mcc=mcc, device=device, geo=geo)
    return cols, label


def reco_stream(
    rng: np.random.Generator, n: int, num_users: int = 128,
    num_products: int = 512, t_max: int = 86_400
) -> Dict[str, np.ndarray]:
    """Minute-level order events (Zipf product popularity)."""
    user = rng.integers(0, num_users, n).astype(np.int32)
    ts = np.sort(rng.integers(0, t_max, n)).astype(np.int32)
    product = (rng.zipf(1.3, n) % num_products).astype(np.int32)
    category = (product % 24).astype(np.int32)
    price = rng.gamma(2.0, 25.0, n).astype(np.float32)
    qty = rng.integers(1, 5, n).astype(np.float32)
    return dict(user=user, ts=ts, product=product, category=category,
                price=price, qty=qty)


def lm_stream(
    rng: np.random.Generator, batch: int, seq_len: int, vocab: int,
) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite stream of {tokens, labels} with learnable local structure."""
    base = np.minimum(
        rng.zipf(1.5, size=(1 << 16,)) % vocab, vocab - 1
    ).astype(np.int32)
    while True:
        toks = np.empty((batch, seq_len + 1), np.int32)
        for b in range(batch):
            start = int(rng.integers(0, len(base) - 2 * seq_len - 2))
            row = base[start:start + seq_len + 1].copy()
            # copy motif: second half repeats a window from the first half
            w = seq_len // 4
            src = int(rng.integers(0, seq_len // 2 - w))
            dst = int(rng.integers(seq_len // 2, seq_len - w))
            row[dst:dst + w] = row[src:src + w]
            toks[b] = row
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
