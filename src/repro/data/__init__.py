"""Data import + synthetic workloads (FeatInsight §3.1 step 1)."""

from repro.data.ingest import insert_rows, load_csv, load_npz, load_table, validate
from repro.data.synthetic import (
    FRAUD_SCHEMA, RECO_SCHEMA, fraud_stream, lm_stream, reco_stream,
)

__all__ = [
    "insert_rows", "load_csv", "load_npz", "load_table", "validate",
    "FRAUD_SCHEMA", "RECO_SCHEMA", "fraud_stream", "lm_stream", "reco_stream",
]
