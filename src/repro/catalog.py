"""Generated feature catalog — the repo's always-in-sync docs layer.

Feature stores live or die by discoverability: a feature that isn't
documented gets rebuilt (slightly differently) by the next team, which is
exactly the drift FeatInsight's lineage/verification machinery exists to
prevent.  So the catalog is *generated from the code*: every canonical
scenario view in :mod:`repro.scenarios` renders itself via
:meth:`~repro.core.view.FeatureView.describe` (source tables, per-column
window/agg lineage, rendered SQL, deploy history), and CI regenerates and
diffs so ``docs/CATALOG.md`` cannot go stale.

Usage::

    python -m repro.catalog            # (re)write docs/CATALOG.md
    python -m repro.catalog --check    # exit 1 if docs/CATALOG.md is stale

Output is deterministic (no wall-clock anywhere) — that's what makes the
regenerate-and-diff gate possible.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

from repro.core.view import FeatureRegistry

CATALOG_PATH = (
    pathlib.Path(__file__).resolve().parents[2] / "docs" / "CATALOG.md"
)

_HEADER = """\
# Feature catalog

> **Generated** by `python -m repro.catalog` from `src/repro/scenarios.py`
> — do not edit by hand.  CI runs `python -m repro.catalog --check` and
> fails when this file is stale.

Every canonical scenario deployed by this reproduction, with its feature
views rendered from the live definitions: source tables and their roles,
per-feature window/aggregation lineage, the OpenMLDB-flavoured SQL, and
the services that deploy each view.
"""


def build_catalog() -> str:
    """Render the full catalog markdown (deterministic)."""
    from repro.scenarios import GENERATED, SCENARIOS

    registry = FeatureRegistry()
    sections = [_HEADER]
    for scen in SCENARIOS.values():
        views = scen.views()
        for v in views:
            registry.register(v)
            if len(views) == 1:
                registry.deploy(f"{scen.name}_svc", v.name, v.version)
            else:
                # the multi-scenario plane deploys every view under one
                # service, tagged per scenario (MultiScenarioService);
                # views the scenario declares as hot-deployed carry the
                # hot-deploy description — the catalog's deploy history
                # records live plane evolutions
                registry.deploy(
                    f"{scen.name}:{v.name}", v.name, v.version,
                    description=(
                        "hot deploy (live plane evolution)"
                        if v.name in scen.hot_deployed
                        else ""
                    ),
                )
            if v.name in scen.exported:
                # the scenario's example also exports a point-in-time
                # training set from these definitions
                # (repro.offline.export_training_set records the same
                # lineage when handed a registry)
                registry.deploy(
                    f"export:{v.name}", v.name, v.version,
                    description=(
                        "point-in-time training-set export "
                        "(offline bridge)"
                    ),
                )
        sections += [
            f"## {scen.title} (`{scen.name}`)",
            "",
            scen.description,
            "",
            f"Run: `{scen.run}`",
            "",
        ]
        if len(views) > 1:
            shared = sorted(
                t
                for t in {tt for v in views for tt in v.tables}
                if sum(t in v.tables for v in views) > 1
            )
            sections += [
                f"Deployed together on one `ScenarioPlane` "
                f"({len(views)} views, one store/mesh); shared tables "
                f"ingested once: {', '.join(f'`{t}`' for t in shared)}.",
                "",
            ]
        for v in views:
            sections.append(v.describe(registry))
    # Generated scenario families render a scale-aware structural census
    # (agg/window/union/join counts + sample entries) instead of 100+
    # full pages — still deterministic, so the staleness gate holds.
    for fam in GENERATED.values():
        sections += [
            f"## {fam.title} (`{fam.name}`)",
            "",
            fam.description,
            "",
            f"Run: `{fam.run}`",
            "",
            fam.summary_md(),
            "",
        ]
    return "\n".join(sections).rstrip() + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="(re)generate or verify docs/CATALOG.md"
    )
    ap.add_argument(
        "--check",
        action="store_true",
        help="regenerate-and-diff: exit 1 if docs/CATALOG.md is stale",
    )
    ap.add_argument(
        "--out", default=str(CATALOG_PATH), help="output path override"
    )
    args = ap.parse_args(argv)
    out = pathlib.Path(args.out)
    fresh = build_catalog()
    if args.check:
        current = out.read_text() if out.exists() else ""
        if current != fresh:
            print(
                f"STALE: {out} does not match the generated catalog; "
                "run `python -m repro.catalog`",
                file=sys.stderr,
            )
            return 1
        print(f"catalog up to date: {out}")
        return 0
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(fresh)
    print(f"wrote {out} ({len(fresh.splitlines())} lines)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
