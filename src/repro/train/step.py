"""Train-step builder: microbatched gradient accumulation + AdamW/ZeRO-1.

Microbatching bounds activation memory (scan-over-layers remat saves one
(tokens, d_model) carry per layer per live microbatch); the gradient
accumulator is kept in a configurable dtype (bf16 default: at 16-256
microbatches the stochastic rounding noise is far below gradient noise,
and it halves the accumulator footprint that dominates device memory for
the 30B-class cells).

Compute/communication overlap: the per-microbatch backward produces
data-axis partial gradients; XLA's latency-hiding scheduler overlaps the
automatically-inserted all-reduces with the next microbatch's compute
because the accumulation scan carries only the accumulator (no barrier).
Optional cross-pod int8 error-feedback compression (optim/compress.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.optim.compress import ef_compress_tree
from repro.sharding.api import current_mesh, current_rules
from repro.sharding.params import param_specs, zero1_spec

__all__ = ["TrainSettings", "build_train_step"]


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    num_microbatches: int = 1
    grad_dtype: str = "bfloat16"
    compress_pod_grads: bool = False
    opt: AdamWConfig = AdamWConfig()


def build_train_step(
    model, cfg: ModelConfig, settings: TrainSettings
) -> Callable:
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics).  Pure; jit/pjit-ready."""

    nm = settings.num_microbatches
    gdt = jnp.dtype(settings.grad_dtype)

    def constrain_gacc(cfg_, gacc):
        """ZeRO-2-style accumulation: pin the gradient accumulator to the
        param spec + a data(-and-pod) shard on the first free divisible dim.
        GSPMD then reduces each microbatch's gradient with a reduce-scatter
        into the sharded accumulator instead of a full all-reduce — halves
        per-microbatch reduction bytes, which is what crosses pods on the
        multi-pod mesh (EXPERIMENTS.md §Perf Y1)."""
        mesh, rules = current_mesh(), current_rules()
        if mesh is None or not rules or not rules.get("grad_accum"):
            return gacc
        axes = rules["grad_accum"]
        axes = tuple(
            a for a in ((axes,) if isinstance(axes, str) else axes)
            if a in mesh.shape
        )
        if not axes:
            return gacc
        specs = param_specs(gacc, cfg_, rules, mesh)

        def f(g, sp):
            sp2 = zero1_spec(sp, g.shape, mesh, data_axes=axes)
            return jax.lax.with_sharding_constraint(
                g, jax.sharding.NamedSharding(mesh, sp2)
            )

        return jax.tree.map(f, gacc, specs)

    def split_micro(batch: Dict) -> Dict:
        def f(x):
            b = x.shape[0]
            assert b % nm == 0, (b, nm)
            return x.reshape(nm, b // nm, *x.shape[1:])
        return jax.tree.map(f, batch)

    def train_step(params, opt_state, batch):
        if nm == 1:
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True
            )(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            micro = split_micro(batch)
            gacc0 = constrain_gacc(cfg, jax.tree.map(
                lambda p: jnp.zeros(p.shape, gdt), params
            ))

            def body(gacc, mb):
                (l, m), g = jax.value_and_grad(
                    model.loss, has_aux=True
                )(params, mb)
                gacc = jax.tree.map(
                    lambda a, gg: a + gg.astype(gdt), gacc, g
                )
                return constrain_gacc(cfg, gacc), (l, m["nll"])

            gacc, (losses, nlls) = jax.lax.scan(body, gacc0, micro)
            grads = jax.tree.map(
                lambda g: g.astype(jnp.float32) / nm, gacc
            )
            loss = losses.mean()
            metrics = {"nll": nlls.mean()}

        if settings.compress_pod_grads:
            grads, residual = ef_compress_tree(grads, opt_state["ef_residual"])
        new_params, new_opt, opt_metrics = adamw_update(
            settings.opt, grads, opt_state, cfg.pdtype
        )
        if settings.compress_pod_grads:
            new_opt["ef_residual"] = residual
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return new_params, new_opt, metrics

    return train_step
