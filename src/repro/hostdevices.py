"""Force a multi-device CPU platform before jax initializes.

XLA only honours ``--xla_force_host_platform_device_count`` if it is set
before the backend is created, so callers (tests/conftest.py, bench and
example entrypoints) must import this module and call
:func:`force_host_devices` before their first ``import jax``.  This
module itself must therefore stay jax-free.
"""

from __future__ import annotations

import os
import sys

__all__ = ["force_host_devices"]


def force_host_devices(n: int = 8) -> bool:
    """Append ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS.

    No-op (returns False) if jax is already imported — too late to take
    effect — or if the user's XLA_FLAGS already pins an explicit device
    count (respected).  Returns True if this call set the flag.
    """
    if "jax" in sys.modules:
        return False
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return False
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}"
    ).strip()
    return True
