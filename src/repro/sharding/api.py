"""Logical-axis sharding rules (MaxText-style), mesh-agnostic model code.

Models annotate activations/params with *logical* axis names
("batch", "seq", "d_model", "d_ff", "heads", "kv_heads", "vocab",
"experts", ...).  A rules table maps logical names to mesh axes; the same
model code runs unsharded on one CPU device (rules empty -> no-op) and
fully sharded on the production mesh (rules installed by the launcher).

Rules are installed with ``use_rules`` (context manager) so tests,
smoke runs and the dry-run can each pick their own mapping without
threading a mesh through every call.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "use_rules",
    "current_rules",
    "current_mesh",
    "logical_constraint",
    "logical_spec",
    "named_sharding",
    "DEFAULT_RULES",
    "MULTI_POD_RULES",
]

MeshAxes = Union[str, Tuple[str, ...], None]

# single-pod (16, 16) ("data", "model") production rules
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("data",),
    "seq": None,
    "d_model": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "kv_head_dim": "model",   # decode caches: shard the head_dim lane
    "d_ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ff": None,
    "opt_state": "data",      # ZeRO-1: optimizer state sharded over data
    "seq_shard": "data",      # SP cells: shard sequence over data axis
}

# multi-pod (2, 16, 16) ("pod", "data", "model"): pod is outer DP
MULTI_POD_RULES: Dict[str, MeshAxes] = {
    **DEFAULT_RULES,
    "batch": ("pod", "data"),
}


class _State(threading.local):
    def __init__(self):
        self.rules: Optional[Dict[str, MeshAxes]] = None
        self.mesh: Optional[Mesh] = None


_STATE = _State()


@contextlib.contextmanager
def use_rules(rules: Dict[str, MeshAxes], mesh: Optional[Mesh] = None):
    prev = (_STATE.rules, _STATE.mesh)
    _STATE.rules, _STATE.mesh = rules, mesh
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh = prev


def current_rules() -> Optional[Dict[str, MeshAxes]]:
    return _STATE.rules


def current_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def logical_spec(
    logical_axes: Sequence[Optional[str]],
    rules: Optional[Dict[str, MeshAxes]] = None,
) -> P:
    """Map logical axis names to a PartitionSpec under the active rules."""
    rules = rules if rules is not None else (_STATE.rules or {})
    parts = []
    for name in logical_axes:
        if name is None:
            parts.append(None)
        else:
            parts.append(rules.get(name))
    return P(*parts)


def logical_constraint(x: jnp.ndarray, *logical_axes: Optional[str]):
    """with_sharding_constraint by logical axis names; no-op without rules.

    Divisibility guard: a mesh-axis mapping is dropped (replicated) when the
    corresponding dim is not divisible by the mesh axis size — e.g. yi-34b's
    56 heads on a 16-way model axis fall back to replication and GSPMD
    shards the fused projections instead.
    """
    rules, mesh = _STATE.rules, _STATE.mesh
    if rules is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    parts = []
    for dim, name in zip(x.shape, logical_axes):
        axes = rules.get(name) if name is not None else None
        if axes is None:
            parts.append(None)
            continue
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        size = 1
        if mesh is not None:
            for a in axes_t:
                size *= mesh.shape[a]
        if size > 1 and dim % size != 0:
            parts.append(None)
        else:
            parts.append(axes)
    return jax.lax.with_sharding_constraint(x, P(*parts))


def named_sharding(mesh: Mesh, *logical_axes: Optional[str]) -> NamedSharding:
    return NamedSharding(mesh, logical_spec(logical_axes))
