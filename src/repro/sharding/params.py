"""Parameter / optimizer-state PartitionSpecs by leaf path.

Megatron-style tensor parallelism on the "model" axis:
  * column-parallel: qkv projections, mlp w_in/w_gate   (shard output dim)
  * row-parallel:    attn wo, mlp w_out                 (shard input dim)
  * vocab-parallel:  embedding table / untied head
  * expert-parallel: MoE expert dim when divisible (moonshot 64/16),
                     else expert-FFN d_ff sharding (mixtral 8<16 -> TP-MoE)

Leading layer-stack dims get None.  Every mapping passes a divisibility
guard — non-divisible dims fall back to replication and GSPMD shards the
surrounding einsums (yi-34b's 56 heads).

ZeRO-1 (`zero1_spec`): optimizer-state leaves additionally shard their
first still-free divisible dim over "data".
"""

from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

__all__ = ["param_specs", "zero1_spec", "tree_named_shardings"]


# (path regex, trailing-dim logical axes) — first match wins.
def _patterns(cfg: ModelConfig):
    model_divides_experts = (
        cfg.num_experts > 0
    )
    pats = [
        (r"embed/table$", ("vocab", None)),
        (r"embed/head$", (None, "vocab")),
        # attention projections (incl. griffin local attn, encdec self/cross)
        (r"(attn|self_attn|cross_attn)/wq$", (None, "fused_heads")),
        (r"(attn|self_attn|cross_attn)/wk$", (None, "fused_heads")),
        (r"(attn|self_attn|cross_attn)/wv$", (None, "fused_heads")),
        (r"(attn|self_attn|cross_attn)/wo$", ("fused_heads", None)),
        # dense MLPs
        (r"mlp\d*/w_in$", (None, "d_ff")),
        (r"mlp\d*/w_gate$", (None, "d_ff")),
        (r"mlp\d*/w_out$", ("d_ff", None)),
        # MoE
        (r"moe/router$", (None, None)),
        (r"moe/w_in$", ("experts", None, "expert_ff")),
        (r"moe/w_gate$", ("experts", None, "expert_ff")),
        (r"moe/w_out$", ("experts", "expert_ff", None)),
        # rwkv time-mix / channel-mix
        (r"tm/w_[rkvg]$", (None, "d_ff")),      # D x D, shard outputs
        (r"tm/w_o$", ("d_ff", None)),
        (r"tm/w_lora_a$", (None, None)),
        (r"tm/w_lora_b$", (None, None)),
        (r"cm/w_k$", (None, "d_ff")),
        (r"cm/w_v$", ("d_ff", None)),
        (r"cm/w_r$", (None, "d_ff")),
        # griffin recurrent block
        (r"rec\d*/w_in$", (None, "d_ff")),
        (r"rec\d*/w_gate$", (None, "d_ff")),
        (r"rec\d*/conv_w$", (None, "d_ff")),
        (r"rec\d*/w_a$", (None, "d_ff")),
        (r"rec\d*/w_x$", (None, "d_ff")),
        (r"rec\d*/w_out$", ("d_ff", None)),
        (r"rec\d*/lam$", ("d_ff",)),
        (r"rec\d*/b_[ax]$", ("d_ff",)),
    ]
    return [(re.compile(p), ax) for p, ax in pats]


def _axis_size(mesh: Optional[Mesh], axes) -> int:
    if mesh is None or axes is None:
        return 1
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    n = 1
    for a in axes_t:
        n *= mesh.shape[a]
    return n


def _spec_for(
    path: str, shape: Tuple[int, ...], cfg: ModelConfig,
    rules: Dict, mesh: Optional[Mesh], pats,
) -> P:
    for pat, axes in pats:
        if pat.search(path):
            trailing = list(axes)
            lead = len(shape) - len(trailing)
            if lead < 0:
                return P()
            logical = [None] * lead + trailing
            parts = []
            for dim, name in zip(shape, logical):
                mapped = rules.get(name) if name else None
                if mapped is None:
                    parts.append(None)
                    continue
                if dim % _axis_size(mesh, mapped) != 0:
                    parts.append(None)  # divisibility guard
                else:
                    parts.append(mapped)
            return P(*parts)
    return P()  # norms, biases, mu vectors, u bonus, router: replicate


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(
    params_tree, cfg: ModelConfig, rules: Dict, mesh: Optional[Mesh],
):
    """PartitionSpec pytree matching ``params_tree`` (arrays or SDS)."""
    pats = _patterns(cfg)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(
            _path_str(path), leaf.shape, cfg, rules, mesh, pats
        ),
        params_tree,
    )


def zero1_spec(spec: P, shape: Tuple[int, ...], mesh: Optional[Mesh],
               data_axes="data") -> P:
    """Add "data" sharding on the first free divisible dim (ZeRO-1)."""
    if mesh is None:
        return spec
    dsize = _axis_size(mesh, data_axes)
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, cur) in enumerate(zip(shape, parts)):
        if cur is None and dim % dsize == 0 and dim >= dsize:
            parts[i] = data_axes
            return P(*parts)
    return P(*parts)


def opt_state_specs(param_spec_tree, params_tree, mesh: Optional[Mesh]):
    """Specs for {master, m, v, step} given param specs (ZeRO-1)."""
    z = jax.tree.map(
        lambda sp, leaf: zero1_spec(sp, leaf.shape, mesh),
        param_spec_tree, params_tree,
    )
    return {"master": z, "m": z, "v": z, "step": P()}


def tree_named_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
