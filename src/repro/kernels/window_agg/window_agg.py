"""Pre-aggregated multi-window query kernel (Pallas TPU).

FeatInsight's hot path: a request row arrives; every feature of the view
needs (sum, count, min, max, sumsq) over several RANGE windows of the
request key's history.  The skiplist walk of the CPU system becomes, on
TPU:

* the query's per-key ring row and bucket-aggregate row are selected by a
  **scalar-prefetched index map** — q_key is prefetched into SMEM before
  the grid step so the DMA engine can fetch exactly the (1, C, L) ring
  tile and (1, NB, L, 5) bucket tile for that key into VMEM (no gather op
  in the kernel body, no host round-trip);
* all windows and all lanes are evaluated from that single VMEM-resident
  tile in one grid step — the "parallelize window operations on the same
  table" optimization of the paper, expressed as vector ops over the
  (C, L) tile;
* middle buckets are selected by *membership* (b_lo < id < b_q) rather
  than enumeration, so the bucket ring needs no modular walk.

Grid: (Q,) — one query per step; Q queries pipeline their DMAs.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["window_stats_pallas", "fold_levels_pallas"]

_TS_EMPTY = -2147483648  # python literal: kernels must not capture device constants
_POS_INF = 3.0e38
_NEG_INF = -3.0e38


def _window_agg_kernel(
    qkey_ref, qts_ref,              # scalar prefetch (SMEM)
    ts_ref, lanes_ref, bstats_ref, bbucket_ref, qlanes_ref,
    out_ref,
    *,
    windows: Sequence[int],
    bucket_size: int,
):
    i = pl.program_id(0)
    ts_q = qts_ref[i]
    B = jnp.int32(bucket_size)

    ts = ts_ref[0]          # (C,)
    g = lanes_ref[0]        # (C, L)
    bstats = bstats_ref[0]  # (NB, L, 5)
    bids = bbucket_ref[0]   # (NB,)
    ql = qlanes_ref[0]      # (L,)

    valid = ts != _TS_EMPTY
    bucket_row = ts // B
    not_future = ts <= ts_q

    for wi, T in enumerate(windows):
        T = jnp.int32(T)
        lo = ts_q - T + 1
        b_q = ts_q // B
        b_lo = (ts_q - T) // B
        in_lo = ts >= lo
        head = valid & not_future & in_lo & (bucket_row == b_lo) & (b_lo != b_q)
        tail = valid & not_future & in_lo & (bucket_row == b_q)
        raw = (head | tail)[:, None]  # (C, 1)
        rawf = raw.astype(jnp.float32)

        s_sum = jnp.sum(g * rawf, axis=0) + ql
        s_cnt = jnp.sum(jnp.broadcast_to(rawf, g.shape), axis=0) + 1.0
        s_min = jnp.minimum(
            jnp.min(jnp.where(raw, g, _POS_INF), axis=0), ql
        )
        s_max = jnp.maximum(
            jnp.max(jnp.where(raw, g, _NEG_INF), axis=0), ql
        )
        s_sq = jnp.sum(g * g * rawf, axis=0) + ql * ql

        mid = ((bids > b_lo) & (bids < b_q))[:, None]  # (NB, 1)
        midf = mid.astype(jnp.float32)
        m_sum = jnp.sum(bstats[..., 0] * midf, axis=0)
        m_cnt = jnp.sum(bstats[..., 1] * midf, axis=0)
        m_min = jnp.min(jnp.where(mid, bstats[..., 2], _POS_INF), axis=0)
        m_max = jnp.max(jnp.where(mid, bstats[..., 3], _NEG_INF), axis=0)
        m_sq = jnp.sum(bstats[..., 4] * midf, axis=0)

        out_ref[0, wi] = jnp.stack(
            [
                s_sum + m_sum,
                s_cnt + m_cnt,
                jnp.minimum(s_min, m_min),
                jnp.maximum(s_max, m_max),
                s_sq + m_sq,
            ],
            axis=-1,
        ).astype(out_ref.dtype)


def window_stats_pallas(
    ring_ts: jnp.ndarray,      # (K, C) int32
    ring_lanes: jnp.ndarray,   # (K, C, L) f32
    bagg_stats: jnp.ndarray,   # (K, NB, L, 5) f32
    bagg_bucket: jnp.ndarray,  # (K, NB) int32
    q_key: jnp.ndarray,        # (Q,) int32
    q_ts: jnp.ndarray,         # (Q,) int32
    q_lanes: jnp.ndarray,      # (Q, L) f32
    *,
    windows: Sequence[int],
    bucket_size: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns (Q, NW, L, 5)."""
    K, C = ring_ts.shape
    L = ring_lanes.shape[-1]
    NB = bagg_bucket.shape[1]
    Q = q_key.shape[0]
    NW = len(windows)

    kernel = functools.partial(
        _window_agg_kernel, windows=tuple(windows), bucket_size=bucket_size
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Q,),
        in_specs=[
            pl.BlockSpec((1, C), lambda i, qk, qt: (qk[i], 0)),
            pl.BlockSpec((1, C, L), lambda i, qk, qt: (qk[i], 0, 0)),
            pl.BlockSpec((1, NB, L, 5), lambda i, qk, qt: (qk[i], 0, 0, 0)),
            pl.BlockSpec((1, NB), lambda i, qk, qt: (qk[i], 0)),
            pl.BlockSpec((1, L), lambda i, qk, qt: (i, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, NW, L, 5), lambda i, qk, qt: (i, 0, 0, 0)
        ),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, NW, L, 5), jnp.float32),
        interpret=interpret,
    )(q_key, q_ts, ring_ts, ring_lanes, bagg_stats, bagg_bucket, q_lanes)


# ---------------------------------------------------------------------------
# Segmented-combine fold levels (offline scan hot loop)
# ---------------------------------------------------------------------------

_FOLD_LANE = 128  # TPU lane width; rows are stored flat as (R, 128) tiles


def _fold_ident(op: str, dtype):
    if op == "min":
        return jnp.asarray(_POS_INF, dtype)
    if op == "max":
        return jnp.asarray(_NEG_INF, dtype)
    return jnp.zeros((), dtype)


def _fold_combine(op: str):
    return {"min": jnp.minimum, "max": jnp.maximum, "or": jnp.bitwise_or}[op]


def _flat_shift(a: jnp.ndarray, d: int, fill) -> jnp.ndarray:
    """Shift a flat row-major (R, LANE) array right by ``d`` positions,
    filling with ``fill`` — static pads/slices/concats only (Mosaic-
    friendly; a gather here is what blew up the old XLA formulation)."""
    rows, lanes = a.shape
    rshift, lshift = divmod(d, lanes)
    if rshift:
        a = jnp.concatenate(
            [jnp.full((rshift, lanes), fill, a.dtype), a[: rows - rshift]],
            axis=0,
        )
    if lshift:
        carry = jnp.concatenate(
            [jnp.full((1, lanes), fill, a.dtype), a[:-1]], axis=0
        )
        a = jnp.concatenate(
            [carry[:, lanes - lshift:], a[:, : lanes - lshift]], axis=1
        )
    return a


def _fold_levels_kernel(
    x_ref, seg_ref, out_ref, cur_ref, src_ref, rsem, wsem,
    *, op: str, levels: int, tile_rows: int,
):
    """Grid-tiled doubling levels of the segmented combine.

    The row axis is tiled over the grid: grid step ``t`` owns flat rows
    ``[t*TR, (t+1)*TR)``.  Only the active tile is VMEM-resident — the
    (TR, 128) x/seg input blocks stream HBM→VMEM through the BlockSpec
    pipeline (double-buffered across steps), while the (levels, R, 128)
    output stays in HBM (``memory_space=ANY``) and is written one
    (TR, 128) tile per level by an explicit DMA.

    The inter-tile boundary combine rides the sequential TPU grid: level
    ``k`` of every earlier tile is already in the HBM output when step
    ``t`` runs, so the shifted source for distance ``2^k`` is fetched
    back from ``out[k]`` by a second DMA.  Three static cases per level
    (the shift distance is a python constant):

    * ``2^k < 128`` — a lane shift whose carry row is the last row of
      tile ``t-1``: one 1-row DMA;
    * ``128 <= 2^k < TR*128`` — an exact row shift by ``2^k/128`` rows
      straddling tiles ``t-1``/``t``: DMA the straddle rows, concat with
      the resident tile;
    * ``2^k >= TR*128`` — the source is exactly tile ``t - 2^k/(TR*128)``
      (both powers of two): DMA the whole tile.

    Every fetch is guarded by ``pl.when`` on the source tile existing;
    elements whose true source precedes the array (idx - 2^k < 0) are
    masked to the identity by the segment guard (seg >= 0 always), so
    skipped DMAs can never leak scratch garbage into a live value.
    """
    t = pl.program_id(0)
    TR = tile_rows
    ident = _fold_ident(op, x_ref.dtype)
    f = _fold_combine(op)
    seg = seg_ref[...]
    row = jax.lax.broadcasted_iota(jnp.int32, (TR, _FOLD_LANE), 0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (TR, _FOLD_LANE), 1)
    idx = (t * TR + row) * _FOLD_LANE + lane
    cur = x_ref[...]
    for k in range(levels):
        # publish level k of this tile; later steps read it back from HBM
        cur_ref[...] = cur
        put = pltpu.make_async_copy(
            cur_ref, out_ref.at[k, pl.ds(t * TR, TR)], wsem
        )
        put.start()
        put.wait()
        if k == levels - 1:
            break
        half = 1 << k
        if half < _FOLD_LANE:
            # lane shift; carry row = out[k] row t*TR - 1 (tile t-1)
            @pl.when(t > 0)
            def _fetch_carry():
                get = pltpu.make_async_copy(
                    out_ref.at[k, pl.ds(t * TR - 1, 1)],
                    src_ref.at[pl.ds(0, 1)],
                    rsem,
                )
                get.start()
                get.wait()

            prev = jnp.concatenate([src_ref[0:1], cur[:-1]], axis=0)
            shifted = jnp.concatenate(
                [prev[:, _FOLD_LANE - half:], cur[:, : _FOLD_LANE - half]],
                axis=1,
            )
        elif (rshift := half // _FOLD_LANE) < TR:
            # row shift straddling tile t-1: fetch its last rshift rows
            @pl.when(t > 0)
            def _fetch_straddle():
                get = pltpu.make_async_copy(
                    out_ref.at[k, pl.ds(t * TR - rshift, rshift)],
                    src_ref.at[pl.ds(0, rshift)],
                    rsem,
                )
                get.start()
                get.wait()

            shifted = jnp.concatenate(
                [src_ref[0:rshift], cur[: TR - rshift]], axis=0
            )
        else:
            # whole-tile shift: the source is exactly tile t - q
            q = rshift // TR

            @pl.when(t >= q)
            def _fetch_tile():
                get = pltpu.make_async_copy(
                    out_ref.at[k, pl.ds((t - q) * TR, TR)], src_ref, rsem
                )
                get.start()
                get.wait()

            shifted = src_ref[...]
        cur = f(cur, jnp.where(idx - half >= seg, shifted, ident))


def fold_levels_pallas(
    x2: jnp.ndarray,    # (R, 128) padded row-major values, R % tile_rows == 0
    seg2: jnp.ndarray,  # (R, 128) int32 padded segment starts
    *,
    op: str,
    levels: int,
    tile_rows: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns (levels, R, 128) doubling-fold levels (grid-tiled rows)."""
    R = x2.shape[0]
    if R % tile_rows or tile_rows % 8 or tile_rows & (tile_rows - 1):
        raise ValueError(
            f"fold tile_rows must be a pow2 multiple of 8 dividing R "
            f"(got tile_rows={tile_rows}, R={R})"
        )
    kernel = functools.partial(
        _fold_levels_kernel, op=op, levels=levels, tile_rows=tile_rows
    )
    return pl.pallas_call(
        kernel,
        grid=(R // tile_rows,),
        in_specs=[
            pl.BlockSpec((tile_rows, _FOLD_LANE), lambda t: (t, 0)),
            pl.BlockSpec((tile_rows, _FOLD_LANE), lambda t: (t, 0)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((levels, R, _FOLD_LANE), x2.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_rows, _FOLD_LANE), x2.dtype),
            pltpu.VMEM((tile_rows, _FOLD_LANE), x2.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(x2, seg2)
