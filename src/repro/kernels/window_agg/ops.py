"""jit'd wrapper for the pre-aggregated window-stats kernel.

``window_stats(...)`` computes (Q, NW, L, 5) stat vectors for a batch of
request rows against an online store's state, dispatching between the
Pallas kernel and the jnp reference.  Finalization (mean/std/...) is done
by the caller (``OnlineFeatureStore`` / benchmarks) — the kernel's contract
is the composable stat vector, which is what pre-aggregation preserves.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels.window_agg.ref import window_stats_ref
from repro.kernels.window_agg.window_agg import window_stats_pallas

__all__ = ["window_stats"]


@functools.partial(
    jax.jit, static_argnames=("windows", "bucket_size", "impl", "interpret")
)
def window_stats(
    ring_ts: jnp.ndarray,
    ring_lanes: jnp.ndarray,
    bagg_stats: jnp.ndarray,
    bagg_bucket: jnp.ndarray,
    q_key: jnp.ndarray,
    q_ts: jnp.ndarray,
    q_lanes: jnp.ndarray,
    *,
    windows: Sequence[int],
    bucket_size: int,
    impl: str = "auto",
    interpret: bool = False,
) -> jnp.ndarray:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return window_stats_ref(
            ring_ts, ring_lanes, bagg_stats, bagg_bucket,
            q_key, q_ts, q_lanes,
            windows=tuple(windows), bucket_size=bucket_size,
        )
    return window_stats_pallas(
        ring_ts, ring_lanes, bagg_stats, bagg_bucket,
        q_key, q_ts, q_lanes,
        windows=tuple(windows), bucket_size=bucket_size,
        interpret=interpret,
    )
