"""jit'd wrappers for the window-aggregation kernels.

* ``window_stats(...)`` computes (Q, NW, L, 5) stat vectors for a batch of
  request rows against an online store's state, dispatching between the
  Pallas kernel and the jnp reference.  Finalization (mean/std/...) is done
  by the caller (``OnlineFeatureStore`` / benchmarks) — the kernel's
  contract is the composable stat vector, which is what pre-aggregation
  preserves.
* ``fold_levels(...)`` computes the doubling levels of a segmented
  idempotent combine (min/max/or) — the hot loop of the offline engine's
  windowed MIN/MAX/DISTINCT scan (``windows.segmented_windowed_fold``).
  The Pallas kernel keeps all levels VMEM-resident; the jnp reference is
  the CPU/XLA fallback and is built from the same static shifts (so both
  compile in seconds where the old gather-chain formulation took minutes).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels.window_agg.ref import (
    fold_identity,
    fold_levels_ref,
    fold_num_levels,
    window_stats_ref,
)
from repro.kernels.window_agg.window_agg import (
    _FOLD_LANE,
    fold_levels_pallas,
    window_stats_pallas,
)

__all__ = ["window_stats", "fold_levels"]

# beyond this many rows the stacked levels outgrow a single core's VMEM
# budget; fall back to the (identically-formulated) XLA path
_FOLD_PALLAS_MAX_ROWS = 1 << 17


@functools.partial(
    jax.jit, static_argnames=("windows", "bucket_size", "impl", "interpret")
)
def window_stats(
    ring_ts: jnp.ndarray,
    ring_lanes: jnp.ndarray,
    bagg_stats: jnp.ndarray,
    bagg_bucket: jnp.ndarray,
    q_key: jnp.ndarray,
    q_ts: jnp.ndarray,
    q_lanes: jnp.ndarray,
    *,
    windows: Sequence[int],
    bucket_size: int,
    impl: str = "auto",
    interpret: bool = False,
) -> jnp.ndarray:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return window_stats_ref(
            ring_ts, ring_lanes, bagg_stats, bagg_bucket,
            q_key, q_ts, q_lanes,
            windows=tuple(windows), bucket_size=bucket_size,
        )
    return window_stats_pallas(
        ring_ts, ring_lanes, bagg_stats, bagg_bucket,
        q_key, q_ts, q_lanes,
        windows=tuple(windows), bucket_size=bucket_size,
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("op", "impl", "interpret"))
def fold_levels(
    x: jnp.ndarray,    # (N,) f32 (min/max) or int32 (or)
    seg: jnp.ndarray,  # (N,) int32 segment-start index per row
    *,
    op: str,
    impl: str = "auto",
    interpret: bool = False,
) -> jnp.ndarray:
    """Doubling levels of the segmented combine: (KL, N).

    Level k row i = op over rows [max(i - 2^k + 1, seg_i), i].  KL =
    floor(log2(N)) + 1, enough for any in-segment range query via binary
    decomposition (see ``windows.segmented_windowed_fold``).
    """
    n = x.shape[0]
    levels = fold_num_levels(n)
    if impl == "auto":
        impl = (
            "pallas"
            if jax.default_backend() == "tpu" and n <= _FOLD_PALLAS_MAX_ROWS
            else "xla"
        )
    if impl == "xla":
        return fold_levels_ref(x, seg, op)

    # pad the flat rows out to whole (8, 128) f32 tiles; padded rows start
    # their own segments (seg = own index) so they never leak backwards,
    # and real rows never look forward — the pad is inert.
    lane = _FOLD_LANE
    rows = -(-n // lane)
    rows += (-rows) % 8
    m = rows * lane
    ident = fold_identity(op, x.dtype)
    xp = jnp.full((m,), ident, x.dtype).at[:n].set(x)
    segp = jnp.arange(m, dtype=jnp.int32).at[:n].set(seg)
    out = fold_levels_pallas(
        xp.reshape(rows, lane),
        segp.reshape(rows, lane),
        op=op,
        levels=levels,
        interpret=interpret,
    )
    return out.reshape(levels, m)[:, :n]
