"""jit'd wrappers for the window-aggregation kernels.

* ``window_stats(...)`` computes (Q, NW, L, 5) stat vectors for a batch of
  request rows against an online store's state, dispatching between the
  Pallas kernel and the jnp reference.  Finalization (mean/std/...) is done
  by the caller (``OnlineFeatureStore`` / benchmarks) — the kernel's
  contract is the composable stat vector, which is what pre-aggregation
  preserves.
* ``fold_levels(...)`` computes the doubling levels of a segmented
  idempotent combine (min/max/or) — the hot loop of the offline engine's
  windowed MIN/MAX/DISTINCT scan (``windows.segmented_windowed_fold``).
  The Pallas kernel keeps all levels VMEM-resident; the jnp reference is
  the CPU/XLA fallback and is built from the same static shifts (so both
  compile in seconds where the old gather-chain formulation took minutes).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import note_dispatch, vmem_row_budget
from repro.kernels.window_agg.ref import (
    fold_identity,
    fold_levels_ref,
    fold_num_levels,
    window_stats_ref,
)
from repro.kernels.window_agg.window_agg import (
    _FOLD_LANE,
    fold_levels_pallas,
    window_stats_pallas,
)

__all__ = ["window_stats", "fold_levels", "FOLD_TILE_ROWS"]

# Rows each fold grid step keeps VMEM-resident.  Live (TR, 128) arrays in
# the kernel body: the pipelined x and seg input blocks (×2 each for the
# double buffer), the cur/src scratch tiles, and ~6 body temporaries
# (iotas, shift concats, mask, combine) → 12.  The kernel STREAMS tiles,
# so this sizes the tile — there is no whole-input row cap any more.
FOLD_TILE_ROWS = vmem_row_budget(12)


def _pow2ceil(v: int) -> int:
    return 1 << (max(v, 1) - 1).bit_length()


@functools.partial(
    jax.jit, static_argnames=("windows", "bucket_size", "impl", "interpret")
)
def _window_stats(
    ring_ts: jnp.ndarray,
    ring_lanes: jnp.ndarray,
    bagg_stats: jnp.ndarray,
    bagg_bucket: jnp.ndarray,
    q_key: jnp.ndarray,
    q_ts: jnp.ndarray,
    q_lanes: jnp.ndarray,
    *,
    windows: Sequence[int],
    bucket_size: int,
    impl: str = "auto",
    interpret: bool = False,
) -> jnp.ndarray:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return window_stats_ref(
            ring_ts, ring_lanes, bagg_stats, bagg_bucket,
            q_key, q_ts, q_lanes,
            windows=tuple(windows), bucket_size=bucket_size,
        )
    return window_stats_pallas(
        ring_ts, ring_lanes, bagg_stats, bagg_bucket,
        q_key, q_ts, q_lanes,
        windows=tuple(windows), bucket_size=bucket_size,
        interpret=interpret,
    )


def window_stats(
    ring_ts: jnp.ndarray,
    ring_lanes: jnp.ndarray,
    bagg_stats: jnp.ndarray,
    bagg_bucket: jnp.ndarray,
    q_key: jnp.ndarray,
    q_ts: jnp.ndarray,
    q_lanes: jnp.ndarray,
    *,
    windows: Sequence[int],
    bucket_size: int,
    impl: str = "auto",
    interpret: bool = False,
) -> jnp.ndarray:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    note_dispatch("window_stats", impl)
    return _window_stats(
        ring_ts, ring_lanes, bagg_stats, bagg_bucket,
        q_key, q_ts, q_lanes,
        windows=tuple(windows), bucket_size=bucket_size,
        impl=impl, interpret=interpret,
    )


def _resolve_fold_impl(n: int, backend: str, impl: str = "auto") -> str:
    """``impl="auto"`` policy for ``fold_levels``.

    The grid-tiled kernel streams row tiles through VMEM, so the policy is
    backend-only: Pallas on TPU at ANY size (the old 2^17-row VMEM cap is
    gone), the identically-formulated XLA reference elsewhere.  ``n`` stays
    a parameter so the policy remains a function of the call, not a global
    — and so tests can pin the no-cap contract at 2^17±1 and 10^7 rows.
    """
    del n  # no size cutoff: tiling makes every size VMEM-feasible
    if impl == "auto":
        return "pallas" if backend == "tpu" else "xla"
    return impl


def fold_levels(
    x: jnp.ndarray,    # (N,) f32 (min/max) or int32 (or)
    seg: jnp.ndarray,  # (N,) int32 segment-start index per row
    *,
    op: str,
    impl: str = "auto",
    interpret: bool = False,
    tile_rows: Optional[int] = None,
) -> jnp.ndarray:
    """Doubling levels of the segmented combine: (KL, N).

    Level k row i = op over rows [max(i - 2^k + 1, seg_i), i].  KL =
    floor(log2(N)) + 1, enough for any in-segment range query via binary
    decomposition (see ``windows.segmented_windowed_fold``).

    ``tile_rows`` overrides the grid tile height (pow2 multiple of 8) —
    tests force small tiles to exercise multi-tile boundary carries in
    interpret mode without 10^6-row inputs.
    """
    impl = _resolve_fold_impl(x.shape[0], jax.default_backend(), impl)
    note_dispatch("fold_levels", impl)
    return _fold_levels(
        x, seg, op=op, impl=impl, interpret=interpret,
        tile_rows=FOLD_TILE_ROWS if tile_rows is None else tile_rows,
    )


@functools.partial(
    jax.jit, static_argnames=("op", "impl", "interpret", "tile_rows")
)
def _fold_levels(
    x: jnp.ndarray,
    seg: jnp.ndarray,
    *,
    op: str,
    impl: str,
    interpret: bool,
    tile_rows: int,
) -> jnp.ndarray:
    n = x.shape[0]
    levels = fold_num_levels(n)
    if impl == "xla":
        return fold_levels_ref(x, seg, op)

    # pad the flat rows out to whole grid tiles; padded rows start their
    # own segments (seg = own index) so they never leak backwards, and
    # real rows never look forward — the pad is inert.  Single-tile inputs
    # shrink the tile to the pow2 cover of the rows instead of padding all
    # the way up to the streaming tile height.
    lane = _FOLD_LANE
    rows = -(-n // lane)
    tr = min(tile_rows, max(_pow2ceil(rows), 8))
    rows = -(-rows // tr) * tr
    m = rows * lane
    ident = fold_identity(op, x.dtype)
    xp = jnp.full((m,), ident, x.dtype).at[:n].set(x)
    segp = jnp.arange(m, dtype=jnp.int32).at[:n].set(seg)
    out = fold_levels_pallas(
        xp.reshape(rows, lane),
        segp.reshape(rows, lane),
        op=op,
        levels=levels,
        tile_rows=tr,
        interpret=interpret,
    )
    return out.reshape(levels, m)[:, :n]
