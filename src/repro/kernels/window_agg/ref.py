"""Pure-jnp oracle for the pre-aggregated window query kernel.

Given the online store's ring buffers + bucket pre-aggregates and a batch
of request rows, compute for every (query, window, lane) the five-stat
vector (sum, count, min, max, sumsq) over the RANGE window ending at the
request (inclusive of the request row) — the exact semantics of
``OnlineFeatureStore._query_pure_preagg``.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp

POS_INF = jnp.float32(3.0e38)
NEG_INF = jnp.float32(-3.0e38)

__all__ = ["window_stats_ref", "POS_INF", "NEG_INF"]


def window_stats_ref(
    ring_ts: jnp.ndarray,      # (K, C) int32 (slot order arbitrary)
    ring_lanes: jnp.ndarray,   # (K, C, L) f32
    bagg_stats: jnp.ndarray,   # (K, NB, L, 5) f32
    bagg_bucket: jnp.ndarray,  # (K, NB) int32 (-1 empty)
    q_key: jnp.ndarray,        # (Q,) int32
    q_ts: jnp.ndarray,         # (Q,) int32
    q_lanes: jnp.ndarray,      # (Q, L) f32 request-row lane values
    windows: Sequence[int],
    bucket_size: int,
) -> jnp.ndarray:
    """Returns (Q, NW, L, 5) composed stats."""
    B = jnp.int32(bucket_size)
    ts = ring_ts[q_key]          # (Q, C)
    lanes = ring_lanes[q_key]    # (Q, C, L)
    bstats = bagg_stats[q_key]   # (Q, NB, L, 5)
    bids = bagg_bucket[q_key]    # (Q, NB)
    valid = ts != jnp.int32(-2147483648)
    bucket_row = ts // B

    outs = []
    for T in windows:
        T = jnp.int32(T)
        lo = q_ts - T + 1
        b_q = q_ts // B
        b_lo = (q_ts - T) // B
        not_future = ts <= q_ts[:, None]
        in_lo = ts >= lo[:, None]
        head = (
            valid & not_future & in_lo
            & (bucket_row == b_lo[:, None]) & (b_lo != b_q)[:, None]
        )
        tail = valid & not_future & in_lo & (bucket_row == b_q[:, None])
        raw = head | tail
        rawf = raw.astype(jnp.float32)[..., None]  # (Q, C, 1)

        g = lanes
        s_raw = jnp.stack(
            [
                (g * rawf).sum(axis=1) + q_lanes,
                rawf.sum(axis=1) + 1.0,
                jnp.minimum(
                    jnp.where(rawf > 0, g, POS_INF).min(axis=1), q_lanes
                ),
                jnp.maximum(
                    jnp.where(rawf > 0, g, NEG_INF).max(axis=1), q_lanes
                ),
                (g * g * rawf).sum(axis=1) + q_lanes * q_lanes,
            ],
            axis=-1,
        )  # (Q, L, 5)

        mid_ok = (bids > b_lo[:, None]) & (bids < b_q[:, None])  # (Q, NB)
        mo = mid_ok[..., None, None]
        s_mid = jnp.stack(
            [
                jnp.where(mo[..., 0], bstats[..., 0], 0.0).sum(axis=1),
                jnp.where(mo[..., 0], bstats[..., 1], 0.0).sum(axis=1),
                jnp.where(mo[..., 0], bstats[..., 2], POS_INF).min(axis=1),
                jnp.where(mo[..., 0], bstats[..., 3], NEG_INF).max(axis=1),
                jnp.where(mo[..., 0], bstats[..., 4], 0.0).sum(axis=1),
            ],
            axis=-1,
        )  # (Q, L, 5)

        s = jnp.stack(
            [
                s_raw[..., 0] + s_mid[..., 0],
                s_raw[..., 1] + s_mid[..., 1],
                jnp.minimum(s_raw[..., 2], s_mid[..., 2]),
                jnp.maximum(s_raw[..., 3], s_mid[..., 3]),
                s_raw[..., 4] + s_mid[..., 4],
            ],
            axis=-1,
        )
        outs.append(s)
    return jnp.stack(outs, axis=1)  # (Q, NW, L, 5)
