"""Pure-jnp oracles for the window-aggregation kernels.

* :func:`window_stats_ref` — the pre-aggregated multi-window query: given
  the online store's ring buffers + bucket pre-aggregates and a batch of
  request rows, compute for every (query, window, lane) the five-stat
  vector (sum, count, min, max, sumsq) over the RANGE window ending at the
  request (inclusive of the request row) — the exact semantics of
  ``OnlineFeatureStore``'s pre-agg query path.
* :func:`fold_levels_ref` — the offline segmented-combine scan: all
  doubling levels of a segmented idempotent fold (min / max / bitwise-or),
  the hot loop of ``windows.segmented_windowed_fold``.  Level ``k`` holds
  the combine over ``[max(i - 2^k + 1, seg_start_i), i]`` for every row;
  each level is one *static* shift (pad + slice — never a gather, which is
  what made the old sparse-table formulation compile minutes-slow) plus
  one elementwise combine.
"""

from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax.numpy as jnp

POS_INF = jnp.float32(3.0e38)
NEG_INF = jnp.float32(-3.0e38)

__all__ = [
    "window_stats_ref",
    "fold_levels_ref",
    "fold_num_levels",
    "fold_identity",
    "fold_op",
    "POS_INF",
    "NEG_INF",
]


# segmented idempotent combines the fold kernel supports
_FOLD_OPS = {
    "min": jnp.minimum,
    "max": jnp.maximum,
    "or": jnp.bitwise_or,
}


def fold_op(op: str):
    return _FOLD_OPS[op]


def fold_identity(op: str, dtype) -> jnp.ndarray:
    if op == "min":
        return POS_INF.astype(dtype)
    if op == "max":
        return NEG_INF.astype(dtype)
    if op == "or":
        return jnp.zeros((), dtype)
    raise ValueError(f"unknown fold op {op!r}")


def fold_num_levels(n: int) -> int:
    """Number of doubling levels for ``n`` rows (level 0 = the rows)."""
    return max(1, int(math.floor(math.log2(max(n, 1)))) + 1)


def fold_levels_ref(
    x: jnp.ndarray,    # (N,) f32 (min/max) or int32 (or)
    seg: jnp.ndarray,  # (N,) int32 — each row's key-segment start index
    op: str,
) -> jnp.ndarray:
    """Returns (KL, N): level k = op over [max(i - 2^k + 1, seg_i), i]."""
    n = x.shape[0]
    ident = fold_identity(op, x.dtype)
    f = _FOLD_OPS[op]
    idx = jnp.arange(n, dtype=jnp.int32)
    levels = [x]
    k = 0
    while (1 << (k + 1)) <= max(n, 1):
        half = 1 << k
        prev = levels[-1]
        shifted = jnp.concatenate(
            [jnp.full((half,), ident, x.dtype), prev[:-half]]
        )
        shifted = jnp.where(idx - half >= seg, shifted, ident)
        levels.append(f(prev, shifted))
        k += 1
    return jnp.stack(levels, 0)


def window_stats_ref(
    ring_ts: jnp.ndarray,      # (K, C) int32 (slot order arbitrary)
    ring_lanes: jnp.ndarray,   # (K, C, L) f32
    bagg_stats: jnp.ndarray,   # (K, NB, L, 5) f32
    bagg_bucket: jnp.ndarray,  # (K, NB) int32 (-1 empty)
    q_key: jnp.ndarray,        # (Q,) int32
    q_ts: jnp.ndarray,         # (Q,) int32
    q_lanes: jnp.ndarray,      # (Q, L) f32 request-row lane values
    windows: Sequence[int],
    bucket_size: int,
) -> jnp.ndarray:
    """Returns (Q, NW, L, 5) composed stats."""
    B = jnp.int32(bucket_size)
    ts = ring_ts[q_key]          # (Q, C)
    lanes = ring_lanes[q_key]    # (Q, C, L)
    bstats = bagg_stats[q_key]   # (Q, NB, L, 5)
    bids = bagg_bucket[q_key]    # (Q, NB)
    valid = ts != jnp.int32(-2147483648)
    bucket_row = ts // B

    outs = []
    for T in windows:
        T = jnp.int32(T)
        lo = q_ts - T + 1
        b_q = q_ts // B
        b_lo = (q_ts - T) // B
        not_future = ts <= q_ts[:, None]
        in_lo = ts >= lo[:, None]
        head = (
            valid & not_future & in_lo
            & (bucket_row == b_lo[:, None]) & (b_lo != b_q)[:, None]
        )
        tail = valid & not_future & in_lo & (bucket_row == b_q[:, None])
        raw = head | tail
        rawf = raw.astype(jnp.float32)[..., None]  # (Q, C, 1)

        g = lanes
        s_raw = jnp.stack(
            [
                (g * rawf).sum(axis=1) + q_lanes,
                rawf.sum(axis=1) + 1.0,
                jnp.minimum(
                    jnp.where(rawf > 0, g, POS_INF).min(axis=1), q_lanes
                ),
                jnp.maximum(
                    jnp.where(rawf > 0, g, NEG_INF).max(axis=1), q_lanes
                ),
                (g * g * rawf).sum(axis=1) + q_lanes * q_lanes,
            ],
            axis=-1,
        )  # (Q, L, 5)

        mid_ok = (bids > b_lo[:, None]) & (bids < b_q[:, None])  # (Q, NB)
        mo = mid_ok[..., None, None]
        s_mid = jnp.stack(
            [
                jnp.where(mo[..., 0], bstats[..., 0], 0.0).sum(axis=1),
                jnp.where(mo[..., 0], bstats[..., 1], 0.0).sum(axis=1),
                jnp.where(mo[..., 0], bstats[..., 2], POS_INF).min(axis=1),
                jnp.where(mo[..., 0], bstats[..., 3], NEG_INF).max(axis=1),
                jnp.where(mo[..., 0], bstats[..., 4], 0.0).sum(axis=1),
            ],
            axis=-1,
        )  # (Q, L, 5)

        s = jnp.stack(
            [
                s_raw[..., 0] + s_mid[..., 0],
                s_raw[..., 1] + s_mid[..., 1],
                jnp.minimum(s_raw[..., 2], s_mid[..., 2]),
                jnp.maximum(s_raw[..., 3], s_mid[..., 3]),
                s_raw[..., 4] + s_mid[..., 4],
            ],
            axis=-1,
        )
        outs.append(s)
    return jnp.stack(outs, axis=1)  # (Q, NW, L, 5)
