"""Flash attention for TPU (Pallas): causal / sliding-window, GQA-aware.

Block-wise online-softmax attention tiled for VMEM:

* grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the last axis is
  sequential on TPU, so the running (max, denom, accum) state lives in VMEM
  scratch across kv steps of one q block.
* GQA without materializing broadcast KV: the kv BlockSpec's index_map
  folds the q-head -> kv-head mapping (h // group), so HBM holds KV once.
* Sliding-window masking skips fully-out-of-window kv blocks structurally
  (mask only; XLA grid is static) — the FLOPs still execute for skipped
  blocks in this static-grid formulation, which is the correct trade on
  TPU for moderate windows (dynamic grids cost more than masked MACs).
* MXU alignment: block_q and block_k default to 128; head_dim is padded to
  a multiple of 128 lanes by ops.py when needed.

Validated on CPU with interpret=True against ref.py (pure jnp).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention_pallas"]

# jax renamed TPUCompilerParams -> CompilerParams around 0.4.38; accept both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG_INF = -1.0e30


def flash_attention_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_k: int,
    seq_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)

    # sanitize rows of partial kv blocks: OOB reads are undefined and would
    # otherwise poison p @ v through 0 * NaN
    kv_valid = (
        ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_k, 1), 0)
        < seq_len
    )
    v = jnp.where(kv_valid, v, 0.0)
    k = jnp.where(kv_valid, k, 0.0)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    mask = k_pos < seq_len
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]          # (bq,)
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (all -inf): exp(-inf - -inf) -> use 0
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(
        m_prev == _NEG_INF, 0.0, jnp.exp(m_prev - m_new)
    )
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, H, S, D)
    k: jnp.ndarray,  # (B, Hkv, S, D)
    v: jnp.ndarray,  # (B, Hkv, S, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq = pl.cdiv(S, block_q)
    nk = pl.cdiv(S, block_k)

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        flash_attention_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        seq_len=S,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, D),
                lambda b, h, qi, ki: (b, h // group, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, D),
                lambda b, h, qi, ki: (b, h // group, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
