"""jit'd public wrapper for flash attention with automatic fallback.

``attention(...)`` dispatches:
* ``impl="pallas"``     — the Pallas TPU kernel (interpret=True on CPU);
* ``impl="xla"``        — the pure-jnp reference (used by the dry-run path,
                          where XLA's fused attention is the object of
                          roofline study);
* ``impl="auto"``       — pallas on TPU backends, xla elsewhere.

Head-dim padding: the kernel wants lane-aligned D; if D % 128 != 0 we pad
q/k/v with zeros (attention output is unaffected: padded q·k lanes add 0).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["attention"]


def _pad_d(x: jnp.ndarray, mult: int = 128) -> jnp.ndarray:
    d = x.shape[-1]
    pad = (-d) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0),) * (x.ndim - 1) + ((0, pad),))


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "scale", "impl", "block_q", "block_k", "interpret"
    ),
)
def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    impl: str = "auto",
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return attention_ref(q, k, v, causal=causal, window=window, scale=scale)
    d0 = q.shape[-1]
    if scale is None:
        scale = 1.0 / (d0 ** 0.5)
    qp, kp, vp = _pad_d(q), _pad_d(k), _pad_d(v)
    out = flash_attention_pallas(
        qp, kp, vp,
        causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out[..., :d0]
