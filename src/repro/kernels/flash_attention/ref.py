"""Pure-jnp oracle for flash attention (causal / sliding window / GQA)."""

from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(
    q: jnp.ndarray,  # (B, H, S, D)
    k: jnp.ndarray,  # (B, Hkv, S, D)
    v: jnp.ndarray,  # (B, Hkv, S, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk",
        q.astype(jnp.float32),
        kk.astype(jnp.float32),
    ) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
