# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

"""Shared VMEM-budget accounting for the ``impl="auto"`` dispatchers.

Every Pallas TPU kernel in this package keeps some per-row working set
resident in VMEM (~16 MiB per core).  Whether a given call fits — and,
for grid-tiled kernels, how many rows each grid step may keep resident —
is the SAME calculation everywhere: count the (rows, 128) f32/i32 arrays
the kernel body holds live at once, multiply by the row stride, divide
the budget.  Each dispatcher states its own array count (that part is
kernel knowledge); the budget arithmetic lives here so no dispatcher
hides a magic row cap.

Used by :mod:`repro.kernels.window_agg.ops` (grid tile sizing — the fold
kernel streams tiles, so there is no row *cap*, only a tile size) and
:mod:`repro.kernels.route.ops` (whole-batch residency cap).
"""

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM, TPU v4/v5-class parts

KERNEL_LANE = 128  # native f32/i32 lane width; kernel rows are (8, 128) tiles


def vmem_row_budget(
    live_arrays: int,
    bytes_per_elem: int = 4,
    lane: int = KERNEL_LANE,
    budget: int = VMEM_BYTES,
) -> int:
    """Largest power-of-two row count whose working set fits ``budget``.

    ``live_arrays`` is the number of (rows, lane) arrays the kernel holds
    live at once — pipelined input blocks count twice (double buffering),
    scratch and output tiles once each, plus the body's largest
    simultaneous set of temporaries.  Power-of-two so shape buckets and
    grid tilings stay pow2-aligned (compile caching, exact row shifts).
    """
    per_row = max(live_arrays, 1) * lane * bytes_per_elem
    rows = budget // per_row
    if rows <= 0:
        return 0
    return 1 << (rows.bit_length() - 1)


def note_dispatch(kernel: str, impl: str) -> None:
    """Count an ``impl="auto"`` resolution into ``kernel_dispatch_total``.

    Every kernel entry point records which implementation it actually
    dispatched — a silent XLA fallback on TPU is exactly the regression
    this metric exists to surface.  Called from the un-jitted dispatch
    wrappers, so under an outer ``jit`` it counts once per trace (the
    decision is trace-time anyway); from host-driven call sites it counts
    per call.
    """
    from repro.obs.telemetry import get_telemetry

    get_telemetry().metrics.counter(
        "kernel_dispatch_total",
        "kernel entry-point dispatches by resolved implementation",
        "1",
        labels=("kernel", "impl"),
    ).inc(1.0, kernel=kernel, impl=impl)
