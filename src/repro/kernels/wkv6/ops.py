"""jit'd wrapper for the RWKV6 time-mix core.

``wkv6(...)`` dispatches between the Pallas kernel (TPU target, interpret
on CPU tests) and an XLA chunked implementation (same factorization,
vectorized with vmap over chunks) used by the dry-run/model path.  Padding:
T is padded to a multiple of the chunk with identity rows (r=k=0, lw=0),
which leave both y and the carried state untouched.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.ref import LOG_W_MIN
from repro.kernels.wkv6.wkv6 import CHUNK, wkv6_pallas

__all__ = ["wkv6"]


def _wkv6_xla_chunked(r, k, v, lw, u, s0, chunk):
    """Same chunk factorization as the kernel, as one lax.scan over chunks."""
    B, H, T, D = r.shape
    nc = T // chunk
    f32 = jnp.float32
    rc = r.astype(f32).reshape(B, H, nc, chunk, D)
    kc = k.astype(f32).reshape(B, H, nc, chunk, D)
    vc = v.astype(f32).reshape(B, H, nc, chunk, D)
    lwc = jnp.clip(lw.astype(f32), LOG_W_MIN, 0.0).reshape(B, H, nc, chunk, D)

    cum = jnp.cumsum(lwc, axis=3)
    cum_prev = cum - lwc
    r_t = rc * jnp.exp(cum_prev)
    k_t = kc * jnp.exp(-cum)
    A = jnp.einsum("bhcti,bhcai->bhcta", r_t, k_t)
    t_pos = jnp.arange(chunk)[:, None]
    a_pos = jnp.arange(chunk)[None, :]
    A = jnp.where(a_pos < t_pos, A, 0.0)
    y_intra = jnp.einsum("bhcta,bhcad->bhctd", A, vc)
    diag_coef = jnp.sum(rc * u[None, :, None, None, :] * kc, axis=-1)
    y_local = y_intra + diag_coef[..., None] * vc

    decay_last = jnp.exp(cum[:, :, :, -1])              # (B,H,nc,D)
    kv = jnp.einsum("bhcai,bhcad->bhcid", k_t, vc)      # (B,H,nc,D,D)

    def step(S, xs):
        r_t_c, y_local_c, decay_c, kv_c = xs
        y = jnp.einsum("bhti,bhid->bhtd", r_t_c, S) + y_local_c
        S_new = decay_c[..., :, None] * (S + kv_c)
        return S_new, y

    xs = (
        jnp.moveaxis(r_t, 2, 0),
        jnp.moveaxis(y_local, 2, 0),
        jnp.moveaxis(decay_last, 2, 0),
        jnp.moveaxis(kv, 2, 0),
    )
    S_fin, ys = jax.lax.scan(step, s0.astype(f32), xs)
    y = jnp.moveaxis(ys, 0, 2).reshape(B, H, T, D)
    return y.astype(r.dtype), S_fin


@functools.partial(jax.jit, static_argnames=("impl", "chunk", "interpret"))
def wkv6(
    r: jnp.ndarray,    # (B, H, T, D)
    k: jnp.ndarray,
    v: jnp.ndarray,
    lw: jnp.ndarray,   # (B, H, T, D) log decay (clamped internally)
    u: jnp.ndarray,    # (H, D)
    s0: jnp.ndarray | None = None,
    *,
    impl: str = "auto",
    chunk: int = CHUNK,
    interpret: bool = False,
):
    """Returns (y (B,H,T,D), final_state (B,H,D,D))."""
    B, H, T, D = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, D, D), jnp.float32)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"

    pad = (-T) % chunk
    if pad:
        def padt(x, fill=0.0):
            return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)),
                           constant_values=fill)
        r, k, v, lw = padt(r), padt(k), padt(v), padt(lw)

    if impl == "pallas":
        y, s_fin = wkv6_pallas(
            r, k, v, lw, u, s0, chunk=chunk, interpret=interpret
        )
    else:
        y, s_fin = _wkv6_xla_chunked(r, k, v, lw, u, s0, chunk)
    if pad:
        y = y[:, :, :T]
    return y, s_fin
