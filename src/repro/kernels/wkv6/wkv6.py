"""Chunked RWKV6 linear-attention scan (Pallas TPU).

The recurrence  S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t,  y_t = r_t·(S_{t-1}
+ u⊙k_t⊗v_t)  is a per-channel exponentially-decayed running aggregate —
structurally the same two-level decomposition as FeatInsight's window
pre-aggregation: *intra-chunk* contributions are computed in parallel on
the MXU, *inter-chunk* state is carried like a bucket pre-aggregate.

Factorization per chunk (size c, positions t, a; channels i):

    cum_t   = Σ_{s<=t} lw_s                      (in-chunk log-decay prefix)
    r~_t    = r_t ⊙ exp(cum_{t-1})
    k~_a    = k_a ⊙ exp(-cum_a)
    y_t     = r~_t @ S0  +  Σ_{a<t} (r~_t·k~_a) v_a  +  (r_t⊙u·k_t) v_t
    S_next  = diag(exp(cum_last)) S0 + diag(exp(cum_last)) (k~ᵀ @ v)

exp(-cum_a) grows with chunk depth; lw is clamped to [LOG_W_MIN, 0]
(see ref.py) so the max exponent is c·|LOG_W_MIN| = 16·3.5 = 56 < 88
(f32 overflow), making the factorization exact in range.

Grid: (B, H, T/c) — the chunk axis is sequential ("arbitrary"), carrying
S in a VMEM scratch accumulator; B and H are parallel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.wkv6.ref import LOG_W_MIN

__all__ = ["wkv6_pallas", "CHUNK"]

# jax renamed TPUCompilerParams -> CompilerParams around 0.4.38; accept both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

CHUNK = 16


def _wkv6_kernel(
    r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
    y_ref, sout_ref,
    s_scratch,
    *,
    chunk: int,
):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_scratch[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)    # (c, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = jnp.clip(lw_ref[0, 0].astype(jnp.float32), LOG_W_MIN, 0.0)
    u = u_ref[0].astype(jnp.float32)       # (D,)

    cum = jnp.cumsum(lw, axis=0)           # inclusive prefix (c, D)
    cum_prev = cum - lw                    # exclusive prefix
    r_t = r * jnp.exp(cum_prev)
    k_t = k * jnp.exp(-cum)

    S = s_scratch[...]                     # (D, D)
    y_cross = jax.lax.dot_general(
        r_t, S, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                       # (c, D)

    A = jax.lax.dot_general(
        r_t, k_t, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                       # (c, c): A[t, a]
    t_pos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    a_pos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(a_pos < t_pos, A, 0.0)   # strict lower triangle
    y_intra = jax.lax.dot_general(
        A, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    diag_coef = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)  # (c,1)
    y = y_cross + y_intra + diag_coef * v

    decay_last = jnp.exp(cum[-1])          # (D,)
    kv = jax.lax.dot_general(
        k_t, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                       # (D, D) = k~ᵀ @ v
    s_scratch[...] = decay_last[:, None] * (S + kv)

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _fin():
        sout_ref[0, 0] = s_scratch[...].astype(sout_ref.dtype)


def wkv6_pallas(
    r: jnp.ndarray,    # (B, H, T, D)
    k: jnp.ndarray,
    v: jnp.ndarray,
    lw: jnp.ndarray,   # (B, H, T, D) log decay
    u: jnp.ndarray,    # (H, D)
    s0: jnp.ndarray,   # (B, H, D, D)
    *,
    chunk: int = CHUNK,
    interpret: bool = False,
):
    B, H, T, D = r.shape
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    grid = (B, H, nc)

    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, D), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, D), r.dtype),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, lw, u, s0)
    return y, s_fin
