"""Pure-jnp oracle for the RWKV6 (Finch) time-mix recurrence.

Per head with head dim D, per timestep t:

    y_t    = r_t · (S_{t-1} + u ⊙ k_t ⊗ v_t)
    S_t    = diag(w_t) S_{t-1} + k_t ⊗ v_t

with data-dependent per-channel decay w_t = exp(lw_t), lw_t <= 0.  This is
exactly FeatInsight's "long window with pre-aggregation" pattern in
disguise: S is a running pre-aggregate and y composes it with the current
row's contribution.

Numerical contract shared with the kernel: lw is clamped to
[LOG_W_MIN, 0]; the clamp bounds intra-chunk exponent magnitudes so the
chunked factorization stays inside f32 range.  (RWKV's reference CUDA
kernels apply an equivalent stability clamp.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["wkv6_ref", "LOG_W_MIN"]

LOG_W_MIN = -3.5  # min per-step log-decay (w >= exp(-3.5) ~ 0.03)


def wkv6_ref(
    r: jnp.ndarray,   # (B, H, T, D)
    k: jnp.ndarray,   # (B, H, T, D)
    v: jnp.ndarray,   # (B, H, T, D)
    lw: jnp.ndarray,  # (B, H, T, D) log-decay (<= 0 after clamp)
    u: jnp.ndarray,   # (H, D) bonus
    state: jnp.ndarray | None = None,  # (B, H, D, D) initial S
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,H,T,D), final state (B,H,D,D))."""
    B, H, T, D = r.shape
    lw = jnp.clip(lw.astype(jnp.float32), LOG_W_MIN, 0.0)
    w = jnp.exp(lw)
    if state is None:
        state = jnp.zeros((B, H, D, D), jnp.float32)

    def step(S, xs):
        r_t, k_t, v_t, w_t = xs  # (B, H, D) each
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,D,D)
        y = jnp.einsum(
            "bhi,bhij->bhj",
            r_t,
            S + u[None, :, :, None] * kv,
        )
        S_new = w_t[..., :, None] * S + kv
        return S_new, y

    xs = tuple(
        jnp.moveaxis(x.astype(jnp.float32), 2, 0) for x in (r, k, v, w)
    )
    S_fin, ys = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(ys, 0, 2)  # (B, H, T, D)
    return y.astype(r.dtype), S_fin
