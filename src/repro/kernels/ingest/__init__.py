# Fused ingest kernel: ring scatter (cursor advance + lane writes) and
# bucket pre-agg state update in ONE pass over the batch.  See ops.py for
# the dispatcher, ingest.py for the Pallas kernel, ref.py for the XLA
# oracle (the exact split ring_ingest + bucket_ingest sequence it fuses).
