"""Dispatcher for the fused ingest kernel.

``fused_ingest(...)`` applies one (key, ts)-sorted ingest batch to the
six primary-store state arrays — ring scatter (cursor advance + lane
writes) AND bucket pre-agg merge — choosing between the Pallas one-pass
kernel and the split XLA oracle (``impl="xla"``, exactly the old
``ring_ingest`` + ``bucket_ingest`` sequence).  Both paths are
bit-identical; callers (``OnlineFeatureStore._ingest_pure``, vmapped
per-shard in ``core/shard.py``) treat the choice as a pure perf knob.

The row→block plumbing the kernel needs (run boundaries, ring slots,
valid masks) is O(N) int32 scan/gather work computed here and handed to
the kernel as scalar-prefetch operands — the payload arrays are only
ever touched inside the kernel's single pass.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import note_dispatch
from repro.kernels.ingest.ingest import fused_ingest_pallas
from repro.kernels.ingest.ref import fused_ingest_ref

__all__ = ["fused_ingest", "fused_ingest_apply", "resolve_ingest_impl"]


def resolve_ingest_impl(impl: str = "auto") -> str:
    """Resolve ``impl="auto"`` against the active backend (host-side)."""
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


def _ffill2(flags: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray):
    """Carry (a, b) at flagged rows forward over unflagged rows."""

    def comb(x, y):
        fx, ax, bx = x
        fy, ay, by = y
        return fx | fy, jnp.where(fy, ay, ax), jnp.where(fy, by, bx)

    return jax.lax.associative_scan(comb, (flags, a, b))


def _seg_cumsum(vals: jnp.ndarray, starts: jnp.ndarray) -> jnp.ndarray:
    """Inclusive segmented cumsum (segments begin where ``starts``)."""

    def comb(x, y):
        fx, vx = x
        fy, vy = y
        return fx | fy, jnp.where(fy, vy, vx + vy)

    _, out = jax.lax.associative_scan(comb, (starts, vals))
    return out


def _ingest_plan(
    key: jnp.ndarray,
    ts: jnp.ndarray,
    cursor: jnp.ndarray,
    *,
    num_keys: int,
    capacity: int,
    num_buckets: int,
    bucket_size: int,
) -> Tuple[jnp.ndarray, ...]:
    """The 9 (N,) int32 scalar-prefetch arrays driving the kernel's pass.

    Sentinel pad rows (key == num_keys) inherit the nearest real row's
    (key, bucket) — forward fill, then backward fill for leading pads —
    so the kernel's block index never jumps to a pad-only block and every
    key's blocks are visited in one consecutive run.  Pad rows write
    nothing (``valid`` gates every state mutation).
    """
    n = key.shape[0]
    key = jnp.asarray(key, jnp.int32)
    valid = key < jnp.int32(num_keys)
    bid_raw = jnp.asarray(ts, jnp.int32) // jnp.int32(bucket_size)
    kz = jnp.where(valid, key, 0)
    bz = jnp.where(valid, bid_raw, 0)
    hf, kf, bf = _ffill2(valid, kz, bz)
    hb, kb, bb = (
        jnp.flip(x, 0)
        for x in _ffill2(
            jnp.flip(valid, 0), jnp.flip(kz, 0), jnp.flip(bz, 0)
        )
    )
    ckey = jnp.where(hf, kf, jnp.where(hb, kb, 0))
    cbid = jnp.where(hf, bf, jnp.where(hb, bb, 0))

    first = jnp.ones((1,), bool)
    kchange = jnp.concatenate([first, ckey[1:] != ckey[:-1]])
    schange = kchange | jnp.concatenate([first, cbid[1:] != cbid[:-1]])
    send = jnp.concatenate([schange[1:], first])
    seg_id = jnp.cumsum(schange.astype(jnp.int32)) - 1
    seg_has_valid = (
        jnp.zeros((n,), jnp.int32).at[seg_id].max(valid.astype(jnp.int32))
    )
    flush = send & (seg_has_valid[seg_id] == 1)

    # ring slot: cursor0[key] + (valid rank within the key run), mod C —
    # identical to ring_ingest's (cursor[key] + rank) % cap for real rows
    cnt = _seg_cumsum(valid.astype(jnp.int32), kchange)
    slot_r = (cursor[ckey] + cnt - 1) % jnp.int32(capacity)
    slot_b = cbid % jnp.int32(num_buckets)

    as_i32 = lambda x: x.astype(jnp.int32)  # noqa: E731
    return (
        ckey, as_i32(kchange), as_i32(schange), as_i32(flush),
        as_i32(valid), slot_r, cnt, cbid, slot_b,
    )


def fused_ingest(
    ring_ts: jnp.ndarray,    # (K, C) int32
    ring_vals: jnp.ndarray,  # (K, C, F) f32
    cursor: jnp.ndarray,     # (K,) int32
    bstats: jnp.ndarray,     # (K, NB, F, NUM_STATS) f32
    bbitmap: jnp.ndarray,    # (K, NB, F) int32
    bbucket: jnp.ndarray,    # (K, NB) int32
    key: jnp.ndarray,        # (N,) int32 sorted by (key, ts); pad key == K
    ts: jnp.ndarray,         # (N,) int32
    vals: jnp.ndarray,       # (N, F) f32
    *,
    bucket_size: int,
    impl: str = "auto",
    interpret: bool = False,
) -> Tuple[jnp.ndarray, ...]:
    """Returns the six updated state arrays (ring ts/vals/cursor, bucket
    stats/bitmap/ids)."""
    impl = resolve_ingest_impl(impl)
    note_dispatch("fused_ingest", impl)
    return _fused_ingest(
        ring_ts, ring_vals, cursor, bstats, bbitmap, bbucket,
        key, ts, vals,
        bucket_size=bucket_size, impl=impl, interpret=interpret,
    )


def fused_ingest_apply(
    ring_ts, ring_vals, cursor, bstats, bbitmap, bbucket, key, ts, vals,
    *, bucket_size: int, impl: str, interpret: bool = False,
) -> Tuple[jnp.ndarray, ...]:
    """Trace-level body of :func:`fused_ingest` — embeddable inside a
    caller's own jit (the online store's ingest fn, vmapped per shard on
    the sharded plane).  ``impl`` must be pre-resolved
    (:func:`resolve_ingest_impl`); the caller owns dispatch counting."""
    if impl == "xla":
        return fused_ingest_ref(
            ring_ts, ring_vals, cursor, bstats, bbitmap, bbucket,
            key, ts, vals, bucket_size=bucket_size,
        )
    plan = _ingest_plan(
        key, ts, cursor,
        num_keys=ring_ts.shape[0], capacity=ring_ts.shape[1],
        num_buckets=bbucket.shape[1], bucket_size=bucket_size,
    )
    return fused_ingest_pallas(
        ring_ts, ring_vals, cursor, bstats, bbitmap, bbucket,
        ts, vals, plan, interpret=interpret,
    )


_fused_ingest = functools.partial(
    jax.jit, static_argnames=("bucket_size", "impl", "interpret")
)(fused_ingest_apply)
