"""XLA oracle for the fused ingest kernel.

This IS the split two-pass sequence the kernel fuses — the ring scatter
(:func:`repro.core.storage.ring_ingest`) followed by the bucket pre-agg
merge (:func:`repro.core.preagg.bucket_ingest`) — exposed over raw state
arrays so the kernel layer stays free of store classes.  The Pallas path
must match it bit-for-bit (tier-1 asserts it across shards {1,4,8}).
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core import preagg as pg
from repro.core import storage as st

__all__ = ["fused_ingest_ref"]


def fused_ingest_ref(
    ring_ts: jnp.ndarray,    # (K, C) int32
    ring_vals: jnp.ndarray,  # (K, C, F) f32
    cursor: jnp.ndarray,     # (K,) int32
    bstats: jnp.ndarray,     # (K, NB, F, NUM_STATS) f32
    bbitmap: jnp.ndarray,    # (K, NB, F) int32
    bbucket: jnp.ndarray,    # (K, NB) int32
    key: jnp.ndarray,        # (N,) int32 sorted by (key, ts); pad key == K
    ts: jnp.ndarray,         # (N,) int32
    vals: jnp.ndarray,       # (N, F) f32
    *,
    bucket_size: int,
) -> Tuple[jnp.ndarray, ...]:
    ring = st.RingStore(ts=ring_ts, vals=ring_vals, cursor=cursor)
    bagg = pg.BucketAgg(
        stats=bstats, bitmap=bbitmap, bucket=bbucket, size=bucket_size
    )
    ring = st.ring_ingest(ring, key, ts, vals)
    bagg = pg.bucket_ingest(bagg, key, ts, vals)
    return ring.ts, ring.vals, ring.cursor, bagg.stats, bagg.bitmap, bagg.bucket
