"""Pallas fused ingest: ring scatter + bucket pre-agg merge, one batch pass.

The split XLA path makes two passes over the batch payloads: one scatter
into the (K, C, F) ring, then a segmented reduction + scatter into the
(K, NB, F, NUM_STATS) bucket states.  This kernel walks the (key, ts)-
sorted batch ONCE over a ``grid=(N,)`` of rows: each step writes its row
into the resident ring blocks of its key AND folds it into a VMEM
accumulator for its (key, bucket) segment, flushing the accumulator into
the resident bucket blocks when the segment ends.

Residency model: every state array is an aliased input/output pair whose
block index is the row's key (``PrefetchScalarGridSpec`` — the same
scalar-prefetched per-key index maps as ``window_stats_pallas``).  Rows
of a key are consecutive (sorted batch), so each key's blocks are
visited exactly once, initialized from the aliased input on the key's
first row, mutated in VMEM across the run, and written back when the
block index moves on.  Pad rows (sentinel key == K) are index-mapped to
a neighbouring real key (fill in ops.py) so they never fault a block
switch, and every state write is gated on the row's validity.

Bit-exactness with the oracle: the per-segment fold runs in batch row
order (``((ident ⊕ r1) ⊕ r2) …``) and merges into the stored state once
per segment — the same association as the oracle's scatter-add segment
reduction — and min/max/OR lanes are order-free, so results match the
split path bit-for-bit (tier-1 asserts it).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.aggregates import NUM_STATS

__all__ = ["fused_ingest_pallas"]

# identity values of the stat lanes (sum, count, min, max, sumsq) — python
# literals, bit-identical to aggregates.POS_INF / NEG_INF (kernels must
# not capture module-level device constants)
_POS_INF = 3.0e38
_NEG_INF = -3.0e38


def _row_bitmap(v: jnp.ndarray) -> jnp.ndarray:
    """In-kernel replica of aggregates.row_bitmap (bit-identical).

    The library version closes over module-level ``jnp.int32`` constants,
    which a Pallas kernel cannot capture — so the two-round mix32 chain
    (hashing.mix64, salt=77, bits=5) is restated here with python-literal
    constants.  tests/test_ingest_fused.py pins the bit-exact equality.
    """

    def mix32(h, salt):
        h = h ^ jnp.int32(salt & 0x7FFFFFFF)
        h = h ^ (h >> 16)
        h = (h * jnp.int32(-2048144789)).astype(jnp.int32)   # 0x85ebca6b
        h = h ^ ((h >> 13) & jnp.int32(0x0007FFFF))
        h = (h * jnp.int32(-1028477387)).astype(jnp.int32)   # 0xc2b2ae35
        h = h ^ ((h >> 16) & jnp.int32(0x0000FFFF))
        return h

    h1 = mix32(v.view(jnp.int32), 77)
    h2 = mix32(h1 ^ jnp.int32(0x5BD1E995), 77 ^ 0x27D4EB2F)
    h = h1 ^ (h2 * jnp.int32(5) + jnp.int32(0x38495AB5))
    bits = jnp.abs(h) % jnp.int32(32)
    return (jnp.int32(1) << bits).astype(jnp.int32)


def _stats_ident(f: int) -> jnp.ndarray:
    """(F, NUM_STATS) identity stat vectors (matches lanes_identity_stack)."""
    li = jax.lax.broadcasted_iota(jnp.int32, (f, NUM_STATS), 1)
    z = jnp.zeros((f, NUM_STATS), jnp.float32)
    return jnp.where(li == 2, _POS_INF, jnp.where(li == 3, _NEG_INF, z))


def _stats_combine(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Lane-wise combine of (..., NUM_STATS) stat vectors — the kernel
    mirror of aggregates.lanes_combine_stack (add/add/min/max/add)."""
    return jnp.stack(
        [
            a[..., 0] + b[..., 0],
            a[..., 1] + b[..., 1],
            jnp.minimum(a[..., 2], b[..., 2]),
            jnp.maximum(a[..., 3], b[..., 3]),
            a[..., 4] + b[..., 4],
        ],
        axis=-1,
    )


def _fused_ingest_kernel(
    # scalar prefetch (all (N,) int32, computed by the ops.py prologue)
    ckey_ref,    # block key per row (pads filled from a neighbouring row)
    kstart_ref,  # 1 on the first row of each key run
    sstart_ref,  # 1 on the first row of each (key, bucket) run
    flush_ref,   # 1 on the last row of a run that holds >= 1 valid row
    valid_ref,   # 1 for real rows, 0 for sentinel pads
    slot_r_ref,  # ring slot (cursor0[key] + valid rank) % C
    cnt_ref,     # inclusive count of valid rows within the key run
    ts_ref,      # row timestamps
    cbid_ref,    # absolute bucket id (pads filled)
    slot_b_ref,  # bucket slot = cbid % NB
    # tensor blocks
    vals_ref,    # (1, F) this row's payload
    vals2_ref,   # (1, F) pre-rounded v*v (see fused_ingest_pallas)
    rts_in, rvals_in, cur_in, bst_in, bbm_in, bid_in,
    rts_out, rvals_out, cur_out, bst_out, bbm_out, bid_out,
    # scratch
    acc_stats,   # (F, NUM_STATS) f32 running segment fold
    acc_bm,      # (1, F) int32 running segment bitmap OR
):
    i = pl.program_id(0)
    cap = rts_out.shape[1]
    f = vals_ref.shape[1]
    ident = _stats_ident(f)

    # first row of a key: the key's blocks just streamed in — seed the
    # output (resident) copies from the aliased inputs so unwritten slots
    # round-trip unchanged
    @pl.when(kstart_ref[i] == 1)
    def _init_blocks():
        rts_out[...] = rts_in[...]
        rvals_out[...] = rvals_in[...]
        cur_out[...] = cur_in[...]
        bst_out[...] = bst_in[...]
        bbm_out[...] = bbm_in[...]
        bid_out[...] = bid_in[...]

    @pl.when(sstart_ref[i] == 1)
    def _reset_segment():
        acc_stats[...] = ident
        acc_bm[...] = jnp.zeros_like(acc_bm)

    v = vals_ref[0, :]  # (F,)

    @pl.when(valid_ref[i] == 1)
    def _ingest_row():
        # ring scatter: ts + payload at this row's slot, cursor advance
        at_slot = (
            jax.lax.broadcasted_iota(jnp.int32, (1, cap), 1) == slot_r_ref[i]
        )
        rts_out[...] = jnp.where(at_slot, ts_ref[i], rts_out[...])
        rvals_out[...] = jnp.where(
            at_slot[..., None], v[None, None, :], rvals_out[...]
        )
        # inclusive count: the key run's last valid row writes the total
        cur_out[0, 0] = cur_in[0, 0] + cnt_ref[i]
        # bucket pre-agg: fold the lifted row into the segment accumulator.
        # The sumsq increment is the PRE-ROUNDED v*v streamed in as its
        # own operand — computing v*v here lets the backend contract the
        # mul into the accumulator add (fma), skipping the rounding step
        # the oracle's materialized lift takes and breaking bit-exactness
        # by 1 ulp.  A loaded value feeding an add cannot contract.
        lifted = jnp.stack(
            [v, jnp.ones_like(v), v, v, vals2_ref[0, :]], axis=-1
        )  # (F, NUM_STATS)
        acc_stats[...] = _stats_combine(acc_stats[...], lifted)
        acc_bm[...] = acc_bm[...] | _row_bitmap(v)[None, :]

    @pl.when(flush_ref[i] == 1)
    def _flush_segment():
        sb = slot_b_ref[i]
        b = cbid_ref[i]
        stored_id = bid_out[0, pl.ds(sb, 1)][0]
        stale = (stored_id != b) & (stored_id != -1)
        st_stats = bst_out[0, pl.ds(sb, 1)]   # (1, F, NUM_STATS)
        st_bm = bbm_out[0, pl.ds(sb, 1)]      # (1, F)
        base_stats = jnp.where(stale, ident[None], st_stats)
        base_bm = jnp.where(stale, 0, st_bm)
        bst_out[0, pl.ds(sb, 1)] = _stats_combine(
            base_stats, acc_stats[...][None]
        )
        bbm_out[0, pl.ds(sb, 1)] = base_bm | acc_bm[...]
        bid_out[0, pl.ds(sb, 1)] = jnp.full((1,), b, jnp.int32)


def fused_ingest_pallas(
    ring_ts: jnp.ndarray,    # (K, C) int32
    ring_vals: jnp.ndarray,  # (K, C, F) f32
    cursor: jnp.ndarray,     # (K,) int32
    bstats: jnp.ndarray,     # (K, NB, F, NUM_STATS) f32
    bbitmap: jnp.ndarray,    # (K, NB, F) int32
    bbucket: jnp.ndarray,    # (K, NB) int32
    ts: jnp.ndarray,         # (N,) int32
    vals: jnp.ndarray,       # (N, F) f32
    plan: Tuple[jnp.ndarray, ...],  # the 10 (N,) int32 prologue arrays
    *,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, ...]:
    """One fused pass; returns the six updated state arrays."""
    K, cap = ring_ts.shape
    f = ring_vals.shape[2]
    nb = bbucket.shape[1]
    n = ts.shape[0]
    (ckey, kstart, sstart, flush, valid, slot_r, cnt, cbid, slot_b) = plan

    def by_key(rank):
        def index_map(i, ckey, *_):
            return (ckey[i],) + (0,) * (rank - 1)

        return index_map

    state_specs = [
        pl.BlockSpec((1, cap), by_key(2)),          # ring_ts
        pl.BlockSpec((1, cap, f), by_key(3)),       # ring_vals
        pl.BlockSpec((1, 1), by_key(2)),            # cursor (K, 1)
        pl.BlockSpec((1, nb, f, NUM_STATS), by_key(4)),  # bstats
        pl.BlockSpec((1, nb, f), by_key(3)),        # bbitmap
        pl.BlockSpec((1, nb), by_key(2)),           # bbucket
    ]
    row_spec = pl.BlockSpec((1, f), lambda i, *_: (i, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=10,
        grid=(n,),
        in_specs=[row_spec, row_spec] + state_specs,
        out_specs=state_specs,
        scratch_shapes=[
            pltpu.VMEM((f, NUM_STATS), jnp.float32),
            pltpu.VMEM((1, f), jnp.int32),
        ],
    )
    out_shapes = [
        jax.ShapeDtypeStruct((K, cap), jnp.int32),
        jax.ShapeDtypeStruct((K, cap, f), jnp.float32),
        jax.ShapeDtypeStruct((K, 1), jnp.int32),
        jax.ShapeDtypeStruct((K, nb, f, NUM_STATS), jnp.float32),
        jax.ShapeDtypeStruct((K, nb, f), jnp.int32),
        jax.ShapeDtypeStruct((K, nb), jnp.int32),
    ]
    # vals2 is the sumsq increment, rounded HERE (outside the kernel) so
    # the kernel's accumulator add sees a materialized operand rather
    # than an adjacent multiply it could fma-contract (see the kernel).
    vals2 = vals * vals
    # operand order: 10 prefetch scalars, vals, vals2, then the 6 state
    # arrays — input_output_aliases indices count the prefetch operands
    outs = pl.pallas_call(
        _fused_ingest_kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        input_output_aliases={12 + j: j for j in range(6)},
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(
        ckey, kstart, sstart, flush, valid, slot_r, cnt,
        jnp.asarray(ts, jnp.int32), cbid, slot_b,
        vals, vals2,
        ring_ts, ring_vals, cursor.reshape(K, 1),
        bstats, bbitmap, bbucket,
    )
    rts, rvals, cur, bst, bbm, bid = outs
    return rts, rvals, cur.reshape(K), bst, bbm, bid
