"""XLA reference for the route-rank kernel (and the CPU/GPU fast path).

``route_rank_ref`` is the whole contract: given per-row shard ids, the
rank of each row *within its shard* in batch order, plus the per-shard
row counts.  That pair is exactly what the fused device-resident request
path needs to scatter a mixed batch into its (S, bucket) per-shard grid
and gather answers back to request order — all device-side.

The formulation is a one-hot running sum (a segmented prefix count), so
results are deterministic integers: the Pallas kernel and this reference
agree bit-for-bit, which the kernel parity test asserts in interpret
mode on CPU.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

__all__ = ["route_rank_ref"]


def route_rank_ref(
    shard: jnp.ndarray, num_shards: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(rank_within_shard (N,) int32, counts (S,) int32) in batch order.

    Rows whose shard id falls outside [0, num_shards) (grid padding uses
    ``num_shards`` as an inert id) get rank 0 and count into no shard.
    """
    shard = jnp.asarray(shard, jnp.int32)
    oh = (
        shard[:, None] == jnp.arange(num_shards, dtype=jnp.int32)[None, :]
    ).astype(jnp.int32)  # (N, S)
    rank = jnp.sum((jnp.cumsum(oh, axis=0) - oh) * oh, axis=1)
    counts = jnp.sum(oh, axis=0)
    return rank.astype(jnp.int32), counts.astype(jnp.int32)
