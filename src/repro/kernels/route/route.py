"""Ring-route rank kernel (Pallas TPU).

The serial dependency of device-side routing is the *rank within shard*:
row i's slot in its shard's padded grid is the number of earlier batch
rows owning the same shard — a segmented prefix count over the batch.
On TPU that is one VMEM-resident pass per shard:

* the shard-id batch lives as a (rows, 128) int32 tile (lane-major
  flattening of the 1-D batch, padded with an inert id);
* grid step ``s`` masks the tile to shard ``s`` and computes the
  flat-order exclusive prefix count from two cumsums (within-row along
  lanes + across rows of the per-row totals) — no gather, no sort;
* each step merges its ranks into the output tile, so after S steps every
  row holds its rank.  S grid steps pipeline; the tile stays resident.

Integer adds only, so the kernel is bit-identical to
:func:`repro.kernels.route.ref.route_rank_ref` (asserted in interpret
mode on CPU — the repo's standing kernel-parity pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["route_rank_pallas", "ROUTE_LANE"]

ROUTE_LANE = 128  # f32/i32 native lane width — tile rows are (8, 128)


def _route_rank_kernel(shard_ref, rank_ref):
    s = pl.program_id(0)
    mask = (shard_ref[...] == s).astype(jnp.int32)  # (rows, LANE)
    # flat-order exclusive prefix count: earlier lanes of this row plus
    # all lanes of earlier rows
    within = jnp.cumsum(mask, axis=1) - mask
    row_tot = jnp.sum(mask, axis=1, keepdims=True)          # (rows, 1)
    prior = jnp.cumsum(row_tot, axis=0) - row_tot           # (rows, 1)
    rank_s = within + prior

    @pl.when(s == 0)
    def _init():
        rank_ref[...] = jnp.where(mask == 1, rank_s, 0)

    @pl.when(s > 0)
    def _merge():
        rank_ref[...] = jnp.where(mask == 1, rank_s, rank_ref[...])


@functools.partial(
    jax.jit, static_argnames=("num_shards", "interpret")
)
def route_rank_pallas(
    shard2d: jnp.ndarray,  # (rows, ROUTE_LANE) int32, padded with >= S
    *,
    num_shards: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Rank-within-shard per element of the (rows, LANE) shard-id tile."""
    rows, lane = shard2d.shape
    return pl.pallas_call(
        _route_rank_kernel,
        grid=(num_shards,),
        in_specs=[
            pl.BlockSpec(
                (rows, lane), lambda s: (0, 0), memory_space=pltpu.VMEM
            )
        ],
        out_specs=pl.BlockSpec(
            (rows, lane), lambda s: (0, 0), memory_space=pltpu.VMEM
        ),
        out_shape=jax.ShapeDtypeStruct((rows, lane), jnp.int32),
        interpret=interpret,
    )(shard2d.astype(jnp.int32))
