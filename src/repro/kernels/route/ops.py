"""jit'd wrapper for the route-rank kernel.

``route_rank(shard, num_shards)`` -> (rank_within_shard, per-shard
counts), dispatching between the Pallas TPU kernel and the XLA
reference (identical integer results).  This is the routing primitive of
the fused device-resident request path (:meth:`repro.core.shard.
ShardedOnlineStore.query` with ``device_routing=True``): shard ids come
from the on-device Feistel permutation, ranks place each row in its
shard's padded grid, counts drive the overflow check and the skew
histograms — one program, no host round-trip.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import note_dispatch, vmem_row_budget
from repro.kernels.route.ref import route_rank_ref
from repro.kernels.route.route import ROUTE_LANE, route_rank_pallas

__all__ = ["route_rank"]

# The route kernel holds the whole batch resident: the (rows, 128) id
# tile, its within-row cumsum, the across-row running totals, and the
# mask temporary — 4 live i32 arrays.  Unlike the fold kernel it does not
# stream tiles, so residency IS the cap; serving batches sit orders of
# magnitude below it.
_ROUTE_PALLAS_MAX_ROWS = ROUTE_LANE * vmem_row_budget(4)


def route_rank(
    shard: jnp.ndarray,  # (N,) int32 shard ids in [0, num_shards)
    *,
    num_shards: int,
    impl: str = "auto",
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(rank (N,) int32, counts (S,) int32): rank of each row within its
    shard in batch order, and rows per shard."""
    if impl == "auto":
        impl = (
            "pallas"
            if jax.default_backend() == "tpu"
            and shard.shape[0] <= _ROUTE_PALLAS_MAX_ROWS
            else "xla"
        )
    note_dispatch("route_rank", impl)
    return _route_rank(
        shard, num_shards=num_shards, impl=impl, interpret=interpret
    )


@functools.partial(
    jax.jit, static_argnames=("num_shards", "impl", "interpret")
)
def _route_rank(
    shard: jnp.ndarray,
    *,
    num_shards: int,
    impl: str,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    n = shard.shape[0]
    if impl == "xla":
        return route_rank_ref(shard, num_shards)
    # lane-major 2-D tiling; padding gets the inert id S (claimed by no
    # grid step, so pad lanes rank as 0 and count into no shard)
    rows = -(-n // ROUTE_LANE)
    rows += (-rows) % 8
    m = rows * ROUTE_LANE
    padded = jnp.full((m,), num_shards, jnp.int32).at[:n].set(
        jnp.asarray(shard, jnp.int32)
    )
    rank2d = route_rank_pallas(
        padded.reshape(rows, ROUTE_LANE),
        num_shards=num_shards,
        interpret=interpret,
    )
    rank = rank2d.reshape(m)[:n]
    counts = jnp.sum(
        (
            jnp.asarray(shard, jnp.int32)[:, None]
            == jnp.arange(num_shards, dtype=jnp.int32)[None, :]
        ).astype(jnp.int32),
        axis=0,
    )
    return rank, counts
