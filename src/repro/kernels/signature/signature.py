"""Multi-hash signature embedding gather (Pallas TPU).

FeatInsight represents trillion-dimensional feature spaces by hashed
signatures; the model-side realization is a hash embedding: each signature
probes a shared (V, D) table at k hashed rows, combined with learned
weights.  The bottleneck is the sparse gather — on TPU the idiomatic form
is a **scalar-prefetch-driven DMA**: row ids are computed ahead of the
grid (XLA-side, cheap int ops), prefetched into SMEM, and each grid step's
BlockSpec index_map selects the (1, D) table row to DMA into VMEM.  The
MXU never sees an indexed load; the DMA engine does the pointer chase.

Grid: (N, k) — k sequential probes accumulate into the same output row
(the output block index is constant across the k axis, so the row stays
VMEM-resident until its last probe).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["signature_embed_pallas"]


def _sig_embed_kernel(ids_ref, table_row_ref, w_ref, out_ref):
    j = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[0, j]
    out_ref[0, :] += w * table_row_ref[0, :].astype(jnp.float32)


def signature_embed_pallas(
    table: jnp.ndarray,    # (V, D)
    ids: jnp.ndarray,      # (N, k) int32 precomputed hash rows
    weights: jnp.ndarray,  # (k,) f32
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    N, k = ids.shape
    V, D = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N, k),
        in_specs=[
            pl.BlockSpec((1, D), lambda i, j, ids: (ids[i, j], 0)),
            pl.BlockSpec((1, k), lambda i, j, ids: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda i, j, ids: (i, 0)),
    )
    return pl.pallas_call(
        _sig_embed_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, D), jnp.float32),
        interpret=interpret,
    )(ids, table, weights.reshape(1, k).astype(jnp.float32))
