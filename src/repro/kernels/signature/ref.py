"""Pure-jnp oracle for the multi-hash signature embedding lookup."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.signature import hash_embedding_lookup_ref, multi_hash_ids

__all__ = ["signature_embed_ref", "multi_hash_ids"]


def signature_embed_ref(
    table: jnp.ndarray,    # (V, D)
    sig: jnp.ndarray,      # (N,) int32 signature ids
    weights: jnp.ndarray,  # (num_hashes,)
    num_hashes: int,
) -> jnp.ndarray:
    """(N, D) combined embedding."""
    return hash_embedding_lookup_ref(table, sig, weights, num_hashes)
