"""jit'd wrapper for signature embedding lookup."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.signature import multi_hash_ids
from repro.kernels.signature.ref import signature_embed_ref
from repro.kernels.signature.signature import signature_embed_pallas

__all__ = ["signature_embed"]


@functools.partial(jax.jit, static_argnames=("num_hashes", "impl", "interpret"))
def signature_embed(
    table: jnp.ndarray,    # (V, D)
    sig: jnp.ndarray,      # (N,) int32 signatures
    weights: jnp.ndarray,  # (num_hashes,)
    *,
    num_hashes: int = 2,
    impl: str = "auto",
    interpret: bool = False,
) -> jnp.ndarray:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return signature_embed_ref(table, sig, weights, num_hashes)
    ids = multi_hash_ids(sig, num_hashes, table.shape[0])  # (N, k)
    out = signature_embed_pallas(table, ids, weights, interpret=interpret)
    return out.astype(table.dtype)
