"""Sharded online serving plane — key-partitioned feature state on a mesh.

FeatInsight's production numbers (100+ scenarios, trillion-dimensional
feature spaces, millisecond updates) rest on OpenMLDB partitioning online
table state across nodes; managed feature stores make the same
partitioned-online-store split their core architecture.  This module is
that layer for the JAX reproduction: a :class:`ShardedOnlineStore` holds
one :class:`~repro.core.online.OnlineState` *per shard* — ring + bucket
pre-aggregates + secondary rings, stacked on a leading ``shard`` axis and
laid out over a 1-D device mesh with ``NamedSharding`` — and answers
batched requests with one compiled program vmapped over shards (GSPMD
partitions it; per-shard compute never crosses devices).

Partitioning scheme — now read from the declarative
:class:`~repro.core.layout.StoreLayout` plan (one planner decides, every
layer consumes):

* **Primary state** is partitioned by deterministic key routing.  By
  default (``hash_routing=True``) keys pass through a
  :class:`~repro.core.hashing.KeyPermutation` — a mix32-Feistel bijection
  on the key domain — and route as ``shard = perm(key) % S``,
  ``local = perm(key) // S``.  The bijection keeps the local id space
  dense (ring tables stay ``ceil(K/S)`` keys per shard) while breaking up
  adversarial/strided key patterns (all keys ≡ 0 mod S collapse onto one
  shard under raw modulo).  ``hash_routing=False`` restores raw
  ``key % S`` / ``key // S`` routing for id spaces known to be uniform.
* **Union-stream tables** share the primary key space (see
  :class:`~repro.core.storage.Database`), so tables referenced *only* by
  WINDOW UNIONs are partitioned the same way — their rows live on the
  shard that answers their key's requests.
* **LAST JOIN targets** are *replicated* on every shard (the classic
  dimension-table strategy): join keys are arbitrary request columns, so
  a lookup must succeed locally on whichever shard owns the request row.
* **Dual-use tables** (both a union stream and a join target) are
  **split** by the planner: the union-stream rows are key-partitioned
  like the primary (stored once, not S×), and only a narrow replicated
  *join slice* (the LAST JOIN argument lanes) is copied per shard —
  recovering the S× memory the replicate-everything policy used to pay.

Request path (the router's dataflow; see :mod:`repro.serve.router`):
rows are bucketed by shard on the host, padded to a shared power-of-two
per-shard shape bucket (compilation caching: one executable per bucket),
executed as one fused sharded query, and scattered back to request order.

Equality contract: every answer is **bit-identical** to the single-device
:class:`~repro.core.online.OnlineFeatureStore` under the same ingest
stream — per-key ring and bucket state depend only on that key's rows
and their order, both of which routing preserves.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.hashing import KeyPermutation
from repro.core.layout import StoreLayout, plan_layout
from repro.core.online import OnlineFeatureStore, OnlineState

__all__ = [
    "RoutePlan",
    "build_route",
    "make_shard_mesh",
    "ShardedOnlineStore",
]


def make_shard_mesh(num_shards: int, devices=None) -> Mesh:
    """1-D ``('shard',)`` mesh over the largest divisor of ``num_shards``
    that the platform can supply (falls back to fewer devices — a 2-device
    box still runs an 8-shard store, two shards per device; one device
    runs everything, which is also the CI path without forced devices)."""
    devices = list(devices) if devices is not None else jax.devices()
    n = 1
    for d in range(min(num_shards, len(devices)), 0, -1):
        if num_shards % d == 0:
            n = d
            break
    return Mesh(np.array(devices[:n]), ("shard",))


@dataclasses.dataclass
class RoutePlan:
    """Host-side routing of one request/ingest batch across shards.

    ``idx[s]`` holds the batch row indices owned by shard ``s`` (in batch
    order, so per-key row order is preserved); ``bucket`` is the padded
    per-shard batch size (shared power-of-two shape bucket).
    """

    idx: List[np.ndarray]
    bucket: int

    @property
    def counts(self) -> np.ndarray:
        return np.array([len(ix) for ix in self.idx], np.int64)


def build_route(
    shard: np.ndarray, num_shards: int, min_bucket: int = 16
) -> RoutePlan:
    """Bucket batch rows by shard id and pick the padded shape bucket."""
    shard = np.asarray(shard)
    idx = [np.nonzero(shard == s)[0] for s in range(num_shards)]
    longest = max((len(ix) for ix in idx), default=0)
    bucket = max(min_bucket, 1 << max(longest - 1, 0).bit_length())
    return RoutePlan(idx=idx, bucket=bucket)


class ShardedOnlineStore(OnlineFeatureStore):
    """Drop-in :class:`OnlineFeatureStore` whose state is key-partitioned
    across ``num_shards`` shards on a JAX device mesh.

    Same public API (``ingest`` / ``ingest_table`` / ``query``), same
    answers bit-for-bit; ``FeatureService`` and ``verify_view`` accept it
    unchanged.  ``num_keys`` / ``secondary_num_keys`` are *global* key
    counts; per-shard tables are sized ``ceil(K/S)``.  All placement
    decisions come from the :class:`~repro.core.layout.StoreLayout`
    (computed here from the view when not passed explicitly).
    """

    def __init__(
        self,
        view,  # repro.core.view.FeatureView
        num_keys: Optional[int] = None,
        num_shards: int = 1,
        capacity: int = 256,
        num_buckets: int = 64,
        bucket_size: int = 64,
        secondary_num_keys: Optional[Dict[str, int]] = None,
        secondary_capacity: Optional[int] = None,
        ttl: Optional[int] = None,
        table_capacity: Optional[Dict[str, int]] = None,
        table_ttl: Optional[Dict[str, int]] = None,
        mesh: Optional[Mesh] = None,
        hash_routing: bool = True,
        layout: Optional[StoreLayout] = None,
    ):
        if layout is None:
            if num_keys is None:
                raise ValueError("ShardedOnlineStore needs num_keys or layout")
            layout = plan_layout(
                [view],
                num_keys=num_keys,
                capacity=capacity,
                num_buckets=num_buckets,
                bucket_size=bucket_size,
                num_shards=num_shards,
                hash_routing=hash_routing,
                secondary_num_keys=secondary_num_keys,
                secondary_capacity=secondary_capacity,
                ttl=ttl,
                table_capacity=table_capacity,
                table_ttl=table_ttl,
            )
        if layout.num_shards is None:
            raise ValueError(
                "ShardedOnlineStore needs a sharded layout "
                "(plan_layout(..., num_shards=S))"
            )
        self._mesh_arg = mesh
        super().__init__(view, layout=layout)

    # -- layout consumption ----------------------------------------------------

    def _apply_layout(self, view, layout: StoreLayout) -> None:
        if layout.num_shards is None or layout.num_shards < 1:
            raise ValueError(
                f"sharded store needs num_shards >= 1, got "
                f"{layout.num_shards}"
            )
        S = int(layout.num_shards)
        self.num_shards = S
        self.global_num_keys = layout.num_keys
        self.hash_routing = layout.hash_routing
        self._perm: Optional[KeyPermutation] = (
            KeyPermutation(layout.perm_domain)
            if layout.perm_domain is not None
            else None
        )
        super()._apply_layout(view, layout)
        self.global_secondary_num_keys = dict(self.secondary_num_keys)
        # the mesh survives layout adoption: same shard count, same devices
        if not hasattr(self, "mesh"):
            self.mesh = (
                self._mesh_arg
                if self._mesh_arg is not None
                else make_shard_mesh(S)
            )
            self.sharding = NamedSharding(self.mesh, P("shard"))

    def _init_state(self) -> OnlineState:
        # stack S identical fresh per-shard states, partition over the mesh
        single = super()._init_state()
        return self._place_state(
            jax.tree.map(lambda x: jnp.stack([x] * self.num_shards), single)
        )

    def _place_state(self, state: OnlineState) -> OnlineState:
        return jax.device_put(
            jax.tree.map(jnp.asarray, state), self.sharding
        )

    def _build_fns(self) -> None:
        # one compiled executable per path, vmapped over the shard axis;
        # GSPMD splits it across mesh devices (no cross-shard collectives
        # in the body — results gather only when fetched to host).  The
        # query fns are built through the _jit_query override below, so
        # they (and every per-scenario QueryProgram) are the vmapped
        # flavour; ingest needs its own wrapping for donation.
        super()._build_fns()
        self._ingest_fn = jax.jit(
            jax.vmap(self._ingest_pure), donate_argnums=(0,)
        )
        self._sec_ingest_fns = {
            i: jax.jit(
                jax.vmap(functools.partial(self._sec_ingest_pure, index=i)),
                donate_argnums=(0,),
            )
            for i in range(len(self._ring_plans))
        }

    def _jit_query(self, fn):
        """Sharded query programs run vmapped over the leading shard axis
        (per-scenario programs compiled later pick this up too)."""
        return jax.jit(jax.vmap(fn))

    # -- routing ---------------------------------------------------------------

    def _check_range(self, key: np.ndarray, upper: Optional[int]) -> np.ndarray:
        """Out-of-range keys are rejected: the single-device store clamps
        them (gather semantics), the sharded store would land on a
        *different* key's state after routing — silently breaking the
        bit-identical contract — so fail loudly instead."""
        key = np.asarray(key)
        upper = self.global_num_keys if upper is None else upper
        if key.size and (key.min() < 0 or key.max() >= upper):
            raise ValueError(
                f"key out of range [0, {upper}): "
                f"[{key.min()}, {key.max()}] (sharded stores cannot clamp "
                "without routing to another key's shard)"
            )
        return key

    def _route_ids(
        self, key: np.ndarray, upper: Optional[int] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Deterministic key -> (shard id, shard-local id), host-side.

        With hash routing the key first passes through the shared Feistel
        permutation; bijectivity keeps local ids collision-free per shard.
        """
        key = self._check_range(key, upper)
        routed = self._perm(key) if self._perm is not None else key
        return routed % self.num_shards, routed // self.num_shards

    def shard_of(
        self, key: np.ndarray, upper: Optional[int] = None
    ) -> np.ndarray:
        """Deterministic key -> shard id (host-side; range-checked)."""
        return self._route_ids(key, upper)[0]

    def _put(self, x: np.ndarray) -> jnp.ndarray:
        return jax.device_put(jnp.asarray(x), self.sharding)

    def _route_rows(
        self,
        plan: RoutePlan,
        arr: np.ndarray,
        pad: str = "repeat",
        sentinel: int = 0,
    ) -> np.ndarray:
        """Scatter (N, ...) batch rows into a padded (S, bucket, ...) grid.

        ``pad='repeat'`` repeats the shard's last real row (query padding:
        harmless read-only recompute, sliced off on scatter-back);
        ``pad='sentinel'`` fills the key column with an out-of-range id so
        every state scatter drops the padding (ingest padding).
        """
        arr = np.asarray(arr)
        S, B = self.num_shards, plan.bucket
        out = np.zeros((S, B) + arr.shape[1:], arr.dtype)
        if pad == "sentinel":
            out[...] = sentinel
        for s, ix in enumerate(plan.idx):
            n = len(ix)
            if not n:
                continue
            out[s, :n] = arr[ix]
            if n < B and pad == "repeat":
                out[s, n:] = arr[ix[-1]]
        return out

    def _scatter_back(
        self, plan: RoutePlan, vals: Tuple[jnp.ndarray, ...], q: int
    ) -> Tuple[np.ndarray, ...]:
        """(S, bucket) per-shard answers -> (Q,) in request order."""
        outs = []
        for v in vals:
            vh = np.asarray(v)
            o = np.zeros((q,), vh.dtype)
            for s, ix in enumerate(plan.idx):
                o[ix] = vh[s, : len(ix)]
            outs.append(o)
        return tuple(outs)

    # -- ingest ----------------------------------------------------------------

    def _sorted_route(
        self, key_h: np.ndarray, ts_h: np.ndarray, upper: Optional[int]
    ) -> Tuple[RoutePlan, np.ndarray]:
        """Routing plan + local ids for one fused ingest chunk, with every
        shard's rows in (local key, ts) order as ring/bucket ingest requires.

        Modulo routing preserves the incoming (key, ts) sort per shard
        (k1 < k2 with k1 ≡ k2 (mod S) implies k1//S < k2//S); the Feistel
        permutation scrambles key order, so hash routing stably re-sorts
        each shard's rows — same-key rows keep their arrival order, so
        per-key state (the bit-identical contract) is unaffected.  A chunk
        satisfying the bucket-span constraint still satisfies it
        shard-locally either way.
        """
        shard, local = self._route_ids(key_h, upper)
        plan = build_route(shard, self.num_shards, min_bucket=64)
        if self.hash_routing:
            plan = RoutePlan(
                idx=[
                    ix[np.lexsort((ts_h[ix], local[ix]))] for ix in plan.idx
                ],
                bucket=plan.bucket,
            )
        return plan, local

    def _ingest_padded(self, key, ts, lanes) -> None:
        """Route one fused (key, ts)-sorted chunk across shards."""
        key_h, ts_h = np.asarray(key), np.asarray(ts)
        plan, local = self._sorted_route(key_h, ts_h, None)
        k = self._route_rows(
            plan, local, pad="sentinel", sentinel=self.num_keys
        )
        t = self._route_rows(plan, ts_h, pad="repeat")
        l = self._route_rows(plan, np.asarray(lanes), pad="sentinel")
        self.state = self._ingest_fn(
            self.state, self._put(k), self._put(t), self._put(l)
        )

    def _sec_ring_ingest_padded(self, index: int, key, ts, lanes) -> None:
        S = self.num_shards
        plan_i = self._ring_plans[index]
        if plan_i.partitioned:
            key_h, ts_h = np.asarray(key), np.asarray(ts)
            plan, local = self._sorted_route(key_h, ts_h, plan_i.num_keys)
            k = self._route_rows(
                plan, local, pad="sentinel", sentinel=plan_i.ring_keys
            )
            t = self._route_rows(plan, ts_h, pad="repeat")
            l = self._route_rows(plan, np.asarray(lanes), pad="sentinel")
        else:
            # replicated dimension table / join slice: identical fused
            # scatter on every shard keeps each replica bit-identical to
            # the single store
            key, ts, lanes = self._pad_batch(key, ts, lanes, plan_i.ring_keys)
            k, t, l = (
                np.broadcast_to(np.asarray(x), (S,) + x.shape)
                for x in (key, ts, lanes)
            )
        self.state = self._sec_ingest_fns[index](
            self.state, self._put(k), self._put(t), self._put(l)
        )

    # -- query -----------------------------------------------------------------

    def query(
        self,
        columns: Dict[str, jnp.ndarray],
        mode: str = "preagg",
        program=None,
    ) -> Dict[str, jnp.ndarray]:
        """Route the request across shards, answer with the fused sharded
        query, scatter back to request order (same contract as the base
        store: {feature_name: (Q,) f32} in input row order).

        Routing happens on the host straight from the request columns
        (normally numpy already); only the routed (S, bucket) grids are
        uploaded — no device round-trip on the latency-critical path.
        ``program`` serves one scenario's compiled sub-view against the
        shared sharded state (see :meth:`OnlineFeatureStore.compile_program`).

        The three stages are traced separately — ``query.route`` (host:
        shard bucketing, padding, upload), ``query.compute`` (device,
        fenced), ``query.scatter`` (host: answers back to request order) —
        so the wire-to-wire breakdown attributes host vs device time per
        stage instead of one opaque wall number.
        """
        from repro.obs import get_telemetry

        tel = get_telemetry()
        self._validate_join_cols(columns, program)
        key_h = np.asarray(columns[self.schema.key]).astype(
            np.int32, copy=False
        )
        q = int(key_h.shape[0])
        pname = program.view.name if program is not None else ""
        with tel.tracer.span(
            "query.route", mode=mode, program=pname, rows=q
        ):
            ts_h = np.asarray(columns[self.schema.ts]).astype(
                np.int32, copy=False
            )
            lane_exprs = None if program is None else program.lane_exprs
            join_cols = (
                self._join_cols if program is None else program.join_cols
            )
            lanes_h = np.asarray(self._lanes(columns, lane_exprs))
            shard, local = self._route_ids(key_h)
            plan = build_route(shard, self.num_shards, min_bucket=16)
            gkey_r = self._route_rows(plan, key_h, pad="repeat")
            args = (
                self._put(self._route_rows(plan, local, pad="repeat")),
                self._put(self._route_rows(plan, ts_h, pad="repeat")),
                self._put(self._route_rows(plan, lanes_h, pad="repeat")),
                tuple(
                    self._put(
                        self._route_rows(
                            plan,
                            np.asarray(columns[c]).astype(
                                np.int32, copy=False
                            ),
                            pad="repeat",
                        )
                    )
                    for c in join_cols
                ),
                self._put(gkey_r),                          # global key
            )
        pad_rows = self.num_shards * plan.bucket - q
        m = tel.metrics
        m.counter(
            "padding_rows_total", "filler rows added to reach shape bucket",
            "1", labels=("layer",),
        ).inc(pad_rows, layer="shard")
        m.gauge(
            "padding_waste_ratio", "filler rows / bucket rows, last batch",
            "1", labels=("layer",),
        ).set(pad_rows / max(self.num_shards * plan.bucket, 1), layer="shard")
        fn = self._query_fn(mode, program)
        t_call = tel.clock.now()
        with tel.tracer.span(
            "query.compute", kind="device", mode=mode, program=pname,
            rows=q, padded=self.num_shards * plan.bucket,
        ) as sp:
            vals = fn(self.state, *args)
            vals = sp.fence(vals)
        self._note_query(tel, mode, program, plan.bucket, t_call)
        with tel.tracer.span("query.scatter", rows=q):
            out = self._finish_query(
                columns, self._scatter_back(plan, vals, q), program
            )
        return out

    # -- observability ---------------------------------------------------------

    def shard_row_counts(self) -> np.ndarray:
        """Total primary rows ever ingested per shard (from ring cursors)."""
        return np.asarray(self.state.ring.cursor).sum(axis=1)
