"""Sharded online serving plane — key-partitioned feature state on a mesh.

FeatInsight's production numbers (100+ scenarios, trillion-dimensional
feature spaces, millisecond updates) rest on OpenMLDB partitioning online
table state across nodes; managed feature stores make the same
partitioned-online-store split their core architecture.  This module is
that layer for the JAX reproduction: a :class:`ShardedOnlineStore` holds
one :class:`~repro.core.online.OnlineState` *per shard* — ring + bucket
pre-aggregates + secondary rings, stacked on a leading ``shard`` axis and
laid out over a 1-D device mesh with ``NamedSharding`` — and answers
batched requests with one compiled program vmapped over shards (GSPMD
partitions it; per-shard compute never crosses devices).

Partitioning scheme — now read from the declarative
:class:`~repro.core.layout.StoreLayout` plan (one planner decides, every
layer consumes):

* **Primary state** is partitioned by deterministic key routing.  By
  default (``hash_routing=True``) keys pass through a
  :class:`~repro.core.hashing.KeyPermutation` — a mix32-Feistel bijection
  on the key domain — and route as ``shard = perm(key) % S``,
  ``local = perm(key) // S``.  The bijection keeps the local id space
  dense (ring tables stay ``ceil(K/S)`` keys per shard) while breaking up
  adversarial/strided key patterns (all keys ≡ 0 mod S collapse onto one
  shard under raw modulo).  ``hash_routing=False`` restores raw
  ``key % S`` / ``key // S`` routing for id spaces known to be uniform.
* **Union-stream tables** share the primary key space (see
  :class:`~repro.core.storage.Database`), so tables referenced *only* by
  WINDOW UNIONs are partitioned the same way — their rows live on the
  shard that answers their key's requests.
* **LAST JOIN targets** are *replicated* on every shard (the classic
  dimension-table strategy): join keys are arbitrary request columns, so
  a lookup must succeed locally on whichever shard owns the request row.
* **Dual-use tables** (both a union stream and a join target) are
  **split** by the planner: the union-stream rows are key-partitioned
  like the primary (stored once, not S×), and only a narrow replicated
  *join slice* (the LAST JOIN argument lanes) is copied per shard —
  recovering the S× memory the replicate-everything policy used to pay.

Request path (the router's dataflow; see :mod:`repro.serve.router`) —
two flavours, bit-identical by contract:

* **Device routing** (default, ``device_routing=True``): the whole batch
  enters ONE fused jit program that computes ``shard = feistel(key) % S``
  on device (:meth:`~repro.core.hashing.KeyPermutation.device_call`),
  ranks rows within their shard (:func:`repro.kernels.route.ops.
  route_rank` — Pallas on TPU, XLA elsewhere), scatters them into a
  capacity-bucketed (S, B) per-shard grid under the ``('shard',)``
  sharding constraint, answers with the vmapped per-shard query, and
  gathers answers back to request order device-side.  Mixed
  multi-scenario batches ride the same program
  (:meth:`ShardedOnlineStore.route_and_query` — the scenario-id column
  is threaded through for the on-device (scenario, shard) histogram).
  The optimistic per-shard capacity ``B ≈ 2·ceil(N/S)`` is checked by an
  on-device overflow flag; pathological skew re-dispatches once at the
  always-safe ``B = N``, so exactness never depends on the guess.
* **Host routing** (``device_routing=False`` — the correctness oracle):
  rows are bucketed by shard on the host, padded to a shared
  power-of-two per-shard shape bucket, executed as one fused sharded
  query, and scattered back to request order on CPU.

Equality contract: every answer is **bit-identical** to the single-device
:class:`~repro.core.online.OnlineFeatureStore` under the same ingest
stream — per-key ring and bucket state depend only on that key's rows
and their order, both of which routing preserves.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.hashing import KeyPermutation
from repro.core.layout import StoreLayout, plan_layout
from repro.core.online import OnlineFeatureStore, OnlineState
from repro.kernels import note_dispatch
from repro.kernels.route.ops import route_rank

__all__ = [
    "RoutePlan",
    "build_route",
    "make_shard_mesh",
    "ShardedOnlineStore",
]


def make_shard_mesh(num_shards: int, devices=None) -> Mesh:
    """1-D ``('shard',)`` mesh over the largest divisor of ``num_shards``
    that the platform can supply (falls back to fewer devices — a 2-device
    box still runs an 8-shard store, two shards per device; one device
    runs everything, which is also the CI path without forced devices)."""
    devices = list(devices) if devices is not None else jax.devices()
    n = 1
    for d in range(min(num_shards, len(devices)), 0, -1):
        if num_shards % d == 0:
            n = d
            break
    return Mesh(np.array(devices[:n]), ("shard",))


@dataclasses.dataclass
class RoutePlan:
    """Host-side routing of one request/ingest batch across shards.

    ``idx[s]`` holds the batch row indices owned by shard ``s`` (in batch
    order, so per-key row order is preserved); ``bucket`` is the padded
    per-shard batch size (shared power-of-two shape bucket).
    """

    idx: List[np.ndarray]
    bucket: int

    @property
    def counts(self) -> np.ndarray:
        return np.array([len(ix) for ix in self.idx], np.int64)


def build_route(
    shard: np.ndarray, num_shards: int, min_bucket: int = 16
) -> RoutePlan:
    """Bucket batch rows by shard id and pick the padded shape bucket."""
    shard = np.asarray(shard)
    idx = [np.nonzero(shard == s)[0] for s in range(num_shards)]
    longest = max((len(ix) for ix in idx), default=0)
    bucket = max(min_bucket, 1 << max(longest - 1, 0).bit_length())
    return RoutePlan(idx=idx, bucket=bucket)


class ShardedOnlineStore(OnlineFeatureStore):
    """Drop-in :class:`OnlineFeatureStore` whose state is key-partitioned
    across ``num_shards`` shards on a JAX device mesh.

    Same public API (``ingest`` / ``ingest_table`` / ``query``), same
    answers bit-for-bit; ``FeatureService`` and ``verify_view`` accept it
    unchanged.  ``num_keys`` / ``secondary_num_keys`` are *global* key
    counts; per-shard tables are sized ``ceil(K/S)``.  All placement
    decisions come from the :class:`~repro.core.layout.StoreLayout`
    (computed here from the view when not passed explicitly).
    """

    def __init__(
        self,
        view,  # repro.core.view.FeatureView
        num_keys: Optional[int] = None,
        num_shards: int = 1,
        capacity: int = 256,
        num_buckets: int = 64,
        bucket_size: int = 64,
        secondary_num_keys: Optional[Dict[str, int]] = None,
        secondary_capacity: Optional[int] = None,
        ttl: Optional[int] = None,
        table_capacity: Optional[Dict[str, int]] = None,
        table_ttl: Optional[Dict[str, int]] = None,
        mesh: Optional[Mesh] = None,
        hash_routing: bool = True,
        layout: Optional[StoreLayout] = None,
        device_routing: bool = True,
    ):
        self.device_routing = bool(device_routing)
        if layout is None:
            if num_keys is None:
                raise ValueError("ShardedOnlineStore needs num_keys or layout")
            layout = plan_layout(
                [view],
                num_keys=num_keys,
                capacity=capacity,
                num_buckets=num_buckets,
                bucket_size=bucket_size,
                num_shards=num_shards,
                hash_routing=hash_routing,
                secondary_num_keys=secondary_num_keys,
                secondary_capacity=secondary_capacity,
                ttl=ttl,
                table_capacity=table_capacity,
                table_ttl=table_ttl,
            )
        if layout.num_shards is None:
            raise ValueError(
                "ShardedOnlineStore needs a sharded layout "
                "(plan_layout(..., num_shards=S))"
            )
        self._mesh_arg = mesh
        super().__init__(view, layout=layout)

    # -- layout consumption ----------------------------------------------------

    def _apply_layout(self, view, layout: StoreLayout) -> None:
        if layout.num_shards is None or layout.num_shards < 1:
            raise ValueError(
                f"sharded store needs num_shards >= 1, got "
                f"{layout.num_shards}"
            )
        S = int(layout.num_shards)
        self.num_shards = S
        self.global_num_keys = layout.num_keys
        self.hash_routing = layout.hash_routing
        self._perm: Optional[KeyPermutation] = (
            KeyPermutation(layout.perm_domain)
            if layout.perm_domain is not None
            else None
        )
        super()._apply_layout(view, layout)
        self.global_secondary_num_keys = dict(self.secondary_num_keys)
        # the mesh survives layout adoption: same shard count, same devices
        if not hasattr(self, "mesh"):
            self.mesh = (
                self._mesh_arg
                if self._mesh_arg is not None
                else make_shard_mesh(S)
            )
            self.sharding = NamedSharding(self.mesh, P("shard"))

    def _init_state(self) -> OnlineState:
        # stack S identical fresh per-shard states, partition over the mesh
        single = super()._init_state()
        return self._place_state(
            jax.tree.map(lambda x: jnp.stack([x] * self.num_shards), single)
        )

    def _place_state(self, state: OnlineState) -> OnlineState:
        return jax.device_put(
            jax.tree.map(jnp.asarray, state), self.sharding
        )

    def _build_fns(self) -> None:
        # one compiled executable per path, vmapped over the shard axis;
        # GSPMD splits it across mesh devices (no cross-shard collectives
        # in the body — results gather only when fetched to host).  The
        # query fns are built through the _jit_query override below, so
        # they (and every per-scenario QueryProgram) are the vmapped
        # flavour; ingest needs its own wrapping for donation.
        super()._build_fns()
        # fused route+query executables are cached per (program, mode,
        # shape bucket) below and must re-trace after a layout adoption,
        # exactly like the base query fns
        self._fused_fns: Dict[Tuple, object] = {}
        self._ingest_fn = jax.jit(
            jax.vmap(self._ingest_pure), donate_argnums=(0,)
        )
        self._sec_ingest_fns = {
            i: jax.jit(
                jax.vmap(functools.partial(self._sec_ingest_pure, index=i)),
                donate_argnums=(0,),
            )
            for i in range(len(self._ring_plans))
        }

    def _jit_query(self, fn):
        """Sharded query programs run vmapped over the leading shard axis
        (per-scenario programs compiled later pick this up too)."""
        return jax.jit(jax.vmap(fn))

    # -- routing ---------------------------------------------------------------

    def _check_range(self, key: np.ndarray, upper: Optional[int]) -> np.ndarray:
        """Out-of-range keys are rejected: the single-device store clamps
        them (gather semantics), the sharded store would land on a
        *different* key's state after routing — silently breaking the
        bit-identical contract — so fail loudly instead."""
        key = np.asarray(key)
        upper = self.global_num_keys if upper is None else upper
        if key.size and (key.min() < 0 or key.max() >= upper):
            raise ValueError(
                f"key out of range [0, {upper}): "
                f"[{key.min()}, {key.max()}] (sharded stores cannot clamp "
                "without routing to another key's shard)"
            )
        return key

    def _route_ids(
        self, key: np.ndarray, upper: Optional[int] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Deterministic key -> (shard id, shard-local id), host-side.

        With hash routing the key first passes through the shared Feistel
        permutation; bijectivity keeps local ids collision-free per shard.
        """
        key = self._check_range(key, upper)
        routed = self._perm(key) if self._perm is not None else key
        return routed % self.num_shards, routed // self.num_shards

    def shard_of(
        self, key: np.ndarray, upper: Optional[int] = None
    ) -> np.ndarray:
        """Deterministic key -> shard id (host-side; range-checked)."""
        return self._route_ids(key, upper)[0]

    def _put(self, x: np.ndarray) -> jnp.ndarray:
        return jax.device_put(jnp.asarray(x), self.sharding)

    def _route_rows(
        self,
        plan: RoutePlan,
        arr: np.ndarray,
        pad: str = "repeat",
        sentinel: int = 0,
    ) -> np.ndarray:
        """Scatter (N, ...) batch rows into a padded (S, bucket, ...) grid.

        ``pad='repeat'`` repeats the shard's last real row (query padding:
        harmless read-only recompute, sliced off on scatter-back);
        ``pad='sentinel'`` fills the key column with an out-of-range id so
        every state scatter drops the padding (ingest padding).
        """
        arr = np.asarray(arr)
        S, B = self.num_shards, plan.bucket
        out = np.zeros((S, B) + arr.shape[1:], arr.dtype)
        if pad == "sentinel":
            out[...] = sentinel
        for s, ix in enumerate(plan.idx):
            n = len(ix)
            if not n:
                continue
            out[s, :n] = arr[ix]
            if n < B and pad == "repeat":
                out[s, n:] = arr[ix[-1]]
        return out

    def _scatter_back(
        self, plan: RoutePlan, vals: Tuple[jnp.ndarray, ...], q: int
    ) -> Tuple[np.ndarray, ...]:
        """(S, bucket) per-shard answers -> (Q,) in request order."""
        outs = []
        for v in vals:
            vh = np.asarray(v)
            o = np.zeros((q,), vh.dtype)
            for s, ix in enumerate(plan.idx):
                o[ix] = vh[s, : len(ix)]
            outs.append(o)
        return tuple(outs)

    # -- ingest ----------------------------------------------------------------

    def _sorted_route(
        self, key_h: np.ndarray, ts_h: np.ndarray, upper: Optional[int]
    ) -> Tuple[RoutePlan, np.ndarray]:
        """Routing plan + local ids for one fused ingest chunk, with every
        shard's rows in (local key, ts) order as ring/bucket ingest requires.

        Modulo routing preserves the incoming (key, ts) sort per shard
        (k1 < k2 with k1 ≡ k2 (mod S) implies k1//S < k2//S); the Feistel
        permutation scrambles key order, so hash routing stably re-sorts
        each shard's rows — same-key rows keep their arrival order, so
        per-key state (the bit-identical contract) is unaffected.  A chunk
        satisfying the bucket-span constraint still satisfies it
        shard-locally either way.
        """
        shard, local = self._route_ids(key_h, upper)
        plan = build_route(shard, self.num_shards, min_bucket=64)
        if self.hash_routing:
            plan = RoutePlan(
                idx=[
                    ix[np.lexsort((ts_h[ix], local[ix]))] for ix in plan.idx
                ],
                bucket=plan.bucket,
            )
        return plan, local

    def _ingest_padded(self, key, ts, lanes) -> None:
        """Route one fused (key, ts)-sorted chunk across shards."""
        key_h, ts_h = np.asarray(key), np.asarray(ts)
        plan, local = self._sorted_route(key_h, ts_h, None)
        k = self._route_rows(
            plan, local, pad="sentinel", sentinel=self.num_keys
        )
        t = self._route_rows(plan, ts_h, pad="repeat")
        l = self._route_rows(plan, np.asarray(lanes), pad="sentinel")
        note_dispatch("fused_ingest", self._ingest_resolved_impl())
        self.state = self._ingest_fn(
            self.state, self._put(k), self._put(t), self._put(l)
        )

    def _sec_ring_ingest_padded(self, index: int, key, ts, lanes) -> None:
        S = self.num_shards
        plan_i = self._ring_plans[index]
        if plan_i.partitioned:
            key_h, ts_h = np.asarray(key), np.asarray(ts)
            plan, local = self._sorted_route(key_h, ts_h, plan_i.num_keys)
            k = self._route_rows(
                plan, local, pad="sentinel", sentinel=plan_i.ring_keys
            )
            t = self._route_rows(plan, ts_h, pad="repeat")
            l = self._route_rows(plan, np.asarray(lanes), pad="sentinel")
        else:
            # replicated dimension table / join slice: identical fused
            # scatter on every shard keeps each replica bit-identical to
            # the single store
            key, ts, lanes = self._pad_batch(key, ts, lanes, plan_i.ring_keys)
            k, t, l = (
                np.broadcast_to(np.asarray(x), (S,) + x.shape)
                for x in (key, ts, lanes)
            )
        self.state = self._sec_ingest_fns[index](
            self.state, self._put(k), self._put(t), self._put(l)
        )

    # -- query -----------------------------------------------------------------

    def query(
        self,
        columns: Dict[str, jnp.ndarray],
        mode: str = "preagg",
        program=None,
        valid: Optional[np.ndarray] = None,
        route_info: Optional[Dict] = None,
    ) -> Dict[str, jnp.ndarray]:
        """Answer a request batch in input row order (same contract as the
        base store: {feature_name: (Q,) f32}).

        ``device_routing=True`` (default) serves the batch through the
        fused on-mesh path — routing, per-shard padding, the vmapped
        query and the gather back to request order are all one jit
        program (:meth:`_query_device_routed`).  ``device_routing=False``
        keeps the host-routed path (:meth:`_query_host_routed`) — the
        correctness oracle the parity tests compare against.

        ``valid`` optionally marks scheduler padding rows so occupancy
        accounting excludes them; ``route_info`` (a dict, filled in
        place) returns the batch's valid-masked per-shard request counts
        (``"shard_counts"``) so the router's skew histograms never
        re-hash keys.
        """
        if self.device_routing:
            return self._query_device_routed(
                columns, mode, program, valid, route_info
            )
        return self._query_host_routed(
            columns, mode, program, valid, route_info
        )

    def _query_host_routed(
        self,
        columns: Dict[str, jnp.ndarray],
        mode: str,
        program=None,
        valid: Optional[np.ndarray] = None,
        route_info: Optional[Dict] = None,
    ) -> Dict[str, jnp.ndarray]:
        """Host-routed request path (the ``device_routing=False`` oracle).

        Routing happens on the host straight from the request columns
        (normally numpy already); only the routed (S, bucket) grids are
        uploaded.  ``program`` serves one scenario's compiled sub-view
        against the shared sharded state (see
        :meth:`OnlineFeatureStore.compile_program`).

        The three stages are traced separately — ``query.route`` (host:
        shard bucketing, padding, upload), ``query.compute`` (device,
        fenced), ``query.scatter`` (host: answers back to request order) —
        so the wire-to-wire breakdown attributes host vs device time per
        stage instead of one opaque wall number.
        """
        from repro.obs import get_telemetry

        tel = get_telemetry()
        self._validate_join_cols(columns, program)
        key_h = np.asarray(columns[self.schema.key]).astype(
            np.int32, copy=False
        )
        q = int(key_h.shape[0])
        pname = program.view.name if program is not None else ""
        with tel.tracer.span(
            "query.route", mode=mode, program=pname, rows=q
        ):
            ts_h = np.asarray(columns[self.schema.ts]).astype(
                np.int32, copy=False
            )
            lane_exprs = None if program is None else program.lane_exprs
            join_cols = (
                self._join_cols if program is None else program.join_cols
            )
            lanes_h = np.asarray(self._lanes(columns, lane_exprs))
            shard, local = self._route_ids(key_h)
            plan = build_route(shard, self.num_shards, min_bucket=16)
            gkey_r = self._route_rows(plan, key_h, pad="repeat")
            args = (
                self._put(self._route_rows(plan, local, pad="repeat")),
                self._put(self._route_rows(plan, ts_h, pad="repeat")),
                self._put(self._route_rows(plan, lanes_h, pad="repeat")),
                tuple(
                    self._put(
                        self._route_rows(
                            plan,
                            np.asarray(columns[c]).astype(
                                np.int32, copy=False
                            ),
                            pad="repeat",
                        )
                    )
                    for c in join_cols
                ),
                self._put(gkey_r),                          # global key
            )
        vmask = (
            np.ones(q, bool) if valid is None else np.asarray(valid, bool)[:q]
        )
        self._note_route(tel, "host", int(vmask.sum()), q, plan.bucket)
        if route_info is not None:
            route_info["shard_counts"] = np.bincount(
                shard[vmask], minlength=self.num_shards
            ).astype(np.int64)
        fn = self._query_fn(mode, program)
        t_call = tel.clock.now()
        with tel.tracer.span(
            "query.compute", kind="device", mode=mode, program=pname,
            rows=q, padded=self.num_shards * plan.bucket,
        ) as sp:
            vals = fn(self.state, *args)
            vals = sp.fence(vals)
        self._note_query(tel, mode, program, plan.bucket, t_call)
        with tel.tracer.span("query.scatter", rows=q):
            out = self._finish_query(
                columns, self._scatter_back(plan, vals, q), program
            )
        return out

    # -- fused device-resident request path ------------------------------------

    def _route_bucket(self, m: int) -> int:
        """Optimistic per-shard grid capacity for an m-row batch: twice
        the even-split share, power-of-two (compilation caching), floored
        at 16 and capped at m (the always-safe bound — no shard can own
        more rows than the batch has).  The fused program's on-device
        overflow flag catches the rare skew beyond 2x and re-dispatches
        at the cap, so this is a latency guess, never a correctness one."""
        per = -(-m // self.num_shards)
        b = 1 << max(2 * per - 1, 0).bit_length()
        cap = 1 << max(m - 1, 0).bit_length()
        return int(min(max(16, b), max(cap, 1)))

    def _route_query_pure(
        self,
        state: OnlineState,
        key,
        ts_q,
        req_lanes,
        join_keys,
        scen,
        valid,
        *,
        bucket: int,
        num_scen: int,
        use_preagg: bool,
        wagg_order=None,
        ljoin_order=None,
        req_lane_of=None,
        join_col_index=None,
    ):
        """The fused on-mesh request program: route, pad, answer, gather.

        (a) ``shard = feistel(key) % S`` via the device Feistel mirror;
        (b) rank-within-shard (route kernel) scatters rows into the
        (S, bucket) per-shard grid, laid over the mesh by a ``('shard',)``
        sharding constraint (GSPMD keeps per-shard compute on its
        device); (c) the unchanged vmapped per-shard query answers every
        grid row; (d) answers gather back to request order device-side.
        Returns (answers, per-(scenario, shard) valid-row counts, overflow
        flag).  Unscattered grid slots hold zeros — key 0 of each shard,
        a harmless read-only recompute discarded by the gather.
        """
        S = self.num_shards
        B = bucket
        key = jnp.asarray(key, jnp.int32)
        routed = (
            self._perm.device_call(key) if self._perm is not None else key
        )
        shard = routed % S
        local = routed // S
        rank, counts = route_rank(shard, num_shards=S)
        overflow = jnp.any(counts > B)
        slot = jnp.minimum(rank, B - 1)

        def to_grid(arr):
            g = jnp.zeros((S, B) + arr.shape[1:], arr.dtype)
            return g.at[shard, rank].set(arr, mode="drop")

        spec = NamedSharding(self.mesh, P("shard"))
        grids = jax.tree.map(
            lambda g: jax.lax.with_sharding_constraint(g, spec),
            (
                to_grid(local),
                to_grid(jnp.asarray(ts_q, jnp.int32)),
                to_grid(jnp.asarray(req_lanes, jnp.float32)),
                tuple(
                    to_grid(jnp.asarray(j, jnp.int32)) for j in join_keys
                ),
                to_grid(key),
            ),
        )
        vals = jax.vmap(
            functools.partial(
                self._query_pure,
                use_preagg=use_preagg,
                wagg_order=wagg_order,
                ljoin_order=ljoin_order,
                req_lane_of=req_lane_of,
                join_col_index=join_col_index,
            )
        )(state, *grids)
        rep = NamedSharding(self.mesh, P())
        out = tuple(
            jax.lax.with_sharding_constraint(v[shard, slot], rep)
            for v in vals
        )
        scounts = (
            jnp.zeros((num_scen, S), jnp.int32)
            .at[jnp.asarray(scen, jnp.int32), shard]
            .add(jnp.asarray(valid, jnp.int32))
        )
        return out, scounts, overflow

    def _route_query_fn(self, mode: str, program, bucket: int, num_scen: int):
        key = (
            program.view.name if program is not None else "",
            mode,
            int(bucket),
            int(num_scen),
        )
        fn = self._fused_fns.get(key)
        if fn is None:
            subset = (
                {}
                if program is None
                else dict(
                    wagg_order=program.wagg_order,
                    ljoin_order=program.ljoin_order,
                    req_lane_of=program.req_lane_of,
                    join_col_index=program.join_col_index,
                )
            )
            fn = jax.jit(
                functools.partial(
                    self._route_query_pure,
                    bucket=int(bucket),
                    num_scen=int(num_scen),
                    use_preagg=(mode != "naive"),
                    **subset,
                )
            )
            self._fused_fns[key] = fn
        return fn

    def _note_route(
        self, tel, path: str, n_rows: int, q: int, bucket: int
    ) -> None:
        """Routing telemetry shared by both paths: rows routed per path
        plus the shard-layer padding accounting."""
        pad_rows = self.num_shards * bucket - q
        m = tel.metrics
        m.counter(
            "route_rows_total",
            "request rows routed to shards, per routing path", "1",
            labels=("path",),
        ).inc(int(n_rows), path=path)
        m.counter(
            "padding_rows_total", "filler rows added to reach shape bucket",
            "1", labels=("layer",),
        ).inc(pad_rows, layer="shard")
        m.gauge(
            "padding_waste_ratio", "filler rows / bucket rows, last batch",
            "1", labels=("layer",),
        ).set(
            pad_rows / max(self.num_shards * bucket, 1), layer="shard"
        )

    def _pad_request(self, key_h, ts_h, lanes, jks, valid_h, scen):
        """Pad flat request arrays to the power-of-two shape bucket by
        repeating the last row (read-only recompute; ``valid`` marks the
        filler so device-side histograms exclude it)."""
        q = int(key_h.shape[0])
        m = max(16, 1 << max(q - 1, 0).bit_length())
        if m != q:
            pad = m - q
            key_h = np.concatenate([key_h, np.repeat(key_h[-1:], pad)])
            ts_h = np.concatenate([ts_h, np.repeat(ts_h[-1:], pad)])
            lanes = jnp.concatenate(
                [lanes, jnp.broadcast_to(lanes[-1:], (pad, lanes.shape[1]))]
            )
            jks = tuple(
                np.concatenate([j, np.repeat(j[-1:], pad)]) for j in jks
            )
            valid_h = np.concatenate([valid_h, np.zeros(pad, bool)])
            scen = np.concatenate([scen, np.repeat(scen[-1:], pad)])
        return key_h, ts_h, lanes, jks, valid_h, scen, m

    def _route_dispatch(
        self, tel, mode, program, key_h, ts_h, lanes, jks, scen, valid_h,
        m: int, num_scen: int, q: int,
    ):
        """One fused device dispatch under the ``route.device`` span (plus
        the rare overflow re-dispatch at the safe capacity, inside the
        same span so span count == dispatches per batch stays 1)."""
        B = self._route_bucket(m)
        pname = program.view.name if program is not None else ""
        t_call = tel.clock.now()
        with tel.tracer.span(
            "route.device", kind="device", mode=mode, program=pname,
            rows=q, padded=m, bucket=B, shards=self.num_shards,
        ) as sp:
            fn = self._route_query_fn(mode, program, B, num_scen)
            vals, scounts, ovf = fn(
                self.state, key_h, ts_h, lanes, jks, scen, valid_h
            )
            vals, scounts = sp.fence((vals, scounts))
            if bool(np.asarray(ovf)):
                # optimistic capacity missed (pathological skew): rerun at
                # the always-safe bucket == batch size; bit-exactness never
                # depends on the optimistic guess
                B = 1 << max(m - 1, 0).bit_length()
                fn = self._route_query_fn(mode, program, B, num_scen)
                vals, scounts, _ = fn(
                    self.state, key_h, ts_h, lanes, jks, scen, valid_h
                )
                vals, scounts = sp.fence((vals, scounts))
        scounts_h = np.asarray(scounts, np.int64)
        self._note_route(tel, "device", scounts_h.sum(), q, B)
        self._note_query(tel, mode, program, (m, B), t_call)
        return vals, scounts_h

    def _query_device_routed(
        self,
        columns: Dict[str, jnp.ndarray],
        mode: str,
        program=None,
        valid: Optional[np.ndarray] = None,
        route_info: Optional[Dict] = None,
    ) -> Dict[str, jnp.ndarray]:
        """Single-program request path: one fused dispatch per batch.

        Host work shrinks to array conversion (``query.route`` span) and
        the post-expression finish (``query.scatter`` span); everything
        between — routing, padding, per-shard compute, gather-back — is
        the fenced ``route.device`` device span.
        """
        from repro.obs import get_telemetry

        tel = get_telemetry()
        self._validate_join_cols(columns, program)
        key_h = self._check_range(
            np.asarray(columns[self.schema.key]).astype(np.int32, copy=False),
            None,
        )
        q = int(key_h.shape[0])
        pname = program.view.name if program is not None else ""
        with tel.tracer.span(
            "query.route", mode=mode, program=pname, rows=q
        ):
            ts_h = np.asarray(columns[self.schema.ts]).astype(
                np.int32, copy=False
            )
            lane_exprs = None if program is None else program.lane_exprs
            join_cols = (
                self._join_cols if program is None else program.join_cols
            )
            lanes = jnp.asarray(self._lanes(columns, lane_exprs))
            jks = tuple(
                np.asarray(columns[c]).astype(np.int32, copy=False)
                for c in join_cols
            )
            vmask = (
                np.ones(q, bool)
                if valid is None
                else np.asarray(valid, bool)[:q]
            )
            key_p, ts_p, lanes_p, jks_p, valid_p, scen_p, m = (
                self._pad_request(
                    key_h, ts_h, lanes, jks, vmask, np.zeros(q, np.int32)
                )
            )
        vals, scounts = self._route_dispatch(
            tel, mode, program, key_p, ts_p, lanes_p, jks_p, scen_p,
            valid_p, m, 1, q,
        )
        if route_info is not None:
            route_info["shard_counts"] = scounts.sum(axis=0)
        with tel.tracer.span("query.scatter", rows=q):
            out = self._finish_query(
                columns, tuple(np.asarray(v)[:q] for v in vals), program
            )
        return out

    def route_and_query(
        self,
        columns: Dict[str, jnp.ndarray],
        scen: np.ndarray,
        num_scen: int,
        mode: str = "preagg",
        valid: Optional[np.ndarray] = None,
        route_info: Optional[Dict] = None,
    ):
        """Fused route+query for a MIXED multi-scenario batch — one device
        dispatch for rows tagged with ``scen`` (scenario ids in
        [0, num_scen)), against the merged store's FULL aggregation set.

        Every scenario of a plane shares the primary schema, so a mixed
        batch carries every column the merged program needs; computing
        the full (wagg + ljoin) set per row is bit-identical to each
        scenario's own program (per-answer compute depends only on that
        row's values).  Returns ``(vals, q)`` — the merged-order answer
        tuple still on device, (m,) arrays to slice to ``[:q]`` — and the
        caller (:meth:`repro.core.scenario.ScenarioPlane.query_mixed`)
        selects each scenario's features from the superset.  ``route_info``
        gains the on-device valid-masked ``"scenario_shard_counts"``
        (num_scen, S) histogram.
        """
        from repro.obs import get_telemetry

        if not self.device_routing:
            raise RuntimeError(
                "route_and_query is the fused device path; this store was "
                "built with device_routing=False (host-routed oracle)"
            )
        tel = get_telemetry()
        self._validate_join_cols(columns, None)
        key_h = self._check_range(
            np.asarray(columns[self.schema.key]).astype(np.int32, copy=False),
            None,
        )
        q = int(key_h.shape[0])
        scen_h = np.asarray(scen, np.int32)
        if scen_h.size and (
            scen_h.min() < 0 or scen_h.max() >= num_scen
        ):
            raise ValueError(
                f"scenario ids out of range [0, {num_scen}): "
                f"[{scen_h.min()}, {scen_h.max()}]"
            )
        with tel.tracer.span(
            "query.route", mode=mode, program="", rows=q
        ):
            ts_h = np.asarray(columns[self.schema.ts]).astype(
                np.int32, copy=False
            )
            lanes = jnp.asarray(self._lanes(columns, None))
            jks = tuple(
                np.asarray(columns[c]).astype(np.int32, copy=False)
                for c in self._join_cols
            )
            vmask = (
                np.ones(q, bool)
                if valid is None
                else np.asarray(valid, bool)[:q]
            )
            key_p, ts_p, lanes_p, jks_p, valid_p, scen_p, m = (
                self._pad_request(key_h, ts_h, lanes, jks, vmask, scen_h)
            )
        # padding repeats the last row's scenario tag but valid=False, so
        # the device histogram never counts it
        vals, scounts = self._route_dispatch(
            tel, mode, None, key_p, ts_p, lanes_p, jks_p, scen_p, valid_p,
            m, int(num_scen), q,
        )
        if route_info is not None:
            route_info["scenario_shard_counts"] = scounts
            route_info["shard_counts"] = scounts.sum(axis=0)
        return vals, q

    # -- observability ---------------------------------------------------------

    def shard_row_counts(self) -> np.ndarray:
        """Total primary rows ever ingested per shard (from ring cursors)."""
        return np.asarray(self.state.ring.cursor).sum(axis=1)
