"""Two-level window pre-aggregation — FeatInsight's long-window optimization.

The paper: "we apply pre-aggregation to handle long time intervals (e.g.,
for years) or hotspot data".  OpenMLDB materializes per-bucket partial
aggregates so a long RANGE window composes O(window/bucket) bucket aggs plus
two raw boundary scans, instead of scanning every raw row.

This module is now *only the bucket store*: a dense per-key ring of
persisted aggregate **states** of the algebra in
:mod:`repro.core.aggregates` — the full stat-lane vector (sum, count, min,
max, sumsq) plus the 32-bit distinct bitmap per (key, bucket, field),
maintained by the same fused-scatter ingest as the row store.  How those
states compose into window answers lives with the aggregator specs
(``AggSpec.fold_buckets`` / ``combine`` / ``finalize``), consumed by
:class:`repro.core.online.OnlineFeatureStore` — there is no aggregate
semantics here to drift out of sync.

A query composes:

    [raw tail rows in the newest partial bucket]      (scan, <= bucket rows)
  + [full buckets strictly inside the window]         (combine, <= NB aggs)
  + [raw head rows in the oldest partial bucket]      (scan, <= bucket rows)

For exact offline<->online consistency the raw ring must retain the boundary
buckets' rows; the middle composes losslessly because bucket rows are
``combine``-able states (sums associative, min/max/bitmap idempotent).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import aggregates as ag
from repro.core.aggregates import (
    LANES,
    NEG_INF,
    NUM_STATS,
    POS_INF,
    row_bitmap,
)

__all__ = [
    "BucketAgg",
    "bucket_init",
    "bucket_init_plan",
    "bucket_ingest",
    "row_stats",
    "stats_identity",
    "row_bitmap",
    "NUM_STATS",
    "POS_INF",
    "NEG_INF",
]

# lift / identity for the persisted full stat vector come straight from the
# lane monoids — the bucket store stores algebra states, nothing else
row_stats = ag.lanes_lift_stack
stats_identity = ag.lanes_identity_stack


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BucketAgg:
    """Per-key ring of per-bucket partial aggregate states.

    stats  : (K, NB, F, NUM_STATS) f32  stat-lane states (aggregates.LANES)
    bitmap : (K, NB, F) int32   32-bit linear-counting bitmap per field
    bucket : (K, NB) int32      absolute bucket id held in each slot (-1 empty)
    """

    stats: jnp.ndarray
    bitmap: jnp.ndarray
    bucket: jnp.ndarray
    size: int  # bucket width in time units (static)

    def tree_flatten(self):
        return (self.stats, self.bitmap, self.bucket), (self.size,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, size=aux[0])

    @property
    def num_buckets(self) -> int:
        return self.bucket.shape[1]


def bucket_init(num_keys: int, num_buckets: int, width: int, size: int) -> BucketAgg:
    return BucketAgg(
        stats=stats_identity((num_keys, num_buckets, width)),
        bitmap=jnp.zeros((num_keys, num_buckets, width), jnp.int32),
        bucket=jnp.full((num_keys, num_buckets), jnp.int32(-1)),
        size=size,
    )


def bucket_init_plan(plan, num_keys: int, width: int) -> BucketAgg:
    """Initialize a bucket store straight from a declarative
    :class:`~repro.core.layout.BucketPlan` — the store consumes the plan
    instead of re-deriving its sizing."""
    return bucket_init(num_keys, plan.num_buckets, width, plan.bucket_size)


def _segment_or_scan(bm: jnp.ndarray, new_seg: jnp.ndarray) -> jnp.ndarray:
    """Inclusive segmented bitwise-OR scan along axis 0."""

    def comb(a, b):
        flag_a, val_a = a
        flag_b, val_b = b
        val = jnp.where(flag_b, val_b, val_a | val_b)
        return flag_a | flag_b, val

    flags = new_seg
    if bm.ndim > 1:
        flags = jnp.broadcast_to(new_seg[:, None], bm.shape)
    _, out = jax.lax.associative_scan(comb, (flags, bm))
    return out


def _lane_scatter(target, index, update, lane_idx: int, lane: str):
    """Merge lifted lane states into stored states with the lane's own
    combine flavour (``.add`` / ``.min`` / ``.max``)."""
    at = target.at[index + (slice(None), lane_idx)]
    kind = ag.lane_scatter_kind(lane)
    if kind == "add":
        return at.add(update, mode="drop")
    if kind == "min":
        return at.min(update, mode="drop")
    return at.max(update, mode="drop")


def bucket_ingest(
    agg: BucketAgg,
    key: jnp.ndarray,   # (N,) int32 sorted by (key, ts)
    ts: jnp.ndarray,    # (N,) int32
    vals: jnp.ndarray,  # (N, F) f32
) -> BucketAgg:
    """Merge an ingest batch into bucket aggregates (one fused pass).

    Constraint (callers assert): a single batch spans fewer than NB buckets,
    so each (key, slot) receives at most one new bucket id.  Slots whose
    stored bucket id differs from the incoming id are reset first (ring
    reuse) — the scatter analogue of OpenMLDB finalizing an old bucket.

    All scatters route padding/no-op rows to out-of-bounds indices with
    mode="drop", so duplicate-index .set hazards cannot occur.
    """
    nb = agg.num_buckets
    K = agg.bucket.shape[0]
    bucket_id = ts // jnp.int32(agg.size)
    slot = bucket_id % nb

    n = key.shape[0]
    new_seg = jnp.concatenate(
        [
            jnp.array([True]),
            (key[1:] != key[:-1]) | (bucket_id[1:] != bucket_id[:-1]),
        ]
    )
    seg_id = jnp.cumsum(new_seg.astype(jnp.int32)) - 1  # (N,), 0..S-1

    rs = row_stats(vals)   # (N, F, NUM_STATS) lifted lane states
    bm = row_bitmap(vals)  # (N, F)

    # --- per-(key,bucket) segment reduction into scratch rows -------------
    width = vals.shape[1]
    seg_stats = stats_identity((n, width))
    for i, lane in enumerate(LANES):
        seg_stats = _lane_scatter(seg_stats, (seg_id,), rs[..., i], i, lane)
    or_scan = _segment_or_scan(bm, new_seg)  # (N, F) inclusive per segment

    # one representative (= last) row per segment
    seg_end = jnp.concatenate([new_seg[1:], jnp.array([True])])
    end_rows = jnp.nonzero(seg_end, size=n, fill_value=0)[0]
    num_segs = seg_id[-1] + 1
    seg_valid = jnp.arange(n, dtype=jnp.int32) < num_segs

    rep_key = key[end_rows]
    rep_slot = slot[end_rows]
    rep_bucket = bucket_id[end_rows]
    rep_stats = seg_stats[jnp.arange(n)]          # row s = segment s's totals
    rep_bm = or_scan[end_rows]

    # out-of-bounds key (=K) for padding rows => dropped by every scatter
    k_v = jnp.where(seg_valid, rep_key, jnp.int32(K))
    s_v = rep_slot

    # --- reset slots holding a stale bucket --------------------------------
    stored = agg.bucket.at[k_v, s_v].get(mode="fill", fill_value=-1)
    stale = seg_valid & (stored != rep_bucket) & (stored != -1)
    k_st = jnp.where(stale, rep_key, jnp.int32(K))
    stats = agg.stats.at[k_st, rep_slot].set(
        stats_identity((n, width)), mode="drop"
    )
    bitmap = agg.bitmap.at[k_st, rep_slot].set(
        jnp.zeros((n, width), jnp.int32), mode="drop"
    )

    # --- combine the new segment aggregates --------------------------------
    for i, lane in enumerate(LANES):
        stats = _lane_scatter(stats, (k_v, s_v), rep_stats[..., i], i, lane)

    # bitmap OR: (key, slot) pairs are unique among valid segments within a
    # batch (batch spans < NB buckets), so gather-OR-set is race-free.
    gathered = bitmap.at[k_v, s_v].get(mode="fill", fill_value=0)
    bitmap = bitmap.at[k_v, s_v].set(gathered | rep_bm, mode="drop")

    bucket_ids = agg.bucket.at[k_v, s_v].set(rep_bucket, mode="drop")
    return BucketAgg(stats=stats, bitmap=bitmap, bucket=bucket_ids, size=agg.size)
