"""Two-level window pre-aggregation — FeatInsight's long-window optimization.

The paper: "we apply pre-aggregation to handle long time intervals (e.g.,
for years) or hotspot data".  OpenMLDB materializes per-bucket partial
aggregates so a long RANGE window composes O(window/bucket) bucket aggs plus
two raw boundary scans, instead of scanning every raw row.

This module is now *only the bucket store*: a dense per-key ring of
persisted aggregate **states** of the algebra in
:mod:`repro.core.aggregates` — the full stat-lane vector (sum, count, min,
max, sumsq) plus the 32-bit distinct bitmap per (key, bucket, field),
maintained by the same fused-scatter ingest as the row store.  How those
states compose into window answers lives with the aggregator specs
(``AggSpec.fold_buckets`` / ``combine`` / ``finalize``), consumed by
:class:`repro.core.online.OnlineFeatureStore` — there is no aggregate
semantics here to drift out of sync.

A query composes:

    [raw tail rows in the newest partial bucket]      (scan, <= bucket rows)
  + [full buckets strictly inside the window]         (combine, <= NB aggs)
  + [raw head rows in the oldest partial bucket]      (scan, <= bucket rows)

For exact offline<->online consistency the raw ring must retain the boundary
buckets' rows; the middle composes losslessly because bucket rows are
``combine``-able states (sums associative, min/max/bitmap idempotent).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import aggregates as ag
from repro.core.aggregates import (
    LANES,
    NEG_INF,
    NUM_STATS,
    POS_INF,
    TOPN_TAIL,
    row_bitmap,
)

__all__ = [
    "BucketAgg",
    "bucket_init",
    "bucket_init_plan",
    "bucket_ingest",
    "row_stats",
    "stats_identity",
    "row_bitmap",
    "NUM_STATS",
    "POS_INF",
    "NEG_INF",
]

# lift / identity for the persisted full stat vector come straight from the
# lane monoids — the bucket store stores algebra states, nothing else
row_stats = ag.lanes_lift_stack
stats_identity = ag.lanes_identity_stack


_TS_EMPTY = jnp.int32(-2147483648)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BucketAgg:
    """Per-key ring of per-bucket partial aggregate states.

    stats  : (K, NB, F, NUM_STATS) f32  stat-lane states (aggregates.LANES)
    bitmap : (K, NB, F) int32   32-bit linear-counting bitmap per field
    bucket : (K, NB) int32      absolute bucket id held in each slot (-1 empty)

    Merge-order state families (``None`` unless the layout persists them —
    a view with FIRST/LAST/TOPN_FREQ over a RANGE window):

    seq    : (K,) int32         per-key arrival counter; the stored merge
                                ``pos`` of a row is its per-key arrival
                                index (mirrors the ring cursor)
    xts/xpos/xhas : (K, NB, 2)  extreme winner per direction
                                (0 = oldest / FIRST, 1 = newest / LAST);
                                winner row shared across lanes
    xval   : (K, NB, F, 2)      the winner row's lane values
    tts/tpos/tvalid : (K, NB, T) newest-first tail of the bucket's rows
                                by (ts, pos), T = aggregates.TOPN_TAIL
    tval   : (K, NB, F, T)      the tail rows' lane values
    """

    stats: jnp.ndarray
    bitmap: jnp.ndarray
    bucket: jnp.ndarray
    size: int  # bucket width in time units (static)
    seq: Optional[jnp.ndarray] = None
    xts: Optional[jnp.ndarray] = None
    xpos: Optional[jnp.ndarray] = None
    xval: Optional[jnp.ndarray] = None
    xhas: Optional[jnp.ndarray] = None
    tts: Optional[jnp.ndarray] = None
    tpos: Optional[jnp.ndarray] = None
    tval: Optional[jnp.ndarray] = None
    tvalid: Optional[jnp.ndarray] = None

    def tree_flatten(self):
        return (
            self.stats, self.bitmap, self.bucket, self.seq,
            self.xts, self.xpos, self.xval, self.xhas,
            self.tts, self.tpos, self.tval, self.tvalid,
        ), (self.size,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        stats, bitmap, bucket, *rest = children
        return cls(stats, bitmap, bucket, size=aux[0], seq=rest[0],
                   xts=rest[1], xpos=rest[2], xval=rest[3], xhas=rest[4],
                   tts=rest[5], tpos=rest[6], tval=rest[7], tvalid=rest[8])

    @property
    def num_buckets(self) -> int:
        return self.bucket.shape[1]


def bucket_init(
    num_keys: int, num_buckets: int, width: int, size: int,
    *, extreme: bool = False, tail: bool = False,
) -> BucketAgg:
    kw = {}
    if extreme or tail:
        kw["seq"] = jnp.zeros((num_keys,), jnp.int32)
    if extreme:
        kw["xts"] = jnp.full((num_keys, num_buckets, 2), _TS_EMPTY)
        kw["xpos"] = jnp.zeros((num_keys, num_buckets, 2), jnp.int32)
        kw["xval"] = jnp.zeros(
            (num_keys, num_buckets, width, 2), jnp.float32
        )
        kw["xhas"] = jnp.zeros((num_keys, num_buckets, 2), bool)
    if tail:
        kw["tts"] = jnp.full((num_keys, num_buckets, TOPN_TAIL), _TS_EMPTY)
        kw["tpos"] = jnp.zeros((num_keys, num_buckets, TOPN_TAIL), jnp.int32)
        kw["tval"] = jnp.zeros(
            (num_keys, num_buckets, width, TOPN_TAIL), jnp.float32
        )
        kw["tvalid"] = jnp.zeros((num_keys, num_buckets, TOPN_TAIL), bool)
    return BucketAgg(
        stats=stats_identity((num_keys, num_buckets, width)),
        bitmap=jnp.zeros((num_keys, num_buckets, width), jnp.int32),
        bucket=jnp.full((num_keys, num_buckets), jnp.int32(-1)),
        size=size,
        **kw,
    )


def bucket_init_plan(plan, num_keys: int, width: int) -> BucketAgg:
    """Initialize a bucket store straight from a declarative
    :class:`~repro.core.layout.BucketPlan` — the store consumes the plan
    instead of re-deriving its sizing (including which merge-order state
    families it persists)."""
    return bucket_init(
        num_keys, plan.num_buckets, width, plan.bucket_size,
        extreme=getattr(plan, "extreme", False),
        tail=getattr(plan, "tail", False),
    )


def _segment_or_scan(bm: jnp.ndarray, new_seg: jnp.ndarray) -> jnp.ndarray:
    """Inclusive segmented bitwise-OR scan along axis 0."""

    def comb(a, b):
        flag_a, val_a = a
        flag_b, val_b = b
        val = jnp.where(flag_b, val_b, val_a | val_b)
        return flag_a | flag_b, val

    flags = new_seg
    if bm.ndim > 1:
        flags = jnp.broadcast_to(new_seg[:, None], bm.shape)
    _, out = jax.lax.associative_scan(comb, (flags, bm))
    return out


def _lane_scatter(target, index, update, lane_idx: int, lane: str):
    """Merge lifted lane states into stored states with the lane's own
    combine flavour (``.add`` / ``.min`` / ``.max``)."""
    at = target.at[index + (slice(None), lane_idx)]
    kind = ag.lane_scatter_kind(lane)
    if kind == "add":
        return at.add(update, mode="drop")
    if kind == "min":
        return at.min(update, mode="drop")
    return at.max(update, mode="drop")


def bucket_ingest(
    agg: BucketAgg,
    key: jnp.ndarray,   # (N,) int32 sorted by (key, ts)
    ts: jnp.ndarray,    # (N,) int32
    vals: jnp.ndarray,  # (N, F) f32
) -> BucketAgg:
    """Merge an ingest batch into bucket aggregates (one fused pass).

    Constraint (callers assert): a single batch spans fewer than NB buckets,
    so each (key, slot) receives at most one new bucket id.  Slots whose
    stored bucket id differs from the incoming id are reset first (ring
    reuse) — the scatter analogue of OpenMLDB finalizing an old bucket.

    All scatters route padding/no-op rows to out-of-bounds indices with
    mode="drop", so duplicate-index .set hazards cannot occur.
    """
    nb = agg.num_buckets
    K = agg.bucket.shape[0]
    bucket_id = ts // jnp.int32(agg.size)
    slot = bucket_id % nb

    n = key.shape[0]
    new_seg = jnp.concatenate(
        [
            jnp.array([True]),
            (key[1:] != key[:-1]) | (bucket_id[1:] != bucket_id[:-1]),
        ]
    )
    seg_id = jnp.cumsum(new_seg.astype(jnp.int32)) - 1  # (N,), 0..S-1

    rs = row_stats(vals)   # (N, F, NUM_STATS) lifted lane states
    bm = row_bitmap(vals)  # (N, F)

    # --- per-(key,bucket) segment reduction into scratch rows -------------
    width = vals.shape[1]
    seg_stats = stats_identity((n, width))
    for i, lane in enumerate(LANES):
        seg_stats = _lane_scatter(seg_stats, (seg_id,), rs[..., i], i, lane)
    or_scan = _segment_or_scan(bm, new_seg)  # (N, F) inclusive per segment

    # one representative (= last) row per segment
    seg_end = jnp.concatenate([new_seg[1:], jnp.array([True])])
    end_rows = jnp.nonzero(seg_end, size=n, fill_value=0)[0]
    num_segs = seg_id[-1] + 1
    seg_valid = jnp.arange(n, dtype=jnp.int32) < num_segs

    rep_key = key[end_rows]
    rep_slot = slot[end_rows]
    rep_bucket = bucket_id[end_rows]
    rep_stats = seg_stats[jnp.arange(n)]          # row s = segment s's totals
    rep_bm = or_scan[end_rows]

    # out-of-bounds key (=K) for padding rows => dropped by every scatter
    k_v = jnp.where(seg_valid, rep_key, jnp.int32(K))
    s_v = rep_slot

    # --- reset slots holding a stale bucket --------------------------------
    stored = agg.bucket.at[k_v, s_v].get(mode="fill", fill_value=-1)
    stale = seg_valid & (stored != rep_bucket) & (stored != -1)
    k_st = jnp.where(stale, rep_key, jnp.int32(K))
    stats = agg.stats.at[k_st, rep_slot].set(
        stats_identity((n, width)), mode="drop"
    )
    bitmap = agg.bitmap.at[k_st, rep_slot].set(
        jnp.zeros((n, width), jnp.int32), mode="drop"
    )

    # --- combine the new segment aggregates --------------------------------
    for i, lane in enumerate(LANES):
        stats = _lane_scatter(stats, (k_v, s_v), rep_stats[..., i], i, lane)

    # bitmap OR: (key, slot) pairs are unique among valid segments within a
    # batch (batch spans < NB buckets), so gather-OR-set is race-free.
    gathered = bitmap.at[k_v, s_v].get(mode="fill", fill_value=0)
    bitmap = bitmap.at[k_v, s_v].set(gathered | rep_bm, mode="drop")

    bucket_ids = agg.bucket.at[k_v, s_v].set(rep_bucket, mode="drop")

    # --- merge-order state families (extreme / tail) -----------------------
    # Presence is a static pytree property, so plain python gating is fine
    # under jit.  Both families key row identity on (ts, pos) where pos is
    # the per-key arrival index: rows are sorted (key, ts) and arrive in
    # batch order, so within a key run pos = seq[key] + rank-in-run.
    seq = agg.seq
    xts, xpos, xval, xhas = agg.xts, agg.xpos, agg.xval, agg.xhas
    tts, tpos, tval, tvalid = agg.tts, agg.tpos, agg.tval, agg.tvalid
    if seq is not None:
        idx = jnp.arange(n, dtype=jnp.int32)
        new_key = jnp.concatenate([jnp.array([True]), key[1:] != key[:-1]])
        run_start = jax.lax.cummax(jnp.where(new_key, idx, 0))
        pos = seq.at[key].get(mode="fill", fill_value=0) + (idx - run_start)
        start_rows = jnp.nonzero(new_seg, size=n, fill_value=0)[0]

    if xts is not None:
        # within a segment ts and pos both ascend, so the lex-oldest row is
        # the segment's first row and the lex-newest its last
        c_rows = jnp.stack([start_rows, end_rows], axis=-1)   # (N, 2)
        c_ts = ts[c_rows]
        c_pos = pos[c_rows]
        c_val = vals[c_rows].transpose(0, 2, 1)               # (N, F, 2)

        xts = xts.at[k_st, rep_slot].set(
            jnp.full((n, 2), _TS_EMPTY), mode="drop")
        xpos = xpos.at[k_st, rep_slot].set(
            jnp.zeros((n, 2), jnp.int32), mode="drop")
        xval = xval.at[k_st, rep_slot].set(
            jnp.zeros((n, width, 2), jnp.float32), mode="drop")
        xhas = xhas.at[k_st, rep_slot].set(
            jnp.zeros((n, 2), bool), mode="drop")

        g_ts = xts.at[k_v, s_v].get(mode="fill", fill_value=_TS_EMPTY)
        g_pos = xpos.at[k_v, s_v].get(mode="fill", fill_value=0)
        g_val = xval.at[k_v, s_v].get(mode="fill", fill_value=0.0)
        g_has = xhas.at[k_v, s_v].get(mode="fill", fill_value=False)

        older = (c_ts < g_ts) | ((c_ts == g_ts) & (c_pos < g_pos))
        newer = (c_ts > g_ts) | ((c_ts == g_ts) & (c_pos > g_pos))
        want = jnp.stack([older[:, 0], newer[:, 1]], axis=-1)
        take = ~g_has | want                                  # (N, 2)

        xts = xts.at[k_v, s_v].set(
            jnp.where(take, c_ts, g_ts), mode="drop")
        xpos = xpos.at[k_v, s_v].set(
            jnp.where(take, c_pos, g_pos), mode="drop")
        xval = xval.at[k_v, s_v].set(
            jnp.where(take[:, None, :], c_val, g_val), mode="drop")
        xhas = xhas.at[k_v, s_v].set(jnp.ones((n, 2), bool), mode="drop")

    if tts is not None:
        T = tts.shape[-1]
        # newest-first candidate rows of each segment (row order is
        # (ts, pos) ascending, so counting back from end_rows is exact)
        t_rows = end_rows[:, None] - jnp.arange(T, dtype=jnp.int32)[None, :]
        in_seg = t_rows >= start_rows[:, None]                # (N, T)
        t_rc = jnp.clip(t_rows, 0, n - 1)
        ct_ts = jnp.where(in_seg, ts[t_rc], _TS_EMPTY)
        ct_pos = jnp.where(in_seg, pos[t_rc], _TS_EMPTY)
        ct_val = jnp.where(
            in_seg[:, None, :], vals[t_rc].transpose(0, 2, 1), 0.0)

        tts = tts.at[k_st, rep_slot].set(
            jnp.full((n, T), _TS_EMPTY), mode="drop")
        tpos = tpos.at[k_st, rep_slot].set(
            jnp.zeros((n, T), jnp.int32), mode="drop")
        tval = tval.at[k_st, rep_slot].set(
            jnp.zeros((n, width, T), jnp.float32), mode="drop")
        tvalid = tvalid.at[k_st, rep_slot].set(
            jnp.zeros((n, T), bool), mode="drop")

        gt_ts = tts.at[k_v, s_v].get(mode="fill", fill_value=_TS_EMPTY)
        gt_pos = tpos.at[k_v, s_v].get(mode="fill", fill_value=0)
        gt_val = tval.at[k_v, s_v].get(mode="fill", fill_value=0.0)
        gt_valid = tvalid.at[k_v, s_v].get(mode="fill", fill_value=False)

        m_ts = jnp.concatenate(
            [ct_ts, jnp.where(gt_valid, gt_ts, _TS_EMPTY)], axis=1)
        m_pos = jnp.concatenate(
            [ct_pos, jnp.where(gt_valid, gt_pos, _TS_EMPTY)], axis=1)
        m_val = jnp.concatenate([ct_val, gt_val], axis=2)     # (N, F, 2T)
        m_valid = jnp.concatenate([in_seg, gt_valid], axis=1)

        # LSD stable descending sort by (ts, pos): pos pass, then ts pass
        o1 = jnp.argsort(~m_pos, axis=1, stable=True)
        o2 = jnp.argsort(
            ~jnp.take_along_axis(m_ts, o1, axis=1), axis=1, stable=True)
        perm = jnp.take_along_axis(o1, o2, axis=1)

        s_ts = jnp.take_along_axis(m_ts, perm, axis=1)[:, :T]
        s_pos = jnp.take_along_axis(m_pos, perm, axis=1)[:, :T]
        s_valid = jnp.take_along_axis(m_valid, perm, axis=1)[:, :T]
        s_val = jnp.take_along_axis(
            m_val, perm[:, None, :], axis=2)[:, :, :T]

        tts = tts.at[k_v, s_v].set(s_ts, mode="drop")
        tpos = tpos.at[k_v, s_v].set(
            jnp.where(s_valid, s_pos, 0), mode="drop")
        tval = tval.at[k_v, s_v].set(
            jnp.where(s_valid[:, None, :], s_val, 0.0), mode="drop")
        tvalid = tvalid.at[k_v, s_v].set(s_valid, mode="drop")

    if seq is not None:
        seq = seq.at[key].add(jnp.ones_like(key), mode="drop")

    return BucketAgg(
        stats=stats, bitmap=bitmap, bucket=bucket_ids, size=agg.size,
        seq=seq, xts=xts, xpos=xpos, xval=xval, xhas=xhas,
        tts=tts, tpos=tpos, tval=tval, tvalid=tvalid,
    )
