"""Feature views, lineage, versioning — FeatInsight's management layer.

Paper §2 "Feature View Management": a *feature view* groups features defined
by a single computation statement; lineage links each feature to its view,
database (here: table schema), and defining expression; earlier versions of
deployed services are cached so users can reuse prior definitions and
"incrementally add new raw data attributes".

The visual DAG of the paper is literally the :mod:`repro.core.expr` tree; a
view's "SQL" rendering is produced by :func:`render_sql` for lineage display
(and to honor the demo's SQL-centric UX in a headless way).
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Optional, Tuple

from repro.core.expr import (
    Agg,
    BinOp,
    Col,
    Expr,
    Hash,
    Lit,
    Signature,
    UnOp,
    WindowAgg,
    collect_columns,
    collect_window_aggs,
)
from repro.core.storage import TableSchema

__all__ = ["FeatureView", "FeatureRegistry", "render_sql"]


def render_sql(name: str, expr: Expr, schema: TableSchema) -> str:
    """Render one feature's defining expression as OpenMLDB-flavoured SQL."""

    def r(e: Expr) -> str:
        if isinstance(e, Col):
            return e.name
        if isinstance(e, Lit):
            return repr(e.value)
        if isinstance(e, BinOp):
            sym = {
                "add": "+", "sub": "-", "mul": "*", "div": "/",
                "gt": ">", "lt": "<", "ge": ">=", "le": "<=", "eq": "=",
            }[e.op]
            return f"({r(e.lhs)} {sym} {r(e.rhs)})"
        if isinstance(e, UnOp):
            if e.op == "clip":
                lo, hi = e.params
                return f"clip({r(e.arg)}, {lo}, {hi})"
            return f"{e.op}({r(e.arg)})"
        if isinstance(e, Hash):
            return f"hash{e.bits}({r(e.arg)})"
        if isinstance(e, Signature):
            args = ", ".join(r(a) for a in e.args)
            return f"signature{e.bits}({args})"
        if isinstance(e, WindowAgg):
            w = e.window
            bound = (
                f"{w.size} PRECEDING"
                if w.mode == "range"
                else f"{w.size - 1} ROWS PRECEDING"
            )
            fn = e.agg.value
            if e.agg == Agg.TOPN_FREQ:
                fn = f"top{e.n + 1}_freq"
            return (
                f"{fn}({r(e.arg)}) OVER (PARTITION BY {schema.key} "
                f"ORDER BY {schema.ts} RANGE BETWEEN {bound} AND CURRENT ROW)"
            )
        raise TypeError(type(e))

    return f"SELECT {r(expr)} AS {name}"


@dataclasses.dataclass
class FeatureView:
    """A named, versioned set of features over one table schema."""

    name: str
    schema: TableSchema
    features: Dict[str, Expr]
    version: int = 1
    description: str = ""

    def lineage(self) -> Dict[str, Dict]:
        """feature -> {view, version, source columns, window specs, sql}."""
        out = {}
        for fname, expr in self.features.items():
            waggs = collect_window_aggs([expr])
            out[fname] = {
                "view": self.name,
                "version": self.version,
                "table": self.schema.name,
                "columns": list(collect_columns([expr])),
                "windows": [
                    {
                        "agg": w.agg.value,
                        "mode": w.window.mode,
                        "size": w.window.size,
                    }
                    for w in waggs.values()
                ],
                "sql": render_sql(fname, expr, self.schema),
            }
        return out

    def evolve(self, new_features: Dict[str, Expr], description: str = "") -> "FeatureView":
        """Incremental redefinition: prior features are kept, new/overridden
        ones merged, version bumped (the paper's cached-version reuse)."""
        merged = dict(self.features)
        merged.update(new_features)
        return FeatureView(
            name=self.name,
            schema=self.schema,
            features=merged,
            version=self.version + 1,
            description=description or self.description,
        )


class FeatureRegistry:
    """All views + version history + deployed services (the metadata plane).

    The paper persists this in the Sage-Studio control plane; here it is an
    in-process registry with JSON export so the launcher/checkpointer can
    persist it alongside model state.
    """

    def __init__(self) -> None:
        self._views: Dict[Tuple[str, int], FeatureView] = {}
        self._latest: Dict[str, int] = {}
        self._services: Dict[str, Dict] = {}
        self._events: List[Dict] = []

    # -- views ---------------------------------------------------------------

    def register(self, view: FeatureView) -> FeatureView:
        key = (view.name, view.version)
        if key in self._views:
            raise ValueError(f"view {key} already registered")
        self._views[key] = view
        self._latest[view.name] = max(
            self._latest.get(view.name, 0), view.version
        )
        self._log("register_view", view=view.name, version=view.version)
        return view

    def get(self, name: str, version: Optional[int] = None) -> FeatureView:
        v = version if version is not None else self._latest[name]
        return self._views[(name, v)]

    def versions(self, name: str) -> List[int]:
        return sorted(v for (n, v) in self._views if n == name)

    def lineage(self, name: str, feature: str, version: Optional[int] = None) -> Dict:
        return self.get(name, version).lineage()[feature]

    # -- services (deployments) ------------------------------------------------

    def deploy(
        self, service: str, view_name: str, version: Optional[int] = None,
        description: str = "",
    ) -> Dict:
        view = self.get(view_name, version)
        rec = {
            "service": service,
            "view": view.name,
            "version": view.version,
            "features": list(view.features),
            "tables": [view.schema.name],
            "description": description,
            "deployed_at": time.time(),
        }
        self._services[service] = rec
        self._log("deploy", **{k: rec[k] for k in ("service", "view", "version")})
        return rec

    def service(self, name: str) -> Dict:
        return self._services[name]

    # -- bookkeeping --------------------------------------------------------------

    def _log(self, kind: str, **kw) -> None:
        self._events.append({"kind": kind, "t": time.time(), **kw})

    def to_json(self) -> str:
        return json.dumps(
            {
                "views": [
                    {
                        "name": v.name,
                        "version": v.version,
                        "table": v.schema.name,
                        "features": {
                            f: render_sql(f, e, v.schema)
                            for f, e in v.features.items()
                        },
                    }
                    for v in self._views.values()
                ],
                "services": self._services,
            },
            indent=2,
            default=str,
        )
