"""Feature views, lineage, versioning — FeatInsight's management layer.

Paper §2 "Feature View Management": a *feature view* groups features defined
by a single computation statement; lineage links each feature to its view,
database (here: table schema), and defining expression; earlier versions of
deployed services are cached so users can reuse prior definitions and
"incrementally add new raw data attributes".

The visual DAG of the paper is literally the :mod:`repro.core.expr` tree; a
view's "SQL" rendering is produced by :func:`render_sql` for lineage display
(and to honor the demo's SQL-centric UX in a headless way).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.expr import (
    Agg,
    BinOp,
    Col,
    Expr,
    Hash,
    LastJoin,
    Lit,
    Signature,
    TableCol,
    UnOp,
    WindowAgg,
    collect_columns,
    collect_last_joins,
    collect_tables,
    collect_window_aggs,
)
from repro.core.storage import Database, TableSchema

__all__ = ["FeatureView", "FeatureRegistry", "render_sql"]


def render_sql(
    name: str,
    expr: Expr,
    schema: TableSchema,
    database: Optional[Database] = None,
) -> str:
    """Render one feature's defining expression as OpenMLDB-flavoured SQL.

    Multi-table features render OpenMLDB's two cross-table clauses: LAST
    JOINs appear as a ``FROM ... LAST JOIN ... ORDER BY ... ON ...`` clause
    (with the joined expression's columns table-qualified), and union
    windows carry the ``UNION table`` prefix inside ``OVER (...)``.
    """

    def r(e: Expr, table: Optional[str] = None) -> str:
        if isinstance(e, Col):
            return f"{table}.{e.name}" if table else e.name
        if isinstance(e, TableCol):
            return f"{e.table}.{e.name}"
        if isinstance(e, Lit):
            return repr(e.value)
        if isinstance(e, BinOp):
            sym = {
                "add": "+", "sub": "-", "mul": "*", "div": "/",
                "gt": ">", "lt": "<", "ge": ">=", "le": "<=", "eq": "=",
            }[e.op]
            return f"({r(e.lhs, table)} {sym} {r(e.rhs, table)})"
        if isinstance(e, UnOp):
            if e.op == "clip":
                lo, hi = e.params
                return f"clip({r(e.arg, table)}, {lo}, {hi})"
            return f"{e.op}({r(e.arg, table)})"
        if isinstance(e, Hash):
            return f"hash{e.bits}({r(e.arg, table)})"
        if isinstance(e, Signature):
            args = ", ".join(r(a, table) for a in e.args)
            return f"signature{e.bits}({args})"
        if isinstance(e, LastJoin):
            return r(e.arg, e.table)
        if isinstance(e, WindowAgg):
            w = e.window
            bound = (
                f"{w.size} PRECEDING"
                if w.mode == "range"
                else f"{w.size - 1} ROWS PRECEDING"
            )
            fn = e.agg.value
            if e.agg == Agg.TOPN_FREQ:
                fn = f"top{e.n + 1}_freq"
            union = "".join(f"UNION {t} " for t in e.union)
            return (
                f"{fn}({r(e.arg, table)}) OVER ({union}PARTITION BY "
                f"{schema.key} ORDER BY {schema.ts} "
                f"RANGE BETWEEN {bound} AND CURRENT ROW)"
            )
        raise TypeError(type(e))

    sql = f"SELECT {r(expr)} AS {name}"
    joins = collect_last_joins([expr])
    if joins:
        clauses = [f"FROM {schema.name}"]
        seen = set()
        for lj in joins.values():
            if (lj.table, lj.on) in seen:
                continue
            seen.add((lj.table, lj.on))
            jkey = (
                database.table(lj.table).key if database is not None else "key"
            )
            jts = (
                database.table(lj.table).ts if database is not None else "ts"
            )
            clauses.append(
                f"LAST JOIN {lj.table} ORDER BY {lj.table}.{jts} ON "
                f"{schema.name}.{lj.on} = {lj.table}.{jkey} AND "
                f"{lj.table}.{jts} <= {schema.name}.{schema.ts}"
            )
        sql += " " + " ".join(clauses)
    return sql


def _reject_stray_tablecols(e: Expr, fname: str) -> None:
    """Raise if a TableCol appears outside a LastJoin argument."""
    if isinstance(e, TableCol):
        raise ValueError(
            f"feature {fname!r}: TableCol({e.table!r}, {e.name!r}) outside a "
            "LAST JOIN argument — qualified columns only resolve inside "
            "last_join(...)"
        )
    if isinstance(e, LastJoin):
        return  # LastJoin.__post_init__ already validated its subtree
    for c in e.children():
        _reject_stray_tablecols(c, fname)


@dataclasses.dataclass
class FeatureView:
    """A named, versioned set of features over one table schema — or, when
    ``database`` is given, over a primary table plus secondary tables
    (point-in-time LAST JOINs and WINDOW UNION streams).

    ``schema`` remains the primary table's schema in both cases; for
    single-table views a one-table :class:`Database` is synthesized so every
    consumer can treat views uniformly.
    """

    name: str
    schema: Optional[TableSchema] = None
    features: Dict[str, Expr] = dataclasses.field(default_factory=dict)
    version: int = 1
    description: str = ""
    database: Optional[Database] = None

    def __post_init__(self) -> None:
        if self.schema is None and self.database is None:
            raise ValueError("FeatureView needs a schema or a database")
        if self.database is None:
            self.database = Database(
                name=self.schema.name, primary=self.schema
            )
        if self.schema is None:
            self.schema = self.database.primary
        if self.schema != self.database.primary:
            raise ValueError(
                f"schema {self.schema.name!r} must equal the database's "
                f"primary table {self.database.primary.name!r}"
            )
        # every referenced table must be a *secondary* table of the database:
        # a LAST JOIN / WINDOW UNION naming the primary table would be
        # silently unanswerable online (primary rows never reach a secondary
        # ring), so reject it here rather than diverge at serve time
        for t in collect_tables(list(self.features.values())):
            self.database.table(t)
            if not self.database.is_secondary(t):
                raise ValueError(
                    f"LAST JOIN / WINDOW UNION over the primary table "
                    f"{t!r} is not supported; register a secondary table"
                )
        # TableCol is only resolvable inside a LAST JOIN argument (it has no
        # table context elsewhere and would silently read the primary table)
        for fname, expr in self.features.items():
            _reject_stray_tablecols(expr, fname)

    @property
    def tables(self) -> List[str]:
        """All source tables actually referenced (primary first)."""
        return [self.schema.name] + list(
            collect_tables(list(self.features.values()))
        )

    def lineage(self) -> Dict[str, Dict]:
        """feature -> {view, version, source tables/columns, windows, joins, sql}."""
        out = {}
        for fname, expr in self.features.items():
            waggs = collect_window_aggs([expr])
            joins = collect_last_joins([expr])
            out[fname] = {
                "view": self.name,
                "version": self.version,
                "table": self.schema.name,
                "tables": [self.schema.name] + list(collect_tables([expr])),
                "columns": list(collect_columns([expr])),
                "windows": [
                    {
                        "agg": w.agg.value,
                        "mode": w.window.mode,
                        "size": w.window.size,
                        "union": list(w.union),
                    }
                    for w in waggs.values()
                ],
                "joins": [
                    {"table": j.table, "on": j.on, "default": j.default}
                    for j in joins.values()
                ],
                "sql": render_sql(fname, expr, self.schema, self.database),
            }
        return out

    def describe(self, registry: Optional["FeatureRegistry"] = None) -> str:
        """Markdown catalog entry for this view — the docs layer's unit.

        Renders what a feature-store catalog page must answer: which
        source tables feed the view (and in what role), what each output
        column computes (window/agg lineage + the OpenMLDB-flavoured SQL),
        and — when a ``registry`` is passed — which services deploy it.
        Deterministic output (no wall-clock times), so the generated
        ``docs/CATALOG.md`` can be CI-gated by regenerate-and-diff.
        """
        exprs = list(self.features.values())
        joins = collect_last_joins(exprs)
        waggs = collect_window_aggs(exprs)
        join_tables = {lj.table for lj in joins.values()}
        union_tables = set()
        for wa in waggs.values():
            union_tables.update(wa.union)

        def role(t: str) -> str:
            r = []
            if t in join_tables:
                r.append("LAST JOIN target")
            if t in union_tables:
                r.append("WINDOW UNION stream")
            return " + ".join(r) or "unreferenced"

        lines = [f"### `{self.name}` (v{self.version})", ""]
        if self.description:
            lines += [self.description, ""]
        lines += [
            "**Source tables**",
            "",
            "| table | role | key | ts | columns |",
            "|---|---|---|---|---|",
        ]
        prim = self.schema
        lines.append(
            f"| `{prim.name}` | primary | `{prim.key}` | `{prim.ts}` | "
            f"{', '.join(f'`{c}`' for c in prim.columns)} |"
        )
        for t in collect_tables(exprs):
            sch = self.database.table(t)
            lines.append(
                f"| `{sch.name}` | {role(t)} | `{sch.key}` | `{sch.ts}` | "
                f"{', '.join(f'`{c}`' for c in sch.columns)} |"
            )
        lines += ["", "**Features**", ""]
        for fname, rec in self.lineage().items():
            parts = []
            for w in rec["windows"]:
                u = (
                    f" UNION {'+'.join(w['union'])}" if w["union"] else ""
                )
                parts.append(
                    f"{w['agg']} over {w['size']} "
                    f"{'rows' if w['mode'] == 'rows' else 's RANGE'}{u}"
                )
            for j in rec["joins"]:
                parts.append(
                    f"LAST JOIN `{j['table']}` on `{j['on']}` "
                    f"(default {j['default']})"
                )
            kind = "; ".join(parts) or "row-level"
            cols = ", ".join(f"`{c}`" for c in rec["columns"]) or "—"
            lines += [
                f"- **`{fname}`** — {kind}; inputs: {cols}",
                "",
                "  ```sql",
                f"  {rec['sql']}",
                "  ```",
                "",
            ]
        if registry is not None:
            deps = registry.deployments(self.name)
            if deps:
                lines += ["**Deploy history**", ""]
                for d in deps:
                    extra = (
                        f" — {d['description']}" if d.get("description") else ""
                    )
                    lines.append(
                        f"- service `{d['service']}` ← `{d['view']}` "
                        f"v{d['version']} "
                        f"({len(d['features'])} features, "
                        f"{len(d['tables'])} tables){extra}"
                    )
                lines.append("")
        return "\n".join(lines)

    def evolve(self, new_features: Dict[str, Expr], description: str = "") -> "FeatureView":
        """Incremental redefinition: prior features are kept, new/overridden
        ones merged, version bumped (the paper's cached-version reuse)."""
        merged = dict(self.features)
        merged.update(new_features)
        return FeatureView(
            name=self.name,
            schema=self.schema,
            features=merged,
            version=self.version + 1,
            description=description or self.description,
            database=self.database,
        )


class FeatureRegistry:
    """All views + version history + deployed services (the metadata plane).

    The paper persists this in the Sage-Studio control plane; here it is an
    in-process registry with JSON export so the launcher/checkpointer can
    persist it alongside model state.

    ``clock`` is injectable — an ``repro.obs.Clock`` (its wall ``time()``
    is used), or a legacy bare callable returning epoch seconds — so
    deploy-history ordering and timestamps are deterministic under
    test/replay.  Real callers omit it and the registry follows the
    *plane* clock, ``repro.obs.get_telemetry().clock``, resolved lazily at
    each stamp: installing one ``FakeClock`` via ``use_telemetry`` drives
    the registry, every ``BatchScheduler``, and every span together.
    """

    def __init__(self, clock=None) -> None:
        self._views: Dict[Tuple[str, int], FeatureView] = {}
        self._latest: Dict[str, int] = {}
        self._services: Dict[str, Dict] = {}
        self._events: List[Dict] = []
        self._clock_src = clock

    def _clock(self) -> float:
        """Wall-epoch stamp from whichever clock governs this registry."""
        src = self._clock_src
        if src is None:
            from repro.obs import get_telemetry

            return get_telemetry().clock.time()
        if hasattr(src, "time"):
            return src.time()       # an obs.Clock (or compatible)
        return src()                # legacy bare callable

    # -- views ---------------------------------------------------------------

    def register(self, view: FeatureView) -> FeatureView:
        key = (view.name, view.version)
        if key in self._views:
            raise ValueError(f"view {key} already registered")
        self._views[key] = view
        self._latest[view.name] = max(
            self._latest.get(view.name, 0), view.version
        )
        self._log("register_view", view=view.name, version=view.version)
        return view

    def get(self, name: str, version: Optional[int] = None) -> FeatureView:
        v = version if version is not None else self._latest[name]
        return self._views[(name, v)]

    def versions(self, name: str) -> List[int]:
        return sorted(v for (n, v) in self._views if n == name)

    def lineage(self, name: str, feature: str, version: Optional[int] = None) -> Dict:
        return self.get(name, version).lineage()[feature]

    # -- services (deployments) ------------------------------------------------

    def deploy(
        self, service: str, view_name: str, version: Optional[int] = None,
        description: str = "",
    ) -> Dict:
        view = self.get(view_name, version)
        now = self._clock()
        rec = {
            "service": service,
            "view": view.name,
            "version": view.version,
            "features": list(view.features),
            "tables": view.tables,
            "description": description,
            "deployed_at": now,
        }
        self._services[service] = rec
        self._log(
            "deploy", t=now,
            **{k: rec[k] for k in ("service", "view", "version")},
        )
        return rec

    def service(self, name: str) -> Dict:
        return self._services[name]

    def deployments(self, view_name: Optional[str] = None) -> List[Dict]:
        """Deploy records (optionally for one view), in deploy order."""
        return [
            rec
            for rec in self._services.values()
            if view_name is None or rec["view"] == view_name
        ]

    # -- bookkeeping --------------------------------------------------------------

    def _log(self, kind: str, t: Optional[float] = None, **kw) -> None:
        self._events.append(
            {"kind": kind, "t": self._clock() if t is None else t, **kw}
        )

    def to_json(self) -> str:
        return json.dumps(
            {
                "views": [
                    {
                        "name": v.name,
                        "version": v.version,
                        "table": v.schema.name,
                        "tables": v.tables,
                        "features": {
                            f: render_sql(f, e, v.schema, v.database)
                            for f, e in v.features.items()
                        },
                    }
                    for v in self._views.values()
                ],
                "services": self._services,
            },
            indent=2,
            default=str,
        )
