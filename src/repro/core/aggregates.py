"""Unified aggregator algebra — one monoid spec per ``Agg``.

FeatInsight's offline/online consistency guarantee (§2(3)) only holds if
every execution path computes *the same function*.  OpenMLDB enforces that
by executing one SQL plan everywhere; this reproduction previously defined
each aggregate's semantics four separate times (offline prefix sums and a
sparse table in :mod:`~repro.core.windows`, bucket stats in
:mod:`~repro.core.preagg`, and naive/pre-agg/union branches in
:mod:`~repro.core.online`) — the exact inconsistency trap the paper's
architecture exists to avoid.

This module is now the single source of truth.  Every ``Agg`` is described
by one algebraic spec:

    init      — the identity state
    lift      — row -> state
    combine   — associative state merge
    finalize  — state -> feature value

and every layer is a *strategy for evaluating folds of that monoid*:

* offline batch scan   — segmented prefix sums (invertible lanes),
  segmented doubling folds (idempotent lanes / bitmaps), or closed forms
  (boundary rows, window tails);
* online naive         — fold over masked ring rows;
* online pre-agg       — fold over raw boundary rows ⊕ per-bucket partial
  states (the bucket store literally persists ``combine``-able states);
* WINDOW UNION         — fold across per-stream partial states;
* sharded plane        — the same folds vmapped over shards.

State families (one per representation, shared by several aggs):

``lanes``    a product of scalar lane monoids (sum, count, min, max,
             sumsq) — SUM/COUNT/MEAN/MIN/MAX/STD each select the lanes
             they need and share one lane definition;
``bitmap``   32-bit linear-counting OR-bitmap — DISTINCT_APPROX;
``extreme``  argmin/argmax by the merge order (ts, stream-rank, slot) —
             FIRST (oldest wins) and LAST (newest wins), which makes
             FIRST union-composable: combining per-stream oldest rows
             yields the merged stream's oldest row;
``tail``     the newest ``TOPN_TAIL`` rows by merge order, a mergeable
             sketch (top-k by (ts, rank, pos) of a union is associative)
             — TOPN_FREQ, now union-composable too.

All four families are bucket-composable: the bucket store persists stat
vectors and bitmaps for the lane/bitmap aggs, and per-bucket extreme /
tail states (with a per-key arrival counter as the stored ``pos``) for
FIRST / LAST / TOPN_FREQ — so every aggregate answers long RANGE windows
from pre-aggregates and ``preagg_fallback_total`` stays at zero.

The merge order matches :func:`repro.core.join.merge_streams`: at equal
timestamps, earlier streams (union tables, in declaration order) sort
*before* later ones, and the primary stream is last; within a stream,
arrival order breaks ties.  Cross-stream combines therefore compare
``(ts, rank, pos)`` lexicographically.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.expr import Agg
from repro.core.hashing import mix64

__all__ = [
    "LANES",
    "NUM_STATS",
    "POS_INF",
    "NEG_INF",
    "TOPN_TAIL",
    "AggSpec",
    "AGG_SPECS",
    "agg_spec",
    "lane_identity",
    "lane_lift",
    "lane_combine",
    "lane_masked_reduce",
    "lane_scatter_kind",
    "lanes_identity_stack",
    "lanes_lift_stack",
    "lanes_combine_stack",
    "row_bitmap",
    "bitmap_estimate",
    "topn_rank",
]

POS_INF = jnp.float32(3.0e38)
NEG_INF = jnp.float32(-3.0e38)
_TS_MIN = jnp.int32(-2147483648)
_TS_MAX = jnp.int32(2147483647)

TOPN_TAIL = 32  # contract: TOPN_FREQ windows are evaluated over <=32 rows

# ---------------------------------------------------------------------------
# Lane monoids — the shared scalar algebra behind SUM/COUNT/MEAN/MIN/MAX/STD
# and the bucket pre-aggregate store (one stat vector per (key, bucket)).
# ---------------------------------------------------------------------------

# stat-lane order == the bucket store's trailing axis layout
LANES: Tuple[str, ...] = ("sum", "count", "min", "max", "sumsq")
NUM_STATS = len(LANES)

_LANE_IDENT = {
    "sum": jnp.float32(0.0),
    "count": jnp.float32(0.0),
    "min": POS_INF,
    "max": NEG_INF,
    "sumsq": jnp.float32(0.0),
}

_LANE_LIFT = {
    "sum": lambda v: v,
    "count": lambda v: jnp.ones_like(v),
    "min": lambda v: v,
    "max": lambda v: v,
    "sumsq": lambda v: v * v,
}

_LANE_COMBINE = {
    "sum": jnp.add,
    "count": jnp.add,
    "min": jnp.minimum,
    "max": jnp.maximum,
    "sumsq": jnp.add,
}

# axis reduction consistent with each lane's combine (XLA-efficient form of
# a combine tree over one array axis)
_LANE_REDUCE = {
    "sum": jnp.sum,
    "count": jnp.sum,
    "min": jnp.min,
    "max": jnp.max,
    "sumsq": jnp.sum,
}

# scatter flavour consistent with each lane's combine (``.at[...].<kind>``)
# — how the bucket store merges lifted rows into persisted states
_LANE_SCATTER = {
    "sum": "add",
    "count": "add",
    "min": "min",
    "max": "max",
    "sumsq": "add",
}

# lanes whose lifted states form a *group* (combine is invertible): the
# offline engine may evaluate their window folds as prefix-sum differences
INVERTIBLE_LANES = ("sum", "count", "sumsq")
# lanes whose combine is idempotent: overlapping-range decompositions are
# valid (the doubling-fold query may use two overlapping power-of-two spans)
IDEMPOTENT_LANES = ("min", "max")


def lane_identity(lane: str) -> jnp.ndarray:
    return _LANE_IDENT[lane]


def lane_lift(lane: str, v: jnp.ndarray) -> jnp.ndarray:
    return _LANE_LIFT[lane](v)


def lane_combine(lane: str, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return _LANE_COMBINE[lane](a, b)


def lane_scatter_kind(lane: str) -> str:
    return _LANE_SCATTER[lane]


def lane_masked_reduce(
    lane: str, lifted: jnp.ndarray, mask: jnp.ndarray, axis: int
) -> jnp.ndarray:
    """Fold lifted states over ``axis``, masked rows contributing identity."""
    return _LANE_REDUCE[lane](
        jnp.where(mask, lifted, _LANE_IDENT[lane]), axis=axis
    )


def lanes_lift_stack(v: jnp.ndarray) -> jnp.ndarray:
    """(...,) values -> (..., NUM_STATS) full stat-vector states (the bucket
    store's row lift — buckets persist every lane so any agg can compose)."""
    return jnp.stack([_LANE_LIFT[l](v) for l in LANES], axis=-1)


def lanes_identity_stack(shape: Tuple[int, ...]) -> jnp.ndarray:
    """(shape, NUM_STATS) identity stat vectors."""
    out = jnp.zeros(shape + (NUM_STATS,), jnp.float32)
    for i, l in enumerate(LANES):
        if l in ("min", "max"):
            out = out.at[..., i].set(_LANE_IDENT[l])
    return out


def lanes_combine_stack(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Associative combine of full stat vectors (..., NUM_STATS)."""
    return jnp.stack(
        [
            _LANE_COMBINE[l](a[..., i], b[..., i])
            for i, l in enumerate(LANES)
        ],
        axis=-1,
    )


# ---------------------------------------------------------------------------
# Bitmap monoid — 32-bit linear counting (DISTINCT_APPROX)
# ---------------------------------------------------------------------------


def row_bitmap(vals: jnp.ndarray) -> jnp.ndarray:
    """Per-value 32-bit linear-counting bitmap contribution (the lift)."""
    return (jnp.int32(1) << mix64(vals, salt=77, bits=5)).astype(jnp.int32)


def bitmap_estimate(bits: jnp.ndarray) -> jnp.ndarray:
    """Linear-counting estimate from an OR-combined bitmap (the finalize)."""
    ones = jax.lax.population_count(bits).astype(jnp.float32)
    frac = jnp.clip(ones / 32.0, 0.0, 1.0 - 1e-6)
    return -32.0 * jnp.log1p(-frac)


def _or_reduce(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    return jax.lax.reduce(x, jnp.int32(0), jax.lax.bitwise_or, (axis,))


# ---------------------------------------------------------------------------
# Merge-order helpers (extreme / tail states)
# ---------------------------------------------------------------------------


def _lex_newer(a, b):
    """True where state-b's (ts, rank, pos) is strictly newer than a's."""
    return (
        (b["ts"] > a["ts"])
        | ((b["ts"] == a["ts"]) & (b["rank"] > a["rank"]))
        | (
            (b["ts"] == a["ts"])
            & (b["rank"] == a["rank"])
            & (b["pos"] > a["pos"])
        )
    )


def _desc_argsort(x: jnp.ndarray) -> jnp.ndarray:
    """Stable descending argsort of int32 keys (~x is monotone-decreasing
    and overflow-free, unlike -x at INT32_MIN)."""
    return jnp.argsort(~x, axis=-1, stable=True)


def _sort_tail_desc(state: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Order tail entries newest-first by (ts, rank, pos); invalid last.

    LSD radix of stable argsorts (pos, then rank, then ts), matching
    :func:`repro.core.join.merge_streams`'s tie rule exactly.
    """
    ts = jnp.where(state["valid"], state["ts"], _TS_MIN)
    rank = jnp.where(state["valid"], state["rank"], jnp.int32(-1))
    pos = jnp.where(state["valid"], state["pos"], _TS_MIN)

    def take(d, order):
        return {k: jnp.take_along_axis(v, order, axis=-1) for k, v in d.items()}

    cur = dict(state, ts=ts, rank=rank, pos=pos)
    for field in ("pos", "rank", "ts"):  # least-significant first
        cur = take(cur, _desc_argsort(cur[field]))
    return cur


def topn_rank(
    vals: jnp.ndarray, valid: jnp.ndarray, nth: int
) -> jnp.ndarray:
    """n-th most-frequent value over newest-first tail entries.

    ``vals``/``valid``: (..., T) with slot 0 the most recent entry.  Ranking
    rule (shared verbatim by offline, online, union, sharded): frequency
    desc, value asc, duplicate occurrences deduped to their most recent
    slot.  Returns 0.0 where fewer than ``nth + 1`` distinct values exist.
    """
    tail = vals.shape[-1]
    eq = (
        (vals[..., :, None] == vals[..., None, :])
        & valid[..., :, None]
        & valid[..., None, :]
    )
    freq = eq.sum(-1).astype(jnp.float32)
    freq = jnp.where(valid, freq, -1.0)
    earlier = jnp.tril(jnp.ones((tail, tail), bool), -1)
    same_as_earlier = (eq & earlier).any(-1)
    is_first = valid & ~same_as_earlier
    score = jnp.where(is_first, freq, -1.0)
    # rank by (freq desc, value asc) — composed into one sortable score
    vmax = jnp.max(jnp.abs(vals), initial=1.0)
    composite = score * (2.0 * vmax + 1.0) - vals
    order = jnp.argsort(-composite, axis=-1)
    pick = order[..., nth]
    picked_score = jnp.take_along_axis(score, pick[..., None], axis=-1)[..., 0]
    val = jnp.take_along_axis(vals, pick[..., None], axis=-1)[..., 0]
    return jnp.where(picked_score >= 0.0, val, 0.0)


# ---------------------------------------------------------------------------
# The spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AggSpec:
    """One aggregate's algebra: (init, lift, combine, finalize) + layout.

    States are dicts of arrays (pytrees), so one spec serves scalars,
    per-query batches, per-shard stacks, and bucket grids alike:

    ``lanes``:    {lane: (...,)}                     (selected stat lanes)
    ``bitmap``:   {"bits": (...,) int32}
    ``extreme``:  {"ts", "rank", "pos", "val", "has"}
    ``tail``:     {"ts", "rank", "pos", "val", "valid"}  each (..., T)
    """

    agg: Agg
    state: str                       # "lanes" | "bitmap" | "extreme" | "tail"
    lanes: Tuple[str, ...] = ()      # state == "lanes": which lanes
    newest: bool = False             # state == "extreme": LAST (vs FIRST)
    union_composable: bool = True
    bucket_composable: bool = False  # state persisted by the bucket store

    # -- init ---------------------------------------------------------------

    def init(self, shape: Tuple[int, ...] = ()) -> Dict[str, jnp.ndarray]:
        """Identity state of batch ``shape``."""
        if self.state == "lanes":
            return {
                l: jnp.broadcast_to(_LANE_IDENT[l], shape) for l in self.lanes
            }
        if self.state == "bitmap":
            return {"bits": jnp.zeros(shape, jnp.int32)}
        if self.state == "extreme":
            return {
                "ts": jnp.broadcast_to(_TS_MIN, shape),
                "rank": jnp.zeros(shape, jnp.int32),
                "pos": jnp.zeros(shape, jnp.int32),
                "val": jnp.zeros(shape, jnp.float32),
                "has": jnp.zeros(shape, bool),
            }
        # tail: zero-width entry set
        return {
            "ts": jnp.zeros(shape + (0,), jnp.int32),
            "rank": jnp.zeros(shape + (0,), jnp.int32),
            "pos": jnp.zeros(shape + (0,), jnp.int32),
            "val": jnp.zeros(shape + (0,), jnp.float32),
            "valid": jnp.zeros(shape + (0,), bool),
        }

    # -- lift ---------------------------------------------------------------

    def lift(
        self,
        val: jnp.ndarray,
        ts: jnp.ndarray,
        rank: jnp.ndarray,
        pos: jnp.ndarray,
    ) -> Dict[str, jnp.ndarray]:
        """Single row -> state.  ``(ts, rank, pos)`` is the row's merge-order
        coordinate (ignored by lanes/bitmap states)."""
        if self.state == "lanes":
            return {l: _LANE_LIFT[l](val) for l in self.lanes}
        if self.state == "bitmap":
            return {"bits": row_bitmap(val)}
        if self.state == "extreme":
            return {
                "ts": jnp.broadcast_to(ts, val.shape),
                "rank": jnp.broadcast_to(rank, val.shape),
                "pos": jnp.broadcast_to(pos, val.shape),
                "val": val,
                "has": jnp.ones(val.shape, bool),
            }
        return {
            "ts": jnp.broadcast_to(ts, val.shape)[..., None],
            "rank": jnp.broadcast_to(rank, val.shape)[..., None],
            "pos": jnp.broadcast_to(pos, val.shape)[..., None],
            "val": val[..., None],
            "valid": jnp.ones(val.shape + (1,), bool),
        }

    # -- combine ------------------------------------------------------------

    def combine(
        self, a: Dict[str, jnp.ndarray], b: Dict[str, jnp.ndarray]
    ) -> Dict[str, jnp.ndarray]:
        """Associative merge of two states."""
        if self.state == "lanes":
            return {l: _LANE_COMBINE[l](a[l], b[l]) for l in self.lanes}
        if self.state == "bitmap":
            return {"bits": a["bits"] | b["bits"]}
        if self.state == "extreme":
            if self.newest:
                pick_b = ~a["has"] | (b["has"] & _lex_newer(a, b))
            else:
                pick_b = ~a["has"] | (b["has"] & ~_lex_newer(a, b))
            pick_b = pick_b & b["has"]
            out = {
                k: jnp.where(pick_b, b[k], a[k])
                for k in ("ts", "rank", "pos", "val")
            }
            out["has"] = a["has"] | b["has"]
            return out
        # tail: union of entry sets, keep the TOPN_TAIL newest by merge order
        cat = {
            k: jnp.concatenate([a[k], b[k]], axis=-1)
            for k in ("ts", "rank", "pos", "val", "valid")
        }
        merged = _sort_tail_desc(cat)
        if merged["ts"].shape[-1] > TOPN_TAIL:
            merged = {k: v[..., :TOPN_TAIL] for k, v in merged.items()}
        return merged

    # -- fold strategies (shared by the online naive/pre-agg/union paths) ---

    def fold_rows(
        self,
        g: jnp.ndarray,       # (Q, C) lane values
        ts: jnp.ndarray,      # (Q, C) row timestamps
        mask: jnp.ndarray,    # (Q, C) in-window mask
        rank: jnp.ndarray,    # scalar int32 — the buffer's stream rank
    ) -> Dict[str, jnp.ndarray]:
        """Fold one ring buffer's masked rows into a state (axis 1).

        The buffer is slot-ordered oldest -> newest, so the slot index is
        the within-stream merge coordinate ``pos``.
        """
        C = g.shape[1]
        if self.state == "lanes":
            return {
                l: lane_masked_reduce(l, _LANE_LIFT[l](g), mask, 1)
                for l in self.lanes
            }
        if self.state == "bitmap":
            return {
                "bits": _or_reduce(
                    jnp.where(mask, row_bitmap(g), jnp.int32(0)), 1
                )
            }
        if self.state == "extreme":
            if self.newest:
                ts_m = jnp.where(mask, ts, _TS_MIN)
                best = jnp.max(ts_m, axis=1)
                cand = mask & (ts == best[:, None])
                pos = C - 1 - jnp.argmax(cand[:, ::-1], axis=1)
            else:
                ts_m = jnp.where(mask, ts, _TS_MAX)
                best = jnp.min(ts_m, axis=1)
                cand = mask & (ts == best[:, None])
                pos = jnp.argmax(cand, axis=1).astype(jnp.int32)
            val = jnp.take_along_axis(g, pos[:, None], axis=1)[:, 0]
            return {
                "ts": best,
                "rank": jnp.broadcast_to(rank, best.shape),
                "pos": pos.astype(jnp.int32),
                "val": val,
                "has": mask.any(axis=1),
            }
        # tail: the newest (TOPN_TAIL - 1) slots, masked — enough because a
        # merged tail of T rows takes at most T-1 from any one stream once
        # the request row is counted (matching the pre-algebra behaviour)
        t = min(TOPN_TAIL - 1, C)
        sl = slice(C - t, C)
        pos = jnp.arange(C, dtype=jnp.int32)[sl][::-1]
        return {
            "ts": jnp.broadcast_to(ts[:, sl][:, ::-1], mask[:, sl].shape),
            "rank": jnp.broadcast_to(rank, (g.shape[0], t)),
            "pos": jnp.broadcast_to(pos, (g.shape[0], t)),
            "val": g[:, sl][:, ::-1],
            "valid": mask[:, sl][:, ::-1],
        }

    def fold_buckets(
        self,
        stats: jnp.ndarray,   # (Q, M, NUM_STATS) gathered bucket stat rows
        bitmap: jnp.ndarray,  # (Q, M) gathered bucket bitmaps
        ok: jnp.ndarray,      # (Q, M) bucket-valid mask
        ext: Dict[str, jnp.ndarray] = None,  # gathered extreme/tail arrays
        rank: jnp.ndarray = None,            # stream rank to stamp on states
    ) -> Dict[str, jnp.ndarray]:
        """Fold pre-aggregated bucket states (bucket_composable specs only).

        The bucket store persists full stat vectors and bitmaps — i.e. the
        lifted-and-combined states of this algebra — so composing a long
        window is just more ``combine``.  Extreme/tail specs read their
        persisted merge-order states from ``ext`` instead: for extreme,
        ``{ts, pos, val, has}`` each (Q, M, 2) with the trailing axis the
        direction (0 = oldest, 1 = newest); for tail, ``{ts, pos, val,
        valid}`` each (Q, M, T) newest-first per bucket.  Buckets cover
        disjoint ts ranges, so cross-bucket ties never happen and the
        stored per-key arrival ``pos`` only ever breaks ties within one
        bucket — where it is exact.
        """
        if self.state == "lanes":
            return {
                l: lane_masked_reduce(
                    l, stats[..., LANES.index(l)], ok, 1
                )
                for l in self.lanes
            }
        if self.state == "bitmap":
            return {
                "bits": _or_reduce(jnp.where(ok, bitmap, jnp.int32(0)), 1)
            }
        if ext is None:
            raise ValueError(
                f"{self.agg} bucket states need the store's extreme/tail "
                "arrays (layout planned without them)"
            )
        if self.state == "extreme":
            d = 1 if self.newest else 0
            ts, pos = ext["ts"][..., d], ext["pos"][..., d]
            val = ext["val"][..., d]
            has = ext["has"][..., d] & ok
            if self.newest:
                ts_m = jnp.where(has, ts, _TS_MIN)
                best_ts = jnp.max(ts_m, axis=1)
                cand = has & (ts == best_ts[:, None])
                pos_m = jnp.where(cand, pos, _TS_MIN)
                best_pos = jnp.max(pos_m, axis=1)
            else:
                ts_m = jnp.where(has, ts, _TS_MAX)
                best_ts = jnp.min(ts_m, axis=1)
                cand = has & (ts == best_ts[:, None])
                pos_m = jnp.where(cand, pos, _TS_MAX)
                best_pos = jnp.min(pos_m, axis=1)
            pick = jnp.argmax(cand & (pos == best_pos[:, None]), axis=1)
            v = jnp.take_along_axis(val, pick[:, None], axis=1)[:, 0]
            return {
                "ts": best_ts,
                "rank": jnp.broadcast_to(rank, best_ts.shape),
                "pos": best_pos,
                "val": v,
                "has": has.any(axis=1),
            }
        # tail: every gathered bucket's tail entries, newest TOPN_TAIL kept
        flat = lambda x: x.reshape(x.shape[0], -1)  # noqa: E731
        valid = flat(ext["valid"] & ok[..., None])
        state = {
            "ts": flat(ext["ts"]),
            "rank": jnp.broadcast_to(rank, valid.shape),
            "pos": flat(ext["pos"]),
            "val": flat(ext["val"]),
            "valid": valid,
        }
        merged = _sort_tail_desc(state)
        if merged["ts"].shape[-1] > TOPN_TAIL:
            merged = {k: v[..., :TOPN_TAIL] for k, v in merged.items()}
        return merged

    # -- finalize -----------------------------------------------------------

    def finalize(self, s: Dict[str, jnp.ndarray], n: int = 0) -> jnp.ndarray:
        """State -> feature value (the one definition every path shares)."""
        a = self.agg
        if a == Agg.SUM:
            return s["sum"]
        if a == Agg.COUNT:
            return s["count"]
        if a == Agg.MEAN:
            return s["sum"] / jnp.maximum(s["count"], 1.0)
        if a == Agg.MIN:
            return s["min"]
        if a == Agg.MAX:
            return s["max"]
        if a == Agg.STD:
            cnt = jnp.maximum(s["count"], 1.0)
            m = s["sum"] / cnt
            return jnp.sqrt(jnp.maximum(s["sumsq"] / cnt - m * m, 0.0))
        if a == Agg.DISTINCT_APPROX:
            return bitmap_estimate(s["bits"])
        if a in (Agg.FIRST, Agg.LAST):
            return s["val"]
        if a == Agg.TOPN_FREQ:
            return topn_rank(s["val"], s["valid"], n)
        raise ValueError(f"unhandled agg {a}")


# ---------------------------------------------------------------------------
# The registry — exactly one spec per Agg
# ---------------------------------------------------------------------------

AGG_SPECS: Dict[Agg, AggSpec] = {
    Agg.SUM: AggSpec(Agg.SUM, "lanes", lanes=("sum",), bucket_composable=True),
    Agg.COUNT: AggSpec(
        Agg.COUNT, "lanes", lanes=("count",), bucket_composable=True
    ),
    Agg.MEAN: AggSpec(
        Agg.MEAN, "lanes", lanes=("sum", "count"), bucket_composable=True
    ),
    Agg.MIN: AggSpec(Agg.MIN, "lanes", lanes=("min",), bucket_composable=True),
    Agg.MAX: AggSpec(Agg.MAX, "lanes", lanes=("max",), bucket_composable=True),
    Agg.STD: AggSpec(
        Agg.STD, "lanes", lanes=("sum", "count", "sumsq"),
        bucket_composable=True,
    ),
    Agg.DISTINCT_APPROX: AggSpec(
        Agg.DISTINCT_APPROX, "bitmap", bucket_composable=True
    ),
    Agg.FIRST: AggSpec(
        Agg.FIRST, "extreme", newest=False, bucket_composable=True
    ),
    Agg.LAST: AggSpec(
        Agg.LAST, "extreme", newest=True, bucket_composable=True
    ),
    Agg.TOPN_FREQ: AggSpec(Agg.TOPN_FREQ, "tail", bucket_composable=True),
}


def agg_spec(agg: Agg) -> AggSpec:
    return AGG_SPECS[agg]
