"""Point-in-time multi-table primitives: LAST JOIN + WINDOW UNION.

OpenMLDB (FeatInsight's execution engine) gets its multi-table
expressiveness from two constructs, both reproduced here as dense
data-parallel TPU primitives over (key, ts)-sorted arrays:

* **LAST JOIN** — for each primary row, the most recent secondary row with
  a matching key and ``ts <= primary ts``.  On CPU OpenMLDB walks the
  secondary skiplist; here the secondary table is (key, ts)-sorted once and
  every primary row resolves with one vectorized lexicographic binary
  search (``searchsorted`` semantics, 32 halving steps, fully
  data-parallel) followed by one gather.
* **WINDOW UNION** — the per-key window is evaluated over the primary
  stream *merged by timestamp* with secondary streams.  We materialize the
  merge: concatenate the streams, stable-sort by (key, ts, stream-rank)
  (secondary rows sort before primary rows at equal timestamps, so they are
  visible to the primary row's window — OpenMLDB's union rows enter the
  window at their own timestamps), run the ordinary segmented window
  machinery (:func:`repro.core.windows.windowed_aggregate`) over the merged
  stream, and read results back at the primary rows' positions.

Everything is int32-safe (no int64 composites — JAX's default x32 mode
silently truncates int64), jit-traceable, and shape-static.
"""

from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "pit_searchsorted",
    "last_join_gather",
    "merge_streams",
]


def pit_searchsorted(
    skey: jnp.ndarray,  # (M,) int32, sorted by (key, ts)
    sts: jnp.ndarray,   # (M,) int32
    qkey: jnp.ndarray,  # (Q,) int32 query join keys
    qts: jnp.ndarray,   # (Q,) int32 query timestamps
) -> jnp.ndarray:
    """Right insertion point of (qkey, qts) in the sorted (skey, sts) pairs.

    Returns (Q,) int32 counts of rows with (skey, sts) <= (qkey, qts)
    lexicographically — i.e. ``searchsorted(..., side="right")`` over the
    pair ordering, without materializing an int64 composite (x32-safe).
    """
    m = skey.shape[0]
    lo = jnp.zeros(qkey.shape, jnp.int32)
    hi = jnp.full(qkey.shape, m, jnp.int32)
    steps = max(1, int(math.ceil(math.log2(max(m, 2)))) + 1)

    def body(_, lohi):
        lo, hi = lohi
        active = lo < hi
        mid = (lo + hi) // 2
        midc = jnp.minimum(mid, m - 1)
        k_m, t_m = skey[midc], sts[midc]
        le = (k_m < qkey) | ((k_m == qkey) & (t_m <= qts))
        lo = jnp.where(active & le, mid + 1, lo)
        hi = jnp.where(active & ~le, mid, hi)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def last_join_gather(
    skey: jnp.ndarray,   # (M,) int32, secondary sorted by (key, ts)
    sts: jnp.ndarray,    # (M,) int32
    svals: jnp.ndarray,  # (M,) f32 pre-evaluated join expression values
    qkey: jnp.ndarray,   # (Q,) int32 primary join-key column
    qts: jnp.ndarray,    # (Q,) int32 primary timestamps
    default: float = 0.0,
) -> jnp.ndarray:
    """Point-in-time LAST JOIN gather.

    For each query row: the value of the newest secondary row with
    ``skey == qkey`` and ``sts <= qts``; ``default`` when no row matches
    (including the empty-secondary-table case).
    """
    m = skey.shape[0]
    if m == 0:
        return jnp.full(qkey.shape, jnp.float32(default))
    j = pit_searchsorted(skey, sts, qkey, qts) - 1
    jc = jnp.maximum(j, 0)
    found = (j >= 0) & (skey[jc] == qkey)
    return jnp.where(found, svals[jc], jnp.float32(default))


def _stable_argsort_by(vals: jnp.ndarray, perm: jnp.ndarray) -> jnp.ndarray:
    """Compose ``perm`` with a stable argsort of ``vals[perm]``."""
    order = jnp.argsort(vals[perm], stable=True)
    return perm[order]


def merge_streams(
    keys: Sequence[jnp.ndarray],
    tss: Sequence[jnp.ndarray],
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Merge several (key, ts) streams into one (key, ts, rank)-sorted stream.

    ``keys[i]``/``tss[i]`` is stream i; stream order is the tie-rank: at
    equal (key, ts), rows of an earlier stream sort first.  Callers place
    secondary (union) streams before the primary stream so union rows are
    inside the primary row's window at equal timestamps.

    Returns (perm, key_m, ts_m, rank_m): ``perm`` indexes the concatenated
    arrays (concatenation order = stream order), and key/ts/rank are the
    merged sorted streams.  LSD radix of three stable argsorts — stability
    makes rows of one stream keep their relative order, which is what lets
    the caller map merged positions back to per-stream row order.
    """
    rank = jnp.concatenate(
        [
            jnp.full(k.shape, jnp.int32(i))
            for i, k in enumerate(keys)
        ]
    )
    key = jnp.concatenate(list(keys)).astype(jnp.int32)
    ts = jnp.concatenate(list(tss)).astype(jnp.int32)

    # concatenation order is already (rank, within-stream order): the first
    # LSD pass (stable sort by rank) is the identity permutation.
    perm = jnp.arange(key.shape[0], dtype=jnp.int32)
    perm = _stable_argsort_by(ts, perm)
    perm = _stable_argsort_by(key, perm)
    return perm, key[perm], ts[perm], rank[perm]
