"""FeatInsight core: feature views, unified offline/online computation,
compact time-series storage, signatures, and consistency verification."""

from repro.core.expr import (  # noqa: F401
    Agg,
    Col,
    Expr,
    Hash,
    LastJoin,
    Lit,
    Signature,
    TableCol,
    WindowAgg,
    WindowSpec,
    last_join,
    range_window,
    rows_window,
    w_count,
    w_distinct_approx,
    w_first,
    w_last,
    w_max,
    w_mean,
    w_min,
    w_std,
    w_sum,
    w_topn_freq,
)
from repro.core.aggregates import AGG_SPECS, AggSpec, agg_spec  # noqa: F401
from repro.core.storage import Database, RowCodec, TableSchema  # noqa: F401
from repro.core.layout import (  # noqa: F401
    BucketPlan,
    LaneSlot,
    RingPlan,
    StoreLayout,
    diff_layouts,
    plan_layout,
)
from repro.core.view import FeatureRegistry, FeatureView, render_sql  # noqa: F401
from repro.core.engine import OfflineEngine  # noqa: F401
from repro.core.online import OnlineFeatureStore, QueryProgram  # noqa: F401
from repro.core.migrate import MigrationReport  # noqa: F401
from repro.core.shard import ShardedOnlineStore, make_shard_mesh  # noqa: F401
from repro.core.scenario import ScenarioPlane, merge_views  # noqa: F401
from repro.core.consistency import ConsistencyReport, verify_view  # noqa: F401
