"""Feature expression DAG — FeatInsight's declarative feature language.

The paper builds features from a visual DAG that compiles to SQL executed by
OpenMLDB.  Here the DAG *is* the IR: a small expression tree of row-level
operations and window aggregations that compiles (via :mod:`repro.core.engine`)
to a single fused, jit-compiled XLA executable per feature view.

Two strata:

* **row-level** expressions (``Col``, ``Lit``, arithmetic, comparisons,
  ``Hash``, ``Signature``) — evaluated pointwise over a batch of rows;
* **window aggregations** (``WindowAgg``) — evaluated per key over a ROWS
  or RANGE window ending at (and including) the current row, exactly the
  OpenMLDB ``window ... rows_range between ... and current row`` semantics.

Window aggregations may themselves feed further row-level expressions
(e.g. ``w_sum(amount, 1h) / w_count(amount, 1h)``), mirroring how FeatInsight
users chain SQL blocks.

Multi-table views (the paper's "large-scale, complex raw data" — e.g. the
2018 PHM dataset's 17 tables) add a third stratum, mirroring OpenMLDB's two
cross-table constructs:

* ``LastJoin`` — point-in-time LAST JOIN: for each primary row, the most
  recent secondary-table row with a matching key and ``ts <= row ts``;
  the joined row feeds a row-level sub-expression (``TableCol`` /
  ``Col`` references resolve against the secondary table);
* ``WindowAgg(..., union=("table", ...))`` — WINDOW UNION: the per-key
  RANGE window is evaluated over the primary stream merged by timestamp
  with the named secondary streams (OpenMLDB's ``WINDOW ... UNION``).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

__all__ = [
    "Agg",
    "WindowSpec",
    "Expr",
    "Col",
    "TableCol",
    "Lit",
    "BinOp",
    "UnOp",
    "Hash",
    "Signature",
    "WindowAgg",
    "LastJoin",
    "last_join",
    "UNION_AGGS",
    "rows_window",
    "range_window",
    "w_sum",
    "w_count",
    "w_mean",
    "w_min",
    "w_max",
    "w_std",
    "w_first",
    "w_last",
    "w_distinct_approx",
    "w_topn_freq",
    "collect_window_aggs",
    "collect_last_joins",
    "collect_columns",
    "collect_tables",
]


class Agg(enum.Enum):
    """Window aggregation kinds (the paper's 'specialized ML functions')."""

    SUM = "sum"
    COUNT = "count"
    MEAN = "mean"
    MIN = "min"
    MAX = "max"
    STD = "std"
    FIRST = "first"
    LAST = "last"
    DISTINCT_APPROX = "distinct_approx"  # 32-bit linear counting
    TOPN_FREQ = "topn_freq"              # exact over the window tail


@dataclasses.dataclass(frozen=True)
class WindowSpec:
    """A per-key window ending at the current row (inclusive).

    mode="rows":  the last ``size`` rows of the same key.
    mode="range": rows of the same key with ``ts in (t_now - size, t_now]``.

    ``bucket`` is the pre-aggregation granularity used by the online store
    (and the Pallas window kernel) for RANGE windows; it does not change the
    result, only how it is computed.
    """

    mode: str
    size: int
    bucket: int = 0

    def __post_init__(self) -> None:
        if self.mode not in ("rows", "range"):
            raise ValueError(f"bad window mode {self.mode!r}")
        if self.size <= 0:
            raise ValueError("window size must be positive")


def rows_window(size: int) -> WindowSpec:
    return WindowSpec("rows", size)


def range_window(size: int, bucket: int = 0) -> WindowSpec:
    return WindowSpec("range", size, bucket)


# ---------------------------------------------------------------------------
# Expression nodes
# ---------------------------------------------------------------------------


class Expr:
    """Base class; supports operator overloading for row-level math."""

    # -- arithmetic ---------------------------------------------------------
    def __add__(self, o: Any) -> "Expr":
        return BinOp("add", self, _wrap(o))

    def __radd__(self, o: Any) -> "Expr":
        return BinOp("add", _wrap(o), self)

    def __sub__(self, o: Any) -> "Expr":
        return BinOp("sub", self, _wrap(o))

    def __rsub__(self, o: Any) -> "Expr":
        return BinOp("sub", _wrap(o), self)

    def __mul__(self, o: Any) -> "Expr":
        return BinOp("mul", self, _wrap(o))

    def __rmul__(self, o: Any) -> "Expr":
        return BinOp("mul", _wrap(o), self)

    def __truediv__(self, o: Any) -> "Expr":
        return BinOp("div", self, _wrap(o))

    def __rtruediv__(self, o: Any) -> "Expr":
        return BinOp("div", _wrap(o), self)

    def __neg__(self) -> "Expr":
        return UnOp("neg", self)

    # -- comparisons (produce 0/1 f32 features) ------------------------------
    def __gt__(self, o: Any) -> "Expr":
        return BinOp("gt", self, _wrap(o))

    def __lt__(self, o: Any) -> "Expr":
        return BinOp("lt", self, _wrap(o))

    def __ge__(self, o: Any) -> "Expr":
        return BinOp("ge", self, _wrap(o))

    def __le__(self, o: Any) -> "Expr":
        return BinOp("le", self, _wrap(o))

    def eq(self, o: Any) -> "Expr":
        return BinOp("eq", self, _wrap(o))

    def log1p(self) -> "Expr":
        return UnOp("log1p", self)

    def abs(self) -> "Expr":
        return UnOp("abs", self)

    def clip(self, lo: float, hi: float) -> "Expr":
        return UnOp("clip", self, params=(float(lo), float(hi)))

    # -- structural ----------------------------------------------------------
    def children(self) -> Tuple["Expr", ...]:
        return ()

    @property
    def key(self) -> Tuple:
        """Hashable structural identity used for CSE / lineage."""
        raise NotImplementedError


def _wrap(v: Any) -> "Expr":
    if isinstance(v, Expr):
        return v
    return Lit(float(v))


@dataclasses.dataclass(frozen=True, eq=False)
class Col(Expr):
    """Reference to a source-table column (lineage leaf).

    Resolves against whichever table the enclosing context evaluates over:
    the primary table for ordinary features, the joined table inside a
    :class:`LastJoin` argument, and *every* unioned table for a
    ``WindowAgg(..., union=...)`` argument (the name must exist in all of
    them — OpenMLDB's WINDOW UNION schema-compatibility rule).
    """

    name: str

    @property
    def key(self) -> Tuple:
        return ("col", self.name)


@dataclasses.dataclass(frozen=True, eq=False)
class TableCol(Expr):
    """Explicitly table-qualified column reference (lineage leaf).

    Only meaningful inside a :class:`LastJoin` argument, where it must name
    the joined table; it resolves to that table's column and records the
    qualified source in lineage.
    """

    table: str
    name: str

    @property
    def key(self) -> Tuple:
        return ("tcol", self.table, self.name)


@dataclasses.dataclass(frozen=True, eq=False)
class Lit(Expr):
    value: float

    @property
    def key(self) -> Tuple:
        return ("lit", self.value)


_BINOPS: Dict[str, Callable] = {
    "add": jnp.add,
    "sub": jnp.subtract,
    "mul": jnp.multiply,
    "div": lambda a, b: a / jnp.where(b == 0, 1.0, b),
    "gt": lambda a, b: (a > b).astype(jnp.float32),
    "lt": lambda a, b: (a < b).astype(jnp.float32),
    "ge": lambda a, b: (a >= b).astype(jnp.float32),
    "le": lambda a, b: (a <= b).astype(jnp.float32),
    "eq": lambda a, b: (a == b).astype(jnp.float32),
}

_UNOPS: Dict[str, Callable] = {
    "neg": jnp.negative,
    "log1p": lambda x: jnp.log1p(jnp.maximum(x, 0.0)),
    "abs": jnp.abs,
}


@dataclasses.dataclass(frozen=True, eq=False)
class BinOp(Expr):
    op: str
    lhs: Expr
    rhs: Expr

    def children(self) -> Tuple[Expr, ...]:
        return (self.lhs, self.rhs)

    @property
    def key(self) -> Tuple:
        return ("bin", self.op, self.lhs.key, self.rhs.key)


@dataclasses.dataclass(frozen=True, eq=False)
class UnOp(Expr):
    op: str
    arg: Expr
    params: Tuple = ()

    def children(self) -> Tuple[Expr, ...]:
        return (self.arg,)

    @property
    def key(self) -> Tuple:
        return ("un", self.op, self.params, self.arg.key)


@dataclasses.dataclass(frozen=True, eq=False)
class Hash(Expr):
    """64-bit mix hash of a column (the signature primitive).

    Result is a non-negative int32 in [0, 2**bits).
    """

    arg: Expr
    bits: int = 20
    salt: int = 0

    def children(self) -> Tuple[Expr, ...]:
        return (self.arg,)

    @property
    def key(self) -> Tuple:
        return ("hash", self.bits, self.salt, self.arg.key)


@dataclasses.dataclass(frozen=True, eq=False)
class Signature(Expr):
    """FeatInsight feature signature: fold several columns into one hashed id.

    The paper uses signatures to label features in trillion-dimensional
    spaces (product × item crosses etc.); we fold the column values through
    k rounds of a 64-bit mixer so the cross never materializes.
    """

    args: Tuple[Expr, ...]
    bits: int = 20
    salt: int = 0

    def children(self) -> Tuple[Expr, ...]:
        return tuple(self.args)

    @property
    def key(self) -> Tuple:
        return ("sig", self.bits, self.salt, tuple(a.key for a in self.args))


# Aggregations whose union-window composition is implemented by both
# engines.  Since the unified aggregator algebra (repro.core.aggregates)
# every registered Agg is union-composable: FIRST carries an argmin-by-
# merge-order state and TOPN_FREQ a mergeable tail sketch, so per-stream
# partial states combine across WINDOW UNION streams.  (Kept as an explicit
# tuple so a future non-composable aggregate fails loudly at construction;
# tests cross-check it against the registry's union_composable flags.)
UNION_AGGS = (
    Agg.SUM, Agg.COUNT, Agg.MEAN, Agg.MIN, Agg.MAX, Agg.STD,
    Agg.DISTINCT_APPROX, Agg.LAST, Agg.FIRST, Agg.TOPN_FREQ,
)


def _contains_node(e: "Expr", types: tuple) -> bool:
    if isinstance(e, types):
        return True
    return any(_contains_node(c, types) for c in e.children())


@dataclasses.dataclass(frozen=True, eq=False)
class WindowAgg(Expr):
    """Per-key window aggregation of a row-level expression.

    ``union`` names secondary tables whose streams are merged (by timestamp)
    into the primary stream before windowing — OpenMLDB WINDOW UNION.  Union
    windows must be RANGE windows (a merged ROWS ranking is not offered by
    the online store) and ``agg`` must be in :data:`UNION_AGGS`.
    """

    agg: Agg
    arg: Expr
    window: WindowSpec
    n: int = 1  # for TOPN_FREQ: which rank (0-based) to return
    union: Tuple[str, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "union", tuple(self.union))
        if self.union:
            if self.window.mode != "range":
                raise ValueError("WINDOW UNION requires a RANGE window")
            if self.agg not in UNION_AGGS:
                raise ValueError(
                    f"{self.agg.value} is not supported over WINDOW UNION"
                )
        if _contains_node(self.arg, (LastJoin,)):
            raise ValueError(
                "window-aggregation arguments may not contain LAST JOINs "
                "(join the value into the view first, window it separately)"
            )

    def children(self) -> Tuple[Expr, ...]:
        return (self.arg,)

    @property
    def key(self) -> Tuple:
        return (
            "wagg",
            self.agg.value,
            self.window.mode,
            self.window.size,
            self.n,
            self.union,
            self.arg.key,
        )


@dataclasses.dataclass(frozen=True, eq=False)
class LastJoin(Expr):
    """Point-in-time LAST JOIN: evaluate ``arg`` on the most recent row of
    ``table`` whose key equals the primary row's ``on`` column and whose
    timestamp is <= the primary row's timestamp (OpenMLDB LAST JOIN with the
    ``ORDER BY ts`` + ``ts <= request ts`` point-in-time condition).

    ``default`` is returned when no secondary row matches.  ``arg`` is a
    row-level expression over the *secondary* table's columns.
    """

    arg: Expr
    table: str
    on: str
    default: float = 0.0

    def __post_init__(self) -> None:
        if _contains_node(self.arg, (WindowAgg, LastJoin)):
            raise ValueError(
                "LAST JOIN arguments must be row-level expressions over the "
                "joined table (no nested windows or joins)"
            )

        def check_tcols(e: Expr) -> None:
            if isinstance(e, TableCol) and e.table != self.table:
                raise ValueError(
                    f"TableCol({e.table!r}, {e.name!r}) inside a LAST JOIN of "
                    f"table {self.table!r}: join arguments evaluate over the "
                    "joined table only"
                )
            for c in e.children():
                check_tcols(c)

        check_tcols(self.arg)

    def children(self) -> Tuple[Expr, ...]:
        return (self.arg,)

    @property
    def key(self) -> Tuple:
        return ("ljoin", self.table, self.on, self.default, self.arg.key)


def last_join(arg: Expr, table: str, on: str, default: float = 0.0) -> LastJoin:
    """DSL constructor: ``last_join(Col("credit_limit"), "accounts", on="account")``."""
    return LastJoin(_wrap(arg), table, on, float(default))


# -- convenience constructors (the user-facing feature DSL) -------------------


def w_sum(arg: Expr, window: WindowSpec, union: Sequence[str] = ()) -> WindowAgg:
    return WindowAgg(Agg.SUM, arg, window, union=tuple(union))


def w_count(arg: Expr, window: WindowSpec, union: Sequence[str] = ()) -> WindowAgg:
    return WindowAgg(Agg.COUNT, arg, window, union=tuple(union))


def w_mean(arg: Expr, window: WindowSpec, union: Sequence[str] = ()) -> WindowAgg:
    return WindowAgg(Agg.MEAN, arg, window, union=tuple(union))


def w_min(arg: Expr, window: WindowSpec, union: Sequence[str] = ()) -> WindowAgg:
    return WindowAgg(Agg.MIN, arg, window, union=tuple(union))


def w_max(arg: Expr, window: WindowSpec, union: Sequence[str] = ()) -> WindowAgg:
    return WindowAgg(Agg.MAX, arg, window, union=tuple(union))


def w_std(arg: Expr, window: WindowSpec, union: Sequence[str] = ()) -> WindowAgg:
    return WindowAgg(Agg.STD, arg, window, union=tuple(union))


def w_first(arg: Expr, window: WindowSpec, union: Sequence[str] = ()) -> WindowAgg:
    return WindowAgg(Agg.FIRST, arg, window, union=tuple(union))


def w_last(arg: Expr, window: WindowSpec, union: Sequence[str] = ()) -> WindowAgg:
    return WindowAgg(Agg.LAST, arg, window, union=tuple(union))


def w_distinct_approx(
    arg: Expr, window: WindowSpec, union: Sequence[str] = ()
) -> WindowAgg:
    return WindowAgg(Agg.DISTINCT_APPROX, arg, window, union=tuple(union))


def w_topn_freq(
    arg: Expr, window: WindowSpec, n: int = 0, union: Sequence[str] = ()
) -> WindowAgg:
    """Approximate top-N frequency: value of the n-th most frequent item in
    the window tail (ties broken by value)."""
    return WindowAgg(Agg.TOPN_FREQ, arg, window, n=n, union=tuple(union))


# ---------------------------------------------------------------------------
# Tree walks
# ---------------------------------------------------------------------------


def collect_window_aggs(exprs: Sequence[Expr]) -> Dict[Tuple, WindowAgg]:
    """All distinct WindowAgg nodes, CSE'd by structural key."""
    out: Dict[Tuple, WindowAgg] = {}

    def walk(e: Expr) -> None:
        if isinstance(e, WindowAgg):
            out.setdefault(e.key, e)
            walk(e.arg)
            return
        for c in e.children():
            walk(c)

    for e in exprs:
        walk(e)
    return out


def collect_last_joins(exprs: Sequence[Expr]) -> Dict[Tuple, LastJoin]:
    """All distinct LastJoin nodes, CSE'd by structural key."""
    out: Dict[Tuple, LastJoin] = {}

    def walk(e: Expr) -> None:
        if isinstance(e, LastJoin):
            out.setdefault(e.key, e)
        for c in e.children():
            walk(c)

    for e in exprs:
        walk(e)
    return out


def collect_columns(exprs: Sequence[Expr]) -> Tuple[str, ...]:
    """All source columns referenced (lineage: feature -> raw columns).

    Columns inside a LastJoin argument (and explicit TableCol references)
    are reported table-qualified as ``"table.col"``.
    """
    cols: List[str] = []

    def add(name: str) -> None:
        if name not in cols:
            cols.append(name)

    def walk(e: Expr, table: Optional[str]) -> None:
        if isinstance(e, Col):
            add(f"{table}.{e.name}" if table else e.name)
        elif isinstance(e, TableCol):
            add(f"{e.table}.{e.name}")
        elif isinstance(e, LastJoin):
            walk(e.arg, e.table)
            return
        for c in e.children():
            walk(c, table)

    for e in exprs:
        walk(e, None)
    return tuple(cols)


def collect_tables(exprs: Sequence[Expr]) -> Tuple[str, ...]:
    """All *secondary* tables referenced (LAST JOIN and WINDOW UNION)."""
    tables: List[str] = []

    def add(name: str) -> None:
        if name not in tables:
            tables.append(name)

    def walk(e: Expr) -> None:
        if isinstance(e, LastJoin):
            add(e.table)
        elif isinstance(e, TableCol):
            add(e.table)
        elif isinstance(e, WindowAgg):
            for t in e.union:
                add(t)
        for c in e.children():
            walk(c)

    for e in exprs:
        walk(e)
    return tuple(tables)


# ---------------------------------------------------------------------------
# Row-level evaluation
# ---------------------------------------------------------------------------


def eval_rowlevel(
    expr: Expr,
    columns: Dict[str, jnp.ndarray],
    wagg_values: Dict[Tuple, jnp.ndarray],
) -> jnp.ndarray:
    """Evaluate ``expr`` pointwise.

    ``columns`` maps column name -> (N,) array; ``wagg_values`` maps a
    WindowAgg *or LastJoin* structural key -> already-computed (N,) result
    (phase 2 of the engine).  WindowAgg/LastJoin nodes MUST appear in
    ``wagg_values``.
    """
    from repro.core.hashing import mix64  # local import to avoid cycle

    def ev(e: Expr) -> jnp.ndarray:
        if isinstance(e, (WindowAgg, LastJoin)):
            return wagg_values[e.key]
        if isinstance(e, Col):
            if e.name not in columns:
                raise KeyError(f"unknown column {e.name!r}")
            return columns[e.name]
        if isinstance(e, TableCol):
            if e.name not in columns:
                raise KeyError(
                    f"unknown column {e.table}.{e.name} in current table"
                )
            return columns[e.name]
        if isinstance(e, Lit):
            return jnp.asarray(e.value, jnp.float32)
        if isinstance(e, BinOp):
            return _BINOPS[e.op](ev(e.lhs), ev(e.rhs))
        if isinstance(e, UnOp):
            if e.op == "clip":
                lo, hi = e.params
                return jnp.clip(ev(e.arg), lo, hi)
            return _UNOPS[e.op](ev(e.arg))
        if isinstance(e, Hash):
            v = ev(e.arg)
            return mix64(v, salt=e.salt, bits=e.bits).astype(jnp.float32)
        if isinstance(e, Signature):
            acc = None
            for i, a in enumerate(e.args):
                h = mix64(ev(a), salt=e.salt + 0x9E37 * (i + 1), bits=32)
                acc = h if acc is None else mix64(
                    acc * 31 + h, salt=e.salt, bits=32
                )
            assert acc is not None
            return jnp.mod(acc, 2 ** e.bits).astype(jnp.float32)
        raise TypeError(f"unknown expr node {type(e)}")

    return ev(expr)
