"""Online feature store — FeatInsight's request-mode serving path.

OpenMLDB request mode: a request row (key, ts, values) arrives; the service
computes every feature of the view *as if that row were appended* to its
key's history, and returns the feature vector in milliseconds.  The row may
then be ingested (deployment-configurable).  Offline↔online consistency
means: the online answer for row i after ingesting rows 0..i-1 equals the
offline batch answer at row i.

Every aggregate's semantics come from the one registry in
:mod:`repro.core.aggregates`; a query is a single generic dataflow:

    lift(request row)
      ⊕ fold(primary window rows)            [raw ring, or raw boundary
                                              rows ⊕ bucket states on the
                                              pre-agg path]
      ⊕ fold(each union table's window rows) [raw secondary rings]
    → finalize

where ⊕ is the spec's associative ``combine``.  Because FIRST carries an
argmin-by-merge-order state and TOPN_FREQ a mergeable tail sketch, *every*
aggregate composes across WINDOW UNION streams — there are no per-agg
branches left in this module.

Two query paths (both pure functions, jit-compiled once per view version —
the paper's "compilation caching"):

* ``naive``  — masked fold over the raw ring (O(C) per query); the
  reproduction of the paper's un-preaggregated baseline.
* ``preagg`` — two-level composition: raw boundary rows + per-bucket
  partial states (O(C_boundary + NB)); the paper's long-window
  optimization.  Applies to every spec the bucket store persists
  (``bucket_composable``).  The Pallas kernel in
  ``repro.kernels.window_agg`` implements this same path with explicit
  VMEM tiling.

Physical layout comes from one place: the declarative
:class:`~repro.core.layout.StoreLayout` plan.  The store no longer derives
ring sizes, lane slots, or secondary-table placement itself — it *consumes*
the plan :func:`~repro.core.layout.plan_layout` computed (constructing a
store without an explicit ``layout`` plans one from its own view, which is
the legacy single-view path).  Because the plan is explicit and diffable,
a live store can :meth:`~OnlineFeatureStore.adopt_layout` an evolved plan —
carrying state buffers over by ring identity instead of rebuilding — which
is how ``ScenarioPlane.evolve`` hot-deploys new scenarios.

Window-aggregation *arguments* may be derived expressions; the store
materializes one lane per distinct argument at ingest (computed columns),
so pre-aggregation composes for derived args too — mirroring OpenMLDB
defining pre-aggregates per aggregation spec.  Evolvable layouts
(``raw_lanes=True``) additionally materialize every raw column as a lane,
so a hot-deployed view's new arguments can be synthesized from history.

Multi-table views add ring stores per referenced secondary table:
point-in-time LAST JOIN lookups (newest matching row with ``ts <= request
ts``) and WINDOW UNION aggregations (primary window combined with the
union tables' masked rings) are answered from this device state inside the
same compiled query.  Secondary rows arrive via :meth:`ingest_table`; a
table may back *several* rings (the sharded dual-use split: a partitioned
union ring plus a replicated LAST JOIN slice).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import preagg as pg
from repro.core import storage as st
from repro.core.aggregates import agg_spec
from repro.core.expr import (
    Expr,
    WindowAgg,
    collect_last_joins,
    collect_window_aggs,
    eval_rowlevel,
)
from repro.core.layout import StoreLayout, plan_layout
from repro.kernels import note_dispatch
from repro.kernels.ingest.ops import fused_ingest_apply, resolve_ingest_impl
from repro.obs import get_telemetry

__all__ = ["OnlineState", "OnlineFeatureStore", "QueryProgram"]

_TS_MIN = jnp.int32(-2147483648)
_POS_MAX = jnp.int32(2147483647)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class OnlineState:
    """All device state of one view's online store (a pytree).

    ``sec`` holds one RingStore per secondary *ring plan*, in the store's
    ``layout.tables`` order (a dual-use table contributes two rings on a
    sharded plane).
    """

    ring: st.RingStore
    bagg: pg.BucketAgg
    sec: Tuple[st.RingStore, ...] = ()

    def tree_flatten(self):
        return (self.ring, self.bagg, self.sec), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


class OnlineFeatureStore:
    """Stateful wrapper: owns an OnlineState + jit-compiled pure kernels.

    One instance per deployed feature-view version (the registry caches
    instances across versions — the paper's service-version cache).
    """

    def __init__(
        self,
        view,  # repro.core.view.FeatureView
        num_keys: Optional[int] = None,
        capacity: int = 256,
        num_buckets: int = 64,
        bucket_size: int = 64,
        secondary_num_keys: Optional[Dict[str, int]] = None,
        secondary_capacity: Optional[int] = None,
        ttl: Optional[int] = None,
        table_capacity: Optional[Dict[str, int]] = None,
        table_ttl: Optional[Dict[str, int]] = None,
        layout: Optional[StoreLayout] = None,
    ):
        if layout is None:
            if num_keys is None:
                raise ValueError("OnlineFeatureStore needs num_keys or layout")
            layout = plan_layout(
                [view],
                num_keys=num_keys,
                capacity=capacity,
                num_buckets=num_buckets,
                bucket_size=bucket_size,
                secondary_num_keys=secondary_num_keys,
                secondary_capacity=secondary_capacity,
                ttl=ttl,
                table_capacity=table_capacity,
                table_ttl=table_ttl,
            )
        self._apply_layout(view, layout)
        self.state = self._init_state()
        self._build_fns()

    # -- layout consumption ---------------------------------------------------

    def _apply_layout(self, view, layout: StoreLayout) -> None:
        """Derive every layout-dependent attribute from the plan.

        Called at construction and again by :meth:`adopt_layout` — all
        lane ids, ring indices, and placement flags live here, nowhere
        else."""
        self.view = view
        self.schema = view.schema
        self.layout = layout
        self.num_keys = layout.primary.ring_keys
        self.capacity = layout.primary.capacity
        self.num_buckets = layout.bucket.num_buckets
        self.bucket_size = layout.bucket.bucket_size
        self._ttl = layout.primary.ttl

        exprs = list(view.features.values())
        self.waggs: Dict[Tuple, WindowAgg] = collect_window_aggs(exprs)
        self._wagg_order: List[Tuple] = list(self.waggs.keys())
        self.ljoins = collect_last_joins(exprs)
        self._ljoin_order: List[Tuple] = list(self.ljoins.keys())

        # lane plan straight from the layout (wagg args, plus raw columns
        # on evolvable layouts)
        self._lane_exprs: List[Expr] = [s.expr for s in layout.primary.lanes]
        self._lane_of: Dict[Tuple, int] = {
            s.key: i for i, s in enumerate(layout.primary.lanes)
        }
        for wk, wa in self.waggs.items():
            if wa.arg.key not in self._lane_of:
                raise ValueError(
                    f"layout has no lane for window argument of "
                    f"{wa.agg.value}() in view {view.name!r}; the layout "
                    "must be planned from (a superset of) this view"
                )
        self.num_lanes = max(len(self._lane_exprs), 1)

        # union waggs whose *primary-stream* part can compose from bucket
        # pre-aggregates (secondary parts always answer from raw rings)
        self._union_preagg: Dict[Tuple, bool] = {}
        for wk, wa in self.waggs.items():
            if wa.window.mode == "range":
                need = self._window_span(wa) // self.bucket_size + 2
                if not wa.union and need > self.num_buckets:
                    feats = [
                        f for f, e in view.features.items()
                        if wk in collect_window_aggs([e])
                    ]
                    raise ValueError(
                        f"window {wa.window.size} of {wa.agg.value}() in "
                        f"feature(s) {feats} of view {view.name!r} needs "
                        f"{need} buckets of {self.bucket_size}, store "
                        f"layout has num_buckets={self.num_buckets}"
                    )
                self._union_preagg[wk] = bool(
                    wa.union
                    and need <= self.num_buckets
                    and agg_spec(wa.agg).bucket_composable
                )

        # -- secondary-ring plane (LAST JOIN + WINDOW UNION sources) --------
        self._ring_plans = layout.tables
        self._sec_names: Tuple[str, ...] = layout.table_names
        # first ring of each table (compat index for tests/verify)
        self._sec_index = {
            t: layout.rings_of(t)[0] for t in self._sec_names
        }
        self._sec_schemas = {
            t: view.database.table(t) for t in self._sec_names
        }
        self._ring_lane_exprs: List[List[Expr]] = [
            [s.expr for s in p.lanes] for p in layout.tables
        ]
        self._ring_lane_of: List[Dict[Tuple, int]] = [
            {s.key: i for i, s in enumerate(p.lanes)} for p in layout.tables
        ]
        self._union_tables: Tuple[str, ...] = ()
        for wa in self.waggs.values():
            for t in wa.union:
                if t not in self._union_tables:
                    self._union_tables += (t,)
        self._union_ring_ix = {
            t: layout.union_ring(t) for t in self._union_tables
        }
        self._join_ring_ix = {
            lj.table: layout.join_ring(lj.table)
            for lj in self.ljoins.values()
        }
        # compat view of placement (True = gathered at the shard-local key)
        self._sec_sharded: Dict[str, bool] = {
            t: any(
                p.partitioned for p in layout.tables if p.table == t
            )
            for t in self._sec_names
        }
        self.secondary_num_keys = {
            t: layout.tables[self._sec_index[t]].num_keys
            for t in self._sec_names
        }
        # request-time join-key columns (primary columns named by LAST JOINs)
        self._join_cols: Tuple[str, ...] = ()
        for lj in self.ljoins.values():
            if lj.on not in self._join_cols:
                self._join_cols += (lj.on,)
        self._join_col_index = {c: i for i, c in enumerate(self._join_cols)}

    def _init_state(self) -> OnlineState:
        lay = self.layout
        sec = tuple(
            st.ring_init(p.ring_keys, p.capacity, max(len(p.lanes), 1))
            for p in lay.tables
        )
        return OnlineState(
            ring=st.ring_init(
                lay.primary.ring_keys, lay.primary.capacity, self.num_lanes
            ),
            bagg=pg.bucket_init_plan(
                lay.bucket, lay.primary.ring_keys, self.num_lanes
            ),
            sec=sec,
        )

    def _build_fns(self) -> None:
        """(Re)wrap the pure kernels in jit.  Fresh wrappers on every
        layout adoption so stale traces (same shapes, different lane plan)
        can never answer a query."""
        # compile-time capture restarts with the wrappers: after a layout
        # adoption every (program, mode, shape-bucket) re-traces, and that
        # recompilation cost should be visible in query_compile_seconds
        self._seen_traces: set = set()
        self._ingest_fn = jax.jit(self._ingest_pure, donate_argnums=(0,))
        self._sec_ingest_fns = {
            i: jax.jit(
                functools.partial(self._sec_ingest_pure, index=i),
                donate_argnums=(0,),
            )
            for i in range(len(self._ring_plans))
        }
        # the query fns go through the overridable _jit_query hook so the
        # sharded store gets its vmapped-over-shards flavour for free —
        # including every per-scenario QueryProgram compiled against this
        # store
        self._query_naive_fn = self._jit_query(self._query_pure_naive)
        self._query_preagg_fn = self._jit_query(self._query_pure_preagg)

    # -- live evolution -------------------------------------------------------

    def adopt_layout(self, view, layout: StoreLayout, backfill=None):
        """Evolve this live store to a new (view, layout) in place.

        Diffs the old plan against ``layout``
        (:func:`~repro.core.layout.diff_layouts`), migrates every state
        buffer (carried verbatim where ring identity is unchanged;
        re-laid / lane-synthesized otherwise — see
        :mod:`repro.core.migrate`), and re-derives all layout-dependent
        attributes.  Compiled :class:`QueryProgram` s created against this
        store stay valid: they re-trace against the evolved state on
        their next call, and their trace-time subsets are matched by
        structural key, not position.

        ``backfill`` (a :class:`repro.offline.backfill.BackfillSource`)
        closes the retention horizon: state the migration could not
        reconstruct (aged-out ring rows, bucket states of lanes that
        cannot be synthesized from stored columns) is re-derived from
        offline history and spliced in *before* the new layout goes
        live — so a deficient splice refuses atomically, exactly like a
        refused migration.

        Returns the :class:`~repro.core.migrate.MigrationReport`.
        """
        from repro.core import migrate
        from repro.core.layout import diff_layouts

        tracer = get_telemetry().tracer
        with tracer.span("migrate.diff"):
            diff = diff_layouts(self.layout, layout)
        # migrate FIRST, against the still-untouched store: a refused
        # migration (unsynthesizable lane, unsupported diff) must leave
        # the live plane exactly as it was — still serving.  The routing
        # attributes migrate_state reads (permutation, shard count) are
        # invariant across any diff diff_layouts accepts.
        state, report = migrate.migrate_state(
            diff, self.state, self, backfill=backfill
        )
        if backfill is not None and report.deficits:
            # the splice also runs against the untouched store (routing /
            # permutation attrs are diff-invariant); it raises — leaving
            # the plane serving the old layout — when history cannot
            # cover a deficit
            state = backfill.splice(diff, state, report, self, view)
        self._apply_layout(view, layout)
        with tracer.span("migrate.place", kind="device") as sp:
            self.state = self._place_state(state)
            sp.fence(self.state.ring.cursor)
        self._build_fns()
        return report

    def _place_state(self, state: OnlineState) -> OnlineState:
        """Device placement of a migrated state (sharded stores re-apply
        their NamedSharding here)."""
        return jax.tree.map(jnp.asarray, state)

    # -- lane evaluation ------------------------------------------------------

    def _lanes(
        self,
        columns: Dict[str, jnp.ndarray],
        exprs: Optional[List[Expr]] = None,
    ) -> jnp.ndarray:
        """(N, L) materialized window-arg lanes from raw columns.

        ``exprs`` overrides the lane list (a scenario program's subset, so
        a request only needs the columns *its* view references).
        """
        exprs = self._lane_exprs if exprs is None else exprs
        if not exprs:
            n = jnp.asarray(columns[self.schema.key]).shape[0]
            return jnp.zeros((n, 1), jnp.float32)
        vals = [
            eval_rowlevel(e, columns, {}).astype(jnp.float32)
            for e in exprs
        ]
        return jnp.stack(vals, axis=-1)

    # -- ingest -----------------------------------------------------------------

    # fused-ingest dispatch knobs (class defaults; override per instance
    # BEFORE the first ingest, or call _build_fns() afterwards — the
    # resolved choice is baked into the jitted ingest trace).  ``auto``
    # picks the Pallas one-pass kernel on TPU, the split XLA oracle
    # elsewhere; both are bit-identical (tier-1 asserts it).
    ingest_impl: str = "auto"
    ingest_interpret: bool = False

    def _ingest_pure(self, state: OnlineState, key, ts, lanes) -> OnlineState:
        """Apply one padded batch to the six primary-store state arrays —
        the fused ingest kernel (ring scatter + bucket pre-agg merge in
        ONE pass, :mod:`repro.kernels.ingest`) or its split XLA oracle.

        Layouts persisting merge-order state families (extreme/tail)
        always take the split path: the fused kernel covers the six core
        arrays only, and the presence of ``bagg.seq`` is a static pytree
        property, so the branch is resolved at trace time."""
        if state.bagg.seq is not None:
            ring = st.ring_ingest(state.ring, key, ts, lanes)
            bagg = pg.bucket_ingest(state.bagg, key, ts, lanes)
            return OnlineState(ring=ring, bagg=bagg, sec=state.sec)
        rts, rvals, cur, bst, bbm, bid = fused_ingest_apply(
            state.ring.ts, state.ring.vals, state.ring.cursor,
            state.bagg.stats, state.bagg.bitmap, state.bagg.bucket,
            key, ts, lanes,
            bucket_size=state.bagg.size,
            impl=resolve_ingest_impl(self.ingest_impl),
            interpret=self.ingest_interpret,
        )
        ring = st.RingStore(ts=rts, vals=rvals, cursor=cur)
        bagg = pg.BucketAgg(
            stats=bst, bitmap=bbm, bucket=bid, size=state.bagg.size
        )
        return OnlineState(ring=ring, bagg=bagg, sec=state.sec)

    def ingest(self, columns: Dict[str, jnp.ndarray]) -> None:
        """Ingest a batch of raw rows (must be (key, ts)-sorted).

        ``bucket_ingest`` requires each fused batch to span fewer than
        ``num_buckets`` pre-agg buckets (a slot must receive at most one
        new bucket id per scatter).  Historical backfills can span the
        whole table's time range, so oversized batches are split here on
        bucket boundaries — each chunk stays one fused scatter.

        The whole batch is timed entry-to-queryable: the freshness clock
        stops only after a fence on the new state's ring cursor, i.e. once
        a concurrent ``query`` would actually see the rows — the paper's
        "millisecond-level feature update" metric
        (``ingest_freshness_seconds{table=}``, weighted per row).
        """
        tel = get_telemetry()
        t0 = tel.clock.now()
        key = jnp.asarray(columns[self.schema.key], jnp.int32)
        ts = jnp.asarray(columns[self.schema.ts], jnp.int32)
        lanes = self._lanes(columns)

        import numpy as _np

        ts_h = _np.asarray(ts)
        if ts_h.size == 0:
            return
        with tel.tracer.span(
            "ingest", kind="device", table=self.schema.name,
            rows=int(ts_h.size),
        ) as sp:
            b = ts_h // self.bucket_size
            span_ok = (b.max() - b.min()) < self.num_buckets - 1
            if span_ok:
                self._ingest_padded(key, ts, lanes)
            else:
                # split into chunks each spanning < num_buckets buckets;
                # rows are (key, ts)-sorted, so chunk by absolute-bucket
                # epoch and re-sort each chunk by (key, ts).
                epoch = b // (self.num_buckets - 1)
                for e in _np.unique(epoch):
                    idx = _np.nonzero(epoch == e)[0]
                    order = idx[
                        _np.lexsort((ts_h[idx], _np.asarray(key)[idx]))
                    ]
                    self._ingest_padded(key[order], ts[order], lanes[order])
            sp.fence(self.state.ring.cursor)
        self._note_freshness(tel, self.schema.name, int(ts_h.size), t0)

    def _note_freshness(self, tel, table: str, n_rows: int, t0: float) -> None:
        """Record one ingest batch's entry-to-queryable freshness, counted
        once per row (call after fencing the new state)."""
        dt = tel.clock.now() - t0
        m = tel.metrics
        m.histogram(
            "ingest_freshness_seconds",
            "ingest-call-to-queryable delay per row", "s",
            labels=("table",),
        ).observe(dt, n=n_rows, table=table)
        m.counter(
            "ingest_rows_total", "rows ingested", "1", labels=("table",),
        ).inc(n_rows, table=table)

    @staticmethod
    def _pad_batch(key, ts, lanes, sentinel: int):
        """Pad a fused ingest batch to a power-of-two shape bucket so one
        compiled executable serves every batch size (the paper's compilation
        caching).  Padding rows carry an out-of-range ``sentinel`` key:
        gathers clip (harmless) and every state scatter drops them."""
        n = int(key.shape[0])
        m = max(64, 1 << (n - 1).bit_length())
        if m != n:
            pad = m - n
            key = jnp.concatenate(
                [key, jnp.full((pad,), sentinel, jnp.int32)]
            )
            ts = jnp.concatenate([ts, jnp.broadcast_to(ts[-1], (pad,))])
            lanes = jnp.concatenate(
                [lanes, jnp.zeros((pad, lanes.shape[1]), lanes.dtype)]
            )
        return key, ts, lanes

    def _ingest_resolved_impl(self) -> str:
        """Host-side mirror of :meth:`_ingest_pure`'s trace-time branch."""
        if self.state.bagg.seq is not None:
            return "xla"
        return resolve_ingest_impl(self.ingest_impl)

    def _ingest_padded(self, key, ts, lanes) -> None:
        key, ts, lanes = self._pad_batch(key, ts, lanes, self.num_keys)
        # dispatch counting lives here (host side, once per batch) — the
        # impl branch itself is baked into the jitted trace
        note_dispatch("fused_ingest", self._ingest_resolved_impl())
        self.state = self._ingest_fn(self.state, key, ts, lanes)

    # -- secondary-table ingest ----------------------------------------------

    def _sec_ingest_pure(
        self, state: OnlineState, key, ts, lanes, *, index: int
    ) -> OnlineState:
        sec = list(state.sec)
        sec[index] = st.ring_ingest(sec[index], key, ts, lanes)
        return OnlineState(ring=state.ring, bagg=state.bagg, sec=tuple(sec))

    def ingest_table(self, table: str, columns: Dict[str, jnp.ndarray]) -> None:
        """Ingest a (key, ts)-sorted batch of rows into every ring of a
        secondary table (no pre-aggregates: secondary state serves LAST
        JOIN lookups and union windows, both answered from raw rings).  A
        dual-use table on a sharded plane writes its partitioned union
        ring *and* its replicated join slice — each with that ring's own
        lane subset."""
        if table == self.schema.name:
            return self.ingest(columns)
        if table not in self._sec_index:
            raise KeyError(
                f"view {self.view.name!r} does not reference table {table!r}"
            )
        tel = get_telemetry()
        t0 = tel.clock.now()
        sch = self._sec_schemas[table]
        key = jnp.asarray(columns[sch.key], jnp.int32)
        n = int(key.shape[0])
        if n == 0:
            return
        ts = jnp.asarray(columns[sch.ts], jnp.int32)
        with tel.tracer.span(
            "ingest", kind="device", table=table, rows=n
        ) as sp:
            for i in self.layout.rings_of(table):
                exprs = self._ring_lane_exprs[i]
                if exprs:
                    lanes = jnp.stack(
                        [
                            eval_rowlevel(e, columns, {}).astype(jnp.float32)
                            for e in exprs
                        ],
                        axis=-1,
                    )
                else:
                    lanes = jnp.zeros((n, 1), jnp.float32)
                self._sec_ring_ingest_padded(i, key, ts, lanes)
            sp.fence(
                tuple(
                    self.state.sec[i].cursor
                    for i in self.layout.rings_of(table)
                )
            )
        self._note_freshness(tel, table, n, t0)

    def _sec_ring_ingest_padded(self, index: int, key, ts, lanes) -> None:
        key, ts, lanes = self._pad_batch(
            key, ts, lanes, self._ring_plans[index].ring_keys
        )
        self.state = self._sec_ingest_fns[index](self.state, key, ts, lanes)

    # -- window masks -------------------------------------------------------------

    def _window_span(self, wa: WindowAgg, ttl: Optional[int] = None) -> int:
        """Effective RANGE lookback: the window size, clamped by the
        TTL retention policy when one is set (rows older than the TTL
        are expired, so no window — RANGE or ROWS — may see them; ROWS
        windows apply the same cutoff as an eligibility mask in
        :meth:`_window_mask`).  ``ttl`` is the governing ring's policy;
        ``None`` falls back to the primary's."""
        ttl = self._ttl if ttl is None else ttl
        if ttl is not None:
            return min(wa.window.size, ttl)
        return wa.window.size

    def _window_mask(
        self, wa: WindowAgg, ts_buf, valid, ts_q,
        ttl: Optional[int] = None,
    ) -> jnp.ndarray:
        ttl = self._ttl if ttl is None else ttl
        not_future = ts_buf <= ts_q[:, None]
        if wa.window.mode == "range":
            lo = ts_q - jnp.int32(self._window_span(wa, ttl)) + 1
            return valid & not_future & (ts_buf >= lo[:, None])
        # rows mode: last (size-1) eligible rows; the request row is the
        # size-th.  Rank from the newest backwards.  TTL-expired rows are
        # not eligible (the retention policy is window-mode-independent).
        eligible = valid & not_future
        if ttl is not None:
            eligible &= ts_buf > (ts_q - jnp.int32(ttl))[:, None]
        newer = jnp.cumsum(eligible[:, ::-1].astype(jnp.int32), axis=1)[:, ::-1]
        rank_from_new = newer - eligible.astype(jnp.int32)  # 0 == newest
        return eligible & (rank_from_new < wa.window.size - 1)

    # -- secondary-state lookups ---------------------------------------------

    def _union_gathers(self, state, key, gkey, tables=None):
        """Gather each union table's ring at the request key (shared across
        every union wagg touching that table).

        ``key`` is the primary-store key (shard-local in a
        :class:`~repro.core.shard.ShardedOnlineStore`), ``gkey`` the global
        key: key-partitioned union rings hold local ids, replicated ones
        global ids.  For the single-device store both are the same array.
        ``tables`` restricts the gathers to the union tables a scenario
        program actually folds.
        """
        out = {}
        for t in (self._union_tables if tables is None else tables):
            i = self._union_ring_ix[t]
            out[t] = st.ring_gather(
                state.sec[i],
                key if self._ring_plans[i].partitioned else gkey,
            )
        return out

    def _last_join_vals(
        self, state, ts_q, join_keys, ljoin_order=None, join_col_index=None
    ) -> List[jnp.ndarray]:
        """Point-in-time LAST JOIN answers, one (Q,) vector per join.

        Newest secondary row with key == request's join key and
        ``ts <= request ts``; ties on ts resolve to the latest-ingested row
        (matching the offline stable (key, ts) sort).  ``ljoin_order``
        restricts the joins computed and ``join_col_index`` maps join
        columns into the (possibly program-scoped) ``join_keys`` tuple.
        Joins always read the table's replicated join ring (the join
        slice, on a split dual-use table).
        """
        out = []
        gathers = {}
        order = self._ljoin_order if ljoin_order is None else ljoin_order
        col_ix = (
            self._join_col_index if join_col_index is None else join_col_index
        )
        for lk in order:
            lj = self.ljoins[lk]
            jk = join_keys[col_ix[lj.on]]
            ring_ix = self._join_ring_ix[lj.table]
            gk = (ring_ix, lj.on)
            if gk not in gathers:
                gathers[gk] = st.ring_gather(state.sec[ring_ix], jk)
            ts_t, lanes_t, valid_t = gathers[gk]
            g = lanes_t[..., self._ring_lane_of[ring_ix][lj.arg.key]]
            m = valid_t & (ts_t <= ts_q[:, None])
            ts_m = jnp.where(m, ts_t, _TS_MIN)
            mx = jnp.max(ts_m, axis=1)
            cand = m & (ts_t == mx[:, None])
            C = ts_t.shape[1]
            pos = C - 1 - jnp.argmax(cand[:, ::-1], axis=1)
            val = jnp.take_along_axis(g, pos[:, None], axis=1)[:, 0]
            found = m.any(axis=1)
            out.append(jnp.where(found, val, jnp.float32(lj.default)))
        return out

    # -- the one query path ---------------------------------------------------

    def _preagg_parts(self, wa, state, key, ts_q, ts_buf, valid, lane):
        """Raw boundary-row mask + gathered middle-bucket states for a RANGE
        window on the pre-agg path.

        The window decomposes into [raw head rows in the oldest partial
        bucket] + [full buckets strictly inside] + [raw tail rows in the
        request's bucket]; middles come back as persisted aggregate states
        ready for ``AggSpec.fold_buckets``.
        """
        B = jnp.int32(self.bucket_size)
        nb = self.num_buckets
        bucket_buf = ts_buf // B
        T = jnp.int32(self._window_span(wa))
        lo = ts_q - T + 1
        b_q = ts_q // B
        b_lo = (ts_q - T) // B
        not_future = ts_buf <= ts_q[:, None]
        in_lo = ts_buf >= lo[:, None]
        head_m = (
            valid & not_future & in_lo
            & (bucket_buf == b_lo[:, None]) & (b_lo != b_q)[:, None]
        )
        tail_m = valid & not_future & in_lo & (bucket_buf == b_q[:, None])
        raw = head_m | tail_m

        # middle full buckets b_lo+1 .. b_q-1, selected by membership
        M = self._max_mid(wa)
        mids = b_lo[:, None] + 1 + jnp.arange(M, dtype=jnp.int32)[None, :]
        mvalid = mids < b_q[:, None]
        slots = mids % nb
        stored = state.bagg.bucket[key[:, None], slots]
        ok = mvalid & (stored == mids)
        ms = state.bagg.stats[key[:, None], slots, lane]   # (Q, M, NUM_STATS)
        mb = state.bagg.bitmap[key[:, None], slots, lane]  # (Q, M)
        # merge-order families gather their persisted states alongside
        # (only for the spec that reads them — the arrays exist whenever
        # the layout planned them, asserted by the caller's family gate)
        ext = None
        spec = agg_spec(wa.agg)
        if spec.state == "extreme":
            ext = {
                "ts": state.bagg.xts[key[:, None], slots],       # (Q, M, 2)
                "pos": state.bagg.xpos[key[:, None], slots],
                "val": state.bagg.xval[key[:, None], slots, lane],
                "has": state.bagg.xhas[key[:, None], slots],
            }
        elif spec.state == "tail":
            ext = {
                "ts": state.bagg.tts[key[:, None], slots],       # (Q, M, T)
                "pos": state.bagg.tpos[key[:, None], slots],
                "val": state.bagg.tval[key[:, None], slots, lane],
                "valid": state.bagg.tvalid[key[:, None], slots],
            }
        return raw, ms, mb, ok, ext

    def _query_pure(self, state, key, ts_q, req_lanes, join_keys, gkey,
                    use_preagg: bool, wagg_order=None, ljoin_order=None,
                    req_lane_of=None, join_col_index=None):
        """Generic fold-then-finalize over every window aggregation.

        For each wagg: lift the request row, combine with the primary
        window's fold (raw ring rows, or boundary rows ⊕ bucket states on
        the pre-agg path), combine with each union table's fold, finalize.
        All semantics live in the :mod:`repro.core.aggregates` specs.

        ``wagg_order`` / ``ljoin_order`` restrict the computation to a
        subset of this store's aggregations and joins — how a
        :class:`QueryProgram` serves one scenario's view against state
        shared by many scenarios.  The subsets are trace-time constants, so
        each program compiles to an executable that gathers and folds only
        the lanes its view needs.  ``req_lane_of`` / ``join_col_index``
        remap window args and join columns into the program-scoped
        ``req_lanes`` / ``join_keys`` request tensors (requests carry only
        the columns *their* view references); stored-state lane ids stay
        global — the shared layout.
        """
        wagg_order = self._wagg_order if wagg_order is None else wagg_order
        req_lane_of = self._lane_of if req_lane_of is None else req_lane_of
        ts_buf, lanes_buf, valid = st.ring_gather(state.ring, key)
        union_tables = tuple(
            t
            for t in self._union_tables
            if any(t in self.waggs[wk].union for wk in wagg_order)
        )
        sec_gathers = self._union_gathers(
            state, key, gkey, tables=union_tables
        )
        out = []
        for wk in wagg_order:
            wa = self.waggs[wk]
            spec = agg_spec(wa.agg)
            lane = self._lane_of[wa.arg.key]
            g = lanes_buf[..., lane]
            r = req_lanes[:, req_lane_of[wa.arg.key]]
            # merge-order coordinate of the request row: primary stream
            # (rank = len(union), matching join.merge_streams), newer than
            # any stored row of the same (ts, stream)
            prim_rank = jnp.int32(len(wa.union))
            acc = spec.lift(r, ts_q, prim_rank, _POS_MAX)
            # family gate: extreme/tail specs can only compose from
            # buckets when the layout persisted their state arrays
            # (static pytree presence, resolved at trace time)
            family_ok = (
                spec.state in ("lanes", "bitmap")
                or (spec.state == "extreme" and state.bagg.xts is not None)
                or (spec.state == "tail" and state.bagg.tts is not None)
            )
            use_buckets = (
                use_preagg
                and spec.bucket_composable
                and family_ok
                and wa.window.mode == "range"
                and (not wa.union or self._union_preagg.get(wk, False))
            )
            if use_buckets:
                raw, ms, mb, ok, ext = self._preagg_parts(
                    wa, state, key, ts_q, ts_buf, valid, lane
                )
                acc = spec.combine(
                    acc, spec.fold_rows(g, ts_buf, raw, prim_rank)
                )
                acc = spec.combine(
                    acc, spec.fold_buckets(ms, mb, ok, ext=ext, rank=prim_rank)
                )
            else:
                m = self._window_mask(wa, ts_buf, valid, ts_q)
                acc = spec.combine(
                    acc, spec.fold_rows(g, ts_buf, m, prim_rank)
                )
            for rank, t in enumerate(wa.union):
                ts_t, lanes_t, valid_t = sec_gathers[t]
                ring_ix = self._union_ring_ix[t]
                lane_ix = self._ring_lane_of[ring_ix]
                g_t = lanes_t[..., lane_ix[wa.arg.key]]
                # union rows expire on their *own* ring's TTL when the
                # layout sets one (per-table knob); else the primary's
                m_t = self._window_mask(
                    wa, ts_t, valid_t, ts_q,
                    ttl=self._ring_plans[ring_ix].ttl,
                )
                acc = spec.combine(
                    acc, spec.fold_rows(g_t, ts_t, m_t, jnp.int32(rank))
                )
            out.append(spec.finalize(acc, n=wa.n))
        out.extend(
            self._last_join_vals(
                state, ts_q, join_keys, ljoin_order, join_col_index
            )
        )
        return tuple(out)

    def _query_pure_naive(self, state, key, ts_q, req_lanes, join_keys, gkey):
        return self._query_pure(
            state, key, ts_q, req_lanes, join_keys, gkey, use_preagg=False
        )

    def _query_pure_preagg(self, state, key, ts_q, req_lanes, join_keys, gkey):
        return self._query_pure(
            state, key, ts_q, req_lanes, join_keys, gkey, use_preagg=True
        )

    def _jit_query(self, fn):
        """How this store turns a pure query fn into a compiled one; the
        sharded store overrides it to vmap over the leading shard axis
        first, so per-scenario programs inherit the right flavour."""
        return jax.jit(fn)

    def compile_program(self, view) -> "QueryProgram":
        """Compile a per-scenario query program for ``view`` against this
        store's (possibly shared, multi-scenario) state."""
        return QueryProgram(self, view)

    def _max_mid(self, wa: WindowAgg) -> int:
        """Static bound on middle-bucket count for a window."""
        return max(
            1,
            min(
                self.num_buckets,
                self._window_span(wa) // self.bucket_size + 1,
            ),
        )

    # -- public query ---------------------------------------------------------------------

    @classmethod
    def create(
        cls,
        view,
        *,
        num_keys: Optional[int] = None,
        num_shards: Optional[int] = None,
        layout: Optional[StoreLayout] = None,
        **store_kwargs,
    ) -> "OnlineFeatureStore":
        """Factory shared by every deployment path (services, verify_view):
        a single-device store, or a :class:`~repro.core.shard.
        ShardedOnlineStore` when ``num_shards`` is given (or the layout
        plans shards)."""
        if layout is not None and layout.num_shards is not None:
            num_shards = layout.num_shards
        if num_shards is not None:
            from repro.core.shard import ShardedOnlineStore

            return ShardedOnlineStore(
                view,
                num_keys=num_keys,
                num_shards=num_shards,
                layout=layout,
                **store_kwargs,
            )
        # routing flavour only exists on the sharded store; a single-device
        # deployment accepts (and ignores) it so build(**kwargs) is uniform
        store_kwargs.pop("device_routing", None)
        return OnlineFeatureStore(
            view, num_keys=num_keys, layout=layout, **store_kwargs
        )

    def _validate_join_cols(
        self,
        columns: Dict[str, jnp.ndarray],
        program: Optional["QueryProgram"] = None,
    ) -> None:
        cols = self._join_cols if program is None else program.join_cols
        view = self.view if program is None else program.view
        for c in cols:
            if c not in columns:
                raise KeyError(
                    f"request rows must carry join-key column {c!r} "
                    f"(LAST JOIN on {c!r} in view {view.name!r})"
                )

    def _request_arrays(
        self,
        columns: Dict[str, jnp.ndarray],
        program: Optional["QueryProgram"] = None,
    ):
        """(key, ts, lanes, join_keys) request tensors, join cols validated.

        With a ``program``, lanes and join keys are scoped to that
        scenario's view — requests need only the columns it references,
        exactly as against a dedicated single-view store.
        """
        self._validate_join_cols(columns, program)
        key = jnp.asarray(columns[self.schema.key], jnp.int32)
        ts_q = jnp.asarray(columns[self.schema.ts], jnp.int32)
        lane_exprs = None if program is None else program.lane_exprs
        join_cols = self._join_cols if program is None else program.join_cols
        req_lanes = self._lanes(columns, lane_exprs)
        join_keys = tuple(
            jnp.asarray(columns[c], jnp.int32) for c in join_cols
        )
        return key, ts_q, req_lanes, join_keys

    def _finish_query(
        self, columns, vals, program: Optional["QueryProgram"] = None
    ) -> Dict[str, jnp.ndarray]:
        """Pre-agg answers -> named features via row-level post-expressions."""
        if program is None:
            keys = self._wagg_order + self._ljoin_order
            features = self.view.features
        else:
            keys = list(program.wagg_order) + list(program.ljoin_order)
            features = program.view.features
        pre_values = dict(zip(keys, vals))
        out: Dict[str, jnp.ndarray] = {}
        for fname, fexpr in features.items():
            out[fname] = eval_rowlevel(fexpr, columns, pre_values)
        return out

    def _query_fn(self, mode: str, program: Optional["QueryProgram"]):
        if program is not None:
            return program.fn(mode)
        return self._query_naive_fn if mode == "naive" else self._query_preagg_fn

    def ingest_row_counts(self) -> Dict[str, int]:
        """Rows stored per table, summed over all device state (from ring
        cursors, so counts are rows *ever ingested*, not current capacity).

        On a sharded store a key-partitioned table counts each row once
        (rows live on exactly one shard) while a replicated LAST JOIN
        target counts ``num_shards``× (one copy per shard).  A split
        dual-use table counts its partitioned union part once plus
        ``num_shards``× its replicated join slice — exactly the
        storage-cost accounting the dual-use partitioning claim is stated
        in.
        """
        counts = {self.schema.name: int(np.sum(self.state.ring.cursor))}
        for i, p in enumerate(self._ring_plans):
            counts[p.table] = counts.get(p.table, 0) + int(
                np.sum(self.state.sec[i].cursor)
            )
        return counts

    def ring_row_counts(self) -> Dict[Tuple[str, str], np.ndarray]:
        """Per-ring stored row totals, keyed ``(table, placement)``.

        Single-device stores report one total per ring; the sharded
        override reports a per-shard vector — the observable behind the
        dual-use assertion that union-stream rows are stored once, not
        once per shard.
        """
        out = {
            (self.schema.name, "partitioned" if self.layout.primary.partitioned
             else "replicated"): np.asarray(self.state.ring.cursor).sum(-1)
        }
        for i, p in enumerate(self._ring_plans):
            k = (p.table, "partitioned" if p.partitioned else "replicated")
            out[k] = np.asarray(self.state.sec[i].cursor).sum(-1)
        return out

    def record_gauges(self) -> None:
        """Publish pull-style state gauges into the installed telemetry:
        per-ring occupancy (stored rows / capacity), capacity-evicted row
        totals, and — where the layout sets a TTL — how many stored rows
        are already past it (logically expired, serving no window).

        Call at scrape/snapshot time; gauges reflect the store *now*.
        """
        tel = get_telemetry()
        m = tel.metrics

        def _ring(ring, plan) -> None:
            table = plan.table
            placement = "partitioned" if plan.partitioned else "replicated"
            cur = np.asarray(ring.cursor)          # (..., K)
            C = int(ring.ts.shape[-1])
            stored = np.minimum(cur, C)
            cap = cur.size * C
            m.gauge(
                "ring_occupancy_ratio", "stored rows / ring capacity", "1",
                labels=("table", "placement"),
            ).set(float(stored.sum()) / max(cap, 1),
                  table=table, placement=placement)
            m.gauge(
                "ring_evicted_rows_total",
                "rows overwritten by ring wraparound (capacity eviction)",
                "1", labels=("table", "placement"),
            ).set(float(np.maximum(cur - C, 0).sum()),
                  table=table, placement=placement)
            if plan.ttl:
                ts = np.asarray(ring.ts)           # (..., K, C)
                valid = np.arange(C) < cur[..., None]
                if valid.any():
                    now_ts = int(ts[valid].max())
                    expired = int(
                        (valid & (ts < now_ts - int(plan.ttl))).sum()
                    )
                else:
                    expired = 0
                m.gauge(
                    "ring_ttl_expired_rows",
                    "stored rows older than the layout TTL", "1",
                    labels=("table",),
                ).set(float(expired), table=table)

        _ring(self.state.ring, self.layout.primary)
        for i, p in enumerate(self._ring_plans):
            _ring(self.state.sec[i], p)

    def query(
        self,
        columns: Dict[str, jnp.ndarray],
        mode: str = "preagg",
        program: Optional["QueryProgram"] = None,
        valid: Optional[np.ndarray] = None,
        route_info: Optional[Dict] = None,
    ) -> Dict[str, jnp.ndarray]:
        """Compute all view features for a batch of request rows.

        columns: raw request columns incl. key, ts, and any LAST JOIN key
        columns; (Q,) each.  Returns {feature_name: (Q,) f32}.

        ``program`` answers with a per-scenario :class:`QueryProgram`
        compiled by :meth:`compile_program` instead of this store's full
        view — the multi-scenario serving path.

        ``valid`` optionally masks scheduler padding rows and
        ``route_info`` (dict, filled in place) reports per-shard request
        counts — one shard here; the sharded store computes the real
        histogram as a routing by-product so callers never re-hash keys.
        """
        tel = get_telemetry()
        if route_info is not None:
            n_real = (
                int(np.asarray(valid, bool).sum())
                if valid is not None
                else len(np.asarray(columns[self.schema.key]))
            )
            route_info["shard_counts"] = np.array([n_real], np.int64)
        key, ts_q, req_lanes, join_keys = self._request_arrays(
            columns, program
        )
        fn = self._query_fn(mode, program)
        # pad the request to a power-of-two shape bucket (compilation
        # caching: one executable per bucket, not per request size)
        q = int(key.shape[0])
        m = max(16, 1 << (q - 1).bit_length())
        t_call = tel.clock.now()
        with tel.tracer.span(
            "query.compute", kind="device", mode=mode,
            program=program.view.name if program is not None else "",
            rows=q, padded=m,
        ) as sp:
            if m != q:
                pad = m - q
                key_p = jnp.concatenate(
                    [key, jnp.broadcast_to(key[-1], (pad,))]
                )
                ts_p = jnp.concatenate(
                    [ts_q, jnp.broadcast_to(ts_q[-1], (pad,))]
                )
                lanes_p = jnp.concatenate(
                    [req_lanes,
                     jnp.broadcast_to(req_lanes[-1:],
                                      (pad, req_lanes.shape[1]))]
                )
                jk_p = tuple(
                    jnp.concatenate([j, jnp.broadcast_to(j[-1], (pad,))])
                    for j in join_keys
                )
                vals = fn(self.state, key_p, ts_p, lanes_p, jk_p, key_p)
                vals = tuple(v[:q] for v in vals)
            else:
                vals = fn(self.state, key, ts_q, req_lanes, join_keys, key)
            vals = sp.fence(vals)
        self._note_query(tel, mode, program, m, t_call)
        return self._finish_query(columns, vals, program)

    def _note_query(self, tel, mode, program, padded_rows, t_call) -> None:
        """Query-side metrics: first-trace compile capture per
        (program, mode, shape bucket) and preagg hit/fallback counters.
        ``padded_rows`` is any hashable shape key — an int bucket, or the
        fused device path's (batch, bucket) pair."""
        name = program.view.name if program is not None else self.view.name
        trace_key = (
            name,
            mode,
            padded_rows if isinstance(padded_rows, tuple) else int(padded_rows),
        )
        if trace_key not in self._seen_traces:
            self._seen_traces.add(trace_key)
            # first call at this shape = trace + XLA compile (+ one
            # execution, negligible next to compilation at smoke sizes)
            tel.metrics.histogram(
                "query_compile_seconds",
                "first-trace wall time per (program, mode, shape bucket)",
                "s", labels=("program", "mode"),
            ).observe(
                tel.clock.now() - t_call, program=name, mode=mode
            )
        wagg_order = (
            self._wagg_order if program is None else program.wagg_order
        )
        hits = tel.metrics.counter(
            "preagg_hits_total",
            "window aggs answered from bucket pre-aggregates", "1",
            labels=("agg",),
        )
        falls = tel.metrics.counter(
            "preagg_fallback_total",
            "window aggs falling back to the raw ring fold", "1",
            labels=("agg",),
        )
        for wk in wagg_order:
            wa = self.waggs[wk]
            spec = agg_spec(wa.agg)
            # host-side mirror of _query_pure's trace-time use_buckets
            family_ok = (
                spec.state in ("lanes", "bitmap")
                or (spec.state == "extreme"
                    and self.state.bagg.xts is not None)
                or (spec.state == "tail"
                    and self.state.bagg.tts is not None)
            )
            hit = (
                mode != "naive"
                and spec.bucket_composable
                and family_ok
                and wa.window.mode == "range"
                and (not wa.union or self._union_preagg.get(wk, False))
            )
            (hits if hit else falls).inc(agg=wa.agg.value)


class QueryProgram:
    """One scenario's compiled query against a shared store.

    The multi-scenario plane (:mod:`repro.core.scenario`) deploys N feature
    views on ONE store whose lane plan is the union of every view's window
    arguments.  A QueryProgram is the per-view slice of that store: the
    view's window aggregations and LAST JOINs as trace-time subsets, jitted
    through the store's :meth:`OnlineFeatureStore._jit_query` hook (so a
    sharded store yields a vmapped-over-shards program).  The compiled
    executable gathers and folds only the lanes its view references — the
    other scenarios' state is carried along untouched.

    Every (wagg, ljoin) key of the view must exist in the store; the
    store's answers through a program are bit-identical to a dedicated
    single-view store fed the same stream (asserted in
    ``tests/test_scenario.py``).  Programs survive
    :meth:`OnlineFeatureStore.adopt_layout`: their subsets are structural
    keys, so they re-trace correctly against the evolved layout.
    """

    def __init__(self, store: OnlineFeatureStore, view):
        exprs = list(view.features.values())
        self.view = view
        waggs = collect_window_aggs(exprs)
        ljoins = collect_last_joins(exprs)
        self.wagg_order: Tuple[Tuple, ...] = tuple(waggs.keys())
        self.ljoin_order: Tuple[Tuple, ...] = tuple(ljoins.keys())
        missing = [k for k in self.wagg_order if k not in store.waggs]
        missing += [k for k in self.ljoin_order if k not in store.ljoins]
        if missing:
            raise ValueError(
                f"view {view.name!r} is not a sub-view of store view "
                f"{store.view.name!r}: {len(missing)} aggregation(s)/join(s) "
                f"missing from the shared lane plan (first: {missing[0]!r})"
            )
        # program-scoped request tensors: requests carry only THIS view's
        # columns, so lanes and join keys get their own (smaller) layout;
        # stored-state lane ids stay global (the shared layout)
        self.lane_exprs: List[Expr] = []
        self.req_lane_of: Dict[Tuple, int] = {}
        for wa in waggs.values():
            if wa.arg.key not in self.req_lane_of:
                self.req_lane_of[wa.arg.key] = len(self.lane_exprs)
                self.lane_exprs.append(wa.arg)
        self.join_cols: Tuple[str, ...] = ()
        for lj in ljoins.values():
            if lj.on not in self.join_cols:
                self.join_cols += (lj.on,)
        self.join_col_index = {c: i for i, c in enumerate(self.join_cols)}
        subset = dict(
            wagg_order=self.wagg_order,
            ljoin_order=self.ljoin_order,
            req_lane_of=self.req_lane_of,
            join_col_index=self.join_col_index,
        )
        self._naive_fn = store._jit_query(
            functools.partial(store._query_pure, use_preagg=False, **subset)
        )
        self._preagg_fn = store._jit_query(
            functools.partial(store._query_pure, use_preagg=True, **subset)
        )

    def fn(self, mode: str):
        return self._naive_fn if mode == "naive" else self._preagg_fn
