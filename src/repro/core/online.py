"""Online feature store — FeatInsight's request-mode serving path.

OpenMLDB request mode: a request row (key, ts, values) arrives; the service
computes every feature of the view *as if that row were appended* to its
key's history, and returns the feature vector in milliseconds.  The row may
then be ingested (deployment-configurable).  Offline↔online consistency
means: the online answer for row i after ingesting rows 0..i-1 equals the
offline batch answer at row i.

Every aggregate's semantics come from the one registry in
:mod:`repro.core.aggregates`; a query is a single generic dataflow:

    lift(request row)
      ⊕ fold(primary window rows)            [raw ring, or raw boundary
                                              rows ⊕ bucket states on the
                                              pre-agg path]
      ⊕ fold(each union table's window rows) [raw secondary rings]
    → finalize

where ⊕ is the spec's associative ``combine``.  Because FIRST carries an
argmin-by-merge-order state and TOPN_FREQ a mergeable tail sketch, *every*
aggregate composes across WINDOW UNION streams — there are no per-agg
branches left in this module.

Two query paths (both pure functions, jit-compiled once per view version —
the paper's "compilation caching"):

* ``naive``  — masked fold over the raw ring (O(C) per query); the
  reproduction of the paper's un-preaggregated baseline.
* ``preagg`` — two-level composition: raw boundary rows + per-bucket
  partial states (O(C_boundary + NB)); the paper's long-window
  optimization.  Applies to every spec the bucket store persists
  (``bucket_composable``).  The Pallas kernel in
  ``repro.kernels.window_agg`` implements this same path with explicit
  VMEM tiling.

Window-aggregation *arguments* may be derived expressions; the store
materializes one lane per distinct argument at ingest (computed columns),
so pre-aggregation composes for derived args too — mirroring OpenMLDB
defining pre-aggregates per aggregation spec.

Multi-table views add one ring store per referenced secondary table:
point-in-time LAST JOIN lookups (newest matching row with ``ts <= request
ts``) and WINDOW UNION aggregations (primary window combined with the
union tables' masked rings) are answered from this device state inside the
same compiled query.  Secondary rows arrive via :meth:`ingest_table`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import preagg as pg
from repro.core import storage as st
from repro.core.aggregates import agg_spec
from repro.core.expr import (
    Expr,
    WindowAgg,
    collect_last_joins,
    collect_tables,
    collect_window_aggs,
    eval_rowlevel,
)

__all__ = ["OnlineState", "OnlineFeatureStore"]

_TS_MIN = jnp.int32(-2147483648)
_POS_MAX = jnp.int32(2147483647)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class OnlineState:
    """All device state of one view's online store (a pytree).

    ``sec`` holds one RingStore per secondary table, in the store's
    ``_sec_names`` order.
    """

    ring: st.RingStore
    bagg: pg.BucketAgg
    sec: Tuple[st.RingStore, ...] = ()

    def tree_flatten(self):
        return (self.ring, self.bagg, self.sec), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


class OnlineFeatureStore:
    """Stateful wrapper: owns an OnlineState + jit-compiled pure kernels.

    One instance per deployed feature-view version (the registry caches
    instances across versions — the paper's service-version cache).
    """

    def __init__(
        self,
        view,  # repro.core.view.FeatureView
        num_keys: int,
        capacity: int = 256,
        num_buckets: int = 64,
        bucket_size: int = 64,
        secondary_num_keys: Optional[Dict[str, int]] = None,
        secondary_capacity: Optional[int] = None,
    ):
        self.view = view
        self.schema = view.schema
        self.num_keys = num_keys
        self.capacity = capacity
        self.num_buckets = num_buckets
        self.bucket_size = bucket_size

        exprs = list(view.features.values())
        # lane plan: one materialized lane per distinct wagg argument
        self.waggs: Dict[Tuple, WindowAgg] = collect_window_aggs(exprs)
        self._wagg_order: List[Tuple] = list(self.waggs.keys())
        self.ljoins = collect_last_joins(exprs)
        self._ljoin_order: List[Tuple] = list(self.ljoins.keys())
        self._lane_exprs: List[Expr] = []
        self._lane_of: Dict[Tuple, int] = {}
        # union waggs whose *primary-stream* part can compose from bucket
        # pre-aggregates (secondary parts always answer from raw rings)
        self._union_preagg: Dict[Tuple, bool] = {}
        for wk, wa in self.waggs.items():
            ak = wa.arg.key
            if ak not in self._lane_of:
                self._lane_of[ak] = len(self._lane_exprs)
                self._lane_exprs.append(wa.arg)
            if wa.window.mode == "range":
                need = wa.window.size // bucket_size + 2
                if not wa.union and need > num_buckets:
                    raise ValueError(
                        f"window {wa.window.size} needs {need} buckets of "
                        f"{bucket_size}, store has {num_buckets}"
                    )
                self._union_preagg[wk] = bool(
                    wa.union
                    and need <= num_buckets
                    and agg_spec(wa.agg).bucket_composable
                )
        self.num_lanes = max(len(self._lane_exprs), 1)

        # -- secondary-table plane (LAST JOIN + WINDOW UNION sources) --------
        db = view.database
        self._sec_names: Tuple[str, ...] = collect_tables(exprs)
        self._sec_index = {t: i for i, t in enumerate(self._sec_names)}
        self._sec_schemas = {t: db.table(t) for t in self._sec_names}
        self._sec_lane_exprs: Dict[str, List[Expr]] = {
            t: [] for t in self._sec_names
        }
        self._sec_lane_of: Dict[str, Dict[Tuple, int]] = {
            t: {} for t in self._sec_names
        }

        def sec_lane(table: str, e: Expr) -> None:
            lanes = self._sec_lane_of[table]
            if e.key not in lanes:
                lanes[e.key] = len(self._sec_lane_exprs[table])
                self._sec_lane_exprs[table].append(e)

        for lj in self.ljoins.values():
            sec_lane(lj.table, lj.arg)
        self._union_tables: Tuple[str, ...] = ()
        for wa in self.waggs.values():
            for t in wa.union:
                sec_lane(t, wa.arg)
                if t not in self._union_tables:
                    self._union_tables += (t,)
        # which secondary tables are key-partitioned (set by ShardedOnlineStore
        # before first trace); partitioned union rings are gathered at the
        # shard-local request key, replicated ones at the global key
        self._sec_sharded: Dict[str, bool] = {t: False for t in self._sec_names}
        # request-time join-key columns (primary columns named by LAST JOINs)
        self._join_cols: Tuple[str, ...] = ()
        for lj in self.ljoins.values():
            if lj.on not in self._join_cols:
                self._join_cols += (lj.on,)
        self._join_col_index = {c: i for i, c in enumerate(self._join_cols)}

        sec_nk = secondary_num_keys or {}
        sec_cap = secondary_capacity or capacity
        self.secondary_num_keys = {
            t: int(sec_nk.get(t, num_keys)) for t in self._sec_names
        }
        sec_rings = tuple(
            st.ring_init(
                self.secondary_num_keys[t],
                sec_cap,
                max(len(self._sec_lane_exprs[t]), 1),
            )
            for t in self._sec_names
        )

        self.state = OnlineState(
            ring=st.ring_init(num_keys, capacity, self.num_lanes),
            bagg=pg.bucket_init(num_keys, num_buckets, self.num_lanes, bucket_size),
            sec=sec_rings,
        )
        # jit caches (compiled once per view version)
        self._ingest_fn = jax.jit(self._ingest_pure, donate_argnums=(0,))
        self._sec_ingest_fns = {
            t: jax.jit(
                functools.partial(self._sec_ingest_pure, index=i),
                donate_argnums=(0,),
            )
            for t, i in self._sec_index.items()
        }
        self._query_naive_fn = jax.jit(self._query_pure_naive)
        self._query_preagg_fn = jax.jit(self._query_pure_preagg)

    # -- lane evaluation ------------------------------------------------------

    def _lanes(self, columns: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """(N, L) materialized window-arg lanes from raw columns."""
        if not self._lane_exprs:
            n = jnp.asarray(columns[self.schema.key]).shape[0]
            return jnp.zeros((n, 1), jnp.float32)
        vals = [
            eval_rowlevel(e, columns, {}).astype(jnp.float32)
            for e in self._lane_exprs
        ]
        return jnp.stack(vals, axis=-1)

    # -- ingest -----------------------------------------------------------------

    def _ingest_pure(self, state: OnlineState, key, ts, lanes) -> OnlineState:
        ring = st.ring_ingest(state.ring, key, ts, lanes)
        bagg = pg.bucket_ingest(state.bagg, key, ts, lanes)
        return OnlineState(ring=ring, bagg=bagg, sec=state.sec)

    def ingest(self, columns: Dict[str, jnp.ndarray]) -> None:
        """Ingest a batch of raw rows (must be (key, ts)-sorted).

        ``bucket_ingest`` requires each fused batch to span fewer than
        ``num_buckets`` pre-agg buckets (a slot must receive at most one
        new bucket id per scatter).  Historical backfills can span the
        whole table's time range, so oversized batches are split here on
        bucket boundaries — each chunk stays one fused scatter.
        """
        key = jnp.asarray(columns[self.schema.key], jnp.int32)
        ts = jnp.asarray(columns[self.schema.ts], jnp.int32)
        lanes = self._lanes(columns)

        import numpy as _np

        ts_h = _np.asarray(ts)
        if ts_h.size == 0:
            return
        b = ts_h // self.bucket_size
        span_ok = (b.max() - b.min()) < self.num_buckets - 1
        if span_ok:
            self._ingest_padded(key, ts, lanes)
            return
        # split into chunks each spanning < num_buckets buckets; rows are
        # (key, ts)-sorted, so chunk by absolute-bucket epoch and re-sort
        # each chunk by (key, ts).
        epoch = b // (self.num_buckets - 1)
        for e in _np.unique(epoch):
            idx = _np.nonzero(epoch == e)[0]
            order = idx[_np.lexsort((ts_h[idx], _np.asarray(key)[idx]))]
            self._ingest_padded(key[order], ts[order], lanes[order])

    @staticmethod
    def _pad_batch(key, ts, lanes, sentinel: int):
        """Pad a fused ingest batch to a power-of-two shape bucket so one
        compiled executable serves every batch size (the paper's compilation
        caching).  Padding rows carry an out-of-range ``sentinel`` key:
        gathers clip (harmless) and every state scatter drops them."""
        n = int(key.shape[0])
        m = max(64, 1 << (n - 1).bit_length())
        if m != n:
            pad = m - n
            key = jnp.concatenate(
                [key, jnp.full((pad,), sentinel, jnp.int32)]
            )
            ts = jnp.concatenate([ts, jnp.broadcast_to(ts[-1], (pad,))])
            lanes = jnp.concatenate(
                [lanes, jnp.zeros((pad, lanes.shape[1]), lanes.dtype)]
            )
        return key, ts, lanes

    def _ingest_padded(self, key, ts, lanes) -> None:
        key, ts, lanes = self._pad_batch(key, ts, lanes, self.num_keys)
        self.state = self._ingest_fn(self.state, key, ts, lanes)

    # -- secondary-table ingest ----------------------------------------------

    def _sec_ingest_pure(
        self, state: OnlineState, key, ts, lanes, *, index: int
    ) -> OnlineState:
        sec = list(state.sec)
        sec[index] = st.ring_ingest(sec[index], key, ts, lanes)
        return OnlineState(ring=state.ring, bagg=state.bagg, sec=tuple(sec))

    def ingest_table(self, table: str, columns: Dict[str, jnp.ndarray]) -> None:
        """Ingest a (key, ts)-sorted batch of rows into a secondary table's
        ring (no pre-aggregates: secondary state serves LAST JOIN lookups
        and union windows, both answered from raw rings)."""
        if table == self.schema.name:
            return self.ingest(columns)
        if table not in self._sec_index:
            raise KeyError(
                f"view {self.view.name!r} does not reference table {table!r}"
            )
        sch = self._sec_schemas[table]
        key = jnp.asarray(columns[sch.key], jnp.int32)
        n = int(key.shape[0])
        if n == 0:
            return
        ts = jnp.asarray(columns[sch.ts], jnp.int32)
        exprs = self._sec_lane_exprs[table]
        if exprs:
            lanes = jnp.stack(
                [
                    eval_rowlevel(e, columns, {}).astype(jnp.float32)
                    for e in exprs
                ],
                axis=-1,
            )
        else:
            lanes = jnp.zeros((n, 1), jnp.float32)
        self._sec_ingest_padded(table, key, ts, lanes)

    def _sec_ingest_padded(self, table: str, key, ts, lanes) -> None:
        key, ts, lanes = self._pad_batch(
            key, ts, lanes, self.secondary_num_keys[table]
        )
        self.state = self._sec_ingest_fns[table](self.state, key, ts, lanes)

    # -- window masks -------------------------------------------------------------

    def _window_mask(self, wa: WindowAgg, ts_buf, valid, ts_q) -> jnp.ndarray:
        not_future = ts_buf <= ts_q[:, None]
        if wa.window.mode == "range":
            lo = ts_q - jnp.int32(wa.window.size) + 1
            return valid & not_future & (ts_buf >= lo[:, None])
        # rows mode: last (size-1) eligible rows; the request row is the
        # size-th.  Rank from the newest backwards.
        eligible = valid & not_future
        newer = jnp.cumsum(eligible[:, ::-1].astype(jnp.int32), axis=1)[:, ::-1]
        rank_from_new = newer - eligible.astype(jnp.int32)  # 0 == newest
        return eligible & (rank_from_new < wa.window.size - 1)

    # -- secondary-state lookups ---------------------------------------------

    def _union_gathers(self, state, key, gkey):
        """Gather each union table's ring at the request key (shared across
        every union wagg touching that table).

        ``key`` is the primary-store key (shard-local in a
        :class:`~repro.core.shard.ShardedOnlineStore`), ``gkey`` the global
        key: key-partitioned union rings hold local ids, replicated ones
        global ids.  For the single-device store both are the same array.
        """
        return {
            t: st.ring_gather(
                state.sec[self._sec_index[t]],
                key if self._sec_sharded.get(t) else gkey,
            )
            for t in self._union_tables
        }

    def _last_join_vals(self, state, ts_q, join_keys) -> List[jnp.ndarray]:
        """Point-in-time LAST JOIN answers, one (Q,) vector per join.

        Newest secondary row with key == request's join key and
        ``ts <= request ts``; ties on ts resolve to the latest-ingested row
        (matching the offline stable (key, ts) sort).
        """
        out = []
        gathers = {}
        for lk in self._ljoin_order:
            lj = self.ljoins[lk]
            jk = join_keys[self._join_col_index[lj.on]]
            gk = (lj.table, lj.on)
            if gk not in gathers:
                gathers[gk] = st.ring_gather(
                    state.sec[self._sec_index[lj.table]], jk
                )
            ts_t, lanes_t, valid_t = gathers[gk]
            g = lanes_t[..., self._sec_lane_of[lj.table][lj.arg.key]]
            m = valid_t & (ts_t <= ts_q[:, None])
            ts_m = jnp.where(m, ts_t, _TS_MIN)
            mx = jnp.max(ts_m, axis=1)
            cand = m & (ts_t == mx[:, None])
            C = ts_t.shape[1]
            pos = C - 1 - jnp.argmax(cand[:, ::-1], axis=1)
            val = jnp.take_along_axis(g, pos[:, None], axis=1)[:, 0]
            found = m.any(axis=1)
            out.append(jnp.where(found, val, jnp.float32(lj.default)))
        return out

    # -- the one query path ---------------------------------------------------

    def _preagg_parts(self, wa, state, key, ts_q, ts_buf, valid, lane):
        """Raw boundary-row mask + gathered middle-bucket states for a RANGE
        window on the pre-agg path.

        The window decomposes into [raw head rows in the oldest partial
        bucket] + [full buckets strictly inside] + [raw tail rows in the
        request's bucket]; middles come back as persisted aggregate states
        ready for ``AggSpec.fold_buckets``.
        """
        B = jnp.int32(self.bucket_size)
        nb = self.num_buckets
        bucket_buf = ts_buf // B
        T = jnp.int32(wa.window.size)
        lo = ts_q - T + 1
        b_q = ts_q // B
        b_lo = (ts_q - T) // B
        not_future = ts_buf <= ts_q[:, None]
        in_lo = ts_buf >= lo[:, None]
        head_m = (
            valid & not_future & in_lo
            & (bucket_buf == b_lo[:, None]) & (b_lo != b_q)[:, None]
        )
        tail_m = valid & not_future & in_lo & (bucket_buf == b_q[:, None])
        raw = head_m | tail_m

        # middle full buckets b_lo+1 .. b_q-1, selected by membership
        M = self._max_mid(wa)
        mids = b_lo[:, None] + 1 + jnp.arange(M, dtype=jnp.int32)[None, :]
        mvalid = mids < b_q[:, None]
        slots = mids % nb
        stored = state.bagg.bucket[key[:, None], slots]
        ok = mvalid & (stored == mids)
        ms = state.bagg.stats[key[:, None], slots, lane]   # (Q, M, NUM_STATS)
        mb = state.bagg.bitmap[key[:, None], slots, lane]  # (Q, M)
        return raw, ms, mb, ok

    def _query_pure(self, state, key, ts_q, req_lanes, join_keys, gkey,
                    use_preagg: bool):
        """Generic fold-then-finalize over every window aggregation.

        For each wagg: lift the request row, combine with the primary
        window's fold (raw ring rows, or boundary rows ⊕ bucket states on
        the pre-agg path), combine with each union table's fold, finalize.
        All semantics live in the :mod:`repro.core.aggregates` specs.
        """
        ts_buf, lanes_buf, valid = st.ring_gather(state.ring, key)
        sec_gathers = self._union_gathers(state, key, gkey)
        out = []
        for wk in self._wagg_order:
            wa = self.waggs[wk]
            spec = agg_spec(wa.agg)
            lane = self._lane_of[wa.arg.key]
            g = lanes_buf[..., lane]
            r = req_lanes[:, lane]
            # merge-order coordinate of the request row: primary stream
            # (rank = len(union), matching join.merge_streams), newer than
            # any stored row of the same (ts, stream)
            prim_rank = jnp.int32(len(wa.union))
            acc = spec.lift(r, ts_q, prim_rank, _POS_MAX)
            use_buckets = (
                use_preagg
                and spec.bucket_composable
                and wa.window.mode == "range"
                and (not wa.union or self._union_preagg.get(wk, False))
            )
            if use_buckets:
                raw, ms, mb, ok = self._preagg_parts(
                    wa, state, key, ts_q, ts_buf, valid, lane
                )
                acc = spec.combine(
                    acc, spec.fold_rows(g, ts_buf, raw, prim_rank)
                )
                acc = spec.combine(acc, spec.fold_buckets(ms, mb, ok))
            else:
                m = self._window_mask(wa, ts_buf, valid, ts_q)
                acc = spec.combine(
                    acc, spec.fold_rows(g, ts_buf, m, prim_rank)
                )
            for rank, t in enumerate(wa.union):
                ts_t, lanes_t, valid_t = sec_gathers[t]
                g_t = lanes_t[..., self._sec_lane_of[t][wa.arg.key]]
                m_t = self._window_mask(wa, ts_t, valid_t, ts_q)
                acc = spec.combine(
                    acc, spec.fold_rows(g_t, ts_t, m_t, jnp.int32(rank))
                )
            out.append(spec.finalize(acc, n=wa.n))
        out.extend(self._last_join_vals(state, ts_q, join_keys))
        return tuple(out)

    def _query_pure_naive(self, state, key, ts_q, req_lanes, join_keys, gkey):
        return self._query_pure(
            state, key, ts_q, req_lanes, join_keys, gkey, use_preagg=False
        )

    def _query_pure_preagg(self, state, key, ts_q, req_lanes, join_keys, gkey):
        return self._query_pure(
            state, key, ts_q, req_lanes, join_keys, gkey, use_preagg=True
        )

    def _max_mid(self, wa: WindowAgg) -> int:
        """Static bound on middle-bucket count for a window."""
        return max(1, min(self.num_buckets, wa.window.size // self.bucket_size + 1))

    # -- public query ---------------------------------------------------------------------

    @classmethod
    def create(
        cls,
        view,
        *,
        num_keys: int,
        num_shards: Optional[int] = None,
        **store_kwargs,
    ) -> "OnlineFeatureStore":
        """Factory shared by every deployment path (services, verify_view):
        a single-device store, or a :class:`~repro.core.shard.
        ShardedOnlineStore` when ``num_shards`` is given."""
        if num_shards is not None:
            from repro.core.shard import ShardedOnlineStore

            return ShardedOnlineStore(
                view, num_keys=num_keys, num_shards=num_shards, **store_kwargs
            )
        return OnlineFeatureStore(view, num_keys=num_keys, **store_kwargs)

    def _validate_join_cols(self, columns: Dict[str, jnp.ndarray]) -> None:
        for c in self._join_cols:
            if c not in columns:
                raise KeyError(
                    f"request rows must carry join-key column {c!r} "
                    f"(LAST JOIN on {c!r} in view {self.view.name!r})"
                )

    def _request_arrays(self, columns: Dict[str, jnp.ndarray]):
        """(key, ts, lanes, join_keys) request tensors, join cols validated."""
        self._validate_join_cols(columns)
        key = jnp.asarray(columns[self.schema.key], jnp.int32)
        ts_q = jnp.asarray(columns[self.schema.ts], jnp.int32)
        req_lanes = self._lanes(columns)
        join_keys = tuple(
            jnp.asarray(columns[c], jnp.int32) for c in self._join_cols
        )
        return key, ts_q, req_lanes, join_keys

    def _finish_query(
        self, columns, vals
    ) -> Dict[str, jnp.ndarray]:
        """Pre-agg answers -> named features via row-level post-expressions."""
        pre_values = dict(
            zip(self._wagg_order + self._ljoin_order, vals)
        )
        out: Dict[str, jnp.ndarray] = {}
        for fname, fexpr in self.view.features.items():
            out[fname] = eval_rowlevel(fexpr, columns, pre_values)
        return out

    def query(
        self, columns: Dict[str, jnp.ndarray], mode: str = "preagg"
    ) -> Dict[str, jnp.ndarray]:
        """Compute all view features for a batch of request rows.

        columns: raw request columns incl. key, ts, and any LAST JOIN key
        columns; (Q,) each.  Returns {feature_name: (Q,) f32}.
        """
        key, ts_q, req_lanes, join_keys = self._request_arrays(columns)
        fn = self._query_naive_fn if mode == "naive" else self._query_preagg_fn
        # pad the request to a power-of-two shape bucket (compilation
        # caching: one executable per bucket, not per request size)
        q = int(key.shape[0])
        m = max(16, 1 << (q - 1).bit_length())
        if m != q:
            pad = m - q
            key_p = jnp.concatenate([key, jnp.broadcast_to(key[-1], (pad,))])
            ts_p = jnp.concatenate([ts_q, jnp.broadcast_to(ts_q[-1], (pad,))])
            lanes_p = jnp.concatenate(
                [req_lanes,
                 jnp.broadcast_to(req_lanes[-1:], (pad, req_lanes.shape[1]))]
            )
            jk_p = tuple(
                jnp.concatenate([j, jnp.broadcast_to(j[-1], (pad,))])
                for j in join_keys
            )
            vals = fn(self.state, key_p, ts_p, lanes_p, jk_p, key_p)
            vals = tuple(v[:q] for v in vals)
        else:
            vals = fn(self.state, key, ts_q, req_lanes, join_keys, key)
        return self._finish_query(columns, vals)
