"""Vectorized per-key window aggregation over (key, ts)-sorted batches.

This is the *offline* executor's compute core (and the oracle the online
store is verified against).  FeatInsight/OpenMLDB evaluates, for every row,
aggregates over a per-key window ending at that row.  On CPU OpenMLDB walks
a skiplist; on TPU we restructure the whole computation into dense
data-parallel primitives:

* windowed SUM/COUNT/MEAN/STD  -> segmented prefix sums, O(N);
* windowed MIN/MAX             -> segmented sparse table (doubling), O(N log N);
* RANGE window starts          -> vectorized lexicographic binary search;
* DISTINCT_APPROX              -> 32-bit linear-counting bitmap, OR-doubling;
* TOPN_FREQ                    -> exact tail-window frequency ranking.

All functions assume rows are sorted by (key, ts) — the invariant the
paper's storage maintains by construction ("pre-sorting data by key and
timestamp for rapid online access").
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.expr import Agg, WindowSpec
from repro.core.hashing import mix64

__all__ = [
    "sort_by_key_ts",
    "segment_starts",
    "window_start_rows",
    "window_start_range",
    "windowed_aggregate",
]

_NEG_INF = jnp.float32(-3.0e38)
_POS_INF = jnp.float32(3.0e38)


def sort_by_key_ts(
    key: jnp.ndarray, ts: jnp.ndarray, *cols: jnp.ndarray
) -> Tuple[jnp.ndarray, ...]:
    """Stable sort rows by (key, ts).  Returns (key, ts, *cols, perm)."""
    n = key.shape[0]
    # lexsort: sort by ts first, then stable-sort by key.
    order = jnp.argsort(ts, stable=True)
    key1, ts1 = key[order], ts[order]
    order2 = jnp.argsort(key1, stable=True)
    perm = order[order2]
    out = [key[perm], ts[perm]]
    out.extend(c[perm] for c in cols)
    out.append(perm)
    return tuple(out)


def segment_starts(key: jnp.ndarray) -> jnp.ndarray:
    """(N,) int32: index of the first row of each row's key segment."""
    n = key.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.array([True]), key[1:] != key[:-1]]
    )
    start_idx = jnp.where(is_start, idx, 0)
    return jax.lax.associative_scan(jnp.maximum, start_idx)


def window_start_rows(seg_start: jnp.ndarray, size: int) -> jnp.ndarray:
    """First in-window row index for a ROWS window of ``size``."""
    n = seg_start.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    return jnp.maximum(seg_start, idx - jnp.int32(size - 1))


def window_start_range(
    key: jnp.ndarray, ts: jnp.ndarray, seg_start: jnp.ndarray, size: int
) -> jnp.ndarray:
    """First row index with ts > ts_i - size within the same key segment.

    Vectorized lexicographic binary search over the (key, ts)-sorted arrays:
    for every row i we search the first j with (key_j, ts_j) >=
    (key_i, ts_i - size + 1).  32 halving steps, fully data-parallel.
    """
    n = key.shape[0]
    target_ts = ts - jnp.int32(size) + jnp.int32(1)
    lo = jnp.zeros((n,), jnp.int32)
    hi = jnp.arange(n, dtype=jnp.int32)  # answer is <= i (window includes i)

    steps = max(1, int(math.ceil(math.log2(max(n, 2)))) + 1)

    def body(_, lohi):
        lo, hi = lohi
        active = lo < hi
        mid = (lo + hi) // 2
        k_m, t_m = key[mid], ts[mid]
        # (k_m, t_m) < (key, target_ts) lexicographically?
        lt = (k_m < key) | ((k_m == key) & (t_m < target_ts))
        lo = jnp.where(active & lt, mid + 1, lo)
        hi = jnp.where(active & ~lt, mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return jnp.maximum(lo, seg_start)


# ---------------------------------------------------------------------------
# Segmented prefix machinery
# ---------------------------------------------------------------------------


def _two_sum(a: jnp.ndarray, b: jnp.ndarray):
    """Knuth TwoSum: s + err == a + b exactly (err is the rounding error)."""
    s = a + b
    bb = s - a
    err = (a - (s - bb)) + (b - bb)
    return s, err


def _df_add(a_hi, a_lo, b_hi, b_lo):
    """Double-float (hi, lo) addition — associative to O(eps^2)."""
    s, err = _two_sum(a_hi, b_hi)
    lo = err + a_lo + b_lo
    hi, lo = _two_sum(s, lo)
    return hi, lo


def _segment_prefix_sum(
    x: jnp.ndarray, seg_start: jnp.ndarray, compensated: bool = True
):
    """Inclusive prefix sum restarting at each key segment.

    Restarting bounds accumulation error by per-key magnitudes rather than
    whole-table magnitudes, and each prefix is carried as an unevaluated
    compensated (hi, lo) double-float pair combined with TwoSum, so the
    residual error is O(eps^2 * per-key prefix magnitude) — small enough
    that STD's sqrt near zero no longer amplifies prefix noise into
    visible error (plain f32 prefixes put single-row windows at ~1e-1
    instead of 0 for value scales ~1e2).  Returns the (hi, lo) pair;
    consume with :func:`_range_sum`.

    ``compensated=False`` skips the second scan lane for inputs whose
    prefixes are exact in f32 anyway (COUNT: small integers), returning
    (prefix, zeros).
    """
    n = x.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = idx == seg_start
    xf = x.astype(jnp.float32)

    if not compensated:
        def comb1(a, b):
            flag_a, val_a = a
            flag_b, val_b = b
            return flag_a | flag_b, jnp.where(flag_b, val_b, val_a + val_b)

        _, out = jax.lax.associative_scan(comb1, (is_start, xf))
        return out, jnp.zeros_like(out)

    def comb(a, b):
        flag_a, hi_a, lo_a = a
        flag_b, hi_b, lo_b = b
        hi, lo = _df_add(hi_a, lo_a, hi_b, lo_b)
        return (
            flag_a | flag_b,
            jnp.where(flag_b, hi_b, hi),
            jnp.where(flag_b, lo_b, lo),
        )

    _, hi, lo = jax.lax.associative_scan(
        comb, (is_start, xf, jnp.zeros_like(xf))
    )
    return hi, lo


def _range_sum(
    ps, j: jnp.ndarray, i: jnp.ndarray, seg_start: jnp.ndarray
) -> jnp.ndarray:
    """sum over rows [j, i] given segment-restarted compensated prefixes."""
    hi, lo = ps
    take = j > seg_start
    jm = jnp.maximum(j - 1, 0)
    left_hi = jnp.where(take, hi[jm], 0.0)
    left_lo = jnp.where(take, lo[jm], 0.0)
    # subtract hi parts first (they cancel), then fold in the compensations
    return (hi[i] - left_hi) + (lo[i] - left_lo)


class _SparseTable:
    """Doubling table for associative idempotent ops (min/max/bitwise-or).

    Level k holds op over [i - 2^k + 1, i], masked so windows never cross
    the row's key-segment start.
    """

    def __init__(self, x: jnp.ndarray, seg_start: jnp.ndarray, op, ident):
        n = x.shape[0]
        self.levels = [x]
        self.op = op
        idx = jnp.arange(n, dtype=jnp.int32)
        k = 0
        while (1 << (k + 1)) <= max(n, 1):
            half = 1 << k
            prev = self.levels[-1]
            shifted = jnp.where(
                (idx - half >= seg_start)[..., None] if prev.ndim > 1 else (idx - half >= seg_start),
                prev[jnp.maximum(idx - half, 0)],
                ident,
            )
            self.levels.append(op(prev, shifted))
            k += 1

    def query(self, j: jnp.ndarray, i: jnp.ndarray) -> jnp.ndarray:
        """op over [j, i] (requires j <= i, same segment)."""
        length = i - j + 1
        # floor(log2(length)) via 31 - clz
        k = 31 - jax.lax.clz(length.astype(jnp.int32))
        k = jnp.maximum(k, 0)
        levels = jnp.stack(self.levels, 0)  # (K, N, ...)
        a = levels[k, i]
        b = levels[k, j + (jnp.int32(1) << k) - 1]
        return self.op(a, b)


# ---------------------------------------------------------------------------
# Aggregation dispatch
# ---------------------------------------------------------------------------


def _topn_tail(
    vals: jnp.ndarray,
    j: jnp.ndarray,
    i: jnp.ndarray,
    tail: int,
    n: int,
) -> jnp.ndarray:
    """Exact n-th most-frequent value over the window tail (<= tail rows).

    Gathers the last ``min(window, tail)`` values per row and ranks by
    (frequency, value).  O(N * tail^2) — tail is small (<=64) by contract.
    """
    N = vals.shape[0]
    idx = jnp.arange(N, dtype=jnp.int32)[:, None]
    offs = jnp.arange(tail, dtype=jnp.int32)[None, :]
    pos = i[:, None] - offs  # most-recent first
    valid = pos >= j[:, None]
    g = vals[jnp.maximum(pos, 0)]  # (N, tail)
    # frequency of each tail element within the valid tail
    eq = (g[:, :, None] == g[:, None, :]) & valid[:, :, None] & valid[:, None, :]
    freq = eq.sum(-1).astype(jnp.float32)  # (N, tail)
    freq = jnp.where(valid, freq, -1.0)
    # dedupe: occurrence j is "first" (most recent) if no earlier slot k<j
    # in the tail holds the same value
    earlier = jnp.tril(jnp.ones((tail, tail), bool), -1)  # earlier[a, k] = k < a
    same_as_earlier = (eq & earlier[None, :, :]).any(-1)
    is_first = valid & ~same_as_earlier
    score = jnp.where(is_first, freq, -1.0)
    # rank by (freq desc, value asc) — compose into one sortable score
    vmax = jnp.max(jnp.abs(g), initial=1.0)
    composite = score * (2.0 * vmax + 1.0) - g
    order = jnp.argsort(-composite, axis=-1)
    pick = order[:, n]
    picked_score = jnp.take_along_axis(score, pick[:, None], axis=1)[:, 0]
    val = jnp.take_along_axis(g, pick[:, None], axis=1)[:, 0]
    return jnp.where(picked_score >= 0.0, val, 0.0)


TOPN_TAIL = 32  # contract: TOPN_FREQ windows are evaluated over <=32 rows


def windowed_aggregate(
    key: jnp.ndarray,
    ts: jnp.ndarray,
    requests: Dict[Tuple, Tuple[Agg, jnp.ndarray, WindowSpec, int]],
) -> Dict[Tuple, jnp.ndarray]:
    """Evaluate a batch of window aggregations over (key, ts)-sorted rows.

    ``requests`` maps a structural key -> (agg, arg_values (N,), window, n).
    Results are (N,) f32, one value per row (point-in-time correct: row i's
    window ends at and includes row i).

    Shared work (segment starts, window starts, prefix sums per distinct
    (arg, window)) is CSE'd across requests — the analogue of OpenMLDB
    executing all features of a view in one pass over the window.
    """
    seg = segment_starts(key)
    n_rows = key.shape[0]
    idx = jnp.arange(n_rows, dtype=jnp.int32)

    # window start per distinct window spec
    starts: Dict[Tuple, jnp.ndarray] = {}

    def start_of(w: WindowSpec) -> jnp.ndarray:
        wk = (w.mode, w.size)
        if wk not in starts:
            if w.mode == "rows":
                starts[wk] = window_start_rows(seg, w.size)
            else:
                starts[wk] = window_start_range(key, ts, seg, w.size)
        return starts[wk]

    # prefix sums per distinct arg id — CSE on array identity.  Values are
    # centered by their global mean first: windowed sums/variances are
    # shift-invariant (modulo the mu*count term added back), and centering
    # keeps f32 prefix magnitudes at variance scale instead of mean^2 scale
    # (otherwise STD suffers catastrophic cancellation).
    ps_cache: Dict[int, Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = {}

    def psums(arr: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        k = id(arr)
        if k not in ps_cache:
            mu = jnp.mean(arr)
            c = arr - mu
            ps_cache[k] = (
                mu,
                _segment_prefix_sum(c, seg),
                _segment_prefix_sum(c * c, seg),
            )
        return ps_cache[k]

    table_cache: Dict[Tuple[int, str], _SparseTable] = {}

    def table_of(arr: jnp.ndarray, kind: str) -> _SparseTable:
        ck = (id(arr), kind)
        if ck not in table_cache:
            if kind == "min":
                table_cache[ck] = _SparseTable(arr, seg, jnp.minimum, _POS_INF)
            elif kind == "max":
                table_cache[ck] = _SparseTable(arr, seg, jnp.maximum, _NEG_INF)
            else:  # bitmap OR for distinct counting
                bit = (jnp.int32(1) << (mix64(arr, salt=77, bits=5))).astype(
                    jnp.int32
                )
                table_cache[ck] = _SparseTable(
                    bit, seg, jnp.bitwise_or, jnp.int32(0)
                )
        return table_cache[ck]

    out: Dict[Tuple, jnp.ndarray] = {}
    count_ps = _segment_prefix_sum(
        jnp.ones((n_rows,), jnp.float32), seg, compensated=False
    )

    for rk, (agg, arr, w, nth) in requests.items():
        j = start_of(w)
        if agg in (Agg.SUM, Agg.MEAN, Agg.STD, Agg.COUNT):
            cnt = _range_sum(count_ps, j, idx, seg)
            if agg == Agg.COUNT:
                out[rk] = cnt
                continue
            mu, ps, ps2 = psums(arr)
            s = _range_sum(ps, j, idx, seg)  # windowed sum of centered values
            if agg == Agg.SUM:
                out[rk] = s + mu * cnt
            elif agg == Agg.MEAN:
                out[rk] = s / jnp.maximum(cnt, 1.0) + mu
            else:  # STD (population; shift-invariant)
                s2 = _range_sum(ps2, j, idx, seg)
                m = s / jnp.maximum(cnt, 1.0)
                var = jnp.maximum(s2 / jnp.maximum(cnt, 1.0) - m * m, 0.0)
                out[rk] = jnp.sqrt(var)
        elif agg == Agg.MIN:
            out[rk] = table_of(arr, "min").query(j, idx)
        elif agg == Agg.MAX:
            out[rk] = table_of(arr, "max").query(j, idx)
        elif agg == Agg.LAST:
            out[rk] = arr
        elif agg == Agg.FIRST:
            out[rk] = arr[j]
        elif agg == Agg.DISTINCT_APPROX:
            bits = table_of(arr, "or").query(j, idx)
            ones = jax.lax.population_count(bits).astype(jnp.float32)
            m = 32.0
            frac = jnp.clip(ones / m, 0.0, 1.0 - 1e-6)
            out[rk] = -m * jnp.log1p(-frac)
        elif agg == Agg.TOPN_FREQ:
            out[rk] = _topn_tail(arr, j, idx, TOPN_TAIL, nth)
        else:
            raise ValueError(f"unhandled agg {agg}")
    return out
