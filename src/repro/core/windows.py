"""Vectorized per-key window aggregation over (key, ts)-sorted batches.

This is the *offline* executor's compute core (and the oracle the online
store is verified against).  FeatInsight/OpenMLDB evaluates, for every row,
aggregates over a per-key window ending at that row.  On CPU OpenMLDB walks
a skiplist; on TPU we restructure the whole computation into dense
data-parallel primitives.

Semantics come from ONE place — the aggregator algebra in
:mod:`repro.core.aggregates` (each ``Agg``'s (init, lift, combine,
finalize)).  This module contributes the *evaluation strategies* for folds
of those monoids over per-row windows ``[j_i, i]``:

* invertible lanes (sum/count/sumsq) -> segmented compensated prefix sums
  (TwoSum double-float, restarted per key) and a range difference — the
  group structure makes the fold O(N);
* idempotent lanes (min/max) and OR-bitmaps -> :func:`segmented_windowed_fold`,
  a doubling scan of *static* shifted combines (log2 N levels, each a pad +
  slice — never a gather) plus a two-gather overlapping-span query.  This
  replaces the old sparse-table formulation whose chained dynamic gathers
  made XLA compile minutes-slow at N >~ 5k; the level build is also the
  Pallas segmented-combine kernel in :mod:`repro.kernels.window_agg`;
* extreme states (FIRST/LAST) -> boundary closed form: the fold of an
  argmin/argmax-by-merge-order monoid over the contiguous range [j, i] is
  exactly row j (FIRST) or row i (LAST);
* tail states (TOPN_FREQ) -> tail closed form: the fold keeps the newest
  ``TOPN_TAIL`` rows, which are directly gatherable as [max(j, i-T+1), i].

All functions assume rows are sorted by (key, ts) — the invariant the
paper's storage maintains by construction ("pre-sorting data by key and
timestamp for rapid online access").
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import aggregates as ag
from repro.core.aggregates import TOPN_TAIL, agg_spec
from repro.core.expr import Agg, WindowSpec
from repro.kernels.window_agg.ops import fold_levels
from repro.kernels.window_agg.ref import fold_op

__all__ = [
    "sort_by_key_ts",
    "segment_starts",
    "window_start_rows",
    "window_start_range",
    "segmented_windowed_fold",
    "windowed_aggregate",
    "TOPN_TAIL",
]


def sort_by_key_ts(
    key: jnp.ndarray, ts: jnp.ndarray, *cols: jnp.ndarray
) -> Tuple[jnp.ndarray, ...]:
    """Stable sort rows by (key, ts).  Returns (key, ts, *cols, perm)."""
    n = key.shape[0]
    # lexsort: sort by ts first, then stable-sort by key.
    order = jnp.argsort(ts, stable=True)
    key1, ts1 = key[order], ts[order]
    order2 = jnp.argsort(key1, stable=True)
    perm = order[order2]
    out = [key[perm], ts[perm]]
    out.extend(c[perm] for c in cols)
    out.append(perm)
    return tuple(out)


def segment_starts(key: jnp.ndarray) -> jnp.ndarray:
    """(N,) int32: index of the first row of each row's key segment."""
    n = key.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.array([True]), key[1:] != key[:-1]]
    )
    start_idx = jnp.where(is_start, idx, 0)
    return jax.lax.associative_scan(jnp.maximum, start_idx)


def window_start_rows(seg_start: jnp.ndarray, size: int) -> jnp.ndarray:
    """First in-window row index for a ROWS window of ``size``."""
    n = seg_start.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    return jnp.maximum(seg_start, idx - jnp.int32(size - 1))


def window_start_range(
    key: jnp.ndarray, ts: jnp.ndarray, seg_start: jnp.ndarray, size: int
) -> jnp.ndarray:
    """First row index with ts > ts_i - size within the same key segment.

    Vectorized lexicographic binary search over the (key, ts)-sorted arrays:
    for every row i we search the first j with (key_j, ts_j) >=
    (key_i, ts_i - size + 1).  32 halving steps, fully data-parallel.
    """
    n = key.shape[0]
    target_ts = ts - jnp.int32(size) + jnp.int32(1)
    lo = jnp.zeros((n,), jnp.int32)
    hi = jnp.arange(n, dtype=jnp.int32)  # answer is <= i (window includes i)

    steps = max(1, int(math.ceil(math.log2(max(n, 2)))) + 1)

    def body(_, lohi):
        lo, hi = lohi
        active = lo < hi
        mid = (lo + hi) // 2
        k_m, t_m = key[mid], ts[mid]
        # (k_m, t_m) < (key, target_ts) lexicographically?
        lt = (k_m < key) | ((k_m == key) & (t_m < target_ts))
        lo = jnp.where(active & lt, mid + 1, lo)
        hi = jnp.where(active & ~lt, mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return jnp.maximum(lo, seg_start)


# ---------------------------------------------------------------------------
# Segmented prefix machinery (invertible lanes: sum / count / sumsq)
# ---------------------------------------------------------------------------


def _two_sum(a: jnp.ndarray, b: jnp.ndarray):
    """Knuth TwoSum: s + err == a + b exactly (err is the rounding error)."""
    s = a + b
    bb = s - a
    err = (a - (s - bb)) + (b - bb)
    return s, err


def _df_add(a_hi, a_lo, b_hi, b_lo):
    """Double-float (hi, lo) addition — associative to O(eps^2)."""
    s, err = _two_sum(a_hi, b_hi)
    lo = err + a_lo + b_lo
    hi, lo = _two_sum(s, lo)
    return hi, lo


def _segment_prefix_sum(
    x: jnp.ndarray, seg_start: jnp.ndarray, compensated: bool = True
):
    """Inclusive prefix sum restarting at each key segment.

    Restarting bounds accumulation error by per-key magnitudes rather than
    whole-table magnitudes, and each prefix is carried as an unevaluated
    compensated (hi, lo) double-float pair combined with TwoSum, so the
    residual error is O(eps^2 * per-key prefix magnitude) — small enough
    that STD's sqrt near zero no longer amplifies prefix noise into
    visible error (plain f32 prefixes put single-row windows at ~1e-1
    instead of 0 for value scales ~1e2).  Returns the (hi, lo) pair;
    consume with :func:`_range_sum`.

    ``compensated=False`` skips the second scan lane for inputs whose
    prefixes are exact in f32 anyway (COUNT: small integers), returning
    (prefix, zeros).
    """
    n = x.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = idx == seg_start
    xf = x.astype(jnp.float32)

    if not compensated:
        def comb1(a, b):
            flag_a, val_a = a
            flag_b, val_b = b
            return flag_a | flag_b, jnp.where(flag_b, val_b, val_a + val_b)

        _, out = jax.lax.associative_scan(comb1, (is_start, xf))
        return out, jnp.zeros_like(out)

    def comb(a, b):
        flag_a, hi_a, lo_a = a
        flag_b, hi_b, lo_b = b
        hi, lo = _df_add(hi_a, lo_a, hi_b, lo_b)
        return (
            flag_a | flag_b,
            jnp.where(flag_b, hi_b, hi),
            jnp.where(flag_b, lo_b, lo),
        )

    _, hi, lo = jax.lax.associative_scan(
        comb, (is_start, xf, jnp.zeros_like(xf))
    )
    return hi, lo


def _range_sum(
    ps, j: jnp.ndarray, i: jnp.ndarray, seg_start: jnp.ndarray
) -> jnp.ndarray:
    """sum over rows [j, i] given segment-restarted compensated prefixes."""
    hi, lo = ps
    take = j > seg_start
    jm = jnp.maximum(j - 1, 0)
    left_hi = jnp.where(take, hi[jm], 0.0)
    left_lo = jnp.where(take, lo[jm], 0.0)
    # subtract hi parts first (they cancel), then fold in the compensations
    return (hi[i] - left_hi) + (lo[i] - left_lo)


# ---------------------------------------------------------------------------
# Segmented windowed fold (idempotent lanes: min / max / bitmap-or)
# ---------------------------------------------------------------------------


def segmented_windowed_fold(
    x: jnp.ndarray,
    seg_start: jnp.ndarray,
    j: jnp.ndarray,
    op: str,
    impl: str = "auto",
) -> jnp.ndarray:
    """op over rows ``[j_i, i]`` for every row i (op in min/max/or).

    Two phases:

    1. **level build** (the scan hot loop): doubling levels of the
       segmented combine, each level one static shifted combine — the
       Pallas segmented-combine kernel on TPU, identically-formulated
       XLA ops elsewhere (:func:`repro.kernels.window_agg.ops.fold_levels`);
    2. **query**: the window [j, i] is covered by the two (overlapping)
       power-of-two spans ending at i and starting at j — valid because
       these combines are idempotent — costing two gathers total.
    """
    n = x.shape[0]
    levels = fold_levels(x, seg_start, op=op, impl=impl)
    idx = jnp.arange(n, dtype=jnp.int32)
    length = idx - j + 1
    k = jnp.maximum(31 - jax.lax.clz(length.astype(jnp.int32)), 0)
    a = levels[k, idx]
    b = levels[k, j + (jnp.int32(1) << k) - 1]
    return fold_op(op)(a, b)


# ---------------------------------------------------------------------------
# Registry-driven aggregation
# ---------------------------------------------------------------------------


def windowed_aggregate(
    key: jnp.ndarray,
    ts: jnp.ndarray,
    requests: Dict[Tuple, Tuple[Agg, jnp.ndarray, WindowSpec, int]],
    impl: str = "auto",
) -> Dict[Tuple, jnp.ndarray]:
    """Evaluate a batch of window aggregations over (key, ts)-sorted rows.

    ``requests`` maps a structural key -> (agg, arg_values (N,), window, n).
    Results are (N,) f32, one value per row (point-in-time correct: row i's
    window ends at and includes row i).

    Each request is answered by folding its :class:`~repro.core.aggregates.
    AggSpec` monoid over the window and applying the spec's ``finalize`` —
    the same definitions the online store composes at request time.  Shared
    work (segment starts, window starts, prefix sums / fold levels per
    distinct arg) is CSE'd across requests — the analogue of OpenMLDB
    executing all features of a view in one pass over the window.
    """
    seg = segment_starts(key)
    n_rows = key.shape[0]
    idx = jnp.arange(n_rows, dtype=jnp.int32)

    # window start per distinct window spec
    starts: Dict[Tuple, jnp.ndarray] = {}

    def start_of(w: WindowSpec) -> jnp.ndarray:
        wk = (w.mode, w.size)
        if wk not in starts:
            if w.mode == "rows":
                starts[wk] = window_start_rows(seg, w.size)
            else:
                starts[wk] = window_start_range(key, ts, seg, w.size)
        return starts[wk]

    # prefix sums per distinct arg id — CSE on array identity.  Values are
    # centered by their global mean first: windowed sums/variances are
    # shift-invariant (modulo the mu*count term added back), and centering
    # keeps f32 prefix magnitudes at variance scale instead of mean^2 scale
    # (otherwise STD suffers catastrophic cancellation).
    ps_cache: Dict[int, Tuple[jnp.ndarray, Tuple, Tuple]] = {}

    def psums(arr: jnp.ndarray):
        k = id(arr)
        if k not in ps_cache:
            mu = jnp.mean(arr)
            c = arr - mu
            ps_cache[k] = (
                mu,
                _segment_prefix_sum(c, seg),
                _segment_prefix_sum(c * c, seg),
            )
        return ps_cache[k]

    # windowed folds per distinct (arg id, op) — min/max lanes and bitmaps
    fold_cache: Dict[Tuple[int, str], jnp.ndarray] = {}

    def fold_of(arr: jnp.ndarray, op: str, j: jnp.ndarray) -> jnp.ndarray:
        # the level build depends only on (arr, op); the two-gather query is
        # per window start, so cache on (arr, op, window) via j's id
        ck = (id(arr), op, id(j))
        if ck not in fold_cache:
            x = ag.row_bitmap(arr) if op == "or" else arr
            fold_cache[ck] = segmented_windowed_fold(x, seg, j, op, impl)
        return fold_cache[ck]

    count_ps = _segment_prefix_sum(
        jnp.ones((n_rows,), jnp.float32), seg, compensated=False
    )

    out: Dict[Tuple, jnp.ndarray] = {}
    for rk, (agg, arr, w, nth) in requests.items():
        spec = agg_spec(agg)
        j = start_of(w)

        if spec.state == "lanes":
            # STD is shift-invariant, so its lanes are evaluated on the
            # centered values directly (best numerics); SUM/MEAN are not,
            # so their sum lane is un-centered by adding mu * count back.
            state: Dict[str, jnp.ndarray] = {}
            cnt = _range_sum(count_ps, j, idx, seg)
            centered = agg == Agg.STD
            for lane in spec.lanes:
                if lane == "count":
                    state["count"] = cnt
                elif lane == "sum":
                    mu, ps, _ = psums(arr)
                    s = _range_sum(ps, j, idx, seg)
                    state["sum"] = s if centered else s + mu * cnt
                elif lane == "sumsq":
                    _, _, ps2 = psums(arr)
                    state["sumsq"] = _range_sum(ps2, j, idx, seg)
                else:  # min / max: idempotent — doubling fold
                    state[lane] = fold_of(arr, lane, j)
            out[rk] = spec.finalize(state, n=nth)
        elif spec.state == "bitmap":
            out[rk] = spec.finalize({"bits": fold_of(arr, "or", j)}, n=nth)
        elif spec.state == "extreme":
            # boundary closed form: the fold of an argmin/argmax-by-merge-
            # order monoid over the contiguous range [j, i] is row i (LAST)
            # or row j (FIRST)
            val = arr if spec.newest else arr[j]
            out[rk] = spec.finalize(
                {"ts": ts, "rank": idx, "pos": idx, "val": val,
                 "has": jnp.ones_like(val, bool)},
                n=nth,
            )
        elif spec.state == "tail":
            # tail closed form: the fold keeps the newest TOPN_TAIL rows,
            # i.e. rows [max(j, i - T + 1), i], gathered newest-first
            offs = jnp.arange(TOPN_TAIL, dtype=jnp.int32)[None, :]
            pos = idx[:, None] - offs
            valid = pos >= j[:, None]
            vals = arr[jnp.maximum(pos, 0)]
            out[rk] = spec.finalize({"val": vals, "valid": valid}, n=nth)
        else:
            raise ValueError(f"unhandled agg {agg}")
    return out
