"""Feature signatures & sketches — FeatInsight's high-dimensional toolkit.

Paper: "feature signatures for high-dimensional scenarios (e.g., labeling
product-item features)", "handling up to a trillion-dimensional feature
space", and "specialized ML functions, such as top-N frequency counts".

A signature maps a (possibly crossed) categorical value into a bounded
hashed id space; the trillion-dimensional cross never materializes.  For
model consumption the signature indexes a vocab-sharded embedding table via
k independent hashes combined by learned weights ("multi-hash" / hash
embeddings) — the gather is the perf-critical op implemented in
``repro.kernels.signature``.

Also here: a count-min sketch (the streaming top-N support structure) in
pure JAX, used by the fraud-detection example for global heavy hitters —
complementary to the exact per-key window TOPN_FREQ in the engine.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.hashing import mix64

__all__ = [
    "signature_ids",
    "multi_hash_ids",
    "hash_embedding_lookup_ref",
    "CountMinSketch",
    "cms_init",
    "cms_update",
    "cms_query",
]


def signature_ids(
    cols: Sequence[jnp.ndarray], bits: int = 20, salt: int = 0
) -> jnp.ndarray:
    """Fold feature columns into one signature id per row, in [0, 2**bits)."""
    acc = None
    for i, c in enumerate(cols):
        h = mix64(jnp.asarray(c), salt=salt + 0x9E37 * (i + 1), bits=32)
        acc = h if acc is None else mix64(acc * 31 + h, salt=salt, bits=32)
    assert acc is not None
    return jnp.mod(acc, 2 ** bits).astype(jnp.int32)


def multi_hash_ids(
    sig: jnp.ndarray, num_hashes: int, table_size: int
) -> jnp.ndarray:
    """k independent re-hashes of a signature into a smaller table.

    (..., ) int32 -> (..., k) int32 in [0, table_size).  Hash-embedding
    trick: the trillion-dim signature space shares a 2**m-row table through
    k probes, collision noise averaging out across probes.
    """
    hs = [
        mix64(sig, salt=0x85EB * (j + 1) + 17, bits=31) % jnp.int32(table_size)
        for j in range(num_hashes)
    ]
    return jnp.stack(hs, axis=-1).astype(jnp.int32)


def hash_embedding_lookup_ref(
    table: jnp.ndarray,      # (V, D)
    sig: jnp.ndarray,        # (...,) int32 signatures
    weights: jnp.ndarray,    # (num_hashes,) or (..., num_hashes) combine weights
    num_hashes: int = 2,
) -> jnp.ndarray:
    """Pure-jnp oracle for the signature-embedding kernel: (..., D)."""
    ids = multi_hash_ids(sig, num_hashes, table.shape[0])  # (..., k)
    vecs = table[ids]                                       # (..., k, D)
    w = jnp.broadcast_to(weights, ids.shape).astype(vecs.dtype)
    return jnp.einsum("...k,...kd->...d", w, vecs)


# ---------------------------------------------------------------------------
# Count-min sketch (streaming heavy hitters)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CountMinSketch:
    counts: jnp.ndarray  # (depth, width) f32

    def tree_flatten(self):
        return (self.counts,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def cms_init(depth: int = 4, width: int = 1024) -> CountMinSketch:
    return CountMinSketch(jnp.zeros((depth, width), jnp.float32))


def _cms_slots(items: jnp.ndarray, depth: int, width: int) -> jnp.ndarray:
    return jnp.stack(
        [
            mix64(items, salt=0x1234 + 31 * d, bits=31) % jnp.int32(width)
            for d in range(depth)
        ],
        axis=0,
    )  # (depth, N)


def cms_update(
    sk: CountMinSketch, items: jnp.ndarray, weights: jnp.ndarray | None = None
) -> CountMinSketch:
    depth, width = sk.counts.shape
    slots = _cms_slots(items, depth, width)
    w = (
        jnp.ones(items.shape, jnp.float32)
        if weights is None
        else weights.astype(jnp.float32)
    )
    rows = jnp.broadcast_to(
        jnp.arange(depth, dtype=jnp.int32)[:, None], slots.shape
    )
    counts = sk.counts.at[rows.reshape(-1), slots.reshape(-1)].add(
        jnp.broadcast_to(w, slots.shape).reshape(-1)
    )
    return CountMinSketch(counts)


def cms_query(sk: CountMinSketch, items: jnp.ndarray) -> jnp.ndarray:
    depth, width = sk.counts.shape
    slots = _cms_slots(items, depth, width)
    rows = jnp.broadcast_to(
        jnp.arange(depth, dtype=jnp.int32)[:, None], slots.shape
    )
    est = sk.counts[rows, slots]  # (depth, N)
    return est.min(axis=0)
