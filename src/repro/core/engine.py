"""Offline feature computation engine (training-set export path).

The paper: offline computation "enhances resource utilization by
parallelizing window operations on the same tables and mitigates data skew
by dynamically reassigning window data according to key columns and data
distribution".  The TPU/XLA reading of that:

* *parallelize window ops on the same table* — all features of a view are
  evaluated in ONE traced program over the sorted table; shared window
  starts / prefix sums / sparse tables are CSE'd (see
  :func:`repro.core.windows.windowed_aggregate`), and XLA fuses the
  pointwise post-expressions.
* *skew mitigation* — rows are globally (key, ts)-sorted and evaluated
  data-parallel over rows, NOT one-key-per-worker, so a hot key costs no
  more than a cold one (the windowed primitives are O(rows), independent of
  per-key cardinality).  `shard_rows` splits the sorted table across the
  data mesh axis at key boundaries for multi-host export.
* *compilation caching* — one jit-compiled executable per (view, version),
  reused across export batches.

Aggregate *semantics* are not defined here: every window aggregation is a
fold of its :mod:`repro.core.aggregates` monoid spec, evaluated by
:func:`repro.core.windows.windowed_aggregate`'s scan strategies — the same
(init, lift, combine, finalize) the online store composes at request time,
which is what makes the offline export and the serving path provably agree
(including FIRST/TOPN_FREQ over WINDOW UNION, which fold per-stream
partial states by merge order).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.expr import (
    collect_last_joins,
    collect_tables,
    collect_window_aggs,
    eval_rowlevel,
)
from repro.core.join import last_join_gather, merge_streams
from repro.core.view import FeatureView
from repro.core.windows import sort_by_key_ts, windowed_aggregate

__all__ = ["OfflineEngine"]

Tables = Dict[str, Dict[str, jnp.ndarray]]


class OfflineEngine:
    """Compiles feature views to batch executables over historical tables.

    Multi-table views compile to the same single fused jitted program:
    secondary tables are (key, ts)-sorted inside the trace, LAST JOINs
    resolve with one vectorized point-in-time binary search + gather per
    (table, join expr), and WINDOW UNION aggregations run the segmented
    window machinery over the timestamp-merged streams.
    """

    def __init__(self) -> None:
        self._cache: Dict[Tuple[str, int], jax.stages.Wrapped] = {}
        self.compile_count = 0  # observability for the deploy benchmark

    def compile(self, view: FeatureView):
        """Return the jit'd executable for a view (cached per version)."""
        key = (view.name, view.version)
        if key in self._cache:
            return self._cache[key]

        feature_names = list(view.features)
        exprs = list(view.features.values())
        waggs = collect_window_aggs(exprs)
        ljoins = collect_last_joins(exprs)
        db = view.database
        schema = view.schema
        needed = collect_tables(exprs)

        def run(
            columns: Dict[str, jnp.ndarray], secondary: Optional[Tables] = None
        ) -> Dict[str, jnp.ndarray]:
            secondary = secondary or {}
            for t in needed:
                if t not in secondary:
                    raise KeyError(
                        f"view {view.name!r} references table {t!r}; pass it "
                        "via secondary={...}"
                    )
            key_c = jnp.asarray(columns[schema.key], jnp.int32)
            ts_c = jnp.asarray(columns[schema.ts], jnp.int32)
            others = [c for c in columns if c not in (schema.key, schema.ts)]
            sorted_all = sort_by_key_ts(
                key_c, ts_c, *[jnp.asarray(columns[c]) for c in others]
            )
            skey, sts = sorted_all[0], sorted_all[1]
            perm = sorted_all[-1]
            sorted_cols = {schema.key: skey, schema.ts: sts}
            for name, arr in zip(others, sorted_all[2:-1]):
                sorted_cols[name] = arr
            n_p = skey.shape[0]

            # one (key, ts) sort per referenced secondary table, shared by
            # every join/union touching it
            sec_sorted: Dict[str, Dict[str, jnp.ndarray]] = {}
            for t in needed:
                tsch = db.table(t)
                tcols = secondary[t]
                tkey = jnp.asarray(tcols[tsch.key], jnp.int32)
                tts = jnp.asarray(tcols[tsch.ts], jnp.int32)
                tothers = [
                    c for c in tcols if c not in (tsch.key, tsch.ts)
                ]
                tsorted = sort_by_key_ts(
                    tkey, tts, *[jnp.asarray(tcols[c]) for c in tothers]
                )
                cols_t = {tsch.key: tsorted[0], tsch.ts: tsorted[1]}
                for name, arr in zip(tothers, tsorted[2:-1]):
                    cols_t[name] = arr
                sec_sorted[t] = cols_t

            pre_vals: Dict[Tuple, jnp.ndarray] = {}

            # -- LAST JOINs: point-in-time searchsorted gather --------------
            for lk, lj in ljoins.items():
                tsch = db.table(lj.table)
                cols_t = sec_sorted[lj.table]
                argv = eval_rowlevel(lj.arg, cols_t, {}).astype(jnp.float32)
                pre_vals[lk] = last_join_gather(
                    cols_t[tsch.key],
                    cols_t[tsch.ts],
                    argv,
                    jnp.asarray(sorted_cols[lj.on], jnp.int32),
                    sts,
                    default=lj.default,
                )

            # -- window aggregations, grouped by union signature ------------
            groups: Dict[Tuple[str, ...], Dict] = {}
            for wk, wa in waggs.items():
                groups.setdefault(wa.union, {})[wk] = wa

            arg_cache: Dict[Tuple, jnp.ndarray] = {}

            def primary_arg(wa) -> jnp.ndarray:
                ak = wa.arg.key
                if ak not in arg_cache:
                    arg_cache[ak] = eval_rowlevel(
                        wa.arg, sorted_cols, {}
                    ).astype(jnp.float32)
                return arg_cache[ak]

            for union, group in groups.items():
                if not union:
                    requests = {
                        wk: (wa.agg, primary_arg(wa), wa.window, wa.n)
                        for wk, wa in group.items()
                    }
                    pre_vals.update(windowed_aggregate(skey, sts, requests))
                    continue
                # WINDOW UNION: merge the union streams (secondaries first,
                # so ts-tied union rows land inside the primary row's
                # window), aggregate over the merged stream, read back at
                # primary positions.
                u_schemas = [db.table(t) for t in union]
                perm_m, key_m, ts_m, rank_m = merge_streams(
                    [sec_sorted[t][s.key] for t, s in zip(union, u_schemas)]
                    + [skey],
                    [sec_sorted[t][s.ts] for t, s in zip(union, u_schemas)]
                    + [sts],
                )
                primary_rank = len(union)
                prim_pos = jnp.nonzero(
                    rank_m == primary_rank, size=n_p
                )[0]
                requests = {}
                for wk, wa in group.items():
                    args = [
                        eval_rowlevel(wa.arg, sec_sorted[t], {}).astype(
                            jnp.float32
                        )
                        for t in union
                    ] + [primary_arg(wa)]
                    arg_m = jnp.concatenate(args)[perm_m]
                    requests[wk] = (wa.agg, arg_m, wa.window, wa.n)
                merged_vals = windowed_aggregate(key_m, ts_m, requests)
                for wk, v in merged_vals.items():
                    pre_vals[wk] = v[prim_pos]

            out = {}
            inv = jnp.zeros_like(perm).at[perm].set(
                jnp.arange(perm.shape[0], dtype=perm.dtype)
            )
            for fname in feature_names:
                v = eval_rowlevel(
                    view.features[fname], sorted_cols, pre_vals
                )
                out[fname] = v[inv]  # back to input row order
            return out

        fn = jax.jit(run)
        self._cache[key] = fn
        self.compile_count += 1
        return fn

    def compute(
        self,
        view: FeatureView,
        columns: Dict[str, jnp.ndarray],
        secondary: Optional[Tables] = None,
    ) -> Dict[str, jnp.ndarray]:
        """Offline batch feature computation (row order preserved).

        ``secondary`` maps secondary table name -> {col: (M,) array} for
        multi-table views; single-table views omit it.
        """
        return self.compile(view)(columns, secondary or {})

    def export_training_set(
        self,
        view: FeatureView,
        columns: Dict[str, jnp.ndarray],
        label: Optional[str] = None,
        path: Optional[str] = None,
        secondary: Optional[Tables] = None,
    ) -> Dict[str, np.ndarray]:
        """Paper step 3: compute features offline and export samples.

        Returns (and optionally .npz-writes) the feature matrix + label.
        """
        feats = self.compute(view, columns, secondary)
        out = {k: np.asarray(v) for k, v in feats.items()}
        if label is not None:
            out["__label__"] = np.asarray(columns[label])
        if path is not None:
            np.savez_compressed(path, **out)
        return out


def shard_rows(
    key: np.ndarray, num_shards: int
) -> np.ndarray:
    """Assign each (sorted) row to a shard, splitting at key boundaries.

    Balanced contiguous partition of the sorted row space that never splits
    a key across shards — the skew-aware reassignment the paper describes,
    with hot keys bounded by the O(rows) windowed primitives.
    """
    n = len(key)
    target = np.linspace(0, n, num_shards + 1)[1:-1].astype(np.int64)
    # move each cut forward to the next key boundary
    cuts = []
    for t in target:
        t = int(t)
        while t < n and t > 0 and key[t] == key[t - 1]:
            t += 1
        cuts.append(t)
    bounds = [0] + cuts + [n]
    shard = np.zeros(n, np.int32)
    for s in range(num_shards):
        shard[bounds[s]:bounds[s + 1]] = s
    return shard
