"""Offline feature computation engine (training-set export path).

The paper: offline computation "enhances resource utilization by
parallelizing window operations on the same tables and mitigates data skew
by dynamically reassigning window data according to key columns and data
distribution".  The TPU/XLA reading of that:

* *parallelize window ops on the same table* — all features of a view are
  evaluated in ONE traced program over the sorted table; shared window
  starts / prefix sums / sparse tables are CSE'd (see
  :func:`repro.core.windows.windowed_aggregate`), and XLA fuses the
  pointwise post-expressions.
* *skew mitigation* — rows are globally (key, ts)-sorted and evaluated
  data-parallel over rows, NOT one-key-per-worker, so a hot key costs no
  more than a cold one (the windowed primitives are O(rows), independent of
  per-key cardinality).  `shard_rows` splits the sorted table across the
  data mesh axis at key boundaries for multi-host export.
* *compilation caching* — one jit-compiled executable per (view, version),
  reused across export batches.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.expr import collect_window_aggs, eval_rowlevel
from repro.core.view import FeatureView
from repro.core.windows import sort_by_key_ts, windowed_aggregate

__all__ = ["OfflineEngine"]


class OfflineEngine:
    """Compiles feature views to batch executables over historical tables."""

    def __init__(self) -> None:
        self._cache: Dict[Tuple[str, int], jax.stages.Wrapped] = {}
        self.compile_count = 0  # observability for the deploy benchmark

    def compile(self, view: FeatureView):
        """Return the jit'd executable for a view (cached per version)."""
        key = (view.name, view.version)
        if key in self._cache:
            return self._cache[key]

        feature_names = list(view.features)
        waggs = collect_window_aggs(list(view.features.values()))
        schema = view.schema

        def run(columns: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
            key_c = jnp.asarray(columns[schema.key], jnp.int32)
            ts_c = jnp.asarray(columns[schema.ts], jnp.int32)
            others = [c for c in columns if c not in (schema.key, schema.ts)]
            sorted_all = sort_by_key_ts(
                key_c, ts_c, *[jnp.asarray(columns[c]) for c in others]
            )
            skey, sts = sorted_all[0], sorted_all[1]
            perm = sorted_all[-1]
            sorted_cols = {schema.key: skey, schema.ts: sts}
            for name, arr in zip(others, sorted_all[2:-1]):
                sorted_cols[name] = arr

            requests = {}
            arg_cache: Dict[Tuple, jnp.ndarray] = {}
            for wk, wa in waggs.items():
                ak = wa.arg.key
                if ak not in arg_cache:
                    arg_cache[ak] = eval_rowlevel(
                        wa.arg, sorted_cols, {}
                    ).astype(jnp.float32)
                requests[wk] = (wa.agg, arg_cache[ak], wa.window, wa.n)

            wagg_values = windowed_aggregate(skey, sts, requests)
            out = {}
            inv = jnp.zeros_like(perm).at[perm].set(
                jnp.arange(perm.shape[0], dtype=perm.dtype)
            )
            for fname in feature_names:
                v = eval_rowlevel(
                    view.features[fname], sorted_cols, wagg_values
                )
                out[fname] = v[inv]  # back to input row order
            return out

        fn = jax.jit(run)
        self._cache[key] = fn
        self.compile_count += 1
        return fn

    def compute(
        self, view: FeatureView, columns: Dict[str, jnp.ndarray]
    ) -> Dict[str, jnp.ndarray]:
        """Offline batch feature computation (row order preserved)."""
        return self.compile(view)(columns)

    def export_training_set(
        self,
        view: FeatureView,
        columns: Dict[str, jnp.ndarray],
        label: Optional[str] = None,
        path: Optional[str] = None,
    ) -> Dict[str, np.ndarray]:
        """Paper step 3: compute features offline and export samples.

        Returns (and optionally .npz-writes) the feature matrix + label.
        """
        feats = self.compute(view, columns)
        out = {k: np.asarray(v) for k, v in feats.items()}
        if label is not None:
            out["__label__"] = np.asarray(columns[label])
        if path is not None:
            np.savez_compressed(path, **out)
        return out


def shard_rows(
    key: np.ndarray, num_shards: int
) -> np.ndarray:
    """Assign each (sorted) row to a shard, splitting at key boundaries.

    Balanced contiguous partition of the sorted row space that never splits
    a key across shards — the skew-aware reassignment the paper describes,
    with hot keys bounded by the O(rows) windowed primitives.
    """
    n = len(key)
    target = np.linspace(0, n, num_shards + 1)[1:-1].astype(np.int64)
    # move each cut forward to the next key boundary
    cuts = []
    for t in target:
        t = int(t)
        while t < n and t > 0 and key[t] == key[t - 1]:
            t += 1
        cuts.append(t)
    bounds = [0] + cuts + [n]
    shard = np.zeros(n, np.int32)
    for s in range(num_shards):
        shard[bounds[s]:bounds[s + 1]] = s
    return shard
