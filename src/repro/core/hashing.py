"""Integer mix hashing in pure JAX (int32 lane pairs — no x64 requirement).

Both directions of the host/device mirror matter now: the sharded plane's
*ingest* routing stays host-side numpy (``mix32_np``), while the serving
*query* path routes on device (``KeyPermutation.device_call``) so a whole
request batch enters one fused program — shard id, per-shard rank, padded
grid and gather-back all computed on the mesh.  The two are bit-exact by
construction (identical constants, identical masked-shift formulation).

TPUs have no 64-bit integer lanes worth using; we emulate a splitmix-style
64-bit mixer on (hi, lo) int32 pairs so feature signatures hash identically
on CPU (tests), TPU (target), and inside Pallas kernels.  All functions are
deterministic pure functions of their inputs — a requirement for the paper's
offline↔online consistency guarantee (the same raw value must produce the
same signature in both pipelines).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["mix32", "mix64", "fold_hash", "mix32_np", "KeyPermutation"]

_M1 = jnp.int32(-2048144789)   # 0x85ebca6b
_M2 = jnp.int32(-1028477387)   # 0xc2b2ae35


def _as_i32(x: jnp.ndarray) -> jnp.ndarray:
    """Reinterpret/convert arbitrary numeric input to int32 deterministically."""
    if x.dtype == jnp.float32:
        # bitcast so 1.0 and 1 hash differently from 1.5 etc.; NaN-safe.
        return jnp.asarray(x).view(jnp.int32)
    if x.dtype in (jnp.int32, jnp.uint32):
        return x.astype(jnp.int32)
    return x.astype(jnp.int32)


def mix32(x: jnp.ndarray, salt: int = 0) -> jnp.ndarray:
    """murmur3-finalizer style avalanche mix over int32 lanes."""
    h = _as_i32(x) ^ jnp.int32(salt & 0x7FFFFFFF)
    h = h ^ (h >> 16)
    h = (h * _M1).astype(jnp.int32)
    h = h ^ ((h >> 13) & jnp.int32(0x0007FFFF))
    h = (h * _M2).astype(jnp.int32)
    h = h ^ ((h >> 16) & jnp.int32(0x0000FFFF))
    return h


def mix64(x: jnp.ndarray, salt: int = 0, bits: int = 32) -> jnp.ndarray:
    """Two-round 32-bit mix folded to ``bits`` bits, result in [0, 2**bits).

    (Named for its role — emulating a 64-bit-quality mixer with two
    dependent 32-bit rounds — not its output width.)
    """
    x = jnp.asarray(x)
    h1 = mix32(x, salt=salt)
    h2 = mix32(h1 ^ jnp.int32(0x5BD1E995), salt=salt ^ 0x27D4EB2F)
    h = h1 ^ (h2 * jnp.int32(5) + jnp.int32(0x38495AB5))
    if bits >= 31:  # int32 non-negative range is 31 usable bits
        return jnp.abs(h) & jnp.int32(0x7FFFFFFF)
    return jnp.abs(h) % jnp.int32(2 ** bits)


def fold_hash(parts, salt: int = 0, bits: int = 20) -> jnp.ndarray:
    """Order-sensitive fold of several arrays into one hashed id per row."""
    acc = None
    for i, p in enumerate(parts):
        h = mix64(jnp.asarray(p), salt=salt + 0x9E37 * (i + 1), bits=32)
        acc = h if acc is None else mix64(acc * 31 + h, salt=salt, bits=32)
    assert acc is not None
    return jnp.mod(acc, 2 ** bits).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Host-side mirrors (numpy) — the sharded plane's routing runs on the host
# straight from request columns, so it must not pay a device dispatch.
# ---------------------------------------------------------------------------


def _np_i32(v: np.ndarray) -> np.ndarray:
    """Wrap int64 intermediates to signed 32-bit (int32 overflow semantics)."""
    return ((v + 2**31) % 2**32) - 2**31


def mix32_np(x, salt: int = 0) -> np.ndarray:
    """Bit-exact numpy mirror of :func:`mix32` for int inputs.

    Computed in int64 with explicit 32-bit wrapping — numpy's int32 ops
    would warn (or differ by platform) on overflow, and jnp dispatch on the
    serving host's routing path costs more than the hash itself.
    """
    h = _np_i32(np.asarray(x, np.int64) ^ (salt & 0x7FFFFFFF))
    h = _np_i32(h ^ (h >> 16))
    h = _np_i32(h * -2048144789)            # 0x85ebca6b
    h = _np_i32(h ^ ((h >> 13) & 0x0007FFFF))
    h = _np_i32(h * -1028477387)            # 0xc2b2ae35
    h = _np_i32(h ^ ((h >> 16) & 0x0000FFFF))
    return h


class KeyPermutation:
    """Deterministic bijection on ``[0, upper)`` — Feistel rounds of the
    module's mixer, with cycle-walking down to the exact domain.

    The sharded serving plane routes ``shard = perm(key) % S`` so that
    adversarial or strided key patterns (every key ≡ 0 mod S — the classic
    failure of raw modulo routing) still spread across shards, while
    ``local = perm(key) // S`` remains dense and collision-free per shard
    *because* the map is a bijection: two keys can only share a local id if
    they land on different shards.

    Stateless and host-side (pure numpy): routing never needs a lookup
    table, so any router replica — or a recovering one — maps keys
    identically.
    """

    def __init__(self, upper: int, rounds: int = 4, salt: int = 0):
        if upper < 1:
            raise ValueError(f"permutation domain must be >= 1, got {upper}")
        self.upper = int(upper)
        bits = max(2, (self.upper - 1).bit_length())
        bits += bits & 1  # even split -> balanced Feistel halves
        self.half = bits // 2
        self.mask = (1 << self.half) - 1
        self.size = 1 << bits
        self.rounds = int(rounds)
        self.salt = int(salt)

    def _once(self, x: np.ndarray) -> np.ndarray:
        left = x >> self.half
        right = x & self.mask
        for r in range(self.rounds):
            f = mix32_np(right, salt=self.salt + 0x9E37 * (r + 1)) & self.mask
            left, right = right, left ^ f
        return (left << self.half) | right

    def _once_inv(self, x: np.ndarray) -> np.ndarray:
        """Inverse of one Feistel pass: run the rounds backwards.

        Forward round r maps (L, R) -> (R, L ^ F_r(R)), so its inverse is
        (L', R') -> (R' ^ F_r(L'), L') with the same round function —
        Feistel networks invert without inverting F.
        """
        left = x >> self.half
        right = x & self.mask
        for r in reversed(range(self.rounds)):
            f = mix32_np(left, salt=self.salt + 0x9E37 * (r + 1)) & self.mask
            left, right = right ^ f, left
        return (left << self.half) | right

    def __call__(self, key) -> np.ndarray:
        """Vectorized permuted ids; walks cycles until back in [0, upper)."""
        x = np.atleast_1d(np.asarray(key)).astype(np.int64)
        out = self._once(x)
        bad = out >= self.upper
        while bad.any():
            out[bad] = self._once(out[bad])
            bad = out >= self.upper
        return out.reshape(np.shape(key))

    def inverse(self, key) -> np.ndarray:
        """Exact inverse of :meth:`__call__` on [0, upper):
        ``inverse(perm(k)) == k`` for every k in the domain.

        Cycle-walking inverts by walking the same cycle backwards: every
        intermediate value of the forward walk lies outside [0, upper), so
        applying the inverse pass until the value re-enters the domain
        retraces the forward walk exactly.  Vectorized host-side numpy,
        like the forward map — migrations use it to decode routed ring
        coordinates back to global keys without materializing a
        full-domain lookup table.
        """
        x = np.atleast_1d(np.asarray(key)).astype(np.int64)
        if x.size and (x.min() < 0 or x.max() >= self.upper):
            raise ValueError(
                f"inverse domain is [0, {self.upper}): "
                f"got [{x.min()}, {x.max()}]"
            )
        out = self._once_inv(x)
        bad = out >= self.upper
        while bad.any():
            out[bad] = self._once_inv(out[bad])
            bad = out >= self.upper
        return out.reshape(np.shape(key))

    # -- device mirror (the fused on-mesh request path) ---------------------

    def _once_device(self, x: jnp.ndarray) -> jnp.ndarray:
        """jnp mirror of :meth:`_once` — bit-exact because every Feistel
        half stays below ``2**half`` and mix32 / mix32_np agree on the low
        ``half`` bits (two's-complement masking is width-independent)."""
        left = x >> self.half
        right = x & self.mask
        for r in range(self.rounds):
            f = mix32(right, salt=self.salt + 0x9E37 * (r + 1)) & jnp.int32(
                self.mask
            )
            left, right = right, left ^ f
        return (left << self.half) | right

    def device_call(self, key: jnp.ndarray) -> jnp.ndarray:
        """Permuted ids computed on device, jit/vmap-safe; identical values
        to :meth:`__call__` for every key in [0, upper).

        Cycle-walking becomes a ``lax.while_loop`` re-permuting only the
        out-of-domain lanes — the loop is data-dependent but terminates in
        a handful of rounds (the walk expects ``size/upper`` < 4 steps).
        """
        import jax

        if self.size > 0x7FFFFFFF:  # pragma: no cover - >2^31 key domains
            raise ValueError(
                f"device permutation needs an int32 domain; size "
                f"{self.size} overflows (route on host instead)"
            )
        x = jnp.asarray(key, jnp.int32)
        out = self._once_device(x)

        def cond(o):
            return jnp.any(o >= self.upper)

        def body(o):
            return jnp.where(o >= self.upper, self._once_device(o), o)

        return jax.lax.while_loop(cond, body, out)
