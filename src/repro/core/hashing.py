"""Integer mix hashing in pure JAX (int32 lane pairs — no x64 requirement).

TPUs have no 64-bit integer lanes worth using; we emulate a splitmix-style
64-bit mixer on (hi, lo) int32 pairs so feature signatures hash identically
on CPU (tests), TPU (target), and inside Pallas kernels.  All functions are
deterministic pure functions of their inputs — a requirement for the paper's
offline↔online consistency guarantee (the same raw value must produce the
same signature in both pipelines).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["mix32", "mix64", "fold_hash"]

_M1 = jnp.int32(-2048144789)   # 0x85ebca6b
_M2 = jnp.int32(-1028477387)   # 0xc2b2ae35


def _as_i32(x: jnp.ndarray) -> jnp.ndarray:
    """Reinterpret/convert arbitrary numeric input to int32 deterministically."""
    if x.dtype == jnp.float32:
        # bitcast so 1.0 and 1 hash differently from 1.5 etc.; NaN-safe.
        return jnp.asarray(x).view(jnp.int32)
    if x.dtype in (jnp.int32, jnp.uint32):
        return x.astype(jnp.int32)
    return x.astype(jnp.int32)


def mix32(x: jnp.ndarray, salt: int = 0) -> jnp.ndarray:
    """murmur3-finalizer style avalanche mix over int32 lanes."""
    h = _as_i32(x) ^ jnp.int32(salt & 0x7FFFFFFF)
    h = h ^ (h >> 16)
    h = (h * _M1).astype(jnp.int32)
    h = h ^ ((h >> 13) & jnp.int32(0x0007FFFF))
    h = (h * _M2).astype(jnp.int32)
    h = h ^ ((h >> 16) & jnp.int32(0x0000FFFF))
    return h


def mix64(x: jnp.ndarray, salt: int = 0, bits: int = 32) -> jnp.ndarray:
    """Two-round 32-bit mix folded to ``bits`` bits, result in [0, 2**bits).

    (Named for its role — emulating a 64-bit-quality mixer with two
    dependent 32-bit rounds — not its output width.)
    """
    x = jnp.asarray(x)
    h1 = mix32(x, salt=salt)
    h2 = mix32(h1 ^ jnp.int32(0x5BD1E995), salt=salt ^ 0x27D4EB2F)
    h = h1 ^ (h2 * jnp.int32(5) + jnp.int32(0x38495AB5))
    if bits >= 31:  # int32 non-negative range is 31 usable bits
        return jnp.abs(h) & jnp.int32(0x7FFFFFFF)
    return jnp.abs(h) % jnp.int32(2 ** bits)


def fold_hash(parts, salt: int = 0, bits: int = 20) -> jnp.ndarray:
    """Order-sensitive fold of several arrays into one hashed id per row."""
    acc = None
    for i, p in enumerate(parts):
        h = mix64(jnp.asarray(p), salt=salt + 0x9E37 * (i + 1), bits=32)
        acc = h if acc is None else mix64(acc * 31 + h, salt=salt, bits=32)
    assert acc is not None
    return jnp.mod(acc, 2 ** bits).astype(jnp.int32)
