"""Multi-scenario serving plane — N feature views, one store, one mesh.

FeatInsight's headline claim is breadth: 100+ real-world scenarios served
from one platform, each with its own feature views but sharing storage and
compute.  Before this module, every scenario paid for its own
:class:`~repro.core.online.OnlineFeatureStore` (or sharded store + mesh):
its own copy of shared tables, its own ingest stream, its own device
memory.  :class:`ScenarioPlane` is the consolidation layer:

* **One plan.**  The plane asks the layout planner
  (:func:`~repro.core.layout.plan_layout`) for a single *evolvable*
  :class:`~repro.core.layout.StoreLayout` over all its views
  (``raw_lanes=True``: every raw column is a lane from day one, so future
  views hot-deploy with complete history).  The plan decides lane slots,
  per-(table, shard) ring identities, and placement (partitioned vs
  replicated vs split dual-use tables); the store merely consumes it.
* **One state.**  The plane merges the registered views into a single
  internal view whose lane plan is the *union* of every view's window
  arguments and whose secondary tables are the union of every view's
  LAST JOIN / WINDOW UNION references (CSE'd by structural key, so two
  scenarios asking for ``w_sum(amount, 1h)`` share one lane).  The merged
  view backs one :class:`OnlineFeatureStore` — or one
  :class:`~repro.core.shard.ShardedOnlineStore` on a single ``('shard',)``
  mesh when ``num_shards`` is given.  A table referenced by many views
  has one ring store per (table, shard), not per view.
* **One ingest.**  Primary rows and secondary-table rows are ingested
  once and serve every scenario; adding scenario #2..#N costs nothing at
  ingest time.  :meth:`ingest_row_counts` exposes the accounting (and the
  shared-ingest test asserts it).
* **Per-scenario programs.**  Each view gets a
  :class:`~repro.core.online.QueryProgram`: its window aggregations and
  joins as trace-time subsets of the shared plan, compiled into an
  executable that gathers and folds only what that view needs.  Queries
  stay **bit-identical** to a dedicated single-view store fed the same
  stream — per-key state depends only on the key's rows and their order,
  and sharing lanes changes neither.
* **Live evolution.**  :meth:`evolve` re-plans the layout for a new view
  list and migrates the running store's state to it
  (:meth:`~repro.core.online.OnlineFeatureStore.adopt_layout`): unchanged
  rings carry over verbatim, new lanes are synthesized from history, and
  only the *new* views' query programs are compiled.  Adding scenario
  #N+1 no longer rebuilds the plane or re-ingests shared tables — the
  paper's "rapid updates and deployments" story
  (``MultiScenarioService.hot_deploy`` is the serving-layer entry).

The serving front-end (scenario-tagged routing, per-scenario stats) lives
in :mod:`repro.serve` — see ``FeatureService.build_multi`` and the
scenario-aware ``ShardRouter``.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional

from repro.core.expr import Expr, collect_tables
from repro.core.layout import StoreLayout, plan_layout
from repro.core.online import OnlineFeatureStore, QueryProgram
from repro.core.storage import Database, TableSchema
from repro.core.view import FeatureView

__all__ = ["merge_views", "ScenarioPlane"]


def merge_views(
    views: List[FeatureView], name: str = "scenario_plane"
) -> FeatureView:
    """Fuse N scenario views into the plane's one internal view.

    Features are namespaced ``"<view>/<feature>"`` (view names must be
    distinct); the merged database is the primary table plus the union of
    all referenced secondary tables.  Every view must share the primary
    schema, and two views referencing the same secondary table name must
    agree on its schema — the plane stores that table once, so a schema
    conflict would silently corrupt one of them.
    """
    if not views:
        raise ValueError("merge_views needs at least one view")
    names = [v.name for v in views]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate scenario view names: {sorted(names)}")
    primary = views[0].schema
    secondaries: Dict[str, TableSchema] = {}
    features: Dict[str, Expr] = {}
    for v in views:
        if v.schema != primary:
            raise ValueError(
                f"scenario {v.name!r} has primary table {v.schema.name!r} "
                f"({v.schema}), the plane's is {primary.name!r} ({primary}): "
                "all scenarios of one plane share one primary stream"
            )
        for t in collect_tables(list(v.features.values())):
            sch = v.database.table(t)
            prev = secondaries.setdefault(t, sch)
            if prev != sch:
                raise ValueError(
                    f"secondary table {t!r} has conflicting schemas across "
                    f"scenarios ({prev} vs {sch}); shared tables are stored "
                    "once, so schemas must agree"
                )
        for fname, expr in v.features.items():
            features[f"{v.name}/{fname}"] = expr
    db = Database(
        name=name, primary=primary, secondary=tuple(secondaries.values())
    )
    return FeatureView(
        name=name,
        features=features,
        database=db,
        description=f"merged plane over scenarios: {', '.join(names)}",
    )


class ScenarioPlane:
    """N deployed scenarios sharing one (optionally sharded) online store.

    ``num_shards=None`` deploys on a single-device store; an integer
    deploys on a :class:`~repro.core.shard.ShardedOnlineStore` over one
    ``('shard',)`` mesh.  ``store_kwargs`` (capacity, num_buckets,
    bucket_size, secondary_num_keys, ...) are planner knobs shared by
    every scenario — they size the one state all scenarios live in, and
    are remembered so :meth:`evolve` re-plans with the same policy.
    """

    def __init__(
        self,
        views: Iterable[FeatureView],
        *,
        num_keys: int,
        num_shards: Optional[int] = None,
        name: str = "scenario_plane",
        mesh=None,
        device_routing: bool = True,
        **store_kwargs,
    ):
        views = list(views)
        self.views: Dict[str, FeatureView] = {v.name: v for v in views}
        self._plan_kwargs = dict(
            num_keys=num_keys, num_shards=num_shards, **store_kwargs
        )
        self.layout: StoreLayout = plan_layout(
            views, raw_lanes=True, **self._plan_kwargs
        )
        self.merged = merge_views(views, name=name)
        if num_shards is not None:
            from repro.core.shard import ShardedOnlineStore

            self.store = ShardedOnlineStore(
                self.merged, layout=self.layout, mesh=mesh,
                device_routing=device_routing,
            )
        else:
            self.store = OnlineFeatureStore(self.merged, layout=self.layout)
        self.programs: Dict[str, QueryProgram] = {
            v.name: self.store.compile_program(v) for v in views
        }

    # -- live evolution ----------------------------------------------------------

    def evolve(
        self,
        new_views: Iterable[FeatureView],
        backfill=None,
        **plan_overrides,
    ):
        """Hot-swap the plane to serve ``new_views`` — a state migration,
        not a rebuild.

        Re-plans the :class:`~repro.core.layout.StoreLayout` for the new
        view list (same planner policy; ``plan_overrides`` may adjust
        knobs like ``capacity``), diffs it against the running plan, and
        migrates the live store in place: unchanged rings carry over
        verbatim (no shared table is re-ingested —
        :meth:`ingest_row_counts` is unchanged for carried tables), new
        lanes are synthesized from the raw-column history, split/added
        rings are rebuilt from per-key row streams.  Only views *not
        already deployed* get a new compiled
        :class:`~repro.core.online.QueryProgram`; existing programs keep
        serving (their trace-time subsets are structural, so they re-trace
        correctly against the evolved layout).

        Returns the :class:`~repro.core.migrate.MigrationReport`; within
        the retention horizon the migrated plane is bit-identical to a
        cold rebuild + full replay (``report.exact``), which the
        hot-deploy gate asserts.  ``backfill`` (a
        :class:`repro.offline.backfill.BackfillSource`) extends that
        bit-exactness *beyond* the horizon: aged-out ring rows and
        bucket states are re-derived from offline history and spliced
        into the migrating state before the new layout goes live.
        """
        from repro.obs import get_telemetry

        tracer = get_telemetry().tracer
        new_views = list(new_views)
        kwargs = dict(self._plan_kwargs)
        kwargs.update(plan_overrides)
        with tracer.span("hot_deploy.plan", views=len(new_views)):
            new_layout = plan_layout(new_views, raw_lanes=True, **kwargs)
            new_merged = merge_views(new_views, name=self.merged.name)
        report = self.store.adopt_layout(
            new_merged, new_layout, backfill=backfill
        )
        old_views = self.views
        self._plan_kwargs = kwargs
        self.layout = new_layout
        self.views = {v.name: v for v in new_views}
        self.merged = new_merged
        # compile only the NEW views' programs; identical already-deployed
        # views keep their compiled programs
        kept = {
            n: p
            for n, p in self.programs.items()
            if self.views.get(n) is old_views.get(n)
        }
        self.programs = kept
        with tracer.span("hot_deploy.compile"):
            for v in new_views:
                if v.name not in self.programs:
                    self.programs[v.name] = self.store.compile_program(v)
                    report.new_programs.append(v.name)
        return report

    # -- introspection ---------------------------------------------------------

    @property
    def scenarios(self) -> List[str]:
        return list(self.views)

    @property
    def num_shards(self) -> int:
        return int(getattr(self.store, "num_shards", 1))

    @property
    def tables(self) -> List[str]:
        """All source tables of the plane (primary first, each once)."""
        return self.merged.tables

    def program(self, scenario: str) -> QueryProgram:
        try:
            return self.programs[scenario]
        except KeyError:
            raise KeyError(
                f"unknown scenario {scenario!r}; plane serves "
                f"{self.scenarios}"
            ) from None

    def ingest_row_counts(self) -> Dict[str, int]:
        """Per-table stored row totals — each shared table counted once
        (× replication on a sharded store), never once per scenario."""
        return self.store.ingest_row_counts()

    # -- data plane ------------------------------------------------------------

    def ingest(self, columns) -> None:
        """Ingest primary rows once, for every scenario."""
        self.store.ingest(columns)

    def ingest_table(self, table: str, columns) -> None:
        """Ingest secondary-table rows once; every scenario referencing
        ``table`` (via LAST JOIN or WINDOW UNION) sees them."""
        self.store.ingest_table(table, columns)

    def query(
        self, scenario: str, columns, mode: str = "preagg",
        valid=None, route_info=None,
    ) -> Dict:
        """Answer one scenario's feature vector for a request batch —
        routed/compiled through that scenario's program against the shared
        state.  Returns {feature_name: (Q,) f32} in that view's naming
        (no plane prefix)."""
        return self.store.query(
            columns, mode=mode, program=self.program(scenario),
            valid=valid, route_info=route_info,
        )

    def query_mixed(
        self, columns, tags, mode: str = "preagg",
        valid=None, route_info=None,
    ) -> Dict[str, Dict]:
        """Answer a MIXED batch — rows tagged per-row with their scenario
        — in ONE fused device dispatch (the device-resident request path;
        needs a sharded store with ``device_routing=True``).

        ``tags`` is a (Q,) array of scenario names; ``valid`` masks
        scheduler padding.  The fused program computes the merged store's
        full aggregation set for every row (bit-identical per answer to
        each scenario's own program — all scenarios share the primary
        schema, so a mixed batch carries every needed column); each
        scenario's features are then finished from that superset, valid
        rows only, in submission order within the scenario.  Returns
        ``{scenario: {feature: rows}}`` like the per-group path.
        """
        import numpy as np

        from repro.core.expr import eval_rowlevel
        from repro.obs import get_telemetry

        names = self.scenarios
        index = {s: i for i, s in enumerate(names)}
        tags = np.asarray(tags)
        try:
            scen = np.asarray([index[t] for t in tags], np.int32)
        except KeyError as e:
            raise KeyError(
                f"unknown scenario {e.args[0]!r}; plane serves {names}"
            ) from None
        vals, q = self.store.route_and_query(
            columns, scen, len(names), mode=mode, valid=valid,
            route_info=route_info,
        )
        if route_info is not None:
            route_info["scenario_names"] = list(names)
        vmask = (
            np.ones(q, bool) if valid is None else np.asarray(valid, bool)[:q]
        )
        keys = list(self.store._wagg_order) + list(self.store._ljoin_order)
        out: Dict[str, Dict] = {}
        with get_telemetry().tracer.span("query.scatter", rows=q):
            pre_values = dict(
                zip(keys, (np.asarray(v)[:q] for v in vals))
            )
            for s in names:
                msk = vmask & (scen == index[s])
                if not msk.any():
                    continue
                cols_s = {
                    c: np.asarray(v)[:q][msk] for c, v in columns.items()
                }
                pv_s = {k: v[msk] for k, v in pre_values.items()}
                out[s] = {
                    fname: np.asarray(eval_rowlevel(fexpr, cols_s, pv_s))
                    for fname, fexpr in self.views[s].features.items()
                }
        return out
