"""Multi-scenario serving plane — N feature views, one store, one mesh.

FeatInsight's headline claim is breadth: 100+ real-world scenarios served
from one platform, each with its own feature views but sharing storage and
compute.  Before this module, every scenario paid for its own
:class:`~repro.core.online.OnlineFeatureStore` (or sharded store + mesh):
its own copy of shared tables, its own ingest stream, its own device
memory.  :class:`ScenarioPlane` is the consolidation layer:

* **One state.**  The plane merges the registered views into a single
  internal view whose lane plan is the *union* of every view's window
  arguments and whose secondary tables are the union of every view's
  LAST JOIN / WINDOW UNION references (CSE'd by structural key, so two
  scenarios asking for ``w_sum(amount, 1h)`` share one lane).  The merged
  view backs one :class:`OnlineFeatureStore` — or one
  :class:`~repro.core.shard.ShardedOnlineStore` on a single ``('shard',)``
  mesh when ``num_shards`` is given.  A table referenced by many views
  has one ring store per (table, shard), not per view.
* **One ingest.**  Primary rows and secondary-table rows are ingested
  once and serve every scenario; adding scenario #2..#N costs nothing at
  ingest time.  :meth:`ingest_row_counts` exposes the accounting (and the
  shared-ingest test asserts it).
* **Per-scenario programs.**  Each view gets a
  :class:`~repro.core.online.QueryProgram`: its window aggregations and
  joins as trace-time subsets of the shared plan, compiled into an
  executable that gathers and folds only what that view needs.  Queries
  stay **bit-identical** to a dedicated single-view store fed the same
  stream — per-key state depends only on the key's rows and their order,
  and sharing lanes changes neither.

The serving front-end (scenario-tagged routing, per-scenario stats) lives
in :mod:`repro.serve` — see ``FeatureService.build_multi`` and the
scenario-aware ``ShardRouter``.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional

from repro.core.expr import Expr, collect_tables
from repro.core.online import OnlineFeatureStore, QueryProgram
from repro.core.storage import Database, TableSchema
from repro.core.view import FeatureView

__all__ = ["merge_views", "ScenarioPlane"]


def merge_views(
    views: List[FeatureView], name: str = "scenario_plane"
) -> FeatureView:
    """Fuse N scenario views into the plane's one internal view.

    Features are namespaced ``"<view>/<feature>"`` (view names must be
    distinct); the merged database is the primary table plus the union of
    all referenced secondary tables.  Every view must share the primary
    schema, and two views referencing the same secondary table name must
    agree on its schema — the plane stores that table once, so a schema
    conflict would silently corrupt one of them.
    """
    if not views:
        raise ValueError("merge_views needs at least one view")
    names = [v.name for v in views]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate scenario view names: {sorted(names)}")
    primary = views[0].schema
    secondaries: Dict[str, TableSchema] = {}
    features: Dict[str, Expr] = {}
    for v in views:
        if v.schema != primary:
            raise ValueError(
                f"scenario {v.name!r} has primary table {v.schema.name!r} "
                f"({v.schema}), the plane's is {primary.name!r} ({primary}): "
                "all scenarios of one plane share one primary stream"
            )
        for t in collect_tables(list(v.features.values())):
            sch = v.database.table(t)
            prev = secondaries.setdefault(t, sch)
            if prev != sch:
                raise ValueError(
                    f"secondary table {t!r} has conflicting schemas across "
                    f"scenarios ({prev} vs {sch}); shared tables are stored "
                    "once, so schemas must agree"
                )
        for fname, expr in v.features.items():
            features[f"{v.name}/{fname}"] = expr
    db = Database(
        name=name, primary=primary, secondary=tuple(secondaries.values())
    )
    return FeatureView(
        name=name,
        features=features,
        database=db,
        description=f"merged plane over scenarios: {', '.join(names)}",
    )


class ScenarioPlane:
    """N deployed scenarios sharing one (optionally sharded) online store.

    ``num_shards=None`` deploys on a single-device store; an integer
    deploys on a :class:`~repro.core.shard.ShardedOnlineStore` over one
    ``('shard',)`` mesh.  ``store_kwargs`` (capacity, num_buckets,
    bucket_size, secondary_num_keys, ...) are shared by every scenario —
    they size the one state all scenarios live in.
    """

    def __init__(
        self,
        views: Iterable[FeatureView],
        *,
        num_keys: int,
        num_shards: Optional[int] = None,
        name: str = "scenario_plane",
        **store_kwargs,
    ):
        views = list(views)
        self.views: Dict[str, FeatureView] = {v.name: v for v in views}
        self.merged = merge_views(views, name=name)
        self.store = OnlineFeatureStore.create(
            self.merged,
            num_keys=num_keys,
            num_shards=num_shards,
            **store_kwargs,
        )
        self.programs: Dict[str, QueryProgram] = {
            v.name: self.store.compile_program(v) for v in views
        }

    # -- introspection ---------------------------------------------------------

    @property
    def scenarios(self) -> List[str]:
        return list(self.views)

    @property
    def num_shards(self) -> int:
        return int(getattr(self.store, "num_shards", 1))

    @property
    def tables(self) -> List[str]:
        """All source tables of the plane (primary first, each once)."""
        return self.merged.tables

    def program(self, scenario: str) -> QueryProgram:
        try:
            return self.programs[scenario]
        except KeyError:
            raise KeyError(
                f"unknown scenario {scenario!r}; plane serves "
                f"{self.scenarios}"
            ) from None

    def ingest_row_counts(self) -> Dict[str, int]:
        """Per-table stored row totals — each shared table counted once
        (× replication on a sharded store), never once per scenario."""
        return self.store.ingest_row_counts()

    # -- data plane ------------------------------------------------------------

    def ingest(self, columns) -> None:
        """Ingest primary rows once, for every scenario."""
        self.store.ingest(columns)

    def ingest_table(self, table: str, columns) -> None:
        """Ingest secondary-table rows once; every scenario referencing
        ``table`` (via LAST JOIN or WINDOW UNION) sees them."""
        self.store.ingest_table(table, columns)

    def query(
        self, scenario: str, columns, mode: str = "preagg"
    ) -> Dict:
        """Answer one scenario's feature vector for a request batch —
        routed/compiled through that scenario's program against the shared
        state.  Returns {feature_name: (Q,) f32} in that view's naming
        (no plane prefix)."""
        return self.store.query(columns, mode=mode, program=self.program(scenario))
