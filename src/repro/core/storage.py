"""Compact time-series storage — the TPU adaptation of FeatInsight's store.

The paper keeps rows in a skiplist sorted by (key, timestamp) with a compact
row encoding (fixed-width fields inline, variable-width out-of-line) and
lock-free CAS updates.  None of that ports to a TPU; what *does* port is the
invariant the skiplist buys: **per-key, timestamp-ordered, O(1)-appendable
recent history**.  We realize it as a structure-of-arrays ring buffer:

  ts    : (K, C)     int32   per-key ring of row timestamps
  vals  : (K, C, F)  float32 per-key ring of encoded row payloads
  cursor: (K,)       int32   next write slot (monotone; slot = cursor % C)

* "Compact row encoding"  -> the codec below: fixed-width numeric fields are
  stored as f32 lanes; variable-width/categorical fields are hashed to
  signatures *at ingest* (64-bit mix folded to `bits`), so every row is a
  fixed-width vector.  This is the paper's own signature trick promoted into
  the storage codec.
* "Lock-free CAS updates" -> pure functional batched scatter with buffer
  donation: one fused XLA scatter applies a whole ingest batch in-place
  (donated), giving contention-free semantics by construction.
* "TTL / batch deletion"  -> rows age out by ring overwrite; reads mask by
  (ts > now - ttl), so expiry is O(0) — the paper's "timestamp ordering and
  batch deletion" with the deletion cost removed entirely.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import fold_hash

__all__ = [
    "TableSchema", "Database", "RowCodec", "RingStore",
    "ring_init", "ring_ingest",
]


@dataclasses.dataclass(frozen=True)
class TableSchema:
    """Schema of a raw source table.

    numeric: fixed-width f32 fields stored verbatim.
    categorical: variable-width fields, hashed to `cat_bits`-bit signatures
    at ingest (they arrive as arbitrary int ids; strings are pre-tokenized
    at the import boundary — TPU tensors cannot hold strings).
    """

    name: str
    key: str
    ts: str
    numeric: Tuple[str, ...] = ()
    categorical: Tuple[str, ...] = ()
    cat_bits: int = 20

    @property
    def columns(self) -> Tuple[str, ...]:
        return self.numeric + self.categorical

    @property
    def width(self) -> int:
        return len(self.numeric) + len(self.categorical)


@dataclasses.dataclass(frozen=True)
class Database:
    """A primary table plus named secondary tables — the multi-table plane.

    Mirrors FeatInsight's database grouping (the 2018 PHM dataset's 17
    tables live in one database): the *primary* table drives feature
    computation row-by-row; *secondary* tables feed point-in-time LAST
    JOINs (their ``key`` column is matched against a primary join column)
    and WINDOW UNION streams (their ``key`` column shares the primary
    key's id space).
    """

    name: str
    primary: TableSchema
    secondary: Tuple[TableSchema, ...] = ()

    def __post_init__(self) -> None:
        names = [self.primary.name] + [t.name for t in self.secondary]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate table names in database: {names}")

    @property
    def tables(self) -> Tuple[TableSchema, ...]:
        return (self.primary,) + self.secondary

    def table(self, name: str) -> TableSchema:
        for t in self.tables:
            if t.name == name:
                return t
        raise KeyError(
            f"table {name!r} not in database {self.name!r} "
            f"(has {[t.name for t in self.tables]})"
        )

    def is_secondary(self, name: str) -> bool:
        return any(t.name == name for t in self.secondary)


class RowCodec:
    """Encode heterogeneous rows into fixed-width f32 vectors (and back)."""

    def __init__(self, schema: TableSchema):
        self.schema = schema
        self._col_index = {c: i for i, c in enumerate(schema.columns)}

    def encode(self, columns: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """dict of (N,) columns -> (N, F) f32 payload."""
        lanes: List[jnp.ndarray] = []
        for c in self.schema.numeric:
            lanes.append(jnp.asarray(columns[c], jnp.float32))
        for c in self.schema.categorical:
            # zlib.crc32, not hash(): Python string hashing is randomized
            # per-process and would break cross-run determinism.
            salt = zlib.crc32(c.encode()) & 0x7FFF
            sig = fold_hash(
                [jnp.asarray(columns[c])], salt=salt,
                bits=self.schema.cat_bits,
            )
            lanes.append(sig.astype(jnp.float32))
        return jnp.stack(lanes, axis=-1)

    def column(self, payload: jnp.ndarray, name: str) -> jnp.ndarray:
        return payload[..., self._col_index[name]]

    def col_id(self, name: str) -> int:
        return self._col_index[name]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RingStore:
    """Per-key timestamp-ordered ring buffers (functional)."""

    ts: jnp.ndarray       # (K, C) int32
    vals: jnp.ndarray     # (K, C, F) f32
    cursor: jnp.ndarray   # (K,) int32, monotone row count per key

    def tree_flatten(self):
        return (self.ts, self.vals, self.cursor), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_keys(self) -> int:
        return self.ts.shape[0]

    @property
    def capacity(self) -> int:
        return self.ts.shape[1]

    @property
    def width(self) -> int:
        return self.vals.shape[2]


def ring_init(num_keys: int, capacity: int, width: int) -> RingStore:
    return RingStore(
        ts=jnp.full((num_keys, capacity), jnp.int32(-2147483648)),
        vals=jnp.zeros((num_keys, capacity, width), jnp.float32),
        cursor=jnp.zeros((num_keys,), jnp.int32),
    )


def ring_ingest(
    store: RingStore,
    key: jnp.ndarray,   # (N,) int32 in [0, K)
    ts: jnp.ndarray,    # (N,) int32, batch sorted by (key, ts)
    vals: jnp.ndarray,  # (N, F) f32 payloads
) -> RingStore:
    """Apply a whole ingest batch as one fused scatter (donated in callers).

    Rows must be pre-sorted by (key, ts) — the import pipeline guarantees it
    (mirroring the paper: data is pre-sorted by key and timestamp).  Multiple
    rows per key per batch are supported: each row's slot is
    cursor[key] + (its rank within its key segment in this batch).
    """
    n = key.shape[0]
    cap = store.capacity
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.array([True]), key[1:] != key[:-1]])
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, 0)
    )
    rank = idx - seg_start  # position of each row within its key's batch rows

    slot = (store.cursor[key] + rank) % cap
    ts_new = store.ts.at[key, slot].set(ts, mode="drop")
    vals_new = store.vals.at[key, slot].set(vals, mode="drop")
    # per-key appended count = segment length; scatter-add ones
    cursor_new = store.cursor.at[key].add(jnp.ones((n,), jnp.int32))
    return RingStore(ts=ts_new, vals=vals_new, cursor=cursor_new)


def ring_gather(
    store: RingStore, keys: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Gather each queried key's ring unrolled oldest->newest.

    Returns (ts (Q, C), vals (Q, C, F), valid (Q, C)).
    """
    cap = store.capacity
    cur = store.cursor[keys]  # (Q,)
    # slot order oldest..newest: cursor - C .. cursor - 1  (mod C)
    offs = jnp.arange(cap, dtype=jnp.int32)[None, :]
    slots = (cur[:, None] - cap + offs) % cap
    age_rank = cur[:, None] - cap + offs  # absolute row index; <0 => never written
    valid = age_rank >= 0
    ts = jnp.take_along_axis(store.ts[keys], slots, axis=1)
    vals = jnp.take_along_axis(
        store.vals[keys], slots[..., None], axis=1
    )
    return ts, vals, valid
