"""Declarative store-layout planning — the serving plane's physical IR.

FeatInsight's deployment story ("rapid updates and deployments to
accommodate real-time data changes", §1) requires the online store's
*physical layout* to be an explicit, diffable object: which ring buffers
exist, how large they are, which value lanes each materializes, and how
each is placed across shards.  Before this module those decisions were
implicit in ``OnlineFeatureStore`` / ``ShardedOnlineStore`` /
``ScenarioPlane`` construction — adding scenario #N+1 rebuilt the merged
store and discarded all ingested state.

:func:`plan_layout` is the one planner: it maps a list of
:class:`~repro.core.view.FeatureView` s (plus sizing knobs) to a
:class:`StoreLayout` — a pure-data plan every storage layer consumes
instead of re-deriving layout ad hoc:

* ``primary``  — the primary table's :class:`RingPlan` (per-shard ring
  keys, capacity, TTL, lane slots);
* ``bucket``   — the :class:`BucketPlan` sizing the pre-aggregate store
  (:mod:`repro.core.preagg` initializes straight from it);
* ``tables``   — one :class:`RingPlan` per secondary *ring* (not per
  table: a dual-use table — WINDOW UNION stream *and* LAST JOIN target —
  is **split** on a sharded plane into a key-partitioned union ring plus
  a replicated join slice holding only the join-argument lanes, instead
  of replicating every row S×).

Because the plan is explicit, deployment becomes *state migration*:
:func:`diff_layouts` matches old and new ring plans by
:meth:`RingPlan.identity`, and :mod:`repro.core.migrate` carries every
unchanged buffer over verbatim, re-lays rings whose capacity or placement
policy changed, and synthesizes newly required lanes from the raw-column
lanes an *evolvable* layout (``raw_lanes=True``) materializes from day
one.  ``ScenarioPlane.evolve`` / ``MultiScenarioService.hot_deploy``
drive that path — the live-plane deployment the paper describes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.aggregates import agg_spec
from repro.core.expr import (
    BinOp,
    Col,
    Expr,
    Lit,
    UnOp,
    collect_last_joins,
    collect_tables,
    collect_window_aggs,
)

__all__ = [
    "LaneSlot",
    "RingPlan",
    "BucketPlan",
    "StoreLayout",
    "LayoutDiff",
    "plan_layout",
    "diff_layouts",
    "synthesizable",
]


def synthesizable(e: Expr) -> bool:
    """True if a lane can be *re-materialized* from stored raw-column
    lanes, bit-exactly: the expr tree is pure f32 row math (``Col`` /
    ``Lit`` / arithmetic / comparisons).  ``Hash`` / ``Signature`` nodes
    are excluded — their mixing is dtype-sensitive (ints convert, floats
    bitcast), so re-evaluating them over f32-stored columns would not
    reproduce the ingest-time value.
    """
    if isinstance(e, (Col, Lit)):
        return True
    if isinstance(e, (BinOp, UnOp)):
        return all(synthesizable(c) for c in e.children())
    return False


@dataclasses.dataclass(frozen=True)
class LaneSlot:
    """One materialized value lane of a ring (identity = the expr key)."""

    key: Tuple
    expr: Expr = dataclasses.field(compare=False, hash=False)
    source: str = "derived"  # 'raw' (a schema column) | 'derived'

    @property
    def synthesizable(self) -> bool:
        return synthesizable(self.expr)


@dataclasses.dataclass(frozen=True)
class RingPlan:
    """Physical plan of one per-key ring buffer.

    ``num_keys`` is the *global* key-domain size; ``ring_keys`` the
    per-shard ring row count (== ``num_keys`` unless the ring is
    key-partitioned on a sharded plane).  ``serves`` records which query
    constructs read this ring (``'union'`` / ``'join'``; the primary ring
    serves ``'window'``).  ``partitioned`` is the placement policy: rows
    routed to one owning shard (vs replicated on every shard).
    """

    table: str
    partitioned: bool
    serves: Tuple[str, ...]
    num_keys: int
    ring_keys: int
    capacity: int
    lanes: Tuple[LaneSlot, ...]
    ttl: Optional[int] = None

    @property
    def lane_keys(self) -> Tuple[Tuple, ...]:
        return tuple(s.key for s in self.lanes)

    def lane_of(self, key: Tuple) -> int:
        return self.lane_keys.index(key)

    def identity(self) -> Tuple:
        """Per-(table, shard) ring identity: two plans with equal identity
        describe byte-compatible buffers whose contents a migration may
        carry over verbatim."""
        return (
            self.table,
            self.partitioned,
            self.num_keys,
            self.ring_keys,
            self.capacity,
            self.lane_keys,
            self.ttl,
        )

    def describe(self) -> str:
        role = "partitioned" if self.partitioned else "replicated"
        return (
            f"{self.table}[{'+'.join(self.serves)}] {role} "
            f"keys={self.num_keys}/{self.ring_keys} cap={self.capacity} "
            f"lanes={len(self.lanes)}"
        )


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Sizing of the two-level pre-aggregation bucket store.

    ``extreme`` / ``tail`` declare which merge-order state families the
    store persists alongside the stat lanes: FIRST/LAST winners per
    direction, and the mergeable newest-rows tail TOPN composes from.
    The planner sets them from the views' RANGE-mode aggregates so
    layouts without those aggregates pay no memory for the extra arrays.
    """

    num_buckets: int
    bucket_size: int
    extreme: bool = False
    tail: bool = False


@dataclasses.dataclass(frozen=True)
class StoreLayout:
    """The full physical plan of one (optionally sharded) online store."""

    num_keys: int                 # global primary key-domain size
    num_shards: Optional[int]     # None = single-device store
    hash_routing: bool
    perm_domain: Optional[int]    # KeyPermutation domain (hash routing)
    primary: RingPlan
    bucket: BucketPlan
    tables: Tuple[RingPlan, ...]  # secondary rings, state.sec order
    raw_lanes: bool               # evolvable: raw columns materialized

    # -- lookups ------------------------------------------------------------

    @property
    def table_names(self) -> Tuple[str, ...]:
        """Distinct secondary tables, in first-ring order."""
        out: List[str] = []
        for p in self.tables:
            if p.table not in out:
                out.append(p.table)
        return tuple(out)

    def rings_of(self, table: str) -> List[int]:
        return [i for i, p in enumerate(self.tables) if p.table == table]

    def _serving(self, table: str, what: str) -> int:
        for i, p in enumerate(self.tables):
            if p.table == table and what in p.serves:
                return i
        raise KeyError(f"no ring of table {table!r} serves {what!r}")

    def union_ring(self, table: str) -> int:
        return self._serving(table, "union")

    def join_ring(self, table: str) -> int:
        return self._serving(table, "join")

    def describe(self) -> str:
        shards = self.num_shards or 1
        lines = [
            f"StoreLayout: shards={shards} "
            f"hash_routing={self.hash_routing} "
            f"buckets={self.bucket.num_buckets}x{self.bucket.bucket_size} "
            f"raw_lanes={self.raw_lanes}",
            f"  primary  {self.primary.describe()}",
        ]
        for p in self.tables:
            lines.append(f"  secondary {p.describe()}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# The planner
# ---------------------------------------------------------------------------


def _feature_names_of_wagg(views, wk: Tuple) -> List[str]:
    """Which view features reference window aggregation ``wk`` (for error
    messages that name the offender, not just the shape mismatch)."""
    names = []
    for v in views:
        for fname, expr in v.features.items():
            if wk in collect_window_aggs([expr]):
                names.append(f"{v.name}/{fname}")
    return names


def plan_layout(
    views: Sequence,  # Sequence[FeatureView]
    *,
    num_keys: int,
    capacity: int = 256,
    num_buckets: int = 64,
    bucket_size: int = 64,
    num_shards: Optional[int] = None,
    hash_routing: bool = True,
    secondary_num_keys: Optional[Dict[str, int]] = None,
    secondary_capacity: Optional[int] = None,
    ttl: Optional[int] = None,
    table_capacity: Optional[Dict[str, int]] = None,
    table_ttl: Optional[Dict[str, int]] = None,
    raw_lanes: bool = False,
) -> StoreLayout:
    """Compute the one :class:`StoreLayout` for a list of feature views.

    Deterministic and **append-stable**: planning ``views + [v_new]``
    keeps every lane slot and ring of ``plan_layout(views)`` at the same
    position and only appends — the property that lets a live plane adopt
    the new layout by carrying state over instead of rebuilding
    (:func:`diff_layouts` + :mod:`repro.core.migrate`).

    ``raw_lanes=True`` makes the layout *evolvable*: every raw schema
    column is materialized as a lane from day one (primary ring, bucket
    store, and every partitioned/union secondary ring), so a future view
    whose window arguments are plain columns hot-deploys with complete
    historical state, and derived arguments can be synthesized from the
    stored columns.  Replicated LAST JOIN *slices* of dual-use tables
    stay narrow (join-argument lanes only) — that is the point of the
    split.

    ``table_capacity`` / ``table_ttl`` override ring capacity and TTL
    *per table* (keyed by table name, primary included) — the planner's
    retention knobs.  Capacity is the true retention lever: a ring
    retains its last ``capacity`` rows per key, so a short-capacity table
    ages rows out (and a migration over it needs the offline backfill
    bridge to stay exact) while a long one carries history verbatim.
    TTL is a *query-time* visibility mask (rows older than ``ttl`` are
    invisible to windows but still occupy slots); per-table TTLs let a
    fast-moving union stream expire early while the primary looks back
    further.

    Placement policy (``num_shards`` set):

    * primary — key-partitioned (`shard = perm(key) % S` under hash
      routing);
    * union-only tables — partitioned the same way (they share the
      primary key space);
    * join-only tables — replicated dimension tables;
    * dual-use tables — **split**: a partitioned union ring (all lanes)
      plus a replicated join slice (join lanes only), recovering the S×
      replication the union-stream rows previously paid.
    """
    views = list(views)
    if not views:
        raise ValueError("plan_layout needs at least one view")
    schema = views[0].schema
    db = views[0].database
    all_exprs: List[Expr] = []
    for v in views:
        all_exprs.extend(v.features.values())

    waggs = collect_window_aggs(all_exprs)
    ljoins = collect_last_joins(all_exprs)
    sec_names = collect_tables(all_exprs)
    sec_schemas = {}
    for v in views:
        for t in collect_tables(list(v.features.values())):
            sec_schemas.setdefault(t, v.database.table(t))

    # per-table retention overrides (capacity = hard retention, ttl =
    # query-time visibility); unknown table names fail loudly
    tcap = dict(table_capacity or {})
    tttl = dict(table_ttl or {})
    known = {schema.name, *sec_names}
    for d, what in ((tcap, "table_capacity"), (tttl, "table_ttl")):
        bad = sorted(set(d) - known)
        if bad:
            raise ValueError(
                f"{what} names unknown table(s) {bad}; the planned views "
                f"reference {sorted(known)}"
            )
    p_cap = int(tcap.get(schema.name, capacity))
    p_ttl = tttl.get(schema.name, ttl)
    p_ttl = None if p_ttl is None else int(p_ttl)

    # window-fit validation, naming the offending feature (pre-agg buckets
    # must cover a non-union RANGE window's span; see online._preagg_parts).
    # Matches the store's own check: a TTL retention policy clamps every
    # window's effective lookback, so it bounds the bucket need too.
    for wk, wa in waggs.items():
        if wa.window.mode == "range" and not wa.union:
            span = (
                wa.window.size if p_ttl is None
                else min(wa.window.size, p_ttl)
            )
            need = span // bucket_size + 2
            if need > num_buckets:
                feats = _feature_names_of_wagg(views, wk)
                raise ValueError(
                    f"window {span} of {wa.agg.value}() in "
                    f"feature(s) {feats} needs {need} buckets of "
                    f"{bucket_size} time units, but the store layout has "
                    f"only num_buckets={num_buckets}; raise num_buckets "
                    f"or bucket_size"
                )

    # -- lane plans ---------------------------------------------------------

    def lane_list(
        raw_cols: Tuple[str, ...], derived: List[Expr]
    ) -> Tuple[LaneSlot, ...]:
        slots: List[LaneSlot] = []
        seen = set()
        if raw_lanes:
            for c in raw_cols:
                e = Col(c)
                slots.append(LaneSlot(e.key, e, source="raw"))
                seen.add(e.key)
        for e in derived:
            if e.key not in seen:
                seen.add(e.key)
                src = "raw" if isinstance(e, Col) else "derived"
                slots.append(LaneSlot(e.key, e, source=src))
        return tuple(slots)

    primary_lanes = lane_list(
        schema.columns, [wa.arg for wa in waggs.values()]
    )

    # per-table argument lanes, in first-seen order (joins walk before
    # unions, matching the pre-layout store's ordering)
    sec_union_args: Dict[str, List[Expr]] = {t: [] for t in sec_names}
    sec_join_args: Dict[str, List[Expr]] = {t: [] for t in sec_names}

    def add(lst: List[Expr], e: Expr) -> None:
        if all(e.key != x.key for x in lst):
            lst.append(e)

    for lj in ljoins.values():
        add(sec_join_args[lj.table], lj.arg)
    for wa in waggs.values():
        for t in wa.union:
            add(sec_union_args[t], wa.arg)

    join_tables = {lj.table for lj in ljoins.values()}
    union_tables = {t for wa in waggs.values() for t in wa.union}

    # -- key-domain / routing sizing ---------------------------------------

    sec_nk = dict(secondary_num_keys or {})
    global_nk = {t: int(sec_nk.get(t, num_keys)) for t in sec_names}
    sec_cap = int(secondary_capacity or capacity)

    sharded = num_shards is not None
    S = int(num_shards) if sharded else 1
    if sharded and S < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    partitioned_sec = (
        {t for t in sec_names if t in union_tables} if sharded else set()
    )
    # a join-only table cannot partition (join keys are arbitrary request
    # columns); a dual-use table partitions its union ring only
    perm_domain: Optional[int] = None
    if sharded:
        dom = max([int(num_keys)] + [global_nk[t] for t in partitioned_sec])
        if hash_routing:
            # one permutation shared by the primary and every partitioned
            # ring (union streams share the primary key space); pad the
            # domain to a multiple of S so local = perm // S stays dense
            perm_domain = S * (-(-dom // S))
            per_shard_keys = perm_domain // S
        else:
            per_shard_keys = -(-dom // S)
    else:
        per_shard_keys = int(num_keys)

    primary = RingPlan(
        table=schema.name,
        partitioned=sharded,
        serves=("window",),
        num_keys=int(num_keys),
        ring_keys=per_shard_keys if sharded else int(num_keys),
        capacity=p_cap,
        lanes=primary_lanes,
        ttl=p_ttl,
    )
    bucket = BucketPlan(
        num_buckets=int(num_buckets),
        bucket_size=int(bucket_size),
        extreme=any(
            wa.window.mode == "range" and agg_spec(wa.agg).state == "extreme"
            for wa in waggs.values()
        ),
        tail=any(
            wa.window.mode == "range" and agg_spec(wa.agg).state == "tail"
            for wa in waggs.values()
        ),
    )

    rings: List[RingPlan] = []
    for t in sec_names:
        tsch = sec_schemas[t]
        cap_t = int(tcap.get(t, sec_cap))
        ttl_t = tttl.get(t)
        ttl_t = None if ttl_t is None else int(ttl_t)
        is_union = t in union_tables
        is_join = t in join_tables
        if sharded and is_union and is_join:
            # dual-use split: partition the union-stream part, replicate
            # only the LAST JOIN slice (narrow: join lanes, no raw lanes)
            rings.append(
                RingPlan(
                    table=t,
                    partitioned=True,
                    serves=("union",),
                    num_keys=global_nk[t],
                    ring_keys=per_shard_keys,
                    capacity=cap_t,
                    lanes=lane_list(tsch.columns, sec_union_args[t]),
                    ttl=ttl_t,
                )
            )
            rings.append(
                RingPlan(
                    table=t,
                    partitioned=False,
                    serves=("join",),
                    num_keys=global_nk[t],
                    ring_keys=global_nk[t],
                    capacity=cap_t,
                    ttl=ttl_t,
                    lanes=tuple(
                        LaneSlot(
                            e.key, e,
                            source="raw" if isinstance(e, Col) else "derived",
                        )
                        for e in sec_join_args[t]
                    ),
                )
            )
            continue
        part = sharded and is_union and not is_join
        serves = tuple(
            w for w, yes in (("union", is_union), ("join", is_join)) if yes
        )
        rings.append(
            RingPlan(
                table=t,
                partitioned=part,
                serves=serves,
                num_keys=global_nk[t],
                ring_keys=per_shard_keys if part else global_nk[t],
                capacity=cap_t,
                lanes=lane_list(
                    tsch.columns, sec_join_args[t] + sec_union_args[t]
                ),
                ttl=ttl_t,
            )
        )

    return StoreLayout(
        num_keys=int(num_keys),
        num_shards=int(num_shards) if sharded else None,
        hash_routing=bool(hash_routing) if sharded else False,
        perm_domain=perm_domain,
        primary=primary,
        bucket=bucket,
        tables=tuple(rings),
        raw_lanes=bool(raw_lanes),
    )


# ---------------------------------------------------------------------------
# Diffing — what a migration must do to get from layout A to layout B
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LayoutDiff:
    """Plan-level diff: how each ring of the *new* layout is sourced.

    ``ring_sources[i]`` (indices into ``old.tables``, or ``"primary"``):
      - int / "primary": carried or transformed from that old ring
      - None: no old state — the ring starts fresh

    ``carried`` marks rings whose :meth:`RingPlan.identity` is unchanged —
    their buffers move over verbatim (zero-copy).
    """

    old: StoreLayout
    new: StoreLayout
    primary_carried: bool
    bucket_carried: bool
    ring_sources: List[Optional[object]]
    carried: List[bool]
    dropped: List[int]  # old ring indices with no consumer in new

    def summary(self) -> str:
        n_carry = sum(self.carried) + int(self.primary_carried)
        n_mig = sum(
            1
            for s, c in zip(self.ring_sources, self.carried)
            if s is not None and not c
        ) + int(not self.primary_carried)
        n_new = sum(1 for s in self.ring_sources if s is None)
        return (
            f"carried={n_carry} migrated={n_mig} new={n_new} "
            f"dropped={len(self.dropped)}"
        )


def _best_source(
    old: StoreLayout, plan: RingPlan
) -> Optional[int]:
    """Pick the old ring a new secondary ring migrates from: exact
    identity first, then same (table, placement), then any ring of the
    table whose lanes can cover the new ring's needs."""
    cands = old.rings_of(plan.table)
    if not cands:
        return None
    for i in cands:
        if old.tables[i].identity() == plan.identity():
            return i
    for i in cands:
        if old.tables[i].partitioned == plan.partitioned:
            return i
    # placement change (e.g. a dual-use split's new replicated join slice
    # sourced from the old partitioned union ring): prefer the widest ring
    return max(cands, key=lambda i: len(old.tables[i].lanes))


def diff_layouts(old: StoreLayout, new: StoreLayout) -> LayoutDiff:
    """Match new rings to old state sources by plan identity.

    Unsupported diffs (shard count, routing mode, bucket width, key-domain
    changes) raise — those require a rebuild, and failing loudly here is
    what keeps the hot-deploy path's bit-exactness contract honest.
    """
    if (old.num_shards or 1) != (new.num_shards or 1):
        raise ValueError(
            f"cannot migrate across shard counts "
            f"({old.num_shards} -> {new.num_shards}); rebuild the plane"
        )
    if old.hash_routing != new.hash_routing:
        raise ValueError("cannot migrate across routing modes; rebuild")
    if old.perm_domain != new.perm_domain:
        raise ValueError(
            f"routing permutation domain changed "
            f"({old.perm_domain} -> {new.perm_domain}): the key -> shard "
            "map itself moved; rebuild the plane"
        )
    if old.bucket.bucket_size != new.bucket.bucket_size:
        raise ValueError(
            f"bucket_size changed ({old.bucket.bucket_size} -> "
            f"{new.bucket.bucket_size}): persisted bucket states do not "
            "re-partition; rebuild the plane"
        )
    if old.num_keys != new.num_keys or (
        old.primary.ring_keys != new.primary.ring_keys
    ):
        raise ValueError(
            f"primary key domain changed ({old.num_keys} -> "
            f"{new.num_keys}); rebuild the plane"
        )

    primary_carried = old.primary.identity() == new.primary.identity()
    bucket_carried = (
        primary_carried and old.bucket == new.bucket
    )
    sources: List[Optional[object]] = []
    carried: List[bool] = []
    used: set = set()
    for plan in new.tables:
        src = _best_source(old, plan)
        sources.append(src)
        if src is not None:
            used.add(src)
        carried.append(
            src is not None and old.tables[src].identity() == plan.identity()
        )
    dropped = [i for i in range(len(old.tables)) if i not in used]
    return LayoutDiff(
        old=old,
        new=new,
        primary_carried=primary_carried,
        bucket_carried=bucket_carried,
        ring_sources=sources,
        carried=carried,
        dropped=dropped,
    )
