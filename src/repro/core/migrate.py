"""Live-plane state migration — hot deployment as a state transform.

FeatInsight deploys new feature services onto a *running* platform; the
OpenMLDB substrate treats deploying a new computation over warm state as a
first-class operation.  This module is that operation for the JAX stores:
given a :class:`~repro.core.layout.LayoutDiff` (old plan → new plan), it
produces the new :class:`~repro.core.online.OnlineState` from the old one
**without re-ingesting anything**:

* rings whose :meth:`~repro.core.layout.RingPlan.identity` is unchanged
  are carried over verbatim (the device buffers move, zero copy);
* rings whose lane plan grew/permuted get their lanes re-mapped, with new
  lanes *synthesized* by re-evaluating the lane expression over the raw
  column lanes an evolvable layout stores (``raw_lanes=True``);
* rings whose capacity changed are re-laid slot-by-slot (the ring's
  cursor arithmetic is reproduced, so the result is byte-identical to a
  store that ran at the new capacity all along — as long as no row had
  already aged out);
* rings whose *placement* changed (the dual-use split: a replicated table
  becoming a partitioned union ring + a narrow replicated join slice, or
  vice versa) are rebuilt by decoding per-key row streams from the source
  ring and re-encoding them under the new routing — per-key ring state
  depends only on that key's rows and their order, which the transform
  preserves exactly;
* bucket pre-aggregate states carry per lane; states for *new* lanes are
  re-folded from the ring's retained rows with the same left-to-right
  association ``bucket_ingest`` uses.

Exactness contract: the migrated state is **bit-identical** to a cold
rebuild + full replay of the same stream whenever the information still
exists in the store — i.e. no required row has aged out of its ring and
(for synthesized lanes) the layout carries raw-column lanes.  When the
horizon is exceeded the migration still succeeds but flags
``report.exact = False`` with a note naming what was lost; the
hot-deploy CI gate (:mod:`benchmarks.bench_deploy`) runs inside the
horizon and asserts bit-exactness outright.

Beyond-the-horizon migrations close the gap through the **offline
backfill bridge** (:mod:`repro.offline.backfill`): every inexactness
site records a structured :class:`Deficit` naming the state it could not
reconstruct, and :func:`migrate_state` accepts a ``backfill=`` source.
When one is passed, lanes that cannot be synthesized from stored f32
columns (hash/signature exprs, un-materialized raw columns) are
*deferred* — zero-filled and recorded as deficits — instead of refusing,
and the caller (:meth:`OnlineFeatureStore.adopt_layout`) splices
offline-re-derived state over every deficit before the new layout goes
live, restoring ``report.exact``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import storage as st
from repro.core.aggregates import (
    LANES,
    NEG_INF,
    POS_INF,
    TOPN_TAIL,
    row_bitmap,
)
from repro.core.expr import Col, eval_rowlevel
from repro.core.layout import LaneSlot, LayoutDiff, RingPlan
from repro.core.online import OnlineState
from repro.obs import get_telemetry

__all__ = ["Deficit", "MigrationReport", "migrate_state"]

_TS_MIN = np.int32(-2147483648)


@dataclasses.dataclass(frozen=True)
class Deficit:
    """One piece of state a migration could not reconstruct exactly.

    ``target`` is ``'ring'`` or ``'bucket'``; ``ring`` indexes the new
    layout's secondary rings (``None`` = the primary ring / the bucket
    store).  ``lanes`` names the affected lane keys, or ``None`` when the
    whole structure is deficient (aged-out rows, bucket-slot remap after
    wraparound).  Deficits are exactly what the offline backfill bridge
    (:mod:`repro.offline.backfill`) knows how to re-derive from history.
    """

    target: str                       # 'ring' | 'bucket'
    table: str
    ring: Optional[int] = None        # new.tables index; None = primary
    lanes: Optional[Tuple] = None     # affected lane keys; None = all
    reason: str = ""

    def describe(self) -> str:
        what = (
            "all lanes" if self.lanes is None
            else ", ".join(repr(k) for k in self.lanes)
        )
        return f"{self.target} {self.table} [{what}]: {self.reason}"


@dataclasses.dataclass
class MigrationReport:
    """What a layout adoption actually did to the live state."""

    diff_summary: str
    carried: List[str] = dataclasses.field(default_factory=list)
    migrated: List[str] = dataclasses.field(default_factory=list)
    fresh: List[str] = dataclasses.field(default_factory=list)
    dropped: List[str] = dataclasses.field(default_factory=list)
    synthesized_lanes: List[str] = dataclasses.field(default_factory=list)
    new_programs: List[str] = dataclasses.field(default_factory=list)
    exact: bool = True
    notes: List[str] = dataclasses.field(default_factory=list)
    deficits: List[Deficit] = dataclasses.field(default_factory=list)
    backfilled: List[str] = dataclasses.field(default_factory=list)
    # inexactness NOT repairable from offline history (e.g. key-domain
    # shrink dropping out-of-domain rows) — the backfill splice never
    # restores report.exact while this is set
    hard_inexact: bool = False

    def add_deficit(self, d: Deficit) -> None:
        """Record a repairable inexactness: the migration proceeds, the
        report flips inexact, and the deficit tells the backfill bridge
        exactly what to re-derive."""
        self.deficits.append(d)
        self.exact = False
        self.notes.append(d.reason)

    def describe(self) -> str:
        lines = [
            f"migration: {self.diff_summary} "
            f"exact={'yes' if self.exact else 'NO'}"
        ]
        for tag, items in (
            ("carried", self.carried),
            ("migrated", self.migrated),
            ("fresh", self.fresh),
            ("dropped", self.dropped),
            ("synthesized", self.synthesized_lanes),
            ("backfilled", self.backfilled),
            ("new programs", self.new_programs),
        ):
            if items:
                lines.append(f"  {tag}: {', '.join(items)}")
        for d in self.deficits:
            lines.append(f"  deficit: {d.describe()}")
        for n in self.notes:
            lines.append(f"  note: {n}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Host-side ring helpers
# ---------------------------------------------------------------------------


def _host_ring(ring: st.RingStore, sharded: bool):
    """Pull a ring to host as (ts (S,K,C), vals (S,K,C,F), cur (S,K)) —
    a leading singleton shard axis is added for unsharded stores so every
    transform below is shard-shape-agnostic."""
    ts = np.asarray(ring.ts)
    vals = np.asarray(ring.vals)
    cur = np.asarray(ring.cursor)
    if not sharded:
        ts, vals, cur = ts[None], vals[None], cur[None]
    return ts, vals, cur


def _mk_ring(ts, vals, cur, sharded: bool) -> st.RingStore:
    if not sharded:
        ts, vals, cur = ts[0], vals[0], cur[0]
    return st.RingStore(
        ts=jnp.asarray(np.ascontiguousarray(ts)),
        vals=jnp.asarray(np.ascontiguousarray(vals)),
        cursor=jnp.asarray(np.ascontiguousarray(cur), jnp.int32),
    )


def _written_mask(cur: np.ndarray, C: int) -> np.ndarray:
    """(..., C) bool: ring slots that have ever been written (slot s is
    first written when the key's cursor passes s)."""
    return cur[..., None] > np.arange(C, dtype=np.int64)


def _collect_cols(e) -> List[str]:
    if isinstance(e, Col):
        return [e.name]
    out: List[str] = []
    for c in e.children():
        out.extend(_collect_cols(c))
    return out


def _synth_refusal(slot: LaneSlot, src_plan: RingPlan, ctx: str) -> Optional[str]:
    """Why ``slot`` cannot be synthesized from ``src_plan``'s stored
    lanes (None when it can)."""
    if not slot.synthesizable:
        return (
            f"lane {slot.key!r} of {ctx} contains hash/signature nodes "
            "whose evaluation is dtype-sensitive — it cannot be "
            "synthesized bit-exactly from stored f32 columns"
        )
    for name in _collect_cols(slot.expr):
        if ("col", name) not in src_plan.lane_keys:
            return (
                f"new lane {slot.key!r} of {ctx} needs raw column "
                f"{name!r}, which the running layout does not materialize "
                "(plan with raw_lanes=True to make the store evolvable)"
            )
    return None


def _synth_lane(
    slot: LaneSlot,
    src_plan: RingPlan,
    vals_src: np.ndarray,       # (..., F_src) raw lane values
    report: MigrationReport,
    ctx: str,
) -> np.ndarray:
    """Re-materialize one lane from the source ring's raw-column lanes.

    Bit-exact vs ingest-time evaluation for pure f32 row math (see
    :func:`repro.core.layout.synthesizable`); anything else requires a
    rebuild (or an offline backfill source) and fails loudly here.
    """
    why = _synth_refusal(slot, src_plan, ctx)
    if why is not None:
        raise ValueError(
            f"cannot hot-deploy: {why}; rebuild the plane for this "
            "deployment, or pass a backfill= source covering "
            f"table {ctx!r}"
        )
    with get_telemetry().tracer.span(
        "migrate.synthesize", table=ctx, lane=str(slot.key)
    ):
        cols: Dict[str, jnp.ndarray] = {}
        for name in _collect_cols(slot.expr):
            ck = ("col", name)
            cols[name] = jnp.asarray(vals_src[..., src_plan.lane_of(ck)])
        if cols:
            v = eval_rowlevel(slot.expr, cols, {}).astype(jnp.float32)
            out = np.asarray(v)
        else:  # literal-only expression
            v = eval_rowlevel(slot.expr, {}, {}).astype(jnp.float32)
            out = np.broadcast_to(np.asarray(v), vals_src.shape[:-1]).copy()
    report.synthesized_lanes.append(f"{ctx}:{slot.key!r}")
    return out


def _map_lanes(
    src_plan: RingPlan,
    dst_plan: RingPlan,
    vals_src: np.ndarray,       # (..., F_src)
    written: Optional[np.ndarray],
    report: MigrationReport,
    ctx: str,
    defer=None,                 # callable(slot, why) -> bool
) -> np.ndarray:
    """(..., F_dst) lane block: carried lanes copied by key, new lanes
    synthesized (zeroed on never-written slots, matching a fresh ring).

    ``defer`` is the backfill hook: when a new lane cannot be synthesized
    and ``defer(slot, why)`` accepts it, the lane is left zero-filled and
    recorded as a deficit for the offline splice instead of refusing.
    """
    F_dst = max(len(dst_plan.lanes), 1)
    out = np.zeros(vals_src.shape[:-1] + (F_dst,), np.float32)
    for j, slot in enumerate(dst_plan.lanes):
        if slot.key in src_plan.lane_keys:
            out[..., j] = vals_src[..., src_plan.lane_of(slot.key)]
            continue
        if defer is not None:
            why = _synth_refusal(slot, src_plan, ctx)
            if why is not None and defer(slot, why):
                continue  # zero-filled; the backfill splice overwrites
        v = _synth_lane(slot, src_plan, vals_src, report, ctx)
        out[..., j] = np.where(written, v, 0.0) if written is not None else v
    return out


def _recap(
    ts: np.ndarray,
    vals: np.ndarray,
    cur: np.ndarray,
    C_new: int,
    report: MigrationReport,
    ctx: str,
    ring_ix: Optional[int],
):
    """Re-lay ring slots for a capacity change, reproducing the cursor
    arithmetic (row at absolute index a lands in slot a % C)."""
    S, K, C_old = ts.shape
    if C_new == C_old:
        return ts, vals
    with get_telemetry().tracer.span(
        "migrate.relay", table=ctx, c_old=C_old, c_new=C_new
    ):
        r = np.minimum(cur, C_old)
        rr = np.minimum(r, C_new).astype(np.int64)
        new_ts = np.full((S, K, C_new), _TS_MIN, np.int32)
        new_vals = np.zeros((S, K, C_new, vals.shape[-1]), np.float32)
        top = int(rr.max()) if rr.size else 0
        for j in range(top):
            si, ki = np.nonzero(j < rr)
            a = cur[si, ki].astype(np.int64) - rr[si, ki] + j
            new_ts[si, ki, a % C_new] = ts[si, ki, a % C_old]
            new_vals[si, ki, a % C_new] = vals[si, ki, a % C_old]
    if C_new > C_old and np.any(cur > C_old):
        report.add_deficit(Deficit(
            target="ring", table=ctx, ring=ring_ix, lanes=None,
            reason=(
                f"{ctx}: capacity grew {C_old}->{C_new} but rows had "
                "already aged out — a cold rebuild would retain more "
                "history"
            ),
        ))
    return new_ts, new_vals


def _relane_ring(
    src_plan: RingPlan,
    dst_plan: RingPlan,
    ring: st.RingStore,
    sharded: bool,
    report: MigrationReport,
    ring_ix: Optional[int] = None,
    defer=None,
) -> st.RingStore:
    """Same key domain & placement: permute/append/synthesize lanes, then
    re-lay capacity if it changed."""
    with get_telemetry().tracer.span(
        "migrate.relane", table=dst_plan.table
    ):
        ts, vals, cur = _host_ring(ring, sharded)
        ctx = dst_plan.table
        written = _written_mask(cur, src_plan.capacity)
        vals = _map_lanes(
            src_plan, dst_plan, vals, written, report, ctx, defer=defer
        )
        ts, vals = _recap(
            ts, vals, cur, dst_plan.capacity, report, ctx, ring_ix
        )
        report.migrated.append(dst_plan.describe())
        return _mk_ring(ts, vals, cur, sharded)


def _decode_streams(
    plan: RingPlan,
    ring_h,
    store,
    report: MigrationReport,
):
    """Source ring -> {global key: (ts (r,), vals (r, F), total_rows)} —
    per-key rows oldest->newest, exactly the per-key stream suffix the
    ring retains."""
    ts, vals, cur = ring_h
    S = ts.shape[0]
    C = plan.capacity
    streams = {}
    if plan.partitioned:
        perm = store._perm
        for s in range(S):
            occupied = np.nonzero(cur[s] > 0)[0]
            if not len(occupied):
                continue
            routed = occupied.astype(np.int64) * S + s
            # algebraic Feistel inverse — O(occupied keys), not a
            # full-domain forward sweep to build a lookup table
            gids = perm.inverse(routed) if perm is not None else routed
            for l, g in zip(occupied, gids):
                c = int(cur[s, l])
                r = min(c, C)
                slots = np.arange(c - r, c, dtype=np.int64) % C
                streams[int(g)] = (ts[s, l, slots], vals[s, l, slots], c)
    else:
        # replicas are identical; decode shard 0
        occupied = np.nonzero(cur[0] > 0)[0]
        for g in occupied:
            c = int(cur[0, g])
            r = min(c, C)
            slots = np.arange(c - r, c, dtype=np.int64) % C
            streams[int(g)] = (ts[0, g, slots], vals[0, g, slots], c)
    return streams


def _reroute_ring(
    src_plan: RingPlan,
    dst_plan: RingPlan,
    ring: st.RingStore,
    store,
    sharded: bool,
    report: MigrationReport,
    ring_ix: Optional[int] = None,
    defer=None,
) -> st.RingStore:
    """Placement change (partitioned <-> replicated, e.g. building a
    dual-use table's replicated join slice from its partitioned union
    ring): decode per-key row streams, re-encode under the new plan."""
    with get_telemetry().tracer.span(
        "migrate.reroute", table=dst_plan.table,
        partitioned=dst_plan.partitioned,
    ):
        return _reroute_ring_impl(
            src_plan, dst_plan, ring, store, sharded, report, ring_ix,
            defer,
        )


def _reroute_ring_impl(
    src_plan: RingPlan,
    dst_plan: RingPlan,
    ring: st.RingStore,
    store,
    sharded: bool,
    report: MigrationReport,
    ring_ix: Optional[int] = None,
    defer=None,
) -> st.RingStore:
    S = store.num_shards if sharded else 1
    streams = _decode_streams(
        src_plan, _host_ring(ring, sharded), store, report
    )
    ctx = f"{dst_plan.table}({'part' if dst_plan.partitioned else 'repl'})"
    F_dst = max(len(dst_plan.lanes), 1)
    K_t, C_t = dst_plan.ring_keys, dst_plan.capacity
    ts_n = np.full((S, K_t, C_t), _TS_MIN, np.int32)
    vals_n = np.zeros((S, K_t, C_t, F_dst), np.float32)
    cur_n = np.zeros((S, K_t), np.int32)
    deficient = False
    for g, (ts_g, vl_g, c) in streams.items():
        if g >= dst_plan.num_keys:
            report.notes.append(
                f"{ctx}: dropped rows of out-of-domain key {g}"
            )
            report.exact = False
            report.hard_inexact = True
            continue
        rows = _map_lanes(
            src_plan, dst_plan, vl_g, None, report, ctx, defer=defer
        )
        r = len(ts_g)
        if min(c, C_t) > r and not deficient:
            deficient = True
            report.add_deficit(Deficit(
                target="ring", table=dst_plan.table, ring=ring_ix,
                lanes=None,
                reason=(
                    f"{ctx}: key {g} lost {min(c, C_t) - r} aged-out rows "
                    "vs a cold rebuild"
                ),
            ))
        rr = min(r, C_t)
        a = np.arange(c - rr, c, dtype=np.int64)
        if dst_plan.partitioned:
            s_arr, l_arr = store._route_ids(
                np.array([g], np.int64), dst_plan.num_keys
            )
            s, l = int(s_arr[0]), int(l_arr[0])
            ts_n[s, l, a % C_t] = ts_g[r - rr:]
            vals_n[s, l, a % C_t] = rows[r - rr:]
            cur_n[s, l] = c
        else:
            ts_n[:, g, a % C_t] = ts_g[r - rr:]
            vals_n[:, g, a % C_t] = rows[r - rr:]
            cur_n[:, g] = c
    report.migrated.append(dst_plan.describe())
    return _mk_ring(ts_n, vals_n, cur_n, sharded)


def _fresh_ring(plan: RingPlan, sharded: bool, S: int) -> st.RingStore:
    r = st.ring_init(plan.ring_keys, plan.capacity, max(len(plan.lanes), 1))
    if sharded:
        r = st.RingStore(
            ts=jnp.broadcast_to(r.ts, (S,) + r.ts.shape),
            vals=jnp.broadcast_to(r.vals, (S,) + r.vals.shape),
            cursor=jnp.broadcast_to(r.cursor, (S,) + r.cursor.shape),
        )
    return r


# ---------------------------------------------------------------------------
# Bucket pre-aggregate migration
# ---------------------------------------------------------------------------


_LANE_IDENT_NP = {
    "sum": np.float32(0.0),
    "count": np.float32(0.0),
    "min": np.float32(POS_INF),
    "max": np.float32(NEG_INF),
    "sumsq": np.float32(0.0),
}


def _rebuild_bucket_lane(
    v: np.ndarray,        # (S, K, C) new-lane ring values
    ts: np.ndarray,       # (S, K, C)
    cur: np.ndarray,      # (S, K)
    bucket_ids: np.ndarray,  # (S, K, NB)
    bsize: int,
):
    """Per-(key, bucket) algebra states for one lane, folded from the
    ring's retained rows oldest -> newest.

    The left-to-right f32 association matches ``bucket_ingest``'s
    scatter-add order row-for-row, so under a replay whose batches bring
    at most one row per (key, bucket) each (the live-service pattern) the
    rebuilt states are bit-identical to having ingested with the lane
    present all along.
    """
    S, K, C = v.shape
    written = _written_mask(cur, C)
    rowb = np.where(written, ts.astype(np.int64) // bsize, np.int64(-2))
    match = (rowb[:, :, None, :] == bucket_ids[..., None].astype(np.int64)) & (
        bucket_ids[..., None] >= 0
    )  # (S, K, NB, C)
    vm = np.where(match, v[:, :, None, :], np.float32(0.0)).astype(np.float32)
    s_sum = np.cumsum(vm, axis=-1, dtype=np.float32)[..., -1]
    s_cnt = match.sum(-1).astype(np.float32)
    s_min = np.where(match, v[:, :, None, :], _LANE_IDENT_NP["min"]).min(-1)
    s_max = np.where(match, v[:, :, None, :], _LANE_IDENT_NP["max"]).max(-1)
    sq = np.where(
        match, (v[:, :, None, :] * v[:, :, None, :]).astype(np.float32), 0.0
    ).astype(np.float32)
    s_sq = np.cumsum(sq, axis=-1, dtype=np.float32)[..., -1]
    by_name = {
        "sum": s_sum, "count": s_cnt, "min": s_min, "max": s_max,
        "sumsq": s_sq,
    }
    stats = np.stack([by_name[l] for l in LANES], axis=-1)
    bm_rows = np.asarray(row_bitmap(jnp.asarray(v)))  # (S, K, C) int32
    bitmap = np.bitwise_or.reduce(
        np.where(match, bm_rows[:, :, None, :], 0), axis=-1
    ).astype(np.int32)
    return stats, bitmap


_TS_EMPTY_NP = np.int32(-2147483648)


def _rebuild_bucket_order(
    vals: np.ndarray,        # (S, K, C, F) new-ring lane values
    ts: np.ndarray,          # (S, K, C)
    cur: np.ndarray,         # (S, K)
    bucket_ids: np.ndarray,  # (S, K, NB)
    bsize: int,
    want_ext: bool,
    want_tail: bool,
) -> Dict[str, np.ndarray]:
    """Merge-order families (extreme winners / newest-rows tail)
    re-derived from the ring's retained rows.

    The absolute arrival index of ring slot ``j`` is exactly
    ``cur-1-((cur-1-j) % C)`` — the newest arrival mapping to that slot —
    so the rebuilt (ts, pos) coordinates equal having persisted the
    families all along, for every row the ring still retains.
    """
    S, K, C = ts.shape
    written = _written_mask(cur, C)
    j = np.arange(C, dtype=np.int64)
    cur64 = cur[..., None].astype(np.int64)
    pos = cur64 - 1 - ((cur64 - 1 - j) % C)                  # (S, K, C)
    ts64 = ts.astype(np.int64)
    rowb = np.where(written, ts64 // bsize, np.int64(-2))
    match = (
        rowb[:, :, None, :] == bucket_ids[..., None].astype(np.int64)
    ) & (bucket_ids[..., None] >= 0)                         # (S, K, NB, C)
    tsb = np.broadcast_to(ts64[:, :, None, :], match.shape)
    posb = np.broadcast_to(pos[:, :, None, :], match.shape)
    sI = np.arange(S)[:, None, None]
    kI = np.arange(K)[None, :, None]
    out: Dict[str, np.ndarray] = {}
    if want_ext:
        has = match.any(-1)
        picks, b_ts, b_pos = [], [], []
        for newest in (False, True):
            lim = np.int64(-(2 ** 62)) if newest else np.int64(2 ** 62)
            red = np.max if newest else np.min
            bt = red(np.where(match, tsb, lim), -1)
            cand = match & (tsb == bt[..., None])
            bp = red(np.where(cand, posb, lim), -1)
            picks.append(np.argmax(cand & (posb == bp[..., None]), -1))
            b_ts.append(bt)
            b_pos.append(bp)
        h2 = np.stack([has, has], -1)
        xval = np.stack([vals[sI, kI, p] for p in picks], -1)
        out["xts"] = np.where(
            h2, np.stack(b_ts, -1), np.int64(_TS_EMPTY_NP)
        ).astype(np.int32)
        out["xpos"] = np.where(h2, np.stack(b_pos, -1), 0).astype(np.int32)
        out["xval"] = np.where(
            h2[:, :, :, None, :], xval, 0.0
        ).astype(np.float32)
        out["xhas"] = h2
    if want_tail:
        T, m = int(TOPN_TAIL), min(C, int(TOPN_TAIL))
        # descending (ts, pos): pos < 2^32, so ts*2^32+pos is the exact
        # lexicographic encoding; ascending argsort of its negation
        big = np.iinfo(np.int64).max
        inv = np.where(match, -(tsb * (2 ** 32) + posb), big)
        order = np.argsort(inv, axis=-1, kind="stable")[..., :m]
        valid = np.take_along_axis(inv, order, -1) != big
        r_ts = np.take_along_axis(tsb, order, -1)
        r_pos = np.take_along_axis(posb, order, -1)
        sI4, kI4 = sI[..., None], kI[..., None]
        r_val = np.moveaxis(vals[sI4, kI4, order], -1, -2)  # (S,K,NB,F,m)

        def pad_t(a, fill):
            if m == T:
                return a
            return np.concatenate(
                [a, np.full(a.shape[:-1] + (T - m,), fill, a.dtype)], -1
            )

        out["tts"] = pad_t(
            np.where(valid, r_ts, np.int64(_TS_EMPTY_NP)).astype(np.int32),
            _TS_EMPTY_NP,
        )
        out["tpos"] = pad_t(np.where(valid, r_pos, 0).astype(np.int32), 0)
        out["tval"] = pad_t(
            np.where(valid[:, :, :, None, :], r_val, 0.0).astype(np.float32),
            np.float32(0.0),
        )
        out["tvalid"] = pad_t(valid, False)
    return out


def _migrate_bucket(
    diff: LayoutDiff,
    bagg,
    new_ring: st.RingStore,
    sharded: bool,
    report: MigrationReport,
):
    """Carry bucket states per lane; remap slots on num_buckets changes;
    re-fold new lanes from the (already migrated) primary ring."""
    from repro.core import preagg as pg

    src_p, dst_p = diff.old.primary, diff.new.primary
    NB_o, NB_n = diff.old.bucket.num_buckets, diff.new.bucket.num_buckets
    bsize = diff.new.bucket.bucket_size

    stats = np.asarray(bagg.stats)
    bitmap = np.asarray(bagg.bitmap)
    bucket = np.asarray(bagg.bucket)
    if not sharded:
        stats, bitmap, bucket = stats[None], bitmap[None], bucket[None]

    # merge-order families the NEW plan persists; carry the old arrays
    # when the old store has them (same remap as stats below)
    want_ext = getattr(diff.new.bucket, "extreme", False)
    want_tail = getattr(diff.new.bucket, "tail", False)
    fam: Dict[str, np.ndarray] = {}
    fam_src = (want_ext or want_tail) and (
        (not want_ext or bagg.xts is not None)
        and (not want_tail or bagg.tts is not None)
    )
    if fam_src:
        names = (("xts", "xpos", "xval", "xhas") if want_ext else ()) + (
            ("tts", "tpos", "tval", "tvalid") if want_tail else ()
        )
        for nm in names:
            a = np.asarray(getattr(bagg, nm))
            fam[nm] = a if sharded else a[None]

    if NB_n != NB_o:
        if np.any(bucket >= NB_o):
            # some slot has cycled at least once -> older buckets of the
            # finer/coarser new ring may be unrecoverable
            report.add_deficit(Deficit(
                target="bucket", table=dst_p.table, lanes=None,
                reason=(
                    f"primary: num_buckets {NB_o}->{NB_n} after "
                    "bucket-ring wraparound — a cold rebuild would retain "
                    "different buckets"
                ),
            ))
        order = np.argsort(bucket, axis=-1, kind="stable")
        b_s = np.take_along_axis(bucket, order, -1)
        st_s = np.take_along_axis(stats, order[..., None, None], 2)
        bm_s = np.take_along_axis(bitmap, order[..., None], 2)
        tgt = np.where(b_s >= 0, b_s % NB_n, NB_n)  # invalid -> spill slot
        S, K = bucket.shape[:2]
        F_o, NS = stats.shape[-2], stats.shape[-1]
        bucket_n = np.full((S, K, NB_n + 1), -1, np.int32)
        stats_n = np.broadcast_to(
            np.array([_LANE_IDENT_NP[l] for l in LANES], np.float32),
            (S, K, NB_n + 1, F_o, NS),
        ).copy()
        bitmap_n = np.zeros((S, K, NB_n + 1, F_o), np.int32)
        # ascending bucket ids: later (larger) ids win slot conflicts,
        # matching the ring's newest-bucket-per-slot retention
        np.put_along_axis(bucket_n, tgt, b_s, axis=2)
        np.put_along_axis(stats_n, tgt[..., None, None], st_s, axis=2)
        np.put_along_axis(bitmap_n, tgt[..., None], bm_s, axis=2)
        bucket, stats, bitmap = (
            bucket_n[..., :NB_n],
            stats_n[..., :NB_n, :, :],
            bitmap_n[..., :NB_n, :],
        )
        fam_empty = {
            "xts": (_TS_EMPTY_NP, 1), "xpos": (np.int32(0), 1),
            "xval": (np.float32(0.0), 2), "xhas": (False, 1),
            "tts": (_TS_EMPTY_NP, 1), "tpos": (np.int32(0), 1),
            "tval": (np.float32(0.0), 2), "tvalid": (False, 1),
        }
        for nm, a in fam.items():
            empty, extra = fam_empty[nm]
            idx = order.reshape(order.shape + (1,) * extra)
            a_s = np.take_along_axis(a, idx, 2)
            a_n = np.full((S, K, NB_n + 1) + a.shape[3:], empty, a.dtype)
            np.put_along_axis(
                a_n, tgt.reshape(tgt.shape + (1,) * extra), a_s, 2
            )
            fam[nm] = a_n[:, :, :NB_n]

    # lane remap / rebuild
    ts_h, vals_h, cur_h = _host_ring(new_ring, sharded)
    F_n = max(len(dst_p.lanes), 1)
    S, K = bucket.shape[:2]
    NS = stats.shape[-1]
    stats_out = np.broadcast_to(
        np.array([_LANE_IDENT_NP[l] for l in LANES], np.float32),
        (S, K, NB_n, F_n, NS),
    ).copy()
    bitmap_out = np.zeros((S, K, NB_n, F_n), np.int32)
    # the rebuild folds the (already re-capped) NEW ring, so rows beyond
    # EITHER capacity are gone — a cold rebuild's bucket store saw them
    ring_lost = bool(
        np.any(cur_h > min(src_p.capacity, dst_p.capacity))
    )
    # primary-ring lanes the migration zero-filled for the backfill
    # splice: their ring values are NOT usable as a fold source
    deferred = {
        k
        for d in report.deficits
        if d.target == "ring" and d.ring is None and d.lanes
        for k in d.lanes
    }
    for j, slot in enumerate(dst_p.lanes):
        if slot.key in src_p.lane_keys:
            i = src_p.lane_of(slot.key)
            stats_out[..., j, :] = stats[..., i, :]
            bitmap_out[..., j] = bitmap[..., i]
        elif slot.key in deferred:
            # identities stay in place; the splice re-folds from history
            report.add_deficit(Deficit(
                target="bucket", table=dst_p.table, lanes=(slot.key,),
                reason=(
                    f"primary: bucket states for deferred lane "
                    f"{slot.key!r} await the backfill splice"
                ),
            ))
        else:
            st_j, bm_j = _rebuild_bucket_lane(
                vals_h[..., j], ts_h, cur_h, bucket, bsize
            )
            stats_out[..., j, :] = st_j
            bitmap_out[..., j] = bm_j
            if ring_lost:
                report.add_deficit(Deficit(
                    target="bucket", table=dst_p.table, lanes=(slot.key,),
                    reason=(
                        f"primary: bucket states for new lane {slot.key!r} "
                        "rebuilt from ring-retained rows only (older rows "
                        "had aged out)"
                    ),
                ))
    # merge-order family outputs: carry (lane-gathered) when every dst
    # lane exists in the source arrays, else re-derive from the new ring
    fam_kw: Dict[str, np.ndarray] = {}
    if want_ext or want_tail:
        # per-key arrival counter ≡ ring cursor (both count every arrival)
        fam_kw["seq"] = cur_h.astype(np.int32)
        lanes_ok = bool(dst_p.lanes) and all(
            s.key in src_p.lane_keys for s in dst_p.lanes
        )
        if fam_src and lanes_ok:
            li = [src_p.lane_of(s.key) for s in dst_p.lanes]
            if want_ext:
                fam_kw["xts"], fam_kw["xpos"] = fam["xts"], fam["xpos"]
                fam_kw["xhas"] = fam["xhas"]
                fam_kw["xval"] = fam["xval"][..., li, :]
            if want_tail:
                fam_kw["tts"], fam_kw["tpos"] = fam["tts"], fam["tpos"]
                fam_kw["tvalid"] = fam["tvalid"]
                fam_kw["tval"] = fam["tval"][..., li, :]
        else:
            fam_kw.update(_rebuild_bucket_order(
                vals_h, ts_h, cur_h, bucket, bsize, want_ext, want_tail
            ))
            if ring_lost:
                report.add_deficit(Deficit(
                    target="bucket", table=dst_p.table, lanes=None,
                    reason=(
                        "primary: merge-order bucket states (extreme/tail)"
                        " rebuilt from ring-retained rows only (older rows"
                        " had aged out)"
                    ),
                ))
    if not sharded:
        stats_out, bitmap_out, bucket = (
            stats_out[0], bitmap_out[0], bucket[0]
        )
        fam_kw = {k: v[0] for k, v in fam_kw.items()}
    report.migrated.append(
        f"bucket[{NB_o}->{NB_n} x {bsize}, lanes {stats.shape[-2]}->{F_n}]"
    )
    return pg.BucketAgg(
        stats=jnp.asarray(np.ascontiguousarray(stats_out)),
        bitmap=jnp.asarray(np.ascontiguousarray(bitmap_out)),
        bucket=jnp.asarray(np.ascontiguousarray(bucket), jnp.int32),
        size=bsize,
        **{
            k: jnp.asarray(np.ascontiguousarray(v))
            for k, v in fam_kw.items()
        },
    )


# ---------------------------------------------------------------------------
# The migration
# ---------------------------------------------------------------------------


def _make_deferrer(backfill, plan: RingPlan, ring_ix, report):
    """Build the per-ring lane-deferral hook: a new lane that cannot be
    synthesized is zero-filled and recorded as a deficit — but only when
    the backfill source actually holds the table's history columns, so a
    migration never silently defers into an unservable splice."""
    if backfill is None:
        return None

    def defer(slot: LaneSlot, why: str) -> bool:
        if not backfill.covers(plan.table, slot.expr):
            return False
        report.add_deficit(Deficit(
            target="ring", table=plan.table, ring=ring_ix,
            lanes=(slot.key,),
            reason=f"{why} — deferred to the offline backfill splice",
        ))
        return True

    return defer


def migrate_state(
    diff: LayoutDiff,
    old_state: OnlineState,
    store,  # OnlineFeatureStore already switched to diff.new
    backfill=None,  # repro.offline.backfill.BackfillSource (duck-typed)
) -> Tuple[OnlineState, MigrationReport]:
    """Transform ``old_state`` (laid out per ``diff.old``) into a state
    laid out per ``diff.new``.  Returns host-or-device arrays; the caller
    places them (:meth:`OnlineFeatureStore._place_state`).

    ``backfill`` only changes *refusal* behaviour here: lanes that cannot
    be synthesized from stored columns are deferred (zero-filled +
    recorded in ``report.deficits``) when the source covers their table.
    The actual splice happens in the caller, against the full report.
    """
    sharded = diff.new.num_shards is not None
    S = diff.new.num_shards or 1
    report = MigrationReport(diff_summary=diff.summary())
    tracer = get_telemetry().tracer

    with tracer.span("migrate", tables=len(diff.new.tables)):
        # -- primary ring + bucket store -----------------------------------
        if diff.primary_carried:
            with tracer.span(
                "migrate.carry", table=diff.new.primary.table
            ):
                ring = old_state.ring
            report.carried.append(diff.new.primary.describe())
        else:
            ring = _relane_ring(
                diff.old.primary, diff.new.primary, old_state.ring,
                sharded, report, ring_ix=None,
                defer=_make_deferrer(
                    backfill, diff.new.primary, None, report
                ),
            )
        if diff.bucket_carried:
            with tracer.span("migrate.carry", table="bucket"):
                bagg = old_state.bagg
            report.carried.append(
                f"bucket[{diff.new.bucket.num_buckets} x "
                f"{diff.new.bucket.bucket_size}]"
            )
        else:
            with tracer.span("migrate.bucket", table=diff.new.primary.table):
                bagg = _migrate_bucket(
                    diff, old_state.bagg, ring, sharded, report
                )

        # -- secondary rings ------------------------------------------------
        sec: List[st.RingStore] = []
        for i, plan in enumerate(diff.new.tables):
            src = diff.ring_sources[i]
            if src is None:
                with tracer.span("migrate.fresh", table=plan.table):
                    sec.append(_fresh_ring(plan, sharded, S))
                report.fresh.append(plan.describe())
                continue
            src_plan = diff.old.tables[src]
            if diff.carried[i]:
                with tracer.span("migrate.carry", table=plan.table):
                    sec.append(old_state.sec[src])
                report.carried.append(plan.describe())
            elif (
                src_plan.partitioned == plan.partitioned
                and src_plan.ring_keys == plan.ring_keys
            ):
                sec.append(
                    _relane_ring(
                        src_plan, plan, old_state.sec[src], sharded,
                        report, ring_ix=i,
                        defer=_make_deferrer(backfill, plan, i, report),
                    )
                )
            else:
                sec.append(
                    _reroute_ring(
                        src_plan, plan, old_state.sec[src], store, sharded,
                        report, ring_ix=i,
                        defer=_make_deferrer(backfill, plan, i, report),
                    )
                )
        for i in diff.dropped:
            report.dropped.append(diff.old.tables[i].describe())

    return (
        OnlineState(ring=ring, bagg=bagg, sec=tuple(sec)),
        report,
    )
