"""Offline↔online consistency verification — FeatInsight §2(3).

"We perform feature computation of test data through execution engines in
both offline and online scenario, and compare the consistency of the
result."  The paper cites month-to-year manual verification campaigns this
replaces (Akulaku); here it is one function.

Protocol (request-mode replay):
  1. offline: batch-compute every feature for every row of the test table;
  2. online: replay rows in timestamp order — for each row, FIRST query the
     online service with the row as the request (its window sees rows
     0..i-1 plus itself, matching offline point-in-time semantics), THEN
     ingest it;
  3. compare per-feature with fp tolerance (both engines are f32; the
     offline engine uses prefix-sum differences, the online engine direct
     masked sums, so exact bit-equality is not the contract — bounded
     relative error is).  Both sides evaluate the *same* aggregator algebra
     (one (init, lift, combine, finalize) per Agg in
     :mod:`repro.core.aggregates`), so the only divergence left is fp
     association order; aggregates that return raw row values (FIRST, LAST,
     MIN, MAX, TOPN_FREQ) agree exactly, which the algebra test-suite
     asserts for the union-composable cases.

The replay is batched by "rounds": rows are grouped so that no key appears
twice in a round; within a round every query is answered against state that
excludes the whole round, which matches offline semantics because windows
are per-key.  This keeps the replay jit-friendly (no per-row Python loop).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import OfflineEngine
from repro.core.online import OnlineFeatureStore
from repro.core.view import FeatureView

__all__ = ["ConsistencyReport", "verify_view", "replay_rounds"]


@dataclasses.dataclass
class ConsistencyReport:
    view: str
    version: int
    n_rows: int
    n_features: int
    max_abs_err: float
    max_rel_err: float
    per_feature: Dict[str, float]
    passed: bool
    mode: str

    def summary(self) -> str:
        flag = "PASS" if self.passed else "FAIL"
        return (
            f"[{flag}] view={self.view} v{self.version} rows={self.n_rows} "
            f"features={self.n_features} max_abs={self.max_abs_err:.3e} "
            f"max_rel={self.max_rel_err:.3e} (mode={self.mode})"
        )


def replay_rounds(key: np.ndarray, ts: np.ndarray) -> List[np.ndarray]:
    """Split row indices (ts-sorted) into rounds with unique keys per round."""
    order = np.argsort(ts, kind="stable")
    rounds: List[List[int]] = []
    seen_at: Dict[int, int] = {}
    for i in order:
        k = int(key[i])
        r = seen_at.get(k, -1) + 1
        seen_at[k] = r
        while len(rounds) <= r:
            rounds.append([])
        rounds[r].append(int(i))
    return [np.array(r, np.int64) for r in rounds]


def verify_view(
    view: FeatureView,
    columns: Dict[str, np.ndarray],
    *,
    num_keys: int,
    capacity: int = 256,
    num_buckets: int = 64,
    bucket_size: int = 64,
    mode: str = "preagg",
    rtol: float = 2e-4,
    atol_scale: float = 1e-3,
    engine: Optional[OfflineEngine] = None,
    secondary: Optional[Dict[str, Dict[str, np.ndarray]]] = None,
    secondary_num_keys: Optional[Dict[str, int]] = None,
    num_shards: Optional[int] = None,
    device_routing: bool = True,
) -> ConsistencyReport:
    """Run the full offline-vs-online verification for one view.

    ``num_shards`` replays against a
    :class:`~repro.core.shard.ShardedOnlineStore` of that many shards
    instead of the single-device store — the sharded serving plane must
    satisfy the *same* offline↔online contract, and its answers are
    bit-identical to the single store's, so one tolerance serves both.
    ``device_routing`` picks the sharded request flavour (the fused
    on-mesh path by default; ``False`` replays through the host-routed
    oracle), so the consistency contract is checkable under both —
    ignored for single-device replays.

    Multi-table views pass their secondary tables via ``secondary``
    ({table: {col: (M,) array}}).  The replay then interleaves ingest
    across tables by timestamp: before each primary round, every
    secondary row with ``ts <= max(round ts)`` that has not been ingested
    yet is pushed into its table's ring — so LAST JOIN lookups and union
    windows are answered from exactly the secondary state a live service
    would hold at that point of the stream (early arrivals are invisible
    anyway: every online path masks ``ts <= request ts``).

    Capacity contract: a round's worth of early-ingested secondary rows
    must not wrap a key's ring (``capacity`` rows per key), or they could
    evict rows an earlier-ts request in the same round still needs — size
    ``capacity`` to the per-key secondary row count, as with the primary.
    """
    engine = engine or OfflineEngine()
    secondary = secondary or {}
    offline = {
        k: np.asarray(v)
        for k, v in engine.compute(view, columns, secondary).items()
    }

    store = OnlineFeatureStore.create(
        view,
        num_keys=num_keys,
        num_shards=num_shards,
        capacity=capacity,
        num_buckets=num_buckets,
        bucket_size=bucket_size,
        secondary_num_keys=secondary_num_keys,
        device_routing=device_routing,
    )
    schema = view.schema
    key = np.asarray(columns[schema.key])
    ts = np.asarray(columns[schema.ts])
    n = len(key)

    # per-table (key, ts)-stable-sorted-by-ts event cursors
    sec_events: Dict[str, Dict] = {}
    for t in store._sec_names:
        tsch = view.database.table(t)
        tcols = {c: np.asarray(v) for c, v in secondary[t].items()}
        order = np.argsort(tcols[tsch.ts], kind="stable")
        sec_events[t] = {
            "cols": {c: v[order] for c, v in tcols.items()},
            "ts": tcols[tsch.ts][order],
            "keycol": tsch.key,
            "tscol": tsch.ts,
            "pos": 0,
        }

    def ingest_secondary_upto(tmax: int) -> None:
        for t, ev in sec_events.items():
            hi = int(np.searchsorted(ev["ts"], tmax, side="right"))
            if hi <= ev["pos"]:
                continue
            sl = slice(ev["pos"], hi)
            ev["pos"] = hi
            batch = {c: v[sl] for c, v in ev["cols"].items()}
            sort = np.lexsort((batch[ev["tscol"]], batch[ev["keycol"]]))
            store.ingest_table(t, {c: v[sort] for c, v in batch.items()})

    online = {f: np.zeros(n, np.float32) for f in view.features}
    for idx in replay_rounds(key, ts):
        ingest_secondary_upto(int(ts[idx].max()))
        batch = {c: np.asarray(columns[c])[idx] for c in columns}
        res = store.query(batch, mode=mode)
        for f, v in res.items():
            online[f][idx] = np.asarray(v)
        # ingest the round (sorted by key then ts as the store requires)
        sort = np.lexsort((ts[idx], key[idx]))
        store.ingest({c: batch[c][sort] for c in batch})

    max_abs = 0.0
    max_rel = 0.0
    per_feature: Dict[str, float] = {}
    ok = True
    for f in view.features:
        a, b = offline[f].astype(np.float64), online[f].astype(np.float64)
        abs_err = np.abs(a - b)
        rel_err = abs_err / np.maximum(np.abs(a), 1.0)
        per_feature[f] = float(abs_err.max(initial=0.0))
        max_abs = max(max_abs, per_feature[f])
        max_rel = max(max_rel, float(rel_err.max(initial=0.0)))
        # Scale-aware tolerance: both engines are f32; the offline path uses
        # prefix-sum differences (error ~ eps * running magnitude) and STD
        # uses the E[x^2] formula (error ~ eps * value^2), so the equality
        # contract is bounded error relative to the feature's scale.
        scale = float(np.percentile(np.abs(a), 99)) if a.size else 1.0
        atol_f = atol_scale * max(1.0, scale)
        if not np.allclose(a, b, rtol=rtol, atol=atol_f):
            ok = False
    return ConsistencyReport(
        view=view.name,
        version=view.version,
        n_rows=n,
        n_features=len(view.features),
        max_abs_err=max_abs,
        max_rel_err=max_rel,
        per_feature=per_feature,
        passed=ok,
        mode=(
            mode
            if num_shards is None
            else f"{mode}/shards={num_shards}"
            + ("" if device_routing else "/host")
        ),
    )
