"""Scenario explosion — the standing stress suite.

FeatInsight's headline claim is 100+ real-world scenarios served from one
platform with consistent offline/online feature computation; the repo's
hand-written catalog has five.  This package closes the gap with a seeded,
deterministic generator (:mod:`repro.stress.generate`) that composes the
full expr IR into N>=100 feature views, and a churn harness
(:mod:`repro.stress.harness`) that deploys them onto one sharded
``ScenarioPlane``, hot-deploys more in waves, drives mixed-scenario
traffic under both routing flavours, and continuously samples the
offline==online verification — shrinking any failure down to a minimal,
runnable repro script.

Entry points::

    python -m repro.stress --smoke      # N=16, fixed seed, 8 shards (CI)
    python -m repro.stress --n 128      # the full sweep
    pytest -m stress                    # the slow test-suite flavour
"""

from repro.stress.generate import (  # noqa: F401
    NUM_ENTITIES,
    NUM_ITEMS,
    PROFILES,
    T_MAX,
    filter_table_knobs,
    gen_store_kwargs,
    gen_views,
    render_summary_md,
    stress_rng,
    summarize_views,
    view_fingerprint,
)
from repro.stress.harness import (  # noqa: F401
    StressFailure,
    StressReport,
    run_stress,
)
