"""Deploy-churn-verify harness over a generated scenario plane.

One run = the paper's serving story at generator scale:

1. **deploy** — N generated views land on ONE sharded ``ScenarioPlane``
   via ``FeatureService.build_multi`` (a held-back tail is reserved for
   churn);
2. **churn** — ``hot_deploy`` waves push the held-back views onto the
   LIVE plane, alternating between history-synthesis-only migrations and
   migrations fed a :class:`~repro.offline.backfill.BackfillSource`
   rebuilt from the exact ingest log — every wave must report an exact
   migration.  A no-backfill wave that draws a view with unsynthesizable
   new lanes (hash/signature) must refuse LOUDLY naming the backfill
   remedy; the harness then retries that view with the exact-history
   source (the documented contract, exercised, not worked around);
3. **traffic** — mixed-scenario batches flow through ``ShardRouter`` /
   ``request_mixed`` under BOTH ``device_routing`` flavours, and each
   phase runs a fused-vs-host parity probe that must match bit-for-bit;
4. **verify** — a seeded rotating subset of live views replays through
   ``verify_view`` (offline==online, alternating routing flavours), plus
   a plane == dedicated-store spot check: one view's answers against a
   fresh single-view store replaying the identical ingest log must be
   bit-identical;
5. **shrink** — any failing check re-runs the failing view in isolation
   on a shrinking data prefix and emits a minimal, runnable repro script
   naming the seed and the view spec (``python -m repro.stress --repro``).

Every sampling decision (traffic tags, verify rotation) flows from the
same named generator as ``gen_views`` — a stress run is reproducible
from ``(seed, n, profile)`` alone.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.consistency import verify_view
from repro.core.online import OnlineFeatureStore
from repro.core.view import FeatureRegistry, FeatureView, render_sql
from repro.data.synthetic import STRESS_DB, stress_stream
from repro.offline.backfill import BackfillSource
from repro.serve.router import ShardRouter
from repro.serve.service import BatchScheduler, FeatureService
from repro.stress.generate import (
    NUM_ENTITIES,
    NUM_ITEMS,
    T_MAX,
    filter_table_knobs,
    gen_store_kwargs,
    gen_views,
    stress_rng,
)

__all__ = ["StressFailure", "StressReport", "run_stress", "run_repro"]


@dataclasses.dataclass
class StressFailure:
    view: str
    stage: str                    # deploy | parity | spot | verify
    detail: str
    shrunk_rows: Optional[int] = None
    repro_path: Optional[str] = None

    def summary(self) -> str:
        extra = ""
        if self.shrunk_rows is not None:
            extra = f" (shrunk to {self.shrunk_rows} rows)"
        if self.repro_path:
            extra += f" repro: {self.repro_path}"
        return f"[{self.stage}] {self.view}: {self.detail}{extra}"


@dataclasses.dataclass
class StressReport:
    seed: int
    n: int
    profile: str
    num_shards: int
    deployed: int
    waves_survived: int
    requests: int
    request_wall_s: float
    deploy_wall_s: float
    parity_batches: int
    verified: List[str]
    spot_checked: List[str]
    failures: List[StressFailure]

    @property
    def passed(self) -> bool:
        return not self.failures

    @property
    def qps(self) -> float:
        return self.requests / self.request_wall_s if self.request_wall_s else 0.0

    def summary(self) -> str:
        flag = "PASS" if self.passed else "FAIL"
        lines = [
            f"[{flag}] stress seed={self.seed} n={self.n} "
            f"profile={self.profile} shards={self.num_shards}: "
            f"{self.deployed} views deployed "
            f"({self.waves_survived} hot-deploy waves), "
            f"{self.requests} requests at {self.qps:.0f} req/s, "
            f"{self.parity_batches} flavour-parity probes, "
            f"{len(self.verified)} verified "
            f"({len(self.spot_checked)} spot checks)"
        ]
        lines += ["  " + f.summary() for f in self.failures]
        return "\n".join(lines)


def _sorted_batch(cols: Dict[str, np.ndarray], key: str, ts: str) -> Dict:
    order = np.lexsort((cols[ts], cols[key]))
    return {c: np.asarray(v)[order] for c, v in cols.items()}


def _slice(cols: Dict[str, np.ndarray], idx) -> Dict[str, np.ndarray]:
    return {c: np.asarray(v)[idx] for c, v in cols.items()}


def _rotate(seq: Sequence[str], k: int, count: int) -> List[str]:
    if not seq:
        return []
    k = k % len(seq)
    doubled = list(seq[k:]) + list(seq[:k])
    return doubled[: min(count, len(seq))]


def _repro_script(*, seed: int, n: int, profile: str, view: FeatureView,
                  data_rows: int, rows: int, device_routing: bool,
                  detail: str) -> str:
    spec = "\n".join(
        f"#   {render_sql(f, e, view.schema, view.database)}"
        for f, e in view.features.items()
    )
    flavour = "" if device_routing else " --host-routing"
    return (
        "#!/usr/bin/env bash\n"
        f"# Minimal repro: stress view {view.name} (v{view.version}) "
        f"failed offline==online verification.\n"
        f"#   seed={seed} n={n} profile={profile} "
        f"flavour={'device' if device_routing else 'host'}\n"
        f"#   {detail}\n"
        "# View spec:\n"
        f"{spec}\n"
        f"PYTHONPATH=src python -m repro.stress --repro "
        f"--seed {seed} --n {n} --profile {profile} "
        f"--view {view.name} --data-rows {data_rows} --rows {rows}"
        f"{flavour}\n"
    )


def _verify_one(view: FeatureView, tabs: Dict[str, Dict], rows: int, *,
                capacity: int, num_shards: Optional[int],
                device_routing: bool):
    """verify_view over a data prefix — the shrinker's unit of work."""
    prim = _slice(tabs["events"], slice(0, rows))
    tmax = int(prim["ts"][-1])
    secondary = {}
    sec_nk = {}
    for t in view.tables[1:]:
        sch = STRESS_DB.table(t)
        keep = np.asarray(tabs[t][sch.ts]) <= tmax
        secondary[t] = _slice(tabs[t], keep)
        if t == "items":
            sec_nk["items"] = NUM_ITEMS
    return verify_view(
        view,
        prim,
        num_keys=NUM_ENTITIES,
        capacity=capacity,
        secondary=secondary or None,
        secondary_num_keys=sec_nk or None,
        num_shards=num_shards,
        device_routing=device_routing,
    )


def run_stress(
    seed: int = 0,
    n: int = 16,
    profile: str = "default",
    *,
    num_shards: int = 8,
    waves: int = 2,
    wave_size: int = 3,
    rows: int = 1200,
    warm_frac: float = 0.6,
    batch: int = 64,
    verify_samples: int = 2,
    verify_rows: int = 480,
    force_fail: Sequence[str] = (),
    repro_dir: Optional[str] = ".",
    emit: Optional[Callable[[str], None]] = None,
) -> StressReport:
    """One full stress run; see the module docstring for the protocol.

    ``force_fail`` names views whose verification verdict is forced to
    FAIL — the switch that demonstrates the shrink-and-repro machinery
    end to end without planting a real bug.
    """
    say = emit or (lambda s: None)
    views = gen_views(seed, n, profile)
    kwargs = gen_store_kwargs(seed, n, profile)
    n_held = waves * wave_size
    if n_held >= n:
        raise ValueError(
            f"waves*wave_size={n_held} must leave initial views (n={n})"
        )
    initial, pending = views[: n - n_held], views[n - n_held:]
    if rows > T_MAX:
        raise ValueError(f"rows={rows} exceeds the unique-ts budget {T_MAX}")
    tabs = stress_stream(
        stress_rng(seed, n, profile, "data"),
        rows,
        num_entities=NUM_ENTITIES,
        num_items=NUM_ITEMS,
        t_max=T_MAX,
    )
    rng = stress_rng(seed, n, profile, "harness")
    failures: List[StressFailure] = []
    verified: List[str] = []
    spot_checked: List[str] = []
    parity_batches = 0
    requests = 0
    request_wall = 0.0

    # -- deploy ------------------------------------------------------------
    registry = FeatureRegistry()
    for v in initial:
        registry.register(v)
    t0 = time.perf_counter()
    svc = FeatureService.build_multi(
        "stress_plane",
        initial,
        num_keys=NUM_ENTITIES,
        registry=registry,
        sharded=True,
        num_shards=num_shards,
        **filter_table_knobs(kwargs, initial),
    )
    deploy_wall = time.perf_counter() - t0
    plane = svc.plane
    say(f"deployed {len(initial)} views on {num_shards} shards "
        f"in {deploy_wall:.1f}s")
    router = ShardRouter(svc, BatchScheduler(max_batch=batch), ingest=False)

    # Ingest log: the harness owns every state mutation (the router runs
    # ingest=False), so a dedicated store can replay the identical stream
    # for the bit-identity spot check, and BackfillSource waves are fed
    # exactly the ingested history.
    log: List[Tuple[str, Dict[str, np.ndarray]]] = []

    def ingest(table: str, cols: Dict[str, np.ndarray]) -> None:
        if not len(next(iter(cols.values()))):
            return
        sch = STRESS_DB.table(table)
        b = _sorted_batch(cols, sch.key, sch.ts)
        if table == STRESS_DB.primary.name:
            plane.ingest(b)
        else:
            plane.ingest_table(table, b)
        log.append((table, b))

    seen_tables = set()

    def ingest_new_tables() -> None:
        """Feed full history into tables the plane just started tracking
        (a hot-deployed view can reference a stream no prior view did)."""
        for t in plane.store._sec_names:
            if t not in seen_tables:
                seen_tables.add(t)
                ingest(t, tabs[t])

    ingest_new_tables()
    i_warm = int(rows * warm_frac)
    ingest("events", _slice(tabs["events"], slice(0, i_warm)))

    chunks = np.array_split(np.arange(i_warm, rows), waves + 1)

    def backfill_from_log() -> BackfillSource:
        hist: Dict[str, Dict[str, np.ndarray]] = {}
        for t, b in log:
            if t not in hist:
                hist[t] = {c: [v] for c, v in b.items()}
            else:
                for c, v in b.items():
                    hist[t][c].append(v)
        return BackfillSource(
            STRESS_DB,
            {t: {c: np.concatenate(vs) for c, vs in cols.items()}
             for t, cols in hist.items()},
        )

    def flavour_parity(idx: np.ndarray, phase: int) -> None:
        """Fused on-mesh answers vs the host-routed oracle, bit-for-bit,
        on identical read-only state."""
        nonlocal parity_batches
        scens = _rotate(plane.scenarios, 2 * phase, 4)
        probe = _slice(tabs["events"], idx[: min(64, len(idx))])
        m = len(probe["ts"])
        tags = np.array([scens[i % len(scens)] for i in range(m)])
        dev = plane.query_mixed(probe, tags)
        store = plane.store
        store.device_routing = False
        try:
            for s in scens:
                sel = tags == s
                if not sel.any():
                    continue
                host = plane.query(s, _slice(probe, sel))
                for f, hv in host.items():
                    dv = dev[s][f]
                    if not np.array_equal(np.asarray(dv), np.asarray(hv)):
                        failures.append(StressFailure(
                            view=s, stage="parity",
                            detail=f"feature {f!r}: fused != host oracle "
                                   f"(phase {phase})",
                        ))
        finally:
            store.device_routing = True
        parity_batches += 1

    def route_traffic(idx: np.ndarray, phase: int) -> None:
        """Mixed-scenario router traffic: the bulk under the fused device
        flavour, a tail slice re-routed through the host oracle."""
        nonlocal requests, request_wall
        scens = plane.scenarios
        cols = _slice(tabs["events"], idx)
        tags = [scens[int(t)] for t in rng.integers(len(scens), size=len(idx))]
        t0 = time.perf_counter()
        for i in range(len(idx)):
            router.submit({c: v[i] for c, v in cols.items()},
                          scenario=tags[i])
        router.drain()
        host_m = min(32, len(idx))
        host_scens = _rotate(scens, phase, 2)
        plane.store.device_routing = False
        try:
            for i in range(host_m):
                router.submit({c: v[i] for c, v in cols.items()},
                              scenario=host_scens[i % len(host_scens)])
            router.drain()
        finally:
            plane.store.device_routing = True
        request_wall += time.perf_counter() - t0
        requests += len(idx) + host_m

    def spot_check(phase: int) -> None:
        """plane == dedicated store, bit-for-bit: replay the ingest log
        into a fresh single-view store and compare one view's answers."""
        scen = _rotate(plane.scenarios, phase, 1)[0]
        view = plane.views[scen]
        dedicated = OnlineFeatureStore.create(
            view,
            num_keys=NUM_ENTITIES,
            **filter_table_knobs(kwargs, [view]),
        )
        ded_tables = set(dedicated._sec_names)
        for t, b in log:
            if t == STRESS_DB.primary.name:
                dedicated.ingest(b)
            elif t in ded_tables:
                dedicated.ingest_table(t, b)
        n_ev = len(tabs["events"]["ts"])
        idx = rng.choice(n_ev, size=min(48, n_ev), replace=False)
        probe = _slice(tabs["events"], np.sort(idx))
        a = plane.query(scen, probe)
        b = dedicated.query(probe)
        for f in view.features:
            if not np.array_equal(np.asarray(a[f]), np.asarray(b[f])):
                failures.append(StressFailure(
                    view=scen, stage="spot",
                    detail=f"feature {f!r}: plane != dedicated store "
                           f"(phase {phase})",
                ))
                return
        spot_checked.append(scen)

    def shrink(view: FeatureView, flag: bool, detail: str,
               forced: bool) -> StressFailure:
        """Re-run the failing view in isolation on a halving data prefix,
        then emit the minimal runnable repro."""
        def fails(r: int) -> bool:
            if forced:
                return True
            return not _verify_one(
                view, tabs, r, capacity=kwargs["capacity"],
                num_shards=num_shards, device_routing=flag,
            ).passed

        r = min(verify_rows, rows)
        while r > 64 and fails(r // 2):
            r //= 2
        script = _repro_script(
            seed=seed, n=n, profile=profile, view=view,
            data_rows=rows, rows=r, device_routing=flag, detail=detail,
        )
        path = None
        if repro_dir is not None:
            import os

            path = os.path.join(repro_dir, f"stress_repro_{view.name}.sh")
            with open(path, "w") as fh:
                fh.write(script)
        return StressFailure(
            view=view.name, stage="verify", detail=detail,
            shrunk_rows=r, repro_path=path,
        )

    verify_i = 0

    def sampled_verify(phase: int) -> None:
        """Seeded rotating subset, alternating routing flavours."""
        nonlocal verify_i
        for s in _rotate(plane.scenarios, phase * verify_samples,
                         verify_samples):
            view = plane.views[s]
            flag = verify_i % 2 == 0
            verify_i += 1
            forced = view.name in force_fail
            rep = _verify_one(
                view, tabs, min(verify_rows, rows),
                capacity=kwargs["capacity"], num_shards=num_shards,
                device_routing=flag,
            )
            if rep.passed and not forced:
                verified.append(f"{s}:{rep.mode}")
                say(f"  verify {rep.summary()}")
            else:
                detail = (
                    "forced failure (--force-fail)" if forced else
                    f"max_abs={rep.max_abs_err:.3e} "
                    f"max_rel={rep.max_rel_err:.3e} mode={rep.mode}"
                )
                failures.append(shrink(view, flag, detail, forced))
                say(f"  verify FAIL {s}: {detail}")

    # -- the churn loop ----------------------------------------------------
    waves_survived = 0
    for phase in range(waves + 1):
        say(f"phase {phase}: {len(plane.scenarios)} live scenarios, "
            f"{len(chunks[phase])} traffic rows")
        flavour_parity(chunks[phase], phase)
        route_traffic(chunks[phase], phase)
        ingest("events", _slice(tabs["events"], chunks[phase]))
        spot_check(phase)
        sampled_verify(phase)
        if phase < waves:
            wave, pending = pending[:wave_size], pending[wave_size:]
            use_backfill = phase % 2 == 1
            src = backfill_from_log() if use_backfill else None
            refused = 0
            for v in wave:
                knobs = filter_table_knobs(
                    {k: kwargs[k] for k in
                     ("table_capacity", "table_ttl",
                      "secondary_num_keys")},
                    list(plane.views.values()) + [v],
                )
                t0 = time.perf_counter()
                try:
                    mig = svc.hot_deploy(v, backfill=src, **knobs)
                except ValueError as e:
                    # no-backfill waves are EXPECTED to hit the loud
                    # refusal for views whose new lanes (hash/signature,
                    # aged-out rows) can't be synthesized from stored f32
                    # history — that refusal IS the migration contract.
                    # Retry with the exact-history source; anything else
                    # (or a refusal that names no backfill remedy, or one
                    # on a wave that already HAD backfill) is a failure.
                    if src is not None or "backfill" not in str(e):
                        failures.append(StressFailure(
                            view=v.name, stage="deploy",
                            detail=f"hot-deploy raised "
                                   f"(backfill={use_backfill}): {e}",
                        ))
                        deploy_wall += time.perf_counter() - t0
                        continue
                    refused += 1
                    mig = svc.hot_deploy(
                        v, backfill=backfill_from_log(), **knobs)
                deploy_wall += time.perf_counter() - t0
                if not mig.exact:
                    failures.append(StressFailure(
                        view=v.name, stage="deploy",
                        detail=f"inexact hot-deploy migration "
                               f"(backfill={use_backfill}): "
                               f"{'; '.join(mig.notes) or mig.diff_summary}",
                    ))
            ingest_new_tables()
            waves_survived += 1
            say(f"  wave {phase + 1}: +{len(wave)} views "
                f"(backfill={use_backfill}, "
                f"refused-then-backfilled={refused})")

    return StressReport(
        seed=seed, n=n, profile=profile, num_shards=num_shards,
        deployed=len(plane.scenarios), waves_survived=waves_survived,
        requests=requests, request_wall_s=request_wall,
        deploy_wall_s=deploy_wall, parity_batches=parity_batches,
        verified=verified, spot_checked=spot_checked, failures=failures,
    )


def run_repro(*, seed: int, n: int, profile: str, view_name: str,
              data_rows: int, rows: int, device_routing: bool,
              num_shards: int = 8) -> "ConsistencyReport":
    """Re-run one generated view's verification exactly as the harness
    did — the target of the emitted minimal repro script."""
    views = {v.name: v for v in gen_views(seed, n, profile)}
    if view_name not in views:
        raise KeyError(f"no generated view {view_name!r} at "
                       f"(seed={seed}, n={n}, profile={profile!r})")
    tabs = stress_stream(
        stress_rng(seed, n, profile, "data"),
        data_rows,
        num_entities=NUM_ENTITIES,
        num_items=NUM_ITEMS,
        t_max=T_MAX,
    )
    kwargs = gen_store_kwargs(seed, n, profile)
    return _verify_one(
        views[view_name], tabs, min(rows, data_rows),
        capacity=kwargs["capacity"], num_shards=num_shards,
        device_routing=device_routing,
    )
