"""Seeded generator of 100+ feature views over the full expr IR surface.

``gen_views(seed, n, profile)`` deterministically samples every ``Agg``
(the round-robin lead feature guarantees all ten appear for n >= 10),
both window modes with varied sizes, WINDOW UNIONs over shared streams
(drawn from a fixed shared-lane pool so the plane's CSE / shared-ingest
accounting is genuinely stressed), multi-table LAST JOINs against shared
dimension tables (including the dual-use refunds table: union stream and
join target at once, which forces the planner's dual-use ring split),
Signature/Hash lanes, and ``FeatureView.evolve`` chains.

Determinism contract (the PR 2 flake class): every sampling decision
flows from ONE named ``np.random.Generator`` seeded through
``np.random.SeedSequence`` with ``zlib.crc32`` for the string inputs —
no ``hash()``, no global numpy state — so ``gen_views(seed, n)`` is
byte-identical across processes (asserted in tier-1).

Generated views obey the store's physical contracts so the harness can
hold exact equalities rather than loose tolerances:

* range windows span <= 1800s with the canonical 64s bucket, so every
  query stays inside the default 64-bucket retention and, with
  ``T_MAX`` < num_buckets * bucket_size, the bucket ring never wraps
  (the same no-wrap discipline as the multi-table test fixtures);
* rows windows stay <= 32 < the 256-row ring capacity, and the matched
  ``stress_stream`` data keeps per-key row counts below capacity, so
  hot-deploy migrations synthesize new lanes exactly from ring history;
* union window arguments only reference columns present in every unioned
  table (``amount`` everywhere; ``quantity`` when the union is
  refunds-only), per the IR validation rule;
* ``table_ttl`` knobs are only aggressive on union-only streams — a TTL
  below ``T_MAX`` on a join target would diverge from the TTL-blind
  offline engine.
"""

from __future__ import annotations

import dataclasses
import hashlib
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.expr import (
    Agg,
    Col,
    Expr,
    Hash,
    Signature,
    WindowAgg,
    collect_last_joins,
    collect_window_aggs,
    last_join,
    range_window,
    rows_window,
)
from repro.core.view import FeatureView, render_sql
from repro.data.synthetic import STRESS_DB

__all__ = [
    "NUM_ENTITIES",
    "NUM_ITEMS",
    "T_MAX",
    "PROFILES",
    "stress_rng",
    "gen_views",
    "gen_store_kwargs",
    "filter_table_knobs",
    "view_fingerprint",
    "summarize_views",
    "render_summary_md",
]

# Matched data-generation geometry (repro.data.synthetic.stress_stream):
# T_MAX < num_buckets * bucket_size = 4096 keeps the bucket ring unwrapped.
NUM_ENTITIES = 48
NUM_ITEMS = 24
T_MAX = 3800

_BUCKET = 64
_RANGE_SIZES = (128, 256, 512, 900, 1800)
_ROWS_SIZES = (4, 8, 12, 20, 32)
_UNION_COMBOS = (("refunds",), ("clicks",), ("refunds", "clicks"))

# Round-robin lead-feature ring: view i's first feature aggregates with
# AGG_RING[i % 10], so any n >= 10 covers the whole Agg enum.
_AGG_RING = (
    Agg.SUM,
    Agg.COUNT,
    Agg.MEAN,
    Agg.MIN,
    Agg.MAX,
    Agg.STD,
    Agg.DISTINCT_APPROX,
    Agg.LAST,
    Agg.FIRST,
    Agg.TOPN_FREQ,
)
_INTISH_AGGS = (Agg.DISTINCT_APPROX, Agg.TOPN_FREQ)

_TAG = zlib.crc32(b"repro.stress")


@dataclasses.dataclass(frozen=True)
class Profile:
    """Sampling weights for one generation profile."""

    p_rows: float      # rows_window share of non-union windows
    p_union: float     # window-union share of waggs
    p_shared: float    # draw the wagg from the shared CSE pool
    p_join: float      # feature is (or composes) a LAST JOIN
    p_sig: float       # feature is a row-level Signature/Hash lane
    p_ratio: float     # feature is a wagg/wagg or wagg/join composite
    p_evolve: float    # view grows an evolve() version bump
    p_dual: float      # join targets the dual-use refunds stream


PROFILES: Dict[str, Profile] = {
    # the balanced default — every IR construct at a realistic mix
    "default": Profile(0.30, 0.35, 0.30, 0.25, 0.12, 0.18, 0.25, 0.10),
    # window-heavy: no joins, dense agg/window variety (the shared CSE
    # pool still contributes its union lanes)
    "windows": Profile(0.45, 0.00, 0.35, 0.00, 0.10, 0.25, 0.15, 0.00),
    # relational-heavy: unions + joins dominate, incl. dual-use refunds
    "relational": Profile(0.15, 0.55, 0.30, 0.40, 0.08, 0.15, 0.25, 0.20),
}


def stress_rng(seed: int, n: int, profile: str, stage: str) -> np.random.Generator:
    """The one named generator: every stress sampling path (views, knobs,
    data, harness decisions) derives from this SeedSequence — crc32 for
    the string components, never ``hash()``."""
    return np.random.default_rng(
        np.random.SeedSequence(
            [
                _TAG,
                int(seed),
                int(n),
                zlib.crc32(profile.encode()),
                zlib.crc32(stage.encode()),
            ]
        )
    )


def _pick(rng: np.random.Generator, seq: Sequence):
    return seq[int(rng.integers(len(seq)))]


def shared_pool() -> Tuple[WindowAgg, ...]:
    """Fixed cross-view shared lanes — identical structural keys across
    many views, so the planner's CSE and the plane's shared-ingest
    accounting are exercised at scale (deliberately, per the paper's
    multi-scenario reuse claim)."""
    amt = Col("amount")
    w18 = range_window(1800, bucket=_BUCKET)
    w9 = range_window(900, bucket=_BUCKET)
    return (
        WindowAgg(Agg.SUM, amt, w18, union=("refunds",)),
        WindowAgg(Agg.COUNT, amt, w18, union=("refunds",)),
        WindowAgg(Agg.MEAN, amt, w9, union=("refunds", "clicks")),
        WindowAgg(Agg.SUM, amt, w9),
        WindowAgg(Agg.MAX, amt, w9, union=("clicks",)),
        WindowAgg(Agg.DISTINCT_APPROX, Hash(amt, bits=6, salt=1), w18),
    )


_POOL = shared_pool()


def _num_arg(rng: np.random.Generator) -> Expr:
    """Row-level numeric argument over primary columns."""
    amt, qty, sc = Col("amount"), Col("quantity"), Col("score")
    return _pick(
        rng,
        (
            amt,
            qty,
            sc,
            amt * qty,
            amt > 100.0,
            amt.log1p(),
            amt + sc * 10.0,
        ),
    )


def _int_arg(rng: np.random.Generator) -> Expr:
    """Integer-valued argument (DISTINCT_APPROX / TOPN_FREQ lanes)."""
    k = int(rng.integers(3))
    if k == 0:
        return Col("item")
    if k == 1:
        return Hash(Col("item"), bits=8, salt=int(rng.integers(16)))
    return Signature(
        (Col("entity"), Col("item")), bits=10, salt=int(rng.integers(16))
    )


def _union_arg(rng: np.random.Generator, union: Tuple[str, ...],
               intish: bool) -> Expr:
    """Union window argument — columns must exist in the primary AND every
    unioned table: ``amount`` always does; ``quantity`` only when the
    union is refunds-only (clicks carries just ``amount``)."""
    cols: List[Expr] = [Col("amount")]
    if union == ("refunds",):
        cols.append(Col("quantity"))
    base = _pick(rng, cols)
    if intish:
        return Hash(base, bits=6, salt=int(rng.integers(16)))
    return _pick(rng, (base, base > 50.0, base.log1p()))


def _window(rng: np.random.Generator, p: Profile,
            force_range: bool = False) -> "WindowSpec":
    if not force_range and rng.random() < p.p_rows:
        return rows_window(_pick(rng, _ROWS_SIZES))
    return range_window(_pick(rng, _RANGE_SIZES), bucket=_BUCKET)


def _wagg(rng: np.random.Generator, p: Profile,
          agg: Optional[Agg] = None) -> WindowAgg:
    if agg is None and rng.random() < p.p_shared:
        return _pick(rng, _POOL)
    agg = agg if agg is not None else _pick(rng, _AGG_RING)
    union: Tuple[str, ...] = ()
    if rng.random() < p.p_union:
        union = _pick(rng, _UNION_COMBOS)
    window = _window(rng, p, force_range=bool(union))
    intish = agg in _INTISH_AGGS
    if union:
        arg = _union_arg(rng, union, intish)
    elif intish:
        arg = _int_arg(rng)
    else:
        arg = _num_arg(rng)
    nn = int(rng.integers(3)) if agg is Agg.TOPN_FREQ else 1
    return WindowAgg(agg, arg, window, n=nn, union=union)


def _join(rng: np.random.Generator, p: Profile) -> Expr:
    """LAST JOIN feature: dimension tables (profiles on entity, items on
    item) plus — with ``p_dual`` — the refunds stream, making refunds a
    dual-use table (union source AND join target) that forces the
    planner's ring split."""
    if rng.random() < p.p_dual:
        arg = _pick(rng, (Col("amount"), Col("quantity")))
        return last_join(arg, "refunds", on="entity", default=0.0)
    if rng.random() < 0.5:
        arg = _pick(
            rng,
            (Col("tier"), Col("spend_limit"), Col("spend_limit") - Col("tier")),
        )
        return last_join(arg, "profiles", on="entity", default=1.0)
    arg = _pick(
        rng,
        (
            Col("base_price"),
            Col("popularity"),
            Col("base_price") * Col("popularity"),
        ),
    )
    return last_join(arg, "items", on="item", default=5.0)


def _rowlevel(rng: np.random.Generator) -> Expr:
    k = int(rng.integers(3))
    if k == 0:
        return Signature(
            (Col("entity"), Col("item"), Col("amount")),
            bits=16,
            salt=int(rng.integers(16)),
        )
    if k == 1:
        return Hash(Col("amount"), bits=12, salt=int(rng.integers(16)))
    return (Col("amount") > 150.0) * Col("quantity")


def _feature(rng: np.random.Generator, p: Profile) -> Expr:
    r = rng.random()
    if r < p.p_join:
        j = _join(rng, p)
        if rng.random() < 0.4:
            # spend-vs-limit style composite: window agg over a join floor
            return _wagg(rng, p) / (j.abs() + 1.0)
        return j
    if r < p.p_join + p.p_sig:
        return _rowlevel(rng)
    if r < p.p_join + p.p_sig + p.p_ratio:
        a, b = _wagg(rng, p), _wagg(rng, p)
        return a / (b.abs() + 1.0) if rng.random() < 0.7 else a - b
    return _wagg(rng, p)


def _gen_one(rng: np.random.Generator, i: int, p: Profile,
             profile: str) -> FeatureView:
    lead = _AGG_RING[i % len(_AGG_RING)]
    feats: Dict[str, Expr] = {
        f"f0_{lead.value.lower()}": _wagg(rng, p, agg=lead)
    }
    for j in range(1, 2 + int(rng.integers(4))):  # 2..5 features total
        feats[f"f{j}"] = _feature(rng, p)
    view = FeatureView(
        name=f"gen_v{i:03d}",
        features=feats,
        database=STRESS_DB,
        description=f"generated stress scenario #{i} (profile {profile})",
    )
    while view.version < 3 and rng.random() < p.p_evolve:
        view = view.evolve(
            {f"evo{view.version}": _wagg(rng, p)},
            description=view.description,
        )
    return view


def gen_views(seed: int, n: int, profile: str = "default") -> List[FeatureView]:
    """The generator: ``n`` deterministic views for ``(seed, profile)``.

    Byte-identical across processes — fingerprint with
    :func:`view_fingerprint` to assert it.
    """
    if profile not in PROFILES:
        raise KeyError(
            f"unknown profile {profile!r}; one of {sorted(PROFILES)}"
        )
    p = PROFILES[profile]
    rng = stress_rng(seed, n, profile, "views")
    return [_gen_one(rng, i, p, profile) for i in range(n)]


def gen_store_kwargs(seed: int, n: int, profile: str = "default") -> Dict:
    """Matched physical-plan knobs for a generated plane.

    Capacities stay above the matched data's per-key row counts (exact
    migrations, no ring eviction); the aggressive TTL lands only on the
    union-only ``clicks`` stream (a TTL below ``T_MAX`` on a join target
    would diverge from the TTL-blind offline engine), while the refunds
    TTL sits above ``T_MAX`` so the knob is exercised but inert.
    """
    rng = stress_rng(seed, n, profile, "knobs")
    return dict(
        capacity=256,
        num_buckets=64,
        bucket_size=_BUCKET,
        secondary_num_keys={"items": NUM_ITEMS},
        table_capacity={
            "refunds": int(_pick(rng, (192, 256))),
            "clicks": int(_pick(rng, (128, 256))),
            "profiles": 64,
            "items": 64,
        },
        table_ttl={
            "clicks": int(_pick(rng, (2400, 3200))),
            "refunds": int(T_MAX + 200),
        },
    )


def filter_table_knobs(kwargs: Dict, views: Sequence[FeatureView]) -> Dict:
    """Restrict per-table knobs to tables the given views reference — the
    layout planner rejects knob entries for tables outside the plan."""
    tabs = {t for v in views for t in v.tables}
    out = dict(kwargs)
    for k in ("table_capacity", "table_ttl", "secondary_num_keys"):
        if out.get(k):
            out[k] = {t: c for t, c in out[k].items() if t in tabs}
    return out


# ---------------------------------------------------------------------------
# Determinism fingerprint + scale-aware summary (catalog consumes these)
# ---------------------------------------------------------------------------


def view_fingerprint(views: Sequence[FeatureView]) -> str:
    """sha256 over names, versions, structural expr keys and rendered SQL
    — the byte-identity witness for the two-process determinism test."""
    h = hashlib.sha256()
    for v in views:
        h.update(f"{v.name}:{v.version}\n".encode())
        for fname, expr in v.features.items():
            h.update(f"{fname}={expr.key!r}\n".encode())
            h.update(render_sql(fname, expr, v.schema, v.database).encode())
            h.update(b"\n")
    return h.hexdigest()


def summarize_views(views: Sequence[FeatureView]) -> Dict:
    """Deterministic structural census of a generated view set."""
    exprs = [e for v in views for e in v.features.values()]
    waggs = collect_window_aggs(exprs)
    per_view_waggs = sum(
        len(collect_window_aggs(list(v.features.values()))) for v in views
    )
    aggs: Dict[str, int] = {a.value: 0 for a in Agg}
    rows_w = range_w = 0
    unions: Dict[str, int] = {}
    for wa in waggs.values():
        aggs[wa.agg.value] += 1
        if wa.window.mode == "rows":
            rows_w += 1
        else:
            range_w += 1
        if wa.union:
            unions["+".join(wa.union)] = unions.get("+".join(wa.union), 0) + 1
    joins: Dict[str, int] = {}
    for lj in collect_last_joins(exprs).values():
        joins[lj.table] = joins.get(lj.table, 0) + 1
    tables = sorted({t for v in views for t in v.tables})
    return {
        "n_views": len(views),
        "n_evolved": sum(1 for v in views if v.version > 1),
        "n_features": sum(len(v.features) for v in views),
        "distinct_waggs": len(waggs),
        "per_view_waggs": per_view_waggs,
        "aggs": {a: c for a, c in sorted(aggs.items())},
        "rows_windows": rows_w,
        "range_windows": range_w,
        "unions": dict(sorted(unions.items())),
        "joins": dict(sorted(joins.items())),
        "tables": tables,
    }


def render_summary_md(views: Sequence[FeatureView], *, seed: int, n: int,
                      profile: str) -> str:
    """Markdown summary for the catalog — scale-aware: a census plus a
    few sample entries instead of 100+ full pages."""
    s = summarize_views(views)
    cse = s["per_view_waggs"] - s["distinct_waggs"]
    lines = [
        f"`gen_views(seed={seed}, n={n}, profile={profile!r})` — "
        "deterministic, byte-identical across processes.",
        "",
        "| metric | value |",
        "|---|---|",
        f"| views (evolved ≥v2) | {s['n_views']} ({s['n_evolved']}) |",
        f"| features | {s['n_features']} |",
        f"| distinct window-agg lanes | {s['distinct_waggs']} "
        f"({cse} deduplicated across views) |",
        f"| windows rows / range | {s['rows_windows']} / "
        f"{s['range_windows']} |",
        "| per-Agg lanes | "
        + ", ".join(f"{a} {c}" for a, c in s["aggs"].items())
        + " |",
        "| union windows | "
        + (
            ", ".join(f"{u} {c}" for u, c in s["unions"].items())
            or "none"
        )
        + " |",
        "| LAST JOINs | "
        + (
            ", ".join(f"{t} {c}" for t, c in s["joins"].items())
            or "none"
        )
        + " |",
        f"| source tables | {', '.join(s['tables'])} |",
        "",
        "Sample entries:",
        "",
    ]
    for v in views[:3]:
        fname, expr = next(iter(v.features.items()))
        sql = render_sql(fname, expr, v.schema, v.database)
        lines.append(
            f"- `{v.name}` v{v.version}, {len(v.features)} features — "
            f"`{sql}`"
        )
    return "\n".join(lines)
