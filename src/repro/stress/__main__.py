"""CLI for the stress suite.

Smoke (CI gate — 16 views, 8 shards, 2 hot-deploy waves, fixed seed)::

    PYTHONPATH=src python -m repro.stress --smoke

Full sweep / custom runs::

    PYTHONPATH=src python -m repro.stress --n 128 --seed 0
    PYTHONPATH=src python -m repro.stress --smoke --force-fail gen_v003

Minimal repro (the harness emits these on verification failure)::

    PYTHONPATH=src python -m repro.stress --repro --seed 0 --n 16 \\
        --view gen_v003 --data-rows 1200 --rows 150 [--host-routing]
"""

from __future__ import annotations

import argparse
import sys

from repro.stress.harness import run_repro, run_stress


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.stress",
        description="scenario-explosion stress suite",
    )
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: N=16, fixed seed, 8 shards, 2 waves")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--profile", default="default")
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--waves", type=int, default=2)
    ap.add_argument("--wave-size", type=int, default=3)
    ap.add_argument("--rows", type=int, default=None,
                    help="primary stream rows (repro mode: verify prefix)")
    ap.add_argument("--verify-samples", type=int, default=2,
                    help="views verified per phase (rotating subset)")
    ap.add_argument("--force-fail", action="append", default=[],
                    metavar="VIEW",
                    help="force this view's verification to FAIL "
                         "(demonstrates shrink + minimal-repro emission)")
    ap.add_argument("--repro", action="store_true",
                    help="re-run one view's verification (emitted scripts)")
    ap.add_argument("--view", help="repro: generated view name")
    ap.add_argument("--data-rows", type=int, default=1200,
                    help="repro: full stream size the harness generated")
    ap.add_argument("--host-routing", action="store_true",
                    help="repro: verify under the host-routed oracle")
    args = ap.parse_args(argv)

    if args.repro:
        if not args.view:
            ap.error("--repro requires --view")
        rep = run_repro(
            seed=args.seed, n=args.n, profile=args.profile,
            view_name=args.view, data_rows=args.data_rows,
            rows=args.rows or args.data_rows,
            device_routing=not args.host_routing, num_shards=args.shards,
        )
        print(rep.summary())
        return 0 if rep.passed else 1

    n = 16 if args.smoke else args.n
    rows = args.rows or 1200
    report = run_stress(
        seed=args.seed, n=n, profile=args.profile,
        num_shards=args.shards, waves=args.waves,
        wave_size=args.wave_size, rows=rows,
        verify_samples=args.verify_samples,
        force_fail=tuple(args.force_fail),
        emit=print,
    )
    print(report.summary())
    return 0 if report.passed else 1


if __name__ == "__main__":
    sys.exit(main())
