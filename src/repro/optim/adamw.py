"""AdamW with fp32 master weights and ZeRO-1-style state sharding.

State = {master, m, v, step}: master/m/v are fp32 copies shaped like the
params.  Sharding: each state leaf inherits the param's (model-axis) spec
*plus* the first free dim divisible by the data-axis size is sharded over
"data" (see sharding/params.zero1_spec) — optimizer math is elementwise, so
any extra sharding is free, and it divides optimizer memory by |data|.

Gradient compression (int8 error feedback) lives in optim/compress.py and
wraps the update when enabled.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 200
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr_peak * warm * (cfg.lr_min / cfg.lr_peak + (1 - cfg.lr_min / cfg.lr_peak) * cos)


def adamw_init(params) -> Dict:
    # copy=True: when params are already f32 (tests/CPU examples) a plain
    # astype aliases the param buffer, and donating params+opt_state to the
    # same jit call would donate one buffer twice.
    f32 = lambda t: jax.tree.map(
        lambda x: jnp.array(x, dtype=jnp.float32, copy=True), t
    )
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {
        "master": f32(params),
        "m": zeros(params),
        "v": zeros(params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree_util.tree_leaves(tree))
    )


def adamw_update(
    cfg: AdamWConfig,
    grads,                 # pytree, any float dtype
    opt_state: Dict,
    param_dtype,
) -> Tuple[Any, Dict, Dict]:
    """Returns (new_params (param_dtype), new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = cosine_lr(cfg, step.astype(jnp.float32))

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        p_new = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * p)
        return m_new, v_new, p_new

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(lambda x: x.astype(param_dtype), new_master)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {
        "master": new_master, "m": new_m, "v": new_v, "step": step
    }, metrics
