"""int8 error-feedback gradient compression (cross-pod DP traffic).

At multi-pod scale the pod-axis gradient all-reduce crosses DCN-class
links (~25x slower than ICI); compressing the cross-pod reduction 4x
(f32->int8 with per-block scales) cuts that term proportionally.  Error
feedback keeps the quantization bias out of the optimization trajectory:
the residual (g - dequant(quant(g))) is added to the next step's gradient.

Used by train.step when ``compress_pod_grads=True``; unit-tested for the
error-feedback contract in tests/test_train.py.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ef_compress_tree"]

_BLOCK = 256


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8 quantization. Returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(
    q: jnp.ndarray, scale: jnp.ndarray, shape, dtype=jnp.float32
) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def ef_compress_tree(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """Quantize (grads + residual) leaf-wise; return (dequantized grads for
    the optimizer, new residuals).  The round-trip models what the wire
    carries; on real multi-pod hardware the int8 payload is what crosses
    the pod axis (psum of int32-accumulated int8 blocks)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s, g.shape)
        return deq, gf - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )
