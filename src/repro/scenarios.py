"""Canonical example scenarios — the repo's mirror of FeatInsight's
"100+ real-world scenarios on one platform" claim.

One module owns every example feature view so the docs stay honest: the
feature catalog (``python -m repro.catalog`` → ``docs/CATALOG.md``), the
README scenarios table, the benchmarks, and the multi-scenario tests all
build their views from here.  Each :class:`Scenario` records what a
platform catalog would: the view definition(s), the workload it models,
and the command that runs it.

The ``multi_scenario`` entry is the consolidation story: three views that
share a WINDOW UNION stream (``wires``) and LAST JOIN dimension tables
(``accounts``, ``merchants``), deployed together on one
:class:`~repro.core.scenario.ScenarioPlane` — shared tables ingested once,
answers bit-identical to three dedicated stores.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

from repro.core import (
    Col,
    FeatureView,
    Signature,
    last_join,
    range_window,
    rows_window,
    w_count,
    w_distinct_approx,
    w_max,
    w_mean,
    w_std,
    w_sum,
)
from repro.data.synthetic import FRAUD_SCHEMA, MULTITABLE_DB, RECO_SCHEMA

__all__ = [
    "Scenario",
    "SCENARIOS",
    "GeneratedFamily",
    "GENERATED",
    "fraud_view",
    "reco_view",
    "multi_table_view",
    "sharded_view",
    "multi_scenario_views",
]


# ---------------------------------------------------------------------------
# View builders (one per deployed scenario)
# ---------------------------------------------------------------------------


def fraud_view() -> FeatureView:
    """§3.3 fraud detection: trailing spend windows over card transactions."""
    amt = Col("amount")
    w1h, w6h = range_window(3600, bucket=64), range_window(21600, bucket=64)
    return FeatureView(
        name="fraud_features",
        schema=FRAUD_SCHEMA,
        description="card-fraud spend windows (§3.3 latency benchmark view)",
        features={
            "amt_sum_1h": w_sum(amt, w1h),
            "amt_mean_1h": w_mean(amt, w1h),
            "amt_std_1h": w_std(amt, w1h),
            "tx_count_1h": w_count(amt, w1h),
            "amt_sum_6h": w_sum(amt, w6h),
            "amt_max_6h": w_max(amt, w6h),
            "tx_count_50": w_count(amt, rows_window(50)),
            "big_ratio_1h": w_count(amt > 100.0, w1h)
            / (1.0 + w_count(amt, w1h)),
        },
    )


def reco_view() -> FeatureView:
    """§3.2 product recommendation: hourly activity + a user×product cross."""
    spend = Col("price") * Col("qty")
    return FeatureView(
        name="user_activity",
        schema=RECO_SCHEMA,
        description="hourly order activity + user-product signature cross",
        features={
            "spend_1h": w_sum(spend, range_window(3600, bucket=64)),
            "orders_1h": w_count(spend, range_window(3600, bucket=64)),
            "avg_price_20": w_mean(Col("price"), rows_window(20)),
            "cross_user_prod": Signature(
                (Col("user"), Col("product")), bits=20
            ),
        },
    )


def multi_table_view() -> FeatureView:
    """§1 multi-table plane: profile LAST JOINs + cross-stream union windows."""
    amt = Col("amount")
    w1h = range_window(3600, bucket=64)
    credit = last_join(
        Col("credit_limit"), "accounts", on="account", default=1000.0
    )
    return FeatureView(
        name="fraud_multitable",
        description="cross-table fraud features: profile joins + union windows",
        features={
            "credit_limit": credit,
            "acct_risk": last_join(
                Col("risk_score"), "accounts", on="account", default=0.5
            ),
            "merchant_reports": last_join(
                Col("fraud_reports"), "merchants", on="merchant"
            ),
            "outflow_sum_1h": w_sum(amt, w1h, union=("wires",)),
            "outflow_cnt_1h": w_count(amt, w1h, union=("wires",)),
            "outflow_mean_1h": w_mean(amt, w1h, union=("wires",)),
            "limit_utilization": w_sum(amt, w1h, union=("wires",)) / credit,
            "big_vs_limit": (amt / credit) > 0.5,
        },
        database=MULTITABLE_DB,
    )


def sharded_view() -> FeatureView:
    """Sharded serving of cross-table fraud features on a device mesh."""
    amt = Col("amount")
    w1h = range_window(3600, bucket=64)
    credit = last_join(
        Col("credit_limit"), "accounts", on="account", default=1000.0
    )
    return FeatureView(
        name="fraud_sharded",
        description="sharded serving of cross-table fraud features",
        features={
            "credit_limit": credit,
            "merchant_ticket": last_join(
                Col("avg_ticket"), "merchants", on="merchant", default=50.0
            ),
            "outflow_1h": w_sum(amt, w1h, union=("wires",)),
            "outflow_cnt_1h": w_count(amt, w1h, union=("wires",)),
            "spend_mean_1h": w_mean(amt, w1h),
            "utilization": w_sum(amt, w1h, union=("wires",)) / credit,
        },
        database=MULTITABLE_DB,
    )


def multi_scenario_views() -> List[FeatureView]:
    """Three scenarios for one :class:`~repro.core.scenario.ScenarioPlane`.

    Deliberately overlapping so consolidation has something to share:
    ``wires`` is WINDOW UNIONed by *acct_risk* and *spend_profile* (and
    the 1h outflow sum is the same structural wagg — one shared lane),
    ``accounts`` is LAST JOINed by *acct_risk* and *merchant_watch*, and
    ``merchants`` by *spend_profile* and *merchant_watch*.
    """
    amt = Col("amount")
    w1h = range_window(3600, bucket=64)
    w6h = range_window(21600, bucket=64)
    outflow_1h = w_sum(amt, w1h, union=("wires",))
    credit = last_join(
        Col("credit_limit"), "accounts", on="account", default=1000.0
    )
    acct_risk = FeatureView(
        name="acct_risk",
        description="account risk: credit utilization over merged outflows",
        features={
            "credit_limit": credit,
            "outflow_1h": outflow_1h,
            "outflow_cnt_1h": w_count(amt, w1h, union=("wires",)),
            "utilization_1h": outflow_1h / credit,
            "overdraft_now": (amt / credit) > 0.5,
        },
        database=MULTITABLE_DB,
    )
    spend_profile = FeatureView(
        name="spend_profile",
        description="spending profile: per-account spend shape vs merchant",
        features={
            "outflow_1h": outflow_1h,  # shared lane with acct_risk
            "outflow_mean_6h": w_mean(amt, w6h, union=("wires",)),
            "spend_std_6h": w_std(amt, w6h),
            "merchant_ticket": last_join(
                Col("avg_ticket"), "merchants", on="merchant", default=50.0
            ),
            "tx_count_10": w_count(amt, rows_window(10)),
        },
        database=MULTITABLE_DB,
    )
    merchant_watch = FeatureView(
        name="merchant_watch",
        description="merchant watchlist: reports + account risk exposure",
        features={
            "acct_risk_score": last_join(
                Col("risk_score"), "accounts", on="account", default=0.5
            ),
            "merchant_reports": last_join(
                Col("fraud_reports"), "merchants", on="merchant"
            ),
            "merchants_seen_6h": w_distinct_approx(Col("merchant"), w6h),
            "spend_max_6h": w_max(amt, w6h),
        },
        database=MULTITABLE_DB,
    )
    return [acct_risk, spend_profile, merchant_watch]


# ---------------------------------------------------------------------------
# The scenario registry (what a platform catalog page lists)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One deployed example scenario: its views, workload, and run command.

    ``hot_deployed`` names the views this scenario deploys onto the LIVE
    plane via ``MultiScenarioService.hot_deploy`` (rather than at launch)
    — the catalog's deploy history records them as hot deploys, matching
    what the example actually does.  ``exported`` names the views whose
    example also exports a point-in-time training set from the same
    definitions (``repro.offline.export_training_set``) — the catalog's
    deploy history records that lineage under an ``export:`` service,
    exactly as a registry-carrying export call would.
    """

    name: str
    title: str
    description: str
    run: str
    views: Callable[[], List[FeatureView]]
    hot_deployed: tuple = ()
    exported: tuple = ()


def _one(builder: Callable[[], FeatureView]) -> Callable[[], List[FeatureView]]:
    return lambda: [builder()]


SCENARIOS: Dict[str, Scenario] = {
    s.name: s
    for s in (
        Scenario(
            name="fraud",
            title="Online fraud detection",
            description=(
                "Card-transaction stream; trailing spend windows feed a "
                "scoring transformer (paper §3.3)."
            ),
            run="PYTHONPATH=src python examples/fraud_detection.py",
            views=_one(fraud_view),
        ),
        Scenario(
            name="recommendation",
            title="Product recommendation",
            description=(
                "Minute-level order events; one-click design→verify→deploy "
                "with version evolution (paper §3.2)."
            ),
            run="PYTHONPATH=src python examples/recommendation.py",
            views=_one(reco_view),
        ),
        Scenario(
            name="multi_table_fraud",
            title="Multi-table fraud features",
            description=(
                "4-table database: point-in-time LAST JOINs + WINDOW UNION "
                "outflows, verified offline↔online."
            ),
            run="PYTHONPATH=src python examples/multi_table_fraud.py",
            views=_one(multi_table_view),
            exported=("fraud_multitable",),
        ),
        Scenario(
            name="sharded_serving",
            title="Sharded online serving",
            description=(
                "The multi-table view key-partitioned across a ('shard',) "
                "device mesh behind a micro-batching router."
            ),
            run="PYTHONPATH=src python examples/sharded_serving.py",
            views=_one(sharded_view),
        ),
        Scenario(
            name="multi_scenario",
            title="Multi-scenario plane",
            description=(
                "Three views (acct_risk, spend_profile, merchant_watch) on "
                "ONE store/mesh; shared tables ingested once, answers "
                "bit-identical to dedicated stores; merchant_watch is "
                "hot-deployed onto the warm plane."
            ),
            run="PYTHONPATH=src python examples/multi_scenario.py",
            views=multi_scenario_views,
            hot_deployed=("merchant_watch",),
        ),
    )
}


# ---------------------------------------------------------------------------
# Generated scenario families (the stress suite's registry hook)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GeneratedFamily:
    """A seeded family of GENERATED scenario views — the catalog entry for
    the paper's "100+ scenarios" scale claim.

    Unlike :class:`Scenario`, the views are not hand-written: they come
    from the deterministic generator in :mod:`repro.stress.generate`, so
    the catalog renders a scale-aware structural census instead of 100+
    full entries.  ``(seed, n, profile)`` pins the family byte-exactly.
    """

    name: str
    title: str
    description: str
    run: str
    seed: int
    n: int
    profile: str

    def views(self) -> List[FeatureView]:
        from repro.stress.generate import gen_views

        return gen_views(self.seed, self.n, self.profile)

    def summary_md(self) -> str:
        from repro.stress.generate import render_summary_md

        return render_summary_md(
            self.views(), seed=self.seed, n=self.n, profile=self.profile
        )


GENERATED: Dict[str, GeneratedFamily] = {
    f.name: f
    for f in (
        GeneratedFamily(
            name="stress",
            title="Scenario explosion (generated stress suite)",
            description=(
                "128 seeded, deterministic feature views sampling the "
                "entire expr IR surface — every Agg, both window modes, "
                "WINDOW UNIONs over shared streams, multi-table LAST "
                "JOINs, Signature/Hash lanes, evolve chains — deployed "
                "onto one sharded plane and churned by the stress "
                "harness (hot-deploy waves, mixed traffic under both "
                "routing flavours, continuous sampled verification with "
                "failure shrinking)."
            ),
            run="PYTHONPATH=src python -m repro.stress --smoke",
            seed=0,
            n=128,
            profile="default",
        ),
    )
}
