"""Manifest-based sharded checkpointing (save / restore / reshard, async).

Layout (one directory per step):

    ckpt_dir/step_000123/
      manifest.json        # tree structure, shapes, dtypes, partition specs
      leaf_000000.npy ...  # one file per pytree leaf
      COMMITTED            # written last; restore ignores dirs without it

* **Atomicity** — leaves + manifest are written into ``.tmp-step_X`` and the
  directory is atomically renamed, then COMMITTED is dropped in; a crash
  mid-save can never corrupt the latest checkpoint (paper analogue:
  FeatInsight's one-click deploy keeps prior service versions live).
* **Async** — ``save(..., blocking=False)`` snapshots to host RAM
  (device_get) synchronously and writes in a background thread; the train
  loop overlaps checkpoint IO with the next steps.  ``wait()`` joins.
* **Resharding** — restore() takes an optional ``shardings`` pytree and
  device_puts each leaf to its (possibly different) target sharding: this
  is the elastic-rescale path (checkpoint saved on a (16,16) mesh restores
  onto (8,16) after losing a data slice).
* **Multi-host** — in a real multi-controller deployment each process
  writes only its addressable shards (process-local leaf slices) and
  restore re-assembles per the manifest specs; this container is
  single-process, so leaves are saved whole.  The manifest format carries
  the spec strings either way.
"""

from __future__ import annotations

import concurrent.futures as cf
import json
import os
import pathlib
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _tree_paths(tree) -> List[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        paths.append("/".join(parts))
    return paths


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = cf.ThreadPoolExecutor(max_workers=2)
        self._pending: List[cf.Future] = []
        self._lock = threading.Lock()

    # -- save -------------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        """Snapshot to host, then write (async unless blocking)."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        paths = _tree_paths(tree)
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
            if hasattr(treedef, "serialize_using_proto") else None,
            "leaves": [
                {"path": p, "file": f"leaf_{i:06d}.npy",
                 "shape": list(l.shape), "dtype": str(l.dtype)}
                for i, (p, l) in enumerate(zip(paths, host_leaves))
            ],
        }

        def write():
            tmp = self.dir / f".tmp-step_{step:09d}"
            final = self.dir / f"step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, leaf in enumerate(host_leaves):
                np.save(tmp / f"leaf_{i:06d}.npy", leaf)
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            (final / "COMMITTED").write_text("ok")
            self._gc()

        if blocking:
            write()
        else:
            with self._lock:
                self._pending.append(self._pool.submit(write))

    def wait(self) -> None:
        with self._lock:
            pending, self._pending = self._pending, []
        for f in pending:
            f.result()

    # -- restore -----------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMITTED").exists():
                steps.append(int(p.name.split("_")[1]))
        return max(steps) if steps else None

    def restore(
        self, step: Optional[int] = None, *, like: Any = None,
        shardings: Any = None,
    ) -> Any:
        """Load a checkpoint.  ``like`` supplies the treedef (required);
        ``shardings`` optionally device_puts each leaf (resharding)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        arrays = [
            np.load(d / leaf["file"]) for leaf in manifest["leaves"]
        ]
        assert like is not None, "restore needs `like` for the tree structure"
        treedef = jax.tree_util.tree_structure(like)
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings
            )
        return tree

    # -- gc -----------------------------------------------------------------------

    def _gc(self) -> None:
        steps = sorted(
            p for p in self.dir.glob("step_*") if (p / "COMMITTED").exists()
        )
        for p in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(p, ignore_errors=True)
