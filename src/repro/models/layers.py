"""Shared model layers (pure JAX, functional params-as-pytrees).

Covers every assigned family's needs: RMSNorm/LayerNorm, RoPE, GQA
attention (full / causal / sliding-window, optional qk_norm, grouped
einsum so broadcast KV is never materialized), SwiGLU / GeGLU /
squared-ReLU MLPs, vocab-padded embeddings with masked logits.

Initialization is deterministic per (seed, path-hash) and usable under
``jax.eval_shape`` (the dry-run instantiates full configs as
ShapeDtypeStructs only).
"""

from __future__ import annotations

import zlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding.api import logical_constraint

__all__ = [
    "dense_init", "norm_init", "norm_apply", "rope", "attention_qkv",
    "gqa_attention", "mlp_init", "mlp_apply", "embed_init", "embed_lookup",
    "logits_from_embedding", "cross_entropy_loss", "key_for",
]


def key_for(seed_key: jax.Array, path: str) -> jax.Array:
    """Deterministic per-path PRNG key (stable across refactors)."""
    return jax.random.fold_in(seed_key, zlib.crc32(path.encode()) & 0x7FFFFFFF)


def scan_layers(body, carry, xs, cfg, length: int):
    """lax.scan over stacked layers, or an unrolled python loop when
    cfg.unroll_layers (roofline probes: XLA cost_analysis counts while-loop
    bodies once, so probes must materialize each layer)."""
    if not cfg.unroll_layers:
        return jax.lax.scan(body, carry, xs, length=length)
    ys = []
    for i in range(length):
        xi = jax.tree.map(lambda x: x[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is None:
        return carry, None
    return carry, jax.tree.map(lambda *a: jnp.stack(a), *ys)


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(cfg: ModelConfig, d: Optional[int] = None) -> Dict:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(p: Dict, x: jnp.ndarray, kind: str = "rmsnorm") -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"] + p.get("bias", 0.0)
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd), positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention_qkv_init(key, cfg: ModelConfig, d_model: Optional[int] = None) -> Dict:
    D = d_model or cfg.d_model
    hd, H, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    p = {
        "wq": dense_init(key_for(key, "wq"), (D, H * hd), cfg.pdtype),
        "wk": dense_init(key_for(key, "wk"), (D, Hkv * hd), cfg.pdtype),
        "wv": dense_init(key_for(key, "wv"), (D, Hkv * hd), cfg.pdtype),
        "wo": dense_init(key_for(key, "wo"), (H * hd, D), cfg.pdtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init(cfg, hd)
        p["k_norm"] = norm_init(cfg, hd)
    return p


def attention_qkv(
    p: Dict,
    x: jnp.ndarray,                  # (B, S, D)
    positions: jnp.ndarray,          # (B, S)
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Project + rope.  Returns q (B,S,H,hd), k,v (B,S,Hkv,hd)."""
    B, S, _ = x.shape
    hd, H, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = norm_apply(p["q_norm"], q, cfg.norm)
        k = norm_apply(p["k_norm"], k, cfg.norm)
    if cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attention(
    q: jnp.ndarray,                  # (B, Sq, H, hd)
    k: jnp.ndarray,                  # (B, Sk, Hkv, hd)
    v: jnp.ndarray,                  # (B, Sk, Hkv, hd)
    q_positions: jnp.ndarray,        # (B, Sq)
    k_positions: jnp.ndarray,        # (B, Sk)  (or None -> arange)
    *,
    causal: bool,
    window: Optional[int],
    kv_valid: Optional[jnp.ndarray] = None,  # (B, Sk) bool
) -> jnp.ndarray:
    """Grouped-query attention; never materializes broadcast KV.

    Returns (B, Sq, H, hd).  f32 softmax accumulation.
    """
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = hd ** -0.5

    qg = q.reshape(B, Sq, Hkv, G, hd)
    # operands stay in their storage dtype (bf16 on TPU): the MXU
    # accumulates bf16 x bf16 -> f32 natively via preferred_element_type.
    # An explicit .astype(f32) here would materialize an f32 copy of the
    # entire KV cache every layer (measured: ~3x decode HBM traffic).
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k,
        preferred_element_type=jnp.float32,
    ) * scale  # (B, Hkv, G, Sq, Sk) f32

    qp = q_positions[:, None, None, :, None]
    kp = k_positions[:, None, None, None, :]
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask = mask & (kp <= qp)
    if window is not None:
        mask = mask & (kp > qp - window)
    if kv_valid is not None:
        mask = mask & kv_valid[:, None, None, None, :]
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p_ = jnp.exp(s - m)
    p_ = jnp.where(mask, p_, 0.0)
    denom = jnp.maximum(p_.sum(-1, keepdims=True), 1e-30)
    p_ = p_ / denom
    # downcast the attention weights to the value dtype (f32 softmax is
    # kept; only the PV matmul runs in storage precision with f32
    # accumulation) -- the standard TPU flash-attention recipe.
    o = jnp.einsum(
        "bhgqk,bkhd->bqhgd", p_.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_model: Optional[int] = None,
             d_ff: Optional[int] = None) -> Dict:
    D = d_model or cfg.d_model
    F = d_ff or cfg.d_ff
    p = {
        "w_in": dense_init(key_for(key, "w_in"), (D, F), cfg.pdtype),
        "w_out": dense_init(key_for(key, "w_out"), (F, D), cfg.pdtype),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(key_for(key, "w_gate"), (D, F), cfg.pdtype)
    return p


def mlp_apply(p: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    h = x @ p["w_in"]
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * h
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * h
    elif cfg.mlp == "squared_relu":
        r = jax.nn.relu(h)
        h = r * r
    else:  # relu
        h = jax.nn.relu(h)
    h = logical_constraint(h, *(None,) * (h.ndim - 1), "d_ff")
    return h @ p["w_out"]


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def embed_init(key, cfg: ModelConfig) -> Dict:
    Vp, D = cfg.vocab_padded, cfg.d_model
    # std 1/sqrt(D): keeps tied-head logits at O(1) scale at init
    p = {"table": dense_init(key_for(key, "embed"), (Vp, D), cfg.pdtype,
                             scale=D ** -0.5)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(key_for(key, "head"), (D, Vp), cfg.pdtype)
    return p


def embed_lookup(p: Dict, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return p["table"][tokens].astype(cfg.cdtype)


def logits_from_embedding(p: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "...d,vd->...v", x.astype(jnp.float32),
            p["table"].astype(jnp.float32),
        )
    else:
        logits = jnp.einsum(
            "...d,dv->...v", x.astype(jnp.float32),
            p["head"].astype(jnp.float32),
        )
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


def cross_entropy_loss(
    logits: jnp.ndarray,   # (B, S, V) f32
    labels: jnp.ndarray,   # (B, S) int32, -1 = ignore
    z_loss: float = 0.0,
) -> Tuple[jnp.ndarray, Dict]:
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * valid
    denom = jnp.maximum(valid.sum(), 1)
    loss = nll.sum() / denom
    metrics = {"nll": loss, "tokens": denom}
    if z_loss > 0:
        zl = z_loss * ((lse * valid) ** 2).sum() / denom
        loss = loss + zl
        metrics["z_loss"] = zl
    return loss, metrics
