"""Model zoo: one builder for every assigned architecture family."""

from repro.models.config import ModelConfig  # noqa: F401


def build_model(cfg: ModelConfig):
    """Return the family driver for a config."""
    if cfg.family in ("dense", "moe"):
        from repro.models.transformer import DecoderLM
        return DecoderLM(cfg)
    if cfg.family == "rwkv":
        from repro.models.rwkv6 import RWKV6LM
        return RWKV6LM(cfg)
    if cfg.family == "griffin":
        from repro.models.griffin import GriffinLM
        return GriffinLM(cfg)
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecLM
        return EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family}")
