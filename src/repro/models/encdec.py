"""Encoder-decoder backbone (seamless-m4t-medium assignment).

The modality frontend is a STUB per the brief: ``input_specs()`` provides
precomputed frame embeddings (B, S_enc, d_model) — the speech encoder's
conv feature extractor is out of scope; the transformer backbone
(12 bidirectional encoder layers + 12 causal decoder layers with
cross-attention) is what this config exercises.

Decode caches: FullKV for decoder self-attention + a static cross-attention
KV computed once from the encoder output.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import kvcache as kvc
from repro.models.layers import (
    attention_qkv,
    attention_qkv_init,
    cross_entropy_loss,
    embed_init,
    embed_lookup,
    gqa_attention,
    key_for,
    logits_from_embedding,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
    scan_layers,
)
from repro.sharding.api import logical_constraint

__all__ = ["EncDecLM"]


def _enc_block_init(key, cfg: ModelConfig) -> Dict:
    return {
        "ln_attn": norm_init(cfg),
        "attn": attention_qkv_init(key_for(key, "attn"), cfg),
        "ln_mlp": norm_init(cfg),
        "mlp": mlp_init(key_for(key, "mlp"), cfg),
    }


def _dec_block_init(key, cfg: ModelConfig) -> Dict:
    return {
        "ln_self": norm_init(cfg),
        "self_attn": attention_qkv_init(key_for(key, "self"), cfg),
        "ln_cross": norm_init(cfg),
        "cross_attn": attention_qkv_init(key_for(key, "cross"), cfg),
        "ln_mlp": norm_init(cfg),
        "mlp": mlp_init(key_for(key, "mlp"), cfg),
    }


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.n_encoder_layers > 0
        self.cfg = cfg

    def init(self, seed: int = 0) -> Dict:
        cfg = self.cfg
        root = jax.random.PRNGKey(seed)
        ek = jax.random.split(key_for(root, "enc"), cfg.n_encoder_layers)
        dk = jax.random.split(key_for(root, "dec"), cfg.n_layers)
        return {
            "embed": embed_init(key_for(root, "embed"), cfg),
            "enc_layers": jax.vmap(lambda k: _enc_block_init(k, cfg))(ek),
            "dec_layers": jax.vmap(lambda k: _dec_block_init(k, cfg))(dk),
            "ln_enc": norm_init(cfg),
            "ln_out": norm_init(cfg),
        }

    # -- encoder ---------------------------------------------------------------

    def encode(self, params: Dict, frames: jnp.ndarray) -> jnp.ndarray:
        """frames: (B, S_enc, D) precomputed frontend embeddings."""
        cfg = self.cfg
        x = frames.astype(cfg.cdtype)
        x = logical_constraint(x, "batch", None, None)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def body(h, lp):
            a_in = norm_apply(lp["ln_attn"], h, cfg.norm)
            q, k, v = attention_qkv(lp["attn"], a_in, positions, cfg)
            o = gqa_attention(q, k, v, positions, positions,
                              causal=False, window=None)
            Bq, Sq, H, hd = o.shape
            h = h + (o.reshape(Bq, Sq, H * hd) @ lp["attn"]["wo"]).astype(h.dtype)
            m_in = norm_apply(lp["ln_mlp"], h, cfg.norm)
            h = h + mlp_apply(lp["mlp"], m_in, cfg).astype(h.dtype)
            return h, None

        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
        x, _ = scan_layers(
            body, x, params["enc_layers"], cfg, cfg.n_encoder_layers
        )
        return norm_apply(params["ln_enc"], x, cfg.norm)

    # -- decoder ---------------------------------------------------------------

    def _dec_block(self, lp, x, positions, enc_out, enc_positions, cfg,
                   self_kv=None, self_kpos=None, self_valid=None,
                   cross_kv=None):
        # self attention (causal)
        a_in = norm_apply(lp["ln_self"], x, cfg.norm)
        q, k_new, v_new = attention_qkv(lp["self_attn"], a_in, positions, cfg)
        if self_kv is None:
            o = gqa_attention(q, k_new, v_new, positions, positions,
                              causal=True, window=None)
            new_self = (k_new, v_new)
        else:
            k_l, v_l = kvc.full_kv_update_layer(
                self_kv[0], self_kv[1], k_new, v_new, positions[:, 0]
            )
            o = gqa_attention(q, k_l, v_l, positions, self_kpos,
                              causal=True, window=None, kv_valid=self_valid)
            new_self = (k_l, v_l)
        B, S, H, hd = o.shape
        x = x + (o.reshape(B, S, H * hd) @ lp["self_attn"]["wo"]).astype(x.dtype)

        # cross attention (to encoder output)
        c_in = norm_apply(lp["ln_cross"], x, cfg.norm)
        qc = (c_in @ lp["cross_attn"]["wq"]).reshape(B, S, cfg.n_heads, cfg.hd)
        if cross_kv is None:
            Se = enc_out.shape[1]
            kc = (enc_out @ lp["cross_attn"]["wk"]).reshape(
                B, Se, cfg.n_kv_heads, cfg.hd
            )
            vc = (enc_out @ lp["cross_attn"]["wv"]).reshape(
                B, Se, cfg.n_kv_heads, cfg.hd
            )
            new_cross = (kc, vc)
        else:
            kc, vc = cross_kv
            new_cross = cross_kv
        oc = gqa_attention(qc, kc, vc, positions, enc_positions,
                           causal=False, window=None)
        x = x + (oc.reshape(B, S, H * hd) @ lp["cross_attn"]["wo"]).astype(x.dtype)

        m_in = norm_apply(lp["ln_mlp"], x, cfg.norm)
        x = x + mlp_apply(lp["mlp"], m_in, cfg).astype(x.dtype)
        return x, new_self, new_cross

    # -- training ----------------------------------------------------------------

    def loss(self, params: Dict, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        frames = batch["frames"]
        tokens, labels = batch["tokens"], batch["labels"]
        enc_out = self.encode(params, frames)
        B, Se, _ = enc_out.shape
        enc_positions = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))

        x = embed_lookup(params["embed"], tokens, cfg)
        S = tokens.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def body(h, lp):
            h, _, _ = self._dec_block(
                lp, h, positions, enc_out, enc_positions, cfg
            )
            return h, None

        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
        x, _ = scan_layers(body, x, params["dec_layers"], cfg, cfg.n_layers)
        x = norm_apply(params["ln_out"], x, cfg.norm)
        logits = logits_from_embedding(params["embed"], x, cfg)
        return cross_entropy_loss(logits, labels)

    # -- serving -------------------------------------------------------------------

    def prefill(self, params: Dict, batch: Dict, max_len: int):
        """Encode frames + consume decoder prompt; build caches."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        B, Se, _ = enc_out.shape
        enc_positions = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
        tokens = batch["tokens"]
        S = tokens.shape[1]
        x = embed_lookup(params["embed"], tokens, cfg)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def body(h, lp):
            h, new_self, new_cross = self._dec_block(
                lp, h, positions, enc_out, enc_positions, cfg
            )
            return h, (new_self[0], new_self[1], new_cross[0], new_cross[1])

        x, (k_s, v_s, k_c, v_c) = scan_layers(
            body, x, params["dec_layers"], cfg, cfg.n_layers
        )
        x = norm_apply(params["ln_out"], x, cfg.norm)
        logits = logits_from_embedding(params["embed"], x[:, -1:], cfg)

        cache = kvc.full_kv_init(cfg, B, max_len)
        k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_s.astype(cache.k.dtype), 0, axis=2
        )
        v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_s.astype(cache.v.dtype), 0, axis=2
        )
        state = {
            "self": kvc.FullKV(k=k, v=v, pos=jnp.full((B,), S, jnp.int32)),
            "cross_k": k_c, "cross_v": v_c,
            "enc_positions": enc_positions,
        }
        return logits, state

    def decode_step(self, params: Dict, state: Dict, tokens: jnp.ndarray):
        cfg = self.cfg
        cache: kvc.FullKV = state["self"]
        B = tokens.shape[0]
        x = embed_lookup(params["embed"], tokens, cfg)
        positions = cache.pos[:, None]
        Smax = cache.max_len
        k_positions = jnp.broadcast_to(
            jnp.arange(Smax, dtype=jnp.int32), (B, Smax)
        )
        valid = k_positions <= cache.pos[:, None]

        def body(h, xs):
            lp, k_l, v_l, k_c, v_c = xs
            h, new_self, _ = self._dec_block(
                lp, h, positions, None, state["enc_positions"], cfg,
                self_kv=(k_l, v_l), self_kpos=k_positions, self_valid=valid,
                cross_kv=(k_c, v_c),
            )
            return h, (new_self[0], new_self[1])

        x, (k_s, v_s) = scan_layers(
            body, x,
            (params["dec_layers"], cache.k, cache.v,
             state["cross_k"], state["cross_v"]),
            cfg, cfg.n_layers,
        )
        x = norm_apply(params["ln_out"], x, cfg.norm)
        logits = logits_from_embedding(params["embed"], x, cfg)
        new_state = dict(
            state,
            self=kvc.FullKV(k=k_s, v=v_s, pos=cache.pos + 1),
        )
        return logits, new_state
