"""Decoder-only transformer LM (dense + MoE FFN), scan-over-layers.

One driver covers nemotron-4 (squared-ReLU), qwen3 (qk_norm), yi (llama
GQA), phi3-mini, mixtral (SWA + MoE), moonshot (64e top-6 MoE), and the
phi3-vision backbone (precomputed patch embeddings prepended — frontend
stub per the assignment).

Layers are stacked on a leading axis and executed with ``jax.lax.scan``
(+ configurable remat), so compile time and HLO size are O(1) in depth —
a hard requirement for dry-running 60-layer configs at 512 devices.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import kvcache as kvc
from repro.models.layers import (
    attention_qkv,
    attention_qkv_init,
    cross_entropy_loss,
    embed_init,
    embed_lookup,
    gqa_attention,
    key_for,
    logits_from_embedding,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
    scan_layers,
)
from repro.models.moe import moe_apply, moe_init
from repro.sharding.api import logical_constraint

__all__ = ["DecoderLM"]


def _block_init(key, cfg: ModelConfig) -> Dict:
    p = {
        "ln_attn": norm_init(cfg),
        "attn": attention_qkv_init(key_for(key, "attn"), cfg),
        "ln_mlp": norm_init(cfg),
    }
    if cfg.family == "moe":
        p["moe"] = moe_init(key_for(key, "moe"), cfg)
    else:
        p["mlp"] = mlp_init(key_for(key, "mlp"), cfg)
    return p


def _block_apply(
    p: Dict,
    x: jnp.ndarray,               # (B, S, D)
    positions: jnp.ndarray,       # (B, S)
    cfg: ModelConfig,
    *,
    kv: Optional[Tuple] = None,   # (k_layer, v_layer[, k_pos]) for decode
    kv_valid: Optional[jnp.ndarray] = None,
    k_positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
) -> Tuple[jnp.ndarray, Dict, Tuple]:
    """Returns (x_out, aux, new_kv (k, v))."""
    h = norm_apply(p["ln_attn"], x, cfg.norm)
    q, k_new, v_new = attention_qkv(p["attn"], h, positions, cfg)
    q = logical_constraint(q, "batch", None, "heads", None)

    if kv is None:
        k_att, v_att = k_new, v_new
        kp = positions
        valid = None
    else:
        k_att, v_att = kv
        kp = k_positions
        valid = kv_valid

    o = gqa_attention(
        q, k_att, v_att, positions, kp,
        causal=causal, window=cfg.sliding_window, kv_valid=valid,
    )
    B, S, H, hd = o.shape
    x = x + (o.reshape(B, S, H * hd) @ p["attn"]["wo"]).astype(x.dtype)

    h = norm_apply(p["ln_mlp"], x, cfg.norm)
    if cfg.family == "moe":
        f, aux = moe_apply(p["moe"], h, cfg)
    else:
        f, aux = mlp_apply(p["mlp"], h, cfg), {}
    x = x + f.astype(x.dtype)
    x = logical_constraint(x, "batch", None, None)
    return x, aux, (k_new, v_new)


class DecoderLM:
    """Pure-function model API: init / apply / prefill / decode_step."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- init -----------------------------------------------------------------

    def init(self, seed: int = 0) -> Dict:
        cfg = self.cfg
        root = jax.random.PRNGKey(seed)
        layer_keys = jax.random.split(key_for(root, "layers"), cfg.n_layers)
        stacked = jax.vmap(lambda k: _block_init(k, cfg))(layer_keys)
        return {
            "embed": embed_init(key_for(root, "embed"), cfg),
            "layers": stacked,
            "ln_out": norm_init(cfg),
        }

    # -- shared embedding-side ----------------------------------------------------

    def _embed_inputs(self, params, batch: Dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """tokens (+ optional frontend embeds) -> (x (B,S,D), positions)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed_lookup(params["embed"], tokens, cfg)
        if cfg.frontend is not None and "frontend_embeds" in batch:
            fe = batch["frontend_embeds"].astype(x.dtype)  # (B, P, D)
            x = jnp.concatenate([fe, x], axis=1)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = logical_constraint(x, "batch", None, None)
        return x, positions

    # -- training forward -----------------------------------------------------------

    def loss(self, params: Dict, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)

        def body(carry, layer_p):
            h, aux_acc = carry
            h, aux, _ = _block_apply(layer_p, h, positions, cfg)
            aux_acc = {
                k: aux_acc.get(k, 0.0) + v for k, v in aux.items()
            } if aux else aux_acc
            return (h, aux_acc), None

        aux0 = (
            {"moe_lb_loss": 0.0, "moe_z_loss": 0.0, "moe_dropped_frac": 0.0}
            if cfg.family == "moe" else {}
        )
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
        (x, aux), _ = scan_layers(
            body, (x, aux0), params["layers"], cfg, cfg.n_layers
        )

        x = norm_apply(params["ln_out"], x, cfg.norm)
        logits = logits_from_embedding(params["embed"], x, cfg)
        labels = batch["labels"]
        if cfg.frontend is not None and "frontend_embeds" in batch:
            P = batch["frontend_embeds"].shape[1]
            pad = jnp.full(
                (labels.shape[0], P), -1, labels.dtype
            )
            labels = jnp.concatenate([pad, labels], axis=1)
        loss, metrics = cross_entropy_loss(logits, labels)
        if cfg.family == "moe":
            L = cfg.n_layers
            lb = aux["moe_lb_loss"] / L
            zl = aux["moe_z_loss"] / L
            loss = loss + 0.01 * lb + 1e-3 * zl
            metrics.update(
                moe_lb_loss=lb, moe_z_loss=zl,
                moe_dropped_frac=aux["moe_dropped_frac"] / L,
            )
        return loss, metrics

    # -- prefill ----------------------------------------------------------------------

    def prefill(
        self, params: Dict, batch: Dict, max_len: Optional[int] = None
    ):
        """Run the prompt, build the cache, return last-token logits."""
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        use_sliding = cfg.sliding_window is not None
        W = min(cfg.sliding_window or S, S) if use_sliding else None
        # a frontend (VLM patches / audio frames) extends the embedded
        # sequence past the token count -- the cache must hold all of it
        max_len = max(max_len or S, S)

        def body(h, layer_p):
            h, _, (k_new, v_new) = _block_apply(layer_p, h, positions, cfg)
            return h, (k_new, v_new)

        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
        x, (k_all, v_all) = scan_layers(
            body, x, params["layers"], cfg, cfg.n_layers
        )
        # k_all: (L, B, S, Hkv, hd)

        x = norm_apply(params["ln_out"], x, cfg.norm)
        logits = logits_from_embedding(params["embed"], x[:, -1:], cfg)

        pos_end = jnp.full((B,), S, jnp.int32)
        if use_sliding:
            Wc = cfg.sliding_window
            cache = kvc.sliding_kv_init(cfg, B, Wc)
            take = min(S, Wc)
            src = k_all[:, :, S - take:]
            srcv = v_all[:, :, S - take:]
            abs_pos = jnp.arange(S - take, S, dtype=jnp.int32)
            slots = abs_pos % Wc
            k = cache.k.at[:, :, slots].set(src.astype(cache.k.dtype))
            v = cache.v.at[:, :, slots].set(srcv.astype(cache.v.dtype))
            k_pos = cache.k_pos.at[:, slots].set(abs_pos[None, :])
            cache = kvc.SlidingKV(k=k, v=v, k_pos=k_pos, pos=pos_end)
        else:
            cache = kvc.full_kv_init(cfg, B, max_len)
            k = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k_all.astype(cache.k.dtype), 0, axis=2
            )
            v = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v_all.astype(cache.v.dtype), 0, axis=2
            )
            cache = kvc.FullKV(k=k, v=v, pos=pos_end)
        return logits, cache

    # -- decode ------------------------------------------------------------------------

    def decode_step(self, params: Dict, cache, tokens: jnp.ndarray):
        """One token for every sequence. tokens: (B, 1)."""
        cfg = self.cfg
        B = tokens.shape[0]
        x = embed_lookup(params["embed"], tokens, cfg)
        positions = cache.pos[:, None]  # (B, 1)
        sliding = isinstance(cache, kvc.SlidingKV)

        if sliding:
            W = cache.window
            k_positions = cache.k_pos  # (B, W)
        else:
            Smax = cache.max_len
            k_positions = jnp.broadcast_to(
                jnp.arange(Smax, dtype=jnp.int32), (B, Smax)
            )

        def body(h, xs):
            layer_p, k_layer, v_layer = xs
            hh = norm_apply(layer_p["ln_attn"], h, cfg.norm)
            q, k_new, v_new = attention_qkv(layer_p["attn"], hh, positions, cfg)
            if sliding:
                k_layer, v_layer = kvc.sliding_kv_update_layer(
                    k_layer, v_layer, k_new, v_new, cache.pos
                )
                kp = k_positions.at[
                    jnp.arange(B), cache.pos % W
                ].set(cache.pos)
                valid = (kp >= 0) & (kp > (cache.pos[:, None] - (cfg.sliding_window or W)))
            else:
                k_layer, v_layer = kvc.full_kv_update_layer(
                    k_layer, v_layer, k_new, v_new, cache.pos
                )
                kp = k_positions
                valid = kp <= cache.pos[:, None]
            o = gqa_attention(
                q, k_layer, v_layer, positions, kp,
                causal=True, window=cfg.sliding_window, kv_valid=valid,
            )
            _, S1, H, hd = o.shape
            h = h + (o.reshape(B, S1, H * hd) @ layer_p["attn"]["wo"]).astype(h.dtype)
            hh = norm_apply(layer_p["ln_mlp"], h, cfg.norm)
            if cfg.family == "moe":
                f, _ = moe_apply(layer_p["moe"], hh, cfg)
            else:
                f = mlp_apply(layer_p["mlp"], hh, cfg)
            h = h + f.astype(h.dtype)
            return h, (k_layer, v_layer)

        x, (k_cache, v_cache) = scan_layers(
            body, x, (params["layers"], cache.k, cache.v), cfg, cfg.n_layers
        )
        x = norm_apply(params["ln_out"], x, cfg.norm)
        logits = logits_from_embedding(params["embed"], x, cfg)

        new_pos = cache.pos + 1
        if sliding:
            k_pos = cache.k_pos.at[jnp.arange(B), cache.pos % cache.window].set(
                cache.pos
            )
            new_cache = kvc.SlidingKV(k=k_cache, v=v_cache, k_pos=k_pos, pos=new_pos)
        else:
            new_cache = kvc.FullKV(k=k_cache, v=v_cache, pos=new_pos)
        return logits, new_cache
