"""Decode-time caches for every model family (functional pytrees).

* FullKV     — dense decoders (nemotron, qwen3, yi, phi3, phi3-vision, and
               the seamless decoder self-attention).
* SlidingKV  — ring-buffer cache for sliding-window attention (mixtral SWA,
               recurrentgemma local attention): O(window) memory at any
               context length — this is what makes the long_500k decode
               cells runnable.
* RecurrentState — RWKV6 (wkv matrix state + token-shift) and
               RG-LRU (hidden + conv tap) states: O(1) in context length.

All caches are stacked on a leading layer axis and updated inside the
layer scan (cache slices are scan xs/ys).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["FullKV", "SlidingKV", "full_kv_init", "sliding_kv_init"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FullKV:
    """k, v: (L, B, Smax, Hkv, hd); pos: (B,) current lengths."""

    k: jnp.ndarray
    v: jnp.ndarray
    pos: jnp.ndarray

    def tree_flatten(self):
        return (self.k, self.v, self.pos), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def max_len(self) -> int:
        return self.k.shape[2]


def full_kv_init(
    cfg: ModelConfig, batch: int, max_len: int, n_layers: Optional[int] = None,
    dtype=None,
) -> FullKV:
    L = n_layers if n_layers is not None else cfg.n_layers
    shape = (L, batch, max_len, cfg.n_kv_heads, cfg.hd)
    dt = dtype or cfg.cdtype
    return FullKV(
        k=jnp.zeros(shape, dt),
        v=jnp.zeros(shape, dt),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def full_kv_update_layer(
    k_layer: jnp.ndarray,   # (B, Smax, Hkv, hd) cache slice
    v_layer: jnp.ndarray,
    k_new: jnp.ndarray,     # (B, S_new, Hkv, hd)
    v_new: jnp.ndarray,
    pos: jnp.ndarray,       # (B,) write offsets (uniform start assumed)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    # uniform-position batched write (serving keeps slot positions aligned;
    # the batch scheduler pads ragged requests)
    start = pos[0]
    k_layer = jax.lax.dynamic_update_slice_in_dim(k_layer, k_new.astype(k_layer.dtype), start, axis=1)
    v_layer = jax.lax.dynamic_update_slice_in_dim(v_layer, v_new.astype(v_layer.dtype), start, axis=1)
    return k_layer, v_layer


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SlidingKV:
    """Ring cache: k, v: (L, B, W, Hkv, hd); k_pos: (B, W) absolute positions
    (-1 = empty); pos: (B,) next position."""

    k: jnp.ndarray
    v: jnp.ndarray
    k_pos: jnp.ndarray
    pos: jnp.ndarray

    def tree_flatten(self):
        return (self.k, self.v, self.k_pos, self.pos), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def window(self) -> int:
        return self.k.shape[2]


def sliding_kv_init(
    cfg: ModelConfig, batch: int, window: int, n_layers: Optional[int] = None,
    dtype=None,
) -> SlidingKV:
    L = n_layers if n_layers is not None else cfg.n_layers
    shape = (L, batch, window, cfg.n_kv_heads, cfg.hd)
    dt = dtype or cfg.cdtype
    return SlidingKV(
        k=jnp.zeros(shape, dt),
        v=jnp.zeros(shape, dt),
        k_pos=jnp.full((batch, window), jnp.int32(-1)),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def sliding_kv_update_layer(
    k_layer: jnp.ndarray,   # (B, W, Hkv, hd)
    v_layer: jnp.ndarray,
    k_new: jnp.ndarray,     # (B, 1, Hkv, hd) — decode writes one token
    v_new: jnp.ndarray,
    pos: jnp.ndarray,       # (B,)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    W = k_layer.shape[1]
    slot = (pos % W)[:, None, None, None]  # (B,1,1,1)
    b_idx = jnp.arange(k_layer.shape[0])[:, None, None, None]
    k_layer = k_layer.at[
        b_idx[..., 0, 0, 0], slot[..., 0, 0, 0]
    ].set(k_new[:, 0].astype(k_layer.dtype))
    v_layer = v_layer.at[
        b_idx[..., 0, 0, 0], slot[..., 0, 0, 0]
    ].set(v_new[:, 0].astype(v_layer.dtype))
    return k_layer, v_layer
