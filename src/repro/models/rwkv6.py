"""RWKV6 "Finch" — attention-free LM with data-dependent decay.

Time-mix uses the chunked wkv6 core (repro.kernels.wkv6): the per-channel
decayed matrix state is FeatInsight's pre-aggregation pattern (running
aggregate + current-row compose) applied to sequence modeling.  Decode
state is O(1) in context — this arch runs the long_500k cell.

Structure per layer (faithful to Finch at the block level):
  time-mix:   ddlerp token-shift -> r,k,v,g,w projections (w via LoRA),
              wkv6 core per 64-dim head, group-norm, gated output
  channel-mix: token-shift -> squared-ReLU MLP with receptance gate
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import LOG_W_MIN
from repro.models.config import ModelConfig
from repro.models.layers import (
    cross_entropy_loss,
    dense_init,
    embed_init,
    embed_lookup,
    key_for,
    logits_from_embedding,
    norm_apply,
    norm_init,
    scan_layers,
)
from repro.sharding.api import logical_constraint

__all__ = ["RWKV6LM", "RWKV_HEAD_DIM"]

RWKV_HEAD_DIM = 64
LORA_R = 32


def _tm_init(key, cfg: ModelConfig) -> Dict:
    D = cfg.d_model
    H = D // RWKV_HEAD_DIM
    p = {
        "mu": jnp.zeros((5, D), jnp.float32),  # r,k,v,w,g shift-mix
        "w_r": dense_init(key_for(key, "w_r"), (D, D), cfg.pdtype),
        "w_k": dense_init(key_for(key, "w_k"), (D, D), cfg.pdtype),
        "w_v": dense_init(key_for(key, "w_v"), (D, D), cfg.pdtype),
        "w_g": dense_init(key_for(key, "w_g"), (D, D), cfg.pdtype),
        "w_o": dense_init(key_for(key, "w_o"), (D, D), cfg.pdtype),
        "w0": jnp.full((D,), -1.0, jnp.float32),     # base log-log decay
        "w_lora_a": dense_init(key_for(key, "wla"), (D, LORA_R), cfg.pdtype),
        "w_lora_b": dense_init(key_for(key, "wlb"), (LORA_R, D), cfg.pdtype),
        "u": jnp.zeros((H, RWKV_HEAD_DIM), jnp.float32),  # bonus
        "gn": norm_init(cfg, D),
    }
    return p


def _cm_init(key, cfg: ModelConfig) -> Dict:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "mu": jnp.zeros((2, D), jnp.float32),  # k, r shift-mix
        "w_k": dense_init(key_for(key, "w_k"), (D, F), cfg.pdtype),
        "w_v": dense_init(key_for(key, "w_v"), (F, D), cfg.pdtype),
        "w_r": dense_init(key_for(key, "w_r"), (D, D), cfg.pdtype),
    }


def _shift(x: jnp.ndarray, state: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Previous-token x (train: roll; decode: carried state). x: (B,S,D)."""
    if state is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([state[:, None, :], x[:, :-1]], axis=1)
    return prev


def _mix(x, prev, mu):
    return x + (prev - x) * mu  # lerp token shift


def _time_mix(
    p: Dict, x: jnp.ndarray, cfg: ModelConfig,
    shift_state: Optional[jnp.ndarray],
    wkv_state: Optional[jnp.ndarray],
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, S, D = x.shape
    H = D // RWKV_HEAD_DIM
    prev = _shift(x, shift_state)
    xr = _mix(x, prev, p["mu"][0])
    xk = _mix(x, prev, p["mu"][1])
    xv = _mix(x, prev, p["mu"][2])
    xw = _mix(x, prev, p["mu"][3])
    xg = _mix(x, prev, p["mu"][4])

    r = (xr @ p["w_r"]).reshape(B, S, H, RWKV_HEAD_DIM)
    k = (xk @ p["w_k"]).reshape(B, S, H, RWKV_HEAD_DIM)
    v = (xv @ p["w_v"]).reshape(B, S, H, RWKV_HEAD_DIM)
    g = jax.nn.silu(xg @ p["w_g"])

    w_log = p["w0"] + jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    lw = -jnp.exp(w_log.astype(jnp.float32))          # (B, S, D), <= 0
    lw = jnp.clip(lw, LOG_W_MIN, 0.0).reshape(B, S, H, RWKV_HEAD_DIM)

    to_bhsd = lambda t: jnp.moveaxis(t, 2, 1)          # (B,H,S,hd)
    s0 = (
        wkv_state if wkv_state is not None
        else jnp.zeros((B, H, RWKV_HEAD_DIM, RWKV_HEAD_DIM), jnp.float32)
    )
    y, s_fin = wkv6(
        to_bhsd(r), to_bhsd(k), to_bhsd(v), to_bhsd(lw),
        p["u"], s0, impl="xla" if cfg.attn_impl == "xla" else "auto",
    )
    y = jnp.moveaxis(y, 1, 2).reshape(B, S, D)
    y = norm_apply(p["gn"], y, "rmsnorm") * g
    out = (y @ p["w_o"]).astype(x.dtype)
    return out, x[:, -1, :], s_fin


def _channel_mix(
    p: Dict, x: jnp.ndarray, cfg: ModelConfig,
    shift_state: Optional[jnp.ndarray],
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    prev = _shift(x, shift_state)
    xk = _mix(x, prev, p["mu"][0])
    xr = _mix(x, prev, p["mu"][1])
    kk = jax.nn.relu(xk @ p["w_k"])
    kk = kk * kk
    kk = logical_constraint(kk, "batch", None, "d_ff")
    out = jax.nn.sigmoid(xr @ p["w_r"]) * (kk @ p["w_v"])
    return out.astype(x.dtype), x[:, -1, :]


def _layer_init(key, cfg: ModelConfig) -> Dict:
    return {
        "ln_tm": norm_init(cfg),
        "tm": _tm_init(key_for(key, "tm"), cfg),
        "ln_cm": norm_init(cfg),
        "cm": _cm_init(key_for(key, "cm"), cfg),
    }


class RWKV6LM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.d_model % RWKV_HEAD_DIM == 0
        self.cfg = cfg

    def init(self, seed: int = 0) -> Dict:
        cfg = self.cfg
        root = jax.random.PRNGKey(seed)
        keys = jax.random.split(key_for(root, "layers"), cfg.n_layers)
        return {
            "embed": embed_init(key_for(root, "embed"), cfg),
            "layers": jax.vmap(lambda k: _layer_init(k, cfg))(keys),
            "ln_out": norm_init(cfg),
        }

    def _apply_layer(self, lp, x, cfg, states):
        """states: None (train) or dict(att_shift, cm_shift, wkv)."""
        tm_in = norm_apply(lp["ln_tm"], x, cfg.norm)
        tm_out, att_shift, wkv_s = _time_mix(
            lp["tm"], tm_in, cfg,
            None if states is None else states["att_shift"],
            None if states is None else states["wkv"],
        )
        x = x + tm_out
        cm_in = norm_apply(lp["ln_cm"], x, cfg.norm)
        cm_out, cm_shift = _channel_mix(
            lp["cm"], cm_in, cfg,
            None if states is None else states["cm_shift"],
        )
        x = x + cm_out
        new_states = {"att_shift": att_shift, "cm_shift": cm_shift, "wkv": wkv_s}
        return x, new_states

    def loss(self, params: Dict, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        x = embed_lookup(params["embed"], tokens, cfg)
        x = logical_constraint(x, "batch", None, None)

        def body(h, lp):
            h, _ = self._apply_layer(lp, h, cfg, None)
            return h, None

        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
        x, _ = scan_layers(body, x, params["layers"], cfg, cfg.n_layers)
        x = norm_apply(params["ln_out"], x, cfg.norm)
        logits = logits_from_embedding(params["embed"], x, cfg)
        return cross_entropy_loss(logits, labels)

    # -- serving ----------------------------------------------------------------

    def init_state(self, batch_size: int) -> Dict:
        cfg = self.cfg
        D = cfg.d_model
        H = D // RWKV_HEAD_DIM
        L = cfg.n_layers
        return {
            "att_shift": jnp.zeros((L, batch_size, D), cfg.cdtype),
            "cm_shift": jnp.zeros((L, batch_size, D), cfg.cdtype),
            "wkv": jnp.zeros((L, batch_size, H, RWKV_HEAD_DIM, RWKV_HEAD_DIM),
                             jnp.float32),
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }

    def prefill(self, params: Dict, batch: Dict):
        """Run the prompt through, carrying states (scan over layers with
        full-sequence wkv — state comes out of the kernel's final state)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed_lookup(params["embed"], tokens, cfg)

        def body(h, lp):
            tm_in = norm_apply(lp["ln_tm"], h, cfg.norm)
            tm_out, att_shift, wkv_s = _time_mix(lp["tm"], tm_in, cfg, None, None)
            h = h + tm_out
            cm_in = norm_apply(lp["ln_cm"], h, cfg.norm)
            cm_out, cm_shift = _channel_mix(lp["cm"], cm_in, cfg, None)
            h = h + cm_out
            return h, (att_shift, cm_shift, wkv_s)

        x, (att_s, cm_s, wkv_s) = scan_layers(
            body, x, params["layers"], cfg, cfg.n_layers
        )
        x = norm_apply(params["ln_out"], x, cfg.norm)
        logits = logits_from_embedding(params["embed"], x[:, -1:], cfg)
        state = {
            "att_shift": att_s, "cm_shift": cm_s, "wkv": wkv_s,
            "pos": jnp.full((B,), S, jnp.int32),
        }
        return logits, state

    def decode_step(self, params: Dict, state: Dict, tokens: jnp.ndarray):
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens, cfg)  # (B, 1, D)

        def body(h, xs):
            lp, att_s, cm_s, wkv_s = xs
            h, ns = self._apply_layer(
                lp, h, cfg,
                {"att_shift": att_s, "cm_shift": cm_s, "wkv": wkv_s},
            )
            return h, (ns["att_shift"], ns["cm_shift"], ns["wkv"])

        x, (att_s, cm_s, wkv_s) = scan_layers(
            body, x,
            (params["layers"], state["att_shift"], state["cm_shift"],
             state["wkv"]),
            cfg, cfg.n_layers,
        )
        x = norm_apply(params["ln_out"], x, cfg.norm)
        logits = logits_from_embedding(params["embed"], x, cfg)
        new_state = {
            "att_shift": att_s, "cm_shift": cm_s, "wkv": wkv_s,
            "pos": state["pos"] + 1,
        }
        return logits, new_state
