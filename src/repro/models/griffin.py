"""RecurrentGemma / Griffin hybrid: RG-LRU recurrence + local attention (1:2).

Block pattern (recurrent, recurrent, local-attn) repeating — 38 layers =
12 scanned super-blocks of 3 + 2 tail recurrent blocks.  Super-block
scanning keeps the HLO O(1) in depth while preserving the heterogeneous
pattern.

RG-LRU (per channel):
    r_t = sigmoid(W_a x_t + b_a)           # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)           # input gate
    log a_t = -c * softplus(Λ) * r_t       # data-dependent decay, c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

A diagonal linear recurrence -> associative scan for train/prefill, O(1)
step for decode.  Local attention is MQA (kv=1) with window 2048; its ring
cache is O(window), so long_500k decode is runnable.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import kvcache as kvc
from repro.models.layers import (
    attention_qkv,
    attention_qkv_init,
    cross_entropy_loss,
    dense_init,
    embed_init,
    embed_lookup,
    gqa_attention,
    key_for,
    logits_from_embedding,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
    scan_layers,
)
from repro.sharding.api import logical_constraint

__all__ = ["GriffinLM"]

_LRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU recurrent block
# ---------------------------------------------------------------------------


def _rec_init(key, cfg: ModelConfig) -> Dict:
    D, R = cfg.d_model, cfg.d_rnn
    return {
        "w_in": dense_init(key_for(key, "w_in"), (D, R), cfg.pdtype),
        "w_gate": dense_init(key_for(key, "w_gate"), (D, R), cfg.pdtype),
        "conv_w": dense_init(key_for(key, "conv"), (cfg.conv_width, R),
                             cfg.pdtype, scale=0.5),
        "w_a": dense_init(key_for(key, "w_a"), (R, R), cfg.pdtype),
        "b_a": jnp.zeros((R,), jnp.float32),
        "w_x": dense_init(key_for(key, "w_x"), (R, R), cfg.pdtype),
        "b_x": jnp.zeros((R,), jnp.float32),
        "lam": jnp.full((R,), 1.0, jnp.float32),  # Λ
        "w_out": dense_init(key_for(key, "w_out"), (R, D), cfg.pdtype),
    }


def _causal_conv(
    x: jnp.ndarray,                    # (B, S, R)
    w: jnp.ndarray,                    # (CW, R) depthwise taps
    state: Optional[jnp.ndarray],      # (B, CW-1, R) previous inputs
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    CW = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], CW - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)      # (B, S+CW-1, R)
    out = sum(
        xp[:, i:i + x.shape[1]] * w[i] for i in range(CW)
    )
    new_state = xp[:, -(CW - 1):] if CW > 1 else pad
    return out, new_state


def _rg_lru(
    x: jnp.ndarray,                    # (B, S, R) conv output
    p: Dict,
    h0: Optional[jnp.ndarray],         # (B, R)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_x"].astype(jnp.float32) + p["b_x"])
    log_a = -_LRU_C * jax.nn.softplus(p["lam"]) * r      # (B, S, R) <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)

    if x.shape[1] == 1 and h0 is not None:  # decode fast path
        h = a[:, 0] * h0 + gated[:, 0]
        return h[:, None].astype(x.dtype), h

    def comb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_in, g_in = a, gated
    if h0 is not None:
        # fold the carry into the first step
        g_in = g_in.at[:, 0].add(a[:, 0] * h0)
    _, h_seq = jax.lax.associative_scan(comb, (a_in, g_in), axis=1)
    return h_seq.astype(x.dtype), h_seq[:, -1].astype(jnp.float32)


def _rec_apply(
    p: Dict, x: jnp.ndarray, cfg: ModelConfig,
    state: Optional[Dict],
) -> Tuple[jnp.ndarray, Dict]:
    """state: {conv: (B, CW-1, R), h: (B, R)} or None (train from zero)."""
    gate = jax.nn.gelu((x @ p["w_gate"]), approximate=True)
    u = x @ p["w_in"]
    u = logical_constraint(u, "batch", None, "d_ff")
    u, conv_state = _causal_conv(
        u, p["conv_w"], None if state is None else state["conv"]
    )
    h, h_last = _rg_lru(u, p, None if state is None else state["h"])
    out = ((gate * h) @ p["w_out"]).astype(x.dtype)
    return out, {"conv": conv_state.astype(x.dtype), "h": h_last}


# ---------------------------------------------------------------------------
# Super-block = [rec, rec, local-attn], each + MLP residual
# ---------------------------------------------------------------------------


def _super_init(key, cfg: ModelConfig) -> Dict:
    return {
        "ln_r1": norm_init(cfg), "rec1": _rec_init(key_for(key, "r1"), cfg),
        "ln_m1": norm_init(cfg), "mlp1": mlp_init(key_for(key, "m1"), cfg),
        "ln_r2": norm_init(cfg), "rec2": _rec_init(key_for(key, "r2"), cfg),
        "ln_m2": norm_init(cfg), "mlp2": mlp_init(key_for(key, "m2"), cfg),
        "ln_a": norm_init(cfg), "attn": attention_qkv_init(key_for(key, "a"), cfg),
        "ln_m3": norm_init(cfg), "mlp3": mlp_init(key_for(key, "m3"), cfg),
    }


class GriffinLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.n_super = cfg.n_layers // cfg.attn_every
        self.n_tail = cfg.n_layers - self.n_super * cfg.attn_every
        assert cfg.n_kv_heads in (1, cfg.n_heads)

    def init(self, seed: int = 0) -> Dict:
        cfg = self.cfg
        root = jax.random.PRNGKey(seed)
        sk = jax.random.split(key_for(root, "supers"), self.n_super)
        params = {
            "embed": embed_init(key_for(root, "embed"), cfg),
            "supers": jax.vmap(lambda k: _super_init(k, cfg))(sk),
            "ln_out": norm_init(cfg),
        }
        tails = {}
        for t in range(self.n_tail):
            tk = key_for(root, f"tail{t}")
            tails[f"t{t}"] = {
                "ln_r": norm_init(cfg), "rec": _rec_init(tk, cfg),
                "ln_m": norm_init(cfg), "mlp": mlp_init(key_for(tk, "m"), cfg),
            }
        params["tails"] = tails
        return params

    # -- forward over full sequences (train / prefill) --------------------------

    def _super_fwd(self, sp, x, positions, cfg, states, window):
        """states None (train) or dict(conv1,h1,conv2,h2,k,v,k_pos,pos)."""
        # rec block 1
        r_in = norm_apply(sp["ln_r1"], x, cfg.norm)
        r_out, ns1 = _rec_apply(
            sp["rec1"], r_in, cfg,
            None if states is None else {"conv": states["conv1"], "h": states["h1"]},
        )
        x = x + r_out
        x = x + mlp_apply(sp["mlp1"], norm_apply(sp["ln_m1"], x, cfg.norm), cfg).astype(x.dtype)
        # rec block 2
        r_in = norm_apply(sp["ln_r2"], x, cfg.norm)
        r_out, ns2 = _rec_apply(
            sp["rec2"], r_in, cfg,
            None if states is None else {"conv": states["conv2"], "h": states["h2"]},
        )
        x = x + r_out
        x = x + mlp_apply(sp["mlp2"], norm_apply(sp["ln_m2"], x, cfg.norm), cfg).astype(x.dtype)
        # local attention block
        a_in = norm_apply(sp["ln_a"], x, cfg.norm)
        q, k_new, v_new = attention_qkv(sp["attn"], a_in, positions, cfg)
        if states is None:
            o = gqa_attention(
                q, k_new, v_new, positions, positions,
                causal=True, window=window,
            )
            new_kv = (k_new, v_new)
            kv_extra = {}
        else:
            B = q.shape[0]
            W = states["k"].shape[1]
            k_layer, v_layer = kvc.sliding_kv_update_layer(
                states["k"], states["v"], k_new, v_new, states["pos"]
            )
            k_pos = states["k_pos"].at[
                jnp.arange(B), states["pos"] % W
            ].set(states["pos"])
            valid = (k_pos >= 0) & (k_pos > (states["pos"][:, None] - window))
            o = gqa_attention(
                q, k_layer, v_layer, positions, k_pos,
                causal=True, window=window, kv_valid=valid,
            )
            new_kv = (k_layer, v_layer)
            kv_extra = {"k_pos": k_pos}
        B, S, H, hd = o.shape
        x = x + (o.reshape(B, S, H * hd) @ sp["attn"]["wo"]).astype(x.dtype)
        x = x + mlp_apply(sp["mlp3"], norm_apply(sp["ln_m3"], x, cfg.norm), cfg).astype(x.dtype)
        new_states = {
            "conv1": ns1["conv"], "h1": ns1["h"],
            "conv2": ns2["conv"], "h2": ns2["h"],
            "k": new_kv[0], "v": new_kv[1], **kv_extra,
        }
        return x, new_states

    def _tail_fwd(self, tp, x, cfg, state):
        r_in = norm_apply(tp["ln_r"], x, cfg.norm)
        r_out, ns = _rec_apply(tp["rec"], r_in, cfg, state)
        x = x + r_out
        x = x + mlp_apply(tp["mlp"], norm_apply(tp["ln_m"], x, cfg.norm), cfg).astype(x.dtype)
        return x, ns

    def loss(self, params: Dict, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        x = embed_lookup(params["embed"], tokens, cfg)
        x = logical_constraint(x, "batch", None, None)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        window = cfg.sliding_window or S

        def body(h, sp):
            h, _ = self._super_fwd(sp, h, positions, cfg, None, window)
            return h, None

        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
        x, _ = scan_layers(body, x, params["supers"], cfg, self.n_super)
        for t in range(self.n_tail):
            x, _ = self._tail_fwd(params["tails"][f"t{t}"], x, cfg, None)
        x = norm_apply(params["ln_out"], x, cfg.norm)
        logits = logits_from_embedding(params["embed"], x, cfg)
        return cross_entropy_loss(logits, labels)

    # -- serving ------------------------------------------------------------------

    def prefill(self, params: Dict, batch: Dict, max_len: Optional[int] = None):
        """Run the prompt once, return (last-token logits, serving state).

        The full-sequence forward (associative-scan RG-LRU + windowed
        attention) also yields each block's final recurrence/conv state;
        the last ``window`` keys/values are scattered into the sliding
        cache slots exactly as decode_step expects them.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed_lookup(params["embed"], tokens, cfg)
        x = logical_constraint(x, "batch", None, None)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        window = cfg.sliding_window or 2048
        W = window
        take = min(S, W)
        abs_pos = jnp.arange(S - take, S, dtype=jnp.int32)
        slots = abs_pos % W

        def body(h, sp):
            h, ns = self._super_fwd(sp, h, positions, cfg, None, window)
            k_new, v_new = ns["k"], ns["v"]  # (B, S, Hkv, hd) train-mode KV
            k_c = jnp.zeros((B, W, cfg.n_kv_heads, cfg.hd), cfg.cdtype)
            k_c = k_c.at[:, slots].set(k_new[:, S - take:].astype(k_c.dtype))
            v_c = jnp.zeros((B, W, cfg.n_kv_heads, cfg.hd), cfg.cdtype)
            v_c = v_c.at[:, slots].set(v_new[:, S - take:].astype(v_c.dtype))
            k_pos = jnp.full((B, W), jnp.int32(-1))
            k_pos = k_pos.at[:, slots].set(abs_pos[None, :])
            out_state = {
                "conv1": ns["conv1"], "h1": ns["h1"],
                "conv2": ns["conv2"], "h2": ns["h2"],
                "k": k_c, "v": v_c, "k_pos": k_pos,
            }
            return h, out_state

        x, states = scan_layers(body, x, params["supers"], cfg, self.n_super)
        state = dict(states)  # leaves carry the (NS, ...) leading dim
        for t in range(self.n_tail):
            x, ns = self._tail_fwd(params["tails"][f"t{t}"], x, cfg, None)
            state[f"tail_conv{t}"] = ns["conv"]
            state[f"tail_h{t}"] = ns["h"]
        state["pos"] = jnp.full((B,), S, jnp.int32)
        x = norm_apply(params["ln_out"], x, cfg.norm)
        logits = logits_from_embedding(params["embed"], x[:, -1:], cfg)
        return logits, state

    def init_state(self, batch_size: int) -> Dict:
        cfg = self.cfg
        R, CW = cfg.d_rnn, cfg.conv_width
        W = cfg.sliding_window or 2048
        NS = self.n_super
        mk = lambda *s: jnp.zeros(s, cfg.cdtype)
        state = {
            "conv1": mk(NS, batch_size, CW - 1, R),
            "h1": jnp.zeros((NS, batch_size, R), jnp.float32),
            "conv2": mk(NS, batch_size, CW - 1, R),
            "h2": jnp.zeros((NS, batch_size, R), jnp.float32),
            "k": mk(NS, batch_size, W, cfg.n_kv_heads, cfg.hd),
            "v": mk(NS, batch_size, W, cfg.n_kv_heads, cfg.hd),
            "k_pos": jnp.full((NS, batch_size, W), jnp.int32(-1)),
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }
        for t in range(self.n_tail):
            state[f"tail_conv{t}"] = mk(batch_size, CW - 1, R)
            state[f"tail_h{t}"] = jnp.zeros((batch_size, R), jnp.float32)
        return state

    def decode_step(self, params: Dict, state: Dict, tokens: jnp.ndarray):
        cfg = self.cfg
        B = tokens.shape[0]
        x = embed_lookup(params["embed"], tokens, cfg)
        positions = state["pos"][:, None]
        window = cfg.sliding_window or 2048

        def body(h, xs):
            sp, c1, h1, c2, h2, k, v, kp = xs
            st = {"conv1": c1, "h1": h1, "conv2": c2, "h2": h2,
                  "k": k, "v": v, "k_pos": kp, "pos": state["pos"]}
            h, ns = self._super_fwd(sp, h, positions, cfg, st, window)
            return h, (ns["conv1"], ns["h1"], ns["conv2"], ns["h2"],
                       ns["k"], ns["v"], ns["k_pos"])

        x, (c1, h1, c2, h2, k, v, kp) = scan_layers(
            body, x,
            (params["supers"], state["conv1"], state["h1"], state["conv2"],
             state["h2"], state["k"], state["v"], state["k_pos"]),
            cfg, self.n_super,
        )
        new_state = dict(state, conv1=c1, h1=h1, conv2=c2, h2=h2, k=k, v=v,
                         k_pos=kp)
        for t in range(self.n_tail):
            st = {"conv": state[f"tail_conv{t}"], "h": state[f"tail_h{t}"]}
            x, ns = self._tail_fwd(params["tails"][f"t{t}"], x, cfg, st)
            new_state[f"tail_conv{t}"] = ns["conv"]
            new_state[f"tail_h{t}"] = ns["h"]
        x = norm_apply(params["ln_out"], x, cfg.norm)
        logits = logits_from_embedding(params["embed"], x, cfg)
        new_state["pos"] = state["pos"] + 1
        return logits, new_state
