"""Mixture-of-Experts FFN with top-k routing and capacity (GShard-style
semantics, sort/scatter dispatch).

Dispatch is sort-based rather than one-hot-einsum: token->expert
assignments are ranked per expert via an argsort, tokens beyond the
per-expert capacity are dropped (classic capacity-factor semantics), kept
tokens are scattered into a dense (E, Cap, D) buffer, expert FFNs run as
one batched einsum, and results scatter-add back with router weights.
This keeps peak memory at k x token activations (no (tokens, E, Cap)
one-hot), shards cleanly (tokens on "batch"/data, experts on "experts"/
model for 64-expert moonshot -> GSPMD inserts the all-to-alls of expert
parallelism), and its FLOPs equal the top-k active-parameter count the
roofline expects.

Routing skew is FeatInsight's "hotspot keys" problem in model form; the
capacity factor + dropped-fraction metric mirror the paper's dynamic
data adjusting.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, key_for
from repro.sharding.api import logical_constraint

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg: ModelConfig) -> Dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    p = {
        "router": dense_init(key_for(key, "router"), (D, E), jnp.float32),
        "w_in": dense_init(key_for(key, "w_in"), (E, D, F), cfg.pdtype),
        "w_out": dense_init(key_for(key, "w_out"), (E, F, D), cfg.pdtype),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(key_for(key, "w_gate"), (E, D, F), cfg.pdtype)
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    cap = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, ((cap + 7) // 8) * 8)


def _seg_rank(sorted_e: jnp.ndarray) -> jnp.ndarray:
    """Per-row rank within runs of equal values. sorted_e: (G, M) sorted."""
    G, M = sorted_e.shape
    iota = jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32), (G, M))
    is_start = jnp.concatenate(
        [jnp.ones((G, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1
    )
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, iota, 0), axis=1
    )
    return iota - seg_start


def moe_apply(
    p: Dict, x: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, S, D) -> (out (B, S, D), aux metrics).

    Group-local dispatch: tokens split into G groups (G = data shards in
    production; 1 on CPU), each group sorts/ranks/scatters privately —
    GSPMD keeps every dispatch op shard-local, and the only cross-device
    traffic is the (G, E, C, D) buffer exchange (expert-parallel
    all-to-all) + the router.  A global sort over the sharded token axis
    would instead replicate the dispatch buffers on every device
    (measured: 20 GB/layer ICI on moonshot — see EXPERIMENTS.md §Perf M1).
    """
    B, S, D = x.shape
    N = B * S
    E, k = cfg.num_experts, cfg.top_k
    G = cfg.moe_groups if N % max(cfg.moe_groups, 1) == 0 else 1
    G = max(G, 1)
    Ng = N // G
    cap = _capacity(Ng, cfg)

    xf = x.reshape(G, Ng, D)
    xf = logical_constraint(xf, "batch", None, None)
    logits = jnp.einsum(
        "gnd,de->gne", xf.astype(jnp.float32), p["router"]
    )                                                     # (G, Ng, E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                  # (G, Ng, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # aux: load-balance loss (Switch-style) + router z-loss
    me = probs.mean((0, 1))                               # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(
        jnp.ones((N * k,), jnp.float32)
    ) / (N * k)
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # group-local sort-based rank within expert
    M = Ng * k
    flat_e = topi.reshape(G, M)
    order = jnp.argsort(flat_e, axis=1, stable=True)      # (G, M) local
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    pos_in_e = _seg_rank(sorted_e)
    keep = pos_in_e < cap
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, E * cap)
    src_token = order // k                                # (G, M)

    # vmapped 1-group gathers/scatters lower with operand_batching_dims,
    # which GSPMD partitions along G; explicit (G, M) index arrays instead
    # replicate the whole (G, M, D) data movement on every device
    # (measured: 51 GB/device/layer — EXPERIMENTS.md §Perf M2).
    gathered_in = jax.vmap(lambda t, s: t[s])(xf, src_token)     # (G, M, D)
    buf = jax.vmap(
        lambda vals, idx: jnp.zeros((E * cap, D), x.dtype)
        .at[idx].set(vals, mode="drop")
    )(gathered_in, dest)
    buf = buf.reshape(G, E, cap, D)
    buf = logical_constraint(buf, "batch", "experts", None, None)

    h = jnp.einsum("gecd,edf->gecf", buf, p["w_in"])
    if cfg.mlp == "swiglu":
        g = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
        h = jax.nn.silu(g) * h
    elif cfg.mlp == "geglu":
        g = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
        h = jax.nn.gelu(g, approximate=True) * h
    else:
        h = jax.nn.relu(h)
    h = logical_constraint(h, "batch", "experts", None, "expert_ff")
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_out"]).reshape(
        G, E * cap, D
    )
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((G, 1, D), out_buf.dtype)], axis=1
    )  # row E*cap = dropped sentinel (zeros)

    gathered = jax.vmap(lambda t, d: t[d])(out_buf, dest)        # (G, M, D)
    w = (jnp.take_along_axis(topw.reshape(G, M), order, axis=1)
         * keep).astype(x.dtype)
    contrib = gathered * w[..., None]
    out = jax.vmap(
        lambda c, s: jnp.zeros((Ng, D), x.dtype).at[s].add(c)
    )(contrib, src_token)

    aux = {
        "moe_lb_loss": lb_loss,
        "moe_z_loss": z_loss,
        "moe_dropped_frac": 1.0 - keep.mean(),
    }
    return out.reshape(B, S, D), aux
