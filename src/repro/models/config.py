"""Model configuration — one dataclass covering all assigned families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig"]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | rwkv | griffin | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None          # default d_model // n_heads
    mlp: str = "swiglu"                     # swiglu | squared_relu | geglu | relu
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None    # SWA (mixtral) / local attn (griffin)
    tie_embeddings: bool = True
    logit_softcap: Optional[float] = None

    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # griffin (recurrentgemma)
    rnn_width: Optional[int] = None         # d_rnn (defaults 4/3 * d_model)
    conv_width: int = 4
    attn_every: int = 3                     # 1 local-attn per N blocks (1:2)

    # encdec (seamless backbone)
    n_encoder_layers: int = 0

    # modality frontend stub: None | "patches" | "frames"
    frontend: Optional[str] = None
    frontend_len: int = 0                   # patches/frames prepended

    # dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # attention implementation: xla | pallas (pallas only on real TPU runs)
    attn_impl: str = "xla"

    # MoE dispatch groups: tokens are partitioned into G groups and routed
    # group-locally (per-group capacity).  Set G = number of data shards so
    # every sort/rank/scatter in the dispatch is shard-local and the only
    # cross-device exchange is the (G,E,C,D) buffer all-to-all.  G=1 is the
    # single-group (global-capacity) semantics.
    moe_groups: int = 1

    # roofline probes: python-loop over layers so cost_analysis counts every
    # layer (XLA counts while-loop bodies once; see launch/probe.py)
    unroll_layers: bool = False

    max_seq_len: int = 8192

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to x128 (MXU lane alignment + 16-way shardability)."""
        return _round_up(self.vocab, 128)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def d_rnn(self) -> int:
        return self.rnn_width or (self.d_model * 4 // 3)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # parameter count (for MODEL_FLOPS = 6 N D roofline accounting)
    def param_count(self, active_only: bool = False) -> int:
        D, F, V, L = self.d_model, self.d_ff, self.vocab_padded, self.n_layers
        hd, H, Hkv = self.hd, self.n_heads, self.n_kv_heads
        attn = D * H * hd + 2 * D * Hkv * hd + H * hd * D
        if self.family == "rwkv":
            # time-mix r,k,v,g,o + decay lora + channel-mix
            attn = 5 * D * D + 2 * D * 64
            ffn = 2 * D * self.d_ff + self.d_ff * D
            per_layer = attn + ffn
            emb = V * D * (1 if self.tie_embeddings else 2)
            return L * per_layer + emb
        if self.mlp in ("swiglu", "geglu"):
            ffn_dense = 3 * D * F
        else:
            ffn_dense = 2 * D * F
        if self.family == "moe":
            n_e = self.top_k if active_only else self.num_experts
            ffn = n_e * ffn_dense + D * self.num_experts
        else:
            ffn = ffn_dense
        per_layer = attn + ffn
        if self.family == "griffin":
            drnn = self.d_rnn
            rec = 2 * D * drnn + drnn * D + drnn * self.conv_width + 2 * drnn
            n_attn = L // self.attn_every
            n_rec = L - n_attn
            body = n_attn * (attn + ffn) + n_rec * (rec + ffn)
        elif self.family == "encdec":
            # encoder self-attn+ffn, decoder self+cross+ffn
            enc = self.n_encoder_layers * (attn + ffn)
            dec = L * (2 * attn + ffn)
            body = enc + dec
        else:
            body = L * per_layer
        emb = V * D * (1 if self.tie_embeddings else 2)
        return body + emb
