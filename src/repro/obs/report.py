"""Markdown dashboard over one telemetry snapshot.

``python -m repro.obs.report`` renders the same ``Telemetry.snapshot()``
dict every other exporter consumes — the JSON document is the contract,
this module is just a view.  With no arguments it looks for
``benchmarks/telemetry_snapshot.json`` (written by ``bench_shard``'s
wire-to-wire section) and falls back to running a tiny demo workload so
the dashboard always renders something real.

    python -m repro.obs.report                  # last bench snapshot / demo
    python -m repro.obs.report --json snap.json # a specific snapshot
    python -m repro.obs.report --prom           # Prometheus exposition
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

__all__ = ["render_markdown", "demo_snapshot"]

DEFAULT_SNAPSHOT = os.path.join("benchmarks", "telemetry_snapshot.json")


def _fmt(v: float) -> str:
    if v == 0:
        return "0"
    if abs(v) >= 1000:
        return f"{v:,.0f}"
    if abs(v) >= 1:
        return f"{v:.3g}"
    return f"{v:.3g}"


def _ms(v: float) -> str:
    return f"{v * 1e3:.3f}"


def _label_str(labels: Dict[str, str]) -> str:
    return ", ".join(f"{k}={v}" for k, v in labels.items()) or "—"


def _span_lines(span: Dict, depth: int, out: List[str]) -> None:
    fence = " ⏚" if span.get("fenced") else ""
    attrs = span.get("attrs") or {}
    attr_s = (
        " (" + ", ".join(f"{k}={v}" for k, v in attrs.items()) + ")"
        if attrs else ""
    )
    out.append(
        f"{'  ' * depth}- `{span['name']}` [{span['kind']}]{fence} "
        f"{_ms(span['duration_s'])} ms{attr_s}"
    )
    for c in span.get("children", ()):
        _span_lines(c, depth + 1, out)


def render_markdown(snapshot: Dict, title: str = "Telemetry dashboard") -> str:
    """Render one ``Telemetry.snapshot()`` dict as a markdown dashboard."""
    lines = [
        f"# {title}",
        "",
        f"schema_version: {snapshot.get('schema_version')} · "
        f"snapshot time: {snapshot.get('time_s', 0):.3f} s",
        "",
    ]
    metrics = snapshot.get("metrics", {})
    scalars = {
        n: m for n, m in metrics.items() if m["type"] in ("counter", "gauge")
    }
    hists = {n: m for n, m in metrics.items() if m["type"] == "histogram"}

    if scalars:
        lines += [
            "## Counters & gauges",
            "",
            "| metric | type | labels | value | unit |",
            "|---|---|---|---:|---|",
        ]
        for name in sorted(scalars):
            m = scalars[name]
            for s in m["series"]:
                lines.append(
                    f"| `{name}` | {m['type']} | {_label_str(s['labels'])} "
                    f"| {_fmt(s['value'])} | {m['unit']} |"
                )
        lines.append("")

    if hists:
        lines += [
            "## Distributions",
            "",
            "| metric | labels | count | mean | p50 | p95 | p99 | max | unit |",
            "|---|---|---:|---:|---:|---:|---:|---:|---|",
        ]
        for name in sorted(hists):
            m = hists[name]
            for s in m["series"]:
                cnt = s["count"]
                mean = s["sum"] / cnt if cnt else 0.0
                if m["unit"] == "s":
                    cells = [_ms(mean), _ms(s["p50"]), _ms(s["p95"]),
                             _ms(s["p99"]), _ms(s["max"])]
                    unit = "ms"
                else:
                    cells = [_fmt(mean), _fmt(s["p50"]), _fmt(s["p95"]),
                             _fmt(s["p99"]), _fmt(s["max"])]
                    unit = m["unit"]
                lines.append(
                    f"| `{name}` | {_label_str(s['labels'])} | {_fmt(cnt)} | "
                    + " | ".join(cells)
                    + f" | {unit} |"
                )
        lines.append("")

    spans = snapshot.get("spans", [])
    if spans:
        lines += [
            "## Recent request-path spans",
            "",
            "`⏚` marks device-fenced spans (duration includes device "
            "execution, not just async dispatch).",
            "",
        ]
        for s in spans:
            _span_lines(s, 0, lines)
        lines.append("")
    return "\n".join(lines)


def demo_snapshot(tel=None) -> Dict:
    """Run a minimal real workload and return its snapshot (the no-args
    fallback so the dashboard never renders empty).  Pass ``tel`` to keep
    the live registry for other exporters (Prometheus)."""
    import numpy as np

    from repro.core import Col, FeatureView, range_window, rows_window, w_count, w_sum
    from repro.data.synthetic import FRAUD_SCHEMA
    from repro.obs import Telemetry, use_telemetry
    from repro.serve.router import ShardRouter
    from repro.serve.service import BatchScheduler, FeatureService

    amt = Col("amount")
    view = FeatureView(
        "demo",
        FRAUD_SCHEMA,
        {
            "s": w_sum(amt, range_window(600, bucket=64)),
            "c5": w_count(amt, rows_window(5)),
        },
    )
    tel = tel if tel is not None else Telemetry()
    with use_telemetry(tel):
        svc = FeatureService.build(
            "demo", view, num_keys=32, sharded=True, num_shards=4,
            capacity=64,
        )
        router = ShardRouter(
            svc, BatchScheduler(max_batch=16, max_wait_us=2_000)
        )
        rng = np.random.default_rng(0)
        now = 0
        for i in range(48):
            router.submit(
                dict(
                    card=int(rng.integers(0, 32)),
                    ts=100_000 + i,
                    amount=float(rng.gamma(1.5, 60.0)),
                    mcc=int(rng.integers(0, 32)),
                    device=int(rng.integers(0, 8)),
                    geo=int(rng.integers(0, 16)),
                ),
                now_us=now,
            )
            now += 250
            router.pump(now_us=now)
        router.drain(now_us=now)
        svc.store.record_gauges()
        return tel.snapshot()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a telemetry snapshot as a markdown dashboard.",
    )
    ap.add_argument(
        "--json", metavar="PATH", default=None,
        help="snapshot JSON to render (default: "
        f"{DEFAULT_SNAPSHOT} if present, else a demo workload)",
    )
    ap.add_argument(
        "--prom", action="store_true",
        help="emit Prometheus text exposition instead of markdown "
        "(demo workload only; saved snapshots render as markdown)",
    )
    args = ap.parse_args(argv)

    if args.prom:
        from repro.obs import Telemetry

        # Prometheus rendering needs the live registry, not just the
        # snapshot dict, so run the demo against one we keep
        tel = Telemetry()
        demo_snapshot(tel)
        print(tel.to_prometheus())
        return 0

    if args.json is not None:
        with open(args.json) as f:
            snap = json.load(f)
        title = f"Telemetry dashboard — {os.path.basename(args.json)}"
    elif os.path.exists(DEFAULT_SNAPSHOT):
        with open(DEFAULT_SNAPSHOT) as f:
            snap = json.load(f)
        title = f"Telemetry dashboard — {DEFAULT_SNAPSHOT}"
    else:
        snap = demo_snapshot()
        title = "Telemetry dashboard — demo workload"
    print(render_markdown(snap, title=title))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
