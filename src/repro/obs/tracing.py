"""Nested request-path spans with explicit device fencing.

JAX dispatch is asynchronous: a jitted call returns device futures, so a
naive ``perf_counter`` pair around it measures *dispatch* cost, not
compute.  A :class:`Span` therefore carries a ``fence()`` method —
``jax.block_until_ready`` on the stage's outputs — so a span that claims
to measure device time provably contains it.  Host-side stages (queue
wait, shard routing, scatter-back) never fence; device stages always do.
That is the whole host/device attribution story, and it is why ROADMAP
item 1's "measured, not assumed" split is now measured.

Spans nest via a stack (``tracer.span(...)`` context managers), and every
completed span *also* folds its duration into the ``span_seconds{name=}``
histogram in the metric registry — dashboards and benchmarks read the
aggregate without walking trees, while tests can assert on the exact tree
shape under a :class:`~repro.obs.telemetry.FakeClock`.
"""

from __future__ import annotations

import contextlib
from collections import deque
from typing import Any, Deque, Dict, Iterator, List, Optional

__all__ = ["Span", "Tracer", "SPAN_KINDS"]

SPAN_KINDS = ("host", "device")


class Span:
    """One timed stage of the request path (possibly with children).

    ``kind`` is ``"host"`` or ``"device"``; a device span should call
    :meth:`fence` on the stage's outputs before it closes, so the recorded
    duration includes device execution rather than just async dispatch.
    """

    __slots__ = ("name", "kind", "t0", "t1", "attrs", "children", "fenced")

    def __init__(self, name: str, kind: str, t0: float,
                 attrs: Optional[Dict[str, Any]] = None):
        if kind not in SPAN_KINDS:
            raise ValueError(f"span kind must be one of {SPAN_KINDS}: {kind!r}")
        self.name = name
        self.kind = kind
        self.t0 = t0
        self.t1: Optional[float] = None
        self.attrs: Dict[str, Any] = dict(attrs or {})
        self.children: List["Span"] = []
        self.fenced = False

    @property
    def duration_s(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def fence(self, *values: Any) -> Any:
        """``jax.block_until_ready`` the stage outputs inside this span, so
        its duration attributes device compute to this stage (and not to
        whatever host code happens to touch the arrays next).  Returns the
        fenced value(s) unchanged; non-array pytrees pass through."""
        import jax

        out = tuple(jax.block_until_ready(v) for v in values)
        self.fenced = True
        return out[0] if len(out) == 1 else out

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "kind": self.kind,
            "t0_s": self.t0,
            "duration_s": self.duration_s,
            "fenced": self.fenced,
            "attrs": dict(self.attrs),
            "children": [c.to_dict() for c in self.children],
        }

    def tree(self, indent: int = 0) -> str:
        """Human-readable nested rendering (used by the report module)."""
        pad = "  " * indent
        mark = "⏚" if self.fenced else "·"
        lines = [
            f"{pad}{self.name} [{self.kind}] {mark} "
            f"{self.duration_s * 1e3:.3f} ms"
            + (f"  {self.attrs}" if self.attrs else "")
        ]
        for c in self.children:
            lines.append(c.tree(indent + 1))
        return "\n".join(lines)

    def find(self, name: str) -> List["Span"]:
        """All descendants (including self) with the given name."""
        out = [self] if self.name == name else []
        for c in self.children:
            out.extend(c.find(name))
        return out


class _NullSpan:
    """No-op span handle for disabled telemetry — same surface as Span."""

    __slots__ = ()
    name = kind = ""
    attrs: Dict[str, Any] = {}
    duration_s = 0.0
    fenced = False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    def fence(self, *values: Any) -> Any:
        # still fence: disabled telemetry must not change *numerics* or
        # memory pressure, but the overhead baseline should not silently
        # skip synchronization the instrumented path performs
        import jax

        out = tuple(jax.block_until_ready(v) for v in values)
        return out[0] if len(out) == 1 else out


_NULL = _NullSpan()


class Tracer:
    """Stack-based span builder over one clock + metric registry.

    Completed *root* spans are kept in a bounded deque (``capacity``);
    every completed span additionally aggregates into the
    ``span_seconds{name=...}`` histogram so the per-stage breakdown is
    available without tree-walking.
    """

    def __init__(self, clock, registry=None, capacity: int = 256,
                 enabled: bool = True):
        self.clock = clock
        self.registry = registry
        self.capacity = capacity
        self.enabled = enabled
        self._stack: List[Span] = []
        self._roots: Deque[Span] = deque(maxlen=capacity)

    @contextlib.contextmanager
    def span(self, name: str, kind: str = "host",
             **attrs: Any) -> Iterator[Span]:
        if not self.enabled:
            yield _NULL
            return
        s = Span(name, kind, self.clock.now(), attrs)
        self._stack.append(s)
        try:
            yield s
        finally:
            s.t1 = self.clock.now()
            popped = self._stack.pop()
            assert popped is s, "span stack corrupted"
            if self._stack:
                self._stack[-1].children.append(s)
            else:
                self._roots.append(s)
            if self.registry is not None:
                self.registry.histogram(
                    "span_seconds",
                    help="wall time per request-path stage",
                    unit="s",
                    labels=("name", "kind"),
                ).observe(s.duration_s, name=s.name, kind=s.kind)

    def roots(self) -> List[Span]:
        """Completed top-level spans, oldest first (bounded window)."""
        return list(self._roots)

    def last_root(self, name: Optional[str] = None) -> Optional[Span]:
        for s in reversed(self._roots):
            if name is None or s.name == name:
                return s
        return None

    def clear(self) -> None:
        self._roots.clear()
